//! Interactive Table-4 ablation: sweep θ with and without the anchor and
//! print the sparsity/recall/latency frontier (plus the decode-reuse
//! extension statistics from the paged KV pool).
//!
//! ```bash
//! cargo run --release --example ablation_theta -- --n 8192
//! ```

use anchor_attention::attention::anchor::{anchor_attention_timed, AnchorConfig};
use anchor_attention::attention::{metrics, TileConfig};
use anchor_attention::coordinator::kv_cache::PagePool;
use anchor_attention::util::cli::Args;
use anchor_attention::workload::qkv::generate;
use anchor_attention::workload::WorkloadProfile;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.usize_or("n", 8192)?;
    let tile = TileConfig::new(128, 128);
    let step = anchor_attention::experiments::common::scaled_step(n, tile);
    let wl = generate(&WorkloadProfile::llama_like(), n, 42);

    println!("θ sweep on a llama-like head (n = {n}, step = {step}):\n");
    println!(
        "{:<16} {:>5} {:>10} {:>9} {:>9}",
        "arm", "θ", "sparsity", "recall", "ms"
    );
    println!("{}", "─".repeat(54));
    for use_anchor in [true, false] {
        for theta in [10.0f32, 11.0, 12.0, 13.0, 14.0, 15.0] {
            let cfg = AnchorConfig { tile, theta, step, init_blocks: 1, use_anchor };
            let (out, t) = anchor_attention_timed(&wl.head, &cfg);
            let rec = metrics::recall(&wl.head, &out.coverage, tile);
            println!(
                "{:<16} {:>5.1} {:>9.1}% {:>8.1}% {:>9.1}",
                if use_anchor { "with anchor" } else { "without anchor" },
                theta,
                out.coverage.sparsity() * 100.0,
                rec.mean_recall * 100.0,
                t.total_s() * 1e3
            );
        }
        println!();
    }

    // Decode-reuse extension (DESIGN.md §7): per-page stripe statistics.
    println!("decode-reuse extension: per-page stripe heat from prefill identification");
    let cfg = AnchorConfig { tile, theta: 12.0, step, init_blocks: 1, use_anchor: true };
    let out = cfg;
    let attn = anchor_attention::attention::anchor::anchor_attention(&wl.head, &out);
    let page_tokens = 256;
    let mut pool = PagePool::new(n / page_tokens + 1, page_tokens);
    pool.admit(0, n)?;
    // Use the last q block's coverage as the decode-relevant heat.
    let last_qb = attn.coverage.q_blocks() - 1;
    for page in 0..n / page_tokens {
        let start = page * page_tokens;
        let hot = (start..start + page_tokens)
            .filter(|&c| attn.coverage.covered(last_qb, c))
            .count() as f32
            / page_tokens as f32;
        pool.record_stripe_stats(0, start, hot)?;
    }
    let hot_pages = pool.hot_pages(0, 0.5);
    println!(
        "{} of {} pages are ≥50% hot for decode ({}% KV-page reduction available)",
        hot_pages.len(),
        n / page_tokens,
        100 * (n / page_tokens - hot_pages.len()) / (n / page_tokens)
    );
    Ok(())
}

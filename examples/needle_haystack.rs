//! Needle-in-a-haystack demo (Fig. 7's mechanism, single run): plant a
//! needle key at a chosen depth, then show which sparse methods' coverage
//! retains it and how output fidelity at the answer position responds.
//!
//! ```bash
//! cargo run --release --example needle_haystack -- --n 8192 --depth 0.35
//! ```

use anchor_attention::attention::full::full_attention;
use anchor_attention::experiments::common::{evaluate, paper_methods};
use anchor_attention::experiments::tab3_ruler::niah_accuracy;
use anchor_attention::util::cli::Args;
use anchor_attention::workload::qkv::generate_with_needle;
use anchor_attention::workload::WorkloadProfile;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.usize_or("n", 8192)?;
    let depth = args.f64_or("depth", 0.35)?;
    let tile = anchor_attention::attention::TileConfig::new(128, 128);

    println!("planting a needle at depth {:.0}% of a {}-token haystack…", depth * 100.0, n);
    let wl = generate_with_needle(&WorkloadProfile::llama_like(), n, 9, Some(depth));
    let needle = wl.meta.needle.as_ref().unwrap();
    println!("needle at position {} (logit {:.1})", needle.position, needle.logit);

    let full = full_attention(&wl.head, tile);
    println!("\n{:<16} {:>9} {:>9} {:>10} {:>8}", "method", "covered?", "sparsity", "accuracy", "ms");
    println!("{}", "─".repeat(58));
    for m in paper_methods(n, tile, 12.0) {
        let e = evaluate(&wl.head, &m, tile);
        let out = m.session().no_cache().build()?.run(&wl.head)?.into_single();
        let last_qb = out.coverage.q_blocks() - 1;
        let covered = out.coverage.covered(last_qb, needle.position);
        let acc = niah_accuracy(&wl.head, &out.coverage, &out.out, &full.out, needle.position, tile);
        println!(
            "{:<16} {:>9} {:>8.1}% {:>10.1} {:>8.1}",
            e.method,
            if covered { "yes" } else { "NO" },
            e.sparsity * 100.0,
            acc,
            e.latency_s * 1e3
        );
    }
    println!("\n(static patterns lose mid-context needles; anchor's global identification keeps them)");
    Ok(())
}

//! Quickstart: run AnchorAttention on one synthetic head and compare to
//! dense attention — recall, sparsity, output error, and latency — then
//! cross-check the AOT HLO artifact on the PJRT runtime if artifacts are
//! built.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use anchor_attention::attention::anchor::{anchor_attention_timed, AnchorConfig};
use anchor_attention::attention::full::full_attention;
use anchor_attention::attention::{metrics, TileConfig};
use anchor_attention::workload::qkv::generate;
use anchor_attention::workload::WorkloadProfile;

fn main() -> anyhow::Result<()> {
    let n = 8192;
    let tile = TileConfig::new(128, 128);
    println!("generating a llama-like synthetic head (n = {n}, d = 64)…");
    let wl = generate(&WorkloadProfile::llama_like(), n, 42);

    println!("dense attention (FlashAttention-style blocked engine)…");
    let t0 = std::time::Instant::now();
    let full = full_attention(&wl.head, tile);
    let t_full = t0.elapsed().as_secs_f64();

    println!("AnchorAttention (θ = 12, step = 4)…");
    let cfg = AnchorConfig { tile, theta: 12.0, step: 4, init_blocks: 1, use_anchor: true };
    let (out, phases) = anchor_attention_timed(&wl.head, &cfg);
    let rec = metrics::recall(&wl.head, &out.coverage, tile);

    println!("\n── results ───────────────────────────────────────────");
    println!("recall                 {:.2}%", rec.mean_recall * 100.0);
    println!("sparsity               {:.2}%", out.coverage.sparsity() * 100.0);
    println!("output rel. error      {:.2e}", out.out.rel_err(&full.out));
    println!("dense latency          {:.1} ms", t_full * 1e3);
    println!(
        "anchor latency         {:.1} ms  (anchor {:.1} + identify {:.1} + sparse {:.1})",
        phases.total_s() * 1e3,
        phases.anchor_s * 1e3,
        phases.identify_s * 1e3,
        phases.sparse_s * 1e3
    );
    println!("speedup                {:.2}x", t_full / phases.total_s());

    // Cross-check against the AOT artifact when available.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\ncross-checking Pallas AOT artifact over PJRT (n = 256)…");
        let rt = anchor_attention::runtime::Runtime::open("artifacts")?;
        let spec = rt.manifest().anchor;
        let small = generate(&WorkloadProfile::llama_like(), 256, 7);
        let lits = [
            anchor_attention::runtime::literal_f32(&[256, 64], &small.head.q.data)?,
            anchor_attention::runtime::literal_f32(&[256, 64], &small.head.k.data)?,
            anchor_attention::runtime::literal_f32(&[256, 64], &small.head.v.data)?,
        ];
        let hlo_out = rt.execute("attn_anchor_256", &lits)?;
        let hlo = anchor_attention::tensor::Mat::from_vec(256, 64, hlo_out[0].to_vec::<f32>()?);
        let cfg = AnchorConfig {
            tile: TileConfig::new(spec.block, spec.block),
            theta: spec.theta as f32,
            step: spec.step,
            init_blocks: spec.init_blocks,
            use_anchor: true,
        };
        let rust = anchor_attention::attention::anchor::anchor_attention(&small.head, &cfg);
        println!(
            "HLO vs engine max diff  {:.2e}  (three-layer consistency)",
            hlo.max_abs_diff(&rust.out)
        );
    } else {
        println!("\n(run `make artifacts` to also cross-check the Pallas AOT path)");
    }
    Ok(())
}

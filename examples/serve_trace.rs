//! **End-to-end serving driver** (the repo's headline integration proof):
//! loads the AOT-compiled tiny LM through the PJRT runtime, serves a
//! Poisson trace of batched requests through the full coordinator stack
//! (admission queue → paged KV pool → chunked-prefill scheduler → dynamic
//! batcher → engine), and reports latency/throughput — once with the dense
//! scheduler cost model and once with the anchor-sparsity-aware model.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_trace
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use anchor_attention::attention::exec::ExecutorKind;
use anchor_attention::coordinator::engine::PjrtEngine;
use anchor_attention::coordinator::request::Request;
use anchor_attention::coordinator::scheduler::{CostConstants, SparsityModel};
use anchor_attention::coordinator::server::{serve, ServerConfig};
use anchor_attention::workload::trace::{generate_trace, TraceConfig};

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let trace_cfg = TraceConfig {
        rate: 4.0,
        num_requests: 12,
        length_mix: vec![(256, 0.4), (768, 0.4), (1536, 0.2)],
        decode_min: 4,
        decode_max: 12,
        seed: 7,
    };
    let trace = generate_trace(&trace_cfg)?;

    for (label, sparsity) in [
        ("dense scheduler", SparsityModel::Dense),
        (
            "anchor-aware scheduler",
            SparsityModel::Anchor {
                stripe_keep: 0.1,
                anchor_tokens: 256,
                plan_hit_rate: 0.5,
                pipelined: false,
                executor: ExecutorKind::Cpu,
                shards: 1,
                constants: CostConstants::modeled(),
            },
        ),
        (
            "anchor-aware scheduler + async plan pipeline",
            SparsityModel::Anchor {
                stripe_keep: 0.1,
                anchor_tokens: 256,
                plan_hit_rate: 0.5,
                pipelined: true,
                executor: ExecutorKind::Cpu,
                shards: 1,
                constants: CostConstants::modeled(),
            },
        ),
    ] {
        println!("\n════ {label} ══════════════════════════════════════");
        println!("loading engine (compiling artifacts)…");
        let mut engine = PjrtEngine::new("artifacts")?;
        let vocab = engine.vocab() as i32;

        let requests: Vec<Request> = trace
            .iter()
            .map(|t| {
                let len = t.prompt_tokens.min(1800);
                let prompt: Vec<i32> = (0..len)
                    .map(|i| ((t.id as usize * 131 + i * 7) % vocab as usize) as i32)
                    .collect();
                Request::new(t.id, prompt, t.decode_tokens, t.arrival_s)
            })
            .collect();

        let mut cfg = ServerConfig::default();
        cfg.scheduler.sparsity = sparsity;
        cfg.pool_pages = 128;

        let report = serve(&cfg, requests, &mut engine, |e, r| {
            e.register(r.id, r.prompt.clone());
        })?;
        report.print_summary();
    }
    Ok(())
}

"""AOT artifact emission: lower JAX/Pallas graphs to HLO **text** for the
Rust PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the `xla` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Outputs (``make artifacts`` -> artifacts/):
    attn_full_<n>.hlo.txt     dense causal attention, one head  [n,d]³ -> [n,d]
    attn_anchor_<n>.hlo.txt   Alg. 1-3 Pallas pipeline, one head
    lm_prefill256.hlo.txt     chunked prefill step (chunk=256)
    lm_decode.hlo.txt         single-token decode step
    lm_prefill_anchor512.hlo.txt  whole-prompt prefill w/ anchor attention
    weights.bin               flat f32 parameter blob (ordered)
    manifest.json             shapes/dtypes/offsets contract for Rust
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import ref
from .kernels import sparse as sparse_mod

ATTN_D = 64
ANCHOR_CFG = ref.AnchorCfg(block=32, theta=12.0, step=4, init_blocks=1)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return {"dtype": dtype, "shape": list(shape)}


def lower_and_write(fn, args, out_dir, name, inputs, outputs):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {fname}: {len(text)} chars")
    return {"name": name, "file": fname, "inputs": inputs, "outputs": outputs}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-anchor-lm", action="store_true", help="skip the slow anchor-LM artifact")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    artifacts = []
    cfg = model_mod.ModelCfg()
    acfg = ANCHOR_CFG

    # ---- single-head attention ops -------------------------------------
    for n in (256, 512):
        s = jax.ShapeDtypeStruct((n, ATTN_D), jnp.float32)
        artifacts.append(
            lower_and_write(
                lambda q, k, v: (ref.full_attention(q, k, v),),
                (s, s, s),
                args.out,
                f"attn_full_{n}",
                inputs=[spec((n, ATTN_D))] * 3,
                outputs=[spec((n, ATTN_D))],
            )
        )
        artifacts.append(
            lower_and_write(
                lambda q, k, v: (sparse_mod.anchor_attention(q, k, v, acfg),),
                (s, s, s),
                args.out,
                f"attn_anchor_{n}",
                inputs=[spec((n, ATTN_D))] * 3,
                outputs=[spec((n, ATTN_D))],
            )
        )

    # ---- LM serving steps ------------------------------------------------
    params = model_mod.init_params(cfg, seed=0)
    specs = model_mod.param_specs(cfg)
    cache_shape = (cfg.n_layers, cfg.n_kv_heads, cfg.max_seq, cfg.d_head)
    param_inputs = [spec(shape) for _, shape in specs]

    def lm_fn(chunk):
        def fn(*flat):
            nparams = len(specs)
            params_ = list(flat[:nparams])
            ids, kc, vc, pos = flat[nparams:]
            logits, kc2, vc2 = model_mod.step(params_, ids, kc, vc, pos, cfg)
            return (logits, kc2, vc2)

        return fn

    for chunk, name in ((256, "lm_prefill256"), (1, "lm_decode")):
        arg_specs = tuple(
            [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in specs]
            + [
                jax.ShapeDtypeStruct((chunk,), jnp.int32),
                jax.ShapeDtypeStruct(cache_shape, jnp.float32),
                jax.ShapeDtypeStruct(cache_shape, jnp.float32),
                jax.ShapeDtypeStruct((), jnp.int32),
            ]
        )
        artifacts.append(
            lower_and_write(
                lm_fn(chunk),
                arg_specs,
                args.out,
                name,
                inputs=param_inputs
                + [
                    {"dtype": "i32", "shape": [chunk]},
                    spec(cache_shape),
                    spec(cache_shape),
                    {"dtype": "i32", "shape": []},
                ],
                outputs=[spec((chunk, cfg.vocab)), spec(cache_shape), spec(cache_shape)],
            )
        )

    # ---- anchor-attention prefill --------------------------------------
    if not args.skip_anchor_lm:
        n_anchor = acfg.block * acfg.step * 4  # 512
        arg_specs = tuple(
            [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in specs]
            + [jax.ShapeDtypeStruct((n_anchor,), jnp.int32)]
        )

        def anchor_fn(*flat):
            params_ = list(flat[: len(specs)])
            ids = flat[len(specs)]
            return (model_mod.prefill_anchor(params_, ids, cfg, acfg),)

        artifacts.append(
            lower_and_write(
                anchor_fn,
                arg_specs,
                args.out,
                f"lm_prefill_anchor{n_anchor}",
                inputs=param_inputs + [{"dtype": "i32", "shape": [n_anchor]}],
                outputs=[spec((n_anchor, cfg.vocab))],
            )
        )

    # ---- weights + manifest ----------------------------------------------
    blob = np.concatenate([np.asarray(p, np.float32).ravel() for p in params])
    blob.tofile(os.path.join(args.out, "weights.bin"))
    print(f"  weights.bin: {blob.nbytes} bytes ({blob.size} f32)")

    offset = 0
    weight_entries = []
    for (name, shape), p in zip(specs, params):
        count = int(np.prod(shape))
        weight_entries.append({"name": name, "shape": list(shape), "offset": offset, "count": count})
        offset += count

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "d_head": cfg.d_head,
            "d_ffn": cfg.d_ffn,
            "max_seq": cfg.max_seq,
            "prefill_chunk": 256,
        },
        "anchor": {
            "block": acfg.block,
            "theta": acfg.theta,
            "step": acfg.step,
            "init_blocks": acfg.init_blocks,
        },
        "weights": {"file": "weights.bin", "params": weight_entries, "total_f32": int(blob.size)},
        "artifacts": artifacts,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  manifest.json: {len(artifacts)} artifacts")


if __name__ == "__main__":
    main()

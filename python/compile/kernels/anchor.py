"""Pallas kernel for Algorithm 1 — Pattern-based Anchor Computation.

Per query block: exact online-softmax attention over the initial key
block(s) and the group-aligned causal local window, emitting the cached
state `(M, L, Acc)` that Algorithm 3 resumes from (paper §3.4).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _anchor_kernel(
    q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref, *, cfg: ref.AnchorCfg, n: int
):
    qb = pl.program_id(0)
    block = cfg.block
    d = q_ref.shape[-1]
    q = pl.load(q_ref, (pl.ds(qb * block, block), slice(None)))
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    row0 = qb * block
    rows = row0 + jax.lax.iota(jnp.int32, block)

    def fold(j, carry):
        m, l, acc = carry
        col0 = j * block
        k_j = jax.lax.dynamic_slice(k_ref[...], (col0, 0), (block, d))
        v_j = jax.lax.dynamic_slice(v_ref[...], (col0, 0), (block, d))
        s = (q @ k_j.T) * scale
        cols = col0 + jax.lax.iota(jnp.int32, block)
        s = jnp.where(cols[None, :] <= rows[:, None], s, ref.NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_j
        return m_new, l, acc

    m0 = jnp.full((block,), ref.NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block,), dtype=jnp.float32)
    acc0 = jnp.zeros((block, d), dtype=jnp.float32)

    # Window: group-aligned start (Alg. 1 line 8) through the diagonal.
    win_start_blk = qb // cfg.step * cfg.step
    init_blks = jnp.minimum(cfg.init_blocks, win_start_blk)

    # Init blocks not overlapped by the window: j in [0, init_blks).
    state = jax.lax.fori_loop(0, init_blks, fold, (m0, l0, acc0))
    # Window blocks: j in [win_start_blk, qb].
    state = jax.lax.fori_loop(win_start_blk, qb + 1, fold, state)

    m, l, acc = state
    pl.store(m_ref, (pl.ds(qb * block, block),), m)
    pl.store(l_ref, (pl.ds(qb * block, block),), l)
    pl.store(acc_ref, (pl.ds(qb * block, block), slice(None)), acc)


def anchor_state(q, k, v, cfg: ref.AnchorCfg):
    """Run Alg. 1; returns `(m, l, acc)` matching `ref.anchor_state`."""
    n, d = q.shape
    assert n % cfg.block == 0, f"n={n} must be a multiple of block={cfg.block}"
    kernel = functools.partial(_anchor_kernel, cfg=cfg, n=n)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ),
        grid=(n // cfg.block,),
        interpret=True,
    )(q, k, v)

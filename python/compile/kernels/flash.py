"""Pallas baseline: dense causal FlashAttention-style kernel.

Grid over query blocks; K/V stay whole-array (interpret mode stages them;
on real TPU the BlockSpec pipeline would stream `block`-sized windows into
VMEM — see DESIGN.md §5). Online softmax over kv tiles, exactly the
blocked scheme of the Rust engine (`attention/full.rs`).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block: int, n: int):
    qb = pl.program_id(0)
    d = q_ref.shape[-1]
    q = pl.load(q_ref, (pl.ds(qb * block, block), slice(None)))  # [block, d]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    row0 = qb * block
    rows = row0 + jax.lax.iota(jnp.int32, block)

    num_kv = qb + 1  # causal: kv blocks 0..=qb

    def body(j, carry):
        m, l, acc = carry
        col0 = j * block
        k_j = jax.lax.dynamic_slice(k_ref[...], (col0, 0), (block, d))
        v_j = jax.lax.dynamic_slice(v_ref[...], (col0, 0), (block, d))
        s = (q @ k_j.T) * scale
        cols = col0 + jax.lax.iota(jnp.int32, block)
        s = jnp.where(cols[None, :] <= rows[:, None], s, ref.NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_j
        return m_new, l, acc

    m0 = jnp.full((block,), ref.NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block,), dtype=jnp.float32)
    acc0 = jnp.zeros((block, d), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_kv, body, (m0, l0, acc0))
    pl.store(o_ref, (pl.ds(qb * block, block), slice(None)), acc / l[:, None])


def flash_attention(q, k, v, *, block: int = 128):
    """Dense causal attention via the Pallas kernel (interpret mode)."""
    n, d = q.shape
    assert n % block == 0, f"n={n} must be a multiple of block={block}"
    kernel = functools.partial(_flash_kernel, block=block, n=n)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        grid=(n // block,),
        interpret=True,
    )(q, k, v)

"""Pure-jnp reference oracle for the AnchorAttention pipeline.

Dense (O(N²)-memory) implementations of the paper's Algorithms 1-3 with
*identical semantics* to both the Pallas kernels in this package and the
Rust engine (`rust/src/attention/anchor/`): every kernel test asserts
allclose against these functions, and `aot.py` lowers the same math into
the HLO artifacts the Rust runtime cross-checks against the engine.

Conventions: single head, row-major `[n, d]` float32, causal masking,
logits scaled by 1/sqrt(d).
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # finite stand-in for -inf: keeps exp() well-defined


@dataclass(frozen=True)
class AnchorCfg:
    """Mirror of the Rust `AnchorConfig` (b_q == b_kv == block)."""

    block: int = 128
    theta: float = 12.0
    step: int = 16
    init_blocks: int = 1
    use_anchor: bool = True

    def window_start(self, qb: int) -> int:
        """First column of the local window for query block `qb` (Alg. 1)."""
        return (qb // self.step) * self.step * self.block

    def init_cols(self, n: int) -> int:
        return min(self.init_blocks * self.block, n)


def full_attention(q, k, v):
    """Dense causal attention — the numeric baseline."""
    n, d = q.shape
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    causal = jnp.tril(jnp.ones((n, n), dtype=bool))
    s = jnp.where(causal, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return p @ v


def anchor_region_mask(n: int, cfg: AnchorCfg):
    """Boolean `[n, n]` mask of the anchor regions (init ∪ window), causal.

    Row r belongs to query block r // block; its anchor region is
    `[0, init_cols) ∪ [window_start(qb), r]`.
    """
    rows = jnp.arange(n)[:, None]
    cols = jnp.arange(n)[None, :]
    qb = rows // cfg.block
    win = (qb // cfg.step) * cfg.step * cfg.block
    causal = cols <= rows
    in_init = cols < cfg.init_cols(n)
    in_window = cols >= win
    return causal & (in_init | in_window)


def anchor_state(q, k, v, cfg: AnchorCfg):
    """Algorithm 1 (dense form): returns `(m, l, acc)` per row.

    `m` is the row max over the anchor regions (the anchor `x_a`),
    `l` the softmax normalizer over those regions, `acc` the unnormalized
    value accumulator.
    """
    n, d = q.shape
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    region = anchor_region_mask(n, cfg)
    s = jnp.where(region, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(region, jnp.exp(s - m[:, None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = p @ v
    return m, l, acc


def stripe_mask(q, k, m, cfg: AnchorCfg):
    """Algorithm 2 (dense form): boolean `[groups, n]` stripe selection.

    Pooled queries (`avgpool(Q, block)`) of each group are scored against
    every key; a candidate column survives iff
    `avgpool(x_a) − qk ≤ θ` for any pooled row of the group. Columns inside
    the init region or at/after the group's window are not candidates
    (they are already covered by Alg. 1).
    """
    n, d = q.shape
    nb = n // cfg.block
    groups = -(-nb // cfg.step)

    q_pool = q.reshape(nb, cfg.block, d).mean(axis=1)
    a_pool = m.reshape(nb, cfg.block).mean(axis=1)
    if not cfg.use_anchor:
        a_pool = jnp.zeros_like(a_pool)

    s = (q_pool @ k.T) / jnp.sqrt(jnp.float32(d))  # [nb, n]
    hit = (a_pool[:, None] - s) <= cfg.theta  # [nb, n]

    # Pad row-count to a multiple of step, then OR within each group.
    pad = groups * cfg.step - nb
    hit = jnp.pad(hit, ((0, pad), (0, 0)), constant_values=False)
    hit = hit.reshape(groups, cfg.step, n).any(axis=1)  # [groups, n]

    cols = jnp.arange(n)[None, :]
    g = jnp.arange(groups)[:, None]
    candidate = (cols >= cfg.init_cols(n)) & (cols < g * cfg.step * cfg.block)
    return hit & candidate


def coverage_mask(n: int, stripes, cfg: AnchorCfg):
    """Full per-row coverage: anchor regions ∪ the row's group stripes."""
    region = anchor_region_mask(n, cfg)
    rows = jnp.arange(n)
    g = rows // cfg.block // cfg.step
    stripe_rows = stripes[g]  # [n, n]
    causal = jnp.arange(n)[None, :] <= rows[:, None]
    return region | (stripe_rows & causal)


def sparse_output(q, k, v, state, stripes, cfg: AnchorCfg):
    """Algorithm 3 (dense form): softmax over the covered set.

    With exact arithmetic, resuming the online softmax from `(m, l, acc)`
    and folding the gathered stripes equals masked softmax over
    anchor-region ∪ stripes — which is what this computes.
    """
    del state  # the dense form recomputes; kernels resume from the cache
    n, d = q.shape
    cov = coverage_mask(n, stripes, cfg)
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    s = jnp.where(cov, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(cov, jnp.exp(s - m), 0.0)
    return (p @ v) / jnp.sum(p, axis=-1, keepdims=True)


def anchor_attention(q, k, v, cfg: AnchorCfg):
    """The full three-stage pipeline (dense form). Returns (out, stripes)."""
    m, l, acc = anchor_state(q, k, v, cfg)
    stripes = stripe_mask(q, k, m, cfg)
    out = sparse_output(q, k, v, (m, l, acc), stripes, cfg)
    return out, stripes


def recall(q, k, cov_rows):
    """Paper's recall metric: covered fraction of true attention mass.

    `cov_rows` is a boolean `[n, n]` per-row coverage mask.
    """
    n, d = q.shape
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    causal = jnp.tril(jnp.ones((n, n), dtype=bool))
    s = jnp.where(causal, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    covered = jnp.where(cov_rows & causal, p, 0.0).sum(axis=-1)
    return covered.mean()

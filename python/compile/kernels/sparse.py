"""Pallas kernel for Algorithm 3 — Fine-Grained Sparse Computation.

Per query block: resume the online softmax from the cached Alg. 1 state
`(M, L, Acc)` and fold in the surviving stripe columns. Key blocks whose
stripe mask is empty are **skipped entirely** (`lax.cond` — the TPU
realization of block skipping); within a touched block, non-surviving
columns are masked in-VMEM. This is the hardware adaptation of the paper's
discrete gather described in DESIGN.md §5: same skipped computation, block
granularity for the HBM→VMEM schedule, stripe granularity for the scores.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _sparse_kernel(
    q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref, mask_ref, o_ref, *, cfg: ref.AnchorCfg, n: int
):
    qb = pl.program_id(0)
    block = cfg.block
    d = q_ref.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    q = pl.load(q_ref, (pl.ds(qb * block, block), slice(None)))
    g = qb // cfg.step

    # Resume from cached anchor state (§3.4).
    m = pl.load(m_ref, (pl.ds(qb * block, block),))
    l = pl.load(l_ref, (pl.ds(qb * block, block),))
    acc = pl.load(acc_ref, (pl.ds(qb * block, block), slice(None)))

    win_start_blk = qb // cfg.step * cfg.step

    def body(j, carry):
        col0 = j * block
        gmask = pl.load(mask_ref, (pl.ds(g, 1), slice(None)))[0]
        colmask = jax.lax.dynamic_slice(gmask, (col0,), (block,))

        def fold(carry):
            m, l, acc = carry
            k_j = jax.lax.dynamic_slice(k_ref[...], (col0, 0), (block, d))
            v_j = jax.lax.dynamic_slice(v_ref[...], (col0, 0), (block, d))
            s = (q @ k_j.T) * scale
            s = jnp.where(colmask[None, :], s, ref.NEG_INF)
            m_, l_, acc_ = m, l, acc
            m_new = jnp.maximum(m_, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_ - m_new)
            p = jnp.where(colmask[None, :], jnp.exp(s - m_new[:, None]), 0.0)
            l_ = l_ * alpha + jnp.sum(p, axis=-1)
            acc_ = acc_ * alpha[:, None] + p @ v_j
            return m_new, l_, acc_

        # Block skip: untouched when no stripe survives in this key block.
        return jax.lax.cond(jnp.any(colmask), fold, lambda c: c, carry)

    m, l, acc = jax.lax.fori_loop(0, win_start_blk, body, (m, l, acc))
    pl.store(o_ref, (pl.ds(qb * block, block), slice(None)), acc / l[:, None])


def sparse_attention(q, k, v, state, stripes, cfg: ref.AnchorCfg):
    """Run Alg. 3; returns the final output matching `ref.sparse_output`."""
    n, d = q.shape
    m, l, acc = state
    assert n % cfg.block == 0
    kernel = functools.partial(_sparse_kernel, cfg=cfg, n=n)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        grid=(n // cfg.block,),
        interpret=True,
    )(q, k, v, m, l, acc, stripes)


def anchor_attention(q, k, v, cfg: ref.AnchorCfg):
    """Full three-kernel pipeline: Alg. 1 → Alg. 2 → Alg. 3."""
    from . import anchor as anchor_mod
    from . import stripe as stripe_mod

    state = anchor_mod.anchor_state(q, k, v, cfg)
    q_pool, a_pool = stripe_mod.pool_inputs(q, state[0], cfg)
    stripes = stripe_mod.stripe_mask(q_pool, a_pool, k, cfg)
    return sparse_attention(q, k, v, state, stripes, cfg)

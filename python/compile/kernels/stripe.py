"""Pallas kernel for Algorithm 2 — Difference-aware Stripe Sparsity
Identification.

Grid over identification groups (`step` query blocks each). The group's
pooled queries are scored against all keys; a candidate column survives iff
`avgpool(x_a) − qk ≤ θ` for any pooled row (Eq. 2). Emits the boolean
stripe mask `[groups, n]` consumed by the Algorithm 3 kernel.

No sorting anywhere — the selection is one compare per score, the paper's
advantage over top-k / top-cdf (§2.1.1).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _stripe_kernel(qp_ref, ap_ref, k_ref, o_ref, *, cfg: ref.AnchorCfg, n: int):
    g = pl.program_id(0)
    step = cfg.step
    d = qp_ref.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    qg = pl.load(qp_ref, (pl.ds(g * step, step), slice(None)))  # [step, d]
    ag = pl.load(ap_ref, (pl.ds(g * step, step),))  # [step] pooled anchors

    # Pooled scores against every key (on TPU this would tile over K; the
    # selection rule is per-column so tiling is mechanical).
    s = (qg @ k_ref[...].T) * scale  # [step, n]
    hit = jnp.any((ag[:, None] - s) <= cfg.theta, axis=0)  # [n]

    cols = jax.lax.iota(jnp.int32, n)
    candidate = (cols >= cfg.init_cols(n)) & (cols < g * step * cfg.block)
    pl.store(o_ref, (pl.ds(g, 1), slice(None)), (hit & candidate)[None, :])


def stripe_mask(q_pool, anchor_pool, k, cfg: ref.AnchorCfg):
    """Run Alg. 2. `q_pool`/`anchor_pool` are the `avgpool(·, block)` of Q
    and of the Alg. 1 anchors; returns bool `[groups, n]` matching
    `ref.stripe_mask`."""
    nb, d = q_pool.shape
    n = k.shape[0]
    assert nb % cfg.step == 0, f"q blocks {nb} must be a multiple of step={cfg.step}"
    groups = nb // cfg.step
    if not cfg.use_anchor:
        anchor_pool = jnp.zeros_like(anchor_pool)
    kernel = functools.partial(_stripe_kernel, cfg=cfg, n=n)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((groups, n), jnp.bool_),
        grid=(groups,),
        interpret=True,
    )(q_pool, anchor_pool, k)


def pool_inputs(q, m, cfg: ref.AnchorCfg):
    """`avgpool(Q, block)` and `avgpool(x_a, block)` (Alg. 2 lines 1-2)."""
    n, d = q.shape
    nb = n // cfg.block
    q_pool = q.reshape(nb, cfg.block, d).mean(axis=1)
    a_pool = m.reshape(nb, cfg.block).mean(axis=1)
    return q_pool, a_pool

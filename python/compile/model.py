"""L2: tiny GQA transformer LM (build-time JAX, never on the request path).

A LLaMA-style decoder — RMSNorm, RoPE, grouped-query attention, SwiGLU —
sized to serve from the CPU PJRT runtime (≈2.7 M params, synthetic weights;
the paper's 7-8B checkpoints are unavailable offline, see DESIGN.md §1).

Two attention backends:

* ``full``   — dense causal attention against the functional KV cache;
  used by the chunked serving artifacts (`lm_prefill_*`, `lm_decode`).
* ``anchor`` — the paper's pipeline, lowered *from the Pallas kernels* in
  `kernels/` so the HLO artifact exercises the same Alg. 1-3 math the Rust
  engine implements (`lm_prefill_anchor`, `attn_anchor_*`).

Weights are passed as ordered parameter lists (never baked into HLO) so the
artifacts stay small; `aot.py` serializes them to `weights.bin` +
`manifest.json` for the Rust loader.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import ref


@dataclass(frozen=True)
class ModelCfg:
    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    d_head: int = 32
    d_ffn: int = 512
    max_seq: int = 2048
    rope_base: float = 10000.0
    eps: float = 1e-5

    @property
    def kv_groups(self) -> int:
        return self.n_heads // self.n_kv_heads


# ---------------------------------------------------------------------------
# Parameters: ordered (name, shape) list -> init -> flat blob
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelCfg):
    """Ordered (name, shape) list — the contract with the Rust loader."""
    specs = [("embed", (cfg.vocab, cfg.d_model))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "attn_norm", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.n_heads * cfg.d_head)),
            (p + "wk", (cfg.d_model, cfg.n_kv_heads * cfg.d_head)),
            (p + "wv", (cfg.d_model, cfg.n_kv_heads * cfg.d_head)),
            (p + "wo", (cfg.n_heads * cfg.d_head, cfg.d_model)),
            (p + "mlp_norm", (cfg.d_model,)),
            (p + "w_gate", (cfg.d_model, cfg.d_ffn)),
            (p + "w_up", (cfg.d_model, cfg.d_ffn)),
            (p + "w_down", (cfg.d_ffn, cfg.d_model)),
        ]
    specs += [("final_norm", (cfg.d_model,)), ("lm_head", (cfg.d_model, cfg.vocab))]
    return specs


def init_params(cfg: ModelCfg, seed: int = 0):
    """Deterministic synthetic weights (truncated-normal-ish scaling)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else cfg.d_model
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(jnp.float32(fan_in))
            )
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope(x, positions, base):
    """x: [n, heads, d_head]; positions: [n]."""
    d = x.shape[-1]
    half = d // 2
    freqs = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [n, half]
    cos = jnp.cos(ang)[:, None, :]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _cache_attention(q, kcache, vcache, q_positions, valid_len, cfg: ModelCfg):
    """Causal attention of q [n, H, dh] over caches [Hkv, max, dh]."""
    n = q.shape[0]
    maxlen = kcache.shape[1]
    # GQA: expand kv heads to query heads.
    k = jnp.repeat(kcache, cfg.kv_groups, axis=0)  # [H, max, dh]
    v = jnp.repeat(vcache, cfg.kv_groups, axis=0)
    qh = jnp.transpose(q, (1, 0, 2))  # [H, n, dh]
    s = jnp.einsum("hnd,hmd->hnm", qh, k) / jnp.sqrt(jnp.float32(cfg.d_head))
    key_pos = jnp.arange(maxlen)
    mask = (key_pos[None, :] <= q_positions[:, None]) & (key_pos[None, :] < valid_len)
    s = jnp.where(mask[None, :, :], s, ref.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("hnm,hmd->hnd", p, v)  # [H, n, dh]
    return jnp.transpose(out, (1, 0, 2)).reshape(n, cfg.n_heads * cfg.d_head)


def step(params, ids, kcache, vcache, pos, cfg: ModelCfg):
    """One chunk step (prefill chunk or single decode token).

    ids:    i32 [chunk]           token ids
    kcache: f32 [L, Hkv, max, dh] functional KV cache (updated copy returned)
    vcache: f32 [L, Hkv, max, dh]
    pos:    i32 scalar            absolute position of ids[0]

    Returns (logits [chunk, vocab], kcache', vcache').
    """
    n = ids.shape[0]
    it = iter(params)

    def nxt():
        return next(it)

    embed = nxt()
    x = embed[ids]  # [n, d_model]
    positions = pos + jnp.arange(n)

    new_k, new_v = [], []
    for layer in range(cfg.n_layers):
        attn_norm, wq, wk, wv, wo = nxt(), nxt(), nxt(), nxt(), nxt()
        mlp_norm, w_gate, w_up, w_down = nxt(), nxt(), nxt(), nxt()

        h = rmsnorm(x, attn_norm, cfg.eps)
        q = (h @ wq).reshape(n, cfg.n_heads, cfg.d_head)
        k = (h @ wk).reshape(n, cfg.n_kv_heads, cfg.d_head)
        v = (h @ wv).reshape(n, cfg.n_kv_heads, cfg.d_head)
        q = rope(q, positions, cfg.rope_base)
        k = rope(k, positions, cfg.rope_base)

        # Functional cache update at [pos, pos+n).
        kc = jax.lax.dynamic_update_slice(
            kcache[layer], jnp.transpose(k, (1, 0, 2)), (0, pos, 0)
        )
        vc = jax.lax.dynamic_update_slice(
            vcache[layer], jnp.transpose(v, (1, 0, 2)), (0, pos, 0)
        )
        new_k.append(kc)
        new_v.append(vc)

        attn = _cache_attention(q, kc, vc, positions, pos + n, cfg)
        x = x + attn @ wo

        h = rmsnorm(x, mlp_norm, cfg.eps)
        x = x + (jax.nn.silu(h @ w_gate) * (h @ w_up)) @ w_down

    final_norm, lm_head = nxt(), nxt()
    logits = rmsnorm(x, final_norm, cfg.eps) @ lm_head
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def empty_caches(cfg: ModelCfg):
    shape = (cfg.n_layers, cfg.n_kv_heads, cfg.max_seq, cfg.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


# ---------------------------------------------------------------------------
# Anchor-attention prefill (the paper's pipeline inside the model)
# ---------------------------------------------------------------------------


def prefill_anchor(params, ids, cfg: ModelCfg, acfg: ref.AnchorCfg):
    """Whole-prompt prefill whose self-attention is AnchorAttention,
    lowered from the Pallas kernels (Alg. 1-3). Returns logits [n, vocab].

    Prompt length must be a multiple of ``acfg.block * acfg.step``.
    """
    from .kernels import sparse as sparse_mod

    n = ids.shape[0]
    it = iter(params)

    def nxt():
        return next(it)

    x = nxt()[ids]
    positions = jnp.arange(n)

    def head_attn(q, k, v):
        return sparse_mod.anchor_attention(q, k, v, acfg)

    for _ in range(cfg.n_layers):
        attn_norm, wq, wk, wv, wo = nxt(), nxt(), nxt(), nxt(), nxt()
        mlp_norm, w_gate, w_up, w_down = nxt(), nxt(), nxt(), nxt()

        h = rmsnorm(x, attn_norm, cfg.eps)
        q = (h @ wq).reshape(n, cfg.n_heads, cfg.d_head)
        k = (h @ wk).reshape(n, cfg.n_kv_heads, cfg.d_head)
        v = (h @ wv).reshape(n, cfg.n_kv_heads, cfg.d_head)
        q = rope(q, positions, cfg.rope_base)
        k = rope(k, positions, cfg.rope_base)
        # GQA expand, then per-head anchor attention.
        k = jnp.repeat(k, cfg.kv_groups, axis=1)
        v = jnp.repeat(v, cfg.kv_groups, axis=1)
        attn = jax.vmap(head_attn, in_axes=1, out_axes=1)(q, k, v)
        x = x + attn.reshape(n, cfg.n_heads * cfg.d_head) @ wo

        h = rmsnorm(x, mlp_norm, cfg.eps)
        x = x + (jax.nn.silu(h @ w_gate) * (h @ w_up)) @ w_down

    final_norm, lm_head = nxt(), nxt()
    return rmsnorm(x, final_norm, cfg.eps) @ lm_head

"""Shared pytest config: these modules exercise the JAX/Pallas layer (and
hypothesis for the property suite), so when those deps are absent (the
hermetic CI image installs them best-effort) the dependent modules are
skipped at collection instead of erroring. `test_smoke.py` always runs."""

import importlib.util
import os
import sys

# Tests import `compile.*` relative to `python/`.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _missing(*mods):
    return any(importlib.util.find_spec(m) is None for m in mods)


collect_ignore = []
if _missing("jax"):
    collect_ignore += ["test_aot.py", "test_kernels_vs_ref.py", "test_model.py"]
if _missing("jax", "hypothesis"):
    collect_ignore += ["test_kernel_properties.py"]

"""AOT path: HLO text round-trips through jax's own HLO parser and the
emitted artifacts execute with correct numerics (CPU client)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def artifacts_present():
    return os.path.exists(os.path.join(ART, "manifest.json"))


class TestHloText:
    def test_lower_simple_fn(self):
        s = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        lowered = jax.jit(lambda q, k, v: (ref.full_attention(q, k, v),)).lower(s, s, s)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "f32[4,4]" in text

    def test_pallas_pipeline_lowers(self):
        from compile.kernels import sparse as sparse_mod

        cfg = ref.AnchorCfg(block=8, theta=2.0, step=2)
        s = jax.ShapeDtypeStruct((32, 8), jnp.float32)
        lowered = jax.jit(lambda q, k, v: (sparse_mod.anchor_attention(q, k, v, cfg),)).lower(
            s, s, s
        )
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text


@pytest.mark.skipif(not artifacts_present(), reason="run `make artifacts` first")
class TestManifest:
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_artifact_files_exist(self):
        m = self.manifest()
        assert len(m["artifacts"]) >= 6
        for a in m["artifacts"]:
            assert os.path.exists(os.path.join(ART, a["file"])), a["file"]

    def test_weights_blob_size_matches(self):
        m = self.manifest()
        blob = os.path.getsize(os.path.join(ART, m["weights"]["file"]))
        assert blob == m["weights"]["total_f32"] * 4
        # Offsets are contiguous.
        off = 0
        for p in m["weights"]["params"]:
            assert p["offset"] == off
            off += p["count"]
        assert off == m["weights"]["total_f32"]

    def test_attn_artifact_io_shapes(self):
        m = self.manifest()
        byname = {a["name"]: a for a in m["artifacts"]}
        a = byname["attn_full_256"]
        assert a["inputs"] == [{"dtype": "f32", "shape": [256, 64]}] * 3
        assert a["outputs"] == [{"dtype": "f32", "shape": [256, 64]}]

    def test_hlo_parseable_and_numerically_correct(self):
        """Load attn_full_256 HLO text back and execute: must equal ref."""
        from jax._src.lib import xla_client as xc

        with open(os.path.join(ART, "attn_full_256.hlo.txt")) as f:
            text = f.read()
        # jax's bundled XLA can parse-and-run the text via the HLO API.
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None

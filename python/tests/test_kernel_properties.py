"""Hypothesis sweeps over the Pallas kernels: shapes, dtypes, θ, step —
each case asserts allclose against the pure-jnp oracle (ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import flash as flash_mod
from compile.kernels import ref
from compile.kernels import sparse as sparse_mod

# Interpret-mode pallas is slow; keep the search space tight but real.
SETTINGS = dict(max_examples=12, deadline=None)


def rand_qkv(seed, n, d, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    mk = lambda k: jax.random.normal(k, (n, d), jnp.float32).astype(dtype).astype(jnp.float32)
    return mk(kq), mk(kk), mk(kv)


@settings(**SETTINGS)
@given(
    blocks=st.integers(min_value=2, max_value=6),
    block=st.sampled_from([8, 16, 32]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_flash_matches_ref_across_shapes(blocks, block, d, seed):
    n = blocks * block
    q, k, v = rand_qkv(seed, n, d)
    got = flash_mod.flash_attention(q, k, v, block=block)
    want = ref.full_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(
    groups=st.integers(min_value=1, max_value=3),
    step=st.sampled_from([2, 4]),
    theta=st.floats(min_value=-5.0, max_value=20.0, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_anchor_pipeline_matches_ref_across_theta(groups, step, theta, seed):
    block = 16
    d = 8
    n = groups * step * block
    cfg = ref.AnchorCfg(block=block, theta=float(theta), step=step, init_blocks=1)
    q, k, v = rand_qkv(seed, n, d)
    got = sparse_mod.anchor_attention(q, k, v, cfg)
    want, _ = ref.anchor_attention(q, k, v, cfg)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(
    seed=st.integers(min_value=0, max_value=2**31),
    use_anchor=st.booleans(),
)
def test_stripe_monotonicity_property(seed, use_anchor):
    """Stripe sets grow monotonically with θ (kernel-level invariant)."""
    from compile.kernels import anchor as anchor_mod
    from compile.kernels import stripe as stripe_mod

    n, d, block, step = 128, 8, 16, 2
    q, k, v = rand_qkv(seed, n, d)
    base = ref.AnchorCfg(block=block, theta=0.0, step=step, use_anchor=use_anchor)
    m, _, _ = anchor_mod.anchor_state(q, k, v, base)
    q_pool, a_pool = stripe_mod.pool_inputs(q, m, base)
    lo = stripe_mod.stripe_mask(q_pool, a_pool, k, base)
    hi_cfg = ref.AnchorCfg(block=block, theta=5.0, step=step, use_anchor=use_anchor)
    hi = stripe_mod.stripe_mask(q_pool, a_pool, k, hi_cfg)
    assert bool(jnp.all(hi | ~lo)), "θ=0 selection must be a subset of θ=5"


@settings(**SETTINGS)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_output_rows_convex_combinations(seed):
    """Kernel outputs stay in the convex hull of V rows (softmax property)."""
    cfg = ref.AnchorCfg(block=16, theta=3.0, step=2)
    q, k, v = rand_qkv(seed, 96, 8)
    out = sparse_mod.anchor_attention(q, k, v, cfg)
    vmin = jnp.min(v, axis=0) - 1e-4
    vmax = jnp.max(v, axis=0) + 1e-4
    assert bool(jnp.all(out >= vmin[None, :])) and bool(jnp.all(out <= vmax[None, :]))

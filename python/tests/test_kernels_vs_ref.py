"""Pallas kernels (interpret=True) vs the pure-jnp oracle in ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import anchor as anchor_mod
from compile.kernels import flash as flash_mod
from compile.kernels import ref
from compile.kernels import sparse as sparse_mod
from compile.kernels import stripe as stripe_mod


def rand_qkv(seed, n, d):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    return (
        jax.random.normal(kq, (n, d), jnp.float32),
        jax.random.normal(kk, (n, d), jnp.float32),
        jax.random.normal(kv, (n, d), jnp.float32),
    )


CFG = ref.AnchorCfg(block=16, theta=2.0, step=2, init_blocks=1)


class TestFlash:
    @pytest.mark.parametrize("n,d,block", [(64, 8, 16), (128, 16, 32), (64, 32, 64)])
    def test_matches_ref(self, n, d, block):
        q, k, v = rand_qkv(0, n, d)
        got = flash_mod.flash_attention(q, k, v, block=block)
        want = ref.full_attention(q, k, v)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_first_row_is_v0(self):
        q, k, v = rand_qkv(1, 32, 8)
        got = flash_mod.flash_attention(q, k, v, block=16)
        np.testing.assert_allclose(got[0], v[0], rtol=1e-5, atol=1e-6)


class TestAnchorState:
    def test_matches_ref(self):
        q, k, v = rand_qkv(2, 96, 8)
        m, l, acc = anchor_mod.anchor_state(q, k, v, CFG)
        m_r, l_r, acc_r = ref.anchor_state(q, k, v, CFG)
        np.testing.assert_allclose(m, m_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(l, l_r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(acc, acc_r, rtol=1e-4, atol=1e-4)

    def test_multi_init_blocks(self):
        cfg = ref.AnchorCfg(block=16, theta=2.0, step=2, init_blocks=2)
        q, k, v = rand_qkv(3, 128, 8)
        m, l, acc = anchor_mod.anchor_state(q, k, v, cfg)
        m_r, l_r, acc_r = ref.anchor_state(q, k, v, cfg)
        np.testing.assert_allclose(m, m_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(acc, acc_r, rtol=1e-4, atol=1e-4)


class TestStripeMask:
    def test_matches_ref(self):
        q, k, v = rand_qkv(4, 128, 8)
        m_r, _, _ = ref.anchor_state(q, k, v, CFG)
        q_pool, a_pool = stripe_mod.pool_inputs(q, m_r, CFG)
        got = stripe_mod.stripe_mask(q_pool, a_pool, k, CFG)
        want = ref.stripe_mask(q, k, m_r, CFG)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_without_anchor(self):
        cfg = ref.AnchorCfg(block=16, theta=0.5, step=2, use_anchor=False)
        q, k, v = rand_qkv(5, 128, 8)
        m_r, _, _ = ref.anchor_state(q, k, v, cfg)
        q_pool, a_pool = stripe_mod.pool_inputs(q, m_r, cfg)
        got = stripe_mod.stripe_mask(q_pool, a_pool, k, cfg)
        want = ref.stripe_mask(q, k, m_r, cfg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestSparse:
    def test_pipeline_matches_ref(self):
        q, k, v = rand_qkv(6, 128, 8)
        got = sparse_mod.anchor_attention(q, k, v, CFG)
        want, _ = ref.anchor_attention(q, k, v, CFG)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_infinite_theta_equals_full(self):
        cfg = ref.AnchorCfg(block=16, theta=1e9, step=2)
        q, k, v = rand_qkv(7, 96, 8)
        got = sparse_mod.anchor_attention(q, k, v, cfg)
        want = ref.full_attention(q, k, v)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_tiny_theta_equals_anchor_only(self):
        cfg = ref.AnchorCfg(block=16, theta=-1e9, step=2)
        q, k, v = rand_qkv(8, 96, 8)
        got = sparse_mod.anchor_attention(q, k, v, cfg)
        m, l, acc = ref.anchor_state(q, k, v, cfg)
        want = acc / l[:, None]
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestRefInvariants:
    def test_recall_of_full_coverage_is_one(self):
        q, k, _ = rand_qkv(9, 64, 8)
        cov = jnp.ones((64, 64), dtype=bool)
        assert abs(float(ref.recall(q, k, cov)) - 1.0) < 1e-6

    def test_anchor_coverage_recall_below_one(self):
        q, k, v = rand_qkv(10, 128, 8)
        _, stripes = ref.anchor_attention(q, k, v, CFG)
        cov = ref.coverage_mask(128, stripes, CFG)
        r = float(ref.recall(q, k, cov))
        assert 0.0 < r <= 1.0 + 1e-6

    def test_stripes_monotone_in_theta(self):
        q, k, v = rand_qkv(11, 128, 8)
        m, _, _ = ref.anchor_state(q, k, v, CFG)
        lo = ref.stripe_mask(q, k, m, ref.AnchorCfg(block=16, theta=0.0, step=2))
        hi = ref.stripe_mask(q, k, m, ref.AnchorCfg(block=16, theta=4.0, step=2))
        assert bool(jnp.all(hi | ~lo))  # lo ⊆ hi

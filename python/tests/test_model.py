"""L2 model: shapes, cache semantics, chunked-vs-monolithic consistency."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as model_mod
from compile.kernels import ref

CFG = model_mod.ModelCfg(
    vocab=64, d_model=32, n_layers=2, n_heads=4, n_kv_heads=2, d_head=8, d_ffn=64, max_seq=128
)


def make_params():
    return model_mod.init_params(CFG, seed=1)


class TestParams:
    def test_spec_order_matches_init(self):
        specs = model_mod.param_specs(CFG)
        params = make_params()
        assert len(specs) == len(params)
        for (name, shape), p in zip(specs, params):
            assert tuple(shape) == p.shape, name

    def test_deterministic(self):
        a = model_mod.init_params(CFG, seed=3)
        b = model_mod.init_params(CFG, seed=3)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_norm_params_are_ones(self):
        specs = model_mod.param_specs(CFG)
        for (name, _), p in zip(specs, make_params()):
            if name.endswith("norm"):
                assert bool(jnp.all(p == 1.0))


class TestStep:
    def test_shapes(self):
        params = make_params()
        k, v = model_mod.empty_caches(CFG)
        ids = jnp.arange(16, dtype=jnp.int32)
        logits, k2, v2 = model_mod.step(params, ids, k, v, jnp.int32(0), CFG)
        assert logits.shape == (16, CFG.vocab)
        assert k2.shape == k.shape and v2.shape == v.shape

    def test_cache_written_at_position(self):
        params = make_params()
        k, v = model_mod.empty_caches(CFG)
        ids = jnp.arange(8, dtype=jnp.int32)
        _, k2, _ = model_mod.step(params, ids, k, v, jnp.int32(16), CFG)
        # Rows 16..24 must be non-zero; rows after must stay zero.
        assert float(jnp.abs(k2[:, :, 16:24, :]).sum()) > 0
        assert float(jnp.abs(k2[:, :, 24:, :]).sum()) == 0

    def test_chunked_prefill_matches_monolithic(self):
        """Prefill in two chunks == prefill in one chunk (KV-cache exactness)."""
        params = make_params()
        ids = jnp.array(np.random.RandomState(0).randint(0, CFG.vocab, 32), jnp.int32)

        k, v = model_mod.empty_caches(CFG)
        logits_all, _, _ = model_mod.step(params, ids, k, v, jnp.int32(0), CFG)

        k, v = model_mod.empty_caches(CFG)
        l1, k, v = model_mod.step(params, ids[:16], k, v, jnp.int32(0), CFG)
        l2, k, v = model_mod.step(params, ids[16:], k, v, jnp.int32(16), CFG)
        np.testing.assert_allclose(l1, logits_all[:16], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(l2, logits_all[16:], rtol=1e-4, atol=1e-4)

    def test_decode_matches_prefill_tail(self):
        """Token-by-token decode == monolithic prefill for the same tokens."""
        params = make_params()
        ids = jnp.array([3, 17, 42, 9], jnp.int32)
        k, v = model_mod.empty_caches(CFG)
        logits_all, _, _ = model_mod.step(params, ids, k, v, jnp.int32(0), CFG)

        k, v = model_mod.empty_caches(CFG)
        for t in range(4):
            lt, k, v = model_mod.step(params, ids[t : t + 1], k, v, jnp.int32(t), CFG)
            np.testing.assert_allclose(lt[0], logits_all[t], rtol=1e-4, atol=1e-4)

    def test_causality(self):
        """Changing a later token must not affect earlier logits."""
        params = make_params()
        k, v = model_mod.empty_caches(CFG)
        a = jnp.array([1, 2, 3, 4, 5, 6, 7, 8], jnp.int32)
        b = a.at[6].set(33)
        la, _, _ = model_mod.step(params, a, k, v, jnp.int32(0), CFG)
        lb, _, _ = model_mod.step(params, b, k, v, jnp.int32(0), CFG)
        np.testing.assert_allclose(la[:6], lb[:6], rtol=1e-5, atol=1e-5)
        assert float(jnp.abs(la[6] - lb[6]).max()) > 1e-4


class TestAnchorPrefill:
    def test_runs_and_matches_full_at_huge_theta(self):
        """θ→∞ anchor prefill == full-attention prefill (whole prompt)."""
        acfg = ref.AnchorCfg(block=8, theta=1e9, step=2, init_blocks=1)
        params = make_params()
        n = acfg.block * acfg.step * 2  # 32
        ids = jnp.array(np.random.RandomState(1).randint(0, CFG.vocab, n), jnp.int32)

        logits_anchor = model_mod.prefill_anchor(params, ids, CFG, acfg)
        k, v = model_mod.empty_caches(CFG)
        logits_full, _, _ = model_mod.step(params, ids, k, v, jnp.int32(0), CFG)
        np.testing.assert_allclose(logits_anchor, logits_full, rtol=1e-3, atol=1e-3)

    def test_finite_theta_close_to_full(self):
        acfg = ref.AnchorCfg(block=8, theta=8.0, step=2, init_blocks=1)
        params = make_params()
        n = 32
        ids = jnp.array(np.random.RandomState(2).randint(0, CFG.vocab, n), jnp.int32)
        logits_anchor = model_mod.prefill_anchor(params, ids, CFG, acfg)
        k, v = model_mod.empty_caches(CFG)
        logits_full, _, _ = model_mod.step(params, ids, k, v, jnp.int32(0), CFG)
        # Sparse prefill approximates dense: correlation of next-token
        # distributions stays high.
        pa = jax.nn.softmax(logits_anchor[-1])
        pf = jax.nn.softmax(logits_full[-1])
        assert float(jnp.abs(pa - pf).sum()) < 0.5, "TV distance too large"

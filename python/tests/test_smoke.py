"""Dependency-free smoke checks: repo layout and kernel-source invariants
that must hold even when JAX is unavailable (keeps `pytest python/tests`
meaningful on hermetic runners)."""

import os

HERE = os.path.dirname(__file__)
KERNELS = os.path.join(HERE, "..", "compile", "kernels")


def test_kernel_modules_present():
    for name in ["ref.py", "flash.py", "anchor.py", "stripe.py", "sparse.py"]:
        assert os.path.exists(os.path.join(KERNELS, name)), name


def test_aot_entrypoint_present():
    assert os.path.exists(os.path.join(HERE, "..", "compile", "aot.py"))
    assert os.path.exists(os.path.join(HERE, "..", "compile", "model.py"))


def test_kernels_do_not_hardcode_interpret_false():
    # Pallas kernels must stay runnable on CPU CI: interpret mode has to be
    # caller-controllable, never pinned off in the source.
    for name in ["flash.py", "anchor.py", "stripe.py", "sparse.py"]:
        with open(os.path.join(KERNELS, name)) as f:
            src = f.read()
        assert "interpret=False" not in src, f"{name} pins interpret=False"

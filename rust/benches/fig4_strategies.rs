//! `cargo bench --bench fig4_strategies` — regenerates the corresponding paper
//! table/figure (see DESIGN.md §3). Set ANCHOR_BENCH_QUICK=1 for a fast
//! reduced-scale pass.

use anchor_attention::experiments::{fig4_strategies, ExpScale};

fn main() {
    let quick = std::env::var("ANCHOR_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let scale = ExpScale::from_quick_flag(quick);
    let seed = 42;
    let t0 = std::time::Instant::now();
    let _ = fig4_strategies::run(scale, seed);
    println!("\n[fig4_strategies] done in {:.1}s", t0.elapsed().as_secs_f64());
}

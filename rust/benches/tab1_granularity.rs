//! `cargo bench --bench tab1_granularity` — regenerates the corresponding paper
//! table/figure (see DESIGN.md §3). Set ANCHOR_BENCH_QUICK=1 for a fast
//! reduced-scale pass.

use anchor_attention::experiments::{tab1_granularity, ExpScale};

fn main() {
    let quick = std::env::var("ANCHOR_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let scale = ExpScale::from_quick_flag(quick);
    let seed = 42;
    let t0 = std::time::Instant::now();
    let _ = tab1_granularity::run(scale, seed);
    println!("\n[tab1_granularity] done in {:.1}s", t0.elapsed().as_secs_f64());
}

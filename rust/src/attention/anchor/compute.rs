//! Algorithm 1 — Pattern-based Anchor Computation.
//!
//! For every query block, run exact blocked attention over the two regions
//! where row maxima concentrate (paper §2.2.2): the initial key block(s)
//! (attention sink) and the group-aligned causal local window. The
//! resulting online-softmax state `(M, L, Acc)` is cached per row; `M` is
//! the anchor score `x_a` of Eq. 1.

use super::{AnchorConfig, AnchorState};
use crate::attention::full::{mask_tile_causal, BlockState};
use crate::attention::mask::Coverage;
use crate::attention::{CostTally, HeadInput};
use crate::tensor::{matmul_nt_scaled, Mat};
use crate::util::threadpool::parallel_map;

/// Run Alg. 1. Returns the cached state plus the coverage of the anchor
/// regions (init ∪ window per query block).
pub fn anchor_pass(input: &HeadInput, cfg: &AnchorConfig) -> (AnchorState, Coverage) {
    let n = input.n();
    let d = input.d();
    let scale = input.scale();
    let tile = cfg.tile;
    let q_blocks = tile.q_blocks(n);
    let init_cols = cfg.init_cols(n);

    let results = parallel_map(q_blocks, |qb| {
        let row0 = qb * tile.b_q;
        let rows = (n - row0).min(tile.b_q);
        let limit = row0 + rows;
        let q_i = input.q.rows_mat(row0, rows);
        let mut state = BlockState::new(rows, d);
        let mut cost = CostTally::default();

        // Region spans: [0, init_cols) ∪ [win_start, limit), merged when
        // they overlap (early blocks).
        let win_start = cfg.window_start(qb).min(limit);
        let spans: [(usize, usize); 2] = if win_start <= init_cols {
            // Window reaches into the init region: one merged span.
            [(0, limit), (0, 0)]
        } else {
            [(0, init_cols.min(limit)), (win_start, limit)]
        };

        let mut s = Mat::zeros(rows, tile.b_kv);
        for (start, end) in spans {
            if start >= end {
                continue;
            }
            let mut col0 = start;
            while col0 < end {
                let cols = (end - col0).min(tile.b_kv);
                let k_j = input.k.rows_mat(col0, cols);
                let v_j = input.v.rows_mat(col0, cols);
                if s.cols != cols || s.rows != rows {
                    s = Mat::zeros(rows, cols);
                }
                matmul_nt_scaled(&q_i, &k_j, scale, &mut s);
                if col0 + cols > row0 {
                    mask_tile_causal(&mut s, row0, col0);
                }
                state.fold_tile(&mut s, &v_j);
                cost.add(CostTally::attn_tile(rows, cols, d));
                col0 += cols;
            }
        }
        (state, cost, win_start, limit)
    });

    let mut m = vec![f32::NEG_INFINITY; n];
    let mut l = vec![0.0f32; n];
    let mut acc = Mat::zeros(n, d);
    let mut cost = CostTally::default();
    let mut coverage = Coverage::new(n, tile.b_q);

    for (qb, (state, c, win_start, limit)) in results.into_iter().enumerate() {
        let row0 = qb * tile.b_q;
        let rows = state.l.len();
        m[row0..row0 + rows].copy_from_slice(&state.m);
        l[row0..row0 + rows].copy_from_slice(&state.l);
        acc.data[row0 * d..(row0 + rows) * d].copy_from_slice(&state.acc.data);
        cost.add(c);
        coverage.set_range(qb, 0, init_cols.min(limit));
        coverage.set_range(qb, win_start, limit);
    }

    (AnchorState { m, l, acc, cost }, coverage)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::TileConfig;
    use crate::tensor::ops::causal_mask_inplace;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn cfg(b: usize, step: usize) -> AnchorConfig {
        AnchorConfig {
            tile: TileConfig::new(b, b),
            theta: 12.0,
            step,
            init_blocks: 1,
            use_anchor: true,
        }
    }

    /// Reference: per-row max over the anchor regions from the naive score
    /// matrix must equal the cached M.
    #[test]
    fn anchor_m_is_region_max() {
        let n = 128;
        let d = 8;
        let h = rand_head(21, n, d);
        let c = cfg(16, 2);
        let (state, _) = anchor_pass(&h, &c);

        let mut s = Mat::zeros(n, n);
        matmul_nt_scaled(&h.q, &h.k, h.scale(), &mut s);
        causal_mask_inplace(&mut s, 0, 0);

        for r in 0..n {
            let qb = r / 16;
            let win = c.window_start(qb);
            let mut expect = f32::NEG_INFINITY;
            for col in 0..=r {
                if col < c.init_cols(n) || col >= win {
                    expect = expect.max(s.at(r, col));
                }
            }
            assert!(
                (state.m[r] - expect).abs() < 1e-5,
                "row {r}: m={} expect={expect}",
                state.m[r]
            );
        }
    }

    /// The normalized anchor state (Acc/L) must equal softmax attention
    /// restricted to the anchor regions.
    #[test]
    fn anchor_acc_matches_masked_softmax() {
        let n = 96;
        let d = 8;
        let h = rand_head(22, n, d);
        let c = cfg(16, 2);
        let (state, coverage) = anchor_pass(&h, &c);

        let mut s = Mat::zeros(n, n);
        matmul_nt_scaled(&h.q, &h.k, h.scale(), &mut s);
        causal_mask_inplace(&mut s, 0, 0);
        // Mask out non-anchor region.
        for r in 0..n {
            let qb = r / 16;
            for col in 0..n {
                if !coverage.covered(qb, col) {
                    s.set(r, col, f32::NEG_INFINITY);
                }
            }
        }
        crate::tensor::ops::softmax_rows(&mut s);
        let mut expect = Mat::zeros(n, d);
        crate::tensor::matmul_nn_acc(&s, &h.v, &mut expect);

        for r in 0..n {
            let inv = 1.0 / state.l[r];
            for col in 0..d {
                let got = state.acc.at(r, col) * inv;
                assert!((got - expect.at(r, col)).abs() < 1e-4, "r={r} c={col}");
            }
        }
    }

    #[test]
    fn coverage_contains_diag_and_first_block() {
        let n = 128;
        let h = rand_head(23, n, 8);
        let c = cfg(16, 4);
        let (_, cov) = anchor_pass(&h, &c);
        for qb in 0..8 {
            // First init column always covered.
            assert!(cov.covered(qb, 0));
            // Diagonal (own block start) always covered.
            assert!(cov.covered(qb, qb * 16));
        }
    }

    #[test]
    fn first_group_fully_covered_by_window() {
        // Blocks in group 0 have window starting at 0: full causal coverage.
        let n = 64;
        let h = rand_head(24, n, 8);
        let c = cfg(16, 4); // all 4 blocks in group 0
        let (state, cov) = anchor_pass(&h, &c);
        assert_eq!(cov.sparsity(), 0.0);
        // So Acc/L == full attention.
        let expect = crate::attention::full::naive_attention(&h);
        for r in 0..n {
            let inv = 1.0 / state.l[r];
            for col in 0..8 {
                assert!((state.acc.at(r, col) * inv - expect.at(r, col)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn ragged_last_block() {
        let n = 100; // not a multiple of 16
        let h = rand_head(25, n, 8);
        let c = cfg(16, 2);
        let (state, _) = anchor_pass(&h, &c);
        assert_eq!(state.m.len(), n);
        assert!(state.l.iter().all(|&l| l > 0.0), "every row saw >=1 key");
    }
}

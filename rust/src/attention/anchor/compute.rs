//! Algorithm 1 — Pattern-based Anchor Computation (planning flavor).
//!
//! For every query block, score the two regions where row maxima
//! concentrate (paper §2.2.2) — the initial key block(s) (attention sink)
//! and the group-aligned causal local window — and keep each row's maximum
//! `M`: the anchor score `x_a` of Eq. 1. This is the *identification-side*
//! half of Alg. 1: only scores are computed (no `P·V`), because in the
//! planner → executor split the anchor regions' attention output is
//! produced by the shared executor from the plan's anchor spans, not here.

use super::AnchorConfig;
use crate::attention::full::mask_tile_causal;
use crate::attention::{CostTally, HeadInput};
use crate::tensor::{matmul_nt_scaled, Mat};
use crate::util::threadpool::parallel_map;

/// Anchor-region scoring for one query block: per-row max over
/// `[0, init_cols) ∪ [win_start, limit)`, causally masked.
fn score_block(input: &HeadInput, cfg: &AnchorConfig, qb: usize) -> (Vec<f32>, CostTally) {
    let n = input.n();
    let d = input.d();
    let scale = input.scale();
    let tile = cfg.tile;
    let init_cols = cfg.init_cols(n);
    let row0 = qb * tile.b_q;
    let rows = (n - row0).min(tile.b_q);
    let limit = row0 + rows;
    let q_i = input.q.rows_mat(row0, rows);
    let mut m = vec![f32::NEG_INFINITY; rows];
    let mut cost = CostTally::default();

    // Region spans: [0, init_cols) ∪ [win_start, limit), merged when
    // they overlap (early blocks).
    let win_start = cfg.window_start(qb).min(limit);
    let spans: [(usize, usize); 2] = if win_start <= init_cols {
        [(0, limit), (0, 0)]
    } else {
        [(0, init_cols.min(limit)), (win_start, limit)]
    };

    let mut s = Mat::zeros(rows, tile.b_kv);
    for (start, end) in spans {
        if start >= end {
            continue;
        }
        let mut col0 = start;
        while col0 < end {
            let cols = (end - col0).min(tile.b_kv);
            let k_j = input.k.rows_mat(col0, cols);
            if s.cols != cols || s.rows != rows {
                s = Mat::zeros(rows, cols);
            }
            matmul_nt_scaled(&q_i, &k_j, scale, &mut s);
            if col0 + cols > row0 {
                mask_tile_causal(&mut s, row0, col0);
            }
            for (r, mr) in m.iter_mut().enumerate() {
                for &x in s.row(r) {
                    if x > *mr {
                        *mr = x;
                    }
                }
            }
            cost.add(CostTally::ident_tile(rows, cols, d));
            col0 += cols;
        }
    }
    (m, cost)
}

/// Compute the per-row anchor scores `M` over the anchor regions
/// (init ∪ window, causally masked). Returns `M` (length `n`, `-∞` only
/// for rows with no visible anchor key — impossible since the diagonal is
/// always in the window) plus the scoring cost.
pub fn anchor_m_pass(input: &HeadInput, cfg: &AnchorConfig) -> (Vec<f32>, CostTally) {
    let n = input.n();
    let q_blocks = cfg.tile.q_blocks(n);
    let results = parallel_map(q_blocks, |qb| score_block(input, cfg, qb));

    let mut m = vec![f32::NEG_INFINITY; n];
    let mut cost = CostTally::default();
    for (qb, (block_m, c)) in results.into_iter().enumerate() {
        let row0 = qb * cfg.tile.b_q;
        m[row0..row0 + block_m.len()].copy_from_slice(&block_m);
        cost.add(c);
    }
    (m, cost)
}

/// As [`anchor_m_pass`], but scoring only the given query blocks — rows
/// outside them stay `-∞` and cost nothing. Each row's `M` depends only
/// on its own block's anchor regions, so the computed entries are exactly
/// the full pass's values. The speculative reuse layer's recall check
/// (DESIGN.md §17) scores only the sampled groups' blocks this way; the
/// restriction is what makes a recall check cheaper than identification.
pub fn anchor_m_pass_for_blocks(
    input: &HeadInput,
    cfg: &AnchorConfig,
    blocks: &[usize],
) -> (Vec<f32>, CostTally) {
    let n = input.n();
    let q_blocks = cfg.tile.q_blocks(n);
    assert!(
        blocks.iter().all(|&qb| qb < q_blocks),
        "query block out of range (have {q_blocks} blocks)"
    );
    let results = parallel_map(blocks.len(), |i| score_block(input, cfg, blocks[i]));

    let mut m = vec![f32::NEG_INFINITY; n];
    let mut cost = CostTally::default();
    for (&qb, (block_m, c)) in blocks.iter().zip(results) {
        let row0 = qb * cfg.tile.b_q;
        m[row0..row0 + block_m.len()].copy_from_slice(&block_m);
        cost.add(c);
    }
    (m, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::TileConfig;
    use crate::tensor::ops::causal_mask_inplace;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn cfg(b: usize, step: usize) -> AnchorConfig {
        AnchorConfig {
            tile: TileConfig::new(b, b),
            theta: 12.0,
            step,
            init_blocks: 1,
            use_anchor: true,
        }
    }

    /// Reference: per-row max over the anchor regions from the naive score
    /// matrix must equal M.
    #[test]
    fn anchor_m_is_region_max() {
        let n = 128;
        let d = 8;
        let h = rand_head(21, n, d);
        let c = cfg(16, 2);
        let (m, _) = anchor_m_pass(&h, &c);

        let mut s = Mat::zeros(n, n);
        matmul_nt_scaled(&h.q, &h.k, h.scale(), &mut s);
        causal_mask_inplace(&mut s, 0, 0);

        for r in 0..n {
            let qb = r / 16;
            let win = c.window_start(qb);
            let mut expect = f32::NEG_INFINITY;
            for col in 0..=r {
                if col < c.init_cols(n) || col >= win {
                    expect = expect.max(s.at(r, col));
                }
            }
            assert!((m[r] - expect).abs() < 1e-5, "row {r}: m={} expect={expect}", m[r]);
        }
    }

    /// Scoring cost is identification-shaped: no P·V flops are counted.
    #[test]
    fn m_pass_counts_ident_cost_only() {
        let h = rand_head(22, 128, 8);
        let c = cfg(16, 2);
        let (_, cost) = anchor_m_pass(&h, &c);
        assert!(cost.ident_scores > 0);
        // 2 flops per score entry (QKᵀ only).
        assert_eq!(cost.flops, 2 * cost.ident_scores * 8);
    }

    #[test]
    fn every_row_sees_its_diagonal() {
        let n = 100; // ragged last block
        let h = rand_head(25, n, 8);
        let c = cfg(16, 2);
        let (m, _) = anchor_m_pass(&h, &c);
        assert_eq!(m.len(), n);
        assert!(m.iter().all(|&x| x > f32::NEG_INFINITY), "every row saw >=1 key");
    }

    /// Restricting the pass to a block subset reproduces the full pass's
    /// values exactly on those rows (per-row independence) and pays less.
    #[test]
    fn block_restricted_m_matches_full_pass() {
        let n = 200; // ragged last block
        let h = rand_head(27, n, 8);
        let c = cfg(16, 2);
        let (full, full_cost) = anchor_m_pass(&h, &c);
        let blocks = [0usize, 5, 12];
        let (partial, cost) = anchor_m_pass_for_blocks(&h, &c, &blocks);
        for &qb in &blocks {
            let row0 = qb * 16;
            let rows = (n - row0).min(16);
            assert_eq!(&partial[row0..row0 + rows], &full[row0..row0 + rows], "block {qb}");
        }
        assert!(partial[16..32].iter().all(|&x| x == f32::NEG_INFINITY));
        assert!(cost.ident_scores > 0 && cost.ident_scores < full_cost.ident_scores);
    }

    /// Larger init region can only raise the anchor.
    #[test]
    fn m_monotone_in_init_blocks() {
        let h = rand_head(26, 128, 8);
        let mut c1 = cfg(16, 2);
        c1.init_blocks = 1;
        let mut c2 = cfg(16, 2);
        c2.init_blocks = 4;
        let (m1, _) = anchor_m_pass(&h, &c1);
        let (m2, _) = anchor_m_pass(&h, &c2);
        for r in 0..128 {
            assert!(m2[r] >= m1[r] - 1e-6, "row {r}: {} < {}", m2[r], m1[r]);
        }
    }
}

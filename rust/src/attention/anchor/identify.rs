//! Algorithm 2 — Difference-aware Stripe Sparsity Identification.
//!
//! Queries are average-pooled per block (`avgpool(Q, b_q)`), the anchor
//! scores likewise (`avgpool(x_a, b_q)`), and `step` pooled query rows form
//! one identification *group* sharing a stripe set (§3.4). For each group,
//! pooled queries are dotted against every candidate key (global scope —
//! everything before the group's local window and after the init block),
//! and key `j` survives iff
//!
//! ```text
//! avgpool(x_a)_i − qk_ij ≤ θ          (Eq. 2)
//! ```
//!
//! for *any* pooled row `i` in the group (a key useful to any of the
//! group's `b_q·step` queries is gathered for all of them — the paper's
//! parallelism/accuracy trade).
//!
//! No sorting anywhere: selection is a single comparison per score, which
//! is the paper's complexity win over top-k/top-cdf (§2.1.1).

use super::{AnchorConfig, StripeSet};
use crate::attention::{CostTally, HeadInput};
use crate::tensor::ops::{avgpool_rows, avgpool_vec};
use crate::tensor::{matmul_nt_scaled, Mat};
use crate::util::threadpool::parallel_map;

/// `avgpool(Q, b_q)` and `avgpool(x_a, b_q)` — one pooled row per query
/// block, the shared inputs of every Alg. 2 selection.
fn pooled_inputs(input: &HeadInput, cfg: &AnchorConfig, m: &[f32]) -> (Mat, Vec<f32>) {
    let n = input.n();
    let q_blocks = cfg.tile.q_blocks(n);
    let q_pool = avgpool_rows(&input.q, cfg.tile.b_q);
    let anchor_pool: Vec<f32> = if cfg.use_anchor {
        assert_eq!(m.len(), n, "anchor scores must cover every row");
        avgpool_vec(m, cfg.tile.b_q)
    } else {
        // Table 4 "Without Anchor": anchor is a zero tensor.
        vec![0.0; q_blocks]
    };
    (q_pool, anchor_pool)
}

/// Alg. 2's selection for one group: pooled queries vs every candidate
/// key; a column survives if ANY pooled row in the group is within θ of
/// its anchor.
fn select_group(
    input: &HeadInput,
    cfg: &AnchorConfig,
    q_pool: &Mat,
    anchor_pool: &[f32],
    g: usize,
) -> (Vec<u32>, CostTally) {
    let n = input.n();
    let d = input.d();
    let scale = input.scale();
    let tile = cfg.tile;
    let q_blocks = tile.q_blocks(n);
    let (cand_start, cand_end) = cfg.candidate_range(g, n);
    if cand_start >= cand_end {
        return (Vec::new(), CostTally::default());
    }
    let row_start = g * cfg.step;
    let row_end = ((g + 1) * cfg.step).min(q_blocks);
    let grows = row_end - row_start;
    let qg = q_pool.rows_mat(row_start, grows);
    let anchors = &anchor_pool[row_start..row_end];

    let mut selected = Vec::new();
    let mut cost = CostTally::default();
    let mut s = Mat::zeros(grows, tile.b_kv);
    let mut col0 = cand_start;
    while col0 < cand_end {
        let cols = (cand_end - col0).min(tile.b_kv);
        let k_j = input.k.rows_mat(col0, cols);
        if s.cols != cols {
            s = Mat::zeros(grows, cols);
        }
        matmul_nt_scaled(&qg, &k_j, scale, &mut s);
        cost.add(CostTally::ident_tile(grows, cols, d));
        for c in 0..cols {
            let mut hit = false;
            for r in 0..grows {
                if anchors[r] - s.at(r, c) <= cfg.theta {
                    hit = true;
                    break;
                }
            }
            if hit {
                selected.push((col0 + c) as u32);
            }
        }
        col0 += cols;
    }
    (selected, cost)
}

/// Run Alg. 2 against the anchor scores `m` (per-row `M` from
/// [`super::compute::anchor_m_pass`]; must have length `n` when
/// `cfg.use_anchor`, ignored otherwise).
pub fn identify_stripes(input: &HeadInput, cfg: &AnchorConfig, m: &[f32]) -> StripeSet {
    let q_blocks = cfg.tile.q_blocks(input.n());
    let groups = q_blocks.div_ceil(cfg.step);
    let (q_pool, anchor_pool) = pooled_inputs(input, cfg, m);

    let per_group: Vec<(Vec<u32>, CostTally)> =
        parallel_map(groups, |g| select_group(input, cfg, &q_pool, &anchor_pool, g));

    let mut cost = CostTally::default();
    let mut out_groups = Vec::with_capacity(groups);
    for (sel, c) in per_group {
        cost.add(c);
        out_groups.push(sel);
    }
    StripeSet { step: cfg.step, groups: out_groups, cost }
}

/// Alg. 2 restricted to an arbitrary subset of groups — same selection
/// rule and the same cost accounting as [`identify_stripes`], but only
/// over `group_ids`. The speculative reuse layer (DESIGN.md §17) uses
/// this twice: the recall check selects fresh stripes for a *sampled*
/// group subset to score a donor plan against, and prefix extension
/// re-identifies only the suffix groups a shorter donor cannot cover.
/// Returns one stripe list per requested group, in `group_ids` order.
pub fn identify_stripes_for_groups(
    input: &HeadInput,
    cfg: &AnchorConfig,
    m: &[f32],
    group_ids: &[usize],
) -> (Vec<Vec<u32>>, CostTally) {
    let q_blocks = cfg.tile.q_blocks(input.n());
    let n_groups = q_blocks.div_ceil(cfg.step);
    assert!(
        group_ids.iter().all(|&g| g < n_groups),
        "group id out of range (have {n_groups} groups)"
    );
    let (q_pool, anchor_pool) = pooled_inputs(input, cfg, m);
    let per_group: Vec<(Vec<u32>, CostTally)> = parallel_map(group_ids.len(), |i| {
        select_group(input, cfg, &q_pool, &anchor_pool, group_ids[i])
    });
    let mut cost = CostTally::default();
    let mut out = Vec::with_capacity(group_ids.len());
    for (sel, c) in per_group {
        cost.add(c);
        out.push(sel);
    }
    (out, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::anchor::compute::anchor_m_pass;
    use crate::attention::TileConfig;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn cfg(theta: f32) -> AnchorConfig {
        AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        }
    }

    #[test]
    fn infinite_theta_selects_every_candidate() {
        let h = rand_head(31, 128, 8);
        let c = cfg(f32::INFINITY);
        let (m, _) = anchor_m_pass(&h, &c);
        let stripes = identify_stripes(&h, &c, &m);
        for (g, sel) in stripes.groups.iter().enumerate() {
            let (start, end) = c.candidate_range(g, 128);
            assert_eq!(sel.len(), end - start, "group {g}");
            // Sorted and in-range.
            assert!(sel.windows(2).all(|w| w[0] < w[1]));
            assert!(sel.iter().all(|&x| (x as usize) >= start && (x as usize) < end));
        }
    }

    #[test]
    fn negative_infinite_theta_selects_nothing() {
        let h = rand_head(32, 128, 8);
        let c = cfg(f32::NEG_INFINITY);
        let (m, _) = anchor_m_pass(&h, &c);
        let stripes = identify_stripes(&h, &c, &m);
        assert_eq!(stripes.total(), 0);
    }

    #[test]
    fn selection_matches_bruteforce_rule() {
        let n = 128;
        let d = 8;
        let h = rand_head(33, n, d);
        let c = cfg(1.0);
        let (m, _) = anchor_m_pass(&h, &c);
        let stripes = identify_stripes(&h, &c, &m);

        // Brute-force Eq. 2 on pooled matrices.
        let q_pool = avgpool_rows(&h.q, 16);
        let a_pool = avgpool_vec(&m, 16);
        let mut s = Mat::zeros(q_pool.rows, n);
        matmul_nt_scaled(&q_pool, &h.k, h.scale(), &mut s);

        for g in 0..stripes.groups.len() {
            let (start, end) = c.candidate_range(g, n);
            let mut expect = Vec::new();
            for col in start..end {
                let mut hit = false;
                for r in g * 2..((g + 1) * 2).min(q_pool.rows) {
                    if a_pool[r] - s.at(r, col) <= 1.0 {
                        hit = true;
                    }
                }
                if hit {
                    expect.push(col as u32);
                }
            }
            assert_eq!(stripes.groups[g], expect, "group {g}");
        }
    }

    #[test]
    fn without_anchor_uses_zero_baseline() {
        let n = 128;
        let h = rand_head(34, n, 8);
        let mut c = cfg(0.5);
        c.use_anchor = false;
        let (m, _) = anchor_m_pass(&h, &c);
        let stripes = identify_stripes(&h, &c, &m);

        // Rule becomes: select iff qk >= -θ for any pooled row.
        let q_pool = avgpool_rows(&h.q, 16);
        let mut s = Mat::zeros(q_pool.rows, n);
        matmul_nt_scaled(&q_pool, &h.k, h.scale(), &mut s);
        for g in 0..stripes.groups.len() {
            let (start, end) = c.candidate_range(g, n);
            for col in start..end {
                let mut hit = false;
                for r in g * 2..((g + 1) * 2).min(q_pool.rows) {
                    if -s.at(r, col) <= 0.5 {
                        hit = true;
                    }
                }
                assert_eq!(stripes.groups[g].contains(&(col as u32)), hit);
            }
        }
    }

    #[test]
    fn early_groups_have_no_candidates() {
        let h = rand_head(35, 64, 8);
        let c = cfg(f32::INFINITY);
        let (m, _) = anchor_m_pass(&h, &c);
        let stripes = identify_stripes(&h, &c, &m);
        // Group 0: window starts at 0, so no candidate columns at all.
        assert!(stripes.groups[0].is_empty());
    }

    /// Restricting Alg. 2 to a group subset changes nothing about the
    /// per-group selections — only which groups get paid for.
    #[test]
    fn subset_identification_matches_full_grid() {
        let h = rand_head(37, 256, 8);
        let c = cfg(1.0);
        let (m, _) = anchor_m_pass(&h, &c);
        let full = identify_stripes(&h, &c, &m);
        let ids = [1usize, 3, 5, 7];
        let (subset, cost) = identify_stripes_for_groups(&h, &c, &m, &ids);
        for (i, &g) in ids.iter().enumerate() {
            assert_eq!(subset[i], full.groups[g], "group {g}");
        }
        assert!(cost.ident_scores > 0 && cost.ident_scores < full.cost.ident_scores);
    }

    #[test]
    fn identification_cost_counted() {
        let h = rand_head(36, 256, 8);
        let c = cfg(0.0);
        let (m, _) = anchor_m_pass(&h, &c);
        let stripes = identify_stripes(&h, &c, &m);
        assert!(stripes.cost.ident_scores > 0);
        assert!(stripes.cost.flops > 0);
    }
}

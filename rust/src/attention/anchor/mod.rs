//! **AnchorAttention** — the paper's contribution (§3, Algorithms 1–3).
//!
//! Pipeline:
//!
//! 1. [`compute::anchor_pass`] (*Pattern-based Anchor Computation*, Alg. 1)
//!    — exact blocked attention over the initial block(s) and the causal
//!    local window, caching online-softmax state `(M, L, Acc)` per row.
//!    `M` is the **anchor**: a near-maximum of each row's logits, because
//!    row maxima concentrate in those regions (paper Fig. 5).
//! 2. [`identify::identify_stripes`] (*Difference-aware Stripe Sparsity
//!    Identification*, Alg. 2) — pooled queries vs all remaining keys; a
//!    key survives iff `avgpool(anchor) − qk ≤ θ`. No sorting; stripe
//!    `(b_q·step, 1)` granularity.
//! 3. [`sparse::sparse_pass`] (*Fine-Grained Sparse Computation*, Alg. 3)
//!    — gathers the surviving discrete keys/values and **continues** the
//!    online softmax from the cached `(M, L, Acc)`, so anchor-region work
//!    is reused, not recomputed (paper §3.4).

pub mod compute;
pub mod identify;
pub mod sparse;

use crate::attention::{AttnOutput, CostTally, HeadInput, TileConfig};
use crate::tensor::Mat;

/// Hyperparameters of AnchorAttention. Paper defaults: `θ = 12`,
/// `step = 16`, block size 128, one initial block.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AnchorConfig {
    pub tile: TileConfig,
    /// Difference threshold θ (Eq. 2): key `j` survives for pooled query
    /// `i` iff `anchor_i − qk_ij ≤ θ`. Larger θ ⇒ more keys ⇒ higher
    /// recall, lower sparsity (Table 4).
    pub theta: f32,
    /// Query blocks sharing one identification pass / stripe set (§3.4).
    pub step: usize,
    /// Number of initial key blocks always computed (the attention sink).
    pub init_blocks: usize,
    /// Ablation switch (Table 4 "Without Anchor"): when false the anchor
    /// is a zero tensor, exactly as the paper implements it.
    pub use_anchor: bool,
}

impl Default for AnchorConfig {
    fn default() -> Self {
        Self {
            tile: TileConfig::default(),
            theta: 12.0,
            step: 16,
            init_blocks: 1,
            use_anchor: true,
        }
    }
}

impl AnchorConfig {
    pub fn with_theta(theta: f32) -> Self {
        Self { theta, ..Default::default() }
    }

    /// First column of the local window for query block `qb` (absolute key
    /// position): Alg. 1 line 8, `⌊i/step⌋ · step · b_q`, group-aligned so
    /// all `step` blocks of a group share a stripe set.
    pub fn window_start(&self, qb: usize) -> usize {
        (qb / self.step) * self.step * self.tile.b_q
    }

    /// Columns always covered by the anchor pass for query block `qb`:
    /// `[0, init_cols) ∪ [window_start, causal_limit)`.
    pub fn init_cols(&self, n: usize) -> usize {
        (self.init_blocks * self.tile.b_kv).min(n)
    }

    /// Candidate range for identification for group `g`: keys in
    /// `[init_cols, group_window_start)` (Alg. 2 line 7: everything before
    /// the group's window that is not the initial region).
    pub fn candidate_range(&self, g: usize, n: usize) -> (usize, usize) {
        let start = self.init_cols(n);
        let end = (g * self.step * self.tile.b_q).min(n);
        (start, end.max(start))
    }
}

/// Cached Alg. 1 state, reused by Alg. 3 (paper §3.4 "temporarily cache the
/// intermediate results … and reuse them").
#[derive(Clone, Debug)]
pub struct AnchorState {
    /// Per-row running max `M` — the anchor scores `x_a`.
    pub m: Vec<f32>,
    /// Per-row normalizer `L`.
    pub l: Vec<f32>,
    /// Unnormalized accumulator `Acc` `[N, d]`.
    pub acc: Mat,
    pub cost: CostTally,
}

/// Output of Alg. 2: for every query-block *group*, the sorted discrete key
/// columns (stripes) to gather, plus identification cost.
#[derive(Clone, Debug)]
pub struct StripeSet {
    pub step: usize,
    pub groups: Vec<Vec<u32>>,
    pub cost: CostTally,
}

impl StripeSet {
    /// Total stripes across groups (for reporting).
    pub fn total(&self) -> usize {
        self.groups.iter().map(|g| g.len()).sum()
    }
}

/// Full three-stage AnchorAttention over one head.
pub fn anchor_attention(input: &HeadInput, cfg: &AnchorConfig) -> AttnOutput {
    let (state, mut coverage) = compute::anchor_pass(input, cfg);
    let stripes = identify::identify_stripes(input, cfg, &state);
    let (out, sparse_cost) = sparse::sparse_pass(input, cfg, &state, &stripes, &mut coverage);

    let mut cost = state.cost;
    cost.add(stripes.cost);
    cost.add(sparse_cost);
    AttnOutput { out, coverage, cost }
}

/// Timing breakdown of the three stages (for Fig. 6b/6c style reporting).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimings {
    pub anchor_s: f64,
    pub identify_s: f64,
    pub sparse_s: f64,
}

impl PhaseTimings {
    pub fn total_s(&self) -> f64 {
        self.anchor_s + self.identify_s + self.sparse_s
    }
}

/// As [`anchor_attention`] but also returns per-phase wallclock.
pub fn anchor_attention_timed(
    input: &HeadInput,
    cfg: &AnchorConfig,
) -> (AttnOutput, PhaseTimings) {
    let t0 = std::time::Instant::now();
    let (state, mut coverage) = compute::anchor_pass(input, cfg);
    let t1 = std::time::Instant::now();
    let stripes = identify::identify_stripes(input, cfg, &state);
    let t2 = std::time::Instant::now();
    let (out, sparse_cost) = sparse::sparse_pass(input, cfg, &state, &stripes, &mut coverage);
    let t3 = std::time::Instant::now();

    let mut cost = state.cost;
    cost.add(stripes.cost);
    cost.add(sparse_cost);
    (
        AttnOutput { out, coverage, cost },
        PhaseTimings {
            anchor_s: (t1 - t0).as_secs_f64(),
            identify_s: (t2 - t1).as_secs_f64(),
            sparse_s: (t3 - t2).as_secs_f64(),
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::naive_attention;
    use crate::attention::mask::Coverage;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn small_cfg(theta: f32) -> AnchorConfig {
        AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        }
    }

    #[test]
    fn window_start_group_aligned() {
        let cfg = AnchorConfig { step: 4, tile: TileConfig::new(128, 128), ..Default::default() };
        assert_eq!(cfg.window_start(0), 0);
        assert_eq!(cfg.window_start(3), 0);
        assert_eq!(cfg.window_start(4), 4 * 128);
        assert_eq!(cfg.window_start(7), 4 * 128);
        assert_eq!(cfg.window_start(8), 8 * 128);
    }

    #[test]
    fn candidate_range_excludes_init_and_window() {
        let cfg = AnchorConfig {
            step: 2,
            tile: TileConfig::new(16, 16),
            init_blocks: 1,
            ..Default::default()
        };
        // Group 0's window starts at 0 -> empty candidates.
        assert_eq!(cfg.candidate_range(0, 256), (16, 16));
        // Group 2 windows from 64; candidates are [16, 64).
        assert_eq!(cfg.candidate_range(2, 256), (16, 64));
    }

    #[test]
    fn large_theta_converges_to_full_attention() {
        // θ = ∞ selects every candidate stripe, so the output must equal
        // dense attention exactly (all probability mass covered).
        let h = rand_head(7, 128, 16);
        let cfg = small_cfg(1e9);
        let out = anchor_attention(&h, &cfg);
        let expect = naive_attention(&h);
        assert!(
            out.out.max_abs_diff(&expect) < 1e-4,
            "max diff {}",
            out.out.max_abs_diff(&expect)
        );
        assert_eq!(out.coverage.sparsity(), 0.0);
    }

    #[test]
    fn tiny_theta_reduces_to_anchor_regions() {
        let h = rand_head(8, 128, 16);
        let cfg = small_cfg(-1e9);
        let out = anchor_attention(&h, &cfg);
        // Coverage should be exactly the anchor regions: init + window.
        let mut expect_cov = Coverage::new(128, 16);
        for qb in 0..8 {
            expect_cov.set_range(qb, 0, cfg.init_cols(128));
            let ws = cfg.window_start(qb);
            expect_cov.set_range(qb, ws, (qb + 1) * 16);
        }
        assert_eq!(out.coverage.total_covered(), expect_cov.total_covered());
        assert!(out.coverage.sparsity() > 0.0);
    }

    #[test]
    fn sparsity_monotone_in_theta() {
        let h = rand_head(9, 256, 16);
        let mut last = -1.0f64;
        for theta in [-5.0, 0.0, 5.0, 1e9] {
            let out = anchor_attention(&h, &small_cfg(theta));
            let s = out.coverage.sparsity();
            assert!(s <= last + 1e-12 || last < 0.0, "sparsity not decreasing: {last} -> {s}");
            last = s;
        }
    }

    #[test]
    fn output_rows_are_convex_combinations() {
        // Every output row of (sparse) softmax attention lies in the convex
        // hull of V rows => bounded by min/max of V per column.
        let h = rand_head(10, 96, 8);
        let out = anchor_attention(&h, &small_cfg(2.0));
        for c in 0..8 {
            let (mut vmin, mut vmax) = (f32::INFINITY, f32::NEG_INFINITY);
            for r in 0..96 {
                vmin = vmin.min(h.v.at(r, c));
                vmax = vmax.max(h.v.at(r, c));
            }
            for r in 0..96 {
                let x = out.out.at(r, c);
                assert!(x >= vmin - 1e-4 && x <= vmax + 1e-4, "row {r} col {c}: {x}");
            }
        }
    }

    #[test]
    fn timed_variant_matches_untimed() {
        let h = rand_head(11, 64, 8);
        let cfg = small_cfg(3.0);
        let a = anchor_attention(&h, &cfg);
        let (b, t) = anchor_attention_timed(&h, &cfg);
        assert!(a.out.max_abs_diff(&b.out) < 1e-6);
        assert!(t.total_s() > 0.0);
    }
}

//! **AnchorAttention** — the paper's contribution (§3, Algorithms 1–3),
//! expressed in the planner → executor pipeline (DESIGN.md §2):
//!
//! 1. [`compute::anchor_m_pass`] (*Pattern-based Anchor Computation*,
//!    Alg. 1, scoring half) — blocked scores over the initial block(s) and
//!    the group-aligned causal local window; each row's max `M` is the
//!    **anchor**, a near-maximum of the row's logits, because row maxima
//!    concentrate in those regions (paper Fig. 5).
//! 2. [`identify::identify_stripes`] (*Difference-aware Stripe Sparsity
//!    Identification*, Alg. 2) — pooled queries vs all remaining keys; a
//!    key survives iff `avgpool(anchor) − qk ≤ θ`. No sorting; stripe
//!    `(b_q·step, 1)` granularity.
//! 3. The resulting [`SparsePlan`] — anchor spans + stripe coordinates per
//!    query-block group — is executed by the shared
//!    [`crate::attention::plan::execute_plan`] (*Fine-Grained Sparse
//!    Computation*, Alg. 3): discrete keys/values are gathered once per
//!    group and folded into one online softmax per query block.

pub mod compute;
pub mod identify;

use std::time::Instant;

use crate::attention::plan::{run_planner, GroupPlan, Planner, SparsePlan};
use crate::attention::{AttnOutput, CostTally, HeadInput, TileConfig};

/// Hyperparameters of AnchorAttention. Paper defaults: `θ = 12`,
/// `step = 16`, block size 128, one initial block.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AnchorConfig {
    pub tile: TileConfig,
    /// Difference threshold θ (Eq. 2): key `j` survives for pooled query
    /// `i` iff `anchor_i − qk_ij ≤ θ`. Larger θ ⇒ more keys ⇒ higher
    /// recall, lower sparsity (Table 4).
    pub theta: f32,
    /// Query blocks sharing one identification pass / stripe set (§3.4).
    pub step: usize,
    /// Number of initial key blocks always computed (the attention sink).
    pub init_blocks: usize,
    /// Ablation switch (Table 4 "Without Anchor"): when false the anchor
    /// is a zero tensor, exactly as the paper implements it (and the
    /// `M` scoring pass is skipped — nothing consumes it).
    pub use_anchor: bool,
}

impl Default for AnchorConfig {
    fn default() -> Self {
        Self {
            tile: TileConfig::default(),
            theta: 12.0,
            step: 16,
            init_blocks: 1,
            use_anchor: true,
        }
    }
}

impl AnchorConfig {
    pub fn with_theta(theta: f32) -> Self {
        Self { theta, ..Default::default() }
    }

    /// First column of the local window for query block `qb` (absolute key
    /// position): Alg. 1 line 8, `⌊i/step⌋ · step · b_q`, group-aligned so
    /// all `step` blocks of a group share a stripe set.
    pub fn window_start(&self, qb: usize) -> usize {
        (qb / self.step) * self.step * self.tile.b_q
    }

    /// Columns always covered by the anchor pass for query block `qb`:
    /// `[0, init_cols) ∪ [window_start, causal_limit)`.
    pub fn init_cols(&self, n: usize) -> usize {
        (self.init_blocks * self.tile.b_kv).min(n)
    }

    /// Candidate range for identification for group `g`: keys in
    /// `[init_cols, group_window_start)` (Alg. 2 line 7: everything before
    /// the group's window that is not the initial region).
    pub fn candidate_range(&self, g: usize, n: usize) -> (usize, usize) {
        let start = self.init_cols(n);
        let end = (g * self.step * self.tile.b_q).min(n);
        (start, end.max(start))
    }

    /// Anchor spans for group `g` at length `n` — the structural
    /// (input-independent) half of a plan's coordinates: init region +
    /// group window, merged when the window reaches the init region (the
    /// executor clips each span to every block's causal limit).
    /// [`AnchorConfig::plan_timed`] and the speculative reuse layer
    /// (DESIGN.md §17) assemble groups from this one definition, so a
    /// reused plan can never drift structurally from a fresh one.
    pub fn group_spans(&self, g: usize, n: usize) -> Vec<(u32, u32)> {
        let init_cols = self.init_cols(n);
        let win = g * self.step * self.tile.b_q;
        let group_end = ((g + 1) * self.step * self.tile.b_q).min(n);
        let mut spans = if win <= init_cols {
            vec![(0u32, group_end as u32)]
        } else {
            vec![(0u32, init_cols as u32), (win as u32, group_end as u32)]
        };
        spans.retain(|&(s, e)| s < e); // drop empty init span when init_blocks = 0
        spans
    }

    /// Assemble a priced [`SparsePlan`] from per-group stripe selections
    /// (the shape Alg. 2 emits) and the identification cost actually
    /// paid. `stripes` must hold one sorted list per group.
    pub fn assemble_plan(
        &self,
        n: usize,
        d: usize,
        stripes: Vec<Vec<u32>>,
        ident_cost: CostTally,
    ) -> SparsePlan {
        let groups = stripes
            .into_iter()
            .enumerate()
            .map(|(g, sel)| GroupPlan { spans: self.group_spans(g, n), stripes: sel })
            .collect();
        SparsePlan::new("anchor", n, d, self.tile, self.step, groups, ident_cost)
    }

    /// Build the plan, also returning per-phase wallclock
    /// `(anchor_s, identify_s)` for Fig. 6-style phase reporting.
    pub fn plan_timed(&self, input: &HeadInput) -> (SparsePlan, f64, f64) {
        let n = input.n();
        let n_groups = self.tile.q_blocks(n).div_ceil(self.step);

        let t0 = Instant::now();
        let (m, m_cost) = if self.use_anchor {
            compute::anchor_m_pass(input, self)
        } else {
            (Vec::new(), CostTally::default())
        };
        let t1 = Instant::now();
        let stripes = identify::identify_stripes(input, self, &m);
        debug_assert_eq!(stripes.groups.len(), n_groups);

        let mut ident_cost = m_cost;
        ident_cost.add(stripes.cost);
        let plan = self.assemble_plan(n, input.d(), stripes.groups, ident_cost);
        let t2 = Instant::now();
        (plan, (t1 - t0).as_secs_f64(), (t2 - t1).as_secs_f64())
    }
}

impl Planner for AnchorConfig {
    fn name(&self) -> &'static str {
        "anchor"
    }

    fn plan(&self, input: &HeadInput) -> SparsePlan {
        self.plan_timed(input).0
    }
}

/// Output of Alg. 2: for every query-block *group*, the sorted discrete key
/// columns (stripes) to gather, plus identification cost.
#[derive(Clone, Debug)]
pub struct StripeSet {
    pub step: usize,
    pub groups: Vec<Vec<u32>>,
    pub cost: CostTally,
}

impl StripeSet {
    /// Total stripes across groups (for reporting).
    pub fn total(&self) -> usize {
        self.groups.iter().map(|g| g.len()).sum()
    }
}

/// Full three-stage AnchorAttention over one head (thin wrapper over the
/// planner → executor pipeline).
pub fn anchor_attention(input: &HeadInput, cfg: &AnchorConfig) -> AttnOutput {
    run_planner(input, cfg)
}

/// Timing breakdown of the three stages (for Fig. 6b/6c style reporting).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimings {
    pub anchor_s: f64,
    pub identify_s: f64,
    pub sparse_s: f64,
}

impl PhaseTimings {
    pub fn total_s(&self) -> f64 {
        self.anchor_s + self.identify_s + self.sparse_s
    }
}

/// As [`anchor_attention`] but also returns per-phase wallclock: anchor
/// scoring, stripe identification, and plan execution.
pub fn anchor_attention_timed(
    input: &HeadInput,
    cfg: &AnchorConfig,
) -> (AttnOutput, PhaseTimings) {
    let (plan, anchor_s, identify_s) = cfg.plan_timed(input);
    let t0 = Instant::now();
    let mut out = crate::attention::plan::execute_plan(input, &plan);
    let sparse_s = t0.elapsed().as_secs_f64();
    out.cost.add(plan.ident_cost);
    (out, PhaseTimings { anchor_s, identify_s, sparse_s })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::naive_attention;
    use crate::attention::mask::Coverage;
    use crate::attention::plan::masked_reference;
    use crate::tensor::Mat;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn small_cfg(theta: f32) -> AnchorConfig {
        AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        }
    }

    #[test]
    fn window_start_group_aligned() {
        let cfg = AnchorConfig { step: 4, tile: TileConfig::new(128, 128), ..Default::default() };
        assert_eq!(cfg.window_start(0), 0);
        assert_eq!(cfg.window_start(3), 0);
        assert_eq!(cfg.window_start(4), 4 * 128);
        assert_eq!(cfg.window_start(7), 4 * 128);
        assert_eq!(cfg.window_start(8), 8 * 128);
    }

    #[test]
    fn candidate_range_excludes_init_and_window() {
        let cfg = AnchorConfig {
            step: 2,
            tile: TileConfig::new(16, 16),
            init_blocks: 1,
            ..Default::default()
        };
        // Group 0's window starts at 0 -> empty candidates.
        assert_eq!(cfg.candidate_range(0, 256), (16, 16));
        // Group 2 windows from 64; candidates are [16, 64).
        assert_eq!(cfg.candidate_range(2, 256), (16, 64));
    }

    #[test]
    fn large_theta_converges_to_full_attention() {
        // θ = ∞ selects every candidate stripe, so the output must equal
        // dense attention exactly (all probability mass covered).
        let h = rand_head(7, 128, 16);
        let cfg = small_cfg(1e9);
        let out = anchor_attention(&h, &cfg);
        let expect = naive_attention(&h);
        assert!(
            out.out.max_abs_diff(&expect) < 1e-4,
            "max diff {}",
            out.out.max_abs_diff(&expect)
        );
        assert_eq!(out.coverage.sparsity(), 0.0);
    }

    #[test]
    fn tiny_theta_reduces_to_anchor_regions() {
        let h = rand_head(8, 128, 16);
        let cfg = small_cfg(-1e9);
        let out = anchor_attention(&h, &cfg);
        // Coverage should be exactly the anchor regions: init + window.
        let mut expect_cov = Coverage::new(128, 16);
        for qb in 0..8 {
            expect_cov.set_range(qb, 0, cfg.init_cols(128));
            let ws = cfg.window_start(qb);
            expect_cov.set_range(qb, ws, (qb + 1) * 16);
        }
        assert_eq!(out.coverage.total_covered(), expect_cov.total_covered());
        assert!(out.coverage.sparsity() > 0.0);
    }

    #[test]
    fn sparsity_monotone_in_theta() {
        let h = rand_head(9, 256, 16);
        let mut last = -1.0f64;
        for theta in [-5.0, 0.0, 5.0, 1e9] {
            let out = anchor_attention(&h, &small_cfg(theta));
            let s = out.coverage.sparsity();
            assert!(s <= last + 1e-12 || last < 0.0, "sparsity not decreasing: {last} -> {s}");
            last = s;
        }
    }

    #[test]
    fn output_rows_are_convex_combinations() {
        // Every output row of (sparse) softmax attention lies in the convex
        // hull of V rows => bounded by min/max of V per column.
        let h = rand_head(10, 96, 8);
        let out = anchor_attention(&h, &small_cfg(2.0));
        for c in 0..8 {
            let (mut vmin, mut vmax) = (f32::INFINITY, f32::NEG_INFINITY);
            for r in 0..96 {
                vmin = vmin.min(h.v.at(r, c));
                vmax = vmax.max(h.v.at(r, c));
            }
            for r in 0..96 {
                let x = out.out.at(r, c);
                assert!(x >= vmin - 1e-4 && x <= vmax + 1e-4, "row {r} col {c}: {x}");
            }
        }
    }

    #[test]
    fn timed_variant_matches_untimed() {
        let h = rand_head(11, 64, 8);
        let cfg = small_cfg(3.0);
        let a = anchor_attention(&h, &cfg);
        let (b, t) = anchor_attention_timed(&h, &cfg);
        assert!(a.out.max_abs_diff(&b.out) < 1e-6);
        assert_eq!(a.cost, b.cost);
        assert!(t.total_s() > 0.0);
    }

    /// The defining property of the pipeline: output equals exact softmax
    /// restricted to the plan's coverage.
    #[test]
    fn output_equals_coverage_masked_softmax() {
        let h = rand_head(42, 128, 8);
        let cfg = small_cfg(2.0);
        let out = anchor_attention(&h, &cfg);
        let expect = masked_reference(&h, &out.coverage);
        assert!(
            out.out.max_abs_diff(&expect) < 1e-4,
            "max diff {}",
            out.out.max_abs_diff(&expect)
        );
    }

    /// Without-anchor ablation still runs the full pipeline and stays
    /// consistent with its own coverage.
    #[test]
    fn without_anchor_matches_masked_softmax() {
        let h = rand_head(43, 128, 8);
        let mut cfg = small_cfg(0.5);
        cfg.use_anchor = false;
        let out = anchor_attention(&h, &cfg);
        let expect = masked_reference(&h, &out.coverage);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
    }

    /// Gather chunking is a pure implementation detail: different kv tile
    /// widths with matched anchor regions agree.
    #[test]
    fn gather_chunking_invariant_to_bkv() {
        let h = rand_head(45, 128, 8);
        let mut c1 = small_cfg(3.0);
        c1.tile = TileConfig::new(16, 8);
        c1.init_blocks = 8; // init region = 64 columns
        let mut c2 = small_cfg(3.0);
        c2.tile = TileConfig::new(16, 64);
        c2.init_blocks = 1; // init region = 64 columns
        let o1 = anchor_attention(&h, &c1);
        let o2 = anchor_attention(&h, &c2);
        assert!(o1.out.max_abs_diff(&o2.out) < 1e-4);
    }

    /// Plan structure: group spans are the init region + group window,
    /// merged for early groups.
    #[test]
    fn plan_spans_match_anchor_regions() {
        let h = rand_head(46, 128, 8);
        let cfg = small_cfg(1.0);
        let plan = Planner::plan(&cfg, &h);
        assert_eq!(plan.step, 2);
        assert_eq!(plan.groups.len(), 4);
        // Group 0: window starts at 0 ⇒ merged span.
        assert_eq!(plan.groups[0].spans, vec![(0, 32)]);
        assert!(plan.groups[0].stripes.is_empty());
        // Group 2: init [0,16) + window [64, 96).
        assert_eq!(plan.groups[2].spans, vec![(0, 16), (64, 96)]);
        // Stripes live strictly between init and window.
        assert!(plan.groups[2]
            .stripes
            .iter()
            .all(|&c| (16..64).contains(&(c as usize))));
    }
}

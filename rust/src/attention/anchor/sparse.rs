//! Algorithm 3 — Fine-Grained Sparse Computation.
//!
//! For every query block, resume the online softmax from the cached Alg. 1
//! state `(M, L, Acc)` and fold in the *discrete* key/value columns of the
//! block's group stripe set (Eq. 4, `load_discrete`). Gathers happen in
//! `b_kv`-sized chunks so the inner matmul keeps dense-tile shape — the
//! paper's point (3): discrete loading preserves full hardware parallelism.

use super::{AnchorConfig, AnchorState, StripeSet};
use crate::attention::full::BlockState;
use crate::attention::mask::Coverage;
use crate::attention::{CostTally, HeadInput};
use crate::tensor::{matmul_nt_scaled, Mat};
use crate::util::threadpool::parallel_map;

/// Run Alg. 3. Updates `coverage` with the gathered stripes and returns the
/// final attention output plus the sparse-phase cost.
pub fn sparse_pass(
    input: &HeadInput,
    cfg: &AnchorConfig,
    state: &AnchorState,
    stripes: &StripeSet,
    coverage: &mut Coverage,
) -> (Mat, CostTally) {
    let n = input.n();
    let d = input.d();
    let scale = input.scale();
    let tile = cfg.tile;
    let q_blocks = tile.q_blocks(n);

    // Parallelize over *groups*: all `step` query blocks of a group share
    // one stripe set, so K'/V' are gathered **once per group** and reused
    // across the group's blocks (§3.4's caching — gathering per query
    // block would redo the same discrete loads `step` times; see
    // EXPERIMENTS.md §Perf for the measured effect).
    let groups = q_blocks.div_ceil(cfg.step);
    let results = parallel_map(groups, |g| {
        let idx = &stripes.groups[g];
        let qb_start = g * cfg.step;
        let qb_end = ((g + 1) * cfg.step).min(q_blocks);

        // Gather the group's discrete K/V columns once, chunked to tile
        // width so the inner matmuls stay dense.
        let mut gathered: Vec<(Mat, Mat)> = Vec::with_capacity(idx.len().div_ceil(tile.b_kv));
        let mut off = 0;
        while off < idx.len() {
            let chunk = &idx[off..(off + tile.b_kv).min(idx.len())];
            gathered.push((input.k.gather_rows(chunk), input.v.gather_rows(chunk)));
            off += chunk.len();
        }

        let mut group_out = Vec::with_capacity((qb_end - qb_start) * tile.b_q * d);
        let mut cost = CostTally::default();
        let mut s = Mat::zeros(tile.b_q, tile.b_kv);
        for qb in qb_start..qb_end {
            let row0 = qb * tile.b_q;
            let rows = (n - row0).min(tile.b_q);
            let q_i = input.q.rows_mat(row0, rows);

            // Resume from the cached anchor state (§3.4 reuse).
            let mut st = BlockState {
                m: state.m[row0..row0 + rows].to_vec(),
                l: state.l[row0..row0 + rows].to_vec(),
                acc: Mat::from_vec(
                    rows,
                    d,
                    state.acc.data[row0 * d..(row0 + rows) * d].to_vec(),
                ),
            };
            // All stripe columns precede the group's window start <= row0,
            // so no causal masking is needed inside the gathered tiles.
            for (k_g, v_g) in &gathered {
                if s.cols != k_g.rows || s.rows != rows {
                    s = Mat::zeros(rows, k_g.rows);
                }
                matmul_nt_scaled(&q_i, k_g, scale, &mut s);
                st.fold_tile(&mut s, v_g);
                cost.add(CostTally::attn_tile(rows, k_g.rows, d));
            }
            let base = group_out.len();
            group_out.resize(base + rows * d, 0.0f32);
            st.write_output(&mut group_out[base..], d);
        }
        (group_out, cost)
    });

    let mut out = Mat::zeros(n, d);
    let mut cost = CostTally::default();
    for (g, (rows_data, c)) in results.into_iter().enumerate() {
        let row0 = g * cfg.step * tile.b_q;
        out.data[row0 * d..row0 * d + rows_data.len()].copy_from_slice(&rows_data);
        cost.add(c);
    }
    for qb in 0..q_blocks {
        coverage.set_indices(qb, &stripes.groups[qb / cfg.step]);
    }
    (out, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::anchor::compute::anchor_pass;
    use crate::attention::anchor::identify::identify_stripes;
    use crate::attention::full::naive_attention;
    use crate::attention::TileConfig;
    use crate::tensor::ops::{causal_mask_inplace, softmax_rows};
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn cfg(theta: f32) -> AnchorConfig {
        AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        }
    }

    /// With θ = ∞, every candidate is gathered, so the result is exact.
    #[test]
    fn full_stripe_set_equals_dense() {
        let h = rand_head(41, 160, 8);
        let c = cfg(f32::INFINITY);
        let (state, mut cov) = anchor_pass(&h, &c);
        let stripes = identify_stripes(&h, &c, &state);
        let (out, _) = sparse_pass(&h, &c, &state, &stripes, &mut cov);
        let expect = naive_attention(&h);
        assert!(out.max_abs_diff(&expect) < 1e-4);
    }

    /// Sparse output must equal softmax restricted to the covered set —
    /// the defining property of masked attention with exact arithmetic.
    #[test]
    fn output_equals_coverage_masked_softmax() {
        let n = 128;
        let d = 8;
        let h = rand_head(42, n, d);
        let c = cfg(2.0);
        let (state, mut cov) = anchor_pass(&h, &c);
        let stripes = identify_stripes(&h, &c, &state);
        let (out, _) = sparse_pass(&h, &c, &state, &stripes, &mut cov);

        let mut s = Mat::zeros(n, n);
        matmul_nt_scaled(&h.q, &h.k, h.scale(), &mut s);
        causal_mask_inplace(&mut s, 0, 0);
        for r in 0..n {
            let qb = r / 16;
            for col in 0..n {
                if !cov.covered(qb, col) {
                    s.set(r, col, f32::NEG_INFINITY);
                }
            }
        }
        softmax_rows(&mut s);
        let mut expect = Mat::zeros(n, d);
        crate::tensor::matmul_nn_acc(&s, &h.v, &mut expect);
        assert!(
            out.max_abs_diff(&expect) < 1e-4,
            "max diff {}",
            out.max_abs_diff(&expect)
        );
    }

    #[test]
    fn empty_stripes_reduce_to_anchor_output() {
        let h = rand_head(43, 96, 8);
        let c = cfg(f32::NEG_INFINITY);
        let (state, mut cov) = anchor_pass(&h, &c);
        let stripes = identify_stripes(&h, &c, &state);
        assert_eq!(stripes.total(), 0);
        let (out, cost) = sparse_pass(&h, &c, &state, &stripes, &mut cov);
        assert_eq!(cost.flops, 0, "no gathered tiles -> no sparse flops");
        // Output = normalized anchor state.
        for r in 0..96 {
            let inv = 1.0 / state.l[r];
            for col in 0..8 {
                assert!((out.at(r, col) - state.acc.at(r, col) * inv).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn coverage_includes_gathered_stripes() {
        let h = rand_head(44, 128, 8);
        let c = cfg(5.0);
        let (state, mut cov) = anchor_pass(&h, &c);
        let stripes = identify_stripes(&h, &c, &state);
        let before = cov.total_covered();
        let (_, _) = sparse_pass(&h, &c, &state, &stripes, &mut cov);
        // Each gathered stripe appears in the coverage of each block in its
        // group (cov only counts causal ones).
        assert!(cov.total_covered() >= before);
        for (g, sel) in stripes.groups.iter().enumerate() {
            for qb in (g * 2)..((g + 1) * 2).min(cov.q_blocks()) {
                for &col in sel {
                    assert!(cov.covered(qb, col as usize), "g={g} qb={qb} col={col}");
                }
            }
        }
    }

    #[test]
    fn gather_chunking_invariant_to_bkv() {
        // Same θ, different kv tile width: outputs must match (chunking is
        // a pure implementation detail of the online softmax).
        let h = rand_head(45, 128, 8);
        let mut c1 = cfg(3.0);
        c1.tile = TileConfig::new(16, 8);
        c1.init_blocks = 8; // init region = 64 columns
        let mut c2 = cfg(3.0);
        c2.tile = TileConfig::new(16, 64);
        c2.init_blocks = 1; // init region = 64 columns
        let o1 = crate::attention::anchor::anchor_attention(&h, &c1);
        let o2 = crate::attention::anchor::anchor_attention(&h, &c2);
        assert!(o1.out.max_abs_diff(&o2.out) < 1e-4);
    }
}

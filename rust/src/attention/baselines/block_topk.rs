//! Block-granular top-k baseline — the analysis comparator of Table 1
//! (block (128,128) top-k=256 vs stripe (128,1) top-k=16384) and §2.1.1's
//! "static k" discussion.

use crate::attention::plan::{plan_from_block_sets, run_planner, Planner, SparsePlan};
use crate::attention::{AttnOutput, CostTally, HeadInput, TileConfig};
use crate::tensor::ops::avgpool_rows;
use crate::tensor::{matmul_nt_scaled, Mat};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockTopKConfig {
    pub tile: TileConfig,
    /// Key blocks kept per query block (Table 1 uses k=256 at 128k; scale
    /// proportionally at shorter lengths).
    pub k: usize,
    /// Always include the diagonal block (local) and block 0 (sink) — set
    /// false for the "pure top-k" analysis variant.
    pub force_sink_local: bool,
}

impl Default for BlockTopKConfig {
    fn default() -> Self {
        Self { tile: TileConfig::default(), k: 256, force_sink_local: true }
    }
}

/// Per-query-block top-k key blocks by pooled block score.
pub fn select_topk_blocks(input: &HeadInput, cfg: &BlockTopKConfig) -> (Vec<Vec<u32>>, CostTally) {
    let n = input.n();
    let d = input.d();
    let scale = input.scale();
    let tile = cfg.tile;
    let q_blocks = tile.q_blocks(n);
    let kv_blocks = tile.kv_blocks(n);

    let q_pool = avgpool_rows(&input.q, tile.b_q);
    let k_pool = avgpool_rows(&input.k, tile.b_kv);
    let mut s = Mat::zeros(q_blocks, kv_blocks);
    matmul_nt_scaled(&q_pool, &k_pool, scale, &mut s);
    let cost = CostTally::ident_tile(q_blocks, kv_blocks, d);

    let mut sets = Vec::with_capacity(q_blocks);
    for qb in 0..q_blocks {
        let visible = kv_blocks.min(((qb + 1) * tile.b_q).div_ceil(tile.b_kv));
        let row = &s.row(qb)[..visible];
        let mut order: Vec<u32> = (0..visible as u32).collect();
        order.sort_unstable_by(|&a, &b| row[b as usize].partial_cmp(&row[a as usize]).unwrap());
        order.truncate(cfg.k.min(visible));
        if cfg.force_sink_local {
            let diag = (visible - 1) as u32;
            if !order.contains(&0) {
                order.push(0);
            }
            if !order.contains(&diag) {
                order.push(diag);
            }
        }
        order.sort_unstable();
        sets.push(order);
    }
    (sets, cost)
}

impl Planner for BlockTopKConfig {
    fn name(&self) -> &'static str {
        "block-topk"
    }

    fn plan(&self, input: &HeadInput) -> SparsePlan {
        let (sets, est_cost) = select_topk_blocks(input, self);
        plan_from_block_sets("block-topk", input, self.tile, &sets, est_cost)
    }
}

pub fn block_topk_attention(input: &HeadInput, cfg: &BlockTopKConfig) -> AttnOutput {
    run_planner(input, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::naive_attention;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn cfg(k: usize, b: usize) -> BlockTopKConfig {
        BlockTopKConfig { tile: TileConfig::new(b, b), k, force_sink_local: true }
    }

    #[test]
    fn k_covering_all_equals_dense() {
        let h = rand_head(91, 128, 8);
        let out = block_topk_attention(&h, &cfg(8, 16));
        let expect = naive_attention(&h);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn exactly_k_blocks_selected() {
        let h = rand_head(92, 512, 8);
        let c = BlockTopKConfig { tile: TileConfig::new(16, 16), k: 3, force_sink_local: false };
        let (sets, _) = select_topk_blocks(&h, &c);
        for (qb, set) in sets.iter().enumerate() {
            assert_eq!(set.len(), 3.min(qb + 1), "qb {qb}");
        }
    }

    #[test]
    fn sink_and_local_forced() {
        let h = rand_head(93, 512, 8);
        let (sets, _) = select_topk_blocks(&h, &cfg(2, 16));
        for (qb, set) in sets.iter().enumerate() {
            assert!(set.contains(&0), "qb {qb} missing sink");
            assert!(set.contains(&(qb as u32)), "qb {qb} missing diagonal");
        }
    }

    #[test]
    fn sparsity_grows_as_k_shrinks() {
        let h = rand_head(94, 512, 8);
        let s_small = block_topk_attention(&h, &cfg(2, 16)).coverage.sparsity();
        let s_large = block_topk_attention(&h, &cfg(16, 16)).coverage.sparsity();
        assert!(s_small > s_large);
    }
}

//! FlexPrefill baseline (Lai et al., 2025): dynamic per-head block
//! selection by *top-cdf* — keep the smallest set of key blocks whose
//! estimated attention mass reaches `γ`, estimated from pooled queries.
//! Representative of the block-granular state of the art the paper claims
//! a 1.44× speedup over.
//!
//! Simplification vs the original: FlexPrefill additionally classifies
//! heads as structured ("vertical-slash") vs query-aware using a JS
//! divergence test (`τ`); we implement the query-aware top-cdf path for
//! every head, which is the path exercised at the paper's settings
//! (γ=0.95, τ=0.1) on long inputs. Documented in DESIGN.md §1.

use crate::attention::plan::{plan_from_block_sets, run_planner, Planner, SparsePlan};
use crate::attention::{AttnOutput, CostTally, HeadInput, TileConfig};
use crate::tensor::ops::avgpool_rows;
use crate::tensor::{matmul_nt_scaled, Mat};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlexPrefillConfig {
    pub tile: TileConfig,
    /// Cumulative attention mass target per query block row (paper: 0.95).
    pub gamma: f64,
    /// Minimum token budget regardless of γ (paper: 1024).
    pub min_budget_tokens: usize,
}

impl Default for FlexPrefillConfig {
    fn default() -> Self {
        Self { tile: TileConfig::default(), gamma: 0.95, min_budget_tokens: 1024 }
    }
}

/// Block selection, following FlexPrefill's query-aware estimation: pooled
/// queries are scored against **all keys** (not pooled keys — key pooling
/// would dilute single-column evidence by 1/b_kv, which is the granularity
/// failure the paper analyzes), softmaxed per pooled row, and each key
/// block's score is the **sum of its member keys' probabilities**. Blocks
/// are then kept by top-cdf(γ) with a floor of `min_budget` blocks.
pub fn select_blocks(input: &HeadInput, cfg: &FlexPrefillConfig) -> (Vec<Vec<u32>>, CostTally) {
    let n = input.n();
    let d = input.d();
    let scale = input.scale();
    let tile = cfg.tile;
    let q_blocks = tile.q_blocks(n);
    let kv_blocks = tile.kv_blocks(n);

    let q_pool = avgpool_rows(&input.q, tile.b_q);
    let mut s = Mat::zeros(q_blocks, n);
    matmul_nt_scaled(&q_pool, &input.k, scale, &mut s);
    let cost = CostTally::ident_tile(q_blocks, n, d);

    let min_blocks = cfg.min_budget_tokens.div_ceil(tile.b_kv).max(1);
    let mut sets = Vec::with_capacity(q_blocks);
    for qb in 0..q_blocks {
        // Causal: keys visible iff col < (qb+1)*b_q.
        let visible_cols = n.min((qb + 1) * tile.b_q);
        let visible = kv_blocks.min(visible_cols.div_ceil(tile.b_kv));
        let row = &s.row(qb)[..visible_cols];
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        // Block score = Σ softmax probs of member keys.
        let mut probs = vec![0.0f64; visible];
        let mut z = 0.0f64;
        for (col, &x) in row.iter().enumerate() {
            let p = ((x - mx) as f64).exp();
            probs[col / tile.b_kv] += p;
            z += p;
        }
        // Sort blocks by probability descending (this sort is FlexPrefill's
        // intrinsic overhead — the paper's difference-aware rule avoids it).
        let mut order: Vec<u32> = (0..visible as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            probs[b as usize].partial_cmp(&probs[a as usize]).unwrap()
        });
        let mut cum = 0.0;
        let mut chosen = Vec::new();
        for &jb in &order {
            if cum >= cfg.gamma * z && chosen.len() >= min_blocks.min(visible) {
                break;
            }
            cum += probs[jb as usize];
            chosen.push(jb);
        }
        chosen.sort_unstable();
        sets.push(chosen);
    }
    (sets, cost)
}

impl Planner for FlexPrefillConfig {
    fn name(&self) -> &'static str {
        "flexprefill"
    }

    fn plan(&self, input: &HeadInput) -> SparsePlan {
        let (sets, est_cost) = select_blocks(input, self);
        plan_from_block_sets("flexprefill", input, self.tile, &sets, est_cost)
    }
}

pub fn flexprefill_attention(input: &HeadInput, cfg: &FlexPrefillConfig) -> AttnOutput {
    run_planner(input, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::naive_attention;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn cfg(gamma: f64, min_tokens: usize, b: usize) -> FlexPrefillConfig {
        FlexPrefillConfig { tile: TileConfig::new(b, b), gamma, min_budget_tokens: min_tokens }
    }

    #[test]
    fn gamma_one_selects_all_visible_blocks() {
        let h = rand_head(81, 128, 8);
        let (sets, _) = select_blocks(&h, &cfg(1.0, 16, 16));
        for (qb, set) in sets.iter().enumerate() {
            assert_eq!(set.len(), qb + 1, "qb {qb} must select every causal block");
        }
        let out = flexprefill_attention(&h, &cfg(1.0, 16, 16));
        let expect = naive_attention(&h);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn min_budget_floor_applies() {
        let h = rand_head(82, 256, 8);
        // γ=0 would select nothing without the floor.
        let (sets, _) = select_blocks(&h, &cfg(0.0, 64, 16));
        for (qb, set) in sets.iter().enumerate() {
            let visible = qb + 1;
            assert!(set.len() >= 4.min(visible), "qb {qb}: {} blocks", set.len());
        }
    }

    #[test]
    fn gamma_monotone_in_coverage() {
        let h = rand_head(83, 512, 8);
        let lo = flexprefill_attention(&h, &cfg(0.5, 16, 16));
        let hi = flexprefill_attention(&h, &cfg(0.99, 16, 16));
        assert!(hi.coverage.total_covered() >= lo.coverage.total_covered());
    }

    #[test]
    fn block_sets_sorted_unique() {
        let h = rand_head(84, 256, 8);
        let (sets, _) = select_blocks(&h, &cfg(0.9, 32, 16));
        for set in &sets {
            assert!(set.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn hot_block_always_selected() {
        // Plant a key block with overwhelming pooled score; top-cdf must
        // include it for late query blocks.
        let n = 256;
        let d = 8;
        let mut rng = Pcg64::seeded(85);
        let q = Mat::from_fn(n, d, |_, _| rng.normal() * 0.1 + 1.0);
        let mut k = Mat::from_fn(n, d, |_, _| rng.normal() * 0.1 - 1.0);
        for r in 32..48 {
            for c in 0..d {
                k.set(r, c, 4.0);
            }
        }
        let v = Mat::from_fn(n, d, |_, _| rng.normal());
        let h = HeadInput::new(q, k, v);
        let (sets, _) = select_blocks(&h, &cfg(0.5, 16, 16));
        // Block 2 holds rows 32..48.
        for qb in 3..16 {
            assert!(sets[qb].contains(&2), "qb {qb}: {:?}", sets[qb]);
        }
    }
}

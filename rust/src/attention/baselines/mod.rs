//! Baseline sparse-attention methods the paper evaluates against
//! (Table 2/3, Fig. 6/7): StreamingLLM, MInference's Vertical_Slash,
//! FlexPrefill, and a block-top-k analysis baseline (Table 1).
//!
//! All baselines produce a [`Coverage`] and compute *exact* softmax
//! attention restricted to that coverage, via one of two shared kernels:
//!
//! * [`block_sparse_attention`] — contiguous key-block tiles (the fast path
//!   block-sparse methods get on real hardware);
//! * [`coverage_attention`] — gather-based, for methods with discrete
//!   column patterns (Vertical_Slash's verticals).

pub mod block_topk;
pub mod flexprefill;
pub mod streaming;
pub mod vertical_slash;

use crate::attention::full::{mask_tile_causal, BlockState};
use crate::attention::mask::Coverage;
use crate::attention::{AttnOutput, CostTally, HeadInput, TileConfig};
use crate::tensor::{matmul_nt_scaled, Mat};
use crate::util::threadpool::parallel_map;

/// Exact attention over per-query-block *key block* lists (contiguous
/// tiles). `block_sets[qb]` holds sorted kv-block indices; blocks past the
/// causal limit are clipped, diagonal blocks are causally masked.
pub fn block_sparse_attention(
    input: &HeadInput,
    tile: TileConfig,
    block_sets: &[Vec<u32>],
) -> AttnOutput {
    let n = input.n();
    let d = input.d();
    let scale = input.scale();
    let q_blocks = tile.q_blocks(n);
    assert_eq!(block_sets.len(), q_blocks);

    let results = parallel_map(q_blocks, |qb| {
        let row0 = qb * tile.b_q;
        let rows = (n - row0).min(tile.b_q);
        let limit = row0 + rows;
        let q_i = input.q.rows_mat(row0, rows);
        let mut st = BlockState::new(rows, d);
        let mut cost = CostTally::default();
        let mut s = Mat::zeros(rows, tile.b_kv);
        for &jb in &block_sets[qb] {
            let col0 = jb as usize * tile.b_kv;
            if col0 >= limit {
                continue;
            }
            let cols = (limit - col0).min(tile.b_kv);
            let k_j = input.k.rows_mat(col0, cols);
            let v_j = input.v.rows_mat(col0, cols);
            if s.cols != cols || s.rows != rows {
                s = Mat::zeros(rows, cols);
            }
            matmul_nt_scaled(&q_i, &k_j, scale, &mut s);
            if col0 + cols > row0 {
                mask_tile_causal(&mut s, row0, col0);
            }
            st.fold_tile(&mut s, &v_j);
            cost.add(CostTally::attn_tile(rows, cols, d));
        }
        let mut out_rows = vec![0.0f32; rows * d];
        st.write_output(&mut out_rows, d);
        (out_rows, cost)
    });

    let mut out = Mat::zeros(n, d);
    let mut cost = CostTally::default();
    let mut coverage = Coverage::new(n, tile.b_q);
    for (qb, (rows_data, c)) in results.into_iter().enumerate() {
        let row0 = qb * tile.b_q;
        out.data[row0 * d..row0 * d + rows_data.len()].copy_from_slice(&rows_data);
        cost.add(c);
        let limit = ((qb + 1) * tile.b_q).min(n);
        for &jb in &block_sets[qb] {
            let col0 = jb as usize * tile.b_kv;
            if col0 < limit {
                coverage.set_range(qb, col0, (col0 + tile.b_kv).min(limit));
            }
        }
    }
    AttnOutput { out, coverage, cost }
}

/// Exact attention over an arbitrary [`Coverage`] (gather path). Columns
/// beyond each row's causal limit are masked per-row inside the tile.
pub fn coverage_attention(input: &HeadInput, tile: TileConfig, coverage: &Coverage) -> AttnOutput {
    let n = input.n();
    let d = input.d();
    let scale = input.scale();
    let q_blocks = tile.q_blocks(n);
    assert_eq!(coverage.n, n);
    assert_eq!(coverage.b_q, tile.b_q);

    let results = parallel_map(q_blocks, |qb| {
        let row0 = qb * tile.b_q;
        let rows = (n - row0).min(tile.b_q);
        let limit = row0 + rows;
        let q_i = input.q.rows_mat(row0, rows);
        let mut st = BlockState::new(rows, d);
        let mut cost = CostTally::default();

        let cols: Vec<u32> =
            coverage.columns(qb).into_iter().filter(|&c| (c as usize) < limit).collect();
        let mut s = Mat::zeros(rows, tile.b_kv.min(cols.len().max(1)));
        let mut off = 0;
        while off < cols.len() {
            let chunk = &cols[off..(off + tile.b_kv).min(cols.len())];
            let k_g = input.k.gather_rows(chunk);
            let v_g = input.v.gather_rows(chunk);
            if s.cols != chunk.len() || s.rows != rows {
                s = Mat::zeros(rows, chunk.len());
            }
            matmul_nt_scaled(&q_i, &k_g, scale, &mut s);
            // Per-row causal mask against absolute column ids.
            for r in 0..rows {
                let abs_row = row0 + r;
                let srow = s.row_mut(r);
                for (ci, &col) in chunk.iter().enumerate() {
                    if col as usize > abs_row {
                        srow[ci] = f32::NEG_INFINITY;
                    }
                }
            }
            st.fold_tile(&mut s, &v_g);
            cost.add(CostTally::attn_tile(rows, chunk.len(), d));
            off += chunk.len();
        }
        let mut out_rows = vec![0.0f32; rows * d];
        st.write_output(&mut out_rows, d);
        (out_rows, cost)
    });

    let mut out = Mat::zeros(n, d);
    let mut cost = CostTally::default();
    for (qb, (rows_data, c)) in results.into_iter().enumerate() {
        let row0 = qb * tile.b_q;
        out.data[row0 * d..row0 * d + rows_data.len()].copy_from_slice(&rows_data);
        cost.add(c);
    }
    AttnOutput { out, coverage: coverage.clone(), cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::naive_attention;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    #[test]
    fn all_blocks_equals_dense() {
        let h = rand_head(51, 128, 8);
        let tile = TileConfig::new(16, 16);
        let sets: Vec<Vec<u32>> = (0..8).map(|qb| (0..=qb as u32).collect()).collect();
        let out = block_sparse_attention(&h, tile, &sets);
        let expect = naive_attention(&h);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
        assert_eq!(out.coverage.sparsity(), 0.0);
    }

    #[test]
    fn coverage_attention_full_equals_dense() {
        let h = rand_head(52, 96, 8);
        let tile = TileConfig::new(32, 32);
        let cov = Coverage::full(96, 32);
        let out = coverage_attention(&h, tile, &cov);
        let expect = naive_attention(&h);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn block_and_gather_paths_agree() {
        let h = rand_head(53, 128, 8);
        let tile = TileConfig::new(16, 16);
        let sets: Vec<Vec<u32>> = (0..8)
            .map(|qb| {
                let mut v: Vec<u32> = vec![0, qb as u32];
                v.dedup();
                v
            })
            .collect();
        let a = block_sparse_attention(&h, tile, &sets);
        let b = coverage_attention(&h, tile, &a.coverage);
        assert!(a.out.max_abs_diff(&b.out) < 1e-4);
        assert_eq!(a.coverage.total_covered(), b.coverage.total_covered());
    }

    #[test]
    fn acausal_blocks_are_clipped() {
        let h = rand_head(54, 64, 8);
        let tile = TileConfig::new(16, 16);
        // Request future blocks for qb 0 — should be ignored gracefully.
        let sets: Vec<Vec<u32>> = vec![vec![0, 3], vec![0, 1], vec![0, 1, 2], vec![0, 1, 2, 3]];
        let out = block_sparse_attention(&h, tile, &sets);
        assert!(out.coverage.covered(0, 0));
        assert!(!out.coverage.covered(0, 48));
    }

    #[test]
    fn diagonal_only_first_row_is_v0() {
        let h = rand_head(55, 64, 8);
        let tile = TileConfig::new(16, 16);
        let sets: Vec<Vec<u32>> = (0..4).map(|qb| vec![qb as u32]).collect();
        let out = block_sparse_attention(&h, tile, &sets);
        for c in 0..8 {
            assert!((out.out.at(0, c) - h.v.at(0, c)).abs() < 1e-5);
        }
    }
}

//! Baseline sparse-attention methods the paper evaluates against
//! (Table 2/3, Fig. 6/7): StreamingLLM, MInference's Vertical_Slash,
//! FlexPrefill, and a block-top-k analysis baseline (Table 1).
//!
//! Every baseline is a [`crate::attention::plan::Planner`]: its selection
//! logic emits a [`crate::attention::plan::SparsePlan`] — contiguous block
//! patterns become anchor spans ([`crate::attention::plan::plan_from_block_sets`]),
//! discrete patterns become stripes
//! ([`crate::attention::plan::plan_from_coverage`]) — and the shared
//! executor computes exact softmax attention restricted to the plan, so
//! every method's numbers stay apples-to-apples.
//!
//! The two legacy kernels survive as thin wrappers over that pipeline.

pub mod block_topk;
pub mod flexprefill;
pub mod streaming;
pub mod vertical_slash;

use crate::attention::mask::Coverage;
use crate::attention::plan::{execute_plan, plan_from_block_sets, plan_from_coverage};
use crate::attention::{AttnOutput, CostTally, HeadInput, TileConfig};

/// Exact attention over per-query-block *key block* lists (contiguous
/// tiles). `block_sets[qb]` holds sorted kv-block indices; blocks past the
/// causal limit are clipped, diagonal blocks are causally masked. Thin
/// wrapper: the block lists become a span-only plan.
pub fn block_sparse_attention(
    input: &HeadInput,
    tile: TileConfig,
    block_sets: &[Vec<u32>],
) -> AttnOutput {
    let plan =
        plan_from_block_sets("block-sparse", input, tile, block_sets, CostTally::default());
    execute_plan(input, &plan)
}

/// Exact attention over an arbitrary [`Coverage`] (gather path). Columns
/// beyond each row's causal limit are masked per-row inside the tile.
/// Thin wrapper: the covered columns become a stripe-only plan.
pub fn coverage_attention(input: &HeadInput, tile: TileConfig, coverage: &Coverage) -> AttnOutput {
    let plan = plan_from_coverage("coverage", input, tile, coverage, CostTally::default());
    execute_plan(input, &plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::naive_attention;
    use crate::tensor::Mat;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    #[test]
    fn all_blocks_equals_dense() {
        let h = rand_head(51, 128, 8);
        let tile = TileConfig::new(16, 16);
        let sets: Vec<Vec<u32>> = (0..8).map(|qb| (0..=qb as u32).collect()).collect();
        let out = block_sparse_attention(&h, tile, &sets);
        let expect = naive_attention(&h);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
        assert_eq!(out.coverage.sparsity(), 0.0);
    }

    #[test]
    fn coverage_attention_full_equals_dense() {
        let h = rand_head(52, 96, 8);
        let tile = TileConfig::new(32, 32);
        let cov = Coverage::full(96, 32);
        let out = coverage_attention(&h, tile, &cov);
        let expect = naive_attention(&h);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn block_and_gather_paths_agree() {
        let h = rand_head(53, 128, 8);
        let tile = TileConfig::new(16, 16);
        let sets: Vec<Vec<u32>> = (0..8)
            .map(|qb| {
                let mut v: Vec<u32> = vec![0, qb as u32];
                v.dedup();
                v
            })
            .collect();
        let a = block_sparse_attention(&h, tile, &sets);
        let b = coverage_attention(&h, tile, &a.coverage);
        assert!(a.out.max_abs_diff(&b.out) < 1e-4);
        assert_eq!(a.coverage.total_covered(), b.coverage.total_covered());
    }

    #[test]
    fn acausal_blocks_are_clipped() {
        let h = rand_head(54, 64, 8);
        let tile = TileConfig::new(16, 16);
        // Request future blocks for qb 0 — should be ignored gracefully.
        let sets: Vec<Vec<u32>> = vec![vec![0, 3], vec![0, 1], vec![0, 1, 2], vec![0, 1, 2, 3]];
        let out = block_sparse_attention(&h, tile, &sets);
        assert!(out.coverage.covered(0, 0));
        assert!(!out.coverage.covered(0, 48));
    }

    #[test]
    fn diagonal_only_first_row_is_v0() {
        let h = rand_head(55, 64, 8);
        let tile = TileConfig::new(16, 16);
        let sets: Vec<Vec<u32>> = (0..4).map(|qb| vec![qb as u32]).collect();
        let out = block_sparse_attention(&h, tile, &sets);
        for c in 0..8 {
            assert!((out.out.at(0, c) - h.v.at(0, c)).abs() < 1e-5);
        }
    }
}

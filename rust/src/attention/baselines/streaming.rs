//! StreamingLLM baseline (Xiao et al., 2024): keep only the initial
//! "attention sink" tokens and a rolling local window. Static pattern —
//! fast, but misses mid-context information (the failure mode Table 3 and
//! Fig. 7 show at long lengths).

use crate::attention::plan::{plan_from_block_sets, run_planner, Planner, SparsePlan};
use crate::attention::{AttnOutput, CostTally, HeadInput, TileConfig};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StreamingConfig {
    pub tile: TileConfig,
    /// Tokens kept at the start of the sequence (paper setup: 1024).
    pub global_tokens: usize,
    /// Rolling local window in tokens (paper setup: 8192 long-context,
    /// 1024 LongBench).
    pub local_tokens: usize,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        Self { tile: TileConfig::default(), global_tokens: 1024, local_tokens: 8192 }
    }
}

impl StreamingConfig {
    pub fn new(global_tokens: usize, local_tokens: usize) -> Self {
        Self { tile: TileConfig::default(), global_tokens, local_tokens }
    }
}

/// Per-query-block key-block list for the streaming pattern.
pub fn streaming_blocks(cfg: &StreamingConfig, n: usize) -> Vec<Vec<u32>> {
    let tile = cfg.tile;
    let q_blocks = tile.q_blocks(n);
    let g_blocks = cfg.global_tokens.div_ceil(tile.b_kv);
    let l_blocks = cfg.local_tokens.div_ceil(tile.b_kv).max(1);
    (0..q_blocks)
        .map(|qb| {
            // Last kv block overlapping this q block (block-level causal).
            let diag = (((qb + 1) * tile.b_q - 1) / tile.b_kv).min(tile.kv_blocks(n) - 1);
            let local_start = (diag + 1).saturating_sub(l_blocks);
            let mut set: Vec<u32> = (0..g_blocks.min(diag + 1) as u32).collect();
            for jb in local_start..=diag {
                if jb >= g_blocks {
                    set.push(jb as u32);
                }
            }
            set
        })
        .collect()
}

impl Planner for StreamingConfig {
    fn name(&self) -> &'static str {
        "streaming-llm"
    }

    /// Static pattern ⇒ zero identification cost: sink + window blocks
    /// become anchor spans.
    fn plan(&self, input: &HeadInput) -> SparsePlan {
        let sets = streaming_blocks(self, input.n());
        plan_from_block_sets("streaming-llm", input, self.tile, &sets, CostTally::default())
    }
}

pub fn streaming_attention(input: &HeadInput, cfg: &StreamingConfig) -> AttnOutput {
    run_planner(input, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::naive_attention;
    use crate::tensor::Mat;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn cfg(global: usize, local: usize, b: usize) -> StreamingConfig {
        StreamingConfig { tile: TileConfig::new(b, b), global_tokens: global, local_tokens: local }
    }

    #[test]
    fn window_covering_everything_equals_dense() {
        let h = rand_head(61, 128, 8);
        let c = cfg(16, 128, 16);
        let out = streaming_attention(&h, &c);
        let expect = naive_attention(&h);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn pattern_is_sink_plus_window() {
        let c = cfg(16, 32, 16);
        let sets = streaming_blocks(&c, 160); // 10 blocks
        // q block 9 (rows 144..160): sink block 0 + local window blocks 8,9.
        assert_eq!(sets[9], vec![0, 8, 9]);
        // q block 1: diag=1, window covers 0..=1, sink = 0 -> {0, 1}.
        assert_eq!(sets[1], vec![0, 1]);
    }

    #[test]
    fn mid_context_not_covered() {
        let h = rand_head(62, 256, 8);
        let c = cfg(16, 32, 16);
        let out = streaming_attention(&h, &c);
        // Key block 4 (cols 64..80) invisible to q block 15.
        assert!(!out.coverage.covered(15, 70));
        assert!(out.coverage.covered(15, 0));
        assert!(out.coverage.covered(15, 255));
        assert!(out.coverage.sparsity() > 0.4);
    }

    #[test]
    fn no_duplicate_blocks_when_window_meets_sink() {
        let c = cfg(32, 64, 16);
        let sets = streaming_blocks(&c, 128);
        for set in &sets {
            let mut s = set.clone();
            s.dedup();
            assert_eq!(&s, set, "sorted, deduped");
        }
    }
}

//! Vertical_Slash baseline (MInference, Jiang et al. 2024): use the last
//! few queries to score every key (vertical) and every diagonal (slash),
//! then keep a fixed token budget of the best verticals and slashes.
//! The pattern is *estimated once from local information* — the precise
//! weakness AnchorAttention's global identification addresses (paper §1).

use crate::attention::mask::Coverage;
use crate::attention::plan::{plan_from_coverage, run_planner, Planner, SparsePlan};
use crate::attention::{AttnOutput, CostTally, HeadInput, TileConfig};
use crate::tensor::{matmul_nt_scaled, Mat};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VerticalSlashConfig {
    pub tile: TileConfig,
    /// Token budget for vertical columns (paper setup: 1024).
    pub vertical_tokens: usize,
    /// Token budget for slash diagonals (paper setup: 8192 long-context).
    pub slash_tokens: usize,
    /// How many trailing queries estimate the pattern (MInference uses 64).
    pub last_q: usize,
}

impl Default for VerticalSlashConfig {
    fn default() -> Self {
        Self {
            tile: TileConfig::default(),
            vertical_tokens: 1024,
            slash_tokens: 8192,
            last_q: 64,
        }
    }
}

/// The estimated pattern: selected vertical columns and slash offsets
/// (`offset = row − col`, 0 = main diagonal).
#[derive(Clone, Debug)]
pub struct VsPattern {
    pub verticals: Vec<u32>,
    pub slashes: Vec<u32>,
    pub cost: CostTally,
}

/// Estimate the vertical/slash pattern from the last `last_q` queries.
pub fn estimate_pattern(input: &HeadInput, cfg: &VerticalSlashConfig) -> VsPattern {
    let n = input.n();
    let d = input.d();
    let scale = input.scale();
    let lq = cfg.last_q.min(n);
    let row0 = n - lq;

    // Scores of the trailing queries against every key (all causally
    // visible for the last rows except the triangular corner).
    let q_tail = input.q.rows_mat(row0, lq);
    let mut s = Mat::zeros(lq, n);
    matmul_nt_scaled(&q_tail, &input.k, scale, &mut s);
    crate::tensor::ops::causal_mask_inplace(&mut s, row0, 0);
    crate::tensor::ops::softmax_rows(&mut s);
    let cost = CostTally::ident_tile(lq, n, d);

    // Vertical score: mean attention probability per column.
    let mut vert = vec![0.0f32; n];
    for r in 0..lq {
        for (c, &p) in s.row(r).iter().enumerate() {
            vert[c] += p;
        }
    }
    // Slash score: mean along diagonals (offset = abs_row - col >= 0).
    let mut slash = vec![0.0f32; n];
    for r in 0..lq {
        let abs_row = row0 + r;
        for (c, &p) in s.row(r).iter().enumerate() {
            if c <= abs_row {
                slash[abs_row - c] += p;
            }
        }
    }

    let verticals = top_indices(&vert, cfg.vertical_tokens.min(n));
    let slashes = top_indices(&slash, cfg.slash_tokens.min(n));
    VsPattern { verticals, slashes, cost }
}

/// Indices of the `k` largest scores, ascending order.
fn top_indices(scores: &[f32], k: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    if k < scores.len() {
        idx.select_nth_unstable_by(k, |&a, &b| {
            scores[b as usize].partial_cmp(&scores[a as usize]).unwrap()
        });
        idx.truncate(k);
    }
    idx.sort_unstable();
    idx
}

/// Materialize the pattern as coverage: verticals cover whole columns;
/// a slash with offset `o` covers column `row − o` for every row, i.e. per
/// query block the diagonal band `[row0 − o, row0 + rows − 1 − o]`.
pub fn pattern_coverage(pattern: &VsPattern, n: usize, tile: TileConfig) -> Coverage {
    let mut cov = Coverage::new(n, tile.b_q);
    let q_blocks = tile.q_blocks(n);
    for qb in 0..q_blocks {
        let row0 = qb * tile.b_q;
        let rows = (n - row0).min(tile.b_q);
        cov.set_indices(qb, &pattern.verticals);
        for &o in &pattern.slashes {
            let o = o as usize;
            let lo = row0.saturating_sub(o);
            let hi = (row0 + rows).saturating_sub(o); // exclusive
            cov.set_range(qb, lo, hi);
        }
    }
    cov
}

impl Planner for VerticalSlashConfig {
    fn name(&self) -> &'static str {
        "vertical-slash"
    }

    /// Discrete pattern ⇒ stripe-only plan: verticals and slash bands are
    /// gathered column-by-column, exactly as MInference's sparse kernel
    /// loads them.
    fn plan(&self, input: &HeadInput) -> SparsePlan {
        let pattern = estimate_pattern(input, self);
        let cov = pattern_coverage(&pattern, input.n(), self.tile);
        plan_from_coverage("vertical-slash", input, self.tile, &cov, pattern.cost)
    }
}

pub fn vertical_slash_attention(input: &HeadInput, cfg: &VerticalSlashConfig) -> AttnOutput {
    run_planner(input, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::naive_attention;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn cfg(v: usize, s: usize, b: usize) -> VerticalSlashConfig {
        VerticalSlashConfig {
            tile: TileConfig::new(b, b),
            vertical_tokens: v,
            slash_tokens: s,
            last_q: 16,
        }
    }

    #[test]
    fn full_budget_equals_dense() {
        let h = rand_head(71, 96, 8);
        let c = cfg(96, 96, 16);
        let out = vertical_slash_attention(&h, &c);
        let expect = naive_attention(&h);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
        assert_eq!(out.coverage.sparsity(), 0.0);
    }

    #[test]
    fn top_indices_selects_largest() {
        let scores = [0.1f32, 5.0, 0.2, 3.0, 4.0];
        assert_eq!(top_indices(&scores, 2), vec![1, 4]);
        assert_eq!(top_indices(&scores, 5), vec![0, 1, 2, 3, 4]);
        assert_eq!(top_indices(&scores, 0), Vec::<u32>::new());
    }

    #[test]
    fn slash_zero_covers_diagonal() {
        let pattern = VsPattern { verticals: vec![], slashes: vec![0], cost: Default::default() };
        let cov = pattern_coverage(&pattern, 64, TileConfig::new(16, 16));
        // q block 1 rows 16..32: slash 0 covers cols 16..32 (band).
        assert!(cov.covered(1, 16) && cov.covered(1, 31));
        assert!(!cov.covered(1, 0) && !cov.covered(1, 32));
    }

    #[test]
    fn planted_vertical_column_is_found() {
        // Construct K so column 7 has a huge dot product with every query.
        let n = 128;
        let d = 8;
        let mut rng = Pcg64::seeded(72);
        let q = Mat::from_fn(n, d, |_, _| rng.normal() * 0.1 + 1.0);
        let mut k = Mat::from_fn(n, d, |_, _| rng.normal() * 0.1 - 1.0);
        for c in 0..d {
            k.set(7, c, 5.0);
        }
        let v = Mat::from_fn(n, d, |_, _| rng.normal());
        let h = HeadInput::new(q, k, v);
        let c = cfg(4, 4, 16);
        let pattern = estimate_pattern(&h, &c);
        assert!(pattern.verticals.contains(&7), "verticals: {:?}", pattern.verticals);
    }

    #[test]
    fn sparsity_positive_with_small_budget() {
        let h = rand_head(73, 256, 8);
        // Each slash offset covers a b_q-wide band per query block, so keep
        // the budgets tiny to exercise a genuinely sparse pattern.
        let c = cfg(2, 2, 16);
        let out = vertical_slash_attention(&h, &c);
        assert!(out.coverage.sparsity() > 0.5, "sparsity {}", out.coverage.sparsity());
    }
}

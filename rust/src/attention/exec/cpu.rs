//! [`CpuTileExecutor`] — the blocked online-softmax tile walk (Alg. 3),
//! moved here from `attention::plan` when execution was lifted behind the
//! [`Executor`] trait. This is the reference backend: every other backend
//! must be bitwise-equal to it.
//!
//! Per group the walk assembles the group's discrete K/V columns **once**
//! (chunked to the kv tile width — §3.4's reuse across the group's `step`
//! query blocks), then runs one online softmax per query block: anchor
//! spans as dense tiles clipped to the block's causal limit, then the
//! gathered stripe chunks with per-row masking at or past the diagonal.
//!
//! Two raw-speed mechanisms live here (DESIGN.md §13):
//!
//! * **Run-serving assembly** — each chunk's contiguous coordinate runs
//!   (see [`LoweringMode`]) are read as `span_into` memcpys; only the
//!   stretches of true singletons fall back to a discrete `gather_into`.
//!   Both writes are pure row copies into the same destination rows, so
//!   the folded tile is bitwise-identical either way.
//! * **Per-worker scratch** — score buffer, gathered K'/V' tiles, the
//!   query tile and the online-softmax state are thread-local and resized
//!   in place, so the steady-state walk allocates nothing per tile. The
//!   scratch is per *worker thread*, not per call: a group runs wholly on
//!   one `parallel_map` worker, and the handful of workers bound the
//!   resident scratch regardless of how many groups a plan has.

use std::cell::RefCell;

use crate::attention::exec::{Executor, KvSource, LoweredChunk, LoweringMode, PlanLowering};
use crate::attention::full::{mask_tile_causal, BlockState};
use crate::attention::plan::SparsePlan;
use crate::attention::{AttnOutput, CostTally};
use crate::tensor::{matmul_nt_scaled, Mat};
use crate::util::threadpool::parallel_map;

/// The multithreaded CPU tile walk.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CpuTileExecutor {
    /// Run groups on the calling thread only (the former
    /// `execute_plan_serial`): set by paths whose parallelism already
    /// lives at a coarser granularity, e.g. head-parallel batching.
    pub serial: bool,
    /// How stripe coordinates are lowered before the walk: contiguous
    /// runs (default) or plain per-coordinate gathers. The discrete mode
    /// exists as the parity reference — outputs are bitwise identical in
    /// both modes.
    pub lowering: LoweringMode,
}

impl Executor for CpuTileExecutor {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn execute_source(
        &self,
        q: &Mat,
        kv: &dyn KvSource,
        plan: &SparsePlan,
        parallel: bool,
    ) -> AttnOutput {
        let lowering = PlanLowering::lower_with(plan, self.lowering);
        execute_lowered(q, kv, plan, &lowering, parallel && !self.serial)
    }
}

/// Execute a lowered plan: the shared host tile kernel. Both backends end
/// here (the PJRT backend after lowering/validation and, under the offline
/// stub, in place of the artifact call), which is what makes cross-backend
/// bitwise parity hold by construction.
pub(crate) fn execute_lowered(
    q: &Mat,
    kv: &dyn KvSource,
    plan: &SparsePlan,
    lowering: &PlanLowering<'_>,
    parallel: bool,
) -> AttnOutput {
    let n = q.rows;
    let d = q.cols;
    assert_eq!(plan.n, n, "plan built for a different sequence length");
    assert_eq!(kv.d(), d, "q/kv head dim mismatch");
    let tile = plan.tile;
    let groups = plan.groups.len();

    let run_group = |g: usize| fold_group(q, kv, plan, &lowering.stripe_chunks[g], g);
    let results: Vec<(Vec<f32>, CostTally)> = if parallel {
        parallel_map(groups, run_group)
    } else {
        (0..groups).map(run_group).collect()
    };

    let mut out = Mat::zeros(n, d);
    let mut cost = CostTally::default();
    for (g, (rows_data, c)) in results.into_iter().enumerate() {
        let row0 = g * plan.step * tile.b_q;
        out.data[row0 * d..row0 * d + rows_data.len()].copy_from_slice(&rows_data);
        cost.add(c);
    }
    AttnOutput { out, coverage: plan.coverage(), cost }
}

/// Per-worker scratch for the tile walk: every buffer the inner loops
/// touch, resized in place so the steady state allocates nothing. Owned by
/// a thread-local (one instance per threadpool worker), not created per
/// call: a group runs wholly on one worker, so no sharing is possible, and
/// the pool's worker count bounds the total resident scratch.
struct Scratch {
    /// Score buffer `s` (`matmul_nt_scaled` writes every element, so
    /// stale data from a previous tile shape is harmless).
    s: Mat,
    /// Gathered K'/V' tiles, one pair per stripe chunk.
    tiles: Vec<(Mat, Mat)>,
    /// The query block rows (copied once per block).
    q_tile: Mat,
    /// Anchor-span K/V tile.
    k_span: Mat,
    /// Anchor-span V tile.
    v_span: Mat,
    /// Online-softmax state, reset per query block.
    state: BlockState,
}

impl Scratch {
    fn new() -> Self {
        Self {
            s: Mat::zeros(0, 0),
            tiles: Vec::new(),
            q_tile: Mat::zeros(0, 0),
            k_span: Mat::zeros(0, 0),
            v_span: Mat::zeros(0, 0),
            state: BlockState::new(0, 0),
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::new());
}

/// Resize a scratch matrix in place without zeroing retained storage —
/// callers overwrite every element of the region they read.
#[inline]
fn resize_mat(m: &mut Mat, rows: usize, cols: usize) {
    m.data.resize(rows * cols, 0.0);
    m.rows = rows;
    m.cols = cols;
}

/// Compute one group's output rows: fold the group's anchor spans as dense
/// tiles, then the gathered stripe chunks — one online softmax per query
/// block, K'/V' assembled **once per group** and reused across its `step`
/// blocks (§3.4's reuse; this is the fine-grained gather substrate every
/// method runs on).
fn fold_group(
    q: &Mat,
    kv: &dyn KvSource,
    plan: &SparsePlan,
    chunks: &[LoweredChunk<'_>],
    g: usize,
) -> (Vec<f32>, CostTally) {
    // The walk never re-enters itself on one thread (KV sources don't call
    // back into executors), so the borrow is exclusive for the whole group.
    SCRATCH.with(|cell| fold_group_scratch(q, kv, plan, chunks, g, &mut cell.borrow_mut()))
}

fn fold_group_scratch(
    q: &Mat,
    kv: &dyn KvSource,
    plan: &SparsePlan,
    chunks: &[LoweredChunk<'_>],
    g: usize,
    scratch: &mut Scratch,
) -> (Vec<f32>, CostTally) {
    let n = q.rows;
    let d = q.cols;
    let scale = 1.0 / (d as f32).sqrt();
    let tile = plan.tile;
    let q_blocks = tile.q_blocks(n);
    let gp = &plan.groups[g];
    let qb_start = g * plan.step;
    let qb_end = ((g + 1) * plan.step).min(q_blocks);
    let Scratch { s, tiles, q_tile, k_span, v_span, state } = scratch;

    // Assemble the group's discrete K/V columns once, chunked to tile
    // width so the inner matmuls stay dense. Contiguous runs are read at
    // span (memcpy) width; stretches of singletons batch into one gather
    // (Eq. 4's two load primitives, picked per run).
    if tiles.len() < chunks.len() {
        tiles.resize_with(chunks.len(), || (Mat::zeros(0, 0), Mat::zeros(0, 0)));
    }
    for (chunk, (k_t, v_t)) in chunks.iter().zip(tiles.iter_mut()) {
        let coords = chunk.coords;
        resize_mat(k_t, coords.len(), d);
        resize_mat(v_t, coords.len(), d);
        let mut idx = 0; // next destination row == index into `coords`
        let mut pend = 0; // start of the pending singleton stretch
        for &(run_s, run_e) in &chunk.runs {
            let len = (run_e - run_s) as usize;
            if len >= 2 {
                if pend < idx {
                    kv.gather_into(&coords[pend..idx], pend, k_t, v_t);
                }
                kv.span_into(run_s as usize, run_e as usize, idx, k_t, v_t);
                idx += len;
                pend = idx;
            } else {
                idx += 1;
            }
        }
        if pend < idx {
            kv.gather_into(&coords[pend..idx], pend, k_t, v_t);
        }
    }

    let mut group_out = Vec::with_capacity((qb_end - qb_start) * tile.b_q * d);
    let mut cost = CostTally::default();
    for qb in qb_start..qb_end {
        let row0 = qb * tile.b_q;
        let rows = (n - row0).min(tile.b_q);
        let limit = row0 + rows;
        resize_mat(q_tile, rows, d);
        q_tile.data.copy_from_slice(q.rows_slice(row0, rows));
        state.reset(rows, d);

        // Anchor spans: contiguous tiles, clipped to the block's causal
        // limit, diagonal tiles causally masked.
        for &(span_s, span_e) in &gp.spans {
            let end = (span_e as usize).min(limit);
            let mut col0 = span_s as usize;
            while col0 < end {
                let cols = (end - col0).min(tile.b_kv);
                resize_mat(k_span, cols, d);
                resize_mat(v_span, cols, d);
                kv.span_into(col0, col0 + cols, 0, k_span, v_span);
                resize_mat(s, rows, cols);
                matmul_nt_scaled(q_tile, k_span, scale, s);
                if col0 + cols > row0 {
                    mask_tile_causal(s, row0, col0);
                }
                state.fold_tile(s, v_span);
                cost.add(CostTally::attn_tile(rows, cols, d));
                col0 += cols;
            }
        }

        // Stripe chunks: the pre-assembled tiles. Chunks entirely before
        // the block's first row need no masking (the common case — anchor
        // stripes precede the group window); otherwise binary-search each
        // row's first out-of-diagonal coordinate (coords are sorted) and
        // mask the suffix.
        for (chunk, (k_g, v_g)) in chunks.iter().zip(tiles.iter()) {
            let coords = chunk.coords;
            resize_mat(s, rows, coords.len());
            matmul_nt_scaled(q_tile, k_g, scale, s);
            if coords.last().is_some_and(|&c| c as usize >= row0) {
                for r in 0..rows {
                    let abs_row = row0 + r;
                    let first_masked = coords.partition_point(|&c| c as usize <= abs_row);
                    for x in &mut s.row_mut(r)[first_masked..] {
                        *x = f32::NEG_INFINITY;
                    }
                }
            }
            state.fold_tile(s, v_g);
            cost.add(CostTally::attn_tile(rows, coords.len(), d));
        }

        let base = group_out.len();
        group_out.resize(base + rows * d, 0.0f32);
        state.write_output(&mut group_out[base..], d);
    }
    (group_out, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::plan::{execute_plan, GroupPlan};
    use crate::attention::{HeadInput, TileConfig};
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn mixed_plan(n: usize, d: usize) -> SparsePlan {
        let tile = TileConfig::new(16, 16);
        let q_blocks = tile.q_blocks(n);
        let step = 2;
        let groups: Vec<GroupPlan> = (0..q_blocks.div_ceil(step))
            .map(|g| {
                let win = (g * step * 16) as u32;
                let end = ((g + 1) * step * 16).min(n) as u32;
                if win == 0 {
                    GroupPlan { spans: vec![(0, end)], stripes: vec![] }
                } else {
                    let stripes: Vec<u32> = (16..win).step_by(3).collect();
                    GroupPlan { spans: vec![(0, 16), (win, end)], stripes }
                }
            })
            .collect();
        SparsePlan::new("test", n, d, tile, step, groups, CostTally::default())
    }

    /// The serial knob changes scheduling only: outputs and costs are
    /// identical to the parallel walk (and to the `execute_plan` wrapper).
    #[test]
    fn serial_knob_is_bitwise_identical() {
        let h = rand_head(91, 160, 8);
        let plan = mixed_plan(160, 8);
        let par = CpuTileExecutor::default().execute(&h, &plan);
        let ser = CpuTileExecutor { serial: true, ..Default::default() }.execute(&h, &plan);
        let wrapper = execute_plan(&h, &plan);
        assert_eq!(par.out.data, ser.out.data);
        assert_eq!(par.cost, ser.cost);
        assert_eq!(par.out.data, wrapper.out.data);
        assert_eq!(par.cost, wrapper.cost);
    }

    /// Execution cost equals the plan's prediction — cost accounting lives
    /// in the plan, the backend merely confirms it.
    #[test]
    fn cost_matches_plan_prediction() {
        let h = rand_head(92, 200, 8);
        let plan = mixed_plan(200, 8);
        let out = CpuTileExecutor::default().execute(&h, &plan);
        assert_eq!(out.cost, plan.predicted_cost);
    }

    /// Run-serving lowering is bitwise-identical to plain per-coordinate
    /// gathers: runs only change the read width, never the folded values.
    /// Covered for strided (all-singleton), contiguous (all-run), and
    /// mixed stripe patterns.
    #[test]
    fn run_lowering_is_bitwise_equal_to_discrete() {
        let runs_exec = CpuTileExecutor { lowering: LoweringMode::Runs, ..Default::default() };
        let disc_exec =
            CpuTileExecutor { lowering: LoweringMode::Discrete, ..Default::default() };
        let n = 160;
        let h = rand_head(93, n, 8);
        let tile = TileConfig::new(16, 16);
        let step = 2;
        let patterns: [&dyn Fn(u32) -> Vec<u32>; 3] = [
            &|win| (16..win).step_by(3).collect(),         // singletons
            &|win| (16..win.min(48)).collect(),            // one long run
            &|win| (16..win).filter(|c| c % 7 != 0).collect(), // mixed
        ];
        for mk in patterns {
            let q_blocks = tile.q_blocks(n);
            let groups: Vec<GroupPlan> = (0..q_blocks.div_ceil(step))
                .map(|g| {
                    let win = (g * step * 16) as u32;
                    let end = ((g + 1) * step * 16).min(n) as u32;
                    if win == 0 {
                        GroupPlan { spans: vec![(0, end)], stripes: vec![] }
                    } else {
                        GroupPlan { spans: vec![(0, 16), (win, end)], stripes: mk(win) }
                    }
                })
                .collect();
            let plan =
                SparsePlan::new("test", n, 8, tile, step, groups, CostTally::default());
            let a = runs_exec.execute(&h, &plan);
            let b = disc_exec.execute(&h, &plan);
            assert_eq!(a.out.data, b.out.data);
            assert_eq!(a.cost, b.cost);
        }
    }
}

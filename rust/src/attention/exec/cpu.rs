//! [`CpuTileExecutor`] — the blocked online-softmax tile walk (Alg. 3),
//! moved here from `attention::plan` when execution was lifted behind the
//! [`Executor`] trait. This is the reference backend: every other backend
//! must be bitwise-equal to it.
//!
//! Per group the walk gathers the group's discrete K/V columns **once**
//! (chunked to the kv tile width — §3.4's reuse across the group's `step`
//! query blocks), then runs one online softmax per query block: anchor
//! spans as dense tiles clipped to the block's causal limit, then the
//! gathered stripe chunks with per-row masking at or past the diagonal.

use crate::attention::exec::{Executor, KvSource, PlanLowering};
use crate::attention::full::{mask_tile_causal, BlockState};
use crate::attention::plan::SparsePlan;
use crate::attention::{AttnOutput, CostTally};
use crate::tensor::{matmul_nt_scaled, Mat};
use crate::util::threadpool::parallel_map;

/// The multithreaded CPU tile walk.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CpuTileExecutor {
    /// Run groups on the calling thread only (the former
    /// `execute_plan_serial`): set by paths whose parallelism already
    /// lives at a coarser granularity, e.g. head-parallel batching.
    pub serial: bool,
}

impl Executor for CpuTileExecutor {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn execute_source(
        &self,
        q: &Mat,
        kv: &dyn KvSource,
        plan: &SparsePlan,
        parallel: bool,
    ) -> AttnOutput {
        let lowering = PlanLowering::lower(plan);
        execute_lowered(q, kv, plan, &lowering, parallel && !self.serial)
    }
}

/// Execute a lowered plan: the shared host tile kernel. Both backends end
/// here (the PJRT backend after lowering/validation and, under the offline
/// stub, in place of the artifact call), which is what makes cross-backend
/// bitwise parity hold by construction.
pub(crate) fn execute_lowered(
    q: &Mat,
    kv: &dyn KvSource,
    plan: &SparsePlan,
    lowering: &PlanLowering<'_>,
    parallel: bool,
) -> AttnOutput {
    let n = q.rows;
    let d = q.cols;
    assert_eq!(plan.n, n, "plan built for a different sequence length");
    assert_eq!(kv.d(), d, "q/kv head dim mismatch");
    let tile = plan.tile;
    let groups = plan.groups.len();

    let run_group = |g: usize| fold_group(q, kv, plan, &lowering.stripe_chunks[g], g);
    let results: Vec<(Vec<f32>, CostTally)> = if parallel {
        parallel_map(groups, run_group)
    } else {
        (0..groups).map(run_group).collect()
    };

    let mut out = Mat::zeros(n, d);
    let mut cost = CostTally::default();
    for (g, (rows_data, c)) in results.into_iter().enumerate() {
        let row0 = g * plan.step * tile.b_q;
        out.data[row0 * d..row0 * d + rows_data.len()].copy_from_slice(&rows_data);
        cost.add(c);
    }
    AttnOutput { out, coverage: plan.coverage(), cost }
}

/// Compute one group's output rows: fold the group's anchor spans as dense
/// tiles, then the gathered stripe chunks — one online softmax per query
/// block, K'/V' gathered **once per group** and reused across its `step`
/// blocks (§3.4's reuse; this is the fine-grained gather substrate every
/// method runs on).
fn fold_group(
    q: &Mat,
    kv: &dyn KvSource,
    plan: &SparsePlan,
    chunks: &[&[u32]],
    g: usize,
) -> (Vec<f32>, CostTally) {
    let n = q.rows;
    let d = q.cols;
    let scale = 1.0 / (d as f32).sqrt();
    let tile = plan.tile;
    let q_blocks = tile.q_blocks(n);
    let gp = &plan.groups[g];
    let qb_start = g * plan.step;
    let qb_end = ((g + 1) * plan.step).min(q_blocks);

    // Gather the group's discrete K/V columns once, chunked to tile width
    // so the inner matmuls stay dense (Eq. 4 `load_discrete`).
    let gathered: Vec<(&[u32], Mat, Mat)> = chunks
        .iter()
        .map(|&chunk| {
            let (k_g, v_g) = kv.gather(chunk);
            (chunk, k_g, v_g)
        })
        .collect();

    let mut group_out = Vec::with_capacity((qb_end - qb_start) * tile.b_q * d);
    let mut cost = CostTally::default();
    let mut s = Mat::zeros(tile.b_q, tile.b_kv);
    for qb in qb_start..qb_end {
        let row0 = qb * tile.b_q;
        let rows = (n - row0).min(tile.b_q);
        let limit = row0 + rows;
        let q_i = q.rows_mat(row0, rows);
        let mut st = BlockState::new(rows, d);

        // Anchor spans: contiguous tiles, clipped to the block's causal
        // limit, diagonal tiles causally masked.
        for &(span_s, span_e) in &gp.spans {
            let end = (span_e as usize).min(limit);
            let mut col0 = span_s as usize;
            while col0 < end {
                let cols = (end - col0).min(tile.b_kv);
                let (k_j, v_j) = kv.span(col0, col0 + cols);
                if s.cols != cols || s.rows != rows {
                    s = Mat::zeros(rows, cols);
                }
                matmul_nt_scaled(&q_i, &k_j, scale, &mut s);
                if col0 + cols > row0 {
                    mask_tile_causal(&mut s, row0, col0);
                }
                st.fold_tile(&mut s, &v_j);
                cost.add(CostTally::attn_tile(rows, cols, d));
                col0 += cols;
            }
        }

        // Stripe chunks: discrete gathers. Chunks entirely before the
        // block's first row need no masking (the common case — anchor
        // stripes precede the group window); otherwise mask per row
        // against the absolute column ids.
        for (chunk, k_g, v_g) in &gathered {
            if s.cols != k_g.rows || s.rows != rows {
                s = Mat::zeros(rows, k_g.rows);
            }
            matmul_nt_scaled(&q_i, k_g, scale, &mut s);
            if chunk.last().is_some_and(|&c| c as usize >= row0) {
                for r in 0..rows {
                    let abs_row = row0 + r;
                    let srow = s.row_mut(r);
                    for (ci, &col) in chunk.iter().enumerate() {
                        if col as usize > abs_row {
                            srow[ci] = f32::NEG_INFINITY;
                        }
                    }
                }
            }
            st.fold_tile(&mut s, v_g);
            cost.add(CostTally::attn_tile(rows, k_g.rows, d));
        }

        let base = group_out.len();
        group_out.resize(base + rows * d, 0.0f32);
        st.write_output(&mut group_out[base..], d);
    }
    (group_out, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::plan::{execute_plan, GroupPlan};
    use crate::attention::{HeadInput, TileConfig};
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn mixed_plan(n: usize, d: usize) -> SparsePlan {
        let tile = TileConfig::new(16, 16);
        let q_blocks = tile.q_blocks(n);
        let step = 2;
        let groups: Vec<GroupPlan> = (0..q_blocks.div_ceil(step))
            .map(|g| {
                let win = (g * step * 16) as u32;
                let end = ((g + 1) * step * 16).min(n) as u32;
                if win == 0 {
                    GroupPlan { spans: vec![(0, end)], stripes: vec![] }
                } else {
                    let stripes: Vec<u32> = (16..win).step_by(3).collect();
                    GroupPlan { spans: vec![(0, 16), (win, end)], stripes }
                }
            })
            .collect();
        SparsePlan::new("test", n, d, tile, step, groups, CostTally::default())
    }

    /// The serial knob changes scheduling only: outputs and costs are
    /// identical to the parallel walk (and to the `execute_plan` wrapper).
    #[test]
    fn serial_knob_is_bitwise_identical() {
        let h = rand_head(91, 160, 8);
        let plan = mixed_plan(160, 8);
        let par = CpuTileExecutor::default().execute(&h, &plan);
        let ser = CpuTileExecutor { serial: true }.execute(&h, &plan);
        let wrapper = execute_plan(&h, &plan);
        assert_eq!(par.out.data, ser.out.data);
        assert_eq!(par.cost, ser.cost);
        assert_eq!(par.out.data, wrapper.out.data);
        assert_eq!(par.cost, wrapper.cost);
    }

    /// Execution cost equals the plan's prediction — cost accounting lives
    /// in the plan, the backend merely confirms it.
    #[test]
    fn cost_matches_plan_prediction() {
        let h = rand_head(92, 200, 8);
        let plan = mixed_plan(200, 8);
        let out = CpuTileExecutor::default().execute(&h, &plan);
        assert_eq!(out.cost, plan.predicted_cost);
    }
}

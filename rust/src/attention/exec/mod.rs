//! Executor backends: the compute side of the Planner → [`SparsePlan`] →
//! Executor pipeline, lifted behind a trait (DESIGN.md §10).
//!
//! A plan is pure coordinates, so *what executes it* is a swappable
//! backend decision — exactly the seam the paper's Fine-grained Sparse
//! Computation (§3.3, Alg. 3) is shaped around: load the plan's discrete
//! KV positions simultaneously on whatever hardware is available.
//! Two backends implement [`Executor`]:
//!
//! * [`CpuTileExecutor`] — the multithreaded online-softmax tile walk
//!   (previously `plan::execute_plan`), the reference semantics.
//! * [`PjrtGatherExecutor`] — lowers a plan to gather indices plus an
//!   `attn_sparse` artifact call through the vendored `xla` stub, with
//!   spec validation against the runtime manifest; under the offline stub
//!   the lowered program is interpreted on host with arithmetic
//!   bitwise-identical to the CPU walk.
//!
//! Executors read K/V through [`KvSource`] — the paper's Eq. 4 load
//! primitives (contiguous `span`, discrete `gather`) over whatever memory
//! holds the keys. [`FlatKv`] serves per-head tensors; the coordinator's
//! `PagedExecutor` (`coordinator::kv_cache`) serves paged KV memory, so
//! paged serving executes plans without flattening the cache first.
//!
//! Cost accounting deliberately stays in the plan
//! ([`SparsePlan::predicted_cost`]), not the backend: every backend must
//! fold exactly the plan's tiles, so the tally is a property of the
//! coordinates, and the scheduler can price work without asking a backend.

pub mod cpu;
pub mod pjrt;

use std::sync::Arc;

use crate::attention::plan::{BatchInput, SparsePlan};
use crate::attention::{AttnOutput, HeadInput};
use crate::tensor::Mat;
use crate::util::threadpool::parallel_map;

pub use cpu::CpuTileExecutor;
pub use pjrt::{validate_sparse_spec, PjrtGatherExecutor, SPARSE_ARTIFACT};

/// K/V read interface for executors: the paper's Eq. 4 load primitives
/// over whatever memory holds the keys. Implementations must return the
/// exact stored rows (pure copies) so every backend sees bitwise-identical
/// operands regardless of the memory layout behind the source.
pub trait KvSource: Sync {
    /// Head dim of the stored rows.
    fn d(&self) -> usize;
    /// Contiguous rows `[start, end)` as `(K, V)` — an anchor-span read.
    fn span(&self, start: usize, end: usize) -> (Mat, Mat);
    /// Discrete rows at `coords` as `(K, V)` — a stripe gather
    /// (`load_discrete`).
    fn gather(&self, coords: &[u32]) -> (Mat, Mat);

    /// Copy contiguous rows `[start, end)` into `k_dst`/`v_dst` starting at
    /// destination row `row0` — the allocation-free form of [`Self::span`]
    /// used by the run-serving tile walk. The default materializes `span`
    /// and copies; sources with contiguous backing override it with direct
    /// `memcpy`-width slice copies. Must write exactly the same bytes
    /// `span` would return (the bitwise-parity contract).
    fn span_into(&self, start: usize, end: usize, row0: usize, k_dst: &mut Mat, v_dst: &mut Mat) {
        let d = self.d();
        let (k, v) = self.span(start, end);
        let rows = end - start;
        k_dst.data[row0 * d..(row0 + rows) * d].copy_from_slice(&k.data);
        v_dst.data[row0 * d..(row0 + rows) * d].copy_from_slice(&v.data);
    }

    /// Copy discrete rows at `coords` into `k_dst`/`v_dst` starting at
    /// destination row `row0` — the allocation-free form of
    /// [`Self::gather`]. Same bitwise contract as [`Self::span_into`].
    fn gather_into(&self, coords: &[u32], row0: usize, k_dst: &mut Mat, v_dst: &mut Mat) {
        let d = self.d();
        let (k, v) = self.gather(coords);
        let rows = coords.len();
        k_dst.data[row0 * d..(row0 + rows) * d].copy_from_slice(&k.data);
        v_dst.data[row0 * d..(row0 + rows) * d].copy_from_slice(&v.data);
    }
}

/// [`KvSource`] over flat per-head `[N, d]` tensors.
pub struct FlatKv<'a> {
    pub k: &'a Mat,
    pub v: &'a Mat,
}

impl<'a> FlatKv<'a> {
    pub fn new(k: &'a Mat, v: &'a Mat) -> Self {
        assert_eq!(k.rows, v.rows, "k/v length");
        assert_eq!(k.cols, v.cols, "k/v head dim");
        Self { k, v }
    }
}

impl KvSource for FlatKv<'_> {
    fn d(&self) -> usize {
        self.k.cols
    }

    fn span(&self, start: usize, end: usize) -> (Mat, Mat) {
        (self.k.rows_mat(start, end - start), self.v.rows_mat(start, end - start))
    }

    fn gather(&self, coords: &[u32]) -> (Mat, Mat) {
        (self.k.gather_rows(coords), self.v.gather_rows(coords))
    }

    fn span_into(&self, start: usize, end: usize, row0: usize, k_dst: &mut Mat, v_dst: &mut Mat) {
        let d = self.k.cols;
        k_dst.data[row0 * d..(row0 + (end - start)) * d]
            .copy_from_slice(&self.k.data[start * d..end * d]);
        v_dst.data[row0 * d..(row0 + (end - start)) * d]
            .copy_from_slice(&self.v.data[start * d..end * d]);
    }

    fn gather_into(&self, coords: &[u32], row0: usize, k_dst: &mut Mat, v_dst: &mut Mat) {
        let d = self.k.cols;
        for (i, &c) in coords.iter().enumerate() {
            let src = c as usize * d;
            let dst = (row0 + i) * d;
            k_dst.data[dst..dst + d].copy_from_slice(&self.k.data[src..src + d]);
            v_dst.data[dst..dst + d].copy_from_slice(&self.v.data[src..src + d]);
        }
    }
}

/// A backend that executes [`SparsePlan`]s: exact softmax attention
/// restricted to the plan's coordinates. Every implementation must be
/// bitwise-equal to [`CpuTileExecutor`] (the parity property in
/// `tests/prop_plan_parity.rs`) and must report the execution-only cost
/// (`plan.predicted_cost`); identification cost is folded in by callers.
pub trait Executor: Sync + Send {
    /// Backend identifier (config value, report column).
    fn name(&self) -> &'static str;

    /// Execute one head's plan with K/V read through `kv`. `parallel`
    /// lets the backend use spare threadpool workers; the batched entry
    /// passes `false` because parallelism already lives at head
    /// granularity there.
    fn execute_source(
        &self,
        q: &Mat,
        kv: &dyn KvSource,
        plan: &SparsePlan,
        parallel: bool,
    ) -> AttnOutput;

    /// Execute one head's plan against its own flat K/V.
    fn execute(&self, input: &HeadInput, plan: &SparsePlan) -> AttnOutput {
        self.execute_source(&input.q, &FlatKv::new(&input.k, &input.v), plan, true)
    }

    /// Batched entry: execute every head of `batch` against its resolved
    /// plan. The default parallelizes at head granularity and runs each
    /// head serially so the pool is not oversubscribed (single-head
    /// batches keep intra-head parallelism).
    fn execute_batch(&self, batch: &BatchInput, plans: &[Arc<SparsePlan>]) -> Vec<AttnOutput> {
        assert_eq!(plans.len(), batch.h(), "one plan per head");
        let parallel_within = batch.h() == 1;
        parallel_map(batch.h(), |h| {
            let head = &batch.heads[h];
            self.execute_source(
                &head.q,
                &FlatKv::new(&head.k, &head.v),
                &plans[h],
                parallel_within,
            )
        })
    }
}

/// Configured executor backend (`"executor": "cpu" | "pjrt"` in config,
/// `--executor` on the CLI).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecutorKind {
    #[default]
    Cpu,
    Pjrt,
}

impl ExecutorKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "cpu" => Ok(ExecutorKind::Cpu),
            "pjrt" => Ok(ExecutorKind::Pjrt),
            other => Err(anyhow::anyhow!("unknown executor '{other}' (expected cpu|pjrt)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecutorKind::Cpu => "cpu",
            ExecutorKind::Pjrt => "pjrt",
        }
    }

    /// Build the backend this kind names.
    pub fn build(self) -> Box<dyn Executor> {
        match self {
            ExecutorKind::Cpu => Box::new(CpuTileExecutor::default()),
            ExecutorKind::Pjrt => Box::new(PjrtGatherExecutor::new()),
        }
    }
}

/// How [`PlanLowering`] serves a chunk's coordinates to the KV source.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LoweringMode {
    /// Segment each chunk into maximal contiguous runs so consecutive
    /// coordinates are read as one `span` (the default; §3.4's insight
    /// that stripes are near-arithmetic, so most "gathers" are spans).
    #[default]
    Runs,
    /// Serve every coordinate as its own single-row gather — the plain
    /// per-coordinate lowering, kept as the parity reference.
    Discrete,
}

/// One lowered stripe chunk: the chunk's coordinates (≤ `tile.b_kv`, plan
/// order) plus the `[start, end)` key runs that cover them in order. Runs
/// are maximal under [`LoweringMode::Runs`] and all singletons under
/// [`LoweringMode::Discrete`]; either way they enumerate exactly `coords`,
/// so the folded tile is identical — only the read width changes.
#[derive(Clone, Debug, PartialEq)]
pub struct LoweredChunk<'p> {
    pub coords: &'p [u32],
    pub runs: Vec<(u32, u32)>,
}

impl LoweredChunk<'_> {
    /// Coordinates served by a multi-row run (a span read, not a gather).
    pub fn spanned_coords(&self) -> usize {
        self.runs.iter().map(|&(a, b)| (b - a) as usize).filter(|&l| l >= 2).sum()
    }
}

/// Segment sorted coordinates into maximal contiguous `[start, end)` runs.
fn segment_runs(coords: &[u32]) -> Vec<(u32, u32)> {
    let mut runs = Vec::new();
    let mut i = 0;
    while i < coords.len() {
        let mut j = i + 1;
        while j < coords.len() && coords[j] == coords[j - 1] + 1 {
            j += 1;
        }
        runs.push((coords[i], coords[j - 1] + 1));
        i = j;
    }
    runs
}

/// A [`SparsePlan`] lowered to its gather program: per group, the stripe
/// coordinates chunked to the kv tile width — the exact tile schedule both
/// backends fold after the anchor spans, and the indices a gather-based
/// kernel (`attn_sparse`) loads simultaneously. Within each chunk the
/// coordinates are further segmented into contiguous runs (see
/// [`LoweringMode`]); chunk boundaries — which pin the fold order and the
/// plan's predicted cost — never move. Chunks borrow the plan's stripe
/// storage (lowering is slice bookkeeping plus run boundaries, not a row
/// copy — plans are `Arc`-shared across a batch's heads, so this runs per
/// execute). Spans need no lowering; they are read straight from the plan.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanLowering<'p> {
    /// `stripe_chunks[g]` = group `g`'s gather chunks, each ≤ `tile.b_kv`
    /// coordinates, in plan (sorted) order.
    pub stripe_chunks: Vec<Vec<LoweredChunk<'p>>>,
    /// Total gathered coordinates across groups.
    pub total_coords: usize,
}

impl<'p> PlanLowering<'p> {
    pub fn lower(plan: &'p SparsePlan) -> Self {
        Self::lower_with(plan, LoweringMode::Runs)
    }

    pub fn lower_with(plan: &'p SparsePlan, mode: LoweringMode) -> Self {
        let b_kv = plan.tile.b_kv;
        let mut total_coords = 0;
        let stripe_chunks = plan
            .groups
            .iter()
            .map(|g| {
                total_coords += g.stripes.len();
                g.stripes
                    .chunks(b_kv)
                    .map(|coords| {
                        let runs = match mode {
                            LoweringMode::Runs => segment_runs(coords),
                            LoweringMode::Discrete => {
                                coords.iter().map(|&c| (c, c + 1)).collect()
                            }
                        };
                        LoweredChunk { coords, runs }
                    })
                    .collect()
            })
            .collect();
        Self { stripe_chunks, total_coords }
    }

    /// Group `g`'s flat gather indices as the i32 vector an `attn_sparse`
    /// artifact call takes.
    pub fn gather_indices(&self, g: usize) -> Vec<i32> {
        self.stripe_chunks[g]
            .iter()
            .flat_map(|c| c.coords.iter())
            .map(|&c| c as i32)
            .collect()
    }

    /// Coordinates served as span reads vs. total, across all groups —
    /// the quantity `bench micro` reports as the span-lowering win.
    pub fn span_stats(&self) -> (usize, usize) {
        let spanned = self
            .stripe_chunks
            .iter()
            .flat_map(|g| g.iter())
            .map(|c| c.spanned_coords())
            .sum();
        (spanned, self.total_coords)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::plan::GroupPlan;
    use crate::attention::{CostTally, TileConfig};

    fn plan_with_stripes(stripes: Vec<u32>) -> SparsePlan {
        let tile = TileConfig::new(16, 4);
        let n = 32;
        let groups = vec![
            GroupPlan { spans: vec![(0, 16)], stripes: vec![] },
            GroupPlan { spans: vec![(16, 32)], stripes },
        ];
        SparsePlan::new("test", n, 8, tile, 1, groups, CostTally::default())
    }

    #[test]
    fn lowering_chunks_to_kv_tile_width() {
        let plan = plan_with_stripes(vec![0, 1, 2, 3, 4, 5]);
        let low = PlanLowering::lower(&plan);
        assert_eq!(low.total_coords, 6);
        assert!(low.stripe_chunks[0].is_empty());
        let chunks: Vec<&[u32]> = low.stripe_chunks[1].iter().map(|c| c.coords).collect();
        assert_eq!(chunks, vec![&[0u32, 1, 2, 3][..], &[4u32, 5][..]]);
        // A fully contiguous chunk is one maximal run.
        assert_eq!(low.stripe_chunks[1][0].runs, vec![(0, 4)]);
        assert_eq!(low.stripe_chunks[1][1].runs, vec![(4, 6)]);
        assert_eq!(low.gather_indices(1), vec![0, 1, 2, 3, 4, 5]);
        assert!(low.gather_indices(0).is_empty());
        assert_eq!(low.span_stats(), (6, 6));
    }

    #[test]
    fn run_segmentation_splits_at_gaps_and_respects_chunks() {
        // Mixed: run of 3, singleton, run of 2 — and runs never cross the
        // b_kv=4 chunk boundary even when coordinates are contiguous
        // across it.
        let plan = plan_with_stripes(vec![0, 1, 2, 7, 9, 10]);
        let low = PlanLowering::lower(&plan);
        assert_eq!(low.stripe_chunks[1][0].runs, vec![(0, 3), (7, 8)]);
        assert_eq!(low.stripe_chunks[1][1].runs, vec![(9, 11)]);
        assert_eq!(low.stripe_chunks[1][0].spanned_coords(), 3);
        assert_eq!(low.span_stats(), (5, 6));

        let contiguous = plan_with_stripes(vec![0, 1, 2, 3, 4, 5, 6, 7]);
        let low = PlanLowering::lower(&contiguous);
        // Chunked first, then segmented: two runs, one per chunk.
        assert_eq!(low.stripe_chunks[1][0].runs, vec![(0, 4)]);
        assert_eq!(low.stripe_chunks[1][1].runs, vec![(4, 8)]);
    }

    #[test]
    fn discrete_lowering_is_all_singletons_over_the_same_coords() {
        let plan = plan_with_stripes(vec![0, 1, 2, 7, 9, 10]);
        let runs = PlanLowering::lower(&plan);
        let discrete = PlanLowering::lower_with(&plan, LoweringMode::Discrete);
        assert_eq!(discrete.total_coords, runs.total_coords);
        for (gr, gd) in runs.stripe_chunks.iter().zip(&discrete.stripe_chunks) {
            assert_eq!(gr.len(), gd.len());
            for (cr, cd) in gr.iter().zip(gd) {
                assert_eq!(cr.coords, cd.coords);
                let singles: Vec<(u32, u32)> =
                    cd.coords.iter().map(|&c| (c, c + 1)).collect();
                assert_eq!(cd.runs, singles);
                // Both modes enumerate exactly the chunk's coordinates.
                let enumerated: Vec<u32> =
                    cr.runs.iter().flat_map(|&(a, b)| a..b).collect();
                assert_eq!(enumerated, cr.coords);
            }
        }
        assert_eq!(discrete.span_stats().0, 0);
    }

    #[test]
    fn span_into_and_gather_into_match_allocating_reads() {
        let k = Mat::from_fn(10, 4, |r, c| (r * 10 + c) as f32);
        let v = Mat::from_fn(10, 4, |r, c| (r * 10 + c) as f32 + 0.5);
        let kv = FlatKv::new(&k, &v);
        let mut kd = Mat::zeros(6, 4);
        let mut vd = Mat::zeros(6, 4);
        kv.span_into(3, 6, 1, &mut kd, &mut vd);
        let (ks, vs) = kv.span(3, 6);
        assert_eq!(&kd.data[4..16], &ks.data[..]);
        assert_eq!(&vd.data[4..16], &vs.data[..]);
        kv.gather_into(&[0, 7, 9], 3, &mut kd, &mut vd);
        let (kg, vg) = kv.gather(&[0, 7, 9]);
        assert_eq!(&kd.data[12..24], &kg.data[..]);
        assert_eq!(&vd.data[12..24], &vg.data[..]);
    }

    #[test]
    fn executor_kind_parses_and_names() {
        assert_eq!(ExecutorKind::parse("cpu").unwrap(), ExecutorKind::Cpu);
        assert_eq!(ExecutorKind::parse("pjrt").unwrap(), ExecutorKind::Pjrt);
        assert!(ExecutorKind::parse("tpu").is_err());
        assert_eq!(ExecutorKind::Cpu.name(), "cpu");
        assert_eq!(ExecutorKind::Pjrt.name(), "pjrt");
        assert_eq!(ExecutorKind::default(), ExecutorKind::Cpu);
        assert_eq!(ExecutorKind::Cpu.build().name(), "cpu");
        assert_eq!(ExecutorKind::Pjrt.build().name(), "pjrt");
    }

    #[test]
    fn flat_kv_reads_match_tensor_primitives() {
        let k = Mat::from_fn(8, 4, |r, c| (r * 10 + c) as f32);
        let v = Mat::from_fn(8, 4, |r, c| (r * 10 + c) as f32 + 0.5);
        let kv = FlatKv::new(&k, &v);
        assert_eq!(kv.d(), 4);
        let (ks, vs) = kv.span(2, 5);
        assert_eq!(ks, k.rows_mat(2, 3));
        assert_eq!(vs, v.rows_mat(2, 3));
        let (kg, vg) = kv.gather(&[1, 6]);
        assert_eq!(kg, k.gather_rows(&[1, 6]));
        assert_eq!(vg, v.gather_rows(&[1, 6]));
    }
}

//! [`PjrtGatherExecutor`] — the gather-based PJRT backend: a plan is
//! lowered to per-group gather indices ([`PlanLowering`]) plus an
//! `attn_sparse` artifact call, the shape the paper's Alg. 3 kernel takes
//! (load the discrete KV positions *simultaneously*, then one dense fold
//! over the gathered rows).
//!
//! The artifact contract is validated against the runtime manifest
//! ([`validate_sparse_spec`]): `attn_sparse(q f32[rows,d], k' f32[m,d],
//! v' f32[m,d], idx i32[m]) -> f32[rows,d]`. Dispatch goes through the
//! vendored `xla` crate; the offline stub's client probe reports the
//! backend unavailable ([`PjrtGatherExecutor::backend_error`]), in which
//! case the lowered program is interpreted on host by the shared tile
//! kernel (`exec::cpu::execute_lowered`) — bitwise-equal to
//! [`CpuTileExecutor`](super::CpuTileExecutor) by construction, so the
//! parity suite covers this backend end to end. Swapping a real `xla`
//! checkout into `rust/vendor/xla` (DESIGN.md §8) flips the probe and
//! makes this the artifact dispatch point without touching call sites.

use std::sync::OnceLock;

use anyhow::{anyhow, ensure, Result};

use crate::attention::exec::{cpu, Executor, KvSource, PlanLowering};
use crate::attention::plan::SparsePlan;
use crate::attention::AttnOutput;
use crate::runtime::Manifest;
use crate::tensor::Mat;

/// Manifest name of the gather-kernel artifact this backend dispatches.
pub const SPARSE_ARTIFACT: &str = "attn_sparse";

/// Gather-based PJRT executor backend.
#[derive(Debug, Default)]
pub struct PjrtGatherExecutor {
    /// Manifest to validate the [`SPARSE_ARTIFACT`] spec against before
    /// dispatch (`None` skips validation — e.g. synthetic benches with no
    /// artifact directory).
    manifest: Option<Manifest>,
    /// Lazily probed PJRT availability: `Some(msg)` records why the
    /// backend is unavailable (always, under the vendored stub).
    backend_err: OnceLock<Option<String>>,
}

impl PjrtGatherExecutor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Validate every plan against `manifest`'s [`SPARSE_ARTIFACT`] spec
    /// before executing it. The infallible [`Executor`] entries treat a
    /// mismatch as a caller bug and panic with the validation message;
    /// callers that want an `Err` (or a one-time check at setup) run
    /// [`validate_sparse_spec`] themselves before executing.
    pub fn with_manifest(manifest: Manifest) -> Self {
        Self { manifest: Some(manifest), backend_err: OnceLock::new() }
    }

    /// Why PJRT dispatch is unavailable, if it is (the vendored stub
    /// always reports its "backend unavailable" message here; a real
    /// `xla` crate returns `None` and dispatch goes to the device).
    pub fn backend_error(&self) -> Option<&str> {
        self.probe().as_deref()
    }

    fn probe(&self) -> &Option<String> {
        self.backend_err.get_or_init(|| match xla::PjRtClient::cpu() {
            Ok(_) => None,
            Err(e) => Some(e.to_string()),
        })
    }
}

impl Executor for PjrtGatherExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute_source(
        &self,
        q: &Mat,
        kv: &dyn KvSource,
        plan: &SparsePlan,
        parallel: bool,
    ) -> AttnOutput {
        let lowering = PlanLowering::lower(plan);
        if let Some(m) = &self.manifest {
            validate_sparse_spec(m, plan, q.cols)
                .expect("attn_sparse artifact spec incompatible with plan");
        }
        // Dispatch seam: with a live PJRT client each group's gathered
        // chunks go to the compiled SPARSE_ARTIFACT executable. The
        // vendored stub's probe reports unavailable, so the lowered
        // program is interpreted by the shared host tile kernel instead —
        // identical tile schedule, identical arithmetic.
        let _ = self.probe();
        cpu::execute_lowered(q, kv, plan, &lowering, parallel)
    }
}

/// Check that `manifest` carries an [`SPARSE_ARTIFACT`] whose signature
/// can execute plans of `plan`'s tile shape at head dim `d`:
/// `(q f32[rows,d], k' f32[m,d], v' f32[m,d], idx i32[m]) -> f32[rows,d]`
/// with `rows ≥ tile.b_q` and `m ≥ tile.b_kv` (one gathered chunk per
/// call never exceeds the kv tile width).
pub fn validate_sparse_spec(manifest: &Manifest, plan: &SparsePlan, d: usize) -> Result<()> {
    let spec = manifest
        .artifact(SPARSE_ARTIFACT)
        .ok_or_else(|| anyhow!("artifact '{SPARSE_ARTIFACT}' not in manifest"))?;
    ensure!(
        spec.inputs.len() == 4,
        "{SPARSE_ARTIFACT}: expected 4 inputs (q, k', v', idx), got {}",
        spec.inputs.len()
    );
    for (name, t) in ["q", "k'", "v'"].iter().zip(&spec.inputs) {
        ensure!(t.dtype == "f32", "{SPARSE_ARTIFACT}: input {name} dtype {} != f32", t.dtype);
        ensure!(t.shape.len() == 2, "{SPARSE_ARTIFACT}: input {name} must be rank 2");
        ensure!(
            t.shape[1] == d,
            "{SPARSE_ARTIFACT}: input {name} head dim {} != {d}",
            t.shape[1]
        );
    }
    let (q_s, k_s, v_s, i_s) =
        (&spec.inputs[0], &spec.inputs[1], &spec.inputs[2], &spec.inputs[3]);
    ensure!(k_s.shape == v_s.shape, "{SPARSE_ARTIFACT}: k'/v' shapes differ");
    ensure!(
        i_s.dtype == "i32" && i_s.shape.len() == 1,
        "{SPARSE_ARTIFACT}: idx must be rank-1 i32"
    );
    ensure!(
        i_s.shape[0] == k_s.shape[0],
        "{SPARSE_ARTIFACT}: idx length {} != gathered rows {}",
        i_s.shape[0],
        k_s.shape[0]
    );
    ensure!(
        plan.tile.b_q <= q_s.shape[0],
        "{SPARSE_ARTIFACT}: q tile {} exceeds artifact rows {}",
        plan.tile.b_q,
        q_s.shape[0]
    );
    ensure!(
        plan.tile.b_kv <= k_s.shape[0],
        "{SPARSE_ARTIFACT}: kv tile {} exceeds artifact gather width {}",
        plan.tile.b_kv,
        k_s.shape[0]
    );
    ensure!(
        spec.outputs.len() == 1
            && spec.outputs[0].dtype == "f32"
            && spec.outputs[0].shape.len() == 2
            && spec.outputs[0].shape[1] == d,
        "{SPARSE_ARTIFACT}: output must be one f32 [rows, {d}] tensor"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exec::CpuTileExecutor;
    use crate::attention::plan::GroupPlan;
    use crate::attention::{CostTally, HeadInput, TileConfig};
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn test_plan(n: usize, d: usize) -> SparsePlan {
        let tile = TileConfig::new(16, 16);
        let groups: Vec<GroupPlan> = (0..tile.q_blocks(n))
            .map(|qb| {
                let limit = (((qb + 1) * 16).min(n)) as u32;
                let win = (qb * 16) as u32;
                if win <= 16 {
                    GroupPlan { spans: vec![(0, limit)], stripes: vec![] }
                } else {
                    let stripes: Vec<u32> = (16..win).step_by(5).collect();
                    GroupPlan { spans: vec![(0, 16), (win, limit)], stripes }
                }
            })
            .collect();
        SparsePlan::new("test", n, d, tile, 1, groups, CostTally::default())
    }

    #[test]
    fn stub_backend_reports_unavailable_and_matches_cpu_bitwise() {
        let h = rand_head(81, 96, 8);
        let plan = test_plan(96, 8);
        let pjrt = PjrtGatherExecutor::new();
        let a = pjrt.execute(&h, &plan);
        assert!(pjrt.backend_error().expect("stub must be unavailable").contains("unavailable"));
        let b = CpuTileExecutor::default().execute(&h, &plan);
        assert_eq!(a.out.data, b.out.data, "pjrt stub not bitwise-equal to cpu");
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.coverage.total_covered(), b.coverage.total_covered());
    }

    const SPEC_JSON: &str = r#"{
        "model": {"vocab": 512, "d_model": 256, "n_layers": 4, "n_heads": 8,
                  "n_kv_heads": 4, "d_head": 32, "d_ffn": 512, "max_seq": 2048,
                  "prefill_chunk": 256},
        "anchor": {"block": 32, "theta": 12.0, "step": 4, "init_blocks": 1},
        "weights": {"file": "weights.bin", "total_f32": 6,
                    "params": [{"name": "a", "shape": [3, 2], "offset": 0, "count": 6}]},
        "artifacts": [{"name": "attn_sparse", "file": "attn_sparse.hlo.txt",
                       "inputs": [{"dtype": "f32", "shape": [128, 8]},
                                  {"dtype": "f32", "shape": [128, 8]},
                                  {"dtype": "f32", "shape": [128, 8]},
                                  {"dtype": "i32", "shape": [128]}],
                       "outputs": [{"dtype": "f32", "shape": [128, 8]}]}]
    }"#;

    #[test]
    fn spec_validation_accepts_matching_artifact() {
        let m = Manifest::parse(SPEC_JSON).unwrap();
        let plan = test_plan(96, 8);
        validate_sparse_spec(&m, &plan, 8).unwrap();
        // Executing through a validated manifest still works (stub path).
        let h = rand_head(82, 96, 8);
        let exec = PjrtGatherExecutor::with_manifest(m);
        let out = exec.execute(&h, &plan);
        let cpu = CpuTileExecutor::default().execute(&h, &plan);
        assert_eq!(out.out.data, cpu.out.data);
    }

    #[test]
    fn spec_validation_rejects_mismatches() {
        let plan = test_plan(96, 8);
        // Missing artifact.
        let none = Manifest::parse(&SPEC_JSON.replace("attn_sparse", "attn_other")).unwrap();
        let err = validate_sparse_spec(&none, &plan, 8).unwrap_err();
        assert!(err.to_string().contains("not in manifest"), "{err}");
        // Head-dim mismatch.
        let m = Manifest::parse(SPEC_JSON).unwrap();
        assert!(validate_sparse_spec(&m, &plan, 16).is_err());
        // Wrong idx dtype.
        let bad_idx = Manifest::parse(&SPEC_JSON.replace(
            r#"{"dtype": "i32", "shape": [128]}"#,
            r#"{"dtype": "f32", "shape": [128]}"#,
        ))
        .unwrap();
        assert!(validate_sparse_spec(&bad_idx, &plan, 8).is_err());
        // idx length no longer matches the gathered-row count.
        let narrow =
            Manifest::parse(&SPEC_JSON.replace("\"shape\": [128]", "\"shape\": [8]")).unwrap();
        assert!(validate_sparse_spec(&narrow, &plan, 8).is_err());
        // Artifact tiles smaller than the plan's tile shape.
        let tiny = Manifest::parse(
            &SPEC_JSON.replace("[128, 8]", "[12, 8]").replace("\"shape\": [128]", "\"shape\": [12]"),
        )
        .unwrap();
        assert!(validate_sparse_spec(&tiny, &plan, 8).is_err());
    }
}

//! Dense causal attention in the FlashAttention style: blocked over
//! (query-block × key-block) tiles with online softmax. This is the
//! paper's `Full-attn` baseline (Fig. 2's denominator) and the numeric
//! reference every sparse method is compared against.
//!
//! [`FullPlanner`] expresses density in the plan IR — one causal span per
//! query block — so the dense baseline runs through the same
//! [`crate::attention::plan::execute_plan`] executor as every sparse
//! method and the measured latencies stay directly comparable.

use crate::attention::plan::{run_planner, GroupPlan, Planner, SparsePlan};
use crate::attention::{AttnOutput, CostTally, HeadInput, TileConfig};
use crate::tensor::{matmul_nn_acc, matmul_nt_scaled, Mat};

/// Online-softmax accumulator state for one query block.
pub(crate) struct BlockState {
    /// Running row maxima `m` (one per query row).
    pub m: Vec<f32>,
    /// Running normalizers `l`.
    pub l: Vec<f32>,
    /// Unnormalized accumulator `acc` `[rows, d]`.
    pub acc: Mat,
}

impl BlockState {
    pub fn new(rows: usize, d: usize) -> Self {
        Self { m: vec![f32::NEG_INFINITY; rows], l: vec![0.0; rows], acc: Mat::zeros(rows, d) }
    }

    /// Reinitialize in place to the state `new(rows, d)` builds, reusing
    /// the backing allocations — the scratch-buffer form used by the
    /// executor's per-worker tile walk.
    pub fn reset(&mut self, rows: usize, d: usize) {
        self.m.clear();
        self.m.resize(rows, f32::NEG_INFINITY);
        self.l.clear();
        self.l.resize(rows, 0.0);
        self.acc.data.clear();
        self.acc.data.resize(rows * d, 0.0);
        self.acc.rows = rows;
        self.acc.cols = d;
    }

    /// Fold one scored tile into the state. `s` holds scaled logits
    /// `[rows, tile_cols]` (already causally masked where needed); `v`
    /// holds the matching value rows `[tile_cols, d]`.
    ///
    /// This is the standard FlashAttention update:
    ///   m' = max(m, rowmax(s)); p = exp(s - m'); α = exp(m - m')
    ///   l  = l·α + rowsum(p);   acc = acc·α + p·V
    pub fn fold_tile(&mut self, s: &mut Mat, v: &Mat) {
        let d = self.acc.cols;
        for r in 0..s.rows {
            let srow = s.row_mut(r);
            let mut tile_max = f32::NEG_INFINITY;
            for &x in srow.iter() {
                tile_max = tile_max.max(x);
            }
            if tile_max == f32::NEG_INFINITY {
                // Entire tile masked for this row: zero the probabilities so
                // the P·V accumulate below is a no-op for row r.
                srow.iter_mut().for_each(|x| *x = 0.0);
                continue;
            }
            let m_new = self.m[r].max(tile_max);
            let alpha = if self.m[r] == f32::NEG_INFINITY {
                0.0
            } else {
                (self.m[r] - m_new).exp()
            };
            let mut rowsum = 0.0f32;
            for x in srow.iter_mut() {
                *x = (*x - m_new).exp();
                rowsum += *x;
            }
            self.l[r] = self.l[r] * alpha + rowsum;
            if alpha != 1.0 {
                for a in self.acc.row_mut(r) {
                    *a *= alpha;
                }
            }
            self.m[r] = m_new;
            let _ = d;
        }
        // acc += P · V  (rows with fully-masked tiles contributed zeros).
        matmul_nn_acc(s, v, &mut self.acc);
    }

    /// Normalize into the output rows: `O = acc / l`.
    pub fn write_output(&self, out_rows: &mut [f32], d: usize) {
        for r in 0..self.l.len() {
            let inv = if self.l[r] > 0.0 { 1.0 / self.l[r] } else { 0.0 };
            let src = self.acc.row(r);
            let dst = &mut out_rows[r * d..(r + 1) * d];
            for (o, &a) in dst.iter_mut().zip(src) {
                *o = a * inv;
            }
        }
    }
}

/// Apply the causal mask to a scored tile whose rows start at absolute
/// position `row0` and columns at `col0`.
pub(crate) fn mask_tile_causal(s: &mut Mat, row0: usize, col0: usize) {
    for r in 0..s.rows {
        let limit = row0 + r; // visible keys: absolute position <= limit
        if col0 + s.cols <= limit + 1 {
            continue; // tile entirely visible for this row
        }
        let row = s.row_mut(r);
        let first_masked = (limit + 1).saturating_sub(col0);
        for x in row.iter_mut().skip(first_masked) {
            *x = f32::NEG_INFINITY;
        }
    }
}

/// Planner for the dense baseline: one `[0, causal_limit)` span per query
/// block, zero identification cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FullPlanner {
    pub tile: TileConfig,
}

impl Planner for FullPlanner {
    fn name(&self) -> &'static str {
        "full-attn"
    }

    fn plan(&self, input: &HeadInput) -> SparsePlan {
        let n = input.n();
        let tile = self.tile;
        let groups: Vec<GroupPlan> = (0..tile.q_blocks(n))
            .map(|qb| GroupPlan {
                spans: vec![(0, (((qb + 1) * tile.b_q).min(n)) as u32)],
                stripes: Vec::new(),
            })
            .collect();
        SparsePlan::new("full-attn", n, input.d(), tile, 1, groups, CostTally::default())
    }
}

/// Dense causal attention over one head (thin wrapper over the planner →
/// executor pipeline).
pub fn full_attention(input: &HeadInput, tile: TileConfig) -> AttnOutput {
    run_planner(input, &FullPlanner { tile })
}

/// Naive O(N²)-memory reference — materializes the score matrix. Only for
/// tests (small N); the blocked implementation must match it exactly.
pub fn naive_attention(input: &HeadInput) -> Mat {
    let n = input.n();
    let d = input.d();
    let scale = input.scale();
    let mut s = Mat::zeros(n, n);
    matmul_nt_scaled(&input.q, &input.k, scale, &mut s);
    crate::tensor::ops::causal_mask_inplace(&mut s, 0, 0);
    crate::tensor::ops::softmax_rows(&mut s);
    let mut out = Mat::zeros(n, d);
    matmul_nn_acc(&s, &input.v, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    pub(crate) fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        let q = Mat::from_fn(n, d, |_, _| rng.normal());
        let k = Mat::from_fn(n, d, |_, _| rng.normal());
        let v = Mat::from_fn(n, d, |_, _| rng.normal());
        HeadInput::new(q, k, v)
    }

    #[test]
    fn blocked_matches_naive_exact_blocks() {
        let h = rand_head(1, 256, 32);
        let blocked = full_attention(&h, TileConfig::new(64, 64));
        let naive = naive_attention(&h);
        assert!(blocked.out.max_abs_diff(&naive) < 1e-4);
    }

    #[test]
    fn blocked_matches_naive_ragged() {
        let h = rand_head(2, 200, 16);
        let blocked = full_attention(&h, TileConfig::new(64, 48));
        let naive = naive_attention(&h);
        assert!(blocked.out.max_abs_diff(&naive) < 1e-4);
    }

    #[test]
    fn blocked_matches_naive_single_block() {
        let h = rand_head(3, 32, 8);
        let blocked = full_attention(&h, TileConfig::new(128, 128));
        let naive = naive_attention(&h);
        assert!(blocked.out.max_abs_diff(&naive) < 1e-4);
    }

    #[test]
    fn first_row_attends_only_to_itself() {
        let h = rand_head(4, 64, 8);
        let out = full_attention(&h, TileConfig::new(16, 16));
        // Row 0 of causal attention = V row 0 exactly.
        for c in 0..8 {
            assert!((out.out.at(0, c) - h.v.at(0, c)).abs() < 1e-5);
        }
    }

    #[test]
    fn coverage_is_full_causal() {
        let h = rand_head(5, 128, 8);
        let out = full_attention(&h, TileConfig::new(32, 32));
        assert_eq!(out.coverage.sparsity(), 0.0);
    }

    #[test]
    fn cost_counts_causal_tiles() {
        let h = rand_head(6, 128, 16);
        let out = full_attention(&h, TileConfig::new(64, 64));
        // Tiles touched: qb0 -> 64 cols; qb1 -> 128 cols. flops = 4*rows*cols*d.
        let expect = 4 * (64 * 64 + 64 * 128) as u64 * 16;
        assert_eq!(out.cost.flops, expect);
    }

    #[test]
    fn mask_tile_causal_diagonal() {
        let mut s = Mat::from_vec(2, 4, vec![1.0; 8]);
        mask_tile_causal(&mut s, 2, 0); // rows at abs pos 2,3; cols 0..4
        assert_eq!(s.row(0), &[1.0, 1.0, 1.0, f32::NEG_INFINITY]);
        assert_eq!(s.row(1), &[1.0, 1.0, 1.0, 1.0]);
    }
}

//! Coverage bookkeeping: which (query-block, key) pairs a sparse method
//! actually computed. Coverage is what the recall and sparsity metrics are
//! defined over, and it is shared by every method so the numbers are
//! comparable.
//!
//! Granularity note (the paper's central point): block-sparse methods can
//! only cover whole `(b_q, b_kv)` tiles, while AnchorAttention covers
//! *stripes* — individual key columns per query-block group — so coverage
//! is stored as a per-query-block **column bitset**.

/// Column bitset over `n` key positions for every query block.
#[derive(Clone, Debug)]
pub struct Coverage {
    pub n: usize,
    pub b_q: usize,
    words_per_block: usize,
    bits: Vec<u64>,
}

impl Coverage {
    pub fn new(n: usize, b_q: usize) -> Self {
        let q_blocks = n.div_ceil(b_q);
        let words_per_block = n.div_ceil(64);
        Self { n, b_q, words_per_block, bits: vec![0; q_blocks * words_per_block] }
    }

    pub fn q_blocks(&self) -> usize {
        if self.b_q == 0 {
            0
        } else {
            self.n.div_ceil(self.b_q)
        }
    }

    #[inline]
    fn block_words(&self, qb: usize) -> &[u64] {
        &self.bits[qb * self.words_per_block..(qb + 1) * self.words_per_block]
    }

    #[inline]
    fn block_words_mut(&mut self, qb: usize) -> &mut [u64] {
        &mut self.bits[qb * self.words_per_block..(qb + 1) * self.words_per_block]
    }

    /// Mark a single key column as computed for query block `qb`.
    #[inline]
    pub fn set(&mut self, qb: usize, col: usize) {
        debug_assert!(col < self.n);
        let w = self.block_words_mut(qb);
        w[col / 64] |= 1u64 << (col % 64);
    }

    /// Mark a contiguous key range `[start, end)`.
    pub fn set_range(&mut self, qb: usize, start: usize, end: usize) {
        let end = end.min(self.n);
        if start >= end {
            return;
        }
        let w = self.block_words_mut(qb);
        let (sw, sb) = (start / 64, start % 64);
        let (ew, eb) = ((end - 1) / 64, (end - 1) % 64);
        if sw == ew {
            let mask = (!0u64 << sb) & (!0u64 >> (63 - eb));
            w[sw] |= mask;
        } else {
            w[sw] |= !0u64 << sb;
            for word in &mut w[sw + 1..ew] {
                *word = !0;
            }
            w[ew] |= !0u64 >> (63 - eb);
        }
    }

    /// Mark a list of discrete columns (the stripe set).
    pub fn set_indices(&mut self, qb: usize, cols: &[u32]) {
        let n = self.n;
        let w = self.block_words_mut(qb);
        for &c in cols {
            debug_assert!((c as usize) < n);
            w[c as usize / 64] |= 1u64 << (c % 64);
        }
    }

    #[inline]
    pub fn covered(&self, qb: usize, col: usize) -> bool {
        let w = self.block_words(qb);
        (w[col / 64] >> (col % 64)) & 1 == 1
    }

    /// Number of covered columns for a query block.
    pub fn count(&self, qb: usize) -> usize {
        self.block_words(qb).iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Sorted covered column indices for a query block.
    pub fn columns(&self, qb: usize) -> Vec<u32> {
        let mut out = Vec::new();
        for (wi, &word) in self.block_words(qb).iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros();
                out.push((wi * 64) as u32 + b);
                bits &= bits - 1;
            }
        }
        out
    }

    /// Intersect coverage of block `qb` with causality for the block's
    /// *last* row (the widest row); callers that need exact per-row
    /// causality handle the diagonal separately.
    pub fn causal_limit(&self, qb: usize) -> usize {
        ((qb + 1) * self.b_q).min(self.n)
    }

    /// Total covered (q-block, key) pairs, counting only causally-valid
    /// columns (col < causal_limit).
    pub fn total_covered(&self) -> u64 {
        let mut total = 0u64;
        for qb in 0..self.q_blocks() {
            let limit = self.causal_limit(qb);
            for (wi, &word) in self.block_words(qb).iter().enumerate() {
                let base = wi * 64;
                if base + 64 <= limit {
                    total += word.count_ones() as u64;
                } else if base < limit {
                    let keep = limit - base;
                    total += (word & ((1u64 << keep) - 1)).count_ones() as u64;
                }
            }
        }
        total
    }

    /// Total causally-valid (q-block, key) pairs — the sparsity denominator
    /// at the identification granularity `(b_q, 1)`.
    pub fn total_causal(&self) -> u64 {
        (0..self.q_blocks()).map(|qb| self.causal_limit(qb) as u64).sum()
    }

    /// Sparsity rate: fraction of causally-valid (q-block, key) pairs *not*
    /// computed (the paper's sparsity metric, Table 1 / Fig. 6).
    pub fn sparsity(&self) -> f64 {
        let total = self.total_causal();
        if total == 0 {
            return 0.0;
        }
        1.0 - self.total_covered() as f64 / total as f64
    }

    /// Union with another coverage (same shape).
    pub fn union(&mut self, other: &Coverage) {
        assert_eq!(self.n, other.n);
        assert_eq!(self.b_q, other.b_q);
        for (a, b) in self.bits.iter_mut().zip(&other.bits) {
            *a |= b;
        }
    }

    /// Full (dense causal) coverage.
    pub fn full(n: usize, b_q: usize) -> Self {
        let mut c = Self::new(n, b_q);
        for qb in 0..c.q_blocks() {
            let limit = c.causal_limit(qb);
            c.set_range(qb, 0, limit);
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_and_query_single_bits() {
        let mut c = Coverage::new(256, 64);
        c.set(1, 0);
        c.set(1, 63);
        c.set(1, 64);
        c.set(1, 255);
        assert!(c.covered(1, 0) && c.covered(1, 63) && c.covered(1, 64) && c.covered(1, 255));
        assert!(!c.covered(1, 1));
        assert_eq!(c.count(1), 4);
        assert_eq!(c.count(0), 0);
        assert_eq!(c.columns(1), vec![0, 63, 64, 255]);
    }

    #[test]
    fn set_range_word_boundaries() {
        let mut c = Coverage::new(256, 64);
        c.set_range(0, 60, 70);
        assert_eq!(c.count(0), 10);
        assert!(c.covered(0, 60) && c.covered(0, 69));
        assert!(!c.covered(0, 59) && !c.covered(0, 70));
        // Full-word interior.
        let mut c2 = Coverage::new(256, 64);
        c2.set_range(0, 0, 256);
        assert_eq!(c2.count(0), 256);
        // Empty range no-op.
        let mut c3 = Coverage::new(256, 64);
        c3.set_range(0, 10, 10);
        assert_eq!(c3.count(0), 0);
    }

    #[test]
    fn range_clamps_to_n() {
        let mut c = Coverage::new(100, 50);
        c.set_range(1, 90, 1000);
        assert_eq!(c.count(1), 10);
    }

    #[test]
    fn causal_accounting() {
        // n=4 blocks of 64: causal totals = 64 + 128 + 192 + 256
        let c = Coverage::full(256, 64);
        assert_eq!(c.total_causal(), 64 + 128 + 192 + 256);
        assert_eq!(c.total_covered(), c.total_causal());
        assert_eq!(c.sparsity(), 0.0);
    }

    #[test]
    fn sparsity_of_empty_is_one() {
        let c = Coverage::new(256, 64);
        assert_eq!(c.sparsity(), 1.0);
    }

    #[test]
    fn acausal_bits_do_not_count() {
        let mut c = Coverage::new(256, 64);
        // Cover future columns for q block 0 — must not count toward coverage.
        c.set_range(0, 128, 256);
        assert_eq!(c.total_covered(), 0);
        assert_eq!(c.count(0), 128, "raw bit count still sees them");
    }

    #[test]
    fn union_merges() {
        let mut a = Coverage::new(128, 64);
        let mut b = Coverage::new(128, 64);
        a.set(0, 3);
        b.set(0, 5);
        a.union(&b);
        assert!(a.covered(0, 3) && a.covered(0, 5));
    }

    #[test]
    fn set_indices_bulk() {
        let mut c = Coverage::new(200, 100);
        c.set_indices(1, &[0, 99, 150]);
        assert_eq!(c.columns(1), vec![0, 99, 150]);
    }

    #[test]
    fn ragged_tail_block() {
        let c = Coverage::full(100, 64); // blocks: 64 + 36 rows
        assert_eq!(c.q_blocks(), 2);
        assert_eq!(c.causal_limit(0), 64);
        assert_eq!(c.causal_limit(1), 100);
    }
}

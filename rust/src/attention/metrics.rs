//! Evaluation metrics, using the paper's definitions:
//!
//! * **Recall** (Fig. 4 caption, following MInference): the fraction of
//!   true attention probability mass that falls on positions the sparse
//!   method actually computed. Computed exactly with a streaming
//!   online-softmax pass, so memory stays O(N) even at long contexts.
//! * **Sparsity**: fraction of causally-valid (query-block, key) pairs not
//!   computed — provided by [`Coverage::sparsity`].
//! * **Output fidelity**: relative Frobenius error of the sparse output vs
//!   dense attention (drives the LongBench/RULER accuracy proxies).

//! With the planner → executor split, coverage comes straight from a
//! [`SparsePlan`] ([`plan_recall`] / [`plan_sparsity`]): recall and
//! sparsity are properties of *identification*, so they are measured
//! without executing any attention.

use crate::attention::mask::Coverage;
use crate::attention::plan::SparsePlan;
use crate::attention::{HeadInput, TileConfig};
use crate::tensor::{matmul_nt_scaled, Mat};
use crate::util::threadpool::parallel_map;

/// Recall statistics for one head.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecallStats {
    /// Mean over query rows of covered probability mass.
    pub mean_recall: f64,
    /// Worst query row.
    pub min_recall: f64,
    /// Number of rows measured.
    pub rows: usize,
}

/// Exact streaming recall of `coverage` against the true attention
/// distribution of `input`. O(N) memory, O(N²) time (it *is* the full
/// score computation — use moderate N; see DESIGN.md §6).
pub fn recall(input: &HeadInput, coverage: &Coverage, tile: TileConfig) -> RecallStats {
    let n = input.n();
    let scale = input.scale();
    assert_eq!(coverage.n, n);
    assert_eq!(coverage.b_q, tile.b_q);
    let q_blocks = tile.q_blocks(n);

    let per_block: Vec<(f64, f64, usize)> = parallel_map(q_blocks, |qb| {
        let row0 = qb * tile.b_q;
        let rows = (n - row0).min(tile.b_q);
        let q_i = input.q.rows_mat(row0, rows);
        let limit = row0 + rows;
        let kv_blocks = limit.div_ceil(tile.b_kv);

        let mut m = vec![f32::NEG_INFINITY; rows];
        let mut den = vec![0.0f64; rows];
        let mut num = vec![0.0f64; rows];
        let mut s = Mat::zeros(rows, tile.b_kv);

        for jb in 0..kv_blocks {
            let col0 = jb * tile.b_kv;
            let cols = (limit - col0).min(tile.b_kv);
            let k_j = input.k.rows_mat(col0, cols);
            if s.cols != cols {
                s = Mat::zeros(rows, cols);
            }
            matmul_nt_scaled(&q_i, &k_j, scale, &mut s);
            for r in 0..rows {
                let abs_row = row0 + r;
                let visible = (abs_row + 1).saturating_sub(col0).min(cols);
                if visible == 0 {
                    continue;
                }
                let srow = &s.row(r)[..visible];
                let tile_max = srow.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let m_new = m[r].max(tile_max);
                let alpha = if m[r] == f32::NEG_INFINITY { 0.0 } else { ((m[r] - m_new) as f64).exp() };
                den[r] *= alpha;
                num[r] *= alpha;
                for (c, &x) in srow.iter().enumerate() {
                    let p = ((x - m_new) as f64).exp();
                    den[r] += p;
                    if coverage.covered(qb, col0 + c) {
                        num[r] += p;
                    }
                }
                m[r] = m_new;
            }
        }

        let mut sum = 0.0f64;
        let mut min = f64::INFINITY;
        for r in 0..rows {
            let rec = if den[r] > 0.0 { num[r] / den[r] } else { 0.0 };
            sum += rec;
            min = min.min(rec);
        }
        (sum, min, rows)
    });

    let mut sum = 0.0;
    let mut min = f64::INFINITY;
    let mut rows = 0;
    for (s, mn, r) in per_block {
        sum += s;
        min = min.min(mn);
        rows += r;
    }
    RecallStats { mean_recall: if rows > 0 { sum / rows as f64 } else { 0.0 }, min_recall: min, rows }
}

/// Pooled-row recall for very long contexts: evaluates coverage against the
/// *block-pooled* score distribution (`avgpool(Q, b_q) · Kᵀ`), which is the
/// identification granularity itself. Used for N ≥ 64k where exact recall
/// is impractical on the CPU testbed (DESIGN.md §6).
pub fn pooled_recall(input: &HeadInput, coverage: &Coverage, tile: TileConfig) -> RecallStats {
    let n = input.n();
    let scale = input.scale();
    let q_pool = crate::tensor::ops::avgpool_rows(&input.q, tile.b_q);
    let q_blocks = q_pool.rows;

    let per_block: Vec<(f64, f64)> = parallel_map(q_blocks, |qb| {
        let limit = ((qb + 1) * tile.b_q).min(n);
        let q_row = q_pool.rows_mat(qb, 1);
        let mut m = f32::NEG_INFINITY;
        let mut den = 0.0f64;
        let mut num = 0.0f64;
        let mut s = Mat::zeros(1, tile.b_kv);
        let kv_blocks = limit.div_ceil(tile.b_kv);
        for jb in 0..kv_blocks {
            let col0 = jb * tile.b_kv;
            let cols = (limit - col0).min(tile.b_kv);
            let k_j = input.k.rows_mat(col0, cols);
            if s.cols != cols {
                s = Mat::zeros(1, cols);
            }
            matmul_nt_scaled(&q_row, &k_j, scale, &mut s);
            let srow = s.row(0);
            let tile_max = srow.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let m_new = m.max(tile_max);
            let alpha = if m == f32::NEG_INFINITY { 0.0 } else { ((m - m_new) as f64).exp() };
            den *= alpha;
            num *= alpha;
            for (c, &x) in srow.iter().enumerate() {
                let p = ((x - m_new) as f64).exp();
                den += p;
                if coverage.covered(qb, col0 + c) {
                    num += p;
                }
            }
            m = m_new;
        }
        let rec = if den > 0.0 { num / den } else { 0.0 };
        (rec, rec)
    });

    let rows = per_block.len();
    let sum: f64 = per_block.iter().map(|x| x.0).sum();
    let min = per_block.iter().map(|x| x.1).fold(f64::INFINITY, f64::min);
    RecallStats { mean_recall: if rows > 0 { sum / rows as f64 } else { 0.0 }, min_recall: min, rows }
}

/// Exact recall of a plan's coverage — no attention executed; the plan IR
/// alone determines the metric.
pub fn plan_recall(input: &HeadInput, plan: &SparsePlan) -> RecallStats {
    recall(input, &plan.coverage(), plan.tile)
}

/// Pooled-recall variant of [`plan_recall`] for very long contexts.
pub fn plan_pooled_recall(input: &HeadInput, plan: &SparsePlan) -> RecallStats {
    pooled_recall(input, &plan.coverage(), plan.tile)
}

/// Sparsity implied by a plan (fraction of causal pairs skipped).
pub fn plan_sparsity(plan: &SparsePlan) -> f64 {
    plan.coverage().sparsity()
}

/// Output fidelity: relative Frobenius error vs the dense output, mapped to
/// an accuracy-like score in [0, 100] (`100 · max(0, 1 − err/tol)` — the
/// LongBench/RULER proxy; see DESIGN.md §1).
pub fn fidelity_score(sparse_out: &Mat, full_out: &Mat, tol: f64) -> f64 {
    let err = sparse_out.rel_err(full_out);
    100.0 * (1.0 - err / tol).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::{full_attention, naive_attention};
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    #[test]
    fn full_coverage_has_recall_one() {
        let h = rand_head(1, 128, 16);
        let tile = TileConfig::new(32, 32);
        let cov = Coverage::full(128, 32);
        let r = recall(&h, &cov, tile);
        assert!((r.mean_recall - 1.0).abs() < 1e-9, "{}", r.mean_recall);
        assert!((r.min_recall - 1.0).abs() < 1e-9);
        assert_eq!(r.rows, 128);
    }

    #[test]
    fn empty_coverage_has_recall_zero() {
        let h = rand_head(2, 64, 8);
        let tile = TileConfig::new(16, 16);
        let cov = Coverage::new(64, 16);
        let r = recall(&h, &cov, tile);
        assert!(r.mean_recall < 1e-12);
    }

    #[test]
    fn recall_matches_naive_probabilities() {
        // Cover only the first 8 keys for every q block; compare to a naive
        // softmax computation of the same mass.
        let n = 64;
        let d = 8;
        let h = rand_head(3, n, d);
        let tile = TileConfig::new(16, 16);
        let mut cov = Coverage::new(n, 16);
        for qb in 0..cov.q_blocks() {
            cov.set_range(qb, 0, 8);
        }
        let got = recall(&h, &cov, tile);

        // Naive: full probs, sum over first 8 columns.
        let scale = h.scale();
        let mut s = Mat::zeros(n, n);
        matmul_nt_scaled(&h.q, &h.k, scale, &mut s);
        crate::tensor::ops::causal_mask_inplace(&mut s, 0, 0);
        crate::tensor::ops::softmax_rows(&mut s);
        let mut acc = 0.0;
        for r in 0..n {
            let mass: f32 = s.row(r)[..8.min(r + 1)].iter().sum();
            acc += mass as f64;
        }
        let expect = acc / n as f64;
        assert!((got.mean_recall - expect).abs() < 1e-5, "{} vs {expect}", got.mean_recall);
    }

    #[test]
    fn partial_coverage_recall_between_zero_and_one() {
        let h = rand_head(4, 96, 8);
        let tile = TileConfig::new(32, 32);
        let mut cov = Coverage::new(96, 32);
        for qb in 0..3 {
            cov.set_range(qb, 0, 16);
        }
        let r = recall(&h, &cov, tile);
        assert!(r.mean_recall > 0.0 && r.mean_recall < 1.0);
        assert!(r.min_recall <= r.mean_recall);
    }

    #[test]
    fn pooled_recall_full_coverage_is_one() {
        let h = rand_head(5, 128, 8);
        let tile = TileConfig::new(32, 32);
        let cov = Coverage::full(128, 32);
        let r = pooled_recall(&h, &cov, tile);
        assert!((r.mean_recall - 1.0).abs() < 1e-9);
    }

    #[test]
    fn plan_metrics_match_executed_metrics() {
        // Recall/sparsity from the plan alone equal the metrics of the
        // executed output's coverage — identification is the metric.
        let h = rand_head(7, 128, 8);
        let m = crate::attention::Method::Anchor(
            crate::attention::anchor::AnchorConfig {
                tile: TileConfig::new(16, 16),
                theta: 3.0,
                step: 2,
                init_blocks: 1,
                use_anchor: true,
            },
        );
        let plan = m.plan(&h);
        let out = m.session().no_cache().build().unwrap().run(&h).unwrap().into_single();
        let from_plan = plan_recall(&h, &plan);
        let from_exec = recall(&h, &out.coverage, plan.tile);
        assert!((from_plan.mean_recall - from_exec.mean_recall).abs() < 1e-12);
        assert_eq!(plan_sparsity(&plan), out.coverage.sparsity());
    }

    #[test]
    fn fidelity_score_bounds() {
        let h = rand_head(6, 64, 8);
        let full = naive_attention(&h);
        let same = full_attention(&h, TileConfig::new(16, 16));
        assert!(fidelity_score(&same.out, &full, 0.2) > 99.9);
        let zeros = Mat::zeros(64, 8);
        assert!(fidelity_score(&zeros, &full, 0.2) < 1.0);
    }
}

//! The attention engine: the paper's contribution (AnchorAttention,
//! Algorithms 1–3) plus every baseline the evaluation compares against,
//! all sharing one blocked, multithreaded f32 substrate so measured
//! latencies are directly comparable (the paper's A100/Triton testbed is
//! substituted by this engine — see DESIGN.md §1).
//!
//! Architecture (DESIGN.md §2/§10/§11): every method is a [`plan::Planner`]
//! that identifies a [`plan::SparsePlan`] (coordinates only); a swappable
//! executor backend ([`exec::Executor`] — CPU tile walk or PJRT gather)
//! computes exact softmax attention restricted to the plan. The single
//! entry point is [`session::AttentionSession`]: a builder fixes the
//! backend, plan cache, pipelining and persistence once, and
//! `session.run(&HeadInput)` / `session.run_batch(&BatchInput)` dispatch
//! the right variant internally — sequential or overlapped through the
//! bounded plan queue ([`pipeline::PlanPipeline`], DESIGN.md §9) with
//! bitwise-identical results. [`shard::ShardedSession`] scales the same
//! front end across head-group shard workers that exchange only plan
//! coordinates (DESIGN.md §12).
//!
//! The pre-session `run_*` entry points are gone: they survived one
//! release (0.3.x) as `#[deprecated]` shims over the session dispatch and
//! were removed in the raw-speed executor pass. Build an
//! [`session::AttentionSession`] instead (DESIGN.md §11).
//!
//! Layout convention: row-major `[N, d]` matrices for Q, K, V per head,
//! causal masking, logits scaled by `1/sqrt(d)`.

pub mod anchor;
pub mod baselines;
pub mod exec;
pub mod full;
pub mod mask;
pub mod metrics;
pub mod pipeline;
pub mod plan;
pub mod reuse;
pub mod session;
pub mod shard;
pub mod strategy;

use crate::tensor::Mat;
use crate::util::threadpool::parallel_map;
use exec::Executor;
use plan::{BatchInput, BatchOutput, PlanCache, PlanKey, Planner, SparsePlan};
use std::sync::Arc;

/// Tiling parameters shared by every method (the paper fixes both to 128).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TileConfig {
    pub b_q: usize,
    pub b_kv: usize,
}

impl Default for TileConfig {
    fn default() -> Self {
        Self { b_q: 128, b_kv: 128 }
    }
}

impl TileConfig {
    pub fn new(b_q: usize, b_kv: usize) -> Self {
        assert!(b_q >= 1 && b_kv >= 1);
        Self { b_q, b_kv }
    }

    pub fn q_blocks(&self, n: usize) -> usize {
        n.div_ceil(self.b_q)
    }

    pub fn kv_blocks(&self, n: usize) -> usize {
        n.div_ceil(self.b_kv)
    }
}

/// Per-head input to any attention method.
#[derive(Clone, Debug)]
pub struct HeadInput {
    pub q: Mat,
    pub k: Mat,
    pub v: Mat,
}

impl HeadInput {
    pub fn new(q: Mat, k: Mat, v: Mat) -> Self {
        assert_eq!(q.cols, k.cols, "q/k head dim");
        assert_eq!(k.rows, v.rows, "k/v length");
        assert_eq!(k.cols, v.cols, "k/v head dim (MHA layout)");
        Self { q, k, v }
    }

    pub fn n(&self) -> usize {
        self.q.rows
    }

    pub fn d(&self) -> usize {
        self.q.cols
    }

    pub fn scale(&self) -> f32 {
        1.0 / (self.d() as f32).sqrt()
    }
}

/// Work/traffic accounting used by the analytic cost model (DESIGN.md §1):
/// every method tallies the multiply-accumulate volume and the KV bytes it
/// actually touches, split by pipeline phase.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostTally {
    /// Multiply-adds in QKᵀ and P·V (counted as 2 flops each).
    pub flops: u64,
    /// Bytes of K/V loaded from "HBM" (i.e. outside the working tile).
    pub kv_bytes: u64,
    /// Score entries evaluated during identification.
    pub ident_scores: u64,
}

impl CostTally {
    pub fn add(&mut self, other: CostTally) {
        self.flops += other.flops;
        self.kv_bytes += other.kv_bytes;
        self.ident_scores += other.ident_scores;
    }

    /// Tally for an attention tile: `rows × cols` score entries at head
    /// dim `d` (QKᵀ + PV, 4·rows·cols·d flops), loading cols KV rows.
    pub fn attn_tile(rows: usize, cols: usize, d: usize) -> CostTally {
        CostTally {
            flops: 4 * (rows * cols * d) as u64,
            kv_bytes: (2 * cols * d * 4) as u64,
            ident_scores: 0,
        }
    }

    /// Tally for an identification tile (pooled-Q × K, scores only).
    pub fn ident_tile(rows: usize, cols: usize, d: usize) -> CostTally {
        CostTally {
            flops: 2 * (rows * cols * d) as u64,
            kv_bytes: (cols * d * 4) as u64,
            ident_scores: (rows * cols) as u64,
        }
    }
}

/// Result of running one attention method on one head.
#[derive(Clone, Debug)]
pub struct AttnOutput {
    pub out: Mat,
    /// Which (q-block, key) pairs were actually computed — drives the
    /// recall/sparsity metrics.
    pub coverage: mask::Coverage,
    pub cost: CostTally,
}

/// Every method the paper evaluates (Table 2/3, Fig. 6/7).
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// Dense FlashAttention — the paper's `Full-attn` baseline.
    Full(TileConfig),
    /// The paper's contribution.
    Anchor(anchor::AnchorConfig),
    /// StreamingLLM: initial + local window only.
    Streaming(baselines::streaming::StreamingConfig),
    /// MInference's Vertical_Slash static pattern.
    VerticalSlash(baselines::vertical_slash::VerticalSlashConfig),
    /// FlexPrefill-style dynamic block top-cdf.
    FlexPrefill(baselines::flexprefill::FlexPrefillConfig),
    /// Block-granular top-k (analysis baseline, Table 1).
    BlockTopK(baselines::block_topk::BlockTopKConfig),
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Full(_) => "full-attn",
            Method::Anchor(_) => "anchor",
            Method::Streaming(_) => "streaming-llm",
            Method::VerticalSlash(_) => "vertical-slash",
            Method::FlexPrefill(_) => "flexprefill",
            Method::BlockTopK(_) => "block-topk",
        }
    }

    /// The planner implementing this method's identification stage.
    pub fn planner(&self) -> Box<dyn Planner> {
        match self {
            Method::Full(tile) => Box::new(full::FullPlanner { tile: *tile }),
            Method::Anchor(cfg) => Box::new(*cfg),
            Method::Streaming(cfg) => Box::new(*cfg),
            Method::VerticalSlash(cfg) => Box::new(*cfg),
            Method::FlexPrefill(cfg) => Box::new(*cfg),
            Method::BlockTopK(cfg) => Box::new(*cfg),
        }
    }

    /// Identify this method's plan for one head (no attention computed).
    pub fn plan(&self, input: &HeadInput) -> SparsePlan {
        self.planner().plan(input)
    }

    /// The `(tile, step)` geometry this method's planner emits (anchor
    /// plans carry the config's `step`; every other planner emits step-1
    /// plans). Sessions use it to reject persisted plans whose geometry
    /// disagrees with the method configuration — a store model tag names
    /// a config cell by convention, but geometry mismatches are cheap to
    /// catch structurally (DESIGN.md §11).
    pub(crate) fn plan_geometry(&self) -> (TileConfig, usize) {
        match self {
            Method::Full(tile) => (*tile, 1),
            Method::Anchor(cfg) => (cfg.tile, cfg.step),
            Method::Streaming(cfg) => (cfg.tile, 1),
            Method::VerticalSlash(cfg) => (cfg.tile, 1),
            Method::FlexPrefill(cfg) => (cfg.tile, 1),
            Method::BlockTopK(cfg) => (cfg.tile, 1),
        }
    }

    /// Two-stage batch execution: first resolve one plan per *distinct*
    /// key (parallel planning, no duplicate identification within the
    /// batch), then hand every head to the executor backend's batched
    /// entry. Hit accounting is deterministic: `hits = heads − fresh keys`.
    /// This is the sequential half of the session dispatch
    /// ([`session::AttentionSession::run_batch`]).
    pub(crate) fn run_batch_inner(
        &self,
        batch: &BatchInput,
        cached: Option<(&PlanCache, &[PlanKey])>,
        spec: Option<&reuse::Speculator>,
        executor: &dyn Executor,
    ) -> BatchOutput {
        let planner = self.planner();
        let planner = planner.as_ref();
        let h_total = batch.h();

        let mut plans: Vec<Option<Arc<SparsePlan>>> = (0..h_total).map(|_| None).collect();
        // Heads that pay their plan's identification cost (the planning
        // head of each fresh key; cache/batch hits ride for free).
        let mut pays_ident = vec![false; h_total];
        let cache_hits;
        let cache_misses;
        match cached {
            Some((cache, keys)) => {
                // First head of each distinct key, in first-seen order.
                let mut firsts: Vec<(PlanKey, usize)> = Vec::new();
                for (h, &k) in keys.iter().enumerate() {
                    if !firsts.iter().any(|&(fk, _)| fk == k) {
                        firsts.push((k, h));
                    }
                }
                let resolved: Vec<(Arc<SparsePlan>, bool)> =
                    parallel_map(firsts.len(), |i| {
                        let (key, h) = firsts[i];
                        // On a miss the speculative reuse layer (if the
                        // session enabled one) widens the lookup; the
                        // builder runs outside the cache lock, so the
                        // speculator may snapshot the cache for donors.
                        cache.get_or_plan(key, || match spec {
                            Some(s) => s.resolve(cache, key, &batch.heads[h]),
                            None => planner.plan(&batch.heads[h]),
                        })
                    });
                let mut misses = 0u64;
                for (&(key, h0), (head_plan, hit)) in firsts.iter().zip(&resolved) {
                    if !hit {
                        misses += 1;
                        pays_ident[h0] = true;
                    }
                    for (h, &k) in keys.iter().enumerate() {
                        if k == key {
                            plans[h] = Some(head_plan.clone());
                        }
                    }
                }
                cache_misses = misses;
                cache_hits = h_total as u64 - misses;
            }
            None => {
                let resolved: Vec<Arc<SparsePlan>> =
                    parallel_map(h_total, |h| Arc::new(planner.plan(&batch.heads[h])));
                for (h, head_plan) in resolved.into_iter().enumerate() {
                    plans[h] = Some(head_plan);
                    pays_ident[h] = true;
                }
                cache_hits = 0;
                cache_misses = h_total as u64;
            }
        }
        let plans: Vec<Arc<SparsePlan>> =
            plans.into_iter().map(|p| p.expect("plan resolved")).collect();

        // The backend's batched entry parallelizes at head granularity
        // (per-head execution runs serially to avoid oversubscribing the
        // pool); the planning head of each fresh key then pays its
        // identification cost.
        let mut outputs = executor.execute_batch(batch, &plans);
        for (h, out) in outputs.iter_mut().enumerate() {
            if pays_ident[h] {
                out.cost.add(plans[h].ident_cost);
            }
        }
        BatchOutput { outputs, plans, cache_hits, cache_misses }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_config_block_counts() {
        let t = TileConfig::new(128, 128);
        assert_eq!(t.q_blocks(1024), 8);
        assert_eq!(t.q_blocks(1000), 8);
        assert_eq!(t.kv_blocks(129), 2);
    }

    #[test]
    fn cost_tally_accumulates() {
        let mut t = CostTally::default();
        t.add(CostTally::attn_tile(2, 3, 4));
        assert_eq!(t.flops, 4 * 24);
        assert_eq!(t.kv_bytes, 2 * 3 * 4 * 4);
        t.add(CostTally::ident_tile(1, 5, 4));
        assert_eq!(t.ident_scores, 5);
    }

    #[test]
    fn head_input_scale() {
        let q = Mat::zeros(4, 16);
        let k = Mat::zeros(4, 16);
        let v = Mat::zeros(4, 16);
        let h = HeadInput::new(q, k, v);
        assert!((h.scale() - 0.25).abs() < 1e-7);
    }

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn small_methods() -> Vec<Method> {
        let tile = TileConfig::new(16, 16);
        vec![
            Method::Full(tile),
            Method::Anchor(anchor::AnchorConfig {
                tile,
                theta: 4.0,
                step: 2,
                init_blocks: 1,
                use_anchor: true,
            }),
            Method::Streaming(baselines::streaming::StreamingConfig {
                tile,
                global_tokens: 16,
                local_tokens: 32,
            }),
            Method::VerticalSlash(baselines::vertical_slash::VerticalSlashConfig {
                tile,
                vertical_tokens: 8,
                slash_tokens: 8,
                last_q: 16,
            }),
            Method::FlexPrefill(baselines::flexprefill::FlexPrefillConfig {
                tile,
                gamma: 0.9,
                min_budget_tokens: 16,
            }),
            Method::BlockTopK(baselines::block_topk::BlockTopKConfig {
                tile,
                k: 3,
                force_sink_local: true,
            }),
        ]
    }

    /// Every method routes through Planner::plan + the session's executor,
    /// and the plan's coverage/cost agree with what the run reports.
    #[test]
    fn run_is_plan_plus_execute_for_all_methods() {
        let h = rand_head(77, 128, 16);
        for m in small_methods() {
            let p = m.plan(&h);
            assert_eq!(p.method, m.name());
            let out = m.session().no_cache().build().unwrap().run(&h).unwrap().into_single();
            assert_eq!(
                out.coverage.total_covered(),
                p.coverage().total_covered(),
                "{}",
                m.name()
            );
            let mut expect_cost = p.predicted_cost;
            expect_cost.add(p.ident_cost);
            assert_eq!(out.cost, expect_cost, "{}", m.name());
        }
    }

    /// Batched multi-head execution matches per-head runs exactly.
    #[test]
    fn run_batch_matches_per_head_runs() {
        let heads: Vec<HeadInput> = (0..3).map(|i| rand_head(100 + i, 96, 8)).collect();
        let batch = plan::BatchInput::new(heads.clone());
        for m in small_methods() {
            let b = m.session().no_cache().build().unwrap().run_batch(&batch).unwrap();
            assert_eq!(b.cache_hits, 0);
            assert_eq!(b.cache_misses, 3);
            for (h, out) in heads.iter().zip(&b.outputs) {
                let single = m.session().no_cache().build().unwrap().run(h).unwrap().into_single();
                assert!(
                    out.out.max_abs_diff(&single.out) < 1e-6,
                    "{} diverges in batch",
                    m.name()
                );
                assert_eq!(out.cost, single.cost, "{}", m.name());
            }
        }
    }

    /// Heads sharing a PlanKey reuse the first head's plan; hits skip the
    /// identification cost. The session owns the cache, so a second batch
    /// on the same session runs warm.
    #[test]
    fn run_batch_cached_shares_plans_within_groups() {
        let shared = rand_head(200, 96, 8);
        let batch = plan::BatchInput::new(vec![shared.clone(), shared.clone(), shared]);
        let keys = vec![
            plan::PlanKey::new(0, 0),
            plan::PlanKey::new(0, 0),
            plan::PlanKey::new(0, 1),
        ];
        let m = Method::Anchor(anchor::AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta: 4.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        });
        let mut session = m.session().keys(keys).build().unwrap();
        let b = session.run_batch(&batch).unwrap();
        // Distinct keys plan exactly once; the other heads hit.
        assert_eq!((b.cache_hits, b.cache_misses), (1, 2));
        assert!(b.outputs[0].out.max_abs_diff(&b.outputs[1].out) < 1e-6);
        assert!(Arc::ptr_eq(&b.plans[0], &b.plans[1]));
        assert_eq!(session.cache_stats().unwrap().entries, 2);
        // A second batch over the session's warm cache is all hits.
        let b2 = session.run_batch(&batch).unwrap();
        assert_eq!((b2.cache_hits, b2.cache_misses), (3, 0));
        // Hit heads do not pay identification cost.
        assert!(b2.outputs[0].cost.flops < b.outputs[0].cost.flops + 1);
        assert_eq!(b2.outputs[1].cost, b2.outputs[0].cost);
        assert_eq!(b2.ident_cost_paid, CostTally::default());
    }

}

//! The attention engine: the paper's contribution (AnchorAttention,
//! Algorithms 1–3) plus every baseline the evaluation compares against,
//! all sharing one blocked, multithreaded f32 substrate so measured
//! latencies are directly comparable (the paper's A100/Triton testbed is
//! substituted by this engine — see DESIGN.md §1).
//!
//! Layout convention: one head at a time, row-major `[N, d]` matrices for
//! Q, K, V, causal masking, logits scaled by `1/sqrt(d)`.

pub mod anchor;
pub mod baselines;
pub mod full;
pub mod mask;
pub mod metrics;
pub mod strategy;

use crate::tensor::Mat;

/// Tiling parameters shared by every method (the paper fixes both to 128).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TileConfig {
    pub b_q: usize,
    pub b_kv: usize,
}

impl Default for TileConfig {
    fn default() -> Self {
        Self { b_q: 128, b_kv: 128 }
    }
}

impl TileConfig {
    pub fn new(b_q: usize, b_kv: usize) -> Self {
        assert!(b_q >= 1 && b_kv >= 1);
        Self { b_q, b_kv }
    }

    pub fn q_blocks(&self, n: usize) -> usize {
        n.div_ceil(self.b_q)
    }

    pub fn kv_blocks(&self, n: usize) -> usize {
        n.div_ceil(self.b_kv)
    }
}

/// Per-head input to any attention method.
#[derive(Clone, Debug)]
pub struct HeadInput {
    pub q: Mat,
    pub k: Mat,
    pub v: Mat,
}

impl HeadInput {
    pub fn new(q: Mat, k: Mat, v: Mat) -> Self {
        assert_eq!(q.cols, k.cols, "q/k head dim");
        assert_eq!(k.rows, v.rows, "k/v length");
        assert_eq!(k.cols, v.cols, "k/v head dim (MHA layout)");
        Self { q, k, v }
    }

    pub fn n(&self) -> usize {
        self.q.rows
    }

    pub fn d(&self) -> usize {
        self.q.cols
    }

    pub fn scale(&self) -> f32 {
        1.0 / (self.d() as f32).sqrt()
    }
}

/// Work/traffic accounting used by the analytic cost model (DESIGN.md §1):
/// every method tallies the multiply-accumulate volume and the KV bytes it
/// actually touches, split by pipeline phase.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostTally {
    /// Multiply-adds in QKᵀ and P·V (counted as 2 flops each).
    pub flops: u64,
    /// Bytes of K/V loaded from "HBM" (i.e. outside the working tile).
    pub kv_bytes: u64,
    /// Score entries evaluated during identification.
    pub ident_scores: u64,
}

impl CostTally {
    pub fn add(&mut self, other: CostTally) {
        self.flops += other.flops;
        self.kv_bytes += other.kv_bytes;
        self.ident_scores += other.ident_scores;
    }

    /// Tally for an attention tile: `rows × cols` score entries at head
    /// dim `d` (QKᵀ + PV, 4·rows·cols·d flops), loading cols KV rows.
    pub fn attn_tile(rows: usize, cols: usize, d: usize) -> CostTally {
        CostTally {
            flops: 4 * (rows * cols * d) as u64,
            kv_bytes: (2 * cols * d * 4) as u64,
            ident_scores: 0,
        }
    }

    /// Tally for an identification tile (pooled-Q × K, scores only).
    pub fn ident_tile(rows: usize, cols: usize, d: usize) -> CostTally {
        CostTally {
            flops: 2 * (rows * cols * d) as u64,
            kv_bytes: (cols * d * 4) as u64,
            ident_scores: (rows * cols) as u64,
        }
    }
}

/// Result of running one attention method on one head.
#[derive(Clone, Debug)]
pub struct AttnOutput {
    pub out: Mat,
    /// Which (q-block, key) pairs were actually computed — drives the
    /// recall/sparsity metrics.
    pub coverage: mask::Coverage,
    pub cost: CostTally,
}

/// Every method the paper evaluates (Table 2/3, Fig. 6/7).
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// Dense FlashAttention — the paper's `Full-attn` baseline.
    Full(TileConfig),
    /// The paper's contribution.
    Anchor(anchor::AnchorConfig),
    /// StreamingLLM: initial + local window only.
    Streaming(baselines::streaming::StreamingConfig),
    /// MInference's Vertical_Slash static pattern.
    VerticalSlash(baselines::vertical_slash::VerticalSlashConfig),
    /// FlexPrefill-style dynamic block top-cdf.
    FlexPrefill(baselines::flexprefill::FlexPrefillConfig),
    /// Block-granular top-k (analysis baseline, Table 1).
    BlockTopK(baselines::block_topk::BlockTopKConfig),
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Full(_) => "full-attn",
            Method::Anchor(_) => "anchor",
            Method::Streaming(_) => "streaming-llm",
            Method::VerticalSlash(_) => "vertical-slash",
            Method::FlexPrefill(_) => "flexprefill",
            Method::BlockTopK(_) => "block-topk",
        }
    }

    /// Run the method on one head.
    pub fn run(&self, input: &HeadInput) -> AttnOutput {
        match self {
            Method::Full(tile) => full::full_attention(input, *tile),
            Method::Anchor(cfg) => anchor::anchor_attention(input, cfg),
            Method::Streaming(cfg) => baselines::streaming::streaming_attention(input, cfg),
            Method::VerticalSlash(cfg) => {
                baselines::vertical_slash::vertical_slash_attention(input, cfg)
            }
            Method::FlexPrefill(cfgg) => baselines::flexprefill::flexprefill_attention(input, cfgg),
            Method::BlockTopK(cfg) => baselines::block_topk::block_topk_attention(input, cfg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_config_block_counts() {
        let t = TileConfig::new(128, 128);
        assert_eq!(t.q_blocks(1024), 8);
        assert_eq!(t.q_blocks(1000), 8);
        assert_eq!(t.kv_blocks(129), 2);
    }

    #[test]
    fn cost_tally_accumulates() {
        let mut t = CostTally::default();
        t.add(CostTally::attn_tile(2, 3, 4));
        assert_eq!(t.flops, 4 * 24);
        assert_eq!(t.kv_bytes, 2 * 3 * 4 * 4);
        t.add(CostTally::ident_tile(1, 5, 4));
        assert_eq!(t.ident_scores, 5);
    }

    #[test]
    fn head_input_scale() {
        let q = Mat::zeros(4, 16);
        let k = Mat::zeros(4, 16);
        let v = Mat::zeros(4, 16);
        let h = HeadInput::new(q, k, v);
        assert!((h.scale() - 0.25).abs() < 1e-7);
    }
}

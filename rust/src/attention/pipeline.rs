//! The async plan pipeline: overlap identification with sparse execution.
//!
//! The paper keeps the GPU busy by making identification cheap relative to
//! the fine-grained sparse computation (§3.2–§3.3); PR 1's Planner →
//! [`SparsePlan`] → Executor split made identification a *detachable*
//! stage, and this module detaches it in time as well: planner workers
//! identify the plan for head/key *i+1* while the drain stage's
//! [`Executor`] backend (whichever the session was built with) drains head
//! *i*, communicating through a bounded two-slot [`OrderedBoundedQueue`]
//! (DESIGN.md §9). Sessions opt in with `SessionBuilder::pipelined(true)`;
//! [`run_planner_batch_pipelined`] is the engine the session dispatches
//! to.
//!
//! Guarantees:
//! * **Determinism** — plans land in submission order regardless of worker
//!   timing, every head executes against the same plan the sequential path
//!   would resolve, and the executed arithmetic is identical, so pipelined
//!   output is **bitwise-equal** to the sequential session dispatch
//!   (property-tested for all six methods).
//! * **No deadlock on failure** — a panicked planner worker poisons the
//!   queue; the executor surfaces its message as an `Err` instead of
//!   blocking forever, and a panicking executor poisons the queue on
//!   unwind so planner workers never block forever either.
//! * **Accounting parity** — cache hits/misses and per-head ident-cost
//!   attribution match the sequential batched path exactly
//!   (`hits = heads − fresh keys`; the first head of each fresh key pays).
//!
//! [`PipelineStats`] reports how much identification wall time the
//! overlap actually hid (`overlap_efficiency`), which is what the
//! scheduler's `max(ident, exec)` pricing (`SparsityModel::Anchor` with
//! `pipelined: true`) assumes and what the CI bench gate tracks.

use std::sync::Arc;
use std::time::Instant;

use crate::attention::exec::Executor;
use crate::attention::plan::{BatchInput, BatchOutput, PlanCache, PlanKey, Planner, SparsePlan};
use crate::attention::reuse::Speculator;
use crate::attention::AttnOutput;
use crate::util::threadpool::{num_threads, panic_message, OrderedBoundedQueue, PoisonOnDrop};

/// Pipeline shape: how far planners may run ahead of the executor and how
/// many worker threads identify concurrently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanPipeline {
    /// Bounded plan-queue depth: at most this many plans are in flight
    /// (identifying or queued) ahead of the executor. The default of 2
    /// means one plan executing, one identifying — the classic double
    /// buffer.
    pub depth: usize,
    /// Planner worker threads. Claims are lookahead-bounded by `depth`,
    /// so workers beyond `depth` would only idle; the default caps them
    /// there (and at one below the pool size), bounding executor
    /// oversubscription to at most `depth` transient planner threads
    /// while plans are in flight.
    pub workers: usize,
}

impl Default for PlanPipeline {
    fn default() -> Self {
        let depth = 2;
        Self { depth, workers: num_threads().saturating_sub(1).clamp(1, depth) }
    }
}

/// Timing breakdown of one pipelined batch.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PipelineStats {
    /// Wall time workers spent identifying (planning), summed over items.
    pub ident_total_s: f64,
    /// Identification time hidden behind execution: `ident_total − stall`.
    pub ident_hidden_s: f64,
    /// Executor busy time, summed over heads.
    pub exec_total_s: f64,
    /// Time the executor spent blocked on the plan queue (unhidden
    /// identification: the first plan is always paid here).
    pub stall_s: f64,
    /// End-to-end batch wall time.
    pub wall_s: f64,
    /// Plan items that flowed through the queue (distinct keys, or heads
    /// when uncached).
    pub items: usize,
}

impl PipelineStats {
    /// Fraction of identification wall time hidden behind execution
    /// (`ident hidden / ident total`, in `[0, 1]`) — the pipeline's
    /// headline number: 1.0 means identification was entirely off the
    /// critical path.
    pub fn overlap_efficiency(&self) -> f64 {
        if self.ident_total_s <= 0.0 {
            0.0
        } else {
            (self.ident_hidden_s / self.ident_total_s).clamp(0.0, 1.0)
        }
    }
}

/// A pipelined batch run: the sequential-identical [`BatchOutput`] plus
/// the overlap accounting.
#[derive(Debug)]
pub struct PipelinedBatchOutput {
    pub batch: BatchOutput,
    pub stats: PipelineStats,
}

/// Pipelined batch execution against an explicit planner and executor
/// backend (the common entry point is a pipelined
/// [`crate::attention::session::AttentionSession`], which dispatches
/// here; tests inject failing planners directly). The drain stage runs on
/// the calling thread against `executor`, so any [`Executor`] backend —
/// CPU tile walk, PJRT gather, paged wrapper — slots under the pipeline
/// unchanged.
///
/// Identification work items are one per *distinct* key in first-seen
/// order (cached) or one per head (uncached) — exactly the work the
/// sequential `run_batch_inner` resolves, so plans, outputs, and
/// hit/ident accounting match it bit-for-bit.
pub fn run_planner_batch_pipelined(
    planner: &dyn Planner,
    batch: &BatchInput,
    cached: Option<(&PlanCache, &[PlanKey])>,
    spec: Option<&Speculator>,
    pipe: &PlanPipeline,
    executor: &dyn Executor,
) -> Result<PipelinedBatchOutput, String> {
    let h_total = batch.h();

    // Plan items and the item index each head waits on.
    let mut firsts: Vec<(Option<PlanKey>, usize)> = Vec::new();
    let mut item_of_head: Vec<usize> = Vec::with_capacity(h_total);
    match cached {
        Some((_, keys)) => {
            assert_eq!(keys.len(), h_total, "one PlanKey per head");
            for (h, &k) in keys.iter().enumerate() {
                match firsts.iter().position(|&(fk, _)| fk == Some(k)) {
                    Some(j) => item_of_head.push(j),
                    None => {
                        item_of_head.push(firsts.len());
                        firsts.push((Some(k), h));
                    }
                }
            }
        }
        None => {
            for h in 0..h_total {
                item_of_head.push(h);
                firsts.push((None, h));
            }
        }
    }
    let n_items = firsts.len();
    let workers = pipe.workers.max(1).min(n_items);

    // Item payload: the resolved plan, whether it was a cache hit, and the
    // wall time the worker spent resolving it.
    type Item = (Arc<SparsePlan>, bool, f64);
    let queue: OrderedBoundedQueue<Item> = OrderedBoundedQueue::new(n_items, pipe.depth);

    let plan_item = |j: usize| -> Result<Item, String> {
        let (key, h0) = firsts[j];
        let t0 = Instant::now();
        let planned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            match (cached, key) {
                // Misses route through the speculative reuse layer when
                // the session enabled one — same interposition as the
                // sequential path, so plans stay bitwise-identical
                // between the two dispatches.
                (Some((cache, _)), Some(k)) => cache.get_or_plan(k, || match spec {
                    Some(s) => s.resolve(cache, k, &batch.heads[h0]),
                    None => planner.plan(&batch.heads[h0]),
                }),
                _ => (Arc::new(planner.plan(&batch.heads[h0])), false),
            }
        }));
        match planned {
            Ok((head_plan, hit)) => Ok((head_plan, hit, t0.elapsed().as_secs_f64())),
            Err(e) => Err(panic_message(&*e)),
        }
    };

    let mut resolved: Vec<Option<(Arc<SparsePlan>, bool)>> = vec![None; n_items];
    let mut outputs: Vec<AttnOutput> = Vec::with_capacity(h_total);
    let mut stats = PipelineStats { items: n_items, ..Default::default() };
    let mut failure: Option<String> = None;
    let t_start = Instant::now();

    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                while let Some(j) = queue.claim() {
                    match plan_item(j) {
                        Ok(item) => queue.push(j, item),
                        Err(msg) => {
                            queue.poison(msg);
                            break;
                        }
                    }
                }
            });
        }

        // Executor (this thread): drain heads in order, popping plans in
        // submission order as they are needed. The guard poisons the queue
        // if execution unwinds, so planner workers never deadlock.
        let mut guard = PoisonOnDrop { queue: &queue, armed: true };
        let mut popped = 0usize;
        'heads: for h in 0..h_total {
            let j = item_of_head[h];
            while popped <= j {
                let t_wait = Instant::now();
                match queue.pop() {
                    Ok(Some((i, (head_plan, hit, plan_s)))) => {
                        stats.stall_s += t_wait.elapsed().as_secs_f64();
                        stats.ident_total_s += plan_s;
                        resolved[i] = Some((head_plan, hit));
                        popped = i + 1;
                    }
                    Ok(None) => break 'heads, // unreachable: popped < n_items
                    Err(msg) => {
                        failure = Some(msg);
                        break 'heads;
                    }
                }
            }
            let (head_plan, hit) = resolved[j].as_ref().expect("plans pop in order");
            let t_exec = Instant::now();
            let mut out = executor.execute(&batch.heads[h], head_plan);
            stats.exec_total_s += t_exec.elapsed().as_secs_f64();
            // The planning head of each fresh key pays its identification
            // cost — identical attribution to the sequential batched path.
            if !*hit && firsts[j].1 == h {
                out.cost.add(head_plan.ident_cost);
            }
            outputs.push(out);
        }
        // On failure the queue is already poisoned by the failing worker,
        // so the remaining workers exit through `claim` and the scope
        // joins cleanly.
        guard.armed = false;
    });

    stats.wall_s = t_start.elapsed().as_secs_f64();
    stats.ident_hidden_s = (stats.ident_total_s - stats.stall_s).max(0.0);
    if let Some(msg) = failure {
        return Err(msg);
    }

    let misses = resolved.iter().filter(|r| matches!(r, Some((_, false)))).count() as u64;
    let (cache_hits, cache_misses) = match cached {
        Some(_) => (h_total as u64 - misses, misses),
        None => (0, h_total as u64),
    };
    let plans: Vec<Arc<SparsePlan>> = item_of_head
        .iter()
        .map(|&j| resolved[j].as_ref().expect("all items resolved").0.clone())
        .collect();

    Ok(PipelinedBatchOutput {
        batch: BatchOutput { outputs, plans, cache_hits, cache_misses },
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::anchor::AnchorConfig;
    use crate::attention::exec::CpuTileExecutor;
    use crate::attention::{HeadInput, Method, TileConfig};
    use crate::tensor::Mat;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn anchor_method() -> Method {
        Method::Anchor(AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta: 4.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        })
    }

    #[test]
    fn pipelined_uncached_is_bitwise_sequential() {
        let heads: Vec<HeadInput> = (0..4).map(|i| rand_head(400 + i, 96, 8)).collect();
        let batch = BatchInput::new(heads);
        let m = anchor_method();
        let seq = m.session().no_cache().build().unwrap().run_batch(&batch).unwrap();
        let piped = m
            .session()
            .no_cache()
            .pipelined(true)
            .build()
            .unwrap()
            .run_batch(&batch)
            .unwrap();
        assert_eq!((piped.cache_hits, piped.cache_misses), (0, 4));
        for (h, (a, b)) in seq.outputs.iter().zip(&piped.outputs).enumerate() {
            assert_eq!(a.out.data, b.out.data, "head {h} output differs bitwise");
            assert_eq!(a.cost, b.cost, "head {h} cost differs");
        }
        let stats = piped.pipeline.expect("pipelined session reports stats");
        assert_eq!(stats.items, 4);
        assert!(stats.ident_total_s > 0.0);
        assert!(stats.wall_s > 0.0);
        let oe = stats.overlap_efficiency();
        assert!((0.0..=1.0).contains(&oe), "overlap efficiency {oe}");
    }

    #[test]
    fn pipelined_cached_matches_sequential_plans_and_accounting() {
        let shared = rand_head(500, 96, 8);
        let batch = BatchInput::new(vec![shared.clone(), shared.clone(), shared]);
        let keys =
            vec![PlanKey::new(0, 0), PlanKey::new(0, 0), PlanKey::new(0, 1)];
        let m = anchor_method();
        let mut seq_session = m.session().keys(keys.clone()).build().unwrap();
        let mut pipe_session = m.session().keys(keys).pipelined(true).build().unwrap();
        let seq = seq_session.run_batch(&batch).unwrap();
        let piped = pipe_session.run_batch(&batch).unwrap();
        assert_eq!(
            (seq.cache_hits, seq.cache_misses),
            (piped.cache_hits, piped.cache_misses)
        );
        // Heads of one key share a plan Arc, as in the sequential path.
        assert!(Arc::ptr_eq(&piped.plans[0], &piped.plans[1]));
        for (h, (a, b)) in seq.outputs.iter().zip(&piped.outputs).enumerate() {
            assert_eq!(a.out.data, b.out.data, "head {h} output differs bitwise");
            assert_eq!(a.cost, b.cost, "head {h} cost differs");
        }
        // Two distinct keys → two plan items through the queue.
        assert_eq!(piped.pipeline.unwrap().items, 2);
        // A second pipelined batch over the session's warm cache is all
        // hits and pays no identification.
        let warm = pipe_session.run_batch(&batch).unwrap();
        assert_eq!((warm.cache_hits, warm.cache_misses), (3, 0));
        assert_eq!(warm.ident_cost_paid.ident_scores, 0);
    }

    #[test]
    fn single_head_batch_flows_through_the_pipeline() {
        let batch = BatchInput::new(vec![rand_head(600, 64, 8)]);
        let m = anchor_method();
        let seq = m.session().no_cache().build().unwrap().run_batch(&batch).unwrap();
        let piped = m
            .session()
            .no_cache()
            .pipeline(PlanPipeline { depth: 1, workers: 1 })
            .build()
            .unwrap()
            .run_batch(&batch)
            .unwrap();
        assert_eq!(seq.outputs[0].out.data, piped.outputs[0].out.data);
        assert_eq!(seq.outputs[0].cost, piped.outputs[0].cost);
    }

    struct PanicPlanner;
    impl Planner for PanicPlanner {
        fn name(&self) -> &'static str {
            "panic-planner"
        }
        fn plan(&self, _input: &HeadInput) -> SparsePlan {
            panic!("identification exploded");
        }
    }

    #[test]
    fn panicked_planner_worker_surfaces_error_instead_of_deadlocking() {
        let heads: Vec<HeadInput> = (0..4).map(|i| rand_head(700 + i, 64, 8)).collect();
        let batch = BatchInput::new(heads);
        for workers in [1, 2] {
            let pipe = PlanPipeline { depth: 2, workers };
            let err = run_planner_batch_pipelined(
                &PanicPlanner,
                &batch,
                None,
                None,
                &pipe,
                &CpuTileExecutor::default(),
            )
            .expect_err("panicking planner must surface an error");
            assert!(err.contains("identification exploded"), "workers={workers}: {err}");
        }
    }
}

//! The plan IR: identification (Alg. 2) and sparse computation (Alg. 3) are
//! separable stages that communicate through *discrete stripe coordinates*,
//! so the engine splits every method into a [`Planner`] that emits a
//! [`SparsePlan`] and a swappable executor backend
//! ([`crate::attention::exec::Executor`], DESIGN.md §2/§10) that computes
//! exact softmax attention restricted to the plan. [`execute_plan`] is the
//! convenience entry bound to the default CPU backend.
//!
//! A plan is pure coordinates — per query-block-group anchor **spans**
//! (contiguous, always-computed regions) plus **stripes** (discrete key
//! columns, the paper's `(b_q·step, 1)` granularity) — so it can be cached,
//! shared across heads in a group ([`PlanCache`], the paper's cross-input
//! commonality, §3.2), analyzed ([`SparsePlan::coverage`] feeds the
//! recall/sparsity metrics without executing attention), and priced
//! ([`SparsePlan::predicted_cost`] mirrors the executors' tile walk
//! exactly — cost is a property of the coordinates, not of the backend).
//!
//! Multi-head execution ([`BatchInput`], driven through
//! [`crate::attention::session::AttentionSession::run_batch`]) parallelizes
//! at head granularity over the shared threadpool; the per-head executor
//! then runs serially so the pool is not oversubscribed.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::attention::exec::{CpuTileExecutor, Executor};
use crate::attention::mask::Coverage;
use crate::attention::{AttnOutput, CostTally, HeadInput, TileConfig};
use crate::tensor::{matmul_nt_scaled, Mat};

/// Plan entries for one query-block *group* (`step` consecutive query
/// blocks sharing one identification result, §3.4).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GroupPlan {
    /// Disjoint, sorted, non-adjacent `[start, end)` key ranges always
    /// computed for every block of the group; the executor clips each span
    /// to the block's causal limit and masks the diagonal tile.
    pub spans: Vec<(u32, u32)>,
    /// Sorted discrete key columns gathered for every block of the group
    /// (disjoint from `spans`). Columns at or past a block's diagonal are
    /// masked per row, so planners may share one stripe set group-wide.
    pub stripes: Vec<u32>,
}

impl GroupPlan {
    /// Number of key coordinates this group touches (spans + stripes).
    pub fn coords(&self) -> usize {
        let span: usize = self.spans.iter().map(|&(s, e)| (e - s) as usize).sum();
        span + self.stripes.len()
    }
}

/// The plan IR one [`Planner`] emits for one head: coordinates only, no
/// tensor data, so plans are cheap to cache and share.
#[derive(Clone, Debug, PartialEq)]
pub struct SparsePlan {
    /// Planner name (method identifier, for reports).
    pub method: &'static str,
    /// Sequence length the plan was built for.
    pub n: usize,
    pub tile: TileConfig,
    /// Query blocks per group (1 for per-block methods).
    pub step: usize,
    /// One entry per group, `ceil(q_blocks / step)` total.
    pub groups: Vec<GroupPlan>,
    /// Work spent building the plan (anchor scoring + identification).
    pub ident_cost: CostTally,
    /// Predicted execution cost — mirrors [`execute_plan`]'s tile walk
    /// exactly, so `predicted_cost == AttnOutput::cost` for a plan executed
    /// without its ident cost folded in.
    pub predicted_cost: CostTally,
}

impl SparsePlan {
    /// Assemble a plan and price it against head dim `d`.
    pub fn new(
        method: &'static str,
        n: usize,
        d: usize,
        tile: TileConfig,
        step: usize,
        groups: Vec<GroupPlan>,
        ident_cost: CostTally,
    ) -> SparsePlan {
        assert!(step >= 1);
        assert_eq!(groups.len(), tile.q_blocks(n).div_ceil(step), "group count");
        let mut plan = SparsePlan {
            method,
            n,
            tile,
            step,
            groups,
            ident_cost,
            predicted_cost: CostTally::default(),
        };
        plan.predicted_cost = plan.predict(d);
        plan
    }

    pub fn q_blocks(&self) -> usize {
        self.tile.q_blocks(self.n)
    }

    /// Group index of a query block.
    pub fn group_of(&self, qb: usize) -> usize {
        qb / self.step
    }

    /// Total stripes across groups (for reporting).
    pub fn total_stripes(&self) -> usize {
        self.groups.iter().map(|g| g.stripes.len()).sum()
    }

    /// The exact (query-block, key) pairs the executor will compute —
    /// recall/sparsity metrics are computed from this without running
    /// attention.
    pub fn coverage(&self) -> Coverage {
        let mut cov = Coverage::new(self.n, self.tile.b_q);
        for qb in 0..self.q_blocks() {
            let limit = ((qb + 1) * self.tile.b_q).min(self.n);
            let g = &self.groups[self.group_of(qb)];
            for &(s, e) in &g.spans {
                cov.set_range(qb, s as usize, (e as usize).min(limit));
            }
            cov.set_indices(qb, &g.stripes);
        }
        cov
    }

    /// Sparsity implied by the plan (fraction of causal pairs skipped).
    pub fn sparsity(&self) -> f64 {
        self.coverage().sparsity()
    }

    /// Walk the same tiles [`execute_plan`] will fold and tally their cost.
    fn predict(&self, d: usize) -> CostTally {
        let tile = self.tile;
        let n = self.n;
        let q_blocks = self.q_blocks();
        let mut cost = CostTally::default();
        for (gi, g) in self.groups.iter().enumerate() {
            let qb_start = gi * self.step;
            let qb_end = ((gi + 1) * self.step).min(q_blocks);
            // Stripe gather chunk sizes are fixed per group.
            let mut chunk_lens = Vec::new();
            let mut off = 0;
            while off < g.stripes.len() {
                let len = (g.stripes.len() - off).min(tile.b_kv);
                chunk_lens.push(len);
                off += len;
            }
            for qb in qb_start..qb_end {
                let row0 = qb * tile.b_q;
                let rows = (n - row0).min(tile.b_q);
                let limit = row0 + rows;
                for &(s, e) in &g.spans {
                    let end = (e as usize).min(limit);
                    let mut col0 = s as usize;
                    while col0 < end {
                        let cols = (end - col0).min(tile.b_kv);
                        cost.add(CostTally::attn_tile(rows, cols, d));
                        col0 += cols;
                    }
                }
                for &len in &chunk_lens {
                    cost.add(CostTally::attn_tile(rows, len, d));
                }
            }
        }
        cost
    }
}

/// A planner maps one head's Q/K (and its config) to a [`SparsePlan`].
/// Implemented by every method config; [`crate::attention::Method`]
/// dispatches to the matching planner.
pub trait Planner: Sync + Send {
    /// Method identifier (matches `Method::name`).
    fn name(&self) -> &'static str;
    /// Identify the plan for `input`.
    fn plan(&self, input: &HeadInput) -> SparsePlan;
}

/// Execute a plan on one head with the default CPU backend, parallelizing
/// over groups. The returned cost is the *execution* cost only — callers
/// fold `plan.ident_cost` in when reporting end-to-end method cost.
/// (The tile walk itself lives in [`CpuTileExecutor`]; sessions swap
/// backends via `SessionBuilder::executor`, DESIGN.md §11.)
pub fn execute_plan(input: &HeadInput, plan: &SparsePlan) -> AttnOutput {
    CpuTileExecutor::default().execute(input, plan)
}

/// Plan + execute + fold the identification cost into the reported tally —
/// the per-head primitive `AttentionSession::run` and the fused method
/// wrappers (`anchor_attention`, …) reduce to.
pub fn run_planner(input: &HeadInput, planner: &dyn Planner) -> AttnOutput {
    run_planner_with(input, planner, &CpuTileExecutor::default())
}

/// As [`run_planner`] on an explicit executor backend.
pub fn run_planner_with(
    input: &HeadInput,
    planner: &dyn Planner,
    executor: &dyn Executor,
) -> AttnOutput {
    let plan = planner.plan(input);
    let mut out = executor.execute(input, &plan);
    out.cost.add(plan.ident_cost);
    out
}

/// Build a step-1 plan from per-query-block *key block* lists (the shape
/// block-sparse baselines produce): adjacent blocks merge into spans,
/// acausal blocks are clipped.
pub fn plan_from_block_sets(
    method: &'static str,
    input: &HeadInput,
    tile: TileConfig,
    block_sets: &[Vec<u32>],
    ident_cost: CostTally,
) -> SparsePlan {
    let n = input.n();
    let q_blocks = tile.q_blocks(n);
    assert_eq!(block_sets.len(), q_blocks);
    let mut groups = Vec::with_capacity(q_blocks);
    for (qb, set) in block_sets.iter().enumerate() {
        let limit = ((qb + 1) * tile.b_q).min(n);
        // Clip, then sort before merging: callers usually pass sorted block
        // lists, but the contract (inherited from the fused kernel this
        // wraps) accepts any order and duplicates.
        let mut clipped: Vec<(u32, u32)> = set
            .iter()
            .map(|&jb| jb as usize * tile.b_kv)
            .filter(|&col0| col0 < limit)
            .map(|col0| (col0 as u32, ((col0 + tile.b_kv).min(limit)) as u32))
            .collect();
        clipped.sort_unstable();
        let mut spans: Vec<(u32, u32)> = Vec::with_capacity(clipped.len());
        for (s, e) in clipped {
            match spans.last_mut() {
                Some(last) if last.1 >= s => last.1 = last.1.max(e),
                _ => spans.push((s, e)),
            }
        }
        groups.push(GroupPlan { spans, stripes: Vec::new() });
    }
    SparsePlan::new(method, n, input.d(), tile, 1, groups, ident_cost)
}

/// Build a step-1 plan that gathers exactly the covered columns of an
/// arbitrary [`Coverage`] (the shape discrete-pattern baselines produce).
pub fn plan_from_coverage(
    method: &'static str,
    input: &HeadInput,
    tile: TileConfig,
    coverage: &Coverage,
    ident_cost: CostTally,
) -> SparsePlan {
    let n = input.n();
    assert_eq!(coverage.n, n);
    assert_eq!(coverage.b_q, tile.b_q);
    let q_blocks = tile.q_blocks(n);
    let mut groups = Vec::with_capacity(q_blocks);
    for qb in 0..q_blocks {
        let limit = ((qb + 1) * tile.b_q).min(n);
        let stripes: Vec<u32> =
            coverage.columns(qb).into_iter().filter(|&c| (c as usize) < limit).collect();
        groups.push(GroupPlan { spans: Vec::new(), stripes });
    }
    SparsePlan::new(method, n, input.d(), tile, 1, groups, ident_cost)
}

/// O(N²)-memory reference: exact softmax attention restricted to a
/// coverage (and causality), rows with no visible key output zero — the
/// semantics [`execute_plan`] must reproduce. Test/verification use only.
pub fn masked_reference(input: &HeadInput, coverage: &Coverage) -> Mat {
    let n = input.n();
    let d = input.d();
    let scale = input.scale();
    let mut s = Mat::zeros(n, n);
    matmul_nt_scaled(&input.q, &input.k, scale, &mut s);
    let mut out = Mat::zeros(n, d);
    for r in 0..n {
        let qb = r / coverage.b_q;
        let mut mx = f32::NEG_INFINITY;
        for c in 0..=r {
            if coverage.covered(qb, c) {
                mx = mx.max(s.at(r, c));
            }
        }
        if mx == f32::NEG_INFINITY {
            continue; // no visible key: zero row
        }
        let mut z = 0.0f32;
        for c in 0..=r {
            if coverage.covered(qb, c) {
                z += (s.at(r, c) - mx).exp();
            }
        }
        for c in 0..=r {
            if !coverage.covered(qb, c) {
                continue;
            }
            let p = (s.at(r, c) - mx).exp() / z;
            for col in 0..d {
                out.set(r, col, out.at(r, col) + p * input.v.at(c, col));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Batched multi-head execution
// ---------------------------------------------------------------------------

/// Multi-head input `[H, N, d]`: every head shares one sequence length and
/// head dim so plans are interchangeable within a head group (GQA-style).
#[derive(Clone, Debug)]
pub struct BatchInput {
    pub heads: Vec<HeadInput>,
}

impl BatchInput {
    pub fn new(heads: Vec<HeadInput>) -> Self {
        assert!(!heads.is_empty(), "empty batch");
        let (n, d) = (heads[0].n(), heads[0].d());
        for h in &heads {
            assert_eq!((h.n(), h.d()), (n, d), "ragged batch");
        }
        Self { heads }
    }

    pub fn h(&self) -> usize {
        self.heads.len()
    }

    pub fn n(&self) -> usize {
        self.heads[0].n()
    }

    pub fn d(&self) -> usize {
        self.heads[0].d()
    }
}

/// Per-head outputs plus the plan-cache interaction of the batch.
#[derive(Debug)]
pub struct BatchOutput {
    pub outputs: Vec<AttnOutput>,
    /// Plans used per head (cache-shared heads hold the same `Arc`).
    pub plans: Vec<Arc<SparsePlan>>,
    /// Cache hits within this batch (0 when run uncached).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl BatchOutput {
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

/// Cache key: heads of one `(layer, head_group)` cell share identification
/// work — the paper's cross-input commonality (§3.2) surfaced as plan reuse.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub layer: u32,
    pub head_group: u32,
}

impl PlanKey {
    pub fn new(layer: u32, head_group: u32) -> Self {
        Self { layer, head_group }
    }
}

/// Aggregate cache counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PlanCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl PlanCacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Thread-safe plan cache keyed by [`PlanKey`]. Concurrent misses on the
/// same key may both plan; the first insert wins and the duplicate is
/// dropped (plans are value-identical for identical inputs, so this is a
/// benign race traded for not holding the lock across planning).
#[derive(Default)]
pub struct PlanCache {
    map: Mutex<HashMap<PlanKey, Arc<SparsePlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a pre-built plan (e.g. warmed from a
    /// [`crate::runtime::manifest::PlanStore`]) without touching the
    /// hit/miss counters; an existing entry wins. The next `get_or_plan`
    /// on `key` is a hit that never re-identifies.
    pub fn seed(&self, key: PlanKey, plan: Arc<SparsePlan>) {
        self.map.lock().unwrap().entry(key).or_insert(plan);
    }

    /// Current entries as `(key, plan)` pairs in deterministic key order —
    /// the shape a persisting session syncs its plan store from after a
    /// run.
    pub fn snapshot(&self) -> Vec<(PlanKey, Arc<SparsePlan>)> {
        let mut out: Vec<(PlanKey, Arc<SparsePlan>)> =
            self.map.lock().unwrap().iter().map(|(k, p)| (*k, p.clone())).collect();
        out.sort_by_key(|(k, _)| (k.layer, k.head_group));
        out
    }

    /// Fetch the plan for `key`, building it with `build` on a miss.
    /// Returns the plan and whether it was a hit.
    pub fn get_or_plan(
        &self,
        key: PlanKey,
        build: impl FnOnce() -> SparsePlan,
    ) -> (Arc<SparsePlan>, bool) {
        if let Some(plan) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (plan.clone(), true);
        }
        let plan = Arc::new(build());
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().unwrap();
        let entry = map.entry(key).or_insert_with(|| plan.clone());
        (entry.clone(), false)
    }

    pub fn stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.lock().unwrap().len(),
        }
    }

    /// Drop all cached plans (e.g. at a layer boundary when keys are
    /// reused) without resetting the hit/miss counters.
    pub fn invalidate(&self) {
        self.map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::anchor::AnchorConfig;
    use crate::attention::full::naive_attention;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    /// Hand-built plan: one group of 2 blocks, an init span, a window span
    /// and mid-context stripes.
    fn mixed_plan(n: usize, d: usize) -> SparsePlan {
        let tile = TileConfig::new(16, 16);
        let q_blocks = tile.q_blocks(n);
        let step = 2;
        let groups: Vec<GroupPlan> = (0..q_blocks.div_ceil(step))
            .map(|g| {
                let win = (g * step * 16) as u32;
                let end = ((g + 1) * step * 16).min(n) as u32;
                if win == 0 {
                    GroupPlan { spans: vec![(0, end)], stripes: vec![] }
                } else {
                    let stripes: Vec<u32> = (16..win).step_by(3).collect();
                    GroupPlan { spans: vec![(0, 16), (win, end)], stripes }
                }
            })
            .collect();
        SparsePlan::new("test", n, d, tile, step, groups, CostTally::default())
    }

    #[test]
    fn full_span_plan_equals_dense() {
        let n = 160;
        let d = 8;
        let h = rand_head(41, n, d);
        let tile = TileConfig::new(16, 16);
        let groups: Vec<GroupPlan> = (0..tile.q_blocks(n))
            .map(|qb| GroupPlan {
                spans: vec![(0, (((qb + 1) * 16).min(n)) as u32)],
                stripes: vec![],
            })
            .collect();
        let plan = SparsePlan::new("full", n, d, tile, 1, groups, CostTally::default());
        let out = execute_plan(&h, &plan);
        let expect = naive_attention(&h);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
        assert_eq!(out.coverage.sparsity(), 0.0);
    }

    #[test]
    fn executor_matches_coverage_masked_softmax() {
        let n = 128;
        let d = 8;
        let h = rand_head(42, n, d);
        let plan = mixed_plan(n, d);
        let out = execute_plan(&h, &plan);
        let expect = masked_reference(&h, &out.coverage);
        assert!(
            out.out.max_abs_diff(&expect) < 1e-4,
            "max diff {}",
            out.out.max_abs_diff(&expect)
        );
    }

    #[test]
    fn serial_and_parallel_executors_agree() {
        let h = rand_head(43, 160, 8);
        let plan = mixed_plan(160, 8);
        let a = execute_plan(&h, &plan);
        let b = CpuTileExecutor { serial: true, ..Default::default() }.execute(&h, &plan);
        assert_eq!(a.cost, b.cost);
        assert!(a.out.max_abs_diff(&b.out) < 1e-6);
    }

    #[test]
    fn predicted_cost_equals_executed_cost() {
        let h = rand_head(44, 200, 8); // ragged tail block
        let plan = mixed_plan(200, 8);
        let out = execute_plan(&h, &plan);
        assert_eq!(plan.predicted_cost, out.cost);
    }

    #[test]
    fn anchor_planner_predicts_its_own_execution() {
        let h = rand_head(45, 256, 16);
        let cfg = AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta: 2.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        };
        let plan = Planner::plan(&cfg, &h);
        let out = execute_plan(&h, &plan);
        assert_eq!(plan.predicted_cost, out.cost);
        assert!(plan.ident_cost.ident_scores > 0);
    }

    #[test]
    fn stripes_at_or_past_diagonal_are_masked_per_row() {
        // Stripe on the diagonal block: rows before the stripe's position
        // must not see it.
        let n = 32;
        let d = 4;
        let h = rand_head(46, n, d);
        let tile = TileConfig::new(16, 16);
        let groups = vec![
            GroupPlan { spans: vec![(0, 16)], stripes: vec![] },
            // Block 1 (rows 16..32): stripe at col 24 (inside the block).
            GroupPlan { spans: vec![(0, 16)], stripes: vec![24] },
        ];
        let plan = SparsePlan::new("test", n, d, tile, 1, groups, CostTally::default());
        let out = execute_plan(&h, &plan);
        let expect = masked_reference(&h, &out.coverage);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn empty_plan_outputs_zero_rows() {
        let n = 32;
        let d = 4;
        let h = rand_head(47, n, d);
        let tile = TileConfig::new(16, 16);
        let groups = vec![GroupPlan::default(), GroupPlan::default()];
        let plan = SparsePlan::new("test", n, d, tile, 1, groups, CostTally::default());
        let out = execute_plan(&h, &plan);
        assert_eq!(out.cost.flops, 0);
        assert!(out.out.data.iter().all(|&x| x == 0.0));
        assert_eq!(out.coverage.sparsity(), 1.0);
    }

    #[test]
    fn chunking_invariant_to_bkv() {
        // Same coordinates, different kv tile width: outputs must match
        // (chunking is a pure implementation detail of the online softmax).
        let n = 128;
        let d = 8;
        let h = rand_head(48, n, d);
        let mk = |b_kv: usize| {
            let tile = TileConfig::new(16, b_kv);
            let groups: Vec<GroupPlan> = (0..8)
                .map(|qb| {
                    let limit = ((qb + 1) * 16) as u32;
                    let win = (qb * 16) as u32;
                    if win <= 8 {
                        GroupPlan { spans: vec![(0, limit)], stripes: vec![] }
                    } else {
                        let stripes: Vec<u32> = (8..win).step_by(5).collect();
                        GroupPlan { spans: vec![(0, 8), (win, limit)], stripes }
                    }
                })
                .collect();
            SparsePlan::new("test", n, d, tile, 1, groups, CostTally::default())
        };
        let o1 = execute_plan(&h, &mk(8));
        let o2 = execute_plan(&h, &mk(64));
        assert!(o1.out.max_abs_diff(&o2.out) < 1e-4);
        assert_eq!(o1.coverage.total_covered(), o2.coverage.total_covered());
    }

    #[test]
    fn plan_from_block_sets_merges_adjacent_blocks() {
        let h = rand_head(49, 64, 8);
        let tile = TileConfig::new(16, 16);
        let sets: Vec<Vec<u32>> = vec![vec![0], vec![0, 1], vec![0, 2], vec![0, 1, 3]];
        let plan = plan_from_block_sets("test", &h, tile, &sets, CostTally::default());
        assert_eq!(plan.groups[1].spans, vec![(0, 32)]);
        assert_eq!(plan.groups[2].spans, vec![(0, 16), (32, 48)]);
        assert_eq!(plan.groups[3].spans, vec![(0, 32), (48, 64)]);
        // Acausal block requests are clipped.
        let sets2: Vec<Vec<u32>> = vec![vec![0, 3], vec![0], vec![0], vec![0]];
        let plan2 = plan_from_block_sets("test", &h, tile, &sets2, CostTally::default());
        assert_eq!(plan2.groups[0].spans, vec![(0, 16)]);
        // Unsorted and duplicated block lists normalize to the same spans.
        let sets3: Vec<Vec<u32>> = vec![vec![0], vec![1, 0, 1], vec![2, 0], vec![3, 1, 0]];
        let plan3 = plan_from_block_sets("test", &h, tile, &sets3, CostTally::default());
        assert_eq!(plan3.groups[1].spans, vec![(0, 32)]);
        assert_eq!(plan3.groups[2].spans, vec![(0, 16), (32, 48)]);
        assert_eq!(plan3.groups[3].spans, vec![(0, 32), (48, 64)]);
    }

    #[test]
    fn plan_from_coverage_roundtrips_columns() {
        let h = rand_head(50, 64, 8);
        let tile = TileConfig::new(16, 16);
        let mut cov = Coverage::new(64, 16);
        cov.set_range(2, 0, 8);
        cov.set(2, 19);
        cov.set(2, 63); // acausal for qb 2 (limit 48): dropped from the plan
        let plan = plan_from_coverage("test", &h, tile, &cov, CostTally::default());
        assert_eq!(plan.groups[2].stripes, vec![0, 1, 2, 3, 4, 5, 6, 7, 19]);
        let out = execute_plan(&h, &plan);
        let expect = masked_reference(&h, &out.coverage);
        assert!(out.out.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn plan_cache_hits_and_misses_counted() {
        let h = rand_head(51, 64, 8);
        let cfg = AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta: 3.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        };
        let cache = PlanCache::new();
        let key = PlanKey::new(0, 0);
        let (p1, hit1) = cache.get_or_plan(key, || Planner::plan(&cfg, &h));
        let (p2, hit2) = cache.get_or_plan(key, || panic!("must not re-plan"));
        assert!(!hit1 && hit2);
        assert!(Arc::ptr_eq(&p1, &p2));
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (1, 1, 1));
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);

        let (_, hit3) = cache.get_or_plan(PlanKey::new(0, 1), || Planner::plan(&cfg, &h));
        assert!(!hit3);
        assert_eq!(cache.stats().entries, 2);
        cache.invalidate();
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn batch_input_shape_checked() {
        let a = rand_head(52, 32, 4);
        let b = rand_head(53, 32, 4);
        let batch = BatchInput::new(vec![a, b]);
        assert_eq!((batch.h(), batch.n(), batch.d()), (2, 32, 4));
    }

    #[test]
    #[should_panic(expected = "ragged batch")]
    fn ragged_batch_rejected() {
        let a = rand_head(54, 32, 4);
        let b = rand_head(55, 64, 4);
        BatchInput::new(vec![a, b]);
    }

    #[test]
    fn coverage_clips_spans_causally() {
        let plan = mixed_plan(128, 8);
        let cov = plan.coverage();
        // Block 0: the group span (0, 32) is clipped to the causal limit 16.
        assert_eq!(cov.count(0), 16);
        assert_eq!(cov.count(1), 32);
        // Block 2 (group 1): init span, window span and stripes {16,19,…}.
        assert!(cov.covered(2, 0) && cov.covered(2, 16) && cov.covered(2, 32));
        assert!(!cov.covered(2, 18)); // 18 ∉ stripes, ∉ spans
    }
}

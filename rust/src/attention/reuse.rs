//! Speculative plan reuse with recall-check fallback (DESIGN.md §17).
//!
//! The paper's core observation (§3.2) — attention patterns share
//! commonalities across inputs — is why a cheap anchor pass can predict
//! the stripe set at all. The [`crate::attention::plan::PlanCache`]
//! already exploits the *exact* form of that commonality (heads of one
//! `(layer, head_group)` cell share a plan); this module widens the
//! lookup to the *approximate* forms: a neighboring layer's plan for the
//! same geometry ([`ReusePolicy::CrossLayer`]) and a shared-prefix plan
//! extended by suffix-only identification ([`ReusePolicy::Prefix`]).
//!
//! A speculative donor is never served blind. The [`Speculator`] runs a
//! **recall check** — Alg. 2's anchor comparison restricted to a sampled
//! group subset (every [`RECALL_SAMPLE_STRIDE`]-th checkable group,
//! counted backward from the last, whose blocks alone pay the anchor `M`
//! pass) — and scores how much of the freshly identified stripe set the
//! donor's coverage retains. Below the policy's recall floor the
//! speculator falls back to full identification, so a stale donor can
//! degrade *speed* (the wasted check is folded into the plan's
//! `ident_cost`), never *correctness*: the fallback plan has exactly the
//! coordinates fresh identification produces, preserving the §11
//! never-serve-a-wrong-plan invariant.
//!
//! Accounting rides the existing machinery: an accepted speculative plan
//! is a fresh [`SparsePlan`] carrying the donor's coordinates but an
//! `ident_cost` equal to the check work actually paid, so the session's
//! `ident_cost_paid` attribution and the scheduler's pricing see the
//! saving without any new plumbing through the executors.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::attention::anchor::compute::anchor_m_pass_for_blocks;
use crate::attention::anchor::identify::identify_stripes_for_groups;
use crate::attention::anchor::AnchorConfig;
use crate::attention::plan::{PlanCache, PlanKey, SparsePlan};
use crate::attention::{CostTally, HeadInput};

/// Sampling rule of the recall check: every stride-th checkable group,
/// counted backward from the last (recent groups see the most context,
/// so drift shows up there first; the last checkable group is always
/// sampled). `bench reuse` measures the check-cost fraction this yields.
pub const RECALL_SAMPLE_STRIDE: usize = 4;

/// Default recall floor: accept a donor when the sampled fresh stripes
/// are ≥ this covered. Measured, not guessed — `bench reuse` sweeps
/// layer distance vs. recall and reports the accept rate at this floor.
pub const DEFAULT_RECALL_FLOOR: f64 = 0.75;

/// Default cross-layer probe distance (`layer ± k`).
pub const DEFAULT_MAX_DISTANCE: u32 = 1;

/// How a session widens plan-cache/store lookup on a miss
/// (`SessionBuilder::reuse`, DESIGN.md §17).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReusePolicy {
    /// Serve cached plans only under their exact key — the pre-reuse
    /// behavior, bitwise-identical to it by construction.
    Exact,
    /// On a miss, probe `layer ± k` (nearest first, lower layer first)
    /// for an equal-length same-geometry plan of the same head group;
    /// serve it if the recall check clears `recall_floor`.
    CrossLayer { max_distance: u32, recall_floor: f64 },
    /// On a miss, probe shared-prefix donors: a shorter plan under the
    /// same key (extended by identifying only the suffix groups), or an
    /// equal-length same-layer plan of another head group (the PR 9
    /// workload `reuse_key` plumbing keys shared-prefix streams apart).
    Prefix { recall_floor: f64 },
}

impl ReusePolicy {
    pub fn cross_layer() -> Self {
        ReusePolicy::CrossLayer {
            max_distance: DEFAULT_MAX_DISTANCE,
            recall_floor: DEFAULT_RECALL_FLOOR,
        }
    }

    pub fn prefix() -> Self {
        ReusePolicy::Prefix { recall_floor: DEFAULT_RECALL_FLOOR }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "exact" => Ok(ReusePolicy::Exact),
            "cross-layer" => Ok(ReusePolicy::cross_layer()),
            "prefix" => Ok(ReusePolicy::prefix()),
            other => Err(anyhow!(
                "unknown reuse policy '{other}' (expected exact|cross-layer|prefix)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReusePolicy::Exact => "exact",
            ReusePolicy::CrossLayer { .. } => "cross-layer",
            ReusePolicy::Prefix { .. } => "prefix",
        }
    }

    pub fn is_exact(&self) -> bool {
        matches!(self, ReusePolicy::Exact)
    }

    /// Policy with the recall floor replaced (no-op for `exact`).
    pub fn with_recall_floor(self, floor: f64) -> Self {
        match self {
            ReusePolicy::Exact => ReusePolicy::Exact,
            ReusePolicy::CrossLayer { max_distance, .. } => {
                ReusePolicy::CrossLayer { max_distance, recall_floor: floor }
            }
            ReusePolicy::Prefix { .. } => ReusePolicy::Prefix { recall_floor: floor },
        }
    }

    fn recall_floor(&self) -> f64 {
        match self {
            ReusePolicy::Exact => 1.0,
            ReusePolicy::CrossLayer { recall_floor, .. }
            | ReusePolicy::Prefix { recall_floor } => *recall_floor,
        }
    }
}

/// Count of common elements of two sorted stripe lists (two-pointer).
fn intersect_count(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut common) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                common += 1;
                i += 1;
                j += 1;
            }
        }
    }
    common
}

/// The speculative resolver a non-`exact` session interposes between a
/// plan-cache miss and fresh identification. Anchor-method only (the
/// recall check *is* Alg. 2 on a sample); `SessionBuilder::build`
/// enforces that. Public only so it can appear in the pipeline entry
/// point's signature — construction and use are crate-internal.
pub struct Speculator {
    policy: ReusePolicy,
    cfg: AnchorConfig,
    /// Shorter-length prefix donors: adopted from the cache on a length
    /// change (before invalidation) and seeded from the plan store's
    /// widened lookup. Equal-length donors come from the live cache.
    donors: Mutex<Vec<(PlanKey, Arc<SparsePlan>)>>,
    hits: AtomicU64,
    fallbacks: AtomicU64,
    recall_sum: Mutex<f64>,
}

impl Speculator {
    pub(crate) fn new(policy: ReusePolicy, cfg: AnchorConfig) -> Self {
        Self {
            policy,
            cfg,
            donors: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
            recall_sum: Mutex::new(0.0),
        }
    }

    /// Reset the per-run counters (the session calls this at the top of
    /// `run`/`run_batch`; [`Speculator::take_run_stats`] reads them
    /// after).
    pub(crate) fn begin_run(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.fallbacks.store(0, Ordering::Relaxed);
        *self.recall_sum.lock().unwrap() = 0.0;
    }

    /// `(speculative_hits, speculative_fallbacks, mean recall)` since the
    /// last [`Speculator::begin_run`].
    pub(crate) fn take_run_stats(&self) -> (u64, u64, Option<f64>) {
        let hits = self.hits.load(Ordering::Relaxed);
        let fallbacks = self.fallbacks.load(Ordering::Relaxed);
        let checks = hits + fallbacks;
        let recall = (checks > 0).then(|| *self.recall_sum.lock().unwrap() / checks as f64);
        (hits, fallbacks, recall)
    }

    /// Adopt the cache's current entries as shorter-length prefix donors
    /// (called on a length change, before the cache is invalidated).
    pub(crate) fn adopt_donors(&self, snapshot: Vec<(PlanKey, Arc<SparsePlan>)>) {
        if !matches!(self.policy, ReusePolicy::Prefix { .. }) {
            return;
        }
        self.donors.lock().unwrap().extend(snapshot);
    }

    /// Seed one prefix donor (the plan store's widened lookup files
    /// shorter compatible plans here during cache warm-up).
    pub(crate) fn seed_donor(&self, key: PlanKey, plan: Arc<SparsePlan>) {
        self.donors.lock().unwrap().push((key, plan));
    }

    fn compatible(&self, p: &SparsePlan) -> bool {
        p.method == "anchor" && p.tile == self.cfg.tile && p.step == self.cfg.step
    }

    /// Can a shorter donor cover at least one complete group (rows and
    /// candidate columns inside its prefix, init region included)?
    fn prefix_usable(&self, donor: &SparsePlan, n: usize) -> bool {
        donor.n >= self.cfg.step * self.cfg.tile.b_q && donor.n >= self.cfg.init_cols(n)
    }

    /// Number of leading groups whose coordinates a donor vouches for:
    /// all of them for an equal-length donor, else the contiguous prefix
    /// of groups whose pooled rows (and therefore candidate columns,
    /// which end before the rows) lie fully inside the donor's length.
    fn reusable_groups(&self, donor: &SparsePlan, n: usize, n_groups: usize) -> usize {
        if donor.n == n {
            return n_groups;
        }
        let rows_per_group = self.cfg.step * self.cfg.tile.b_q;
        (0..n_groups).take_while(|&g| (g + 1) * rows_per_group <= donor.n).count()
    }

    /// Pick the donor to recall-check for a missed `key`, or `None` for
    /// a plain miss. Deterministic: the cache snapshot is key-sorted and
    /// the donor list is probed by (same-key, largest length) first.
    fn find_donor(&self, cache: &PlanCache, key: PlanKey, n: usize) -> Option<Arc<SparsePlan>> {
        match self.policy {
            ReusePolicy::Exact => None,
            ReusePolicy::CrossLayer { max_distance, .. } => {
                let snap = cache.snapshot();
                for dist in 1..=max_distance {
                    // Lower layer first: in a forward pass it is the one
                    // already computed.
                    for layer in [key.layer.checked_sub(dist), key.layer.checked_add(dist)]
                    {
                        let Some(layer) = layer else { continue };
                        if let Some((_, p)) = snap.iter().find(|(k, p)| {
                            k.layer == layer
                                && k.head_group == key.head_group
                                && p.n == n
                                && self.compatible(p)
                        }) {
                            return Some(p.clone());
                        }
                    }
                }
                None
            }
            ReusePolicy::Prefix { .. } => {
                let donors = self.donors.lock().unwrap();
                // 1. A shorter plan under the same key: this stream's own
                //    prefix, extended by suffix identification.
                if let Some((_, p)) = donors
                    .iter()
                    .filter(|(k, p)| {
                        *k == key && p.n < n && self.compatible(p) && self.prefix_usable(p, n)
                    })
                    .max_by_key(|(_, p)| p.n)
                {
                    return Some(p.clone());
                }
                // 2. An equal-length same-layer plan of another head group
                //    from the live cache (shared-prefix streams).
                let snap = cache.snapshot();
                if let Some((_, p)) = snap.iter().find(|(k, p)| {
                    k.layer == key.layer && *k != key && p.n == n && self.compatible(p)
                }) {
                    return Some(p.clone());
                }
                // 3. A shorter same-layer donor from any head group.
                if let Some((_, p)) = donors
                    .iter()
                    .filter(|(k, p)| {
                        k.layer == key.layer
                            && p.n < n
                            && self.compatible(p)
                            && self.prefix_usable(p, n)
                    })
                    .max_by_key(|(_, p)| p.n)
                {
                    return Some(p.clone());
                }
                None
            }
        }
    }

    /// Resolve a plan for a missed `key`: recall-check a donor when one
    /// exists, else identify fresh. Runs inside the cache's
    /// `get_or_plan` builder (outside its lock), so reading the cache
    /// snapshot here is deadlock-free.
    pub(crate) fn resolve(
        &self,
        cache: &PlanCache,
        key: PlanKey,
        input: &HeadInput,
    ) -> SparsePlan {
        match self.find_donor(cache, key, input.n()) {
            Some(donor) => self.check_and_build(&donor, input),
            None => self.cfg.plan_timed(input).0,
        }
    }

    /// The query blocks of the given groups (the rows the recall check /
    /// suffix identification must score).
    fn blocks_of(&self, groups: impl Iterator<Item = usize>, q_blocks: usize) -> Vec<usize> {
        let mut blocks = Vec::new();
        for g in groups {
            blocks.extend(g * self.cfg.step..((g + 1) * self.cfg.step).min(q_blocks));
        }
        blocks
    }

    /// Recall-check `donor` against fresh identification on the sampled
    /// group subset; on acceptance assemble a plan from the donor's
    /// coordinates (suffix groups identified fresh for a shorter donor),
    /// on rejection fall back to full identification with the wasted
    /// check folded into `ident_cost`.
    fn check_and_build(&self, donor: &SparsePlan, input: &HeadInput) -> SparsePlan {
        let cfg = &self.cfg;
        let n = input.n();
        let d = input.d();
        let q_blocks = cfg.tile.q_blocks(n);
        let n_groups = q_blocks.div_ceil(cfg.step);
        let reusable = self.reusable_groups(donor, n, n_groups);

        // Sampled subset of the checkable groups (reusable groups with a
        // non-empty candidate range; the rest have structural coordinates
        // the donor cannot get wrong).
        let checkable: Vec<usize> = (0..reusable)
            .filter(|&g| {
                let (s, e) = cfg.candidate_range(g, n);
                s < e
            })
            .collect();
        let mut sampled: Vec<usize> =
            checkable.iter().rev().copied().step_by(RECALL_SAMPLE_STRIDE).collect();
        sampled.reverse();

        let mut paid = CostTally::default();
        let (fresh, recall) = if sampled.is_empty() {
            (Vec::new(), 1.0)
        } else {
            let m = if cfg.use_anchor {
                let blocks = self.blocks_of(sampled.iter().copied(), q_blocks);
                let (m, m_cost) = anchor_m_pass_for_blocks(input, cfg, &blocks);
                paid.add(m_cost);
                m
            } else {
                Vec::new()
            };
            let (fresh, check_cost) = identify_stripes_for_groups(input, cfg, &m, &sampled);
            paid.add(check_cost);
            let mut fresh_total = 0usize;
            let mut covered = 0usize;
            for (sel, &g) in fresh.iter().zip(&sampled) {
                fresh_total += sel.len();
                covered += intersect_count(sel, &donor.groups[g].stripes);
            }
            let recall =
                if fresh_total == 0 { 1.0 } else { covered as f64 / fresh_total as f64 };
            (fresh, recall)
        };
        drop(fresh);

        *self.recall_sum.lock().unwrap() += recall;
        if recall < self.policy.recall_floor() {
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
            let mut plan = cfg.plan_timed(input).0;
            // The wasted check is real paid work — fold it into the
            // plan's identification cost so `ident_cost_paid` (and the
            // scheduler pricing downstream) stays honest.
            plan.ident_cost.add(paid);
            return plan;
        }

        self.hits.fetch_add(1, Ordering::Relaxed);
        let mut stripes: Vec<Vec<u32>> = Vec::with_capacity(n_groups);
        for g in 0..reusable {
            stripes.push(donor.groups[g].stripes.clone());
        }
        if reusable < n_groups {
            // Prefix extension: identify only the suffix groups, with the
            // anchor pass restricted to their blocks.
            let suffix: Vec<usize> = (reusable..n_groups).collect();
            let m = if cfg.use_anchor {
                let blocks = self.blocks_of(suffix.iter().copied(), q_blocks);
                let (m, m_cost) = anchor_m_pass_for_blocks(input, cfg, &blocks);
                paid.add(m_cost);
                m
            } else {
                Vec::new()
            };
            let (suffix_sel, suffix_cost) = identify_stripes_for_groups(input, cfg, &m, &suffix);
            paid.add(suffix_cost);
            stripes.extend(suffix_sel);
        }
        cfg.assemble_plan(n, d, stripes, paid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::plan::Planner;
    use crate::attention::TileConfig;
    use crate::tensor::Mat;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn small_cfg() -> AnchorConfig {
        AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta: 4.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        }
    }

    #[test]
    fn policy_parses_and_names_roundtrip() {
        for name in ["exact", "cross-layer", "prefix"] {
            assert_eq!(ReusePolicy::parse(name).unwrap().name(), name);
        }
        assert!(ReusePolicy::parse("fuzzy").is_err());
        assert!(ReusePolicy::Exact.is_exact());
        assert!(!ReusePolicy::prefix().is_exact());
        let p = ReusePolicy::cross_layer().with_recall_floor(0.5);
        assert_eq!(p, ReusePolicy::CrossLayer { max_distance: 1, recall_floor: 0.5 });
    }

    #[test]
    fn intersect_counts_sorted_overlap() {
        assert_eq!(intersect_count(&[1, 3, 5, 9], &[2, 3, 4, 5, 6]), 2);
        assert_eq!(intersect_count(&[], &[1, 2]), 0);
        assert_eq!(intersect_count(&[7], &[7]), 1);
    }

    /// An identical-input donor passes the recall check with recall 1.0
    /// and the accepted plan's coordinates equal fresh identification's,
    /// at strictly lower identification cost.
    #[test]
    fn identical_donor_accepted_with_full_recall_and_cheaper_ident() {
        let cfg = small_cfg();
        let h = rand_head(60, 256, 8);
        let fresh = Planner::plan(&cfg, &h);
        let spec = Speculator::new(ReusePolicy::cross_layer(), cfg);
        let cache = PlanCache::new();
        cache.seed(PlanKey::new(0, 0), Arc::new(fresh.clone()));
        let plan = spec.resolve(&cache, PlanKey::new(1, 0), &h);
        let (hits, fallbacks, recall) = spec.take_run_stats();
        assert_eq!((hits, fallbacks), (1, 0));
        assert_eq!(recall, Some(1.0));
        for (a, b) in plan.groups.iter().zip(&fresh.groups) {
            assert_eq!(a, b);
        }
        assert_eq!(plan.predicted_cost, fresh.predicted_cost);
        assert!(
            plan.ident_cost.ident_scores < fresh.ident_cost.ident_scores,
            "check {} !< full {}",
            plan.ident_cost.ident_scores,
            fresh.ident_cost.ident_scores
        );
    }

    /// A deliberately wrong donor fails the check; the fallback plan is
    /// coordinate-equal to fresh identification and pays check + full
    /// ident. Deterministic by construction: `theta = ∞` makes fresh
    /// identification select *every* candidate column, so an
    /// empty-stripe donor scores recall exactly 0 on any sampled group.
    #[test]
    fn wrong_donor_falls_back_to_fresh_coordinates() {
        let cfg = AnchorConfig { theta: f32::INFINITY, ..small_cfg() };
        let h = rand_head(61, 256, 8);
        let fresh = Planner::plan(&cfg, &h);
        assert!(fresh.total_stripes() > 0, "test needs a non-trivial selection");
        let mut wrong = fresh.clone();
        for grp in wrong.groups.iter_mut() {
            grp.stripes.clear();
        }
        let spec = Speculator::new(
            ReusePolicy::CrossLayer { max_distance: 1, recall_floor: 0.99 },
            cfg,
        );
        let cache = PlanCache::new();
        cache.seed(PlanKey::new(0, 0), Arc::new(wrong));
        let plan = spec.resolve(&cache, PlanKey::new(1, 0), &h);
        let (hits, fallbacks, _) = spec.take_run_stats();
        assert_eq!((hits, fallbacks), (0, 1));
        for (a, b) in plan.groups.iter().zip(&fresh.groups) {
            assert_eq!(a, b, "fallback must serve fresh coordinates");
        }
        assert!(plan.ident_cost.ident_scores > fresh.ident_cost.ident_scores);
    }

    /// A wrong-length donor is structurally skipped by cross-layer
    /// lookup: plain miss, no check, no fallback.
    #[test]
    fn cross_layer_skips_wrong_length_donors() {
        let cfg = small_cfg();
        let short = rand_head(62, 128, 8);
        let h = rand_head(63, 256, 8);
        let spec = Speculator::new(ReusePolicy::cross_layer(), cfg);
        let cache = PlanCache::new();
        cache.seed(PlanKey::new(0, 0), Arc::new(Planner::plan(&cfg, &short)));
        let plan = spec.resolve(&cache, PlanKey::new(1, 0), &h);
        assert_eq!(spec.take_run_stats(), (0, 0, None));
        assert_eq!(plan, Planner::plan(&cfg, &h));
    }

    /// Prefix extension: a shorter same-key donor built from the same
    /// prefix rows yields exactly the coordinates fresh identification
    /// finds, at lower cost (suffix-only identification).
    #[test]
    fn prefix_donor_extends_to_fresh_coordinates() {
        let cfg = small_cfg();
        let n_full = 256;
        let n_prefix = 128;
        let full = rand_head(64, n_full, 8);
        let prefix = HeadInput::new(
            full.q.rows_mat(0, n_prefix),
            full.k.rows_mat(0, n_prefix),
            full.v.rows_mat(0, n_prefix),
        );
        let donor = Planner::plan(&cfg, &prefix);
        let fresh = Planner::plan(&cfg, &full);
        let spec = Speculator::new(ReusePolicy::prefix(), cfg);
        spec.seed_donor(PlanKey::new(0, 0), Arc::new(donor));
        let cache = PlanCache::new();
        let plan = spec.resolve(&cache, PlanKey::new(0, 0), &full);
        let (hits, fallbacks, recall) = spec.take_run_stats();
        assert_eq!((hits, fallbacks), (1, 0), "recall {recall:?}");
        for (g, (a, b)) in plan.groups.iter().zip(&fresh.groups).enumerate() {
            assert_eq!(a, b, "group {g}");
        }
        assert!(plan.ident_cost.ident_scores < fresh.ident_cost.ident_scores);
    }

    /// A donor too short to cover one complete group is never picked.
    #[test]
    fn useless_prefix_donor_is_skipped() {
        let cfg = small_cfg();
        let tiny = rand_head(65, 16, 8); // one block < step*b_q = 32
        let h = rand_head(66, 128, 8);
        let spec = Speculator::new(ReusePolicy::prefix(), cfg);
        spec.seed_donor(PlanKey::new(0, 0), Arc::new(Planner::plan(&cfg, &tiny)));
        let plan = spec.resolve(&PlanCache::new(), PlanKey::new(0, 0), &h);
        assert_eq!(spec.take_run_stats(), (0, 0, None));
        assert_eq!(plan, Planner::plan(&cfg, &h));
    }
}

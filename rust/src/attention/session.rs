//! [`AttentionSession`] — the single entry point to the attention engine.
//!
//! Three PRs of growth (Planner → [`SparsePlan`] → Executor split, the
//! async plan pipeline, pluggable backends) each added an orthogonal knob
//! to [`Method`], leaving a ten-function `run_*` matrix that every new
//! axis would double again. The session collapses that matrix: a
//! [`SessionBuilder`] fixes the knobs once —
//!
//! ```ignore
//! let mut session = AttentionSession::builder(method)
//!     .executor(ExecutorKind::Pjrt)
//!     .cache(PlanCache::default())
//!     .pipelined(true)
//!     .persist("artifacts/manifest.json")
//!     .build()?;
//! let out = session.run_batch(&batch)?;
//! ```
//!
//! — and exactly two run methods ([`AttentionSession::run`],
//! [`AttentionSession::run_batch`]) dispatch the cached / pipelined /
//! backend variants internally, returning a [`SessionOutput`] that unifies
//! the per-head and batched results with hit-rate, identification-cost and
//! [`PipelineStats`] accounting.
//!
//! The session also *owns* plan persistence: built with `persist(path)`,
//! it warms its [`PlanCache`] from the runtime manifest's
//! [`PlanStore`] at first use (per sequence length) and files fresh plans
//! back, so the paper's identification amortization (§3.2 cross-input
//! commonality) extends across process restarts — a restarted process
//! reports a plan-cache hit on the first batch for a previously seen
//! `(model, layer, head_group, n)` key. `flush` (or drop) writes the
//! store back. Lifecycle: **build → warm-from-store → run → flush**
//! (DESIGN.md §11).
//!
//! Misconfiguration fails at `build()`, never at run time: a pipelined
//! session on the serial CPU walk, a persistence path without a runtime
//! manifest, and persistence with the cache disabled are all rejected
//! with descriptive errors.
//!
//! Caveat on cache keys: the [`PlanCache`] is keyed by `(layer,
//! head_group)` — an *exact-policy* session reusing those keys across
//! unrelated inputs would serve stale plans, so sessions running
//! arbitrary per-head inputs (experiments, latency probes) should
//! `no_cache()`, and cached sessions are for serving-shaped workloads
//! where a key names a stable GQA cell. `SessionBuilder::reuse` widens
//! the lookup *deliberately* (cross-layer / shared-prefix speculation,
//! DESIGN.md §17), but unlike a key collision every widened serve is
//! guarded by a recall check that falls back to fresh identification —
//! staleness there degrades speed, never coordinates.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::attention::exec::{CpuTileExecutor, Executor, ExecutorKind, PjrtGatherExecutor};
use crate::attention::pipeline::{run_planner_batch_pipelined, PipelineStats, PlanPipeline};
use crate::attention::plan::{
    BatchInput, BatchOutput, PlanCache, PlanCacheStats, PlanKey, SparsePlan,
};
use crate::attention::reuse::{ReusePolicy, Speculator};
use crate::attention::{AttnOutput, CostTally, HeadInput, Method};
use crate::runtime::manifest::{PlanStore, PlanStoreKey};

/// How a session assigns [`PlanKey`]s to the heads of a batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KeyPolicy {
    /// `keys[h] = (layer, h / group_size)` — GQA-style grouping; the
    /// default (`layer = 0, group_size = 1`) gives every head its own key.
    Gqa { layer: u32, group_size: usize },
    /// Explicit per-head keys; `run_batch` rejects batches whose head
    /// count disagrees.
    Explicit(Vec<PlanKey>),
}

impl KeyPolicy {
    pub(crate) fn keys_for(&self, heads: usize) -> Result<Vec<PlanKey>> {
        match self {
            KeyPolicy::Gqa { layer, group_size } => Ok((0..heads)
                .map(|h| PlanKey::new(*layer, (h / group_size) as u32))
                .collect()),
            KeyPolicy::Explicit(keys) => {
                if keys.len() != heads {
                    return Err(anyhow!(
                        "session has {} explicit plan keys but the batch has {heads} heads",
                        keys.len()
                    ));
                }
                Ok(keys.clone())
            }
        }
    }

    fn key_of(&self, h: usize) -> Result<PlanKey> {
        match self {
            KeyPolicy::Gqa { layer, group_size } => {
                Ok(PlanKey::new(*layer, (h / group_size) as u32))
            }
            KeyPolicy::Explicit(keys) => keys.get(h).copied().ok_or_else(|| {
                anyhow!("head {h} has no explicit plan key ({} configured)", keys.len())
            }),
        }
    }
}

/// How a sharded session reaches its shard workers (DESIGN.md §14):
/// in-process threads (the default), or spawned worker processes behind
/// the coordinate-only wire protocol ([`crate::wire`]). Output is
/// bitwise-identical either way — this is a deployment knob, not a
/// semantic one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SessionTransport {
    #[default]
    Threads,
    /// One child worker process per shard (`anchor-attn worker`),
    /// dispatched over Unix domain sockets.
    Process,
}

impl SessionTransport {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "threads" => Ok(SessionTransport::Threads),
            "process" => Ok(SessionTransport::Process),
            other => Err(anyhow!("unknown transport '{other}' (expected threads|process)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SessionTransport::Threads => "threads",
            SessionTransport::Process => "process",
        }
    }
}

/// Declarative session settings — the config file's `"session"` block and
/// the CLI flags behind it. [`SessionConfig::builder`] turns them into a
/// [`SessionBuilder`] for a concrete method.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionConfig {
    pub executor: ExecutorKind,
    pub pipelined: bool,
    /// Enable the session-owned [`PlanCache`] (on by default; persistence
    /// requires it).
    pub cache: bool,
    /// Runtime-manifest path plans persist into (`--plan-store`).
    pub plan_store: Option<String>,
    /// Model identifier plans are keyed under in the store.
    pub model: String,
    /// Head-group shard workers (`--shards`, DESIGN.md §12); 1 = the
    /// unsharded session.
    pub shards: usize,
    /// Optional cap on persisted plans (`"store_max_entries"`): the plan
    /// store evicts LRU-ish past it, loudly.
    pub store_max_entries: Option<usize>,
    /// Shard-worker transport (`"transport"` / `--transport`, DESIGN.md
    /// §14): threads in-process, or spawned worker processes over the
    /// wire.
    pub transport: SessionTransport,
    /// Speculative plan-reuse policy (`"reuse"` / `--reuse`, DESIGN.md
    /// §17): `exact` (default, pre-reuse behavior), `cross-layer`, or
    /// `prefix`.
    pub reuse: ReusePolicy,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            executor: ExecutorKind::Cpu,
            pipelined: false,
            cache: true,
            plan_store: None,
            model: "default".to_string(),
            shards: 1,
            store_max_entries: None,
            transport: SessionTransport::Threads,
            reuse: ReusePolicy::Exact,
        }
    }
}

impl SessionConfig {
    /// A builder for `method` with this config applied (`shards` is not
    /// consumed here — a single `AttentionSession` is the shard worker;
    /// use [`SessionConfig::sharded_builder`] for the sharded front end).
    pub fn builder(&self, method: Method) -> SessionBuilder {
        let mut b = AttentionSession::builder(method)
            .executor(self.executor)
            .pipelined(self.pipelined)
            .model(&self.model)
            .reuse(self.reuse);
        if !self.cache {
            b = b.no_cache();
        }
        if let Some(p) = &self.plan_store {
            b = b.persist(p);
        }
        if let Some(cap) = self.store_max_entries {
            b = b.store_max_entries(cap);
        }
        b
    }

    /// A sharded-session builder for `method` with this config applied,
    /// including the `shards` count (DESIGN.md §12) and the worker
    /// transport (DESIGN.md §14).
    pub fn sharded_builder(
        &self,
        method: Method,
    ) -> crate::attention::shard::ShardedSessionBuilder {
        let mut b = crate::attention::shard::ShardedSession::builder(method, self.shards)
            .executor(self.executor)
            .pipelined(self.pipelined)
            .model(&self.model)
            .reuse(self.reuse);
        if self.transport == SessionTransport::Process {
            b = b.remote(crate::wire::RemoteSpec::Spawn { program: None });
        }
        if !self.cache {
            b = b.no_cache();
        }
        if let Some(p) = &self.plan_store {
            b = b.persist(p);
        }
        if let Some(cap) = self.store_max_entries {
            b = b.store_max_entries(cap);
        }
        b
    }
}

/// Builder for [`AttentionSession`]; every knob of the old `run_*` matrix
/// is set here exactly once. Misconfiguration fails at
/// [`SessionBuilder::build`] with a descriptive error, never at run time.
pub struct SessionBuilder {
    method: Method,
    executor: ExecutorKind,
    serial_cpu: bool,
    cache: Option<Arc<PlanCache>>,
    keys: KeyPolicy,
    pipelined: bool,
    pipeline: PlanPipeline,
    persist: Option<PathBuf>,
    model: String,
    store_cap: Option<usize>,
    shard_worker: bool,
    reuse: ReusePolicy,
}

impl SessionBuilder {
    fn new(method: Method) -> Self {
        Self {
            method,
            executor: ExecutorKind::Cpu,
            serial_cpu: false,
            cache: Some(Arc::new(PlanCache::new())),
            keys: KeyPolicy::Gqa { layer: 0, group_size: 1 },
            pipelined: false,
            pipeline: PlanPipeline::default(),
            persist: None,
            model: "default".to_string(),
            store_cap: None,
            shard_worker: false,
            reuse: ReusePolicy::Exact,
        }
    }

    /// Executor backend (`cpu` | `pjrt`).
    pub fn executor(mut self, kind: ExecutorKind) -> Self {
        self.executor = kind;
        self
    }

    /// Run the CPU tile walk serially (debug/determinism aid). Only valid
    /// with the CPU executor and a non-pipelined session.
    pub fn serial_cpu(mut self, serial: bool) -> Self {
        self.serial_cpu = serial;
        self
    }

    /// Use the given plan cache — e.g. one pre-warmed elsewhere — instead
    /// of the default fresh cache. Pre-warmed entries must hold plans for
    /// the first run's sequence length (the executor rejects wrong-length
    /// plans); later length changes invalidate and re-warm as usual.
    pub fn cache(mut self, cache: PlanCache) -> Self {
        self.cache = Some(Arc::new(cache));
        self
    }

    /// Share a plan cache with other sessions — the shard-worker wiring
    /// (DESIGN.md §12): shards of one [`crate::attention::shard::ShardedSession`]
    /// exchange plan coordinates exclusively through this shared cache.
    pub fn shared_cache(mut self, cache: Arc<PlanCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Mark this session a shard worker: the coordinating
    /// `ShardedSession` owns cache warm/invalidate and store sync, so the
    /// worker must never invalidate the shared cache or touch a store
    /// itself (incompatible with `persist`).
    pub(crate) fn shard_worker(mut self) -> Self {
        self.shard_worker = true;
        self
    }

    /// Disable plan caching: every run re-identifies. Incompatible with
    /// `persist` (a store has nothing to warm).
    pub fn no_cache(mut self) -> Self {
        self.cache = None;
        self
    }

    /// Explicit per-head plan keys for `run_batch`.
    pub fn keys(mut self, keys: Vec<PlanKey>) -> Self {
        self.keys = KeyPolicy::Explicit(keys);
        self
    }

    /// GQA-style key assignment: `keys[h] = (layer, h / group_size)`.
    pub fn gqa_keys(mut self, layer: u32, group_size: usize) -> Self {
        self.keys = KeyPolicy::Gqa { layer, group_size };
        self
    }

    /// Overlap identification with execution through the bounded plan
    /// queue (DESIGN.md §9); output stays bitwise-equal to sequential.
    pub fn pipelined(mut self, pipelined: bool) -> Self {
        self.pipelined = pipelined;
        self
    }

    /// Pipeline shape (queue depth / planner workers); implies
    /// `pipelined(true)`.
    pub fn pipeline(mut self, pipe: PlanPipeline) -> Self {
        self.pipeline = pipe;
        self.pipelined = true;
        self
    }

    /// Persist plans into the runtime manifest at `path` (warm on build,
    /// flush on [`AttentionSession::flush`] / drop). The manifest must
    /// already exist; requires the cache.
    pub fn persist(mut self, path: impl Into<PathBuf>) -> Self {
        self.persist = Some(path.into());
        self
    }

    /// Model identifier plans are keyed under in the store.
    pub fn model(mut self, model: &str) -> Self {
        self.model = model.to_string();
        self
    }

    /// Cap the plan store's resident entries (LRU-ish eviction, loudly
    /// logged); requires `persist`.
    pub fn store_max_entries(mut self, cap: usize) -> Self {
        self.store_cap = Some(cap);
        self
    }

    /// Speculative plan-reuse policy (DESIGN.md §17). Non-`exact`
    /// policies widen cache misses to cross-layer / shared-prefix donor
    /// plans behind a recall check; they require the plan cache and the
    /// anchor method (the check *is* Alg. 2 on a sampled group subset).
    pub fn reuse(mut self, policy: ReusePolicy) -> Self {
        self.reuse = policy;
        self
    }

    /// Validate the configuration and assemble the session.
    pub fn build(self) -> Result<AttentionSession> {
        if let KeyPolicy::Gqa { group_size, .. } = self.keys {
            if group_size == 0 {
                return Err(anyhow!("session key policy: group_size must be >= 1"));
            }
        }
        if self.serial_cpu && self.executor != ExecutorKind::Cpu {
            return Err(anyhow!(
                "serial_cpu applies to the cpu executor; the session names '{}'",
                self.executor.name()
            ));
        }
        if self.pipelined && self.serial_cpu {
            return Err(anyhow!(
                "pipelined session on the serial CPU executor: the drain stage would run \
                 single-threaded with nothing to overlap against — drop serial_cpu(true) \
                 or pipelined(true)"
            ));
        }
        if self.shard_worker && self.persist.is_some() {
            return Err(anyhow!(
                "a shard worker must not persist: the coordinating ShardedSession \
                 owns the plan store (DESIGN.md §12)"
            ));
        }
        let spec = match (&self.reuse, &self.method) {
            (ReusePolicy::Exact, _) => None,
            (policy, _) if self.cache.is_none() => {
                return Err(anyhow!(
                    "reuse '{}' widens the plan-cache lookup — a no_cache() session \
                     has no cache to widen; re-enable the cache or use reuse 'exact'",
                    policy.name()
                ));
            }
            (policy, Method::Anchor(cfg)) => Some(Arc::new(Speculator::new(*policy, *cfg))),
            (policy, other) => {
                return Err(anyhow!(
                    "reuse '{}' requires the anchor method (the recall check is \
                     Alg. 2's anchor comparison — only the anchor planner can score \
                     a speculative plan); the session runs '{}'",
                    policy.name(),
                    other.name()
                ));
            }
        };
        let store = open_plan_store(&self.persist, self.cache.is_some(), self.store_cap)?;
        let executor: Box<dyn Executor> = match self.executor {
            ExecutorKind::Cpu => {
                Box::new(CpuTileExecutor { serial: self.serial_cpu, ..Default::default() })
            }
            ExecutorKind::Pjrt => Box::new(PjrtGatherExecutor::new()),
        };
        Ok(AttentionSession {
            method: self.method,
            executor,
            executor_kind: self.executor,
            cache: self.cache,
            keys: self.keys,
            pipelined: self.pipelined,
            pipeline: self.pipeline,
            store,
            model: self.model,
            current_n: None,
            store_seeded: 0,
            shard_worker: self.shard_worker,
            spec,
        })
    }
}

/// Unified result of [`AttentionSession::run`] / `run_batch`: per-head
/// outputs and plans plus the cache, identification-cost and pipeline
/// accounting the old `AttnOutput`/`BatchOutput`/`PipelinedBatchOutput`
/// trio split across three shapes.
#[derive(Debug)]
pub struct SessionOutput {
    pub outputs: Vec<AttnOutput>,
    /// Plans used per head (cache-shared heads hold the same `Arc`).
    pub plans: Vec<Arc<SparsePlan>>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Identification cost actually paid this run (fresh keys only; a
    /// fully warm run reports zero — the fig2 cold-vs-warm column). A
    /// speculative hit pays only its recall-check (plus any prefix
    /// suffix-identification) cost here, which is the reuse layer's
    /// entire saving (DESIGN.md §17).
    pub ident_cost_paid: CostTally,
    /// Overlap accounting when the session pipelines batches.
    pub pipeline: Option<PipelineStats>,
    /// Cache misses this run that a speculative donor plan resolved after
    /// passing the recall check (always 0 under reuse `exact`).
    pub speculative_hits: u64,
    /// Cache misses whose recall check rejected the donor and fell back
    /// to full identification (output unchanged, check cost wasted).
    pub speculative_fallbacks: u64,
    /// Mean recall the checks measured this run; `None` when no donor
    /// was checked.
    pub speculative_recall: Option<f64>,
}

impl SessionOutput {
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// The single head's output (panics on a multi-head result).
    pub fn single(&self) -> &AttnOutput {
        assert_eq!(self.outputs.len(), 1, "single() on a multi-head output");
        &self.outputs[0]
    }

    /// Consume into the single head's output (panics on a multi-head
    /// result).
    pub fn into_single(self) -> AttnOutput {
        assert_eq!(self.outputs.len(), 1, "into_single() on a multi-head output");
        self.outputs.into_iter().next().expect("one output")
    }

    /// Consume into the legacy batched shape (used by the deprecated
    /// shims).
    pub fn into_batch(self) -> BatchOutput {
        BatchOutput {
            outputs: self.outputs,
            plans: self.plans,
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
        }
    }
}

/// A configured attention session: one method, one executor backend, one
/// plan cache (optionally manifest-persisted), two run methods.
pub struct AttentionSession {
    method: Method,
    executor: Box<dyn Executor>,
    executor_kind: ExecutorKind,
    cache: Option<Arc<PlanCache>>,
    keys: KeyPolicy,
    pipelined: bool,
    pipeline: PlanPipeline,
    store: Option<PlanStore>,
    model: String,
    /// Sequence length the cache is currently warmed for; a different `n`
    /// invalidates and re-warms (plan keys carry no length).
    current_n: Option<usize>,
    store_seeded: u64,
    /// Shard-worker mode: cache lifecycle is owned by the coordinating
    /// `ShardedSession`, so prepare/invalidate/sync are no-ops here.
    shard_worker: bool,
    /// Speculative reuse layer for non-`exact` policies (DESIGN.md §17);
    /// `None` means exact lookup, bitwise the pre-reuse behavior.
    spec: Option<Arc<Speculator>>,
}

/// Shared persistence validation + store opening for the session and
/// sharded-session builders: a persistence path requires the cache, a
/// store cap requires a path and must be nonzero. Keeping one copy means
/// the two builders cannot drift on store semantics (DESIGN.md §12).
pub(crate) fn open_plan_store(
    persist: &Option<PathBuf>,
    cache_present: bool,
    store_cap: Option<usize>,
) -> Result<Option<PlanStore>> {
    if persist.is_some() && !cache_present {
        return Err(anyhow!(
            "plan persistence requires the plan cache: a session built with \
             persist()/--plan-store but no_cache() has nothing to warm or flush — \
             re-enable the cache or drop the persistence path"
        ));
    }
    if store_cap.is_some() && persist.is_none() {
        return Err(anyhow!(
            "store_max_entries caps the persisted plan store — there is none \
             without persist()/--plan-store"
        ));
    }
    if store_cap == Some(0) {
        return Err(anyhow!(
            "store_max_entries must be >= 1 — a zero-entry store could never \
             warm-start anything"
        ));
    }
    // No context wrap: the store's own error already names the path and
    // the fix, and the vendored `anyhow` displays only the outermost
    // message.
    match persist {
        Some(path) => {
            let mut s = PlanStore::open(path)?;
            if let Some(cap) = store_cap {
                s.set_max_entries(Some(cap));
            }
            Ok(Some(s))
        }
        None => Ok(None),
    }
}

/// Seed `cache` from `store`'s `(model, *, *, n)` entries whose method,
/// plan geometry (tile, step) *and* priced head dim all match — a
/// persisted plan from a differently-configured method must re-identify,
/// never serve stale coordinates or mispriced costs. Returns the seeded
/// count. Shared by the session's warm path and the `ShardedSession`
/// coordinator (DESIGN.md §12). The filter runs on the store's index
/// ([`PlanStore::plans_for_compatible`]), so non-matching entries are
/// never decoded — seeding cost scales with this session's slice of the
/// store, not the total key count (DESIGN.md §15).
pub(crate) fn seed_cache_from_store(
    cache: &PlanCache,
    store: &mut PlanStore,
    model: &str,
    method: &Method,
    n: usize,
    d: usize,
) -> u64 {
    let (tile, step) = method.plan_geometry();
    let mut seeded = 0;
    for (key, plan) in store.plans_for_compatible(model, n, method.name(), tile, step, d) {
        cache.seed(key, plan);
        seeded += 1;
    }
    seeded
}

/// File every cached plan for length `n` into the store. Store-seeded and
/// previously filed entries hold the same `Arc`, so the steady-state sync
/// is a pointer compare per entry — no deep work, no dirtying. A
/// caller-warmed cache may hold other-length plans the batch never
/// touched; those are never filed under this length's key.
pub(crate) fn sync_cache_to_store(
    store: &mut PlanStore,
    cache: &PlanCache,
    model: &str,
    n: usize,
    d: usize,
) {
    for (key, plan) in cache.snapshot() {
        if plan.n != n {
            continue;
        }
        store.insert(
            PlanStoreKey {
                model: model.to_string(),
                layer: key.layer,
                head_group: key.head_group,
                n,
            },
            d,
            plan,
        );
    }
}

impl AttentionSession {
    pub fn builder(method: Method) -> SessionBuilder {
        SessionBuilder::new(method)
    }

    pub fn method(&self) -> &Method {
        &self.method
    }

    pub fn executor_kind(&self) -> ExecutorKind {
        self.executor_kind
    }

    pub fn is_pipelined(&self) -> bool {
        self.pipelined
    }

    /// Cache counters, when the session caches plans.
    pub fn cache_stats(&self) -> Option<PlanCacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Persisted-plan count, when the session persists.
    pub fn store_len(&self) -> Option<usize> {
        self.store.as_ref().map(|s| s.len())
    }

    /// Persisted-plan count under this session's model tag, when the
    /// session persists — entries other cells filed can never seed this
    /// session, so warm-start expectations should read this, not
    /// [`AttentionSession::store_len`].
    pub fn store_len_for_model(&self) -> Option<usize> {
        self.store.as_ref().map(|s| s.len_for_model(&self.model))
    }

    /// Persisted-plan count this session could actually seed from
    /// (model tag + method + plan geometry, any length) — the honest
    /// input to warm-start expectations like the serve plan-hit prior.
    pub fn store_len_compatible(&self) -> Option<usize> {
        let store = self.store.as_ref()?;
        let (tile, step) = self.method.plan_geometry();
        Some(store.len_compatible(&self.model, self.method.name(), tile, step))
    }

    /// Store-to-cache seeding events so far (warm-start observability).
    /// Counts every seed, so a session alternating sequence lengths
    /// re-counts entries on each re-warm — a rate of re-warming, not a
    /// distinct-plan count.
    pub fn store_seeded(&self) -> u64 {
        self.store_seeded
    }

    /// Replace the per-head plan keys (the `ShardedSession` coordinator
    /// routes each shard's sub-batch keys through this before dispatch).
    pub(crate) fn set_keys(&mut self, keys: Vec<PlanKey>) {
        self.keys = KeyPolicy::Explicit(keys);
    }

    /// Warm the cache for sequence length `n` at head dim `d`: on a
    /// length change the cache is invalidated (keys carry no length) and
    /// re-seeded from the store via [`seed_cache_from_store`]'s
    /// compatibility filter. A shard worker skips this entirely — the
    /// coordinating `ShardedSession` owns warm/invalidate, and a worker
    /// invalidating the *shared* cache would wipe its siblings' plans.
    fn prepare_cache(&mut self, n: usize, d: usize) {
        if self.shard_worker {
            return;
        }
        let Some(cache) = self.cache.clone() else { return };
        if self.current_n == Some(n) {
            return;
        }
        // Invalidate only on an actual length change: the first run must
        // not wipe a cache the caller pre-warmed via `.cache()`.
        if self.current_n.is_some() {
            // Under prefix reuse the outgoing plans become shorter-length
            // donors first — a grown sequence's next run extends them by
            // suffix identification instead of starting over.
            if let Some(spec) = &self.spec {
                spec.adopt_donors(cache.snapshot());
            }
            cache.invalidate();
        }
        if let Some(store) = self.store.as_mut() {
            self.store_seeded += seed_cache_from_store(&cache, store, &self.model, &self.method, n, d);
            // Widened store lookup (DESIGN.md §17): shorter compatible
            // plans cannot seed the cache (the executor rejects
            // wrong-length plans) but can seed the speculator's prefix
            // donor table.
            if let Some(spec) = &self.spec {
                let (tile, step) = self.method.plan_geometry();
                for (key, plan) in
                    store.plans_for_prefix(&self.model, n, self.method.name(), tile, step, d)
                {
                    spec.seed_donor(key, plan);
                }
            }
        }
        self.current_n = Some(n);
    }

    /// File every cached plan for length `n` into the store (no-op when
    /// the session does not persist).
    fn sync_store(&mut self, n: usize, d: usize) {
        let Some(cache) = self.cache.clone() else { return };
        if let Some(store) = self.store.as_mut() {
            sync_cache_to_store(store, &cache, &self.model, n, d);
        }
    }

    /// Run the method on one head. Sequential (per-head work has nothing
    /// to overlap); consults the cache via the head-0 key when caching is
    /// enabled, otherwise identifies fresh like the legacy `Method::run`.
    pub fn run(&mut self, input: &HeadInput) -> Result<SessionOutput> {
        let n = input.n();
        self.prepare_cache(n, input.d());
        if let Some(spec) = &self.spec {
            spec.begin_run();
        }
        let planner = self.method.planner();
        let (plan, hit) = match &self.cache {
            Some(cache) => {
                let key = self.keys.key_of(0)?;
                cache.get_or_plan(key, || match &self.spec {
                    Some(s) => s.resolve(cache, key, input),
                    None => planner.plan(input),
                })
            }
            None => (Arc::new(planner.plan(input)), false),
        };
        let mut out = self.executor.execute(input, &plan);
        let mut ident_paid = CostTally::default();
        if !hit {
            out.cost.add(plan.ident_cost);
            ident_paid.add(plan.ident_cost);
        }
        self.sync_store(n, input.d());
        let (speculative_hits, speculative_fallbacks, speculative_recall) =
            self.spec.as_ref().map_or((0, 0, None), |s| s.take_run_stats());
        Ok(SessionOutput {
            outputs: vec![out],
            plans: vec![plan],
            cache_hits: u64::from(hit),
            cache_misses: u64::from(!hit),
            ident_cost_paid: ident_paid,
            pipeline: None,
            speculative_hits,
            speculative_fallbacks,
            speculative_recall,
        })
    }

    /// Run the method on a multi-head batch, dispatching the sequential or
    /// pipelined path on the configured backend, with cache semantics and
    /// hit accounting identical to the legacy cached entry points —
    /// bitwise-equal outputs in every configuration.
    pub fn run_batch(&mut self, batch: &BatchInput) -> Result<SessionOutput> {
        let n = batch.n();
        self.prepare_cache(n, batch.d());
        if let Some(spec) = &self.spec {
            spec.begin_run();
        }
        let keys = match &self.cache {
            Some(_) => Some(self.keys.keys_for(batch.h())?),
            None => None,
        };
        let (out, stats) = {
            let cached = match (&self.cache, &keys) {
                (Some(c), Some(k)) => Some((c.as_ref(), k.as_slice())),
                _ => None,
            };
            let spec = self.spec.as_deref();
            if self.pipelined {
                let planner = self.method.planner();
                let piped = run_planner_batch_pipelined(
                    planner.as_ref(),
                    batch,
                    cached,
                    spec,
                    &self.pipeline,
                    self.executor.as_ref(),
                )
                .map_err(|e| anyhow!("pipelined batch failed: {e}"))?;
                (piped.batch, Some(piped.stats))
            } else {
                (self.method.run_batch_inner(batch, cached, spec, self.executor.as_ref()), None)
            }
        };
        let BatchOutput { outputs, plans, cache_hits, cache_misses } = out;
        // A head pays identification iff its reported cost exceeds the
        // plan's pure execution cost (executors tally exactly
        // `predicted_cost` — a tested invariant), which recovers the
        // fresh-key attribution without re-deriving it here.
        let mut ident_paid = CostTally::default();
        for (o, p) in outputs.iter().zip(&plans) {
            if o.cost != p.predicted_cost {
                ident_paid.add(p.ident_cost);
            }
        }
        // Persistence syncs from the cache, not from the payer set, so
        // fresh plans with zero identification cost (full-attn,
        // streaming-llm) are filed too and the restart warm-start
        // guarantee holds for every method.
        self.sync_store(n, batch.d());
        let (speculative_hits, speculative_fallbacks, speculative_recall) =
            self.spec.as_ref().map_or((0, 0, None), |s| s.take_run_stats());
        Ok(SessionOutput {
            outputs,
            plans,
            cache_hits,
            cache_misses,
            ident_cost_paid: ident_paid,
            pipeline: stats,
            speculative_hits,
            speculative_fallbacks,
            speculative_recall,
        })
    }

    /// Write filed plans back to the runtime manifest (no-op when the
    /// session does not persist or nothing changed). Also runs on drop,
    /// best-effort.
    pub fn flush(&mut self) -> Result<()> {
        match self.store.as_mut() {
            Some(store) => store.flush(),
            None => Ok(()),
        }
    }
}

impl Drop for AttentionSession {
    fn drop(&mut self) {
        if let Some(store) = self.store.as_mut() {
            let _ = store.flush();
        }
    }
}

impl Method {
    /// Session builder for this method — the replacement for the
    /// deprecated `run_*` entry-point matrix (DESIGN.md §11).
    pub fn session(&self) -> SessionBuilder {
        AttentionSession::builder(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::anchor::AnchorConfig;
    use crate::attention::plan::run_planner;
    use crate::attention::TileConfig;
    use crate::tensor::Mat;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn anchor_method() -> Method {
        Method::Anchor(AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta: 4.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        })
    }

    fn tmp_manifest(tag: &str) -> PathBuf {
        let path = std::env::temp_dir()
            .join(format!("anchor_session_{}_{tag}.json", std::process::id()));
        std::fs::write(&path, "{}\n").unwrap();
        path
    }

    #[test]
    fn session_run_matches_run_planner() {
        let h = rand_head(11, 96, 8);
        let m = anchor_method();
        let legacy = run_planner(&h, m.planner().as_ref());
        let mut session = m.session().no_cache().build().unwrap();
        let out = session.run(&h).unwrap();
        assert_eq!(out.outputs[0].out.data, legacy.out.data);
        assert_eq!(out.outputs[0].cost, legacy.cost);
        assert_eq!((out.cache_hits, out.cache_misses), (0, 1));
        assert_eq!(out.ident_cost_paid, out.plans[0].ident_cost);
    }

    #[test]
    fn cached_session_amortizes_identification_across_runs() {
        let h = rand_head(12, 96, 8);
        let m = anchor_method();
        let mut session = m.session().build().unwrap();
        let cold = session.run(&h).unwrap();
        let warm = session.run(&h).unwrap();
        assert_eq!((cold.cache_hits, cold.cache_misses), (0, 1));
        assert_eq!((warm.cache_hits, warm.cache_misses), (1, 0));
        assert_eq!(warm.ident_cost_paid, CostTally::default());
        assert_eq!(warm.outputs[0].cost, warm.plans[0].predicted_cost);
        assert!(Arc::ptr_eq(&cold.plans[0], &warm.plans[0]));
        let stats = session.cache_stats().unwrap();
        assert_eq!((stats.hits, stats.misses), (1, 1));
    }

    #[test]
    fn length_change_invalidates_the_cache() {
        let m = anchor_method();
        let mut session = m.session().build().unwrap();
        let a = session.run(&rand_head(13, 96, 8)).unwrap();
        // Same key, new length: must re-identify, not serve the 96-plan.
        let b = session.run(&rand_head(14, 64, 8)).unwrap();
        assert_eq!(a.plans[0].n, 96);
        assert_eq!(b.plans[0].n, 64);
        assert_eq!((b.cache_hits, b.cache_misses), (0, 1));
    }

    #[test]
    fn explicit_keys_must_match_batch_heads() {
        let m = anchor_method();
        let mut session = m.session().keys(vec![PlanKey::new(0, 0)]).build().unwrap();
        let batch = BatchInput::new(vec![rand_head(15, 64, 8), rand_head(16, 64, 8)]);
        let err = session.run_batch(&batch).unwrap_err().to_string();
        assert!(err.contains("2 heads"), "{err}");
    }

    #[test]
    fn build_rejects_pipelined_serial_cpu() {
        let err = anchor_method()
            .session()
            .serial_cpu(true)
            .pipelined(true)
            .build()
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("serial CPU"), "{err}");
    }

    #[test]
    fn build_rejects_serial_knob_on_pjrt() {
        let err = anchor_method()
            .session()
            .executor(ExecutorKind::Pjrt)
            .serial_cpu(true)
            .build()
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("serial_cpu"), "{err}");
    }

    #[test]
    fn build_rejects_persistence_without_a_manifest() {
        let missing = std::env::temp_dir().join("anchor_session_no_manifest.json");
        let _ = std::fs::remove_file(&missing);
        let err = anchor_method()
            .session()
            .persist(&missing)
            .build()
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("manifest"), "{err}");
    }

    #[test]
    fn build_rejects_persistence_with_cache_disabled() {
        let path = tmp_manifest("nocache");
        let err = anchor_method()
            .session()
            .no_cache()
            .persist(&path)
            .build()
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("cache"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn build_rejects_reuse_without_cache() {
        let err = anchor_method()
            .session()
            .no_cache()
            .reuse(ReusePolicy::prefix())
            .build()
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("no cache to widen"), "{err}");
    }

    #[test]
    fn build_rejects_reuse_on_non_anchor_methods() {
        let err = Method::Full(TileConfig::new(16, 16))
            .session()
            .reuse(ReusePolicy::cross_layer())
            .build()
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("anchor method"), "{err}");
    }

    /// A prefix-reuse session that grows its sequence adopts the old
    /// plans as donors: the longer run reports a speculative hit and pays
    /// less identification than a cold run at the new length.
    #[test]
    fn prefix_reuse_extends_across_a_length_change() {
        let m = anchor_method();
        let full = rand_head(22, 256, 8);
        let prefix = HeadInput::new(
            full.q.rows_mat(0, 128),
            full.k.rows_mat(0, 128),
            full.v.rows_mat(0, 128),
        );
        let mut session = m.session().reuse(ReusePolicy::prefix()).build().unwrap();
        let short = session.run(&prefix).unwrap();
        assert_eq!(short.speculative_hits, 0); // no donors yet
        let grown = session.run(&full).unwrap();
        assert_eq!((grown.cache_hits, grown.cache_misses), (0, 1));
        assert_eq!((grown.speculative_hits, grown.speculative_fallbacks), (1, 0));
        // Output identical to an exact-policy session at the full length
        // (the prefix donor's stripes match fresh identification here).
        let exact = m.session().build().unwrap().run(&full).unwrap();
        assert_eq!(grown.outputs[0].out.data, exact.outputs[0].out.data);
        assert!(
            grown.ident_cost_paid.ident_scores < exact.ident_cost_paid.ident_scores,
            "speculative {} !< fresh {}",
            grown.ident_cost_paid.ident_scores,
            exact.ident_cost_paid.ident_scores
        );
    }

    #[test]
    fn build_rejects_zero_group_size() {
        let err = anchor_method()
            .session()
            .gqa_keys(0, 0)
            .build()
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("group_size"), "{err}");
    }

    #[test]
    fn persisted_plans_warm_a_restarted_session() {
        let path = tmp_manifest("restart");
        let heads: Vec<HeadInput> = {
            let shared = rand_head(17, 96, 8);
            vec![shared.clone(), shared]
        };
        let batch = BatchInput::new(heads);
        let keys = vec![PlanKey::new(0, 0), PlanKey::new(0, 0)];
        let m = anchor_method();

        let cold_out;
        {
            let mut cold = m
                .session()
                .keys(keys.clone())
                .persist(&path)
                .model("llama-like/anchor")
                .build()
                .unwrap();
            cold_out = cold.run_batch(&batch).unwrap();
            assert_eq!((cold_out.cache_hits, cold_out.cache_misses), (1, 1));
            assert!(cold_out.ident_cost_paid.ident_scores > 0);
            cold.flush().unwrap();
            assert_eq!(cold.store_len(), Some(1));
        } // drop = restart boundary

        let mut warm = m
            .session()
            .keys(keys)
            .persist(&path)
            .model("llama-like/anchor")
            .build()
            .unwrap();
        let warm_out = warm.run_batch(&batch).unwrap();
        // First batch after "restart": the previously seen key hits.
        assert_eq!((warm_out.cache_hits, warm_out.cache_misses), (2, 0));
        assert_eq!(warm_out.ident_cost_paid, CostTally::default());
        assert_eq!(warm.store_seeded(), 1);
        for (a, b) in cold_out.outputs.iter().zip(&warm_out.outputs) {
            assert_eq!(a.out.data, b.out.data, "warm output must be bitwise-identical");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn store_plans_of_other_methods_or_models_do_not_seed() {
        let path = tmp_manifest("filter");
        let h = rand_head(18, 96, 8);
        let m = anchor_method();
        {
            let mut s = m.session().persist(&path).model("cell-a").build().unwrap();
            s.run(&h).unwrap();
            s.flush().unwrap();
        }
        // Different model tag: nothing seeds.
        let mut other_model = m.session().persist(&path).model("cell-b").build().unwrap();
        let out = other_model.run(&h).unwrap();
        assert_eq!(other_model.store_seeded(), 0);
        assert_eq!((out.cache_hits, out.cache_misses), (0, 1));
        // Same model tag, different method: the anchor plan must not serve
        // a full-attn session.
        let mut other_method = Method::Full(TileConfig::new(16, 16))
            .session()
            .persist(&path)
            .model("cell-a")
            .build()
            .unwrap();
        let out = other_method.run(&h).unwrap();
        assert_eq!(other_method.store_seeded(), 0);
        assert_eq!(out.plans[0].method, "full-attn");
        // Same model tag and method, different identification step: the
        // stored step-2 plan has the wrong geometry for a step-4 session,
        // so it must re-identify rather than serve stale coordinates.
        let mut other_step = Method::Anchor(AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta: 4.0,
            step: 4,
            init_blocks: 1,
            use_anchor: true,
        })
        .session()
        .persist(&path)
        .model("cell-a")
        .build()
        .unwrap();
        let out = other_step.run(&h).unwrap();
        assert_eq!(other_step.store_seeded(), 0);
        assert_eq!((out.cache_hits, out.cache_misses), (0, 1));
        assert_eq!(out.plans[0].step, 4);
        // Drop (and so flush) every session before removing the file, or a
        // late drop would recreate it.
        drop(other_model);
        drop(other_method);
        drop(other_step);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pre_warmed_cache_survives_the_first_run() {
        let h = rand_head(19, 96, 8);
        let m = anchor_method();
        let cache = PlanCache::new();
        cache.seed(PlanKey::new(0, 0), Arc::new(m.plan(&h)));
        let mut session = m.session().cache(cache).build().unwrap();
        let out = session.run(&h).unwrap();
        assert_eq!((out.cache_hits, out.cache_misses), (1, 0));
        assert_eq!(out.ident_cost_paid, CostTally::default());
        // A length change still invalidates as usual.
        let other = session.run(&rand_head(21, 64, 8)).unwrap();
        assert_eq!((other.cache_hits, other.cache_misses), (0, 1));
    }

    /// Methods whose identification is free (zero ident cost) still
    /// persist and warm-start: the store syncs from the cache, not from
    /// the set of ident-paying heads.
    #[test]
    fn zero_ident_methods_persist_through_run_batch() {
        let path = tmp_manifest("zeroident");
        let m = Method::Full(TileConfig::new(16, 16));
        let batch = BatchInput::new(vec![rand_head(20, 64, 8)]);
        {
            let mut s = m.session().persist(&path).model("z").build().unwrap();
            let out = s.run_batch(&batch).unwrap();
            assert_eq!((out.cache_hits, out.cache_misses), (0, 1));
            s.flush().unwrap();
            assert_eq!(s.store_len(), Some(1));
            assert_eq!(s.store_len_for_model(), Some(1));
        }
        let mut warm = m.session().persist(&path).model("z").build().unwrap();
        let out = warm.run_batch(&batch).unwrap();
        assert_eq!((out.cache_hits, out.cache_misses), (1, 0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn session_config_builder_applies_fields() {
        let cfg = SessionConfig {
            executor: ExecutorKind::Pjrt,
            pipelined: true,
            cache: true,
            plan_store: None,
            model: "m7".to_string(),
            shards: 1,
            store_max_entries: None,
            transport: SessionTransport::Threads,
            reuse: ReusePolicy::Exact,
        };
        let session = cfg.builder(anchor_method()).build().unwrap();
        assert_eq!(session.executor_kind(), ExecutorKind::Pjrt);
        assert!(session.is_pipelined());
        let cfg = SessionConfig { cache: false, ..SessionConfig::default() };
        let session = cfg.builder(anchor_method()).build().unwrap();
        assert!(session.cache_stats().is_none());
    }
}

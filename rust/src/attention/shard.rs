//! Head-group sharding: distribute a [`BatchInput`]'s head groups across
//! shard workers that exchange **only plan coordinates** (DESIGN.md §12).
//!
//! The paper's stripe plans are discrete coordinates, tiny relative to the
//! K/V they index (§3.2–§3.3) — which is what makes sharding cheap at the
//! head-group granularity: a [`ShardedSession`] partitions the batch's
//! [`PlanKey`]s across `S` shard workers (deterministic round-robin over
//! the sorted distinct keys), each shard owning a full
//! [`AttentionSession`] with its own executor backend and pipeline, while
//! every shard reads and writes one shared [`PlanCache`] and the
//! coordinator alone warms/flushes the manifest [`PlanStore`]. K/V never
//! crosses a shard boundary: each head's Q/K/V is handed to exactly one
//! shard, and what shards exchange — through the cache and the store — is
//! [`SparsePlan`] coordinates. The store is segmented (DESIGN.md §15):
//! the coordinator's warm pass filters on the index and decodes only this
//! session's compatible slice, so a fleet-sized store does not tax a
//! single cell's startup.
//!
//! The worker seam is a transport choice (DESIGN.md §14): by default
//! shards are in-process threads; [`ShardedSessionBuilder::remote`] swaps
//! them for *processes* behind the coordinate-only wire protocol
//! ([`crate::wire`]) — spawned children or pre-started TCP/UDS endpoints
//! — without touching the partition/merge logic. The dispatch payload and
//! the reply differ only in serialization: sub-batch Q/K/V out once, plan
//! coordinates and output rows back.
//!
//! Invariants (property-tested in `tests/prop_shard_parity.rs` and, for
//! the wire leg, `tests/wire_parity.rs`):
//! * **Bitwise parity** — the merged [`SessionOutput`] is bitwise-equal to
//!   the unsharded session for every planner, across shard counts
//!   (including ones that do not divide the head count), sequential and
//!   pipelined, on every executor backend, over threads and over the
//!   wire. All heads of one `PlanKey` land on one shard and sub-batches
//!   preserve the original head order, so each key's plan is identified
//!   from the same head the unsharded path would pick. Floats cross the
//!   wire as raw IEEE-754 bits and `predicted_cost`/`Coverage` are
//!   re-derived from the decoded coordinates, so remote replies carry no
//!   rounding.
//! * **Accounting parity** — merged `cache_hits + cache_misses` equals the
//!   unsharded run head count, hit/ident attribution sums across shards to
//!   the unsharded totals, and the merged hit rate is what a serving loop
//!   feeds into the scheduler's `plan_hit_rate` EWMA
//!   (`SparsityModel::observe_plan_hit_rate`).
//! * **Failure is loud** — a shard worker that errors, panics, dies
//!   mid-batch, or misses a wire deadline surfaces as an `Err` naming the
//!   shard; the remaining shards are joined first, never leaked. Remote
//!   shards reconnect (with backoff, respawning dead children in spawn
//!   mode) at the *next* batch, so a subsequent batch succeeds without
//!   caller intervention.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::attention::exec::ExecutorKind;
use crate::attention::pipeline::PipelineStats;
use crate::attention::plan::{BatchInput, PlanCache, PlanCacheStats, PlanKey, SparsePlan};
use crate::attention::reuse::ReusePolicy;
use crate::attention::session::{
    open_plan_store, seed_cache_from_store, sync_cache_to_store, AttentionSession, KeyPolicy,
    SessionOutput,
};
use crate::attention::{AttnOutput, CostTally, Method};
use crate::runtime::manifest::PlanStore;
use crate::util::threadpool::panic_message;
use crate::wire::codec::{ConfigureMsg, DispatchMsg, ReplyMsg};
use crate::wire::transport::{
    spawn_socket_path, Endpoint, RemoteShard, RemoteSpec, ShardEndpoint, WireTimeouts,
};

/// Builder for [`ShardedSession`] — the sharded front end to the session
/// API; every knob mirrors [`crate::attention::session::SessionBuilder`].
pub struct ShardedSessionBuilder {
    method: Method,
    shards: usize,
    executor: ExecutorKind,
    pipelined: bool,
    cache: Option<Arc<PlanCache>>,
    keys: KeyPolicy,
    persist: Option<PathBuf>,
    model: String,
    store_cap: Option<usize>,
    remote: Option<RemoteSpec>,
    timeouts: WireTimeouts,
    reuse: ReusePolicy,
}

impl ShardedSessionBuilder {
    fn new(method: Method, shards: usize) -> Self {
        Self {
            method,
            shards,
            executor: ExecutorKind::Cpu,
            pipelined: false,
            cache: Some(Arc::new(PlanCache::new())),
            keys: KeyPolicy::Gqa { layer: 0, group_size: 1 },
            persist: None,
            model: "default".to_string(),
            store_cap: None,
            remote: None,
            timeouts: WireTimeouts::default(),
            reuse: ReusePolicy::Exact,
        }
    }

    /// Executor backend every shard worker runs (`cpu` | `pjrt`).
    pub fn executor(mut self, kind: ExecutorKind) -> Self {
        self.executor = kind;
        self
    }

    /// Run each shard's batch through the async plan pipeline.
    pub fn pipelined(mut self, pipelined: bool) -> Self {
        self.pipelined = pipelined;
        self
    }

    /// Share a pre-warmed plan cache instead of a fresh one.
    pub fn shared_cache(mut self, cache: Arc<PlanCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Disable plan caching: every shard re-identifies its keys each run.
    /// Incompatible with `persist`.
    pub fn no_cache(mut self) -> Self {
        self.cache = None;
        self
    }

    /// Explicit per-head plan keys for `run_batch`.
    pub fn keys(mut self, keys: Vec<PlanKey>) -> Self {
        self.keys = KeyPolicy::Explicit(keys);
        self
    }

    /// GQA-style key assignment: `keys[h] = (layer, h / group_size)`.
    pub fn gqa_keys(mut self, layer: u32, group_size: usize) -> Self {
        self.keys = KeyPolicy::Gqa { layer, group_size };
        self
    }

    /// Persist plans into the runtime manifest at `path`. The coordinator
    /// owns the store: shards only see coordinates through the shared
    /// cache.
    pub fn persist(mut self, path: impl Into<PathBuf>) -> Self {
        self.persist = Some(path.into());
        self
    }

    /// Model identifier plans are keyed under in the store.
    pub fn model(mut self, model: &str) -> Self {
        self.model = model.to_string();
        self
    }

    /// Cap the plan store's resident entries; requires `persist`.
    pub fn store_max_entries(mut self, cap: usize) -> Self {
        self.store_cap = Some(cap);
        self
    }

    /// Address shard workers over the wire ([`crate::wire`]) instead of
    /// in-process threads: spawned child processes or pre-started TCP/UDS
    /// endpoints. Connections are lazy (first `run_batch`); workers are
    /// configured with this builder's exact method/executor/pipeline
    /// shape, so the two transports cannot drift.
    pub fn remote(mut self, spec: RemoteSpec) -> Self {
        self.remote = Some(spec);
        self
    }

    /// Per-shard connect/read deadlines and reconnect backoff for the
    /// remote transport.
    pub fn wire_timeouts(mut self, timeouts: WireTimeouts) -> Self {
        self.timeouts = timeouts;
        self
    }

    /// Speculative plan-reuse policy for every shard worker (DESIGN.md
    /// §17). Thread workers speculate against the *shared* cache, so
    /// cross-layer and equal-length shared-prefix donors work exactly as
    /// in the unsharded session; shorter-length prefix donors are adopted
    /// on length changes only by unsharded sessions (the coordinator owns
    /// the shared cache's lifecycle, and workers never snapshot it at
    /// invalidation). Incompatible with the remote transport — the wire
    /// protocol ships exact seeds only.
    pub fn reuse(mut self, policy: ReusePolicy) -> Self {
        self.reuse = policy;
        self
    }

    /// Validate the configuration and assemble the sharded session.
    pub fn build(self) -> Result<ShardedSession> {
        if self.shards == 0 {
            return Err(anyhow!("sharded session: shards must be >= 1"));
        }
        if let KeyPolicy::Gqa { group_size, .. } = self.keys {
            if group_size == 0 {
                return Err(anyhow!("sharded session key policy: group_size must be >= 1"));
            }
        }
        if !self.reuse.is_exact() && self.remote.is_some() {
            return Err(anyhow!(
                "reuse '{}' is not available over the remote transport: wire \
                 workers receive exact-key seeds only and cannot snapshot the \
                 coordinator's cache for donor plans — run reuse over threads, \
                 or use reuse 'exact' with remote shards",
                self.reuse.name()
            ));
        }
        let store = open_plan_store(&self.persist, self.cache.is_some(), self.store_cap)?;
        let backend = match self.remote {
            None => {
                let mut workers = Vec::with_capacity(self.shards);
                for _ in 0..self.shards {
                    let mut b = AttentionSession::builder(self.method.clone())
                        .executor(self.executor)
                        .reuse(self.reuse)
                        .shard_worker();
                    b = match &self.cache {
                        Some(c) => b.shared_cache(c.clone()),
                        None => b.no_cache(),
                    };
                    if self.pipelined {
                        b = b.pipelined(true);
                    }
                    workers.push(b.build()?);
                }
                ShardBackend::Threads(workers)
            }
            Some(spec) => {
                let endpoints: Vec<Endpoint> = match spec {
                    RemoteSpec::Spawn { program } => {
                        let program = match program {
                            Some(p) => p,
                            None => std::env::current_exe()
                                .map_err(|e| anyhow!("sharded session: current_exe: {e}"))?,
                        };
                        (0..self.shards)
                            .map(|s| Endpoint::Spawn {
                                program: program.clone(),
                                socket: spawn_socket_path(s),
                            })
                            .collect()
                    }
                    RemoteSpec::Endpoints(eps) => {
                        if eps.len() != self.shards {
                            return Err(anyhow!(
                                "sharded session: {} endpoint(s) for {} shard(s)",
                                eps.len(),
                                self.shards
                            ));
                        }
                        eps.into_iter()
                            .map(|ep| match ep {
                                ShardEndpoint::Tcp(addr) => Endpoint::Tcp(addr),
                                ShardEndpoint::Uds(path) => Endpoint::Uds(path),
                            })
                            .collect()
                    }
                };
                let remotes: Vec<RemoteShard> = endpoints
                    .into_iter()
                    .enumerate()
                    .map(|(s, ep)| {
                        let cfg = ConfigureMsg {
                            shard_id: s as u32,
                            method: self.method.clone(),
                            executor: self.executor,
                            pipelined: self.pipelined,
                            cache: self.cache.is_some(),
                        };
                        RemoteShard::new(s, ep, self.timeouts, &cfg)
                    })
                    .collect();
                ShardBackend::Remote(remotes)
            }
        };
        Ok(ShardedSession {
            method: self.method,
            shards: self.shards,
            backend,
            cache: self.cache,
            keys: self.keys,
            store,
            model: self.model,
            current_n: None,
            store_seeded: 0,
        })
    }
}

/// The worker transport behind a [`ShardedSession`]: in-process sessions
/// on scoped threads, or wire-connected worker processes. Partitioning
/// and merging are transport-independent; only dispatch differs.
enum ShardBackend {
    Threads(Vec<AttentionSession>),
    Remote(Vec<RemoteShard>),
}

/// `S` shard workers behind one session-shaped front: `run_batch`
/// partitions the batch's head groups, dispatches each shard's sub-batch
/// (on its own thread, or over its own wire connection), and merges the
/// per-shard results back into one [`SessionOutput`] (original head
/// order, summed accounting). See the module docs for the replication
/// story: plans, never K/V.
pub struct ShardedSession {
    method: Method,
    shards: usize,
    backend: ShardBackend,
    cache: Option<Arc<PlanCache>>,
    keys: KeyPolicy,
    store: Option<PlanStore>,
    model: String,
    /// Sequence length the shared cache is currently warmed for.
    current_n: Option<usize>,
    store_seeded: u64,
}

impl ShardedSession {
    pub fn builder(method: Method, shards: usize) -> ShardedSessionBuilder {
        ShardedSessionBuilder::new(method, shards)
    }

    pub fn method(&self) -> &Method {
        &self.method
    }

    /// Shard worker count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Whether shards are wire-connected processes (vs in-process threads).
    pub fn is_remote(&self) -> bool {
        matches!(self.backend, ShardBackend::Remote(_))
    }

    /// Shared-cache counters. Over threads these sum across shards by
    /// construction; over the wire the workers keep their own per-dispatch
    /// caches, so the authoritative hit/miss numbers are the merged
    /// [`SessionOutput`] fields, and this reflects coordinator-side
    /// seeding only.
    pub fn cache_stats(&self) -> Option<PlanCacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Persisted-plan count, when the session persists.
    pub fn store_len(&self) -> Option<usize> {
        self.store.as_ref().map(|s| s.len())
    }

    /// Persisted-plan count this session could actually seed from
    /// (model tag + method + plan geometry, any length) — the honest
    /// input to warm-start expectations like the serve plan-hit prior
    /// (mirrors `AttentionSession::store_len_compatible`).
    pub fn store_len_compatible(&self) -> Option<usize> {
        let store = self.store.as_ref()?;
        let (tile, step) = self.method.plan_geometry();
        Some(store.len_compatible(&self.model, self.method.name(), tile, step))
    }

    /// Store-to-cache seeding events so far (coordinator-side; shard
    /// workers never seed).
    pub fn store_seeded(&self) -> u64 {
        self.store_seeded
    }

    /// Warm the shared cache for `(n, d)` exactly once per length change —
    /// the coordinator-side half of the worker `shard_worker` contract: a
    /// single invalidate+seed here, instead of one racing per shard.
    fn prepare(&mut self, n: usize, d: usize) {
        let Some(cache) = self.cache.clone() else {
            self.current_n = Some(n);
            return;
        };
        if self.current_n == Some(n) {
            return;
        }
        if self.current_n.is_some() {
            cache.invalidate();
        }
        if let Some(store) = self.store.as_mut() {
            self.store_seeded +=
                seed_cache_from_store(&cache, store, &self.model, &self.method, n, d);
        }
        self.current_n = Some(n);
    }

    fn sync_store(&mut self, n: usize, d: usize) {
        let Some(cache) = self.cache.clone() else { return };
        if let Some(store) = self.store.as_mut() {
            sync_cache_to_store(store, &cache, &self.model, n, d);
        }
    }

    /// Run the method on a multi-head batch across the shard workers.
    /// Output, plans, and cache/ident accounting are bitwise-identical to
    /// the unsharded [`AttentionSession::run_batch`] in every
    /// configuration — including over the wire; a failed, panicked, dead,
    /// or deadline-missing shard surfaces as an `Err` naming it.
    pub fn run_batch(&mut self, batch: &BatchInput) -> Result<SessionOutput> {
        let n = batch.n();
        let d = batch.d();
        self.prepare(n, d);
        let keys = self.keys.keys_for(batch.h())?;
        let shards = self.shards;

        // Deterministic round-robin by PlanKey: the batch's distinct keys
        // in (layer, head_group) order, key j -> shard j % S. Sorting (not
        // first-seen order) keeps the assignment stable across batches
        // with different head compositions, so a warm key re-routes to the
        // shard-independent shared cache either way. All heads of one key
        // land on one shard, preserving the unsharded path's
        // one-identification-per-fresh-key accounting.
        let mut distinct: Vec<PlanKey> = keys.clone();
        distinct.sort_by_key(|k| (k.layer, k.head_group));
        distinct.dedup();
        let shard_of: HashMap<PlanKey, usize> =
            distinct.iter().enumerate().map(|(j, &k)| (k, j % shards)).collect();

        // Partition heads, preserving original order within each shard.
        let mut head_idx: Vec<Vec<usize>> = vec![Vec::new(); shards];
        for (h, k) in keys.iter().enumerate() {
            head_idx[shard_of[k]].push(h);
        }

        let out = match &mut self.backend {
            ShardBackend::Threads(workers) => {
                Self::run_threads(workers, batch, keys, head_idx)?
            }
            ShardBackend::Remote(remotes) => {
                Self::run_remote(remotes, &self.cache, batch, &keys, head_idx)?
            }
        };
        self.sync_store(n, d);
        Ok(out)
    }

    /// In-process transport: shard sessions on scoped threads over the
    /// shared cache.
    fn run_threads(
        workers: &mut [AttentionSession],
        batch: &BatchInput,
        keys: Vec<PlanKey>,
        mut head_idx: Vec<Vec<usize>>,
    ) -> Result<SessionOutput> {
        let shards = workers.len();
        // Fast path: every head routed to one shard (shards == 1, or few
        // distinct keys). Run the whole batch on that worker in place —
        // no sub-batch copies, no thread spawn — so the unsharded grid
        // pays zero dispatch overhead over a plain session. Panics are
        // still caught (the same loud-failure contract as the threaded
        // path).
        let occupied: Vec<usize> = (0..shards).filter(|&s| !head_idx[s].is_empty()).collect();
        if occupied.len() == 1 {
            let s = occupied[0];
            let worker = &mut workers[s];
            worker.set_keys(keys);
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                worker.run_batch(batch)
            }));
            return match run {
                Ok(r) => r.map_err(|e| anyhow!("shard {s} failed: {e}")),
                Err(e) => Err(anyhow!("shard {s} failed: {}", panic_message(&*e))),
            };
        }

        // One job per non-empty shard: the shard's sub-batch plus the
        // matching per-head keys. Each head's Q/K/V is copied to exactly
        // ONE worker — a stand-in for the one-time placement transfer of
        // a multi-device deployment — never replicated across shards;
        // only plan coordinates are shared. The copy is the multi-shard
        // dispatch cost (the single-shard path above pays none), bounded
        // by one batch, not by shard count.
        struct ShardJob<'w> {
            shard: usize,
            worker: &'w mut AttentionSession,
            heads: Vec<usize>,
            sub: BatchInput,
            keys: Vec<PlanKey>,
        }
        let mut jobs: Vec<ShardJob<'_>> = Vec::new();
        for (s, worker) in workers.iter_mut().enumerate() {
            let hs = std::mem::take(&mut head_idx[s]);
            if hs.is_empty() {
                continue;
            }
            let sub = BatchInput::new(hs.iter().map(|&h| batch.heads[h].clone()).collect());
            let sub_keys: Vec<PlanKey> = hs.iter().map(|&h| keys[h]).collect();
            jobs.push(ShardJob { shard: s, worker, heads: hs, sub, keys: sub_keys });
        }

        // Dispatch: one thread per shard job (the workers' own executors
        // parallelize within each shard over the shared pool). Joining
        // every handle before merging keeps a failing shard from leaking
        // its siblings.
        type ShardResult = (usize, Vec<usize>, Result<SessionOutput, String>);
        let mut results: Vec<ShardResult> = Vec::with_capacity(jobs.len());
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(jobs.len());
            for job in jobs {
                let ShardJob { shard, worker, heads, sub, keys } = job;
                let handle = scope.spawn(move || {
                    worker.set_keys(keys);
                    worker.run_batch(&sub).map_err(|e| e.to_string())
                });
                handles.push((shard, heads, handle));
            }
            for (shard, heads, handle) in handles {
                let r = match handle.join() {
                    Ok(r) => r,
                    Err(e) => Err(panic_message(&*e)),
                };
                results.push((shard, heads, r));
            }
        });

        // Merge: outputs and plans return to original head positions;
        // hit/miss/ident accounting sums; pipeline stats aggregate with
        // concurrent wall time (max) and summed stage times.
        let mut merge = Merge::new(batch.h());
        for (s, hs, r) in results {
            let out = r.map_err(|msg| anyhow!("shard {s} failed: {msg}"))?;
            merge.accounting(
                out.cache_hits,
                out.cache_misses,
                out.ident_cost_paid,
                out.pipeline,
            );
            merge.speculative(
                out.speculative_hits,
                out.speculative_fallbacks,
                out.speculative_recall,
            );
            for ((&h, o), p) in hs.iter().zip(out.outputs).zip(out.plans) {
                merge.place(h, o, p);
            }
        }
        Ok(merge.finish())
    }

    /// Wire transport: each occupied shard gets one Dispatch frame
    /// (sub-batch Q/K/V + keys + cache seeds for those keys) on its own
    /// thread; replies carry output rows and delta-encoded plan
    /// coordinates, from which `predicted_cost` and `Coverage` are
    /// re-derived — bitwise, because the pricing walk is pure integer
    /// arithmetic and floats crossed as raw bits.
    fn run_remote(
        remotes: &mut [RemoteShard],
        cache: &Option<Arc<PlanCache>>,
        batch: &BatchInput,
        keys: &[PlanKey],
        mut head_idx: Vec<Vec<usize>>,
    ) -> Result<SessionOutput> {
        let snapshot: Vec<(PlanKey, Arc<SparsePlan>)> =
            cache.as_ref().map(|c| c.snapshot()).unwrap_or_default();

        struct RemoteJob<'w> {
            shard: usize,
            remote: &'w mut RemoteShard,
            heads: Vec<usize>,
            msg: DispatchMsg,
        }
        let mut jobs: Vec<RemoteJob<'_>> = Vec::new();
        for (s, remote) in remotes.iter_mut().enumerate() {
            let hs = std::mem::take(&mut head_idx[s]);
            if hs.is_empty() {
                continue;
            }
            let sub_keys: Vec<PlanKey> = hs.iter().map(|&h| keys[h]).collect();
            // Seeds: the coordinator cache's current plans for exactly the
            // keys this shard owns — the wire stand-in for the thread
            // workers' shared-cache reads, and what makes the worker's
            // hit/miss accounting land where the thread path puts it.
            let seeds: Vec<(PlanKey, Arc<SparsePlan>)> =
                snapshot.iter().filter(|(k, _)| sub_keys.contains(k)).cloned().collect();
            let msg = DispatchMsg {
                seq: 0, // assigned by the transport
                keys: sub_keys,
                seeds,
                heads: hs.iter().map(|&h| batch.heads[h].clone()).collect(),
            };
            jobs.push(RemoteJob { shard: s, remote, heads: hs, msg });
        }

        type RemoteResult = (usize, Vec<usize>, Result<ReplyMsg, String>);
        let mut results: Vec<RemoteResult> = Vec::with_capacity(jobs.len());
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(jobs.len());
            for job in jobs {
                let RemoteJob { shard, remote, heads, mut msg } = job;
                let handle =
                    scope.spawn(move || remote.round_trip(&mut msg).map_err(|e| e.to_string()));
                handles.push((shard, heads, handle));
            }
            for (shard, heads, handle) in handles {
                let r = match handle.join() {
                    Ok(r) => r,
                    Err(e) => Err(panic_message(&*e)),
                };
                results.push((shard, heads, r));
            }
        });

        let mut merge = Merge::new(batch.h());
        for (s, hs, r) in results {
            let reply = r.map_err(|msg| anyhow!("shard {s} failed: {msg}"))?;
            if reply.outs.len() != hs.len() {
                return Err(anyhow!(
                    "shard {s} failed: reply carried {} head(s) for {} dispatched",
                    reply.outs.len(),
                    hs.len()
                ));
            }
            merge.accounting(reply.cache_hits, reply.cache_misses, reply.ident_paid, reply.pipeline);
            for ((&h, (mat, cost)), &pi) in hs.iter().zip(reply.outs).zip(&reply.plan_of) {
                let plan = reply.plans[pi as usize].clone();
                if plan.n != batch.n() || mat.rows != batch.n() || mat.cols != batch.d() {
                    return Err(anyhow!(
                        "shard {s} failed: reply geometry {}×{} / plan n {} for a {}×{} batch",
                        mat.rows,
                        mat.cols,
                        plan.n,
                        batch.n(),
                        batch.d()
                    ));
                }
                // Warm the coordinator cache so the next batch's seeds make
                // this key a worker-side hit (an existing entry wins — same
                // plan by determinism).
                if let Some(c) = cache {
                    c.seed(keys[h], plan.clone());
                }
                let coverage = plan.coverage();
                merge.place(h, AttnOutput { out: mat, coverage, cost }, plan);
            }
        }
        Ok(merge.finish())
    }

    /// Write filed plans back to the runtime manifest (no-op when the
    /// session does not persist). Also runs on drop, best-effort.
    pub fn flush(&mut self) -> Result<()> {
        match self.store.as_mut() {
            Some(store) => store.flush(),
            None => Ok(()),
        }
    }
}

/// Shared merge state for both transports: outputs/plans return to
/// original head positions, accounting sums, pipeline stats aggregate
/// with concurrent wall time (max) and summed stage times.
struct Merge {
    outputs: Vec<Option<AttnOutput>>,
    plans: Vec<Option<Arc<SparsePlan>>>,
    cache_hits: u64,
    cache_misses: u64,
    ident_paid: CostTally,
    pipeline: Option<PipelineStats>,
    speculative_hits: u64,
    speculative_fallbacks: u64,
    // Recall mean weighted by each shard's check count (hits + fallbacks),
    // so the merged `speculative_recall` equals the mean over all checks.
    recall_weighted: f64,
}

impl Merge {
    fn new(h: usize) -> Self {
        Self {
            outputs: (0..h).map(|_| None).collect(),
            plans: (0..h).map(|_| None).collect(),
            cache_hits: 0,
            cache_misses: 0,
            ident_paid: CostTally::default(),
            pipeline: None,
            speculative_hits: 0,
            speculative_fallbacks: 0,
            recall_weighted: 0.0,
        }
    }

    fn speculative(&mut self, hits: u64, fallbacks: u64, recall: Option<f64>) {
        self.speculative_hits += hits;
        self.speculative_fallbacks += fallbacks;
        if let Some(r) = recall {
            self.recall_weighted += r * (hits + fallbacks) as f64;
        }
    }

    fn accounting(
        &mut self,
        hits: u64,
        misses: u64,
        ident: CostTally,
        pipeline: Option<PipelineStats>,
    ) {
        self.cache_hits += hits;
        self.cache_misses += misses;
        self.ident_paid.add(ident);
        if let Some(st) = pipeline {
            let agg = self.pipeline.get_or_insert_with(PipelineStats::default);
            agg.ident_total_s += st.ident_total_s;
            agg.ident_hidden_s += st.ident_hidden_s;
            agg.exec_total_s += st.exec_total_s;
            agg.stall_s += st.stall_s;
            agg.wall_s = agg.wall_s.max(st.wall_s);
            agg.items += st.items;
        }
    }

    fn place(&mut self, h: usize, out: AttnOutput, plan: Arc<SparsePlan>) {
        self.outputs[h] = Some(out);
        self.plans[h] = Some(plan);
    }

    fn finish(self) -> SessionOutput {
        SessionOutput {
            outputs: self
                .outputs
                .into_iter()
                .map(|o| o.expect("every head owned by exactly one shard"))
                .collect(),
            plans: self
                .plans
                .into_iter()
                .map(|p| p.expect("every head's plan owned by exactly one shard"))
                .collect(),
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
            ident_cost_paid: self.ident_paid,
            pipeline: self.pipeline,
            speculative_hits: self.speculative_hits,
            speculative_fallbacks: self.speculative_fallbacks,
            speculative_recall: {
                let checks = self.speculative_hits + self.speculative_fallbacks;
                (checks > 0).then(|| self.recall_weighted / checks as f64)
            },
        }
    }
}

impl Drop for ShardedSession {
    fn drop(&mut self) {
        if let Some(store) = self.store.as_mut() {
            let _ = store.flush();
        }
    }
}

impl Method {
    /// Sharded-session builder for this method: `shards` head-group
    /// workers behind one `run_batch` (DESIGN.md §12). `shards == 1` is
    /// the unsharded session with identical bits.
    pub fn sharded_session(&self, shards: usize) -> ShardedSessionBuilder {
        ShardedSession::builder(self.clone(), shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::anchor::AnchorConfig;
    use crate::attention::{HeadInput, TileConfig};
    use crate::tensor::Mat;
    use crate::util::rng::Pcg64;
    use crate::wire::worker::serve_uds;
    use std::time::Duration;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn anchor_method() -> Method {
        Method::Anchor(AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta: 4.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        })
    }

    #[test]
    fn build_rejects_zero_shards() {
        let err = anchor_method().sharded_session(0).build().map(|_| ()).unwrap_err().to_string();
        assert!(err.contains("shards"), "{err}");
    }

    #[test]
    fn build_rejects_persist_without_cache() {
        let err = anchor_method()
            .sharded_session(2)
            .no_cache()
            .persist("/nonexistent/manifest.json")
            .build()
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("cache"), "{err}");
    }

    #[test]
    fn build_rejects_store_cap_without_persist() {
        let err = anchor_method()
            .sharded_session(2)
            .store_max_entries(8)
            .build()
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("persist"), "{err}");
    }

    /// More shards than distinct keys: the extra workers idle, every head
    /// still executes exactly once.
    #[test]
    fn more_shards_than_keys_still_covers_every_head() {
        let heads: Vec<HeadInput> = (0..3).map(|i| rand_head(60 + i, 64, 8)).collect();
        let batch = BatchInput::new(heads);
        let m = anchor_method();
        let mut sharded = m.sharded_session(8).build().unwrap();
        let out = sharded.run_batch(&batch).unwrap();
        assert_eq!(out.outputs.len(), 3);
        assert_eq!(out.cache_hits + out.cache_misses, 3);
        let mut unsharded = m.session().build().unwrap();
        let base = unsharded.run_batch(&batch).unwrap();
        for (a, b) in base.outputs.iter().zip(&out.outputs) {
            assert_eq!(a.out.data, b.out.data);
            assert_eq!(a.cost, b.cost);
        }
    }

    /// Heads of one key land on one shard: plan `Arc`s are shared exactly
    /// as in the unsharded cached path, and a second batch runs warm
    /// through the shared cache.
    #[test]
    fn key_groups_stay_shard_local_and_warm_across_batches() {
        let shared = rand_head(71, 96, 8);
        let batch = BatchInput::new(vec![shared.clone(), shared.clone(), shared]);
        let keys = vec![PlanKey::new(0, 0), PlanKey::new(0, 0), PlanKey::new(0, 1)];
        let m = anchor_method();
        let mut sharded = m.sharded_session(2).keys(keys).build().unwrap();
        let cold = sharded.run_batch(&batch).unwrap();
        assert_eq!((cold.cache_hits, cold.cache_misses), (1, 2));
        assert!(Arc::ptr_eq(&cold.plans[0], &cold.plans[1]));
        let warm = sharded.run_batch(&batch).unwrap();
        assert_eq!((warm.cache_hits, warm.cache_misses), (3, 0));
        assert_eq!(warm.ident_cost_paid, CostTally::default());
        assert!((warm.hit_rate() - 1.0).abs() < 1e-12);
    }

    /// A length change invalidates the shared cache exactly once at the
    /// coordinator, not once per shard.
    #[test]
    fn length_change_invalidates_shared_cache_once() {
        let m = anchor_method();
        let mut sharded = m.sharded_session(2).build().unwrap();
        let a = sharded.run_batch(&BatchInput::new(vec![rand_head(80, 96, 8)])).unwrap();
        assert_eq!(a.plans[0].n, 96);
        let b = sharded.run_batch(&BatchInput::new(vec![rand_head(81, 64, 8)])).unwrap();
        assert_eq!(b.plans[0].n, 64);
        assert_eq!((b.cache_hits, b.cache_misses), (0, 1));
    }

    /// Sharded persistence warm-starts a restarted sharded session, same
    /// contract as the unsharded session (DESIGN.md §11/§12).
    #[test]
    fn sharded_session_warm_starts_from_the_store() {
        let path = std::env::temp_dir()
            .join(format!("anchor_shard_store_{}.json", std::process::id()));
        std::fs::write(&path, "{}\n").unwrap();
        let heads: Vec<HeadInput> = (0..4).map(|i| rand_head(90 + i, 96, 8)).collect();
        let batch = BatchInput::new(heads);
        let m = anchor_method();
        let cold_out = {
            let mut cold = m
                .sharded_session(2)
                .persist(&path)
                .model("shard-restart")
                .build()
                .unwrap();
            let out = cold.run_batch(&batch).unwrap();
            assert!(out.ident_cost_paid.ident_scores > 0, "cold run must identify");
            cold.flush().unwrap();
            assert_eq!(cold.store_len(), Some(4));
            out
        };
        let mut warm = m
            .sharded_session(3)
            .persist(&path)
            .model("shard-restart")
            .build()
            .unwrap();
        let out = warm.run_batch(&batch).unwrap();
        assert_eq!((out.cache_hits, out.cache_misses), (4, 0));
        assert_eq!(out.ident_cost_paid, CostTally::default());
        assert_eq!(warm.store_seeded(), 4);
        for (a, b) in cold_out.outputs.iter().zip(&out.outputs) {
            assert_eq!(a.out.data, b.out.data, "warm output must be bitwise-identical");
        }
        drop(warm);
        let _ = std::fs::remove_file(&path);
    }

    // -- remote transport (in-process workers over UDS; true child
    //    processes are exercised in tests/wire_parity.rs, where the built
    //    binary is available) --

    fn worker_sockets(tag: &str, count: usize) -> Vec<std::path::PathBuf> {
        (0..count)
            .map(|i| {
                std::env::temp_dir().join(format!(
                    "anchor_shard_test_{tag}_{}_{i}.sock",
                    std::process::id()
                ))
            })
            .collect()
    }

    fn start_workers(paths: &[std::path::PathBuf]) -> Vec<std::thread::JoinHandle<()>> {
        paths
            .iter()
            .map(|p| {
                let p = p.clone();
                std::thread::spawn(move || {
                    serve_uds(&p).expect("worker serve loop");
                })
            })
            .collect()
    }

    #[test]
    fn remote_endpoint_count_must_match_shards() {
        let err = anchor_method()
            .sharded_session(2)
            .remote(RemoteSpec::Endpoints(vec![ShardEndpoint::Tcp("127.0.0.1:1".into())]))
            .build()
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("endpoint"), "{err}");
    }

    /// The full wire loop against in-process workers: outputs, costs, plan
    /// coordinates, and hit/miss/ident accounting are bitwise-equal to the
    /// thread transport, cold and warm.
    #[test]
    fn remote_uds_workers_match_thread_shards_bitwise() {
        let sockets = worker_sockets("parity", 2);
        let handles = start_workers(&sockets);
        let heads: Vec<HeadInput> = (0..5).map(|i| rand_head(300 + i, 96, 8)).collect();
        let batch = BatchInput::new(heads);
        let keys = vec![
            PlanKey::new(0, 0),
            PlanKey::new(0, 0),
            PlanKey::new(0, 1),
            PlanKey::new(0, 1),
            PlanKey::new(0, 2),
        ];
        let m = anchor_method();
        let mut threads = m.sharded_session(2).keys(keys.clone()).build().unwrap();
        let mut remote = m
            .sharded_session(2)
            .keys(keys)
            .remote(RemoteSpec::Endpoints(
                sockets.iter().cloned().map(ShardEndpoint::Uds).collect(),
            ))
            .build()
            .unwrap();
        assert!(remote.is_remote() && !threads.is_remote());
        for round in 0..2 {
            let a = threads.run_batch(&batch).unwrap();
            let b = remote.run_batch(&batch).unwrap();
            assert_eq!((a.cache_hits, a.cache_misses), (b.cache_hits, b.cache_misses), "round {round}");
            assert_eq!(a.ident_cost_paid, b.ident_cost_paid, "round {round}");
            for (x, y) in a.outputs.iter().zip(&b.outputs) {
                assert_eq!(x.out.data, y.out.data, "round {round}: outputs must be bitwise");
                assert_eq!(x.cost, y.cost, "round {round}");
                assert_eq!(x.coverage.total_covered(), y.coverage.total_covered());
            }
            for (p, q) in a.plans.iter().zip(&b.plans) {
                assert_eq!(**p, **q, "round {round}: plan coordinates must match");
            }
        }
        // Key-group plan sharing survives the wire (per-batch Arc dedup).
        let b = remote.run_batch(&batch).unwrap();
        assert!(Arc::ptr_eq(&b.plans[0], &b.plans[1]));
        drop(remote);
        for h in handles {
            h.join().unwrap();
        }
    }

    /// An unreachable worker fails the batch with an error naming the
    /// shard — the thread path's loud-failure contract, over the wire.
    #[test]
    fn remote_connect_timeout_names_the_shard() {
        let missing = std::env::temp_dir().join("anchor_shard_test_never_bound.sock");
        let _ = std::fs::remove_file(&missing);
        let mut remote = anchor_method()
            .sharded_session(1)
            .remote(RemoteSpec::Endpoints(vec![ShardEndpoint::Uds(missing)]))
            .wire_timeouts(WireTimeouts {
                connect: Duration::from_millis(80),
                read: Duration::from_secs(1),
                retries: 1,
                backoff: Duration::from_millis(10),
            })
            .build()
            .unwrap();
        let batch = BatchInput::new(vec![rand_head(400, 64, 8)]);
        let err = remote.run_batch(&batch).unwrap_err().to_string();
        assert!(err.contains("shard 0"), "{err}");
        assert!(err.contains("attempt"), "{err}");
    }

    /// `no_cache` over the wire matches `no_cache` over threads: every
    /// head re-identifies, no seeds cross.
    #[test]
    fn remote_no_cache_matches_threads() {
        let sockets = worker_sockets("nocache", 2);
        let handles = start_workers(&sockets);
        let heads: Vec<HeadInput> = (0..3).map(|i| rand_head(500 + i, 64, 8)).collect();
        let batch = BatchInput::new(heads);
        let m = anchor_method();
        let mut threads = m.sharded_session(2).no_cache().build().unwrap();
        let mut remote = m
            .sharded_session(2)
            .no_cache()
            .remote(RemoteSpec::Endpoints(
                sockets.iter().cloned().map(ShardEndpoint::Uds).collect(),
            ))
            .build()
            .unwrap();
        let a = threads.run_batch(&batch).unwrap();
        let b = remote.run_batch(&batch).unwrap();
        assert_eq!((b.cache_hits, b.cache_misses), (0, 3));
        assert_eq!((a.cache_hits, a.cache_misses), (b.cache_hits, b.cache_misses));
        for (x, y) in a.outputs.iter().zip(&b.outputs) {
            assert_eq!(x.out.data, y.out.data);
            assert_eq!(x.cost, y.cost);
        }
        drop(remote);
        for h in handles {
            h.join().unwrap();
        }
    }
}

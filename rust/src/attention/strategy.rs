//! Identification-strategy analysis (§2.1, Fig. 4/8/9/10, Table 1):
//! given the *pooled* score matrix (`avgpool(Q, b_q) · Kᵀ/√d`) of a head,
//! select important keys per query block with
//!
//! * **top-k** — fixed count, needs sorting, misses with dynamic inputs;
//! * **top-cdf** — smallest set reaching cumulative mass γ, needs sorting;
//! * **difference-aware** — `anchor − score ≤ θ`, sort-free (the paper's);
//!
//! at either **stripe** granularity `(b_q, 1)` or **block** granularity
//! `(b_q, b_kv)`. The resulting [`Coverage`] feeds the shared recall /
//! sparsity metrics so the strategies are compared apples-to-apples.

//! Each strategy emits a [`SparsePlan`] ([`select_plan`]): stripes become
//! plan stripes, block selections become plan spans, and the recall /
//! sparsity metrics read the plan's coverage directly — no attention is
//! executed anywhere in the strategy analysis.

use crate::attention::mask::Coverage;
use crate::attention::plan::{GroupPlan, SparsePlan};
use crate::attention::{CostTally, HeadInput, TileConfig};
use crate::tensor::ops::avgpool_rows;
use crate::tensor::{matmul_nt_scaled, Mat};

/// Which selection rule to apply to the pooled scores.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    /// Keep the `k` highest-scoring units per query block.
    TopK { k: usize },
    /// Keep the smallest set of units whose softmax mass reaches `gamma`.
    TopCdf { gamma: f64 },
    /// Keep units with `anchor − score ≤ theta` (difference-aware).
    DiffAware { theta: f32 },
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::TopK { .. } => "top-k",
            Strategy::TopCdf { .. } => "top-cdf",
            Strategy::DiffAware { .. } => "difference-aware",
        }
    }
}

/// Selection granularity (§2.1.2): stripes select individual keys, blocks
/// select contiguous `b_kv` ranges.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    Stripe,
    Block,
}

/// Pooled score matrix plus per-block anchors for a head.
#[derive(Clone)]
pub struct PooledScores {
    /// `[q_blocks, n]` pooled logits (causally valid region only is used).
    pub scores: Mat,
    /// Per-query-block anchor: max pooled score over sink + diagonal
    /// regions (what Alg. 1/2 would provide at this granularity).
    pub anchors: Vec<f32>,
    pub tile: TileConfig,
    pub n: usize,
    /// Head dim of the scored head (prices the emitted plans).
    pub d: usize,
}

/// Build pooled scores for strategy analysis.
pub fn pooled_scores(input: &HeadInput, tile: TileConfig) -> PooledScores {
    let n = input.n();
    let q_pool = avgpool_rows(&input.q, tile.b_q);
    let mut scores = Mat::zeros(q_pool.rows, n);
    matmul_nt_scaled(&q_pool, &input.k, input.scale(), &mut scores);

    // Anchor at pooled granularity: max over init block ∪ diagonal block.
    let init_cols = tile.b_kv.min(n);
    let mut anchors = Vec::with_capacity(q_pool.rows);
    for qb in 0..q_pool.rows {
        let limit = ((qb + 1) * tile.b_q).min(n);
        let win_start = qb * tile.b_q;
        let row = scores.row(qb);
        let mut a = f32::NEG_INFINITY;
        for col in 0..init_cols.min(limit) {
            a = a.max(row[col]);
        }
        for col in win_start..limit {
            a = a.max(row[col]);
        }
        anchors.push(a);
    }
    PooledScores { scores, anchors, tile, n, d: input.d() }
}

/// Apply a strategy at a granularity, emitting a per-query-block
/// [`SparsePlan`]: stripe selections become plan stripes, block
/// selections become plan spans. The plan is executable by
/// [`crate::attention::plan::execute_plan`] and analyzable via
/// [`SparsePlan::coverage`] without execution.
pub fn select_plan(ps: &PooledScores, strategy: Strategy, gran: Granularity) -> SparsePlan {
    let tile = ps.tile;
    let n = ps.n;
    let mut groups = Vec::with_capacity(ps.scores.rows);
    for qb in 0..ps.scores.rows {
        let limit = ((qb + 1) * tile.b_q).min(n);
        let row = &ps.scores.row(qb)[..limit];
        let mut gp = GroupPlan::default();
        match gran {
            Granularity::Stripe => {
                select_units(row, strategy, ps.anchors[qb], |col| gp.stripes.push(col as u32));
                gp.stripes.sort_unstable();
            }
            Granularity::Block => {
                // Aggregate stripe scores to block scores by mean.
                let blocks = limit.div_ceil(tile.b_kv);
                let mut bscores = Vec::with_capacity(blocks);
                for jb in 0..blocks {
                    let s = jb * tile.b_kv;
                    let e = (s + tile.b_kv).min(limit);
                    bscores.push(row[s..e].iter().sum::<f32>() / (e - s) as f32);
                }
                select_units(&bscores, strategy, ps.anchors[qb], |jb| {
                    let s = jb * tile.b_kv;
                    gp.spans.push((s as u32, ((s + tile.b_kv).min(limit)) as u32));
                });
                // Merge adjacent selected blocks into maximal spans.
                gp.spans.sort_unstable();
                let mut merged: Vec<(u32, u32)> = Vec::with_capacity(gp.spans.len());
                for (s, e) in gp.spans.drain(..) {
                    match merged.last_mut() {
                        Some(last) if last.1 >= s => last.1 = last.1.max(e),
                        _ => merged.push((s, e)),
                    }
                }
                gp.spans = merged;
            }
        }
        groups.push(gp);
    }
    // Identification here scored every causal (pooled-row, key) pair.
    let total_scores: usize =
        (0..ps.scores.rows).map(|qb| ((qb + 1) * tile.b_q).min(n)).sum();
    let ident = CostTally {
        flops: 2 * (total_scores * ps.d) as u64,
        kv_bytes: (n * ps.d * 4) as u64,
        ident_scores: total_scores as u64,
    };
    SparsePlan::new(strategy.name(), n, ps.d, tile, 1, groups, ident)
}

/// Apply a strategy at a granularity; returns coverage over `(b_q, 1)`
/// pairs (block selections expand to their member columns). Thin wrapper
/// over [`select_plan`].
pub fn select(ps: &PooledScores, strategy: Strategy, gran: Granularity) -> Coverage {
    select_plan(ps, strategy, gran).coverage()
}

/// Core selection over a score vector; invokes `mark` for chosen units.
fn select_units(scores: &[f32], strategy: Strategy, anchor: f32, mut mark: impl FnMut(usize)) {
    match strategy {
        Strategy::TopK { k } => {
            let mut order: Vec<usize> = (0..scores.len()).collect();
            let k = k.min(scores.len());
            order.sort_unstable_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            for &i in &order[..k] {
                mark(i);
            }
        }
        Strategy::TopCdf { gamma } => {
            let mx = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let probs: Vec<f64> = scores.iter().map(|&x| ((x - mx) as f64).exp()).collect();
            let z: f64 = probs.iter().sum();
            let mut order: Vec<usize> = (0..scores.len()).collect();
            order.sort_unstable_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
            let mut cum = 0.0;
            for &i in &order {
                if cum >= gamma * z {
                    break;
                }
                cum += probs[i];
                mark(i);
            }
        }
        Strategy::DiffAware { theta } => {
            for (i, &s) in scores.iter().enumerate() {
                if anchor - s <= theta {
                    mark(i);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    #[test]
    fn topk_selects_exactly_k_stripes() {
        let h = rand_head(101, 128, 8);
        let tile = TileConfig::new(16, 16);
        let ps = pooled_scores(&h, tile);
        let cov = select(&ps, Strategy::TopK { k: 5 }, Granularity::Stripe);
        for qb in 0..8 {
            let limit = (qb + 1) * 16;
            assert_eq!(cov.count(qb), 5.min(limit), "qb {qb}");
        }
    }

    #[test]
    fn topcdf_gamma_one_selects_everything() {
        let h = rand_head(102, 64, 8);
        let tile = TileConfig::new(16, 16);
        let ps = pooled_scores(&h, tile);
        let cov = select(&ps, Strategy::TopCdf { gamma: 1.0 }, Granularity::Stripe);
        assert_eq!(cov.sparsity(), 0.0);
    }

    #[test]
    fn diff_aware_threshold_rule() {
        let h = rand_head(103, 64, 8);
        let tile = TileConfig::new(16, 16);
        let ps = pooled_scores(&h, tile);
        let cov = select(&ps, Strategy::DiffAware { theta: 2.0 }, Granularity::Stripe);
        for qb in 0..4 {
            let limit = (qb + 1) * 16;
            for col in 0..limit {
                let expect = ps.anchors[qb] - ps.scores.at(qb, col) <= 2.0;
                assert_eq!(cov.covered(qb, col), expect, "qb {qb} col {col}");
            }
        }
    }

    #[test]
    fn block_granularity_selects_whole_blocks() {
        let h = rand_head(104, 128, 8);
        let tile = TileConfig::new(16, 16);
        let ps = pooled_scores(&h, tile);
        let cov = select(&ps, Strategy::TopK { k: 2 }, Granularity::Block);
        for qb in 0..8 {
            let cnt = cov.count(qb);
            // 2 blocks of 16 columns (or fewer for early rows).
            assert_eq!(cnt % 16, 0, "qb {qb}: {cnt} not block-aligned");
            assert!(cnt <= 32);
        }
    }

    #[test]
    fn stripe_beats_block_sparsity_at_same_budget() {
        // Table 1's core claim: at matched covered-token budget, stripe
        // selection concentrates coverage on high-mass keys. Verify stripe
        // top-k (k=16) recall >= block top-k (k=1 block = 16 cols) recall.
        let h = rand_head(105, 256, 16);
        let tile = TileConfig::new(16, 16);
        let ps = pooled_scores(&h, tile);
        let stripe = select(&ps, Strategy::TopK { k: 16 }, Granularity::Stripe);
        let block = select(&ps, Strategy::TopK { k: 1 }, Granularity::Block);
        let r_stripe = crate::attention::metrics::recall(&h, &stripe, tile);
        let r_block = crate::attention::metrics::recall(&h, &block, tile);
        assert!(
            r_stripe.mean_recall >= r_block.mean_recall - 1e-9,
            "stripe {} vs block {}",
            r_stripe.mean_recall,
            r_block.mean_recall
        );
    }

    /// Strategy plans are executable: the executor's output matches the
    /// masked-softmax reference for the plan's coverage.
    #[test]
    fn strategy_plans_execute_consistently() {
        let h = rand_head(107, 96, 8);
        let tile = TileConfig::new(16, 16);
        let ps = pooled_scores(&h, tile);
        for (strategy, gran) in [
            (Strategy::TopK { k: 8 }, Granularity::Stripe),
            (Strategy::TopCdf { gamma: 0.8 }, Granularity::Block),
            (Strategy::DiffAware { theta: 1.5 }, Granularity::Stripe),
        ] {
            let plan = select_plan(&ps, strategy, gran);
            assert_eq!(plan.method, strategy.name());
            let out = crate::attention::plan::execute_plan(&h, &plan);
            let expect = crate::attention::plan::masked_reference(&h, &out.coverage);
            assert!(
                out.out.max_abs_diff(&expect) < 1e-4,
                "{:?}/{:?}: {}",
                strategy,
                gran,
                out.out.max_abs_diff(&expect)
            );
            assert_eq!(plan.predicted_cost, out.cost);
        }
    }

    #[test]
    fn anchor_is_max_of_sink_and_diag() {
        let h = rand_head(106, 64, 8);
        let tile = TileConfig::new(16, 16);
        let ps = pooled_scores(&h, tile);
        for qb in 0..4 {
            let limit = (qb + 1) * 16;
            let row = ps.scores.row(qb);
            let mut expect = f32::NEG_INFINITY;
            for col in 0..16.min(limit) {
                expect = expect.max(row[col]);
            }
            for col in qb * 16..limit {
                expect = expect.max(row[col]);
            }
            assert_eq!(ps.anchors[qb], expect);
        }
    }
}

//! Typed configuration loaded from JSON files (`configs/*.json`).
//!
//! Every field has a default, so configs can be sparse overrides; the CLI
//! further overrides individual fields (`--theta`, `--rate`, …).

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::attention::anchor::AnchorConfig;
use crate::attention::exec::ExecutorKind;
use crate::attention::reuse::ReusePolicy;
use crate::attention::session::{SessionConfig, SessionTransport};
use crate::attention::TileConfig;
use crate::coordinator::scheduler::{CostConstants, SchedulerConfig, SparsityModel};
use crate::coordinator::server::ServerConfig;
use crate::util::json::Json;
use crate::workload::trace::TraceConfig;

/// Top-level application config.
#[derive(Clone, Debug)]
pub struct AppConfig {
    pub artifact_dir: String,
    pub anchor: AnchorConfig,
    pub server: ServerConfig,
    pub trace: TraceConfig,
    /// Attention-session settings (`"session"` block): executor backend,
    /// pipelining, plan cache and manifest-backed plan persistence
    /// (DESIGN.md §11).
    pub session: SessionConfig,
}

impl Default for AppConfig {
    fn default() -> Self {
        Self {
            artifact_dir: "artifacts".to_string(),
            anchor: AnchorConfig::default(),
            server: ServerConfig::default(),
            trace: TraceConfig::default(),
            session: SessionConfig::default(),
        }
    }
}

impl AppConfig {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow!("reading {}: {e}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("config json: {e}"))?;
        let mut cfg = AppConfig::default();

        if let Some(s) = j.get("artifact_dir").as_str() {
            cfg.artifact_dir = s.to_string();
        }

        let a = j.get("anchor");
        if !a.is_null() {
            let d = AnchorConfig::default();
            let b_q = a.get("b_q").as_usize().unwrap_or(d.tile.b_q);
            let b_kv = a.get("b_kv").as_usize().unwrap_or(d.tile.b_kv);
            cfg.anchor = AnchorConfig {
                tile: TileConfig::new(b_q, b_kv),
                theta: a.get("theta").as_f64().unwrap_or(d.theta as f64) as f32,
                step: a.get("step").as_usize().unwrap_or(d.step),
                init_blocks: a.get("init_blocks").as_usize().unwrap_or(d.init_blocks),
                use_anchor: a.get("use_anchor").as_bool().unwrap_or(true),
            };
        }

        let s = j.get("server");
        if !s.is_null() {
            let d = ServerConfig::default();
            let sd = SchedulerConfig::default();
            let sched = s.get("scheduler");
            let sparsity = match sched.get("sparsity").as_str() {
                None | Some("dense") => SparsityModel::Dense,
                Some("anchor") => SparsityModel::Anchor {
                    stripe_keep: sched.get("stripe_keep").as_f64().unwrap_or(0.1),
                    anchor_tokens: sched.get("anchor_tokens").as_usize().unwrap_or(256),
                    plan_hit_rate: sched.get("plan_hit_rate").as_f64().unwrap_or(0.0),
                    // Prior on the speculative-reuse hit rate among misses
                    // (DESIGN.md §17); the serve loop's EWMA moves it live.
                    speculative_hit_rate: sched
                        .get("speculative_hit_rate")
                        .as_f64()
                        .unwrap_or(0.0),
                    // Async plan pipeline: price identification as
                    // overlapped with execution (DESIGN.md §9).
                    pipelined: sched.get("pipelined").as_bool().unwrap_or(false),
                    // Executor backend the estimates are attributed to
                    // (DESIGN.md §10): "cpu" (default) or "pjrt".
                    executor: match sched.get("executor").as_str() {
                        None => ExecutorKind::default(),
                        Some(s) => ExecutorKind::parse(s)?,
                    },
                    // Head-group shard workers priced by the cost model
                    // (DESIGN.md §12): near-linear exec scaling plus a
                    // plan-broadcast term; 1 = unsharded.
                    shards: match sched.get("shards").as_usize() {
                        None => 1,
                        Some(0) => return Err(anyhow!("scheduler shards must be >= 1")),
                        Some(s) => s,
                    },
                    // Modeled defaults; `serve --calibration F` swaps in a
                    // measured set from the manifest (DESIGN.md §13).
                    constants: CostConstants::modeled(),
                },
                Some(other) => return Err(anyhow!("unknown sparsity model '{other}'")),
            };
            cfg.server = ServerConfig {
                scheduler: SchedulerConfig {
                    iter_budget: sched.get("iter_budget").as_f64().unwrap_or(sd.iter_budget),
                    chunk: sched.get("chunk").as_usize().unwrap_or(sd.chunk),
                    max_running: sched.get("max_running").as_usize().unwrap_or(sd.max_running),
                    sparsity,
                    decode_token_cost: sched
                        .get("decode_token_cost")
                        .as_f64()
                        .unwrap_or(sd.decode_token_cost),
                    // Under KV pressure, evict the largest in-flight prefill
                    // to admit a smaller queued request (DESIGN.md §16).
                    preempt_prefill: sched
                        .get("preempt_prefill")
                        .as_bool()
                        .unwrap_or(sd.preempt_prefill),
                },
                pool_pages: s.get("pool_pages").as_usize().unwrap_or(d.pool_pages),
                page_tokens: s.get("page_tokens").as_usize().unwrap_or(d.page_tokens),
                max_seq: s.get("max_seq").as_usize().unwrap_or(d.max_seq),
                realtime: s.get("realtime").as_bool().unwrap_or(d.realtime),
                max_pending: match s.get("max_pending").as_usize() {
                    Some(0) => return Err(anyhow!("server max_pending must be >= 1")),
                    cap => cap.or(d.max_pending),
                },
            };
        }

        let se = j.get("session");
        if !se.is_null() {
            let d = SessionConfig::default();
            cfg.session = SessionConfig {
                executor: match se.get("executor").as_str() {
                    None => d.executor,
                    Some(s) => ExecutorKind::parse(s)?,
                },
                pipelined: se.get("pipelined").as_bool().unwrap_or(d.pipelined),
                cache: se.get("cache").as_bool().unwrap_or(d.cache),
                plan_store: se.get("plan_store").as_str().map(|s| s.to_string()),
                model: se.get("model").as_str().unwrap_or(&d.model).to_string(),
                shards: match se.get("shards").as_usize() {
                    None => d.shards,
                    Some(0) => return Err(anyhow!("session shards must be >= 1")),
                    Some(s) => s,
                },
                store_max_entries: match se.get("store_max_entries").as_usize() {
                    Some(0) => {
                        return Err(anyhow!("session store_max_entries must be >= 1"))
                    }
                    cap => cap,
                },
                transport: match se.get("transport").as_str() {
                    None => d.transport,
                    Some(s) => SessionTransport::parse(s)?,
                },
                reuse: parse_reuse(se, d.reuse)?,
            };
        }

        let t = j.get("trace");
        if !t.is_null() {
            let d = TraceConfig::default();
            let length_mix = match t.get("length_mix").as_arr() {
                None => d.length_mix.clone(),
                Some(arr) => arr
                    .iter()
                    .map(|pair| -> Result<(usize, f64)> {
                        let len = pair.idx(0).as_usize().ok_or_else(|| anyhow!("bad mix len"))?;
                        let w = pair.idx(1).as_f64().ok_or_else(|| anyhow!("bad mix weight"))?;
                        Ok((len, w))
                    })
                    .collect::<Result<Vec<_>>>()?,
            };
            cfg.trace = TraceConfig {
                rate: t.get("rate").as_f64().unwrap_or(d.rate),
                num_requests: t.get("num_requests").as_usize().unwrap_or(d.num_requests),
                length_mix,
                decode_min: t.get("decode_min").as_usize().unwrap_or(d.decode_min),
                decode_max: t.get("decode_max").as_usize().unwrap_or(d.decode_max),
                seed: t.get("seed").as_i64().unwrap_or(d.seed as i64) as u64,
            };
            // Reject degenerate traces at parse time, matching the
            // `shards: 0` precedent above.
            cfg.trace.validate().map_err(|e| anyhow!("trace config: {e}"))?;
        }

        Ok(cfg)
    }
}

/// Parse the session block's speculative-reuse keys (DESIGN.md §17):
/// `reuse` names the policy, `reuse_distance` widens cross-layer donor
/// probing, `recall_floor` tightens the acceptance gate. A modifier key
/// that cannot apply to the chosen policy is an error, not a silent
/// no-op — a config asking for a floor must be getting one.
fn parse_reuse(se: &Json, default: ReusePolicy) -> Result<ReusePolicy> {
    let mut policy = match se.get("reuse").as_str() {
        None => default,
        Some(s) => ReusePolicy::parse(s)?,
    };
    match se.get("reuse_distance").as_usize() {
        None => {}
        Some(0) => return Err(anyhow!("session reuse_distance must be >= 1")),
        Some(k) => match policy {
            ReusePolicy::CrossLayer { recall_floor, .. } => {
                policy = ReusePolicy::CrossLayer { max_distance: k as u32, recall_floor };
            }
            other => {
                return Err(anyhow!(
                    "session reuse_distance only applies to reuse \"cross-layer\" \
                     (policy is \"{}\")",
                    other.name()
                ))
            }
        },
    }
    match se.get("recall_floor").as_f64() {
        None => {}
        Some(f) if !(0.0..=1.0).contains(&f) => {
            return Err(anyhow!("session recall_floor must be in [0, 1] (got {f})"))
        }
        Some(_) if policy.is_exact() => {
            return Err(anyhow!(
                "session recall_floor requires reuse \"cross-layer\" or \"prefix\""
            ))
        }
        Some(f) => policy = policy.with_recall_floor(f),
    }
    Ok(policy)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_config_is_defaults() {
        let cfg = AppConfig::parse("{}").unwrap();
        assert_eq!(cfg.artifact_dir, "artifacts");
        assert_eq!(cfg.anchor.theta, 12.0);
        assert_eq!(cfg.server.scheduler.chunk, 256);
    }

    #[test]
    fn sparse_overrides_apply() {
        let cfg = AppConfig::parse(
            r#"{
            "anchor": {"theta": 13.5, "step": 8},
            "server": {"pool_pages": 16,
                       "scheduler": {"sparsity": "anchor", "stripe_keep": 0.05}},
            "trace": {"rate": 7.5, "num_requests": 3,
                      "length_mix": [[128, 1.0]]}
        }"#,
        )
        .unwrap();
        assert_eq!(cfg.anchor.theta, 13.5);
        assert_eq!(cfg.anchor.step, 8);
        assert_eq!(cfg.anchor.init_blocks, 1, "untouched default");
        assert_eq!(cfg.server.pool_pages, 16);
        match cfg.server.scheduler.sparsity {
            SparsityModel::Anchor { stripe_keep, pipelined, .. } => {
                assert_eq!(stripe_keep, 0.05);
                assert!(!pipelined, "pipelined defaults off");
            }
            _ => panic!("expected anchor sparsity"),
        }
        assert_eq!(cfg.trace.rate, 7.5);
        assert_eq!(cfg.trace.length_mix, vec![(128, 1.0)]);
    }

    #[test]
    fn pipelined_sparsity_parses() {
        let cfg = AppConfig::parse(
            r#"{"server": {"scheduler": {"sparsity": "anchor", "pipelined": true}}}"#,
        )
        .unwrap();
        assert!(cfg.server.scheduler.sparsity.is_pipelined());
    }

    #[test]
    fn executor_backend_parses_and_defaults() {
        let cfg = AppConfig::parse(
            r#"{"server": {"scheduler": {"sparsity": "anchor", "executor": "pjrt"}}}"#,
        )
        .unwrap();
        assert_eq!(cfg.server.scheduler.sparsity.executor_kind(), ExecutorKind::Pjrt);
        let cfg = AppConfig::parse(r#"{"server": {"scheduler": {"sparsity": "anchor"}}}"#).unwrap();
        assert_eq!(cfg.server.scheduler.sparsity.executor_kind(), ExecutorKind::Cpu);
        // Dense attributes to the default CPU walk.
        let cfg = AppConfig::parse("{}").unwrap();
        assert_eq!(cfg.server.scheduler.sparsity.executor_kind(), ExecutorKind::Cpu);
        // Unknown backends are rejected.
        let res = AppConfig::parse(
            r#"{"server": {"scheduler": {"sparsity": "anchor", "executor": "tpu"}}}"#,
        );
        assert!(res.is_err());
    }

    #[test]
    fn session_block_parses_and_defaults() {
        let cfg = AppConfig::parse("{}").unwrap();
        assert_eq!(cfg.session, SessionConfig::default());
        let cfg = AppConfig::parse(
            r#"{"session": {"executor": "pjrt", "pipelined": true, "cache": true,
                            "plan_store": "artifacts/manifest.json", "model": "llama-like"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.session.executor, ExecutorKind::Pjrt);
        assert!(cfg.session.pipelined);
        assert_eq!(cfg.session.plan_store.as_deref(), Some("artifacts/manifest.json"));
        assert_eq!(cfg.session.model, "llama-like");
        // Unknown executor in the session block is rejected.
        assert!(AppConfig::parse(r#"{"session": {"executor": "tpu"}}"#).is_err());
    }

    #[test]
    fn shards_parse_in_scheduler_and_session_blocks() {
        let cfg = AppConfig::parse(
            r#"{"server": {"scheduler": {"sparsity": "anchor", "shards": 4}},
                "session": {"shards": 4, "store_max_entries": 64}}"#,
        )
        .unwrap();
        assert_eq!(cfg.server.scheduler.sparsity.shards(), 4);
        assert_eq!(cfg.session.shards, 4);
        assert_eq!(cfg.session.store_max_entries, Some(64));
        // Defaults: unsharded, uncapped.
        let cfg = AppConfig::parse(r#"{"server": {"scheduler": {"sparsity": "anchor"}}}"#).unwrap();
        assert_eq!(cfg.server.scheduler.sparsity.shards(), 1);
        assert_eq!(cfg.session.shards, 1);
        assert_eq!(cfg.session.store_max_entries, None);
        // Zero shards is a configuration error, not a silent clamp.
        assert!(AppConfig::parse(
            r#"{"server": {"scheduler": {"sparsity": "anchor", "shards": 0}}}"#
        )
        .is_err());
        assert!(AppConfig::parse(r#"{"session": {"shards": 0}}"#).is_err());
        assert!(
            AppConfig::parse(r#"{"session": {"store_max_entries": 0}}"#).is_err(),
            "zero store cap is rejected, not silently clamped"
        );
    }

    #[test]
    fn session_transport_parses_and_defaults() {
        let cfg = AppConfig::parse("{}").unwrap();
        assert_eq!(cfg.session.transport, SessionTransport::Threads);
        let cfg = AppConfig::parse(r#"{"session": {"transport": "process"}}"#).unwrap();
        assert_eq!(cfg.session.transport, SessionTransport::Process);
        // Unknown transports are rejected, not defaulted.
        assert!(AppConfig::parse(r#"{"session": {"transport": "carrier-pigeon"}}"#).is_err());
    }

    #[test]
    fn session_reuse_parses_modifiers_and_rejects_misapplied_keys() {
        let cfg = AppConfig::parse("{}").unwrap();
        assert!(cfg.session.reuse.is_exact(), "exact by default");
        let cfg = AppConfig::parse(
            r#"{"session": {"reuse": "cross-layer", "reuse_distance": 3,
                            "recall_floor": 0.9}}"#,
        )
        .unwrap();
        assert_eq!(
            cfg.session.reuse,
            ReusePolicy::CrossLayer { max_distance: 3, recall_floor: 0.9 }
        );
        let cfg =
            AppConfig::parse(r#"{"session": {"reuse": "prefix", "recall_floor": 0.5}}"#).unwrap();
        assert_eq!(cfg.session.reuse, ReusePolicy::Prefix { recall_floor: 0.5 });
        // Misapplied or degenerate modifier keys are errors, not no-ops.
        assert!(AppConfig::parse(r#"{"session": {"reuse": "telepathy"}}"#).is_err());
        assert!(AppConfig::parse(
            r#"{"session": {"reuse": "cross-layer", "reuse_distance": 0}}"#
        )
        .is_err());
        assert!(
            AppConfig::parse(r#"{"session": {"reuse": "prefix", "reuse_distance": 2}}"#).is_err()
        );
        assert!(AppConfig::parse(r#"{"session": {"recall_floor": 0.9}}"#).is_err());
        assert!(AppConfig::parse(
            r#"{"session": {"reuse": "prefix", "recall_floor": 1.5}}"#
        )
        .is_err());
    }

    #[test]
    fn max_pending_parses_and_rejects_zero() {
        let cfg = AppConfig::parse("{}").unwrap();
        assert_eq!(cfg.server.max_pending, None, "unbounded by default");
        let cfg = AppConfig::parse(r#"{"server": {"max_pending": 32}}"#).unwrap();
        assert_eq!(cfg.server.max_pending, Some(32));
        assert!(AppConfig::parse(r#"{"server": {"max_pending": 0}}"#).is_err());
    }

    #[test]
    fn preempt_prefill_parses_and_defaults_off() {
        let cfg = AppConfig::parse("{}").unwrap();
        assert!(!cfg.server.scheduler.preempt_prefill);
        let cfg = AppConfig::parse(r#"{"server": {"scheduler": {"preempt_prefill": true}}}"#)
            .unwrap();
        assert!(cfg.server.scheduler.preempt_prefill);
    }

    #[test]
    fn degenerate_trace_blocks_are_rejected_at_parse() {
        // Zero/negative rate.
        assert!(AppConfig::parse(r#"{"trace": {"rate": 0.0}}"#).is_err());
        // Empty length mix.
        assert!(AppConfig::parse(r#"{"trace": {"length_mix": []}}"#).is_err());
        // Non-positive mixture weight.
        assert!(AppConfig::parse(r#"{"trace": {"length_mix": [[128, 0.0]]}}"#).is_err());
        // Inverted decode bounds.
        assert!(
            AppConfig::parse(r#"{"trace": {"decode_min": 9, "decode_max": 2}}"#).is_err()
        );
        // A well-formed block still parses.
        let cfg = AppConfig::parse(r#"{"trace": {"rate": 2.0, "decode_max": 64}}"#).unwrap();
        assert_eq!(cfg.trace.decode_max, 64);
    }

    #[test]
    fn unknown_sparsity_rejected() {
        let res = AppConfig::parse(r#"{"server": {"scheduler": {"sparsity": "magic"}}}"#);
        assert!(res.is_err());
    }

    #[test]
    fn bad_json_rejected() {
        assert!(AppConfig::parse("{").is_err());
    }
}

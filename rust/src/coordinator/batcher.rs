//! Dynamic batcher: turns an [`IterationPlan`] into a validated, ordered
//! [`EngineBatch`]. Decode steps are packed first (they are
//! latency-critical and batch naturally), prefill chunks follow.

use anyhow::{anyhow, Result};

use super::request::{Phase, RequestState};
use super::scheduler::IterationPlan;

/// One unit of engine work.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkItem {
    /// Run the next `take` prompt tokens of request `req` through a
    /// prefill chunk.
    Prefill { req: u64, take: usize },
    /// One decode step for `req` feeding `token` (the previously sampled
    /// token, or the prompt-derived first token).
    Decode { req: u64, token: i32 },
}

/// A batch handed to the engine thread.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EngineBatch {
    pub iteration: u64,
    pub items: Vec<WorkItem>,
}

impl EngineBatch {
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn decode_width(&self) -> usize {
        self.items.iter().filter(|i| matches!(i, WorkItem::Decode { .. })).count()
    }

    pub fn prefill_tokens(&self) -> usize {
        self.items
            .iter()
            .map(|i| match i {
                WorkItem::Prefill { take, .. } => *take,
                _ => 0,
            })
            .sum()
    }
}

/// Validate a plan against request states and materialize the batch.
pub fn build_batch(
    iteration: u64,
    plan: &IterationPlan,
    states: &[RequestState],
) -> Result<EngineBatch> {
    let find = |id: u64| -> Result<&RequestState> {
        states
            .iter()
            .find(|s| s.request.id == id)
            .ok_or_else(|| anyhow!("plan references unknown request {id}"))
    };

    let mut items = Vec::with_capacity(plan.decode.len() + plan.prefill.len());

    for &id in &plan.decode {
        let st = find(id)?;
        if st.phase != Phase::Decode {
            return Err(anyhow!("request {id} scheduled for decode but in {:?}", st.phase));
        }
        // Feed the last sampled token; the first decode step after prefill
        // feeds the token sampled from the prefill logits.
        let token = *st
            .generated
            .last()
            .ok_or_else(|| anyhow!("request {id} decoding with no seed token"))?;
        items.push(WorkItem::Decode { req: id, token });
    }

    for &(id, take) in &plan.prefill {
        let st = find(id)?;
        if st.phase != Phase::Prefill {
            return Err(anyhow!("request {id} scheduled for prefill but in {:?}", st.phase));
        }
        if take == 0 || take > st.remaining_prefill() {
            return Err(anyhow!(
                "request {id}: chunk {take} exceeds remaining {}",
                st.remaining_prefill()
            ));
        }
        items.push(WorkItem::Prefill { req: id, take });
    }

    Ok(EngineBatch { iteration, items })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;

    fn state(id: u64, prompt: usize, phase: Phase, prefilled: usize) -> RequestState {
        let mut st = RequestState::new(Request::new(id, vec![1; prompt], 8, 0.0));
        st.phase = phase;
        st.prefilled = prefilled;
        if phase == Phase::Decode {
            st.generated.push(42);
        }
        st
    }

    #[test]
    fn decode_items_precede_prefill() {
        let states = vec![state(1, 64, Phase::Decode, 64), state(2, 512, Phase::Prefill, 0)];
        let plan = IterationPlan {
            prefill: vec![(2, 256)],
            decode: vec![1],
            ..IterationPlan::default()
        };
        let b = build_batch(3, &plan, &states).unwrap();
        assert_eq!(b.items[0], WorkItem::Decode { req: 1, token: 42 });
        assert_eq!(b.items[1], WorkItem::Prefill { req: 2, take: 256 });
        assert_eq!(b.decode_width(), 1);
        assert_eq!(b.prefill_tokens(), 256);
    }

    #[test]
    fn rejects_wrong_phase() {
        let states = vec![state(1, 64, Phase::Queued, 0)];
        let plan = IterationPlan { prefill: vec![(1, 64)], ..IterationPlan::default() };
        assert!(build_batch(0, &plan, &states).is_err());
    }

    #[test]
    fn rejects_oversized_chunk() {
        let states = vec![state(1, 100, Phase::Prefill, 50)];
        let plan = IterationPlan { prefill: vec![(1, 64)], ..IterationPlan::default() };
        assert!(build_batch(0, &plan, &states).is_err());
    }

    #[test]
    fn rejects_unknown_request() {
        let plan = IterationPlan { prefill: vec![(9, 1)], ..IterationPlan::default() };
        assert!(build_batch(0, &plan, &[]).is_err());
    }

    #[test]
    fn empty_plan_empty_batch() {
        let b = build_batch(0, &IterationPlan::default(), &[]).unwrap();
        assert!(b.is_empty());
    }
}

//! Machine calibration of the scheduler's cost constants (DESIGN.md §13).
//!
//! The modeled constants in [`scheduler`](super::scheduler) —
//! [`IDENT_COST_FRAC`](super::scheduler::IDENT_COST_FRAC) and
//! [`PLAN_BROADCAST_FRAC`](super::scheduler::PLAN_BROADCAST_FRAC) — are
//! paper-derived guesses. `anchor-attn calibrate` replaces them with
//! numbers measured on the machine actually serving:
//!
//! * **span read** — contiguous K/V rows through [`KvSource::span_into`]
//!   (ns per row), the run-serving fast path;
//! * **discrete gather** — strided rows through [`KvSource::gather_into`]
//!   (ns per row), the singleton-stripe path;
//! * **tile fold** — one online-softmax `BlockState::fold_tile` over a
//!   `b_q × b_kv` score tile (ns per score element);
//! * **identification** — a full anchor re-plan of the context, timed
//!   against **dense execution** of the same context on the chosen
//!   executor backend. Their ratio is `ident_cost_frac`: what a
//!   plan-cache miss costs as a fraction of densely attending the
//!   context, the exact shape the scheduler's chunk pricing consumes;
//! * **plan broadcast** — cloning the plan's coordinate vectors (what
//!   head-group shards actually exchange, DESIGN.md §12), again relative
//!   to dense execution, giving `plan_broadcast_frac`. With
//!   [`calibrate_with`]'s `wire` flag the clone proxy is replaced by a
//!   real framed socket round-trip of the delta-encoded coordinates
//!   (DESIGN.md §14) — encode, syscall, decode — the number
//!   `serve --transport process` should be priced with.
//!
//! The derived fractions are clamped to sane ranges so a freak timer
//! reading can never wedge the scheduler (e.g. a zero-cost ident would
//! admit unbounded prefill). Raw ns rates ride along in the
//! [`CostConstants`] for provenance and for the micro-bench
//! gather-vs-span crossover report.

use crate::attention::anchor::AnchorConfig;
use crate::attention::exec::{ExecutorKind, FlatKv, KvSource};
use crate::attention::full::BlockState;
use crate::attention::{HeadInput, Method, TileConfig};
use crate::tensor::Mat;
use crate::util::rng::Pcg64;
use crate::util::timer::{BenchResult, BenchRunner};

/// Clamp range for the identification fraction: a miss always costs
/// something, and can never be priced above one dense pass.
const IDENT_FRAC_RANGE: (f64, f64) = (0.001, 1.0);
/// Clamp range for the per-shard broadcast fraction: coordinates are
/// orders of magnitude lighter than K/V, so anything above 10% of a dense
/// pass is a measurement artifact.
const BROADCAST_FRAC_RANGE: (f64, f64) = (1e-6, 0.1);

/// One executor's measured calibration: the derived [`CostConstants`]
/// plus the raw timings they came from.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub kind: ExecutorKind,
    pub constants: crate::coordinator::scheduler::CostConstants,
    /// Full-context anchor identification wall time (seconds).
    pub ident_s: f64,
    /// Full-context dense execution wall time on `kind` (seconds).
    pub dense_exec_s: f64,
    /// Plan coordinate clone wall time (seconds) — the shard broadcast.
    pub broadcast_s: f64,
    /// Raw per-primitive bench rows, for reporting.
    pub rows: Vec<BenchResult>,
}

/// Sequence length / head dim the calibration workload uses. `d = 64`
/// exercises the specialized fold kernels serving actually hits.
fn workload_shape(quick: bool) -> (usize, usize) {
    if quick {
        (1024, 64)
    } else {
        (4096, 64)
    }
}

/// Identification step mirroring the experiments' scaling policy
/// (DESIGN.md §6): keep ≥8 groups so anchor does not collapse to full.
fn scaled_step(n: usize, tile: TileConfig) -> usize {
    let blocks = n / tile.b_q;
    if blocks >= 128 {
        16
    } else {
        (blocks / 8).max(2)
    }
}

/// Measure the cost-model primitives for `kind` on this machine.
/// `quick` trades precision for wall time (CI smoke runs).
pub fn calibrate(kind: ExecutorKind, quick: bool) -> Calibration {
    calibrate_with(kind, quick, false)
}

/// [`calibrate`] with an explicit broadcast methodology: `wire = true`
/// measures the plan-broadcast constant over a real framed socket
/// round-trip of the delta-encoded coordinates instead of the in-memory
/// clone proxy.
pub fn calibrate_with(kind: ExecutorKind, quick: bool, wire: bool) -> Calibration {
    let runner = if quick { BenchRunner::quick() } else { BenchRunner::default() };
    let (n, d) = workload_shape(quick);
    let tile = TileConfig::new(128, 128);
    let mut rng = Pcg64::seeded(0xCA11B);
    let head = HeadInput::new(
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
    );
    let kv = FlatKv::new(&head.k, &head.v);
    let mut rows = Vec::new();

    // Span vs gather: same row count, contiguous vs stride-3 coordinates,
    // through the executors' allocation-free read entries.
    let read_rows = (n / 4).min(1024);
    let mut k_dst = Mat::zeros(read_rows, d);
    let mut v_dst = Mat::zeros(read_rows, d);
    let span = runner.run("span_into/contiguous", || {
        kv.span_into(0, read_rows, 0, &mut k_dst, &mut v_dst);
        k_dst.data[0]
    });
    let span_ns_per_row = span.mean_s * 1e9 / read_rows as f64;
    rows.push(span);
    let coords: Vec<u32> = (0..read_rows as u32).map(|i| i * 3).collect();
    assert!((*coords.last().unwrap() as usize) < n);
    let gather = runner.run("gather_into/stride3", || {
        kv.gather_into(&coords, 0, &mut k_dst, &mut v_dst);
        k_dst.data[0]
    });
    let gather_ns_per_row = gather.mean_s * 1e9 / read_rows as f64;
    rows.push(gather);

    // Tile fold: one online-softmax fold of a b_q × b_kv score tile.
    // fold_tile rewrites the scores in place, so each iteration restores
    // them first; the 64 KiB copy is noise next to the exp-heavy fold.
    let scores = Mat::from_fn(tile.b_q, tile.b_kv, |_, _| rng.normal());
    let mut s_work = scores.clone();
    let v_tile = Mat::from_fn(tile.b_kv, d, |_, _| rng.normal());
    let mut state = BlockState::new(tile.b_q, d);
    let fold = runner.run("fold_tile/128x128", || {
        s_work.data.copy_from_slice(&scores.data);
        state.reset(tile.b_q, d);
        state.fold_tile(&mut s_work, &v_tile);
        state.l[0]
    });
    let fold_ns_per_score = fold.mean_s * 1e9 / (tile.b_q * tile.b_kv) as f64;
    rows.push(fold);

    // Identification vs dense execution: the two wall times whose ratio
    // the scheduler's miss pricing is.
    let anchor = Method::Anchor(AnchorConfig {
        tile,
        theta: 12.0,
        step: scaled_step(n, tile),
        init_blocks: 1,
        use_anchor: true,
    });
    let ident = runner.run("ident/anchor-plan", || anchor.plan(&head).ident_cost.ident_scores);
    rows.push(ident.clone());
    let dense_plan = Method::Full(tile).plan(&head);
    let executor = kind.build();
    let dense = runner.run("exec/dense-full-head", || {
        executor.execute(&head, &dense_plan).out.data[0]
    });
    rows.push(dense.clone());

    // Plan broadcast: coordinates are the only payload shard workers
    // exchange. The default proxy clones the coordinate vectors; the wire
    // mode round-trips the delta-encoded frame through a real socketpair
    // (encode + two syscalls + decode), pricing process transport.
    let anchor_plan = anchor.plan(&head);
    let bcast = if wire {
        use crate::wire::codec::{get_plan, put_plan};
        use crate::wire::frame::{read_frame, read_frame_opt, write_frame, Dec, Enc, FrameKind};
        use std::os::unix::net::UnixStream;
        let (mut here, mut there) = UnixStream::pair().expect("calibrate: socketpair");
        let echo = std::thread::spawn(move || {
            while let Ok(Some((kind, payload))) = read_frame_opt(&mut there) {
                if kind != FrameKind::Ping || write_frame(&mut there, FrameKind::Pong, &payload).is_err() {
                    break;
                }
            }
        });
        let r = runner.run("broadcast/wire-coords", || {
            let mut e = Enc::new();
            put_plan(&mut e, &anchor_plan, d);
            write_frame(&mut here, FrameKind::Ping, &e.buf).expect("calibrate: wire write");
            let (_, payload) = read_frame(&mut here).expect("calibrate: wire read");
            let mut dec = Dec::new(&payload);
            get_plan(&mut dec).expect("calibrate: wire decode").groups.len()
        });
        drop(here); // EOF stops the echo thread
        echo.join().expect("calibrate: echo thread");
        r
    } else {
        runner.run("broadcast/coord-clone", || {
            anchor_plan
                .groups
                .iter()
                .map(|g| (g.spans.clone(), g.stripes.clone()))
                .collect::<Vec<_>>()
                .len()
        })
    };
    rows.push(bcast.clone());

    let ident_cost_frac =
        (ident.mean_s / dense.mean_s).clamp(IDENT_FRAC_RANGE.0, IDENT_FRAC_RANGE.1);
    let plan_broadcast_frac =
        (bcast.mean_s / dense.mean_s).clamp(BROADCAST_FRAC_RANGE.0, BROADCAST_FRAC_RANGE.1);
    Calibration {
        kind,
        constants: crate::coordinator::scheduler::CostConstants {
            ident_cost_frac,
            plan_broadcast_frac,
            span_ns_per_row,
            gather_ns_per_row,
            fold_ns_per_score,
        },
        ident_s: ident.mean_s,
        dense_exec_s: dense.mean_s,
        broadcast_s: bcast.mean_s,
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A quick calibration produces finite, clamped, measured constants
    /// that the sparsity model accepts.
    #[test]
    fn quick_calibration_yields_sane_measured_constants() {
        let cal = calibrate(ExecutorKind::Cpu, true);
        let c = cal.constants;
        assert!(c.is_measured());
        assert!(
            (IDENT_FRAC_RANGE.0..=IDENT_FRAC_RANGE.1).contains(&c.ident_cost_frac),
            "ident frac {}",
            c.ident_cost_frac
        );
        assert!(
            (BROADCAST_FRAC_RANGE.0..=BROADCAST_FRAC_RANGE.1).contains(&c.plan_broadcast_frac),
            "broadcast frac {}",
            c.plan_broadcast_frac
        );
        for (name, v) in [
            ("span", c.span_ns_per_row),
            ("gather", c.gather_ns_per_row),
            ("fold", c.fold_ns_per_score),
        ] {
            assert!(v.is_finite() && v > 0.0, "{name} rate {v}");
        }
        // Gathering strided rows is never cheaper than the straight copy.
        assert!(
            c.gather_ns_per_row >= c.span_ns_per_row * 0.5,
            "gather {} vs span {}",
            c.gather_ns_per_row,
            c.span_ns_per_row
        );
        let mut m = crate::coordinator::scheduler::SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 256,
            plan_hit_rate: 0.0,
            speculative_hit_rate: 0.0,
            pipelined: false,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: Default::default(),
        };
        m.set_constants(c);
        let eff = m.effective_context(4096);
        assert!(eff.is_finite() && eff > 0.0 && eff <= 4096.0, "eff {eff}");
        assert_eq!(cal.rows.len(), 6);
    }

    /// The wire broadcast mode yields a measured, clamped constant from a
    /// real framed round-trip — the `calibrate --wire` acceptance path.
    #[test]
    fn wire_broadcast_round_trip_is_measured() {
        let cal = calibrate_with(ExecutorKind::Cpu, true, true);
        let c = cal.constants;
        assert!(c.is_measured());
        assert!(
            (BROADCAST_FRAC_RANGE.0..=BROADCAST_FRAC_RANGE.1).contains(&c.plan_broadcast_frac),
            "wire broadcast frac {}",
            c.plan_broadcast_frac
        );
        assert!(cal.broadcast_s.is_finite() && cal.broadcast_s > 0.0);
        assert_eq!(cal.rows.len(), 6, "wire mode replaces the clone row, not adds one");
        assert!(
            cal.rows.iter().any(|r| r.name == "broadcast/wire-coords"),
            "rows: {:?}",
            cal.rows.iter().map(|r| r.name.clone()).collect::<Vec<_>>()
        );
    }
}

//! Engine: the single thread that owns the PJRT runtime and executes
//! [`EngineBatch`]es. `PjRtClient` is `Rc`-based (not `Send`), so the
//! engine is constructed *inside* its thread and communicates over
//! channels. A [`StepExecutor`] trait abstracts the engine so the server
//! and its tests can run against a deterministic mock without artifacts.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::mpsc;

use anyhow::{anyhow, Result};

use super::batcher::{EngineBatch, WorkItem};
use super::scheduler::SparsityModel;
use crate::model::{argmax, LmModel, LmSession};
use crate::runtime::Runtime;

/// Result of one work item.
#[derive(Clone, Debug, PartialEq)]
pub enum StepOutcome {
    /// A prefill chunk completed. `next_token` is only meaningful when
    /// `prompt_done` (sampled from the last valid logits row).
    PrefillChunk { req: u64, took: usize, prompt_done: bool, next_token: i32, elapsed_s: f64 },
    /// One decode step completed, emitting `token`.
    Decoded { req: u64, token: i32, elapsed_s: f64 },
    /// The request errored (propagated to the server for teardown).
    Failed { req: u64, error: String },
}

/// Anything that can execute engine batches (PJRT engine or mock).
pub trait StepExecutor {
    fn execute(&mut self, batch: &EngineBatch) -> Vec<StepOutcome>;
    /// Free any per-request state (called when a request finishes).
    fn finish_request(&mut self, req: u64);
    /// Register a request's prompt ahead of its first prefill chunk
    /// (no-op for executors that track progress externally).
    fn register(&mut self, _req: u64, _prompt: Vec<i32>) {}
    /// Plan-cache hit rate the engine observed since the last poll — e.g.
    /// a merged `SessionOutput::hit_rate()` from the attention sessions
    /// (sharded or not) behind the steps. The serve loop drains this after
    /// every iteration and folds it into the scheduler's `plan_hit_rate`
    /// EWMA (`SparsityModel::observe_plan_hit_rate`), closing the live
    /// feedback loop DESIGN.md §11 left open. Default: no observation.
    fn observed_plan_hit_rate(&mut self) -> Option<f64> {
        None
    }
    /// Per-request plan-cache attribution since the last poll:
    /// `(request id, cache hits, cache misses)` triples from the attention
    /// sessions behind the steps. The serve loop drains this every
    /// iteration and attaches the totals to the request's
    /// [`RequestRecord`](super::metrics::RequestRecord), which is what
    /// makes hit rates attributable *per workload scenario* in the serving
    /// report. Default: no attribution (executors that don't run sessions,
    /// like the mock, report nothing).
    fn take_plan_attribution(&mut self) -> Vec<(u64, u64, u64)> {
        Vec::new()
    }
    /// Speculative plan-reuse hit rate the engine observed since the last
    /// poll — `speculative_hits / (speculative_hits + speculative_fallbacks)`
    /// merged over the attention sessions behind the steps. Drained by the
    /// serve loop into the scheduler's `speculative_hit_rate` EWMA
    /// (`SparsityModel::observe_speculative_hit_rate`), so recall-check
    /// pricing (DESIGN.md §17) tracks what the sessions actually achieve.
    /// `None` when no recall checks ran (exact policy, or nothing to reuse).
    fn observed_speculative_hit_rate(&mut self) -> Option<f64> {
        None
    }
    /// Per-request speculative-reuse attribution since the last poll:
    /// `(request id, speculative hits, speculative fallbacks)` triples,
    /// same contract as [`Self::take_plan_attribution`]. Attached to
    /// [`RequestRecord`](super::metrics::RequestRecord)s so speculative
    /// hit rates are reportable per workload scenario.
    fn take_speculative_attribution(&mut self) -> Vec<(u64, u64, u64)> {
        Vec::new()
    }
}

/// The real PJRT-backed engine. Owns one [`LmModel`] and per-request
/// sessions. Must live on a single thread.
pub struct PjrtEngine {
    model: LmModel,
    sessions: HashMap<u64, LmSession>,
    /// Remaining prompt per in-flight prefill request.
    prompts: HashMap<u64, (Vec<i32>, usize)>,
}

impl PjrtEngine {
    pub fn new(artifact_dir: &str) -> Result<Self> {
        let runtime = Rc::new(Runtime::open(artifact_dir)?);
        let model = LmModel::load(runtime)?;
        model.warmup()?;
        Ok(Self { model, sessions: HashMap::new(), prompts: HashMap::new() })
    }

    /// Register a request's prompt before its first prefill chunk.
    pub fn register(&mut self, req: u64, prompt: Vec<i32>) {
        self.prompts.insert(req, (prompt, 0));
    }

    pub fn vocab(&self) -> usize {
        self.model.vocab
    }

    fn run_prefill(&mut self, req: u64, take: usize) -> Result<(bool, i32, f64)> {
        let (prompt, off) = self
            .prompts
            .get(&req)
            .cloned()
            .ok_or_else(|| anyhow!("request {req} not registered"))?;
        if !self.sessions.contains_key(&req) {
            self.sessions.insert(req, self.model.new_session()?);
        }
        let t0 = std::time::Instant::now();
        let chunk = &prompt[off..(off + take).min(prompt.len())];
        let session = self.sessions.get_mut(&req).unwrap();
        let logits = self.model.prefill(session, chunk)?;
        let elapsed = t0.elapsed().as_secs_f64();
        let new_off = off + chunk.len();
        let done = new_off >= prompt.len();
        self.prompts.insert(req, (prompt, new_off));
        Ok((done, argmax(&logits), elapsed))
    }

    fn run_decode(&mut self, req: u64, token: i32) -> Result<(i32, f64)> {
        let session = self
            .sessions
            .get_mut(&req)
            .ok_or_else(|| anyhow!("request {req} has no session"))?;
        let t0 = std::time::Instant::now();
        let logits = self.model.decode(session, token)?;
        Ok((argmax(&logits), t0.elapsed().as_secs_f64()))
    }
}

impl StepExecutor for PjrtEngine {
    fn execute(&mut self, batch: &EngineBatch) -> Vec<StepOutcome> {
        let mut out = Vec::with_capacity(batch.items.len());
        for item in &batch.items {
            match *item {
                WorkItem::Prefill { req, take } => match self.run_prefill(req, take) {
                    Ok((done, next, dt)) => out.push(StepOutcome::PrefillChunk {
                        req,
                        took: take,
                        prompt_done: done,
                        next_token: next,
                        elapsed_s: dt,
                    }),
                    Err(e) => out.push(StepOutcome::Failed { req, error: e.to_string() }),
                },
                WorkItem::Decode { req, token } => match self.run_decode(req, token) {
                    Ok((next, dt)) => {
                        out.push(StepOutcome::Decoded { req, token: next, elapsed_s: dt })
                    }
                    Err(e) => out.push(StepOutcome::Failed { req, error: e.to_string() }),
                },
            }
        }
        out
    }

    fn finish_request(&mut self, req: u64) {
        self.sessions.remove(&req);
        self.prompts.remove(&req);
    }

    fn register(&mut self, req: u64, prompt: Vec<i32>) {
        PjrtEngine::register(self, req, prompt);
    }
}

/// Deterministic mock for server tests: each prefill chunk or decode step
/// costs a fixed virtual time and emits `(req * 31 + step) % vocab`. An
/// optional [`SparsityModel`] prices prefill chunks exactly like the
/// scheduler's chunk cost — `take · (0.5 + 0.5 · eff(context_after) /
/// context_after)`, with per-request context tracked across chunks — so
/// sparsity, plan-cache hit rates, and pipelined (overlapped) ident
/// pricing propagate into the reported engine-busy time (batching cost
/// estimate ↔ engine agreement).
pub struct MockEngine {
    pub vocab: i32,
    pub steps: u64,
    /// When set, prefill `elapsed_s` follows the scheduler's chunk-cost
    /// shape at the request's accumulated context (dense time otherwise).
    pub cost_model: Option<SparsityModel>,
    /// Tokens prefilled so far per in-flight request.
    prefilled: HashMap<u64, usize>,
}

impl MockEngine {
    pub fn new(vocab: i32) -> Self {
        Self { vocab, steps: 0, cost_model: None, prefilled: HashMap::new() }
    }

    /// Mock whose virtual prefill time follows a sparsity/plan-hit model.
    pub fn with_cost_model(vocab: i32, model: SparsityModel) -> Self {
        Self { vocab, steps: 0, cost_model: Some(model), prefilled: HashMap::new() }
    }

    fn prefill_time(&mut self, req: u64, take: usize) -> f64 {
        let ctx_after = self.prefilled.entry(req).or_insert(0);
        *ctx_after += take;
        let base = 1e-4 * take as f64;
        match &self.cost_model {
            None => base,
            Some(model) => {
                let ctx = (*ctx_after).max(1);
                let eff = model.effective_context(ctx);
                base * (0.5 + 0.5 * eff / ctx as f64)
            }
        }
    }
}

impl StepExecutor for MockEngine {
    fn execute(&mut self, batch: &EngineBatch) -> Vec<StepOutcome> {
        let mut out = Vec::new();
        for item in &batch.items {
            self.steps += 1;
            match *item {
                WorkItem::Prefill { req, take } => {
                    let elapsed_s = self.prefill_time(req, take);
                    out.push(StepOutcome::PrefillChunk {
                        req,
                        took: take,
                        // The server tracks progress; the mock can't know, so
                        // it reports done=false and the server infers from
                        // counts.
                        prompt_done: false,
                        next_token: ((req * 31 + self.steps) % self.vocab as u64) as i32,
                        elapsed_s,
                    })
                }
                WorkItem::Decode { req, .. } => out.push(StepOutcome::Decoded {
                    req,
                    token: ((req * 31 + self.steps) % self.vocab as u64) as i32,
                    elapsed_s: 1e-4,
                }),
            }
        }
        out
    }

    fn finish_request(&mut self, req: u64) {
        self.prefilled.remove(&req);
    }
}

/// Commands for a channel-driven engine thread.
pub enum EngineCmd {
    Register { req: u64, prompt: Vec<i32> },
    Run(EngineBatch),
    Finish { req: u64 },
    Shutdown,
}

/// Channel handles to a spawned engine thread: command sender plus
/// outcome receiver.
pub type EngineChannels =
    (mpsc::Sender<EngineCmd>, mpsc::Receiver<Result<Vec<StepOutcome>, String>>);

/// Engine-thread main loop, shared by every channel-driven executor
/// backend ([`spawn_engine`], [`spawn_mock_engine`]). The channel
/// decouples the coordinator from the executor, which is what lets the
/// coordinator submit batch *k+1* while batch *k*'s results are still in
/// flight — the step-level face of the plan pipeline (DESIGN.md §9).
fn run_engine_loop<E: StepExecutor>(
    mut engine: E,
    cmd_rx: &mpsc::Receiver<EngineCmd>,
    res_tx: &mpsc::Sender<Result<Vec<StepOutcome>, String>>,
) {
    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            EngineCmd::Register { req, prompt } => engine.register(req, prompt),
            EngineCmd::Run(batch) => {
                let outcomes = engine.execute(&batch);
                if res_tx.send(Ok(outcomes)).is_err() {
                    break;
                }
            }
            EngineCmd::Finish { req } => engine.finish_request(req),
            EngineCmd::Shutdown => break,
        }
    }
}

/// Spawn the PJRT engine on its own thread. Returns command sender and
/// outcome receiver. The engine compiles artifacts at startup (blocking
/// until ready; an `Err` is reported through the result channel).
pub fn spawn_engine(artifact_dir: String) -> EngineChannels {
    let (cmd_tx, cmd_rx) = mpsc::channel::<EngineCmd>();
    let (res_tx, res_rx) = mpsc::channel::<Result<Vec<StepOutcome>, String>>();
    std::thread::spawn(move || {
        let engine = match PjrtEngine::new(&artifact_dir) {
            Ok(e) => {
                let _ = res_tx.send(Ok(Vec::new())); // ready signal
                e
            }
            Err(e) => {
                let _ = res_tx.send(Err(format!("engine init: {e}")));
                return;
            }
        };
        run_engine_loop(engine, &cmd_rx, &res_tx);
    });
    (cmd_tx, res_rx)
}

/// Spawn a [`MockEngine`] behind the same channel protocol as
/// [`spawn_engine`] (including the ready signal), so coordinator code and
/// benches exercise the threaded step path without artifacts. Pair it
/// with a [`SparsityModel`] whose `pipelined` flag is on to model the
/// async plan pipeline: prefill chunks are then priced at
/// `max(ident, exec)` — identification off the critical path — exactly as
/// the scheduler budgets them.
pub fn spawn_mock_engine(vocab: i32, cost_model: Option<SparsityModel>) -> EngineChannels {
    let (cmd_tx, cmd_rx) = mpsc::channel::<EngineCmd>();
    let (res_tx, res_rx) = mpsc::channel::<Result<Vec<StepOutcome>, String>>();
    std::thread::spawn(move || {
        let engine = match cost_model {
            Some(model) => MockEngine::with_cost_model(vocab, model),
            None => MockEngine::new(vocab),
        };
        let _ = res_tx.send(Ok(Vec::new())); // ready signal
        run_engine_loop(engine, &cmd_rx, &res_tx);
    });
    (cmd_tx, res_rx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exec::ExecutorKind;
    use crate::coordinator::scheduler::CostConstants;

    #[test]
    fn mock_engine_is_deterministic() {
        let batch = EngineBatch {
            iteration: 0,
            items: vec![
                WorkItem::Prefill { req: 1, take: 256 },
                WorkItem::Decode { req: 2, token: 5 },
            ],
        };
        let mut a = MockEngine::new(512);
        let mut b = MockEngine::new(512);
        assert_eq!(a.execute(&batch), b.execute(&batch));
    }

    /// Warmer plan caches make mock prefill cheaper, mirroring the
    /// scheduler's chunk-cost model — and dense time is the ceiling.
    #[test]
    fn mock_cost_model_tracks_plan_hits() {
        let mk = |hit| {
            MockEngine::with_cost_model(
                64,
                SparsityModel::Anchor {
                    stripe_keep: 0.1,
                    anchor_tokens: 256,
                    plan_hit_rate: hit,
                    speculative_hit_rate: 0.0,
                    pipelined: false,
                    executor: ExecutorKind::Cpu,
                    shards: 1,
                    constants: CostConstants::modeled(),
                },
            )
        };
        let batch = EngineBatch {
            iteration: 0,
            items: vec![WorkItem::Prefill { req: 1, take: 4096 }],
        };
        let elapsed = |mut e: MockEngine| match e.execute(&batch)[0] {
            StepOutcome::PrefillChunk { elapsed_s, .. } => elapsed_s,
            _ => panic!(),
        };
        let dense = elapsed(MockEngine::new(64));
        let cold = elapsed(mk(0.0));
        let warm = elapsed(mk(1.0));
        assert!(warm < cold, "warm {warm} vs cold {cold}");
        assert!(cold < dense, "cold {cold} vs dense {dense}");

        // Context accumulates across chunks of one request: later chunks of
        // a long prompt are cheaper per token (the sparse advantage grows
        // with context, exactly as the scheduler prices it).
        let mut e = mk(0.0);
        let chunk = |req| EngineBatch {
            iteration: 0,
            items: vec![WorkItem::Prefill { req, take: 256 }],
        };
        let t1 = match e.execute(&chunk(7))[0] {
            StepOutcome::PrefillChunk { elapsed_s, .. } => elapsed_s,
            _ => panic!(),
        };
        let mut t_last = t1;
        for _ in 0..7 {
            t_last = match e.execute(&chunk(7))[0] {
                StepOutcome::PrefillChunk { elapsed_s, .. } => elapsed_s,
                _ => panic!(),
            };
        }
        assert!(t_last < t1, "deep chunk {t_last} vs first chunk {t1}");
        // finish_request clears the context tracking.
        e.finish_request(7);
        let t_fresh = match e.execute(&chunk(7))[0] {
            StepOutcome::PrefillChunk { elapsed_s, .. } => elapsed_s,
            _ => panic!(),
        };
        assert!((t_fresh - t1).abs() < 1e-12);
    }

    /// The pipelined cost model makes mock prefill no slower than the
    /// sequential one (identification hides behind execution) and never
    /// cheaper than a fully warm cache (which has no ident work to hide).
    #[test]
    fn mock_pipelined_prefill_hides_identification() {
        let mk = |hit, pipelined| {
            MockEngine::with_cost_model(
                64,
                SparsityModel::Anchor {
                    stripe_keep: 0.1,
                    anchor_tokens: 256,
                    plan_hit_rate: hit,
                    speculative_hit_rate: 0.0,
                    pipelined,
                    executor: ExecutorKind::Cpu,
                    shards: 1,
                    constants: CostConstants::modeled(),
                },
            )
        };
        let batch = EngineBatch {
            iteration: 0,
            items: vec![WorkItem::Prefill { req: 1, take: 4096 }],
        };
        let elapsed = |mut e: MockEngine| match e.execute(&batch)[0] {
            StepOutcome::PrefillChunk { elapsed_s, .. } => elapsed_s,
            _ => panic!(),
        };
        let seq_cold = elapsed(mk(0.0, false));
        let pipe_cold = elapsed(mk(0.0, true));
        let warm = elapsed(mk(1.0, false));
        assert!(pipe_cold < seq_cold, "pipelined {pipe_cold} vs sequential {seq_cold}");
        assert!(warm <= pipe_cold + 1e-12, "warm {warm} vs pipelined-cold {pipe_cold}");
    }

    /// The mock engine speaks the same channel protocol as the PJRT
    /// engine thread: ready signal, register/run/finish/shutdown.
    #[test]
    fn spawn_mock_engine_serves_the_channel_protocol() {
        let model = SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 256,
            plan_hit_rate: 0.0,
            speculative_hit_rate: 0.0,
            pipelined: true,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: CostConstants::modeled(),
        };
        let (cmd_tx, res_rx) = spawn_mock_engine(64, Some(model));
        // Ready signal first.
        assert!(res_rx.recv().unwrap().unwrap().is_empty());
        cmd_tx.send(EngineCmd::Register { req: 1, prompt: vec![0; 512] }).unwrap();
        let batch = EngineBatch {
            iteration: 0,
            items: vec![
                WorkItem::Prefill { req: 1, take: 256 },
                WorkItem::Decode { req: 2, token: 3 },
            ],
        };
        cmd_tx.send(EngineCmd::Run(batch)).unwrap();
        let outcomes = res_rx.recv().unwrap().unwrap();
        assert_eq!(outcomes.len(), 2);
        assert!(matches!(outcomes[0], StepOutcome::PrefillChunk { req: 1, took: 256, .. }));
        assert!(matches!(outcomes[1], StepOutcome::Decoded { req: 2, .. }));
        cmd_tx.send(EngineCmd::Finish { req: 1 }).unwrap();
        cmd_tx.send(EngineCmd::Shutdown).unwrap();
        // The engine thread exits: the result channel hangs up.
        assert!(res_rx.recv().is_err());
    }

    #[test]
    fn mock_tokens_in_vocab() {
        let mut e = MockEngine::new(64);
        let batch = EngineBatch {
            iteration: 0,
            items: (0..20).map(|i| WorkItem::Decode { req: i, token: 0 }).collect(),
        };
        for o in e.execute(&batch) {
            match o {
                StepOutcome::Decoded { token, .. } => assert!((0..64).contains(&token)),
                _ => panic!("unexpected outcome"),
            }
        }
    }
}

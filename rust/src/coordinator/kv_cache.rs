//! Paged KV-cache accounting: fixed-size pages, per-sequence allocation,
//! and per-page **stripe statistics** — the prefill identification's hot
//! fraction is attached to each page so the decode phase can prioritize
//! hot pages (the paper's stated future work, implemented as an extension;
//! DESIGN.md §7).
//!
//! Storage itself lives in each session's functional cache literal; the
//! pool provides the *admission control* a real serving deployment gets
//! from GPU memory: a sequence may only run while it holds pages.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

/// Per-page stripe statistics recorded during prefill identification.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PageStripeStats {
    /// Fraction of this page's keys selected as stripes during prefill.
    pub hot_fraction: f32,
}

#[derive(Clone, Debug)]
struct SeqAlloc {
    pages: Vec<u32>,
    tokens: usize,
}

/// Fixed-capacity page pool.
pub struct PagePool {
    page_tokens: usize,
    free: Vec<u32>,
    seqs: HashMap<u64, SeqAlloc>,
    stats: Vec<PageStripeStats>,
    total_pages: usize,
}

impl PagePool {
    pub fn new(total_pages: usize, page_tokens: usize) -> Self {
        assert!(page_tokens >= 1 && total_pages >= 1);
        Self {
            page_tokens,
            free: (0..total_pages as u32).rev().collect(),
            seqs: HashMap::new(),
            stats: vec![PageStripeStats::default(); total_pages],
            total_pages,
        }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free.len()
    }

    pub fn utilization(&self) -> f64 {
        self.used_pages() as f64 / self.total_pages as f64
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Can a new sequence of `tokens` total be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens.max(1)) <= self.free.len()
    }

    /// Reserve pages for a new sequence (its *full* expected length —
    /// conservative admission, no mid-decode eviction in this build).
    pub fn admit(&mut self, seq: u64, tokens: usize) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            return Err(anyhow!("sequence {seq} already admitted"));
        }
        let need = self.pages_for(tokens.max(1));
        if need > self.free.len() {
            return Err(anyhow!(
                "admission of {tokens} tokens needs {need} pages, only {} free",
                self.free.len()
            ));
        }
        let pages = self.free.split_off(self.free.len() - need);
        self.seqs.insert(seq, SeqAlloc { pages, tokens });
        Ok(())
    }

    /// Release a finished sequence's pages.
    pub fn release(&mut self, seq: u64) -> Result<()> {
        let alloc = self.seqs.remove(&seq).ok_or_else(|| anyhow!("unknown sequence {seq}"))?;
        for p in &alloc.pages {
            self.stats[*p as usize] = PageStripeStats::default();
        }
        self.free.extend(alloc.pages);
        Ok(())
    }

    pub fn pages_of(&self, seq: u64) -> Option<&[u32]> {
        self.seqs.get(&seq).map(|a| a.pages.as_slice())
    }

    /// Record stripe stats for the page holding `token_pos` of `seq`
    /// (called by the engine after each prefill chunk's identification).
    pub fn record_stripe_stats(&mut self, seq: u64, token_pos: usize, hot_fraction: f32) -> Result<()> {
        let alloc = self.seqs.get(&seq).ok_or_else(|| anyhow!("unknown sequence {seq}"))?;
        let page_idx = token_pos / self.page_tokens;
        let page = *alloc
            .pages
            .get(page_idx)
            .ok_or_else(|| anyhow!("token {token_pos} beyond allocation"))?;
        self.stats[page as usize].hot_fraction = hot_fraction;
        Ok(())
    }

    pub fn stripe_stats(&self, page: u32) -> PageStripeStats {
        self.stats[page as usize]
    }

    /// Decode-reuse extension: the pages of `seq` whose prefill hot
    /// fraction meets `min_hot`, i.e. the pages decode attention should
    /// visit first.
    pub fn hot_pages(&self, seq: u64, min_hot: f32) -> Vec<u32> {
        self.seqs
            .get(&seq)
            .map(|a| {
                a.pages
                    .iter()
                    .copied()
                    .filter(|&p| self.stats[p as usize].hot_fraction >= min_hot)
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_and_release_roundtrip() {
        let mut pool = PagePool::new(8, 64);
        assert!(pool.can_admit(256));
        pool.admit(1, 256).unwrap(); // 4 pages
        assert_eq!(pool.used_pages(), 4);
        assert_eq!(pool.pages_of(1).unwrap().len(), 4);
        pool.release(1).unwrap();
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(pool.free_pages(), 8);
    }

    #[test]
    fn admission_control_blocks_when_full() {
        let mut pool = PagePool::new(4, 64);
        pool.admit(1, 200).unwrap(); // 4 pages
        assert!(!pool.can_admit(1));
        assert!(pool.admit(2, 64).is_err());
        pool.release(1).unwrap();
        assert!(pool.can_admit(256));
    }

    #[test]
    fn double_admit_rejected() {
        let mut pool = PagePool::new(4, 64);
        pool.admit(7, 64).unwrap();
        assert!(pool.admit(7, 64).is_err());
    }

    #[test]
    fn release_unknown_rejected() {
        let mut pool = PagePool::new(4, 64);
        assert!(pool.release(3).is_err());
    }

    #[test]
    fn stripe_stats_tracked_per_page() {
        let mut pool = PagePool::new(8, 64);
        pool.admit(1, 256).unwrap();
        pool.record_stripe_stats(1, 0, 0.9).unwrap();
        pool.record_stripe_stats(1, 130, 0.2).unwrap(); // page 2
        let pages = pool.pages_of(1).unwrap().to_vec();
        assert_eq!(pool.stripe_stats(pages[0]).hot_fraction, 0.9);
        assert_eq!(pool.stripe_stats(pages[2]).hot_fraction, 0.2);
        let hot = pool.hot_pages(1, 0.5);
        assert_eq!(hot, vec![pages[0]]);
    }

    #[test]
    fn stats_reset_on_release() {
        let mut pool = PagePool::new(2, 64);
        pool.admit(1, 64).unwrap();
        let page = pool.pages_of(1).unwrap()[0];
        pool.record_stripe_stats(1, 0, 0.7).unwrap();
        pool.release(1).unwrap();
        assert_eq!(pool.stripe_stats(page).hot_fraction, 0.0);
    }

    #[test]
    fn zero_token_admission_takes_one_page() {
        let mut pool = PagePool::new(2, 64);
        pool.admit(1, 0).unwrap();
        assert_eq!(pool.used_pages(), 1);
    }
}

//! Paged KV-cache accounting: fixed-size pages, per-sequence allocation,
//! and per-page **stripe statistics** — the prefill identification's hot
//! fraction is attached to each page so the decode phase can prioritize
//! hot pages (the paper's stated future work, implemented as an extension;
//! DESIGN.md §7).
//!
//! PJRT-session storage lives in each session's functional cache literal;
//! the pool provides the *admission control* a real serving deployment
//! gets from GPU memory: a sequence may only run while it holds pages.
//! [`PagedKvStore`] adds engine-side paged K/V storage with
//! **gather-by-coordinates** access, so a [`SparsePlan`]'s stripe
//! coordinates can be executed directly against paged memory (Eq. 4
//! `load_discrete` over pages instead of a flat tensor). [`PagedExecutor`]
//! closes the loop: it plugs the store in as the [`KvSource`] of any
//! [`Executor`] backend, so paged serving executes plans without
//! flattening the cache first (DESIGN.md §10).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::attention::exec::{Executor, KvSource};
use crate::attention::plan::SparsePlan;
use crate::attention::AttnOutput;
use crate::tensor::Mat;

/// Per-page stripe statistics recorded during prefill identification.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PageStripeStats {
    /// Fraction of this page's keys selected as stripes during prefill.
    pub hot_fraction: f32,
}

#[derive(Clone, Debug)]
struct SeqAlloc {
    pages: Vec<u32>,
    tokens: usize,
}

/// Fixed-capacity page pool.
pub struct PagePool {
    page_tokens: usize,
    free: Vec<u32>,
    seqs: HashMap<u64, SeqAlloc>,
    stats: Vec<PageStripeStats>,
    total_pages: usize,
    /// Eviction events (scheduler preemption under memory pressure).
    evictions: u64,
    /// Pages reclaimed across all evictions.
    evicted_pages: u64,
}

impl PagePool {
    pub fn new(total_pages: usize, page_tokens: usize) -> Self {
        assert!(page_tokens >= 1 && total_pages >= 1);
        Self {
            page_tokens,
            free: (0..total_pages as u32).rev().collect(),
            seqs: HashMap::new(),
            stats: vec![PageStripeStats::default(); total_pages],
            total_pages,
            evictions: 0,
            evicted_pages: 0,
        }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free.len()
    }

    pub fn utilization(&self) -> f64 {
        self.used_pages() as f64 / self.total_pages as f64
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Can a new sequence of `tokens` total be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens.max(1)) <= self.free.len()
    }

    /// Reserve pages for a new sequence (its *full* expected length —
    /// conservative admission; decoding sequences are never evicted, only
    /// prefill-phase sequences may be preempted via [`PagePool::evict`]).
    pub fn admit(&mut self, seq: u64, tokens: usize) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            return Err(anyhow!("sequence {seq} already admitted"));
        }
        let need = self.pages_for(tokens.max(1));
        if need > self.free.len() {
            return Err(anyhow!(
                "admission of {tokens} tokens needs {need} pages, only {} free",
                self.free.len()
            ));
        }
        let pages = self.free.split_off(self.free.len() - need);
        self.seqs.insert(seq, SeqAlloc { pages, tokens });
        Ok(())
    }

    /// Release a finished sequence's pages.
    pub fn release(&mut self, seq: u64) -> Result<()> {
        let alloc = self.seqs.remove(&seq).ok_or_else(|| anyhow!("unknown sequence {seq}"))?;
        for p in &alloc.pages {
            self.stats[*p as usize] = PageStripeStats::default();
        }
        self.free.extend(alloc.pages);
        Ok(())
    }

    /// Evict a sequence under memory pressure: identical to [`release`]
    /// (pages freed, per-page stats reset) but counted separately, because
    /// an eviction means the victim must re-prefill from scratch while a
    /// release means it finished. Scheduler preemption is the only caller.
    ///
    /// [`release`]: PagePool::release
    pub fn evict(&mut self, seq: u64) -> Result<()> {
        let pages = self.seqs.get(&seq).map(|a| a.pages.len()).unwrap_or(0);
        self.release(seq)?;
        self.evictions += 1;
        self.evicted_pages += pages as u64;
        Ok(())
    }

    /// Eviction events so far (one per preempted sequence).
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Total pages reclaimed by evictions.
    pub fn evicted_pages(&self) -> u64 {
        self.evicted_pages
    }

    pub fn pages_of(&self, seq: u64) -> Option<&[u32]> {
        self.seqs.get(&seq).map(|a| a.pages.as_slice())
    }

    /// Record stripe stats for the page holding `token_pos` of `seq`
    /// (called by the engine after each prefill chunk's identification).
    pub fn record_stripe_stats(&mut self, seq: u64, token_pos: usize, hot_fraction: f32) -> Result<()> {
        let alloc = self.seqs.get(&seq).ok_or_else(|| anyhow!("unknown sequence {seq}"))?;
        let page_idx = token_pos / self.page_tokens;
        let page = *alloc
            .pages
            .get(page_idx)
            .ok_or_else(|| anyhow!("token {token_pos} beyond allocation"))?;
        self.stats[page as usize].hot_fraction = hot_fraction;
        Ok(())
    }

    pub fn stripe_stats(&self, page: u32) -> PageStripeStats {
        self.stats[page as usize]
    }

    /// Decode-reuse extension: the pages of `seq` whose prefill hot
    /// fraction meets `min_hot`, i.e. the pages decode attention should
    /// visit first.
    pub fn hot_pages(&self, seq: u64, min_hot: f32) -> Vec<u32> {
        self.seqs
            .get(&seq)
            .map(|a| {
                a.pages
                    .iter()
                    .copied()
                    .filter(|&p| self.stats[p as usize].hot_fraction >= min_hot)
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Record per-page stripe statistics straight from a [`SparsePlan`]:
    /// each page's hot fraction is the share of its tokens selected as a
    /// stripe by at least one query-block group. This is how prefill
    /// identification feeds the decode-phase page prioritization without
    /// the engine re-deriving anything from attention outputs.
    ///
    /// Errors (never panics) on an unadmitted `seq` and on any stripe at
    /// or past the admitted-token boundary — a coordinate the sequence's
    /// pages cannot hold means the plan and the allocation disagree, which
    /// must surface, not be silently absorbed into the heat map.
    pub fn record_plan(&mut self, seq: u64, plan: &SparsePlan) -> Result<()> {
        let alloc =
            self.seqs.get(&seq).ok_or_else(|| anyhow!("unknown sequence {seq}"))?;
        let pages = alloc.pages.clone();
        let covered_tokens = alloc.tokens.min(plan.n);
        let mut hot_counts = vec![0u32; pages.len()];
        let mut seen = vec![false; covered_tokens];
        for group in &plan.groups {
            for &col in &group.stripes {
                let col = col as usize;
                if col >= alloc.tokens {
                    return Err(anyhow!(
                        "plan stripe {col} out of range: sequence {seq} admitted {} tokens",
                        alloc.tokens
                    ));
                }
                if col < covered_tokens && !seen[col] {
                    seen[col] = true;
                    hot_counts[col / self.page_tokens] += 1;
                }
            }
        }
        for (idx, &page) in pages.iter().enumerate() {
            let page_start = idx * self.page_tokens;
            if page_start >= covered_tokens {
                // Past the plan's range: reset, so a shorter re-plan never
                // leaves stale heat from an earlier, longer plan.
                self.stats[page as usize].hot_fraction = 0.0;
                continue;
            }
            let page_len = (covered_tokens - page_start).min(self.page_tokens);
            self.stats[page as usize].hot_fraction =
                hot_counts[idx] as f32 / page_len as f32;
        }
        Ok(())
    }
}

/// Engine-side paged K/V storage for one layer: page-granular rows with
/// contiguous span reads and **gather-by-coordinates** — the plan
/// executor's `load_discrete` primitive over paged memory.
pub struct PagedKvStore {
    page_tokens: usize,
    d: usize,
    /// Per-page `[page_tokens, d]` K/V rows, indexed by page id.
    k_pages: Vec<Mat>,
    v_pages: Vec<Mat>,
}

impl PagedKvStore {
    pub fn new(total_pages: usize, page_tokens: usize, d: usize) -> Self {
        assert!(page_tokens >= 1 && d >= 1);
        Self {
            page_tokens,
            d,
            k_pages: (0..total_pages).map(|_| Mat::zeros(page_tokens, d)).collect(),
            v_pages: (0..total_pages).map(|_| Mat::zeros(page_tokens, d)).collect(),
        }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Write one token's K/V rows at sequence position `pos`, translating
    /// through the sequence's page table.
    pub fn write(&mut self, pages: &[u32], pos: usize, k_row: &[f32], v_row: &[f32]) -> Result<()> {
        if k_row.len() != self.d || v_row.len() != self.d {
            return Err(anyhow!("row dim mismatch: expected {}", self.d));
        }
        let (page, off) = self.translate(pages, pos)?;
        self.k_pages[page].row_mut(off).copy_from_slice(k_row);
        self.v_pages[page].row_mut(off).copy_from_slice(v_row);
        Ok(())
    }

    /// Gather discrete sequence positions (a plan's stripe coordinates)
    /// into contiguous `[len(coords), d]` K/V matrices.
    pub fn gather(&self, pages: &[u32], coords: &[u32]) -> Result<(Mat, Mat)> {
        let mut k = Mat::zeros(coords.len(), self.d);
        let mut v = Mat::zeros(coords.len(), self.d);
        for (i, &pos) in coords.iter().enumerate() {
            let (page, off) = self.translate(pages, pos as usize)?;
            k.row_mut(i).copy_from_slice(self.k_pages[page].row(off));
            v.row_mut(i).copy_from_slice(self.v_pages[page].row(off));
        }
        Ok((k, v))
    }

    /// Read a contiguous span `[start, end)` (a plan's anchor span) into
    /// contiguous K/V matrices — copied one page-aligned run at a time,
    /// not row by row (this is the hot read path for anchor regions).
    pub fn span(&self, pages: &[u32], start: usize, end: usize) -> Result<(Mat, Mat)> {
        if end < start {
            return Err(anyhow!("bad span [{start}, {end})"));
        }
        let len = end - start;
        let d = self.d;
        let mut k = Mat::zeros(len, d);
        let mut v = Mat::zeros(len, d);
        let mut pos = start;
        let mut out_row = 0;
        while pos < end {
            let (page, off) = self.translate(pages, pos)?;
            let run = (self.page_tokens - off).min(end - pos);
            k.data[out_row * d..(out_row + run) * d]
                .copy_from_slice(&self.k_pages[page].data[off * d..(off + run) * d]);
            v.data[out_row * d..(out_row + run) * d]
                .copy_from_slice(&self.v_pages[page].data[off * d..(off + run) * d]);
            pos += run;
            out_row += run;
        }
        Ok((k, v))
    }

    fn translate(&self, pages: &[u32], pos: usize) -> Result<(usize, usize)> {
        let page_idx = pos / self.page_tokens;
        let page = *pages
            .get(page_idx)
            .ok_or_else(|| anyhow!("position {pos} beyond the sequence's page table"))?;
        let page = page as usize;
        if page >= self.k_pages.len() {
            return Err(anyhow!("page {page} out of range"));
        }
        Ok((page, pos % self.page_tokens))
    }

    /// Check that every coordinate `plan` touches resolves through
    /// `pages`: plan length, span ends and stripe columns must land inside
    /// the page table, and every page id must exist in this store. Run
    /// before executing a plan against paged memory so bad coordinates
    /// surface as an error, not a panic inside the tile walk.
    pub fn validate_plan(&self, pages: &[u32], plan: &SparsePlan) -> Result<()> {
        let capacity = pages.len() * self.page_tokens;
        if plan.n > capacity {
            return Err(anyhow!("plan length {} exceeds paged capacity {capacity}", plan.n));
        }
        for &p in pages {
            if (p as usize) >= self.k_pages.len() {
                return Err(anyhow!("page {p} out of range"));
            }
        }
        for g in &plan.groups {
            for &(s, e) in &g.spans {
                if s > e || e as usize > capacity {
                    return Err(anyhow!("span [{s}, {e}) outside paged capacity {capacity}"));
                }
            }
            // Stripes are sorted: checking the last bounds them all.
            if let Some(&c) = g.stripes.last() {
                if c as usize >= capacity {
                    return Err(anyhow!("stripe {c} outside paged capacity {capacity}"));
                }
            }
        }
        Ok(())
    }
}

/// [`KvSource`] over a [`PagedKvStore`] plus one sequence's page table:
/// span and gather reads translate through the table, so an executor's
/// tile walk runs directly on paged memory. Reads are pure copies of the
/// stored rows, so paged execution is bitwise-identical to flat execution
/// over the same values (property-tested in `tests/prop_plan_parity.rs`).
pub struct PagedKv<'a> {
    store: &'a PagedKvStore,
    pages: &'a [u32],
}

impl<'a> PagedKv<'a> {
    pub fn new(store: &'a PagedKvStore, pages: &'a [u32]) -> Self {
        Self { store, pages }
    }
}

impl KvSource for PagedKv<'_> {
    fn d(&self) -> usize {
        self.store.d
    }

    fn span(&self, start: usize, end: usize) -> (Mat, Mat) {
        self.store.span(self.pages, start, end).expect("paged span (validate_plan first)")
    }

    fn gather(&self, coords: &[u32]) -> (Mat, Mat) {
        self.store.gather(self.pages, coords).expect("paged gather (validate_plan first)")
    }

    fn span_into(&self, start: usize, end: usize, row0: usize, k_dst: &mut Mat, v_dst: &mut Mat) {
        // Page-run memcpy straight into the destination tile — no
        // intermediate Mat. Same traversal as `PagedKvStore::span`.
        let store = self.store;
        let d = store.d;
        let mut pos = start;
        let mut out_row = row0;
        while pos < end {
            let (page, off) =
                store.translate(self.pages, pos).expect("paged span (validate_plan first)");
            let run = (store.page_tokens - off).min(end - pos);
            k_dst.data[out_row * d..(out_row + run) * d]
                .copy_from_slice(&store.k_pages[page].data[off * d..(off + run) * d]);
            v_dst.data[out_row * d..(out_row + run) * d]
                .copy_from_slice(&store.v_pages[page].data[off * d..(off + run) * d]);
            pos += run;
            out_row += run;
        }
    }

    fn gather_into(&self, coords: &[u32], row0: usize, k_dst: &mut Mat, v_dst: &mut Mat) {
        let store = self.store;
        let d = store.d;
        for (i, &pos) in coords.iter().enumerate() {
            let (page, off) = store
                .translate(self.pages, pos as usize)
                .expect("paged gather (validate_plan first)");
            let dst = (row0 + i) * d;
            k_dst.data[dst..dst + d].copy_from_slice(store.k_pages[page].row(off));
            v_dst.data[dst..dst + d].copy_from_slice(store.v_pages[page].row(off));
        }
    }
}

/// Executor wrapper routing any backend's K/V reads through paged serving
/// memory: [`PagedKvStore::gather`] / [`PagedKvStore::span`] become the
/// backend's [`KvSource`], so paged serving executes a [`SparsePlan`]
/// without flattening the cache. Q still arrives per head; the flat K/V
/// of a [`crate::attention::HeadInput`] handed to [`Executor::execute`]
/// are ignored — the store is authoritative.
///
/// Plan/page-table mismatches: [`PagedExecutor::try_execute`] surfaces
/// them as an `Err` (the serving entry). The infallible [`Executor`]
/// trait entries instead validate up front and panic with the validation
/// message — an assertion against caller bugs, never a mid-walk index
/// panic deep inside worker threads.
pub struct PagedExecutor<'a> {
    store: &'a PagedKvStore,
    pages: &'a [u32],
    inner: &'a dyn Executor,
}

impl<'a> PagedExecutor<'a> {
    pub fn new(store: &'a PagedKvStore, pages: &'a [u32], inner: &'a dyn Executor) -> Self {
        Self { store, pages, inner }
    }

    /// Serving entry: validate the plan against the page table, then
    /// execute it on the wrapped backend. Invalid coordinates surface as
    /// an `Err`, never a panic inside the walk.
    pub fn try_execute(&self, q: &Mat, plan: &SparsePlan) -> Result<AttnOutput> {
        self.store.validate_plan(self.pages, plan)?;
        Ok(self.inner.execute_source(q, &PagedKv::new(self.store, self.pages), plan, true))
    }
}

impl Executor for PagedExecutor<'_> {
    /// Reports the wrapped backend's identity — the paged route is a
    /// memory-layout detail, not a different compute backend.
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn execute_source(
        &self,
        q: &Mat,
        _kv: &dyn KvSource,
        plan: &SparsePlan,
        parallel: bool,
    ) -> AttnOutput {
        // The trait entry is infallible: assert plan/page-table agreement
        // up front (one clear message) instead of unwrapping mid-walk.
        // Callers that need an Err use `try_execute`.
        self.store
            .validate_plan(self.pages, plan)
            .expect("plan does not resolve through the page table (use try_execute for an Err)");
        // Every read goes through the paged source, whatever K/V the
        // caller supplied.
        self.inner.execute_source(q, &PagedKv::new(self.store, self.pages), plan, parallel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_and_release_roundtrip() {
        let mut pool = PagePool::new(8, 64);
        assert!(pool.can_admit(256));
        pool.admit(1, 256).unwrap(); // 4 pages
        assert_eq!(pool.used_pages(), 4);
        assert_eq!(pool.pages_of(1).unwrap().len(), 4);
        pool.release(1).unwrap();
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(pool.free_pages(), 8);
    }

    #[test]
    fn admission_control_blocks_when_full() {
        let mut pool = PagePool::new(4, 64);
        pool.admit(1, 200).unwrap(); // 4 pages
        assert!(!pool.can_admit(1));
        assert!(pool.admit(2, 64).is_err());
        pool.release(1).unwrap();
        assert!(pool.can_admit(256));
    }

    #[test]
    fn double_admit_rejected() {
        let mut pool = PagePool::new(4, 64);
        pool.admit(7, 64).unwrap();
        assert!(pool.admit(7, 64).is_err());
    }

    #[test]
    fn release_unknown_rejected() {
        let mut pool = PagePool::new(4, 64);
        assert!(pool.release(3).is_err());
    }

    #[test]
    fn stripe_stats_tracked_per_page() {
        let mut pool = PagePool::new(8, 64);
        pool.admit(1, 256).unwrap();
        pool.record_stripe_stats(1, 0, 0.9).unwrap();
        pool.record_stripe_stats(1, 130, 0.2).unwrap(); // page 2
        let pages = pool.pages_of(1).unwrap().to_vec();
        assert_eq!(pool.stripe_stats(pages[0]).hot_fraction, 0.9);
        assert_eq!(pool.stripe_stats(pages[2]).hot_fraction, 0.2);
        let hot = pool.hot_pages(1, 0.5);
        assert_eq!(hot, vec![pages[0]]);
    }

    #[test]
    fn evictions_are_counted_separately_from_releases() {
        let mut pool = PagePool::new(8, 64);
        pool.admit(1, 256).unwrap(); // 4 pages
        pool.admit(2, 64).unwrap(); // 1 page
        assert_eq!(pool.evictions(), 0);
        pool.evict(1).unwrap();
        assert_eq!(pool.evictions(), 1);
        assert_eq!(pool.evicted_pages(), 4);
        assert_eq!(pool.free_pages(), 7);
        // A normal release does not bump the eviction counters.
        pool.release(2).unwrap();
        assert_eq!(pool.evictions(), 1);
        assert_eq!(pool.evicted_pages(), 4);
        assert!(pool.evict(99).is_err());
    }

    #[test]
    fn stats_reset_on_release() {
        let mut pool = PagePool::new(2, 64);
        pool.admit(1, 64).unwrap();
        let page = pool.pages_of(1).unwrap()[0];
        pool.record_stripe_stats(1, 0, 0.7).unwrap();
        pool.release(1).unwrap();
        assert_eq!(pool.stripe_stats(page).hot_fraction, 0.0);
    }

    #[test]
    fn zero_token_admission_takes_one_page() {
        let mut pool = PagePool::new(2, 64);
        pool.admit(1, 0).unwrap();
        assert_eq!(pool.used_pages(), 1);
    }

    fn test_plan(n: usize, stripes_per_group: &[Vec<u32>]) -> crate::attention::plan::SparsePlan {
        use crate::attention::plan::{GroupPlan, SparsePlan};
        use crate::attention::{CostTally, TileConfig};
        let tile = TileConfig::new(16, 16);
        let groups = stripes_per_group
            .iter()
            .map(|s| GroupPlan { spans: Vec::new(), stripes: s.clone() })
            .collect();
        SparsePlan::new("test", n, 8, tile, 1, groups, CostTally::default())
    }

    #[test]
    fn record_plan_sets_page_hot_fractions() {
        let mut pool = PagePool::new(8, 16); // page_tokens == b_q == 16
        pool.admit(1, 64).unwrap(); // 4 pages
        // 64-token plan: page 0 fully hot for group 3, page 1 half hot.
        let plan = test_plan(
            64,
            &[
                vec![],
                vec![0, 1],
                vec![2, 3, 16, 17, 18, 19, 20, 21, 22, 23],
                (0..16u32).collect::<Vec<_>>(),
            ],
        );
        pool.record_plan(1, &plan).unwrap();
        let pages = pool.pages_of(1).unwrap().to_vec();
        // Page 0: all 16 tokens selected by some group.
        assert_eq!(pool.stripe_stats(pages[0]).hot_fraction, 1.0);
        // Page 1: tokens 16..24 selected → 8/16.
        assert_eq!(pool.stripe_stats(pages[1]).hot_fraction, 0.5);
        // Pages 2, 3: untouched.
        assert_eq!(pool.stripe_stats(pages[2]).hot_fraction, 0.0);
        assert_eq!(pool.hot_pages(1, 0.6), vec![pages[0]]);
    }

    #[test]
    fn record_plan_resets_stale_heat_on_shorter_replan() {
        let mut pool = PagePool::new(8, 16);
        pool.admit(1, 64).unwrap();
        let pages = pool.pages_of(1).unwrap().to_vec();
        // Long plan heats page 3 fully.
        let long = test_plan(64, &[vec![], vec![], vec![], (48..64u32).collect()]);
        pool.record_plan(1, &long).unwrap();
        assert_eq!(pool.stripe_stats(pages[3]).hot_fraction, 1.0);
        // Shorter re-plan covers only the first 32 tokens: later pages must
        // not keep the old heat.
        let short = test_plan(32, &[vec![0], vec![]]);
        pool.record_plan(1, &short).unwrap();
        assert_eq!(pool.stripe_stats(pages[3]).hot_fraction, 0.0);
        assert_eq!(pool.stripe_stats(pages[2]).hot_fraction, 0.0);
        assert!(pool.stripe_stats(pages[0]).hot_fraction > 0.0);
    }

    #[test]
    fn record_plan_unknown_sequence_rejected() {
        let mut pool = PagePool::new(2, 16);
        let plan = test_plan(16, &[vec![0]]);
        assert!(pool.record_plan(9, &plan).is_err());
    }

    /// Edge cases must error, never panic: a stripe at exactly the
    /// admitted-token boundary (one past the last valid position) and an
    /// unadmitted sequence.
    #[test]
    fn record_plan_boundary_coordinate_errors_not_panics() {
        let mut pool = PagePool::new(8, 16);
        pool.admit(1, 32).unwrap(); // positions 0..32 valid
        // Stripe at exactly 32 — the admitted boundary — must error.
        let boundary = test_plan(48, &[vec![0], vec![31, 32], vec![]]);
        let err = pool.record_plan(1, &boundary).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        // Well past the boundary errors too.
        let far = test_plan(48, &[vec![], vec![], vec![47]]);
        assert!(pool.record_plan(1, &far).is_err());
        // The last valid position is fine, and heat still lands.
        let ok = test_plan(32, &[vec![0], vec![31]]);
        pool.record_plan(1, &ok).unwrap();
        let pages = pool.pages_of(1).unwrap().to_vec();
        assert!(pool.stripe_stats(pages[1]).hot_fraction > 0.0);
        // Unadmitted sequence: error, not panic, and pool state untouched.
        assert!(pool.record_plan(7, &ok).is_err());
    }

    #[test]
    fn paged_store_gather_matches_flat_gather() {
        use crate::tensor::Mat;
        let d = 8;
        let n = 48;
        let flat_k = Mat::from_fn(n, d, |r, c| (r * 100 + c) as f32);
        let flat_v = Mat::from_fn(n, d, |r, c| (r * 100 + c) as f32 + 0.5);
        let mut store = PagedKvStore::new(4, 16, d);
        let pages: Vec<u32> = vec![2, 0, 3]; // deliberately non-identity
        for pos in 0..n {
            store.write(&pages, pos, flat_k.row(pos), flat_v.row(pos)).unwrap();
        }
        let coords: Vec<u32> = vec![0, 5, 17, 31, 32, 47];
        let (k, v) = store.gather(&pages, &coords).unwrap();
        assert_eq!(k, flat_k.gather_rows(&coords));
        assert_eq!(v, flat_v.gather_rows(&coords));
        // Contiguous span read crosses page boundaries transparently.
        let (ks, _) = store.span(&pages, 10, 40).unwrap();
        let span_coords: Vec<u32> = (10..40).collect();
        assert_eq!(ks, flat_k.gather_rows(&span_coords));
    }

    #[test]
    fn paged_store_bounds_checked() {
        let mut store = PagedKvStore::new(2, 16, 4);
        let pages = vec![0u32, 1];
        assert!(store.write(&pages, 40, &[0.0; 4], &[0.0; 4]).is_err());
        assert!(store.write(&pages, 0, &[0.0; 3], &[0.0; 4]).is_err());
        assert!(store.gather(&pages, &[33]).is_err());
        assert!(store.span(&pages, 5, 3).is_err());
        assert!(store.gather(&pages, &[31]).is_ok());
    }

    /// Executing a plan through the paged route (store as KvSource) is
    /// bitwise-identical to flat execution, for both executor backends.
    #[test]
    fn paged_executor_matches_flat_execution_bitwise() {
        use crate::attention::exec::{CpuTileExecutor, PjrtGatherExecutor};
        use crate::attention::{anchor::AnchorConfig, HeadInput, Method, TileConfig};
        use crate::util::rng::Pcg64;

        let n = 96;
        let d = 8;
        let mut rng = Pcg64::seeded(33);
        let head = HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        );
        let m = Method::Anchor(AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta: 3.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        });
        let plan = m.plan(&head);

        // Page the K/V rows in through a deliberately non-identity table.
        let mut store = PagedKvStore::new(8, 16, d);
        let pages: Vec<u32> = vec![5, 0, 7, 2, 4, 1];
        for pos in 0..n {
            store.write(&pages, pos, head.k.row(pos), head.v.row(pos)).unwrap();
        }

        let cpu = CpuTileExecutor::default();
        let pjrt = PjrtGatherExecutor::new();
        let flat = cpu.execute(&head, &plan);
        let paged_cpu =
            PagedExecutor::new(&store, &pages, &cpu).try_execute(&head.q, &plan).unwrap();
        let paged_pjrt =
            PagedExecutor::new(&store, &pages, &pjrt).try_execute(&head.q, &plan).unwrap();
        assert_eq!(flat.out.data, paged_cpu.out.data, "paged cpu diverges");
        assert_eq!(flat.out.data, paged_pjrt.out.data, "paged pjrt diverges");
        assert_eq!(flat.cost, paged_cpu.cost);
        assert_eq!(flat.cost, paged_pjrt.cost);
        // The wrapper reports the backend identity it routes to.
        assert_eq!(PagedExecutor::new(&store, &pages, &cpu).name(), "cpu");
        assert_eq!(PagedExecutor::new(&store, &pages, &pjrt).name(), "pjrt");
    }

    /// A plan whose coordinates outrun the page table errors up front.
    #[test]
    fn paged_executor_rejects_out_of_table_plans() {
        use crate::attention::exec::CpuTileExecutor;

        let d = 4;
        let store = PagedKvStore::new(2, 16, d);
        let pages = vec![0u32]; // capacity: 16 tokens
        let plan = test_plan(32, &[vec![0], vec![17]]);
        let cpu = CpuTileExecutor::default();
        let q = Mat::zeros(32, d);
        let err = PagedExecutor::new(&store, &pages, &cpu).try_execute(&q, &plan).unwrap_err();
        assert!(err.to_string().contains("capacity"), "{err}");
        // Same store, table that covers the plan: executes cleanly.
        let pages_ok = vec![0u32, 1];
        let ok_plan = test_plan(32, &[vec![0], vec![3, 17]]);
        let out =
            PagedExecutor::new(&store, &pages_ok, &cpu).try_execute(&q, &ok_plan).unwrap();
        assert_eq!(out.out.rows, 32);
    }
}

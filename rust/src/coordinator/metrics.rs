//! Serving metrics: per-request records and aggregate report.

use std::collections::BTreeMap;

use crate::util::stats;

/// What ultimately happened to one submitted request — the per-request
/// outcome the [`ServeReport`] carries so a front end (or its operator)
/// can tell shed load from served load without parsing log lines.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Served to completion.
    #[default]
    Completed,
    /// Rejected at validation (empty prompt, zero decode budget).
    RejectedInvalid,
    /// Rejected because prompt + decode budget exceeds `max_seq`.
    RejectedOversized,
    /// Shed by admission control (`max_pending` queue cap).
    Overloaded,
    /// Admitted but failed mid-serve (engine error).
    Failed,
}

impl RequestOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            RequestOutcome::Completed => "completed",
            RequestOutcome::RejectedInvalid => "rejected-invalid",
            RequestOutcome::RejectedOversized => "rejected-oversized",
            RequestOutcome::Overloaded => "overloaded",
            RequestOutcome::Failed => "failed",
        }
    }
}

/// Final record for one served request.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub id: u64,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub arrival_s: f64,
    /// Time to first token, from arrival.
    pub ttft_s: f64,
    /// End-to-end latency, from arrival.
    pub e2e_s: f64,
    /// How the request ended (completed / rejected / shed / failed).
    pub outcome: RequestOutcome,
    /// Workload scenario tag the request carried (`None` if untagged).
    pub scenario: Option<String>,
    /// Plan-cache hits/misses attributed to this request by the engine
    /// (zero when the executor doesn't attribute, e.g. the mock).
    pub plan_hits: u64,
    pub plan_misses: u64,
    /// Of this request's `plan_misses`, how many were resolved by a
    /// speculative reuse hit (recall check passed) vs fell back to full
    /// identification (DESIGN.md §17).
    pub speculative_hits: u64,
    pub speculative_fallbacks: u64,
    /// KV-page evictions this request suffered (prefill preemption).
    pub evictions: u32,
}

/// Per-scenario aggregate inside a [`ServeReport`] — the breakdown ISSUE 9
/// gates on (shared-prefix traffic must out-hit needle traffic).
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioStats {
    pub scenario: String,
    pub requests: usize,
    pub completed: usize,
    pub p50_ttft_s: f64,
    pub p99_ttft_s: f64,
    pub plan_hits: u64,
    pub plan_misses: u64,
    pub speculative_hits: u64,
    pub speculative_fallbacks: u64,
    pub evictions: u64,
}

impl ScenarioStats {
    /// Plan-cache hit rate over attributed lookups (0 when none).
    pub fn plan_hit_rate(&self) -> f64 {
        let total = self.plan_hits + self.plan_misses;
        if total == 0 {
            0.0
        } else {
            self.plan_hits as f64 / total as f64
        }
    }

    /// Fraction of plan-cache misses a speculative reuse hit resolved
    /// instead of full identification (0 when nothing missed) — the
    /// serve-slo shared-prefix floor reads this (DESIGN.md §17).
    pub fn speculative_hit_rate(&self) -> f64 {
        if self.plan_misses == 0 {
            0.0
        } else {
            self.speculative_hits as f64 / self.plan_misses as f64
        }
    }
}

/// Aggregate serving report (printed by `serve` / `examples/serve_trace`).
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub records: Vec<RequestRecord>,
    pub wall_s: f64,
    pub iterations: u64,
    pub engine_busy_s: f64,
    /// Iterations whose engine reported a merged plan-cache hit rate that
    /// was folded into the scheduler's `plan_hit_rate` EWMA live
    /// (DESIGN.md §12).
    pub plan_hit_observations: u64,
    /// Scheduler's plan-hit EWMA at the end of the run (`None` for the
    /// dense model, which carries no amortization state).
    pub final_plan_hit_rate: Option<f64>,
    /// KV-page eviction events the pool recorded (prefill preemption under
    /// memory pressure).
    pub kv_evictions: u64,
    /// High-water mark of the admission queue depth.
    pub peak_queue_depth: usize,
}

impl ServeReport {
    /// Records with the given outcome.
    pub fn outcome_count(&self, outcome: RequestOutcome) -> usize {
        self.records.iter().filter(|r| r.outcome == outcome).count()
    }

    pub fn total_prompt_tokens(&self) -> usize {
        self.records.iter().map(|r| r.prompt_tokens).sum()
    }

    pub fn total_generated_tokens(&self) -> usize {
        self.records.iter().map(|r| r.generated_tokens).sum()
    }

    pub fn prefill_throughput(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_prompt_tokens() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn decode_throughput(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_generated_tokens() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn ttft_percentile(&self, q: f64) -> f64 {
        // Shed/rejected records carry NaN latencies; filter them so the
        // percentile sort never sees an unordered value.
        let xs: Vec<f64> =
            self.records.iter().map(|r| r.ttft_s).filter(|x| x.is_finite()).collect();
        stats::percentile(&xs, q)
    }

    pub fn e2e_percentile(&self, q: f64) -> f64 {
        let xs: Vec<f64> =
            self.records.iter().map(|r| r.e2e_s).filter(|x| x.is_finite()).collect();
        stats::percentile(&xs, q)
    }

    /// Per-scenario breakdown, sorted by scenario tag (untagged traffic
    /// aggregates under `"untagged"`). Single pass over the records: the
    /// `BTreeMap` yields the same sorted-tag order the old
    /// sort+dedup+rescan produced, without the O(tags × records)
    /// re-filtering on multi-thousand-request traces.
    pub fn scenario_breakdown(&self) -> Vec<ScenarioStats> {
        #[derive(Default)]
        struct Acc {
            requests: usize,
            completed: usize,
            ttfts: Vec<f64>,
            plan_hits: u64,
            plan_misses: u64,
            speculative_hits: u64,
            speculative_fallbacks: u64,
            evictions: u64,
        }
        let mut by_tag: BTreeMap<&str, Acc> = BTreeMap::new();
        for r in &self.records {
            let acc =
                by_tag.entry(r.scenario.as_deref().unwrap_or("untagged")).or_default();
            acc.requests += 1;
            if r.outcome == RequestOutcome::Completed {
                acc.completed += 1;
            }
            if r.ttft_s.is_finite() {
                acc.ttfts.push(r.ttft_s);
            }
            acc.plan_hits += r.plan_hits;
            acc.plan_misses += r.plan_misses;
            acc.speculative_hits += r.speculative_hits;
            acc.speculative_fallbacks += r.speculative_fallbacks;
            acc.evictions += r.evictions as u64;
        }
        by_tag
            .into_iter()
            .map(|(tag, acc)| ScenarioStats {
                scenario: tag.to_string(),
                requests: acc.requests,
                completed: acc.completed,
                p50_ttft_s: stats::percentile(&acc.ttfts, 50.0),
                p99_ttft_s: stats::percentile(&acc.ttfts, 99.0),
                plan_hits: acc.plan_hits,
                plan_misses: acc.plan_misses,
                speculative_hits: acc.speculative_hits,
                speculative_fallbacks: acc.speculative_fallbacks,
                evictions: acc.evictions,
            })
            .collect()
    }

    pub fn utilization(&self) -> f64 {
        if self.wall_s > 0.0 {
            (self.engine_busy_s / self.wall_s).min(1.0)
        } else {
            0.0
        }
    }

    pub fn print_summary(&self) {
        println!("── serve report ──────────────────────────────────────");
        println!("requests          {:>10}", self.records.len());
        println!("wall time         {:>10.2} s", self.wall_s);
        println!("iterations        {:>10}", self.iterations);
        println!("engine util       {:>10.1} %", self.utilization() * 100.0);
        println!(
            "prompt tokens     {:>10}   ({:.0} tok/s)",
            self.total_prompt_tokens(),
            self.prefill_throughput()
        );
        println!(
            "generated tokens  {:>10}   ({:.0} tok/s)",
            self.total_generated_tokens(),
            self.decode_throughput()
        );
        println!(
            "TTFT p50/p95      {:>8.3} / {:.3} s",
            self.ttft_percentile(50.0),
            self.ttft_percentile(95.0)
        );
        println!(
            "E2E  p50/p95      {:>8.3} / {:.3} s",
            self.e2e_percentile(50.0),
            self.e2e_percentile(95.0)
        );
        if let Some(rate) = self.final_plan_hit_rate {
            println!(
                "plan-hit EWMA     {:>10.2}   ({} live observation(s))",
                rate, self.plan_hit_observations
            );
        }
        if self.kv_evictions > 0 {
            println!("kv evictions      {:>10}", self.kv_evictions);
        }
        if self.peak_queue_depth > 0 {
            println!("peak queue depth  {:>10}", self.peak_queue_depth);
        }
        let breakdown = self.scenario_breakdown();
        if breakdown.iter().any(|s| s.scenario != "untagged") {
            for s in &breakdown {
                let spec = if s.speculative_hits + s.speculative_fallbacks > 0 {
                    format!(", spec hit {:.0}%", s.speculative_hit_rate() * 100.0)
                } else {
                    String::new()
                };
                println!(
                    "  [{}] {} req, p99 TTFT {:.3} s, plan hit {:.0}%{spec}",
                    s.scenario,
                    s.requests,
                    s.p99_ttft_s,
                    s.plan_hit_rate() * 100.0
                );
            }
        }
        let not_completed: Vec<String> = [
            RequestOutcome::RejectedInvalid,
            RequestOutcome::RejectedOversized,
            RequestOutcome::Overloaded,
            RequestOutcome::Failed,
        ]
        .iter()
        .filter_map(|&o| {
            let n = self.outcome_count(o);
            (n > 0).then(|| format!("{n} {}", o.name()))
        })
        .collect();
        if !not_completed.is_empty() {
            println!("not completed     {:>10}", not_completed.join(", "));
        }
    }

    /// Compact JSON summary — the wire front-end's Metrics reply.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"requests\": {}, \"completed\": {}, \"rejected_invalid\": {}, \
             \"rejected_oversized\": {}, \"overloaded\": {}, \"failed\": {}, \
             \"iterations\": {}, \"wall_s\": {:.6}, \"prompt_tokens\": {}, \
             \"generated_tokens\": {}, \"plan_hit_observations\": {}, \
             \"kv_evictions\": {}, \"peak_queue_depth\": {}}}",
            self.records.len(),
            self.outcome_count(RequestOutcome::Completed),
            self.outcome_count(RequestOutcome::RejectedInvalid),
            self.outcome_count(RequestOutcome::RejectedOversized),
            self.outcome_count(RequestOutcome::Overloaded),
            self.outcome_count(RequestOutcome::Failed),
            self.iterations,
            self.wall_s,
            self.total_prompt_tokens(),
            self.total_generated_tokens(),
            self.plan_hit_observations,
            self.kv_evictions,
            self.peak_queue_depth,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(id: u64, ttft: f64, e2e: f64) -> RequestRecord {
        RequestRecord {
            id,
            prompt_tokens: 100,
            generated_tokens: 10,
            arrival_s: 0.0,
            ttft_s: ttft,
            e2e_s: e2e,
            outcome: RequestOutcome::Completed,
            scenario: None,
            plan_hits: 0,
            plan_misses: 0,
            speculative_hits: 0,
            speculative_fallbacks: 0,
            evictions: 0,
        }
    }

    #[test]
    fn aggregates() {
        let rep = ServeReport {
            records: vec![record(1, 0.1, 1.0), record(2, 0.3, 2.0)],
            wall_s: 4.0,
            iterations: 10,
            engine_busy_s: 2.0,
            ..ServeReport::default()
        };
        assert_eq!(rep.total_prompt_tokens(), 200);
        assert_eq!(rep.total_generated_tokens(), 20);
        assert_eq!(rep.prefill_throughput(), 50.0);
        assert_eq!(rep.decode_throughput(), 5.0);
        assert_eq!(rep.utilization(), 0.5);
        assert!((rep.ttft_percentile(50.0) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn empty_report_is_safe() {
        let rep = ServeReport::default();
        assert_eq!(rep.prefill_throughput(), 0.0);
        assert_eq!(rep.ttft_percentile(99.0), 0.0);
        assert_eq!(rep.utilization(), 0.0);
    }

    #[test]
    fn outcomes_are_counted_and_summarized() {
        let mut shed = record(3, f64::NAN, f64::NAN);
        shed.generated_tokens = 0;
        shed.outcome = RequestOutcome::Overloaded;
        let rep = ServeReport {
            records: vec![record(1, 0.1, 1.0), record(2, 0.3, 2.0), shed],
            ..ServeReport::default()
        };
        assert_eq!(rep.outcome_count(RequestOutcome::Completed), 2);
        assert_eq!(rep.outcome_count(RequestOutcome::Overloaded), 1);
        assert_eq!(rep.outcome_count(RequestOutcome::Failed), 0);
        let json = rep.to_json();
        assert!(json.contains("\"completed\": 2"), "{json}");
        assert!(json.contains("\"overloaded\": 1"), "{json}");
        assert!(json.contains("\"kv_evictions\": 0"), "{json}");
    }

    #[test]
    fn nan_latencies_do_not_poison_percentiles() {
        // A shed record carries NaN; percentiles must come from the two
        // finite records only (and not panic in the sort).
        let mut shed = record(3, f64::NAN, f64::NAN);
        shed.outcome = RequestOutcome::Overloaded;
        let rep = ServeReport {
            records: vec![record(1, 0.1, 1.0), record(2, 0.3, 2.0), shed],
            ..ServeReport::default()
        };
        assert!((rep.ttft_percentile(50.0) - 0.2).abs() < 1e-9);
        assert!(rep.e2e_percentile(99.0).is_finite());
    }

    #[test]
    fn scenario_breakdown_attributes_hits_per_tag() {
        let tagged = |id, tag: &str, ttft: f64, hits, misses| {
            let mut r = record(id, ttft, ttft + 1.0);
            r.scenario = Some(tag.to_string());
            r.plan_hits = hits;
            r.plan_misses = misses;
            r
        };
        let rep = ServeReport {
            records: vec![
                tagged(1, "shared-prefix", 0.1, 9, 1),
                tagged(2, "shared-prefix", 0.2, 8, 2),
                tagged(3, "needle", 0.4, 0, 10),
                record(4, 0.3, 1.3),
            ],
            ..ServeReport::default()
        };
        let breakdown = rep.scenario_breakdown();
        let tags: Vec<&str> = breakdown.iter().map(|s| s.scenario.as_str()).collect();
        assert_eq!(tags, vec!["needle", "shared-prefix", "untagged"]);
        let shared = &breakdown[1];
        assert_eq!(shared.requests, 2);
        assert_eq!(shared.completed, 2);
        assert!((shared.plan_hit_rate() - 17.0 / 20.0).abs() < 1e-9);
        let needle = &breakdown[0];
        assert_eq!(needle.plan_hit_rate(), 0.0);
        assert!(shared.plan_hit_rate() > needle.plan_hit_rate());
        assert_eq!(breakdown[2].plan_hits + breakdown[2].plan_misses, 0);
    }

    /// Speculative attribution aggregates per tag, and the rate is over
    /// plan misses (a tag with no misses reports 0, not NaN).
    #[test]
    fn scenario_breakdown_aggregates_speculative_attribution() {
        let spec = |id, tag: &str, misses, spec_hits, fallbacks| {
            let mut r = record(id, 0.1, 1.0);
            r.scenario = Some(tag.to_string());
            r.plan_misses = misses;
            r.speculative_hits = spec_hits;
            r.speculative_fallbacks = fallbacks;
            r
        };
        let rep = ServeReport {
            records: vec![
                spec(1, "shared-prefix", 4, 3, 1),
                spec(2, "shared-prefix", 4, 3, 0),
                spec(3, "needle", 8, 0, 0),
            ],
            ..ServeReport::default()
        };
        let breakdown = rep.scenario_breakdown();
        let shared = breakdown.iter().find(|s| s.scenario == "shared-prefix").unwrap();
        assert_eq!((shared.speculative_hits, shared.speculative_fallbacks), (6, 1));
        assert!((shared.speculative_hit_rate() - 6.0 / 8.0).abs() < 1e-9);
        let needle = breakdown.iter().find(|s| s.scenario == "needle").unwrap();
        assert_eq!(needle.speculative_hit_rate(), 0.0);
        // No misses at all: rate degrades to 0, never divides by zero.
        assert_eq!(
            ScenarioStats {
                scenario: "x".into(),
                requests: 0,
                completed: 0,
                p50_ttft_s: 0.0,
                p99_ttft_s: 0.0,
                plan_hits: 5,
                plan_misses: 0,
                speculative_hits: 0,
                speculative_fallbacks: 0,
                evictions: 0,
            }
            .speculative_hit_rate(),
            0.0
        );
    }
}

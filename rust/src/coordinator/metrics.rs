//! Serving metrics: per-request records and aggregate report.

use crate::util::stats;

/// What ultimately happened to one submitted request — the per-request
/// outcome the [`ServeReport`] carries so a front end (or its operator)
/// can tell shed load from served load without parsing log lines.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Served to completion.
    #[default]
    Completed,
    /// Rejected at validation (empty prompt, zero decode budget).
    RejectedInvalid,
    /// Rejected because prompt + decode budget exceeds `max_seq`.
    RejectedOversized,
    /// Shed by admission control (`max_pending` queue cap).
    Overloaded,
    /// Admitted but failed mid-serve (engine error).
    Failed,
}

impl RequestOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            RequestOutcome::Completed => "completed",
            RequestOutcome::RejectedInvalid => "rejected-invalid",
            RequestOutcome::RejectedOversized => "rejected-oversized",
            RequestOutcome::Overloaded => "overloaded",
            RequestOutcome::Failed => "failed",
        }
    }
}

/// Final record for one served request.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub id: u64,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub arrival_s: f64,
    /// Time to first token, from arrival.
    pub ttft_s: f64,
    /// End-to-end latency, from arrival.
    pub e2e_s: f64,
    /// How the request ended (completed / rejected / shed / failed).
    pub outcome: RequestOutcome,
}

/// Aggregate serving report (printed by `serve` / `examples/serve_trace`).
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub records: Vec<RequestRecord>,
    pub wall_s: f64,
    pub iterations: u64,
    pub engine_busy_s: f64,
    /// Iterations whose engine reported a merged plan-cache hit rate that
    /// was folded into the scheduler's `plan_hit_rate` EWMA live
    /// (DESIGN.md §12).
    pub plan_hit_observations: u64,
    /// Scheduler's plan-hit EWMA at the end of the run (`None` for the
    /// dense model, which carries no amortization state).
    pub final_plan_hit_rate: Option<f64>,
}

impl ServeReport {
    /// Records with the given outcome.
    pub fn outcome_count(&self, outcome: RequestOutcome) -> usize {
        self.records.iter().filter(|r| r.outcome == outcome).count()
    }

    pub fn total_prompt_tokens(&self) -> usize {
        self.records.iter().map(|r| r.prompt_tokens).sum()
    }

    pub fn total_generated_tokens(&self) -> usize {
        self.records.iter().map(|r| r.generated_tokens).sum()
    }

    pub fn prefill_throughput(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_prompt_tokens() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn decode_throughput(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_generated_tokens() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn ttft_percentile(&self, q: f64) -> f64 {
        let xs: Vec<f64> = self.records.iter().map(|r| r.ttft_s).collect();
        stats::percentile(&xs, q)
    }

    pub fn e2e_percentile(&self, q: f64) -> f64 {
        let xs: Vec<f64> = self.records.iter().map(|r| r.e2e_s).collect();
        stats::percentile(&xs, q)
    }

    pub fn utilization(&self) -> f64 {
        if self.wall_s > 0.0 {
            (self.engine_busy_s / self.wall_s).min(1.0)
        } else {
            0.0
        }
    }

    pub fn print_summary(&self) {
        println!("── serve report ──────────────────────────────────────");
        println!("requests          {:>10}", self.records.len());
        println!("wall time         {:>10.2} s", self.wall_s);
        println!("iterations        {:>10}", self.iterations);
        println!("engine util       {:>10.1} %", self.utilization() * 100.0);
        println!(
            "prompt tokens     {:>10}   ({:.0} tok/s)",
            self.total_prompt_tokens(),
            self.prefill_throughput()
        );
        println!(
            "generated tokens  {:>10}   ({:.0} tok/s)",
            self.total_generated_tokens(),
            self.decode_throughput()
        );
        println!(
            "TTFT p50/p95      {:>8.3} / {:.3} s",
            self.ttft_percentile(50.0),
            self.ttft_percentile(95.0)
        );
        println!(
            "E2E  p50/p95      {:>8.3} / {:.3} s",
            self.e2e_percentile(50.0),
            self.e2e_percentile(95.0)
        );
        if let Some(rate) = self.final_plan_hit_rate {
            println!(
                "plan-hit EWMA     {:>10.2}   ({} live observation(s))",
                rate, self.plan_hit_observations
            );
        }
        let not_completed: Vec<String> = [
            RequestOutcome::RejectedInvalid,
            RequestOutcome::RejectedOversized,
            RequestOutcome::Overloaded,
            RequestOutcome::Failed,
        ]
        .iter()
        .filter_map(|&o| {
            let n = self.outcome_count(o);
            (n > 0).then(|| format!("{n} {}", o.name()))
        })
        .collect();
        if !not_completed.is_empty() {
            println!("not completed     {:>10}", not_completed.join(", "));
        }
    }

    /// Compact JSON summary — the wire front-end's Metrics reply.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"requests\": {}, \"completed\": {}, \"rejected_invalid\": {}, \
             \"rejected_oversized\": {}, \"overloaded\": {}, \"failed\": {}, \
             \"iterations\": {}, \"wall_s\": {:.6}, \"prompt_tokens\": {}, \
             \"generated_tokens\": {}, \"plan_hit_observations\": {}}}",
            self.records.len(),
            self.outcome_count(RequestOutcome::Completed),
            self.outcome_count(RequestOutcome::RejectedInvalid),
            self.outcome_count(RequestOutcome::RejectedOversized),
            self.outcome_count(RequestOutcome::Overloaded),
            self.outcome_count(RequestOutcome::Failed),
            self.iterations,
            self.wall_s,
            self.total_prompt_tokens(),
            self.total_generated_tokens(),
            self.plan_hit_observations,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(id: u64, ttft: f64, e2e: f64) -> RequestRecord {
        RequestRecord {
            id,
            prompt_tokens: 100,
            generated_tokens: 10,
            arrival_s: 0.0,
            ttft_s: ttft,
            e2e_s: e2e,
            outcome: RequestOutcome::Completed,
        }
    }

    #[test]
    fn aggregates() {
        let rep = ServeReport {
            records: vec![record(1, 0.1, 1.0), record(2, 0.3, 2.0)],
            wall_s: 4.0,
            iterations: 10,
            engine_busy_s: 2.0,
            ..ServeReport::default()
        };
        assert_eq!(rep.total_prompt_tokens(), 200);
        assert_eq!(rep.total_generated_tokens(), 20);
        assert_eq!(rep.prefill_throughput(), 50.0);
        assert_eq!(rep.decode_throughput(), 5.0);
        assert_eq!(rep.utilization(), 0.5);
        assert!((rep.ttft_percentile(50.0) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn empty_report_is_safe() {
        let rep = ServeReport::default();
        assert_eq!(rep.prefill_throughput(), 0.0);
        assert_eq!(rep.ttft_percentile(99.0), 0.0);
        assert_eq!(rep.utilization(), 0.0);
    }

    #[test]
    fn outcomes_are_counted_and_summarized() {
        let mut shed = record(3, f64::NAN, f64::NAN);
        shed.generated_tokens = 0;
        shed.outcome = RequestOutcome::Overloaded;
        let rep = ServeReport {
            records: vec![record(1, 0.1, 1.0), record(2, 0.3, 2.0), shed],
            ..ServeReport::default()
        };
        assert_eq!(rep.outcome_count(RequestOutcome::Completed), 2);
        assert_eq!(rep.outcome_count(RequestOutcome::Overloaded), 1);
        assert_eq!(rep.outcome_count(RequestOutcome::Failed), 0);
        let json = rep.to_json();
        assert!(json.contains("\"completed\": 2"), "{json}");
        assert!(json.contains("\"overloaded\": 1"), "{json}");
    }
}

//! L3 serving coordinator — a vLLM-router-style stack in which sparse
//! prefill is a first-class scheduling citizen (DESIGN.md §4):
//!
//! ```text
//! trace ──▶ AdmissionQueue ──▶ Scheduler ──▶ Batcher ──▶ Engine (PJRT)
//!                 ▲              │  ▲                        │
//!                 │              ▼  │ page grants            ▼
//!              arrivals       PagePool ◀──────────────── step results
//! ```
//!
//! * [`queue`] — admission with arrival timestamps.
//! * [`calibrate`] — measures the scheduler's cost constants (span read,
//!   discrete gather, tile fold, ident-vs-dense) on the serving machine;
//!   `anchor-attn calibrate` persists them via the runtime manifest
//!   (DESIGN.md §13).
//! * [`kv_cache`] — paged KV accounting (fixed-size pages, per-page stripe
//!   statistics for the decode-reuse extension, DESIGN.md §7).
//! * [`scheduler`] — iteration-level planning: chunked prefill + decode
//!   interleave under a token budget; the anchor sparsity estimate shrinks
//!   prefill cost, letting more work co-schedule (the paper's speedup as
//!   scheduler headroom).
//! * [`batcher`] — packages an iteration plan into engine batches.
//! * [`engine`] — the single thread that owns the PJRT runtime/model.
//! * [`server`] — trace-driven driver producing a [`metrics::ServeReport`].

pub mod batcher;
pub mod calibrate;
pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod server;

//! Admission queue: thread-safe FIFO with arrival-time-gated release
//! (trace replay) and graceful close.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use super::request::Request;

#[derive(Default)]
struct Inner {
    queue: VecDeque<Request>,
    closed: bool,
    peak: usize,
}

/// MPMC admission queue (Mutex + Condvar; no external deps offline).
#[derive(Default)]
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl AdmissionQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&self, r: Request) {
        let mut g = self.inner.lock().unwrap();
        assert!(!g.closed, "push after close");
        g.queue.push_back(r);
        g.peak = g.peak.max(g.queue.len());
        self.cv.notify_all();
    }

    /// High-water mark of the queue depth since construction (never resets).
    /// Serving harnesses report this as `peak_queue_depth`.
    pub fn peak_depth(&self) -> usize {
        self.inner.lock().unwrap().peak
    }

    /// No more requests will arrive; wakes all waiters.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pop every request with `arrival_s <= now_s` (trace replay gate).
    pub fn drain_arrived(&self, now_s: f64) -> Vec<Request> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        while let Some(front) = g.queue.front() {
            if front.arrival_s <= now_s {
                out.push(g.queue.pop_front().unwrap());
            } else {
                break;
            }
        }
        out
    }

    /// Blocking pop; returns None when closed and drained.
    pub fn pop_blocking(&self) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.queue.pop_front() {
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64, t: f64) -> Request {
        Request::new(id, vec![1], 1, t)
    }

    #[test]
    fn fifo_order() {
        let q = AdmissionQueue::new();
        q.push(req(1, 0.0));
        q.push(req(2, 0.0));
        assert_eq!(q.pop_blocking().unwrap().id, 1);
        assert_eq!(q.pop_blocking().unwrap().id, 2);
    }

    #[test]
    fn drain_respects_arrival_time() {
        let q = AdmissionQueue::new();
        q.push(req(1, 0.5));
        q.push(req(2, 1.5));
        q.push(req(3, 2.5));
        let got = q.drain_arrived(1.6);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn peak_depth_is_a_high_water_mark() {
        let q = AdmissionQueue::new();
        assert_eq!(q.peak_depth(), 0);
        q.push(req(1, 0.0));
        q.push(req(2, 0.0));
        q.push(req(3, 0.0));
        assert_eq!(q.peak_depth(), 3);
        q.pop_blocking();
        q.pop_blocking();
        assert_eq!(q.len(), 1);
        // Draining does not lower the mark; a later burst can raise it.
        assert_eq!(q.peak_depth(), 3);
        q.push(req(4, 0.0));
        q.push(req(5, 0.0));
        q.push(req(6, 0.0));
        assert_eq!(q.peak_depth(), 4);
    }

    #[test]
    fn close_unblocks_poppers() {
        let q = Arc::new(AdmissionQueue::new());
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop_blocking());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(t.join().unwrap().is_none());
    }

    #[test]
    fn pop_after_close_drains_remaining() {
        let q = AdmissionQueue::new();
        q.push(req(9, 0.0));
        q.close();
        assert_eq!(q.pop_blocking().unwrap().id, 9);
        assert!(q.pop_blocking().is_none());
    }
}

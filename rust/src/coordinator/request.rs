//! Request/response types flowing through the coordinator.

/// Lifecycle of a request inside the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Waiting for admission (pages not yet granted).
    Queued,
    /// Prefill in progress; `prefilled` tracks completed prompt tokens.
    Prefill,
    /// Emitting tokens one per iteration.
    Decode,
    /// All tokens emitted.
    Finished,
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Trace-time arrival (seconds from trace start).
    pub arrival_s: f64,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize, arrival_s: f64) -> Self {
        Self { id, prompt, max_new_tokens, arrival_s }
    }

    pub fn total_tokens(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }
}

/// Mutable per-request scheduling state.
#[derive(Clone, Debug)]
pub struct RequestState {
    pub request: Request,
    pub phase: Phase,
    /// Prompt tokens already prefetched through the model.
    pub prefilled: usize,
    /// Generated tokens so far.
    pub generated: Vec<i32>,
    /// Wall-clock seconds (virtual serve time) of first emitted token.
    pub first_token_s: Option<f64>,
    /// Completion time.
    pub finished_s: Option<f64>,
}

impl RequestState {
    pub fn new(request: Request) -> Self {
        Self {
            request,
            phase: Phase::Queued,
            prefilled: 0,
            generated: Vec::new(),
            first_token_s: None,
            finished_s: None,
        }
    }

    pub fn remaining_prefill(&self) -> usize {
        self.request.prompt.len() - self.prefilled
    }

    pub fn decode_done(&self) -> bool {
        self.generated.len() >= self.request.max_new_tokens
    }

    /// Current sequence length (consumed cache tokens).
    pub fn seq_len(&self) -> usize {
        self.prefilled + self.generated.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_transitions_bookkeeping() {
        let r = Request::new(1, vec![1, 2, 3, 4], 2, 0.0);
        assert_eq!(r.total_tokens(), 6);
        let mut st = RequestState::new(r);
        assert_eq!(st.phase, Phase::Queued);
        assert_eq!(st.remaining_prefill(), 4);
        st.prefilled = 4;
        assert_eq!(st.remaining_prefill(), 0);
        st.generated.push(7);
        st.generated.push(8);
        assert!(st.decode_done());
        assert_eq!(st.seq_len(), 6);
    }
}

//! Request/response types flowing through the coordinator.
//!
//! [`Request`] is the public request envelope: front ends (the CLI trace
//! replay and the wire front-end alike) construct one through
//! [`Request::builder`], which rejects malformed submissions with a typed
//! [`RequestError`] — an empty prompt, a zero decode budget, or a prompt
//! that cannot fit the sequence budget — instead of silently clamping.
//! The raw [`Request::new`] constructor stays for trusted internal
//! callers (tests, trace generators) that build by-construction-valid
//! requests.

use std::fmt;

/// Why a request submission was rejected before admission. Typed so front
/// ends can map each variant to a wire status code
/// ([`crate::wire::StatusCode`]) instead of pattern-matching strings.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestError {
    /// The prompt carried no tokens.
    EmptyPrompt,
    /// `max_new_tokens` was zero — the request could never emit a token.
    ZeroDecode,
    /// Prompt + decode budget exceeds the sequence capacity. Carries the
    /// numbers so the reply can say exactly what to shrink.
    PromptTooLong { prompt: usize, budget: usize },
}

impl fmt::Display for RequestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RequestError::EmptyPrompt => write!(f, "empty prompt"),
            RequestError::ZeroDecode => write!(f, "max_new_tokens must be >= 1"),
            RequestError::PromptTooLong { prompt, budget } => write!(
                f,
                "prompt of {prompt} token(s) exceeds the {budget}-token budget \
                 (max_seq minus the decode allotment)"
            ),
        }
    }
}

impl std::error::Error for RequestError {}

/// Lifecycle of a request inside the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Waiting for admission (pages not yet granted).
    Queued,
    /// Prefill in progress; `prefilled` tracks completed prompt tokens.
    Prefill,
    /// Emitting tokens one per iteration.
    Decode,
    /// All tokens emitted.
    Finished,
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Trace-time arrival (seconds from trace start).
    pub arrival_s: f64,
    /// Workload scenario tag (e.g. `"shared-prefix"`) for per-scenario
    /// report breakdowns; `None` for untagged traffic.
    pub scenario: Option<String>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize, arrival_s: f64) -> Self {
        Self { id, prompt, max_new_tokens, arrival_s, scenario: None }
    }

    /// Start a validated request build; [`RequestBuilder::build`] checks
    /// the submission against the sequence capacity.
    pub fn builder(id: u64) -> RequestBuilder {
        RequestBuilder {
            id,
            prompt: Vec::new(),
            max_new_tokens: 1,
            arrival_s: 0.0,
            scenario: None,
        }
    }

    pub fn total_tokens(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }

    /// The validation the builder applies, callable on an already-built
    /// request (the admission path re-checks wire submissions with it).
    pub fn validate(&self, max_seq: usize) -> Result<(), RequestError> {
        if self.prompt.is_empty() {
            return Err(RequestError::EmptyPrompt);
        }
        if self.max_new_tokens == 0 {
            return Err(RequestError::ZeroDecode);
        }
        if self.total_tokens() > max_seq {
            return Err(RequestError::PromptTooLong {
                prompt: self.prompt.len(),
                budget: max_seq.saturating_sub(self.max_new_tokens),
            });
        }
        Ok(())
    }
}

/// Builder for [`Request`] — the validated construction path every front
/// end shares. `build(max_seq)` rejects malformed submissions with a
/// typed [`RequestError`] instead of clamping them into shape.
#[derive(Clone, Debug)]
pub struct RequestBuilder {
    id: u64,
    prompt: Vec<i32>,
    max_new_tokens: usize,
    arrival_s: f64,
    scenario: Option<String>,
}

impl RequestBuilder {
    pub fn prompt(mut self, prompt: Vec<i32>) -> Self {
        self.prompt = prompt;
        self
    }

    pub fn max_new_tokens(mut self, n: usize) -> Self {
        self.max_new_tokens = n;
        self
    }

    pub fn arrival_s(mut self, t: f64) -> Self {
        self.arrival_s = t;
        self
    }

    /// Tag the request with a workload scenario for report attribution.
    pub fn scenario(mut self, tag: &str) -> Self {
        self.scenario = Some(tag.to_string());
        self
    }

    /// Validate against the serving sequence capacity and construct.
    pub fn build(self, max_seq: usize) -> Result<Request, RequestError> {
        let req = Request {
            id: self.id,
            prompt: self.prompt,
            max_new_tokens: self.max_new_tokens,
            arrival_s: self.arrival_s,
            scenario: self.scenario,
        };
        req.validate(max_seq)?;
        Ok(req)
    }
}

/// Mutable per-request scheduling state.
#[derive(Clone, Debug)]
pub struct RequestState {
    pub request: Request,
    pub phase: Phase,
    /// Prompt tokens already prefetched through the model.
    pub prefilled: usize,
    /// Generated tokens so far.
    pub generated: Vec<i32>,
    /// Wall-clock seconds (virtual serve time) of first emitted token.
    pub first_token_s: Option<f64>,
    /// Completion time.
    pub finished_s: Option<f64>,
    /// Times this request's KV pages were evicted by prefill preemption
    /// (bounded by the scheduler's per-request preemption cap).
    pub preemptions: u32,
}

impl RequestState {
    pub fn new(request: Request) -> Self {
        Self {
            request,
            phase: Phase::Queued,
            prefilled: 0,
            generated: Vec::new(),
            first_token_s: None,
            finished_s: None,
            preemptions: 0,
        }
    }

    pub fn remaining_prefill(&self) -> usize {
        self.request.prompt.len() - self.prefilled
    }

    pub fn decode_done(&self) -> bool {
        self.generated.len() >= self.request.max_new_tokens
    }

    /// Current sequence length (consumed cache tokens).
    pub fn seq_len(&self) -> usize {
        self.prefilled + self.generated.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_transitions_bookkeeping() {
        let r = Request::new(1, vec![1, 2, 3, 4], 2, 0.0);
        assert_eq!(r.total_tokens(), 6);
        let mut st = RequestState::new(r);
        assert_eq!(st.phase, Phase::Queued);
        assert_eq!(st.remaining_prefill(), 4);
        st.prefilled = 4;
        assert_eq!(st.remaining_prefill(), 0);
        st.generated.push(7);
        st.generated.push(8);
        assert!(st.decode_done());
        assert_eq!(st.seq_len(), 6);
    }

    #[test]
    fn builder_accepts_a_valid_request() {
        let r = Request::builder(7)
            .prompt(vec![1, 2, 3])
            .max_new_tokens(4)
            .arrival_s(0.5)
            .build(16)
            .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.total_tokens(), 7);
        assert_eq!(r.arrival_s, 0.5);
    }

    #[test]
    fn builder_rejects_with_typed_errors_not_clamps() {
        assert_eq!(
            Request::builder(1).max_new_tokens(2).build(16).unwrap_err(),
            RequestError::EmptyPrompt
        );
        assert_eq!(
            Request::builder(1).prompt(vec![1]).max_new_tokens(0).build(16).unwrap_err(),
            RequestError::ZeroDecode
        );
        let err = Request::builder(1)
            .prompt(vec![0; 30])
            .max_new_tokens(4)
            .build(16)
            .unwrap_err();
        assert_eq!(err, RequestError::PromptTooLong { prompt: 30, budget: 12 });
        // The error names the actionable budget, not just "too long".
        assert!(err.to_string().contains("12"), "{err}");
    }

    #[test]
    fn validate_matches_builder_on_boundaries() {
        // Exactly at capacity is accepted; one past is rejected.
        assert!(Request::new(1, vec![0; 12], 4, 0.0).validate(16).is_ok());
        assert!(Request::new(1, vec![0; 13], 4, 0.0).validate(16).is_err());
    }
}

//! Iteration-level scheduler (Orca-style continuous batching with chunked
//! prefill), sparse-attention-aware.
//!
//! Every engine iteration the scheduler assembles a plan under a *cost
//! budget*: decode steps for all decoding requests (latency-critical),
//! then prefill chunks for admitted requests, largest-remaining-first.
//! Chunk costs are scaled by the anchor sparsity estimate: a sparse
//! prefill chunk at long context costs a fraction of a dense one, so more
//! prefill co-schedules with decode — the paper's speedup surfacing as
//! scheduler headroom (DESIGN.md §4).

use super::kv_cache::PagePool;
use super::request::{Phase, RequestState};
use crate::attention::exec::ExecutorKind;

/// Identification overhead as a fraction of context token-cost when a
/// chunk must (re)plan: the pooled Alg. 2 pass scans every candidate key
/// once at pooled-row granularity, which the cost model prices at ~1/8 of
/// an attended token each. A plan-cache hit skips this entirely.
pub const IDENT_COST_FRAC: f64 = 0.125;

/// Plan-broadcast overhead per *extra* shard, as a fraction of context
/// token-cost: head-group sharding replicates only `SparsePlan`
/// coordinates (a few bytes per tile) where K/V would be `2·d·4` bytes
/// per token, so distributing a plan to one more shard costs orders of
/// magnitude less than the execution it unlocks (DESIGN.md §12). The
/// 0.2%/shard constant keeps scaling near-linear at practical shard
/// counts while still pricing a floor — past `attn / broadcast` shards,
/// adding workers stops paying. This is a modeled guess: `anchor-attn
/// calibrate --wire` replaces it with a measured constant from a real
/// framed socket round-trip of the delta-encoded coordinates
/// (DESIGN.md §14), which is what `serve --transport process` should be
/// priced with.
pub const PLAN_BROADCAST_FRAC: f64 = 0.002;

/// What a *speculative* plan-cache hit (DESIGN.md §17) still pays,
/// as a fraction of the full identification cost: the recall check runs
/// Alg. 2 over a strided sample of the donor's reusable groups (every
/// 4th), with the anchor m-pass restricted to exactly the sampled blocks
/// — roughly a quarter of the pooled pass plus comparison overhead. A
/// speculative hit therefore prices as `RECALL_COST_FRAC · ident`
/// instead of dropping the term entirely the way an exact hit does.
pub const RECALL_COST_FRAC: f64 = 0.35;

/// The constants the Anchor cost estimates are built from: either the
/// modeled defaults above or machine-measured replacements produced by
/// `anchor-attn calibrate` and persisted under the runtime manifest's
/// `calibration` key (DESIGN.md §13). The two fractions are the
/// dimensionless knobs [`SparsityModel::effective_context`] actually
/// consumes; the ns-rate fields carry the raw primitive measurements the
/// fractions were derived from, so a calibrated scheduler can always name
/// its provenance (`0.0` = modeled, nothing was measured).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostConstants {
    /// Identification overhead as a fraction of context token-cost on a
    /// plan-cache miss (modeled default: [`IDENT_COST_FRAC`]).
    pub ident_cost_frac: f64,
    /// Plan-broadcast overhead per extra shard as a fraction of context
    /// token-cost (modeled default: [`PLAN_BROADCAST_FRAC`]).
    pub plan_broadcast_frac: f64,
    /// Measured contiguous span read rate, ns per K/V row.
    pub span_ns_per_row: f64,
    /// Measured discrete (per-coordinate) gather rate, ns per K/V row.
    pub gather_ns_per_row: f64,
    /// Measured online-softmax tile fold rate, ns per score element.
    pub fold_ns_per_score: f64,
}

impl CostConstants {
    /// The modeled defaults — bit-identical to the historical global
    /// constants, so an uncalibrated scheduler prices exactly as before.
    pub fn modeled() -> Self {
        Self {
            ident_cost_frac: IDENT_COST_FRAC,
            plan_broadcast_frac: PLAN_BROADCAST_FRAC,
            span_ns_per_row: 0.0,
            gather_ns_per_row: 0.0,
            fold_ns_per_score: 0.0,
        }
    }

    /// Whether these constants came from a calibration run (any primitive
    /// rate measured) rather than the modeled defaults.
    pub fn is_measured(&self) -> bool {
        self.span_ns_per_row > 0.0
            || self.gather_ns_per_row > 0.0
            || self.fold_ns_per_score > 0.0
    }
}

impl Default for CostConstants {
    fn default() -> Self {
        Self::modeled()
    }
}

/// How prefill attention cost scales with context for the active method.
#[derive(Clone, Copy, Debug)]
pub enum SparsityModel {
    /// Dense attention: cost ∝ context length.
    Dense,
    /// AnchorAttention: anchor regions (window + init) plus a stripe
    /// fraction of the remaining context survive, plus identification
    /// overhead on plan-cache misses.
    Anchor {
        /// Fraction of non-anchor keys surviving identification
        /// (1 − sparsity; measured by the engine, e.g. ~0.1 at θ=12).
        stripe_keep: f64,
        /// Anchor window + init tokens always computed.
        anchor_tokens: usize,
        /// Observed plan-cache hit rate in [0, 1] (heads sharing a
        /// `(layer, head_group)` cell reuse identification work); hits
        /// drop the identification term from the chunk cost.
        plan_hit_rate: f64,
        /// Observed *speculative* reuse hit rate in [0, 1] among the
        /// cache misses (DESIGN.md §17): the fraction of misses a
        /// widened-lookup donor plan served after passing the sampled
        /// recall check. A speculative hit still pays the check —
        /// [`RECALL_COST_FRAC`] of full identification — so the miss
        /// fraction's ident term scales by
        /// `(1 − s) + s · RECALL_COST_FRAC`. `0.0` (the default and the
        /// exact-policy value) reproduces the historical pricing bit for
        /// bit.
        speculative_hit_rate: f64,
        /// Whether the engine runs the async plan pipeline (DESIGN.md §9).
        /// When on, identification of chunk *i+1* overlaps execution of
        /// chunk *i*, so a chunk costs `max(ident, exec)` effective tokens
        /// instead of `ident + exec`: only the slower stage is on the
        /// critical path.
        pipelined: bool,
        /// Which executor backend drains plans (DESIGN.md §10). Both
        /// backends fold exactly the plan's tiles — cost is a property of
        /// the coordinates, so the arithmetic above is backend-invariant —
        /// but the kind is carried here so every cost estimate, report and
        /// bench row names the backend it was priced for, and backend
        /// regressions stay attributable.
        executor: ExecutorKind,
        /// Head-group shard workers executing the plan (DESIGN.md §12).
        /// Execution scales near-linearly (`attn / shards`) because
        /// shards exchange only plan coordinates, never K/V; each extra
        /// shard adds a [`PLAN_BROADCAST_FRAC`] coordination term.
        /// Identification is not divided — a fresh key identifies once
        /// and the plan broadcasts. `1` (or `0`, clamped) is unsharded.
        shards: usize,
        /// Cost constants the estimate arithmetic reads:
        /// [`CostConstants::modeled`] by default, or a measured set loaded
        /// from the manifest's `calibration` key (`serve --calibration`).
        constants: CostConstants,
    },
}

impl SparsityModel {
    /// Effective attended tokens for a chunk at `context` total length —
    /// attention work plus the amortized identification work.
    pub fn effective_context(&self, context: usize) -> f64 {
        match *self {
            SparsityModel::Dense => context as f64,
            SparsityModel::Anchor {
                stripe_keep,
                anchor_tokens,
                plan_hit_rate,
                speculative_hit_rate,
                pipelined,
                shards,
                constants,
                ..
            } => {
                let anchored = context.min(anchor_tokens) as f64;
                let rest = context.saturating_sub(anchor_tokens) as f64;
                let s = shards.max(1) as f64;
                // Near-linear exec scaling: shards split the attention
                // work by head group; the per-extra-shard broadcast term
                // prices replicating plan coordinates (never K/V) to each
                // worker. Identification is not divided — a fresh key
                // plans once, then the coordinates fan out.
                let attn = (anchored + stripe_keep * rest) / s
                    + constants.plan_broadcast_frac * (s - 1.0) * context as f64;
                // Misses split into speculative hits (priced at the recall
                // check, RECALL_COST_FRAC of a full pass) and true misses
                // (full identification). spec = 0 is the historical pricing.
                let spec = speculative_hit_rate.clamp(0.0, 1.0);
                let ident = (1.0 - plan_hit_rate.clamp(0.0, 1.0))
                    * ((1.0 - spec) + spec * RECALL_COST_FRAC)
                    * constants.ident_cost_frac
                    * context as f64;
                // Pipelined: identification overlaps execution, so only the
                // slower stage sits on the critical path. Sequential: the
                // stages serialize.
                let eff = if pipelined { attn.max(ident) } else { attn + ident };
                eff.min(context as f64)
            }
        }
    }

    /// Whether the model prices overlapped (pipelined) identification.
    pub fn is_pipelined(&self) -> bool {
        matches!(self, SparsityModel::Anchor { pipelined: true, .. })
    }

    /// The executor backend this model's estimates are attributed to
    /// (dense attention has no plan executor; report it as the default
    /// CPU walk).
    pub fn executor_kind(&self) -> ExecutorKind {
        match *self {
            SparsityModel::Dense => ExecutorKind::Cpu,
            SparsityModel::Anchor { executor, .. } => executor,
        }
    }

    /// Shard workers the estimates assume (dense serving is unsharded).
    pub fn shards(&self) -> usize {
        match *self {
            SparsityModel::Dense => 1,
            SparsityModel::Anchor { shards, .. } => shards.max(1),
        }
    }

    /// The cost constants the estimates are built from (dense pricing has
    /// no tunable constants).
    pub fn constants(&self) -> Option<CostConstants> {
        match *self {
            SparsityModel::Dense => None,
            SparsityModel::Anchor { constants, .. } => Some(constants),
        }
    }

    /// Install a measured constant set — a calibration artifact loaded
    /// from the runtime manifest — in place of the modeled defaults.
    /// No-op for dense, which has no constants to replace.
    pub fn set_constants(&mut self, c: CostConstants) {
        if let SparsityModel::Anchor { constants, .. } = self {
            *constants = c;
        }
    }

    /// Current plan-cache hit-rate estimate (the EWMA state), when the
    /// model amortizes identification.
    pub fn plan_hit_rate(&self) -> Option<f64> {
        match *self {
            SparsityModel::Dense => None,
            SparsityModel::Anchor { plan_hit_rate, .. } => Some(plan_hit_rate),
        }
    }

    /// Current speculative-reuse hit-rate estimate (the EWMA state), when
    /// the model prices recall-checked reuse.
    pub fn speculative_hit_rate(&self) -> Option<f64> {
        match *self {
            SparsityModel::Dense => None,
            SparsityModel::Anchor { speculative_hit_rate, .. } => Some(speculative_hit_rate),
        }
    }

    /// Fold a newly observed speculative-reuse hit rate — the sessions'
    /// `speculative_hits / (hits + fallbacks)` — into the model (no-op
    /// for dense). Same EWMA shape as [`Self::observe_plan_hit_rate`],
    /// drained from [`StepExecutor::observed_speculative_hit_rate`]
    /// (`crate::coordinator::engine::StepExecutor`) by the serve loop.
    pub fn observe_speculative_hit_rate(&mut self, observed: f64) {
        if let SparsityModel::Anchor { speculative_hit_rate, .. } = self {
            *speculative_hit_rate = 0.5 * *speculative_hit_rate + 0.5 * observed.clamp(0.0, 1.0);
        }
    }

    /// Fold a newly observed plan-cache hit rate into the model (no-op for
    /// dense). Wired from two sides: a serving loop can aggregate
    /// `SessionOutput::hit_rate()` from the attention engine, and
    /// `serve --plan-store` feeds 1.0 when a populated manifest plan store
    /// guarantees first-touch hits for previously seen keys (DESIGN.md
    /// §11).
    pub fn observe_plan_hit_rate(&mut self, observed: f64) {
        if let SparsityModel::Anchor { plan_hit_rate, .. } = self {
            // Exponential moving average keeps the estimate stable across
            // bursty traces.
            *plan_hit_rate = 0.5 * *plan_hit_rate + 0.5 * observed.clamp(0.0, 1.0);
        }
    }
}

/// Per-request cap on prefill preemptions: after this many evictions a
/// request keeps its pages, bounding worst-case re-prefill work.
pub const MAX_PREEMPTIONS: u32 = 2;

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Cost budget per iteration, in normalized token-cost units.
    pub iter_budget: f64,
    /// Prefill chunk size (must equal the artifact chunk).
    pub chunk: usize,
    /// Max concurrent running requests (decode batch width).
    pub max_running: usize,
    pub sparsity: SparsityModel,
    /// Per-token cost of a decode step relative to a prefill token.
    pub decode_token_cost: f64,
    /// Allow a blocked admission to evict a strictly larger prefill-phase
    /// request (never a decoding one) and take its pages. Off by default:
    /// the conservative no-eviction admission of earlier builds.
    pub preempt_prefill: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            iter_budget: 1024.0,
            chunk: 256,
            max_running: 8,
            sparsity: SparsityModel::Dense,
            decode_token_cost: 4.0,
            preempt_prefill: false,
        }
    }
}

/// One engine iteration's work.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IterationPlan {
    /// (request id, chunk token count) prefill chunks this iteration.
    pub prefill: Vec<(u64, usize)>,
    /// Request ids taking one decode step.
    pub decode: Vec<u64>,
    /// Request ids newly admitted (pages granted) this iteration.
    pub admitted: Vec<u64>,
    /// Request ids whose pages were evicted this iteration (prefill
    /// preemption); they return to the queue and re-prefill from scratch.
    pub preempted: Vec<u64>,
}

impl IterationPlan {
    pub fn is_empty(&self) -> bool {
        self.prefill.is_empty() && self.decode.is_empty()
    }
}

/// Chunk cost: attention over the effective context plus linear MLP work.
fn chunk_cost(cfg: &SchedulerConfig, context_after: usize, chunk: usize) -> f64 {
    let eff = cfg.sparsity.effective_context(context_after);
    // Attention ~ chunk × eff/context_after share + MLP ~ chunk.
    chunk as f64 * (0.5 + 0.5 * eff / context_after.max(1) as f64)
}

/// Build the next iteration plan. Mutates request phases for admissions.
pub fn plan_iteration(
    cfg: &SchedulerConfig,
    states: &mut [RequestState],
    pool: &mut PagePool,
) -> IterationPlan {
    let mut plan = IterationPlan::default();
    let mut budget = cfg.iter_budget;

    // 1. Decode steps first (latency-critical); every decoding request
    //    advances one token per iteration.
    for st in states.iter_mut() {
        if st.phase == Phase::Decode && !st.decode_done() {
            let cost = cfg.decode_token_cost;
            if budget < cost {
                break;
            }
            budget -= cost;
            plan.decode.push(st.request.id);
        }
    }

    // 2. Admissions: FIFO while pages are available and running slots open.
    //    With `preempt_prefill`, a blocked admission may evict one
    //    *strictly larger* prefill-phase request (never a decoding one —
    //    its pages hold issued tokens) and take its pages. The strict size
    //    order is the livelock guard: a victim can never in turn preempt
    //    the request that displaced it, and [`MAX_PREEMPTIONS`] bounds how
    //    often any one request re-prefills.
    let running = states
        .iter()
        .filter(|s| matches!(s.phase, Phase::Prefill | Phase::Decode))
        .count();
    let mut slots = cfg.max_running.saturating_sub(running);
    for i in 0..states.len() {
        if slots == 0 {
            break;
        }
        if states[i].phase != Phase::Queued {
            continue;
        }
        let tokens = states[i].request.total_tokens();
        if cfg.preempt_prefill && !pool.can_admit(tokens) {
            // Largest eligible victim: prefill phase (no tokens issued),
            // strictly more total tokens than the blocked request (so the
            // freed pages are guaranteed to cover it), under the
            // preemption cap, and not admitted this very iteration.
            let victim = (0..states.len())
                .filter(|&j| {
                    j != i
                        && states[j].phase == Phase::Prefill
                        && states[j].preemptions < MAX_PREEMPTIONS
                        && states[j].request.total_tokens() > tokens
                        && !plan.admitted.contains(&states[j].request.id)
                })
                .max_by_key(|&j| states[j].request.total_tokens());
            if let Some(v) = victim {
                let vid = states[v].request.id;
                pool.evict(vid).expect("prefill victim holds pages");
                states[v].phase = Phase::Queued;
                states[v].prefilled = 0;
                states[v].preemptions += 1;
                plan.preempted.push(vid);
                slots += 1; // the victim's running slot opens up
            }
        }
        if pool.can_admit(tokens) {
            pool.admit(states[i].request.id, tokens).expect("can_admit checked");
            states[i].phase = Phase::Prefill;
            plan.admitted.push(states[i].request.id);
            slots -= 1;
        }
    }

    // 3. Prefill chunks, longest-remaining-first (maximizes the sparse
    //    method's advantage: long contexts shrink the most).
    let mut prefill_idx: Vec<usize> = states
        .iter()
        .enumerate()
        .filter(|(_, s)| s.phase == Phase::Prefill && s.remaining_prefill() > 0)
        .map(|(i, _)| i)
        .collect();
    prefill_idx.sort_by_key(|&i| std::cmp::Reverse(states[i].remaining_prefill()));

    for i in prefill_idx {
        let st = &states[i];
        let take = st.remaining_prefill().min(cfg.chunk);
        let ctx_after = st.prefilled + take;
        let cost = chunk_cost(cfg, ctx_after, take);
        if budget < cost {
            continue; // try a shorter-context request instead
        }
        budget -= cost;
        plan.prefill.push((st.request.id, take));
    }

    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;

    fn mk_states(specs: &[(u64, usize, usize)]) -> Vec<RequestState> {
        specs
            .iter()
            .map(|&(id, prompt, new)| RequestState::new(Request::new(id, vec![1; prompt], new, 0.0)))
            .collect()
    }

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            iter_budget: 600.0,
            chunk: 256,
            max_running: 4,
            sparsity: SparsityModel::Dense,
            decode_token_cost: 4.0,
            preempt_prefill: false,
        }
    }

    #[test]
    fn admits_until_pool_full() {
        let mut pool = PagePool::new(8, 256); // 2048 tokens capacity
        let mut states = mk_states(&[(1, 1024, 16), (2, 512, 16), (3, 1024, 16)]);
        let plan = plan_iteration(&cfg(), &mut states, &mut pool);
        // 1 (5 pages incl. decode) + 2 (3 pages) fit; 3 does not.
        assert_eq!(plan.admitted, vec![1, 2]);
        assert_eq!(states[0].phase, Phase::Prefill);
        assert_eq!(states[2].phase, Phase::Queued);
    }

    #[test]
    fn decode_scheduled_before_prefill() {
        let mut pool = PagePool::new(32, 256);
        let mut states = mk_states(&[(1, 512, 4), (2, 512, 4)]);
        states[0].phase = Phase::Decode;
        states[0].prefilled = 512;
        let plan = plan_iteration(&cfg(), &mut states, &mut pool);
        assert_eq!(plan.decode, vec![1]);
        assert!(plan.prefill.iter().any(|&(id, _)| id == 2));
    }

    #[test]
    fn budget_caps_prefill_chunks() {
        let mut pool = PagePool::new(64, 256);
        // Many long requests; budget 600 allows at most 2 full dense chunks.
        let mut states = mk_states(&[(1, 2048, 0), (2, 2048, 0), (3, 2048, 0), (4, 2048, 0)]);
        let mut c = cfg();
        c.max_running = 8;
        let plan = plan_iteration(&c, &mut states, &mut pool);
        assert!(plan.prefill.len() <= 2, "{:?}", plan.prefill);
    }

    #[test]
    fn anchor_sparsity_fits_more_prefill_at_long_context() {
        let mut pool = PagePool::new(64, 256);
        let mk = || {
            let mut s = mk_states(&[(1, 2048, 0), (2, 2048, 0), (3, 2048, 0), (4, 2048, 0)]);
            for st in &mut s {
                st.phase = Phase::Prefill;
                st.prefilled = 1792; // deep into long prompts
            }
            s
        };
        let mut dense_states = mk();
        for st in &dense_states {
            pool.admit(st.request.id, st.request.total_tokens()).unwrap();
        }
        let mut c = cfg();
        c.max_running = 8;
        let dense = plan_iteration(&c, &mut dense_states, &mut pool);

        let mut sparse_states = mk();
        c.sparsity = SparsityModel::Anchor {
            stripe_keep: 0.08,
            anchor_tokens: 256,
            plan_hit_rate: 0.0,
            speculative_hit_rate: 0.0,
            pipelined: false,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: CostConstants::modeled(),
        };
        let sparse = plan_iteration(&c, &mut sparse_states, &mut pool);
        assert!(
            sparse.prefill.len() > dense.prefill.len(),
            "sparse {:?} vs dense {:?}",
            sparse.prefill,
            dense.prefill
        );
    }

    #[test]
    fn longest_remaining_first() {
        let mut pool = PagePool::new(64, 256);
        let mut states = mk_states(&[(1, 256, 0), (2, 2048, 0)]);
        for st in &mut states {
            st.phase = Phase::Prefill;
            pool.admit(st.request.id, st.request.total_tokens()).unwrap();
        }
        let mut c = cfg();
        c.iter_budget = 260.0; // room for ~1 chunk
        let plan = plan_iteration(&c, &mut states, &mut pool);
        assert_eq!(plan.prefill.len(), 1);
        assert_eq!(plan.prefill[0].0, 2, "long request first");
    }

    #[test]
    fn effective_context_model() {
        let dense = SparsityModel::Dense;
        assert_eq!(dense.effective_context(1000), 1000.0);
        let anchor = SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 200,
            plan_hit_rate: 1.0,
            speculative_hit_rate: 0.0,
            pipelined: false,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: CostConstants::modeled(),
        };
        let eff = anchor.effective_context(1000);
        assert!((eff - (200.0 + 0.1 * 800.0)).abs() < 1e-9);
        // Short context: everything anchored.
        assert_eq!(anchor.effective_context(100), 100.0);
    }

    /// Plan-cache hits remove the identification term: the same chunk
    /// costs strictly less at a higher observed hit rate, which buys the
    /// scheduler extra prefill headroom.
    #[test]
    fn plan_hits_reduce_chunk_cost() {
        let mk = |hit| SparsityModel::Anchor {
            stripe_keep: 0.08,
            anchor_tokens: 256,
            plan_hit_rate: hit,
            speculative_hit_rate: 0.0,
            pipelined: false,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: CostConstants::modeled(),
        };
        let cold = mk(0.0).effective_context(4096);
        let warm = mk(1.0).effective_context(4096);
        assert!(
            (cold - warm - IDENT_COST_FRAC * 4096.0).abs() < 1e-9,
            "cold {cold} vs warm {warm}"
        );

        // The headroom is visible in the iteration plan: warm cache fits
        // at least as many chunks, and strictly more at this budget.
        let run = |hit| {
            let mut pool = PagePool::new(64, 256);
            let mut states = mk_states(&[(1, 2048, 0), (2, 2048, 0), (3, 2048, 0), (4, 2048, 0)]);
            for st in &mut states {
                st.phase = Phase::Prefill;
                st.prefilled = 1792;
                pool.admit(st.request.id, st.request.total_tokens()).unwrap();
            }
            let mut c = cfg();
            c.max_running = 8;
            c.iter_budget = 480.0;
            c.sparsity = mk(hit);
            plan_iteration(&c, &mut states, &mut pool).prefill.len()
        };
        assert!(run(1.0) > run(0.0), "warm {} vs cold {}", run(1.0), run(0.0));
    }

    /// Speculative hits price the miss fraction's ident work at the
    /// sampled recall check ([`RECALL_COST_FRAC`] of a full pass): dearer
    /// than an exact hit, strictly cheaper than a cold miss — and a zero
    /// rate (the default, and what the exact policy reports) reproduces
    /// the historical pricing bit for bit.
    #[test]
    fn speculative_hits_price_ident_at_recall_check() {
        let mk = |spec| SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 256,
            plan_hit_rate: 0.0,
            speculative_hit_rate: spec,
            pipelined: false,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: CostConstants::modeled(),
        };
        let n = 4096;
        // attn = 256 + 0.1·3840 = 640; full ident = 0.125·4096 = 512.
        let cold = mk(0.0).effective_context(n);
        let all_spec = mk(1.0).effective_context(n);
        let half = mk(0.5).effective_context(n);
        assert!((cold - 1152.0).abs() < 1e-9, "cold {cold}");
        assert!(
            (all_spec - (640.0 + RECALL_COST_FRAC * 512.0)).abs() < 1e-9,
            "speculative {all_spec}"
        );
        assert!(cold > half && half > all_spec, "{cold} > {half} > {all_spec}");
        // Exact hits still beat speculative ones: the check is not free.
        let warm = SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 256,
            plan_hit_rate: 1.0,
            speculative_hit_rate: 1.0,
            pipelined: false,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: CostConstants::modeled(),
        };
        assert!(warm.effective_context(n) < all_spec);

        // EWMA + getter, and the dense no-op.
        let mut m = mk(0.0);
        assert_eq!(m.speculative_hit_rate(), Some(0.0));
        m.observe_speculative_hit_rate(1.0);
        assert_eq!(m.speculative_hit_rate(), Some(0.5));
        m.observe_speculative_hit_rate(1.0);
        assert_eq!(m.speculative_hit_rate(), Some(0.75));
        let mut d = SparsityModel::Dense;
        d.observe_speculative_hit_rate(1.0);
        assert_eq!(d.speculative_hit_rate(), None);
    }

    /// With the plan pipeline on, identification is priced `max(ident,
    /// exec)` — overlapped — instead of `ident + exec`, so the same chunk
    /// is never more expensive pipelined and the scheduler fits at least
    /// as much prefill per iteration.
    #[test]
    fn pipelined_ident_priced_as_max_not_sum() {
        let mk = |pipelined| SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 256,
            plan_hit_rate: 0.0,
            speculative_hit_rate: 0.0,
            pipelined,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: CostConstants::modeled(),
        };
        let n = 4096;
        // attn = 256 + 0.1·3840 = 640; ident = 0.125·4096 = 512.
        let seq = mk(false).effective_context(n);
        let pipe = mk(true).effective_context(n);
        assert!((seq - 1152.0).abs() < 1e-9, "sequential {seq}");
        assert!((pipe - 640.0).abs() < 1e-9, "pipelined {pipe}");

        // Ident-dominated regime: the overlapped cost is the ident term.
        let lean = SparsityModel::Anchor {
            stripe_keep: 0.0,
            anchor_tokens: 0,
            plan_hit_rate: 0.0,
            speculative_hit_rate: 0.0,
            pipelined: true,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: CostConstants::modeled(),
        };
        assert!((lean.effective_context(n) - 512.0).abs() < 1e-9);

        // Pipelined cost never exceeds sequential across contexts/hit rates.
        for ctx in [1usize, 64, 256, 1024, 4096, 65536] {
            for hit in [0.0, 0.3, 1.0] {
                let with = |pipelined| SparsityModel::Anchor {
                    stripe_keep: 0.1,
                    anchor_tokens: 256,
                    plan_hit_rate: hit,
                    speculative_hit_rate: 0.0,
                    pipelined,
                    executor: ExecutorKind::Cpu,
                    shards: 1,
                    constants: CostConstants::modeled(),
                };
                assert!(
                    with(true).effective_context(ctx) <= with(false).effective_context(ctx) + 1e-12,
                    "ctx {ctx} hit {hit}"
                );
            }
        }
        assert!(mk(true).is_pipelined() && !mk(false).is_pipelined());
        assert!(!SparsityModel::Dense.is_pipelined());
    }

    /// Shard pricing: near-linear execution scaling with a plan-broadcast
    /// floor (DESIGN.md §12). Two shards roughly halve the attention term,
    /// never increase cost; the broadcast term makes scaling sub-linear
    /// and eventually caps useful shard counts.
    #[test]
    fn shard_pricing_scales_near_linearly_with_broadcast_floor() {
        let mk = |shards| SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 256,
            plan_hit_rate: 1.0, // isolate the exec term
            speculative_hit_rate: 0.0,
            pipelined: false,
            executor: ExecutorKind::Cpu,
            shards,
            constants: CostConstants::modeled(),
        };
        let n = 65536;
        let one = mk(1).effective_context(n);
        let two = mk(2).effective_context(n);
        let four = mk(4).effective_context(n);
        // attn(1) = 256 + 0.1·65280 = 6784.
        assert!((one - 6784.0).abs() < 1e-9, "unsharded {one}");
        // attn(2) = 6784/2 + 0.002·1·65536 = 3523.072.
        assert!((two - (6784.0 / 2.0 + PLAN_BROADCAST_FRAC * 65536.0)).abs() < 1e-9);
        // Near-linear: 2 shards cut cost by >1.9x at this length.
        assert!(one / two > 1.9, "2-shard speedup {}", one / two);
        assert!(two > one / 2.0, "broadcast term must price a floor");
        assert!(four < two, "4 shards still cheaper than 2 at 64k");
        // Diminishing returns: the broadcast floor eventually dominates —
        // an absurd shard count is priced worse than a moderate one.
        assert!(mk(256).effective_context(n) > mk(8).effective_context(n));
        // More shards never exceed the dense ceiling.
        for s in [1, 2, 4, 8, 64] {
            assert!(mk(s).effective_context(n) <= n as f64);
        }
        // shards: 0 clamps to unsharded rather than dividing by zero.
        assert_eq!(mk(0).effective_context(n), one);
        assert_eq!(mk(0).shards(), 1);
        assert_eq!(mk(4).shards(), 4);
        assert_eq!(SparsityModel::Dense.shards(), 1);
        // Sharding composes with the scheduler: a sharded model fits at
        // least as many prefill chunks per iteration.
        let run = |sparsity| {
            let mut pool = PagePool::new(64, 256);
            let mut states = mk_states(&[(1, 2048, 0), (2, 2048, 0), (3, 2048, 0), (4, 2048, 0)]);
            for st in &mut states {
                st.phase = Phase::Prefill;
                st.prefilled = 1792;
                pool.admit(st.request.id, st.request.total_tokens()).unwrap();
            }
            let mut c = cfg();
            c.max_running = 8;
            c.iter_budget = 450.0;
            c.sparsity = sparsity;
            plan_iteration(&c, &mut states, &mut pool).prefill.len()
        };
        assert!(run(mk(4)) >= run(mk(1)), "sharded {} vs unsharded {}", run(mk(4)), run(mk(1)));
    }

    #[test]
    fn observe_plan_hit_rate_is_ema_and_dense_noop() {
        let mut m = SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 256,
            plan_hit_rate: 0.0,
            speculative_hit_rate: 0.0,
            pipelined: false,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: CostConstants::modeled(),
        };
        m.observe_plan_hit_rate(1.0);
        match m {
            SparsityModel::Anchor { plan_hit_rate, .. } => {
                assert!((plan_hit_rate - 0.5).abs() < 1e-12)
            }
            _ => panic!(),
        }
        m.observe_plan_hit_rate(1.0);
        match m {
            SparsityModel::Anchor { plan_hit_rate, .. } => {
                assert!((plan_hit_rate - 0.75).abs() < 1e-12)
            }
            _ => panic!(),
        }
        let mut d = SparsityModel::Dense;
        d.observe_plan_hit_rate(1.0);
        assert_eq!(d.effective_context(100), 100.0);
    }

    /// Measured constants displace the modeled defaults in the estimate
    /// arithmetic: the same model prices differently once calibrated, and
    /// the modeled set is bit-identical to the historical globals so an
    /// uncalibrated scheduler is unchanged.
    #[test]
    fn calibrated_constants_displace_modeled_defaults() {
        assert_eq!(CostConstants::modeled().ident_cost_frac, IDENT_COST_FRAC);
        assert_eq!(CostConstants::modeled().plan_broadcast_frac, PLAN_BROADCAST_FRAC);
        assert_eq!(CostConstants::default(), CostConstants::modeled());
        assert!(!CostConstants::modeled().is_measured());

        let measured = CostConstants {
            ident_cost_frac: 0.25,
            plan_broadcast_frac: 0.004,
            span_ns_per_row: 1.5,
            gather_ns_per_row: 6.0,
            fold_ns_per_score: 0.8,
        };
        assert!(measured.is_measured());
        let mut m = SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 256,
            plan_hit_rate: 0.0,
            speculative_hit_rate: 0.0,
            pipelined: false,
            executor: ExecutorKind::Cpu,
            shards: 2,
            constants: CostConstants::modeled(),
        };
        let modeled_eff = m.effective_context(4096);
        m.set_constants(measured);
        assert_eq!(m.constants(), Some(measured));
        // attn = (256 + 0.1·3840)/2 + 0.004·1·4096; ident = 0.25·4096.
        let expect = (256.0 + 0.1 * 3840.0) / 2.0 + 0.004 * 4096.0 + 0.25 * 4096.0;
        let eff = m.effective_context(4096);
        assert!((eff - expect).abs() < 1e-9, "calibrated {eff} vs {expect}");
        assert!(eff != modeled_eff, "calibration must actually change pricing");
        // Dense has no constants to replace.
        let mut d = SparsityModel::Dense;
        d.set_constants(measured);
        assert_eq!(d.constants(), None);
        assert_eq!(d.effective_context(100), 100.0);
    }

    #[test]
    fn finished_requests_ignored() {
        let mut pool = PagePool::new(8, 256);
        let mut states = mk_states(&[(1, 256, 1)]);
        states[0].phase = Phase::Finished;
        let plan = plan_iteration(&cfg(), &mut states, &mut pool);
        assert!(plan.is_empty());
        assert!(plan.admitted.is_empty());
    }

    /// Preemption off (the default): a blocked small request waits behind
    /// a large prefill exactly as before.
    #[test]
    fn no_preemption_by_default() {
        let mut pool = PagePool::new(8, 256); // 2048 tokens
        let mut states = mk_states(&[(1, 1800, 8), (2, 300, 8)]);
        states[0].phase = Phase::Prefill;
        pool.admit(1, states[0].request.total_tokens()).unwrap();
        let plan = plan_iteration(&cfg(), &mut states, &mut pool);
        assert!(plan.preempted.is_empty());
        assert_eq!(states[1].phase, Phase::Queued);
        assert_eq!(pool.evictions(), 0);
        assert!(plan.prefill.iter().any(|&(id, _)| id == 1));
    }

    /// Preemption on: the blocked smaller request evicts the strictly
    /// larger prefill victim, takes its pages, and is admitted in the same
    /// iteration (so the plan is never empty and the serve loop never
    /// bails on a false deadlock).
    #[test]
    fn preemption_evicts_larger_prefill_and_admits_same_iteration() {
        let mut pool = PagePool::new(8, 256);
        let mut states = mk_states(&[(1, 1800, 8), (2, 300, 8)]);
        states[0].phase = Phase::Prefill;
        states[0].prefilled = 512;
        pool.admit(1, states[0].request.total_tokens()).unwrap();
        let mut c = cfg();
        c.preempt_prefill = true;
        let plan = plan_iteration(&c, &mut states, &mut pool);
        assert_eq!(plan.preempted, vec![1]);
        assert_eq!(plan.admitted, vec![2]);
        // The victim re-queues and its progress resets.
        assert_eq!(states[0].phase, Phase::Queued);
        assert_eq!(states[0].prefilled, 0);
        assert_eq!(states[0].preemptions, 1);
        // The winner holds pages and gets prefill work this iteration.
        assert_eq!(states[1].phase, Phase::Prefill);
        assert!(plan.prefill.iter().any(|&(id, _)| id == 2));
        assert!(!plan.is_empty());
        assert_eq!(pool.evictions(), 1);
    }

    /// A decoding request is never a preemption victim, and a victim must
    /// be *strictly* larger — an equal-size queued request cannot displace
    /// it (the total order that prevents eviction livelock).
    #[test]
    fn preemption_spares_decoders_and_equal_sizes() {
        let mut c = cfg();
        c.preempt_prefill = true;
        // Decoder fills the pool: the queued request must simply wait.
        let mut pool = PagePool::new(8, 256);
        let mut states = mk_states(&[(1, 1800, 8), (2, 300, 8)]);
        states[0].phase = Phase::Decode;
        states[0].prefilled = 1800;
        pool.admit(1, states[0].request.total_tokens()).unwrap();
        let plan = plan_iteration(&c, &mut states, &mut pool);
        assert!(plan.preempted.is_empty());
        assert_eq!(states[1].phase, Phase::Queued);
        // Equal sizes: no strict order, no eviction.
        let mut pool = PagePool::new(8, 256);
        let mut states = mk_states(&[(1, 1800, 8), (2, 1800, 8)]);
        states[0].phase = Phase::Prefill;
        pool.admit(1, states[0].request.total_tokens()).unwrap();
        let plan = plan_iteration(&c, &mut states, &mut pool);
        assert!(plan.preempted.is_empty());
        assert_eq!(pool.evictions(), 0);
    }

    /// The per-request cap: after [`MAX_PREEMPTIONS`] evictions a request
    /// keeps its pages for good.
    #[test]
    fn preemption_cap_protects_repeat_victims() {
        let mut c = cfg();
        c.preempt_prefill = true;
        let mut pool = PagePool::new(8, 256);
        let mut states = mk_states(&[(1, 1800, 8), (2, 300, 8)]);
        states[0].phase = Phase::Prefill;
        states[0].preemptions = MAX_PREEMPTIONS;
        pool.admit(1, states[0].request.total_tokens()).unwrap();
        let plan = plan_iteration(&c, &mut states, &mut pool);
        assert!(plan.preempted.is_empty(), "capped victim was evicted again");
        assert_eq!(states[0].phase, Phase::Prefill);
        assert_eq!(states[1].phase, Phase::Queued);
    }
}

//! Trace-driven serving loop and the typed request front-end:
//! admission → scheduling → batching → engine, producing a
//! [`ServeReport`]. Generic over [`StepExecutor`] so the whole control
//! plane is unit-testable with [`MockEngine`]; the binary wires in the
//! PJRT engine.
//!
//! Three front doors, one loop:
//! * [`serve`] — trusted, pre-built [`Request`]s (trace replay, tests);
//! * [`serve_requests`] — typed submissions ([`ServeRequest`]) validated
//!   through [`Request::builder`]'s rules and admission-controlled
//!   (`max_pending`), each answered with a [`ServeResponse`] carrying an
//!   explicit [`StatusCode`]; rejected submissions land in the report
//!   with their [`RequestOutcome`], never silently dropped;
//! * [`serve_wire`] — the same envelope over the coordinate-only wire
//!   protocol (DESIGN.md §14): `ReqSubmit` frames answered per-request,
//!   plus `Health` and `Metrics` probe endpoints.
//!
//! Configuration overrides flow through one validated path: the CLI, a
//! config file, and the wire front-end all construct a [`ServeOverrides`]
//! and apply it via [`ServerConfig::apply_overrides`] — no stringly-typed
//! flag surgery at call sites, and every rejection is a descriptive
//! error.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::time::Instant;

use anyhow::Result;

use super::batcher::build_batch;
use super::engine::{StepExecutor, StepOutcome};
use super::kv_cache::PagePool;
use super::metrics::{RequestOutcome, RequestRecord, ServeReport};
use super::request::{Phase, Request, RequestError, RequestState};
use super::scheduler::{plan_iteration, CostConstants, SchedulerConfig, SparsityModel};
use crate::attention::exec::ExecutorKind;
use crate::attention::reuse::ReusePolicy;
use crate::attention::session::{SessionConfig, SessionTransport};
use crate::wire::codec::{HealthReplyMsg, MetricsReplyMsg, ReqReplyMsg, ReqSubmitMsg};
use crate::wire::frame::{read_frame_opt, write_frame, FrameKind};
use crate::wire::{ErrorEnvelope, StatusCode};
use crate::workload::trace::TraceConfig;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub scheduler: SchedulerConfig,
    pub pool_pages: usize,
    pub page_tokens: usize,
    /// Reject prompts longer than this (the artifact cache capacity).
    pub max_seq: usize,
    /// Gate arrivals on wall-clock trace replay; `false` releases
    /// everything immediately (max-throughput mode).
    pub realtime: bool,
    /// Admission-control cap on queued submissions: past it, the typed
    /// front doors shed load with an `Overloaded` reply instead of
    /// building an unbounded backlog. `None` = unbounded (the trusted
    /// trace-replay default).
    pub max_pending: Option<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            scheduler: SchedulerConfig::default(),
            pool_pages: 64,
            page_tokens: 64,
            max_seq: 2048,
            realtime: false,
            max_pending: None,
        }
    }
}

/// Typed serve-time overrides — what the CLI flags, the config file, and
/// the wire front-end can each change about a loaded [`ServerConfig`] /
/// session / trace. One struct, one validated application path
/// ([`ServerConfig::apply_overrides`]), descriptive errors; `None`/`false`
/// fields leave the config untouched.
#[derive(Clone, Debug, Default)]
pub struct ServeOverrides {
    /// Trace arrival rate (requests/s).
    pub rate: Option<f64>,
    /// Trace request count.
    pub num_requests: Option<usize>,
    /// Swap the scheduler's sparsity model for the anchor cost model.
    pub anchor_sched: bool,
    /// Price identification as overlapped with execution (DESIGN.md §9).
    /// Only meaningful with the anchor model.
    pub pipeline: bool,
    /// Executor backend the scheduler's estimates are attributed to.
    pub executor: Option<ExecutorKind>,
    /// Head-group shard workers (scheduler pricing AND session execution).
    pub shards: Option<usize>,
    /// Shard-worker transport for the session (threads | process).
    pub transport: Option<SessionTransport>,
    /// Manifest path holding machine-measured cost constants
    /// (DESIGN.md §13) to swap in for the modeled defaults.
    pub calibration: Option<String>,
    /// Manifest-backed plan store path for the session block.
    pub plan_store: Option<String>,
    /// Admission-control queue cap (shed with `Overloaded` past it).
    pub max_pending: Option<usize>,
    /// Speculative plan-reuse policy for the attention sessions
    /// (DESIGN.md §17): exact | cross-layer | prefix.
    pub reuse: Option<ReusePolicy>,
}

impl ServerConfig {
    /// Apply the scheduler/server-side overrides, validating each:
    /// zero shard counts, a calibration without the anchor model, a
    /// missing calibration entry, and a zero queue cap are all rejected
    /// with descriptive errors instead of being clamped or ignored.
    pub fn apply_overrides(&mut self, ov: &ServeOverrides) -> Result<()> {
        if ov.anchor_sched {
            self.scheduler.sparsity = SparsityModel::Anchor {
                stripe_keep: 0.1,
                anchor_tokens: 256,
                plan_hit_rate: 0.0,
                speculative_hit_rate: 0.0,
                pipelined: ov.pipeline,
                executor: ExecutorKind::default(),
                shards: 1,
                constants: CostConstants::modeled(),
            };
        }
        if let Some(kind) = ov.executor {
            if let SparsityModel::Anchor { ref mut executor, .. } = self.scheduler.sparsity {
                *executor = kind;
            }
        }
        if let Some(n) = ov.shards {
            anyhow::ensure!(n >= 1, "shards override must be >= 1 (got {n})");
            if let SparsityModel::Anchor { ref mut shards, .. } = self.scheduler.sparsity {
                *shards = n;
            }
        }
        // The calibration lookup keys on the executor backend actually
        // priced, so it reads the post-override executor.
        if let Some(path) = &ov.calibration {
            let kind = match self.scheduler.sparsity {
                SparsityModel::Anchor { executor, .. } => executor,
                _ => anyhow::bail!(
                    "calibration override needs the anchor scheduler (pass --anchor-sched \
                     or set scheduler.sparsity in the config)"
                ),
            };
            let c = crate::runtime::manifest::load_calibration(path, kind)?.ok_or_else(|| {
                anyhow::anyhow!(
                    "manifest '{path}' holds no calibration for executor '{}' — run \
                     `anchor-attn calibrate --manifest {path} --executor {}` first",
                    kind.name(),
                    kind.name()
                )
            })?;
            self.scheduler.sparsity.set_constants(c);
        }
        if let Some(cap) = ov.max_pending {
            anyhow::ensure!(cap >= 1, "max_pending override must be >= 1 (got {cap})");
            self.max_pending = Some(cap);
        }
        Ok(())
    }
}

impl ServeOverrides {
    /// Apply the session-block overrides (same validation discipline).
    pub fn apply_session(&self, cfg: &mut SessionConfig) -> Result<()> {
        if let Some(n) = self.shards {
            anyhow::ensure!(n >= 1, "shards override must be >= 1 (got {n})");
            cfg.shards = n;
        }
        if let Some(t) = self.transport {
            cfg.transport = t;
        }
        if let Some(p) = &self.plan_store {
            cfg.plan_store = Some(p.clone());
        }
        if let Some(policy) = self.reuse {
            cfg.reuse = policy;
        }
        Ok(())
    }

    /// Apply the trace-block overrides.
    pub fn apply_trace(&self, cfg: &mut TraceConfig) {
        if let Some(r) = self.rate {
            cfg.rate = r;
        }
        if let Some(n) = self.num_requests {
            cfg.num_requests = n;
        }
    }
}

/// One typed front-end submission — the validated public envelope the
/// wire `ReqSubmit` frame decodes into.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub arrival_s: f64,
}

/// Per-submission reply: an explicit status code plus a human-readable
/// detail (empty on acceptance). Maps 1:1 onto the wire `ReqReply` frame.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeResponse {
    pub id: u64,
    pub status: StatusCode,
    pub detail: String,
}

impl ServeResponse {
    pub fn accepted(id: u64) -> Self {
        Self { id, status: StatusCode::Ok, detail: String::new() }
    }

    pub fn is_accepted(&self) -> bool {
        self.status == StatusCode::Ok
    }
}

/// The admission decision for one submission: validated request in, or a
/// typed rejection (status code for the reply, outcome for the report).
fn admit_one(
    cfg: &ServerConfig,
    queued: usize,
    sub: &ServeRequest,
) -> Result<Request, (StatusCode, RequestOutcome, String)> {
    if let Some(cap) = cfg.max_pending {
        if queued >= cap {
            return Err((
                StatusCode::Overloaded,
                RequestOutcome::Overloaded,
                format!("queue full ({cap} pending); retry later"),
            ));
        }
    }
    let req = Request::builder(sub.id)
        .prompt(sub.prompt.clone())
        .max_new_tokens(sub.max_new_tokens)
        .arrival_s(sub.arrival_s)
        .build(cfg.max_seq);
    req.map_err(|e| {
        let (status, outcome) = match e {
            RequestError::EmptyPrompt | RequestError::ZeroDecode => {
                (StatusCode::Invalid, RequestOutcome::RejectedInvalid)
            }
            RequestError::PromptTooLong { .. } => {
                (StatusCode::Oversized, RequestOutcome::RejectedOversized)
            }
        };
        (status, outcome, e.to_string())
    })
}

/// A report record for a submission that never reached the engine.
fn rejected_record(sub: &ServeRequest, outcome: RequestOutcome) -> RequestRecord {
    RequestRecord {
        id: sub.id,
        prompt_tokens: sub.prompt.len(),
        generated_tokens: 0,
        arrival_s: sub.arrival_s,
        ttft_s: f64::NAN,
        e2e_s: f64::NAN,
        outcome,
        scenario: None,
        plan_hits: 0,
        plan_misses: 0,
        speculative_hits: 0,
        speculative_fallbacks: 0,
        evictions: 0,
    }
}

/// The typed front door: validate and admission-control `submissions`,
/// serve the accepted ones, and answer every submission — accepted or
/// not — with a [`ServeResponse`]. Rejected submissions appear in the
/// report's records with their [`RequestOutcome`].
pub fn serve_requests<E: StepExecutor>(
    cfg: &ServerConfig,
    submissions: Vec<ServeRequest>,
    executor: &mut E,
    register: impl Fn(&mut E, &Request),
) -> Result<(ServeReport, Vec<ServeResponse>)> {
    let mut admitted: Vec<Request> = Vec::new();
    let mut responses: Vec<ServeResponse> = Vec::new();
    let mut rejects: Vec<RequestRecord> = Vec::new();
    for sub in &submissions {
        match admit_one(cfg, admitted.len(), sub) {
            Ok(req) => {
                responses.push(ServeResponse::accepted(sub.id));
                admitted.push(req);
            }
            Err((status, outcome, detail)) => {
                responses.push(ServeResponse { id: sub.id, status, detail });
                rejects.push(rejected_record(sub, outcome));
            }
        }
    }
    let mut report = serve(cfg, admitted, executor, register)?;
    report.records.extend(rejects);
    Ok((report, responses))
}

/// The wire front door (DESIGN.md §14): drive one framed connection —
/// `ReqSubmit` frames are admitted through the same path as
/// [`serve_requests`] and answered immediately with `ReqReply`; `Health`
/// answers queue depth vs capacity; `Metrics` answers a JSON counter
/// snapshot. `Shutdown` (or EOF) closes admission, serves the accepted
/// batch, and — on `Shutdown` — answers a final `Metrics` frame carrying
/// the full report before returning it.
pub fn serve_wire<S: Read + Write, E: StepExecutor>(
    cfg: &ServerConfig,
    stream: &mut S,
    executor: &mut E,
    register: impl Fn(&mut E, &Request),
) -> Result<ServeReport> {
    let mut admitted: Vec<Request> = Vec::new();
    let mut rejects: Vec<RequestRecord> = Vec::new();
    let mut reply_final = false;
    loop {
        let Some((kind, payload)) = read_frame_opt(stream)? else {
            break; // EOF: serve what was admitted, nobody is listening
        };
        match kind {
            FrameKind::ReqSubmit => {
                // A malformed payload rejects that submission, not the
                // connection — frames are length-delimited, the stream
                // stays aligned.
                let reply = match ReqSubmitMsg::decode(&payload) {
                    Ok(msg) => {
                        let sub = ServeRequest {
                            id: msg.id,
                            prompt: msg.prompt,
                            max_new_tokens: msg.max_new_tokens as usize,
                            arrival_s: msg.arrival_s,
                        };
                        match admit_one(cfg, admitted.len(), &sub) {
                            Ok(req) => {
                                admitted.push(req);
                                ReqReplyMsg {
                                    id: sub.id,
                                    status: StatusCode::Ok,
                                    detail: String::new(),
                                }
                            }
                            Err((status, outcome, detail)) => {
                                rejects.push(rejected_record(&sub, outcome));
                                ReqReplyMsg { id: sub.id, status, detail }
                            }
                        }
                    }
                    Err(e) => ReqReplyMsg {
                        id: 0,
                        status: StatusCode::Invalid,
                        detail: format!("malformed submission: {e}"),
                    },
                };
                write_frame(stream, FrameKind::ReqReply, &reply.encode())?;
            }
            FrameKind::Health => {
                let msg = HealthReplyMsg {
                    queued: admitted.len() as u64,
                    capacity: cfg.max_pending.unwrap_or(0) as u64,
                };
                write_frame(stream, FrameKind::HealthReply, &msg.encode())?;
            }
            FrameKind::Metrics => {
                let json = format!(
                    "{{\"queued\": {}, \"rejected\": {}, \"max_pending\": {}}}",
                    admitted.len(),
                    rejects.len(),
                    cfg.max_pending.map_or("null".to_string(), |c| c.to_string()),
                );
                let msg = MetricsReplyMsg { json };
                write_frame(stream, FrameKind::MetricsReply, &msg.encode())?;
            }
            FrameKind::Ping => write_frame(stream, FrameKind::Pong, &[])?,
            FrameKind::Shutdown => {
                reply_final = true;
                break;
            }
            other => {
                let env = ErrorEnvelope::new(
                    StatusCode::Internal,
                    format!("unexpected {other:?} frame on the serve front-end"),
                );
                write_frame(stream, FrameKind::Error, &env.encode())?;
                anyhow::bail!("serve front-end: unexpected {other:?} frame");
            }
        }
    }
    let mut report = serve(cfg, admitted, executor, register)?;
    report.records.extend(rejects);
    if reply_final {
        let msg = MetricsReplyMsg { json: report.to_json() };
        write_frame(stream, FrameKind::MetricsReply, &msg.encode())?;
    }
    Ok(report)
}

/// Serve `trace` to completion on `executor`.
///
/// The scheduler config is copied into a mutable local so the sparsity
/// model's `plan_hit_rate` EWMA can move *during* the run: after every
/// engine iteration the loop drains
/// [`StepExecutor::observed_plan_hit_rate`] — the merged hit rate of the
/// attention sessions behind the steps — and folds it in, so later
/// iterations are priced with the amortization actually being observed
/// (DESIGN.md §12).
pub fn serve<E: StepExecutor>(
    cfg: &ServerConfig,
    trace: Vec<Request>,
    executor: &mut E,
    register: impl Fn(&mut E, &Request),
) -> Result<ServeReport> {
    let mut pending: Vec<Request> = trace;
    pending.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    pending.reverse(); // pop from the back = earliest first

    let mut sched = cfg.scheduler;
    let mut states: Vec<RequestState> = Vec::new();
    let mut outcomes: HashMap<u64, RequestOutcome> = HashMap::new();
    let mut pool = PagePool::new(cfg.pool_pages, cfg.page_tokens);
    let mut report = ServeReport::default();
    // Per-request plan-cache attribution drained from the executor.
    let mut plan_attrib: HashMap<u64, (u64, u64)> = HashMap::new();
    // Per-request speculative-reuse attribution (hits, fallbacks).
    let mut spec_attrib: HashMap<u64, (u64, u64)> = HashMap::new();
    let t0 = Instant::now();
    let mut iteration = 0u64;

    loop {
        let now = t0.elapsed().as_secs_f64();

        // Admit arrivals (all at once in max-throughput mode).
        while let Some(last) = pending.last() {
            if !cfg.realtime || last.arrival_s <= now {
                let req = pending.pop().unwrap();
                if req.total_tokens() > cfg.max_seq {
                    // Reject oversized requests up front.
                    let mut st = RequestState::new(req);
                    st.phase = Phase::Finished;
                    st.finished_s = Some(now);
                    outcomes.insert(st.request.id, RequestOutcome::RejectedOversized);
                    states.push(st);
                    continue;
                }
                register(executor, &req);
                states.push(RequestState::new(req));
            } else {
                break;
            }
        }

        let all_done = pending.is_empty() && states.iter().all(|s| s.phase == Phase::Finished);
        if all_done {
            break;
        }

        let queued_now =
            pending.len() + states.iter().filter(|s| s.phase == Phase::Queued).count();
        report.peak_queue_depth = report.peak_queue_depth.max(queued_now);

        let plan = plan_iteration(&sched, &mut states, &mut pool);
        // Preempted requests restart prefill from scratch: reset the
        // executor's per-request context so its cost/progress tracking
        // matches the scheduler's `prefilled = 0`.
        for &vid in &plan.preempted {
            executor.finish_request(vid);
            let st = states.iter().find(|s| s.request.id == vid).unwrap();
            register(executor, &st.request);
        }
        if plan.is_empty() {
            if let Some(next) = pending.last() {
                // Idle until the next arrival.
                let wait = (next.arrival_s - now).max(0.0).min(0.05);
                std::thread::sleep(std::time::Duration::from_secs_f64(wait.max(1e-4)));
                continue;
            }
            // Nothing runnable but requests are queued and the pool is
            // full of *running* requests — should not happen, but avoid a
            // spin: error out loudly.
            anyhow::bail!("scheduler deadlock: queued requests but empty plan");
        }

        let batch = build_batch(iteration, &plan, &states)?;
        iteration += 1;
        let outcomes_step = executor.execute(&batch);
        // Live amortization feedback: the engine's merged plan-cache hit
        // rate moves the scheduler's EWMA for the *next* iterations.
        if let Some(observed) = executor.observed_plan_hit_rate() {
            sched.sparsity.observe_plan_hit_rate(observed);
            report.plan_hit_observations += 1;
        }
        for (req, hits, misses) in executor.take_plan_attribution() {
            let e = plan_attrib.entry(req).or_insert((0, 0));
            e.0 += hits;
            e.1 += misses;
        }
        // Same feedback loop for speculative reuse: observed hit rate
        // moves the recall-check pricing (DESIGN.md §17), per-request
        // attribution lands in the records.
        if let Some(observed) = executor.observed_speculative_hit_rate() {
            sched.sparsity.observe_speculative_hit_rate(observed);
        }
        for (req, hits, fallbacks) in executor.take_speculative_attribution() {
            let e = spec_attrib.entry(req).or_insert((0, 0));
            e.0 += hits;
            e.1 += fallbacks;
        }
        let now = t0.elapsed().as_secs_f64();

        for outcome in outcomes_step {
            match outcome {
                StepOutcome::PrefillChunk { req, took, next_token, elapsed_s, .. } => {
                    report.engine_busy_s += elapsed_s;
                    let st = states.iter_mut().find(|s| s.request.id == req).unwrap();
                    st.prefilled += took;
                    if st.remaining_prefill() == 0 {
                        // Prompt complete: the prefill logits give token 1.
                        st.phase = Phase::Decode;
                        st.generated.push(next_token);
                        st.first_token_s = Some(now);
                        if st.decode_done() {
                            finish(st, &mut pool, executor, now)?;
                        }
                    }
                }
                StepOutcome::Decoded { req, token, elapsed_s } => {
                    report.engine_busy_s += elapsed_s;
                    let st = states.iter_mut().find(|s| s.request.id == req).unwrap();
                    st.generated.push(token);
                    if st.decode_done() {
                        finish(st, &mut pool, executor, now)?;
                    }
                }
                StepOutcome::Failed { req, error } => {
                    eprintln!("request {req} failed: {error}");
                    let st = states.iter_mut().find(|s| s.request.id == req).unwrap();
                    if matches!(st.phase, Phase::Prefill | Phase::Decode) {
                        pool.release(req)?;
                    }
                    st.phase = Phase::Finished;
                    st.finished_s = Some(now);
                    outcomes.insert(req, RequestOutcome::Failed);
                    executor.finish_request(req);
                }
            }
        }
    }

    report.wall_s = t0.elapsed().as_secs_f64();
    report.iterations = iteration;
    report.final_plan_hit_rate = sched.sparsity.plan_hit_rate();
    report.kv_evictions = pool.evictions();
    for st in &states {
        let (plan_hits, plan_misses) =
            plan_attrib.get(&st.request.id).copied().unwrap_or((0, 0));
        let (speculative_hits, speculative_fallbacks) =
            spec_attrib.get(&st.request.id).copied().unwrap_or((0, 0));
        report.records.push(RequestRecord {
            id: st.request.id,
            prompt_tokens: st.request.prompt.len(),
            generated_tokens: st.generated.len(),
            arrival_s: st.request.arrival_s,
            ttft_s: st.first_token_s.map(|t| t - st.request.arrival_s).unwrap_or(f64::NAN),
            e2e_s: st.finished_s.map(|t| t - st.request.arrival_s).unwrap_or(f64::NAN),
            outcome: outcomes
                .get(&st.request.id)
                .copied()
                .unwrap_or(RequestOutcome::Completed),
            scenario: st.request.scenario.clone(),
            plan_hits,
            plan_misses,
            speculative_hits,
            speculative_fallbacks,
            evictions: st.preemptions,
        });
    }
    Ok(report)
}

fn finish<E: StepExecutor>(
    st: &mut RequestState,
    pool: &mut PagePool,
    executor: &mut E,
    now: f64,
) -> Result<()> {
    st.phase = Phase::Finished;
    st.finished_s = Some(now);
    pool.release(st.request.id)?;
    executor.finish_request(st.request.id);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exec::ExecutorKind;
    use crate::coordinator::engine::MockEngine;

    fn trace(n: usize, prompt: usize, new_tokens: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request::new(i as u64, vec![1; prompt], new_tokens, 0.0))
            .collect()
    }

    fn run(trace: Vec<Request>, cfg: &ServerConfig) -> ServeReport {
        let mut engine = MockEngine::new(512);
        serve(cfg, trace, &mut engine, |_, _| {}).unwrap()
    }

    #[test]
    fn serves_all_requests_to_completion() {
        let cfg = ServerConfig::default();
        let rep = run(trace(6, 300, 4), &cfg);
        assert_eq!(rep.records.len(), 6);
        for r in &rep.records {
            assert_eq!(r.prompt_tokens, 300);
            assert_eq!(r.generated_tokens, 4);
            assert!(r.ttft_s.is_finite() && r.e2e_s.is_finite());
            assert!(r.ttft_s <= r.e2e_s + 1e-9);
            assert_eq!(r.outcome, RequestOutcome::Completed);
        }
        assert!(rep.iterations > 0);
    }

    #[test]
    fn oversized_request_rejected_not_served() {
        let mut cfg = ServerConfig::default();
        cfg.max_seq = 256;
        let mut t = trace(1, 1000, 4);
        t.extend(trace(1, 100, 2).into_iter().map(|mut r| {
            r.id = 99;
            r
        }));
        let rep = run(t, &cfg);
        let rejected = rep.records.iter().find(|r| r.prompt_tokens == 1000).unwrap();
        assert_eq!(rejected.generated_tokens, 0);
        assert_eq!(rejected.outcome, RequestOutcome::RejectedOversized);
        let ok = rep.records.iter().find(|r| r.id == 99).unwrap();
        assert_eq!(ok.generated_tokens, 2);
        assert_eq!(ok.outcome, RequestOutcome::Completed);
    }

    #[test]
    fn pool_pressure_serializes_but_completes() {
        let mut cfg = ServerConfig::default();
        cfg.pool_pages = 6; // tight: one 300-token request = 5 pages
        let rep = run(trace(4, 300, 2), &cfg);
        assert_eq!(rep.records.len(), 4);
        assert!(rep.records.iter().all(|r| r.generated_tokens == 2));
    }

    #[test]
    fn prefill_preemption_completes_everything_and_counts_evictions() {
        let mut cfg = ServerConfig::default();
        cfg.pool_pages = 8; // 512 tokens: the big request fills the pool
        cfg.page_tokens = 64;
        cfg.scheduler.preempt_prefill = true;
        let mut t = trace(1, 480, 4); // id 0: 8 pages, blocks everyone
        t.extend((1..4).map(|i| Request::new(i, vec![1; 120], 2, 0.0)));
        let rep = run(t, &cfg);
        assert_eq!(rep.records.len(), 4);
        assert!(
            rep.records.iter().all(|r| r.outcome == RequestOutcome::Completed),
            "{:?}",
            rep.records
        );
        // The big request was displaced at least once and the pool counted it.
        assert!(rep.kv_evictions >= 1, "expected evictions, got {}", rep.kv_evictions);
        let big = rep.records.iter().find(|r| r.id == 0).unwrap();
        assert!(big.evictions >= 1 && big.evictions <= 2, "{:?}", big);
        assert_eq!(big.generated_tokens, 4);
        assert!(rep.peak_queue_depth >= 3);
        // Scenario tags flow through to records (none set here).
        assert!(rep.records.iter().all(|r| r.scenario.is_none()));
    }

    #[test]
    fn single_token_generation() {
        let cfg = ServerConfig::default();
        let rep = run(trace(2, 64, 1), &cfg);
        assert!(rep.records.iter().all(|r| r.generated_tokens == 1));
    }

    #[test]
    fn chunked_prefill_counts_tokens_exactly() {
        let cfg = ServerConfig::default();
        // 600 tokens => chunks of 256+256+88.
        let rep = run(trace(1, 600, 1), &cfg);
        assert_eq!(rep.total_prompt_tokens(), 600);
    }

    #[test]
    fn anchor_scheduler_lowers_iterations_for_long_prompts() {
        use crate::coordinator::scheduler::{CostConstants, SparsityModel};
        let mk = |sparsity| {
            let mut cfg = ServerConfig::default();
            cfg.scheduler.sparsity = sparsity;
            cfg.scheduler.iter_budget = 400.0;
            cfg.pool_pages = 256;
            run(trace(6, 1500, 2), &cfg)
        };
        let dense = mk(SparsityModel::Dense);
        let anchor = mk(SparsityModel::Anchor {
            stripe_keep: 0.08,
            anchor_tokens: 256,
            plan_hit_rate: 0.5,
            speculative_hit_rate: 0.0,
            pipelined: false,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: CostConstants::modeled(),
        });
        assert!(
            anchor.iterations <= dense.iterations,
            "anchor {} vs dense {}",
            anchor.iterations,
            dense.iterations
        );
    }

    /// The pipelined cost model buys headroom: the same trace completes in
    /// no more iterations than the sequential anchor model (overlapped
    /// identification frees iteration budget for extra prefill chunks).
    #[test]
    fn pipelined_scheduler_no_worse_than_sequential_anchor() {
        use crate::coordinator::scheduler::{CostConstants, SparsityModel};
        let mk = |pipelined| {
            let mut cfg = ServerConfig::default();
            cfg.scheduler.sparsity = SparsityModel::Anchor {
                stripe_keep: 0.08,
                anchor_tokens: 256,
                plan_hit_rate: 0.0,
                speculative_hit_rate: 0.0,
                pipelined,
                executor: ExecutorKind::Cpu,
                shards: 1,
                constants: CostConstants::modeled(),
            };
            cfg.scheduler.iter_budget = 400.0;
            cfg.pool_pages = 256;
            run(trace(6, 1500, 2), &cfg)
        };
        let sequential = mk(false);
        let piped = mk(true);
        assert!(
            piped.iterations <= sequential.iterations,
            "pipelined {} vs sequential {}",
            piped.iterations,
            sequential.iterations
        );
        // The mock engine's busy time reflects the cheaper pipelined
        // chunks too (cost model ↔ engine agreement).
        assert!(piped.engine_busy_s <= sequential.engine_busy_s + 1e-9);
    }

    // -- typed front-end --

    fn sub(id: u64, prompt: usize, new_tokens: usize) -> ServeRequest {
        ServeRequest { id, prompt: vec![1; prompt], max_new_tokens: new_tokens, arrival_s: 0.0 }
    }

    #[test]
    fn serve_requests_answers_every_submission_with_a_status() {
        let mut cfg = ServerConfig::default();
        cfg.max_seq = 512;
        let subs = vec![
            sub(1, 100, 4),        // ok
            sub(2, 0, 4),          // invalid: empty prompt
            sub(3, 100, 0),        // invalid: zero decode
            sub(4, 5000, 4),       // oversized
            sub(5, 100, 2),        // ok
        ];
        let mut engine = MockEngine::new(512);
        let (rep, responses) = serve_requests(&cfg, subs, &mut engine, |_, _| {}).unwrap();
        assert_eq!(responses.len(), 5);
        assert!(responses[0].is_accepted());
        assert_eq!(responses[1].status, StatusCode::Invalid);
        assert_eq!(responses[2].status, StatusCode::Invalid);
        assert_eq!(responses[3].status, StatusCode::Oversized);
        assert!(responses[4].is_accepted());
        // Rejections carry actionable detail, not just a code.
        assert!(responses[3].detail.contains("budget"), "{}", responses[3].detail);
        // Every submission lands in the report with its outcome.
        assert_eq!(rep.records.len(), 5);
        assert_eq!(rep.outcome_count(RequestOutcome::Completed), 2);
        assert_eq!(rep.outcome_count(RequestOutcome::RejectedInvalid), 2);
        assert_eq!(rep.outcome_count(RequestOutcome::RejectedOversized), 1);
    }

    #[test]
    fn admission_control_sheds_load_with_overloaded() {
        let mut cfg = ServerConfig::default();
        cfg.max_pending = Some(2);
        let subs = (0..4).map(|i| sub(i, 64, 1)).collect();
        let mut engine = MockEngine::new(512);
        let (rep, responses) = serve_requests(&cfg, subs, &mut engine, |_, _| {}).unwrap();
        assert!(responses[0].is_accepted() && responses[1].is_accepted());
        assert_eq!(responses[2].status, StatusCode::Overloaded);
        assert_eq!(responses[3].status, StatusCode::Overloaded);
        assert_eq!(rep.outcome_count(RequestOutcome::Completed), 2);
        assert_eq!(rep.outcome_count(RequestOutcome::Overloaded), 2);
    }

    #[test]
    fn overrides_apply_through_one_validated_path() {
        let mut cfg = ServerConfig::default();
        let ov = ServeOverrides {
            anchor_sched: true,
            pipeline: true,
            executor: Some(ExecutorKind::Pjrt),
            shards: Some(4),
            max_pending: Some(32),
            ..ServeOverrides::default()
        };
        cfg.apply_overrides(&ov).unwrap();
        match cfg.scheduler.sparsity {
            SparsityModel::Anchor { pipelined, executor, shards, .. } => {
                assert!(pipelined);
                assert_eq!(executor, ExecutorKind::Pjrt);
                assert_eq!(shards, 4);
            }
            _ => panic!("anchor_sched override must install the anchor model"),
        }
        assert_eq!(cfg.max_pending, Some(32));
        // Validation is loud, not clamping.
        let bad = ServeOverrides { shards: Some(0), ..ServeOverrides::default() };
        assert!(ServerConfig::default().apply_overrides(&bad).is_err());
        let bad = ServeOverrides { max_pending: Some(0), ..ServeOverrides::default() };
        assert!(ServerConfig::default().apply_overrides(&bad).is_err());
        // Calibration without the anchor model is a descriptive error.
        let bad = ServeOverrides {
            calibration: Some("nonexistent.json".into()),
            ..ServeOverrides::default()
        };
        let err = ServerConfig::default().apply_overrides(&bad).unwrap_err().to_string();
        assert!(err.contains("anchor"), "{err}");
    }

    #[test]
    fn overrides_apply_to_session_and_trace_blocks() {
        let ov = ServeOverrides {
            rate: Some(9.5),
            num_requests: Some(7),
            shards: Some(3),
            transport: Some(SessionTransport::Process),
            plan_store: Some("artifacts/manifest.json".into()),
            ..ServeOverrides::default()
        };
        let mut session = SessionConfig::default();
        ov.apply_session(&mut session).unwrap();
        assert_eq!(session.shards, 3);
        assert_eq!(session.transport, SessionTransport::Process);
        assert_eq!(session.plan_store.as_deref(), Some("artifacts/manifest.json"));
        let mut trace = TraceConfig::default();
        ov.apply_trace(&mut trace);
        assert_eq!(trace.rate, 9.5);
        assert_eq!(trace.num_requests, 7);
    }

    /// The wire front door end-to-end over an in-memory duplex stream:
    /// submissions answered per-request with typed status codes, health
    /// and metrics probes answered, and the final report delivered on
    /// Shutdown.
    #[test]
    fn wire_front_end_serves_a_framed_session() {
        use crate::wire::codec::{HealthReplyMsg, MetricsReplyMsg, ReqReplyMsg, ReqSubmitMsg};
        use crate::wire::frame::{encode_frame, read_frame, FrameKind};
        use std::os::unix::net::UnixStream;

        let (mut client, mut server) = UnixStream::pair().unwrap();
        let serve_thread = std::thread::spawn(move || {
            let mut cfg = ServerConfig::default();
            cfg.max_pending = Some(2);
            let mut engine = MockEngine::new(512);
            serve_wire(&cfg, &mut server, &mut engine, |_, _| {}).unwrap()
        });

        let submit = |client: &mut UnixStream, id: u64, prompt: usize| -> ReqReplyMsg {
            let msg = ReqSubmitMsg {
                id,
                prompt: vec![1; prompt],
                max_new_tokens: 2,
                arrival_s: 0.0,
            };
            client.write_all(&encode_frame(FrameKind::ReqSubmit, &msg.encode())).unwrap();
            let (kind, payload) = read_frame(client).unwrap();
            assert_eq!(kind, FrameKind::ReqReply);
            ReqReplyMsg::decode(&payload).unwrap()
        };

        // Health before anything queued.
        client.write_all(&encode_frame(FrameKind::Health, &[])).unwrap();
        let (kind, payload) = read_frame(&mut client).unwrap();
        assert_eq!(kind, FrameKind::HealthReply);
        let health = HealthReplyMsg::decode(&payload).unwrap();
        assert_eq!((health.queued, health.capacity), (0, 2));

        assert_eq!(submit(&mut client, 1, 100).status, StatusCode::Ok);
        assert_eq!(submit(&mut client, 2, 0).status, StatusCode::Invalid);
        assert_eq!(submit(&mut client, 3, 100).status, StatusCode::Ok);
        // Queue cap reached: typed shed, not a hang or a silent drop.
        let shed = submit(&mut client, 4, 100);
        assert_eq!(shed.status, StatusCode::Overloaded);
        assert!(shed.detail.contains("retry"), "{}", shed.detail);

        // Metrics probe mid-session.
        client.write_all(&encode_frame(FrameKind::Metrics, &[])).unwrap();
        let (kind, payload) = read_frame(&mut client).unwrap();
        assert_eq!(kind, FrameKind::MetricsReply);
        let m = MetricsReplyMsg::decode(&payload).unwrap();
        assert!(m.json.contains("\"queued\": 2"), "{}", m.json);

        // Shutdown: the accepted batch serves; the final metrics frame
        // carries the report.
        client.write_all(&encode_frame(FrameKind::Shutdown, &[])).unwrap();
        let (kind, payload) = read_frame(&mut client).unwrap();
        assert_eq!(kind, FrameKind::MetricsReply);
        let final_m = MetricsReplyMsg::decode(&payload).unwrap();
        assert!(final_m.json.contains("\"completed\": 2"), "{}", final_m.json);

        let report = serve_thread.join().unwrap();
        assert_eq!(report.outcome_count(RequestOutcome::Completed), 2);
        assert_eq!(report.outcome_count(RequestOutcome::RejectedInvalid), 1);
        assert_eq!(report.outcome_count(RequestOutcome::Overloaded), 1);
        assert_eq!(report.records.len(), 4);
    }
}

//! Trace-driven serving loop: admission → scheduling → batching → engine,
//! producing a [`ServeReport`]. Generic over [`StepExecutor`] so the whole
//! control plane is unit-testable with [`MockEngine`]; the binary wires in
//! the PJRT engine.

use std::time::Instant;

use anyhow::Result;

use super::batcher::build_batch;
use super::engine::{StepExecutor, StepOutcome};
use super::kv_cache::PagePool;
use super::metrics::{RequestRecord, ServeReport};
use super::request::{Phase, Request, RequestState};
use super::scheduler::{plan_iteration, SchedulerConfig};

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub scheduler: SchedulerConfig,
    pub pool_pages: usize,
    pub page_tokens: usize,
    /// Reject prompts longer than this (the artifact cache capacity).
    pub max_seq: usize,
    /// Gate arrivals on wall-clock trace replay; `false` releases
    /// everything immediately (max-throughput mode).
    pub realtime: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            scheduler: SchedulerConfig::default(),
            pool_pages: 64,
            page_tokens: 64,
            max_seq: 2048,
            realtime: false,
        }
    }
}

/// Serve `trace` to completion on `executor`.
///
/// The scheduler config is copied into a mutable local so the sparsity
/// model's `plan_hit_rate` EWMA can move *during* the run: after every
/// engine iteration the loop drains
/// [`StepExecutor::observed_plan_hit_rate`] — the merged hit rate of the
/// attention sessions behind the steps — and folds it in, so later
/// iterations are priced with the amortization actually being observed
/// (DESIGN.md §12).
pub fn serve<E: StepExecutor>(
    cfg: &ServerConfig,
    trace: Vec<Request>,
    executor: &mut E,
    register: impl Fn(&mut E, &Request),
) -> Result<ServeReport> {
    let mut pending: Vec<Request> = trace;
    pending.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    pending.reverse(); // pop from the back = earliest first

    let mut sched = cfg.scheduler;
    let mut states: Vec<RequestState> = Vec::new();
    let mut pool = PagePool::new(cfg.pool_pages, cfg.page_tokens);
    let mut report = ServeReport::default();
    let t0 = Instant::now();
    let mut iteration = 0u64;

    loop {
        let now = t0.elapsed().as_secs_f64();

        // Admit arrivals (all at once in max-throughput mode).
        while let Some(last) = pending.last() {
            if !cfg.realtime || last.arrival_s <= now {
                let req = pending.pop().unwrap();
                if req.total_tokens() > cfg.max_seq {
                    // Reject oversized requests up front.
                    let mut st = RequestState::new(req);
                    st.phase = Phase::Finished;
                    st.finished_s = Some(now);
                    states.push(st);
                    continue;
                }
                register(executor, &req);
                states.push(RequestState::new(req));
            } else {
                break;
            }
        }

        let all_done = pending.is_empty() && states.iter().all(|s| s.phase == Phase::Finished);
        if all_done {
            break;
        }

        let plan = plan_iteration(&sched, &mut states, &mut pool);
        if plan.is_empty() {
            if let Some(next) = pending.last() {
                // Idle until the next arrival.
                let wait = (next.arrival_s - now).max(0.0).min(0.05);
                std::thread::sleep(std::time::Duration::from_secs_f64(wait.max(1e-4)));
                continue;
            }
            // Nothing runnable but requests are queued and the pool is
            // full of *running* requests — should not happen, but avoid a
            // spin: error out loudly.
            anyhow::bail!("scheduler deadlock: queued requests but empty plan");
        }

        let batch = build_batch(iteration, &plan, &states)?;
        iteration += 1;
        let outcomes = executor.execute(&batch);
        // Live amortization feedback: the engine's merged plan-cache hit
        // rate moves the scheduler's EWMA for the *next* iterations.
        if let Some(observed) = executor.observed_plan_hit_rate() {
            sched.sparsity.observe_plan_hit_rate(observed);
            report.plan_hit_observations += 1;
        }
        let now = t0.elapsed().as_secs_f64();

        for outcome in outcomes {
            match outcome {
                StepOutcome::PrefillChunk { req, took, next_token, elapsed_s, .. } => {
                    report.engine_busy_s += elapsed_s;
                    let st = states.iter_mut().find(|s| s.request.id == req).unwrap();
                    st.prefilled += took;
                    if st.remaining_prefill() == 0 {
                        // Prompt complete: the prefill logits give token 1.
                        st.phase = Phase::Decode;
                        st.generated.push(next_token);
                        st.first_token_s = Some(now);
                        if st.decode_done() {
                            finish(st, &mut pool, executor, now)?;
                        }
                    }
                }
                StepOutcome::Decoded { req, token, elapsed_s } => {
                    report.engine_busy_s += elapsed_s;
                    let st = states.iter_mut().find(|s| s.request.id == req).unwrap();
                    st.generated.push(token);
                    if st.decode_done() {
                        finish(st, &mut pool, executor, now)?;
                    }
                }
                StepOutcome::Failed { req, error } => {
                    eprintln!("request {req} failed: {error}");
                    let st = states.iter_mut().find(|s| s.request.id == req).unwrap();
                    if matches!(st.phase, Phase::Prefill | Phase::Decode) {
                        pool.release(req)?;
                    }
                    st.phase = Phase::Finished;
                    st.finished_s = Some(now);
                    executor.finish_request(req);
                }
            }
        }
    }

    report.wall_s = t0.elapsed().as_secs_f64();
    report.iterations = iteration;
    report.final_plan_hit_rate = sched.sparsity.plan_hit_rate();
    for st in &states {
        report.records.push(RequestRecord {
            id: st.request.id,
            prompt_tokens: st.request.prompt.len(),
            generated_tokens: st.generated.len(),
            arrival_s: st.request.arrival_s,
            ttft_s: st.first_token_s.map(|t| t - st.request.arrival_s).unwrap_or(f64::NAN),
            e2e_s: st.finished_s.map(|t| t - st.request.arrival_s).unwrap_or(f64::NAN),
        });
    }
    Ok(report)
}

fn finish<E: StepExecutor>(
    st: &mut RequestState,
    pool: &mut PagePool,
    executor: &mut E,
    now: f64,
) -> Result<()> {
    st.phase = Phase::Finished;
    st.finished_s = Some(now);
    pool.release(st.request.id)?;
    executor.finish_request(st.request.id);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exec::ExecutorKind;
    use crate::coordinator::engine::MockEngine;

    fn trace(n: usize, prompt: usize, new_tokens: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request::new(i as u64, vec![1; prompt], new_tokens, 0.0))
            .collect()
    }

    fn run(trace: Vec<Request>, cfg: &ServerConfig) -> ServeReport {
        let mut engine = MockEngine::new(512);
        serve(cfg, trace, &mut engine, |_, _| {}).unwrap()
    }

    #[test]
    fn serves_all_requests_to_completion() {
        let cfg = ServerConfig::default();
        let rep = run(trace(6, 300, 4), &cfg);
        assert_eq!(rep.records.len(), 6);
        for r in &rep.records {
            assert_eq!(r.prompt_tokens, 300);
            assert_eq!(r.generated_tokens, 4);
            assert!(r.ttft_s.is_finite() && r.e2e_s.is_finite());
            assert!(r.ttft_s <= r.e2e_s + 1e-9);
        }
        assert!(rep.iterations > 0);
    }

    #[test]
    fn oversized_request_rejected_not_served() {
        let mut cfg = ServerConfig::default();
        cfg.max_seq = 256;
        let mut t = trace(1, 1000, 4);
        t.extend(trace(1, 100, 2).into_iter().map(|mut r| {
            r.id = 99;
            r
        }));
        let rep = run(t, &cfg);
        let rejected = rep.records.iter().find(|r| r.prompt_tokens == 1000).unwrap();
        assert_eq!(rejected.generated_tokens, 0);
        let ok = rep.records.iter().find(|r| r.id == 99).unwrap();
        assert_eq!(ok.generated_tokens, 2);
    }

    #[test]
    fn pool_pressure_serializes_but_completes() {
        let mut cfg = ServerConfig::default();
        cfg.pool_pages = 6; // tight: one 300-token request = 5 pages
        let rep = run(trace(4, 300, 2), &cfg);
        assert_eq!(rep.records.len(), 4);
        assert!(rep.records.iter().all(|r| r.generated_tokens == 2));
    }

    #[test]
    fn single_token_generation() {
        let cfg = ServerConfig::default();
        let rep = run(trace(2, 64, 1), &cfg);
        assert!(rep.records.iter().all(|r| r.generated_tokens == 1));
    }

    #[test]
    fn chunked_prefill_counts_tokens_exactly() {
        let cfg = ServerConfig::default();
        // 600 tokens => chunks of 256+256+88.
        let rep = run(trace(1, 600, 1), &cfg);
        assert_eq!(rep.total_prompt_tokens(), 600);
    }

    #[test]
    fn anchor_scheduler_lowers_iterations_for_long_prompts() {
        use crate::coordinator::scheduler::{CostConstants, SparsityModel};
        let mk = |sparsity| {
            let mut cfg = ServerConfig::default();
            cfg.scheduler.sparsity = sparsity;
            cfg.scheduler.iter_budget = 400.0;
            cfg.pool_pages = 256;
            run(trace(6, 1500, 2), &cfg)
        };
        let dense = mk(SparsityModel::Dense);
        let anchor = mk(SparsityModel::Anchor {
            stripe_keep: 0.08,
            anchor_tokens: 256,
            plan_hit_rate: 0.5,
            pipelined: false,
            executor: ExecutorKind::Cpu,
            shards: 1,
            constants: CostConstants::modeled(),
        });
        assert!(
            anchor.iterations <= dense.iterations,
            "anchor {} vs dense {}",
            anchor.iterations,
            dense.iterations
        );
    }

    /// The pipelined cost model buys headroom: the same trace completes in
    /// no more iterations than the sequential anchor model (overlapped
    /// identification frees iteration budget for extra prefill chunks).
    #[test]
    fn pipelined_scheduler_no_worse_than_sequential_anchor() {
        use crate::coordinator::scheduler::{CostConstants, SparsityModel};
        let mk = |pipelined| {
            let mut cfg = ServerConfig::default();
            cfg.scheduler.sparsity = SparsityModel::Anchor {
                stripe_keep: 0.08,
                anchor_tokens: 256,
                plan_hit_rate: 0.0,
                pipelined,
                executor: ExecutorKind::Cpu,
                shards: 1,
                constants: CostConstants::modeled(),
            };
            cfg.scheduler.iter_budget = 400.0;
            cfg.pool_pages = 256;
            run(trace(6, 1500, 2), &cfg)
        };
        let sequential = mk(false);
        let piped = mk(true);
        assert!(
            piped.iterations <= sequential.iterations,
            "pipelined {} vs sequential {}",
            piped.iterations,
            sequential.iterations
        );
        // The mock engine's busy time reflects the cheaper pipelined
        // chunks too (cost model ↔ engine agreement).
        assert!(piped.engine_busy_s <= sequential.engine_busy_s + 1e-9);
    }
}

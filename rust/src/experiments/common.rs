//! Shared experiment plumbing: scaled method construction, evaluation of
//! one (head, method) pair — metrics read from the [`SparsePlan`], the
//! executor only runs for latency/output fidelity — and report formatting.

use std::time::Instant;

use crate::attention::anchor::AnchorConfig;
use crate::attention::baselines::block_topk::BlockTopKConfig;
use crate::attention::baselines::flexprefill::FlexPrefillConfig;
use crate::attention::baselines::streaming::StreamingConfig;
use crate::attention::baselines::vertical_slash::VerticalSlashConfig;
use crate::attention::plan::{self, BatchInput, PlanKey};
use crate::attention::{metrics, HeadInput, Method, TileConfig};
use crate::util::json::Json;
use crate::workload::qkv::generate;
use crate::workload::WorkloadProfile;

/// Quick (CI/test) vs full (bench) experiment scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpScale {
    Quick,
    Full,
}

impl ExpScale {
    pub fn from_quick_flag(quick: bool) -> Self {
        if quick {
            ExpScale::Quick
        } else {
            ExpScale::Full
        }
    }

    /// Primary evaluation length.
    pub fn main_n(self) -> usize {
        match self {
            ExpScale::Quick => 4096,
            ExpScale::Full => 16384,
        }
    }

    /// Length sweep for Fig. 2 / 6c / Table 3.
    pub fn lengths(self) -> Vec<usize> {
        match self {
            ExpScale::Quick => vec![2048, 4096, 8192],
            ExpScale::Full => vec![4096, 8192, 16384],
        }
    }

    /// Tile used throughout (paper: 128; quick shrinks with N).
    pub fn tile(self) -> TileConfig {
        TileConfig::new(128, 128)
    }
}

/// Identification step scaled to keep ≥8 groups at short lengths (the
/// paper's step=16 assumes 128k ⇒ 1024 query blocks; at CI lengths it
/// would collapse to a single group and anchor would equal full).
pub fn scaled_step(n: usize, tile: TileConfig) -> usize {
    let blocks = n / tile.b_q;
    if blocks >= 128 {
        16
    } else {
        (blocks / 8).max(2)
    }
}

/// The paper's method set at parameters scaled to length `n`
/// (paper values are tuned for 128k; DESIGN.md §6 scaling policy keeps the
/// *fractions* of context constant).
pub fn paper_methods(n: usize, tile: TileConfig, theta: f32) -> Vec<Method> {
    let frac = |tokens_at_128k: usize| -> usize {
        ((tokens_at_128k as f64) * (n as f64) / 131072.0).round().max(tile.b_kv as f64) as usize
    };
    vec![
        Method::Full(tile),
        Method::Streaming(StreamingConfig {
            tile,
            global_tokens: frac(1024),
            local_tokens: frac(8192),
        }),
        Method::VerticalSlash(VerticalSlashConfig {
            tile,
            vertical_tokens: frac(1024),
            slash_tokens: frac(8192),
            last_q: 64.min(n),
        }),
        Method::FlexPrefill(FlexPrefillConfig {
            tile,
            gamma: 0.95,
            min_budget_tokens: frac(1024),
        }),
        Method::Anchor(AnchorConfig {
            tile,
            theta,
            step: scaled_step(n, tile),
            init_blocks: 1,
            use_anchor: true,
        }),
    ]
}

/// As [`paper_methods`] with the anchor identification step pinned to
/// `step` when given (the fig2 `--step` re-measure grid); `None` keeps
/// the length-scaled default.
pub fn paper_methods_with_step(
    n: usize,
    tile: TileConfig,
    theta: f32,
    step: Option<usize>,
) -> Vec<Method> {
    let mut methods = paper_methods(n, tile, theta);
    if let Some(step) = step {
        for m in &mut methods {
            if let Method::Anchor(cfg) = m {
                cfg.step = step.max(1);
            }
        }
    }
    methods
}

/// Analysis-only extra baseline (Table 1).
pub fn block_topk_method(n: usize, tile: TileConfig) -> Method {
    let k_blocks = ((256.0 * n as f64 / 131072.0).round() as usize).max(2);
    Method::BlockTopK(BlockTopKConfig { tile, k: k_blocks, force_sink_local: true })
}

/// One evaluated (head, method) data point.
#[derive(Clone, Debug)]
pub struct EvalRow {
    pub method: String,
    pub n: usize,
    pub recall: f64,
    pub min_recall: f64,
    pub sparsity: f64,
    /// Total method latency (plan + execute).
    pub latency_s: f64,
    /// Identification share of `latency_s` (what a plan-cache hit saves).
    pub plan_s: f64,
    pub flops: u64,
    pub output_rel_err: f64,
}

/// Run a method on a head, measuring latency, recall, sparsity and output
/// fidelity against dense attention. Recall and sparsity come straight
/// from the plan's coverage; attention only executes for the latency and
/// fidelity columns.
pub fn evaluate(head: &HeadInput, method: &Method, tile: TileConfig) -> EvalRow {
    let full = crate::attention::full::full_attention(head, tile);

    let t0 = Instant::now();
    let head_plan = method.plan(head);
    let t1 = Instant::now();
    let out = plan::execute_plan(head, &head_plan);
    let t2 = Instant::now();

    let cov = head_plan.coverage();
    let rec = metrics::recall(head, &cov, tile);
    let mut flops = out.cost.flops;
    flops += head_plan.ident_cost.flops;
    EvalRow {
        method: method.name().to_string(),
        n: head.n(),
        recall: rec.mean_recall,
        min_recall: rec.min_recall,
        sparsity: cov.sparsity(),
        latency_s: (t2 - t0).as_secs_f64(),
        plan_s: (t1 - t0).as_secs_f64(),
        flops,
        output_rel_err: out.out.rel_err(&full.out),
    }
}

/// Latency-only measurement (no metric overhead) with `iters` repeats,
/// reporting the minimum (steady-state) time. Runs through an uncached
/// session so every repeat pays full identification (full-method latency,
/// not the amortized serving case).
pub fn measure_latency(head: &HeadInput, method: &Method, iters: usize) -> f64 {
    let mut session = method
        .session()
        .no_cache()
        .build()
        .expect("default session config is infallible");
    let mut best = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        let out = session.run(head).expect("uncached run cannot fail");
        let dt = t0.elapsed().as_secs_f64();
        crate::util::timer::black_box(out.outputs[0].out.data[0]);
        best = best.min(dt);
    }
    best
}

/// GQA-style multi-head batch: `heads` heads in groups of `group_size`;
/// heads within a group share Q/K (one seed per group — the query-head
/// group attends one KV pattern) and differ in V, so plan reuse within a
/// group is exact while outputs stay distinct.
pub fn gqa_batch(
    profile: &WorkloadProfile,
    n: usize,
    heads: usize,
    group_size: usize,
    seed: u64,
) -> BatchInput {
    assert!(heads >= 1 && group_size >= 1);
    let mut out = Vec::with_capacity(heads);
    for h in 0..heads {
        let g = h / group_size;
        let wl = generate(profile, n, seed.wrapping_add(g as u64));
        let mut head = wl.head;
        if h % group_size != 0 {
            // Re-randomize V only: same K/Q ⇒ same plan, different output.
            let mut rng = crate::util::rng::Pcg64::seeded(
                seed.wrapping_mul(31).wrapping_add(h as u64),
            );
            for x in head.v.data.iter_mut() {
                *x = rng.normal();
            }
        }
        out.push(head);
    }
    BatchInput::new(out)
}

/// Plan-cache keys matching [`gqa_batch`]'s grouping.
pub fn gqa_keys(layer: u32, heads: usize, group_size: usize) -> Vec<PlanKey> {
    (0..heads).map(|h| PlanKey::new(layer, (h / group_size) as u32)).collect()
}

/// One batched data point: latency for the whole `[H, N, d]` batch through
/// the head-parallel path plus the batch's plan-cache interaction.
#[derive(Clone, Debug)]
pub struct BatchEvalRow {
    pub method: String,
    pub n: usize,
    pub heads: usize,
    pub latency_s: f64,
    pub hit_rate: f64,
    pub sparsity: f64,
}

/// Run a method over a multi-head batch through a fresh session whose
/// plan cache is keyed by [`gqa_keys`]; reports wallclock, cache hit rate
/// and mean sparsity.
pub fn evaluate_batch(
    method: &Method,
    batch: &BatchInput,
    layer: u32,
    group_size: usize,
) -> BatchEvalRow {
    let keys = gqa_keys(layer, batch.h(), group_size);
    let mut session = method
        .session()
        .keys(keys)
        .build()
        .expect("default session config is infallible");
    let t0 = Instant::now();
    let out = session.run_batch(batch).expect("cached batch cannot fail");
    let latency_s = t0.elapsed().as_secs_f64();
    let sparsity = out
        .plans
        .iter()
        .map(|p| p.coverage().sparsity())
        .sum::<f64>()
        / out.plans.len() as f64;
    BatchEvalRow {
        method: method.name().to_string(),
        n: batch.n(),
        heads: batch.h(),
        latency_s,
        hit_rate: out.hit_rate(),
        sparsity,
    }
}

/// Default workload for experiments.
pub fn default_profile() -> WorkloadProfile {
    WorkloadProfile::llama_like()
}

/// Fixed-width table printing.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
    println!("{}", "─".repeat(total));
    for row in rows {
        line(row);
    }
}

/// CSV emission: header + rows.
pub fn to_csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = headers.join(",");
    s.push('\n');
    for row in rows {
        s.push_str(&row.join(","));
        s.push('\n');
    }
    s
}

/// Machine-readable bench report: run metadata + per-measurement rows +
/// run-level summary fields. CI bench gates diff these across modes
/// (e.g. sequential vs pipelined `fig2_speedup`), so keys must stay
/// stable and latency/overlap fields must be numbers, not formatted
/// strings.
pub fn bench_report_json(
    experiment: &str,
    mode: &str,
    seed: u64,
    rows: Vec<Json>,
    summary: Vec<(&str, Json)>,
) -> Json {
    let mut pairs = vec![
        ("experiment", Json::str(experiment)),
        ("mode", Json::str(mode)),
        ("seed", Json::num(seed as f64)),
        ("threads", Json::num(crate::util::threadpool::num_threads() as f64)),
        ("rows", Json::Arr(rows)),
    ];
    pairs.extend(summary);
    Json::obj(pairs)
}

/// Write a pretty-printed JSON report under `reports/`.
pub fn write_json_report(name: &str, report: &Json) -> std::io::Result<std::path::PathBuf> {
    let mut contents = report.to_string_pretty();
    contents.push('\n');
    crate::util::write_report(name, &contents)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::tensor::Mat;

    #[test]
    fn paper_methods_scale_with_length() {
        let tile = TileConfig::new(128, 128);
        let m = paper_methods(131072, tile, 12.0);
        assert_eq!(m.len(), 5);
        match &m[1] {
            Method::Streaming(c) => {
                assert_eq!(c.global_tokens, 1024);
                assert_eq!(c.local_tokens, 8192);
            }
            _ => panic!(),
        }
        let m4k = paper_methods(4096, tile, 12.0);
        match &m4k[1] {
            Method::Streaming(c) => {
                assert_eq!(c.global_tokens, 128, "floored at one block");
                assert_eq!(c.local_tokens, 256);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn evaluate_full_has_recall_one() {
        let mut rng = Pcg64::seeded(1);
        let d = 32;
        let n = 256;
        let h = HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        );
        let tile = TileConfig::new(64, 64);
        let row = evaluate(&h, &Method::Full(tile), tile);
        assert!((row.recall - 1.0).abs() < 1e-9);
        assert_eq!(row.sparsity, 0.0);
        assert!(row.output_rel_err < 1e-5);
    }

    #[test]
    fn csv_shape() {
        let csv = to_csv(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(csv, "a,b\n1,2\n");
    }

    /// The bench JSON keys the CI gate reads must round-trip as numbers.
    #[test]
    fn bench_report_json_shape() {
        let row = Json::obj(vec![
            ("method", Json::str("anchor")),
            ("latency_ms", Json::num(1.5)),
            ("overlap_efficiency", Json::num(0.5)),
        ]);
        let rep = bench_report_json(
            "fig2_speedup",
            "pipelined",
            42,
            vec![row],
            vec![
                ("total_latency_ms", Json::num(1.5)),
                ("max_overlap_efficiency", Json::num(0.5)),
            ],
        );
        let parsed = Json::parse(&rep.to_string()).unwrap();
        assert_eq!(parsed.get("experiment").as_str(), Some("fig2_speedup"));
        assert_eq!(parsed.get("mode").as_str(), Some("pipelined"));
        assert_eq!(parsed.get("seed").as_usize(), Some(42));
        assert!(parsed.get("threads").as_usize().unwrap() >= 1);
        assert_eq!(parsed.get("rows").idx(0).get("method").as_str(), Some("anchor"));
        assert_eq!(parsed.get("total_latency_ms").as_f64(), Some(1.5));
        assert_eq!(parsed.get("max_overlap_efficiency").as_f64(), Some(0.5));
    }

    #[test]
    fn gqa_batch_shares_qk_within_groups() {
        let p = WorkloadProfile::llama_like();
        let batch = gqa_batch(&p, 512, 4, 2, 9);
        // Group 0 = heads 0,1: same Q/K, different V.
        assert_eq!(batch.heads[0].q.data, batch.heads[1].q.data);
        assert_eq!(batch.heads[0].k.data, batch.heads[1].k.data);
        assert_ne!(batch.heads[0].v.data, batch.heads[1].v.data);
        // Across groups everything differs.
        assert_ne!(batch.heads[0].q.data, batch.heads[2].q.data);
    }

    #[test]
    fn evaluate_batch_reports_hit_rate() {
        let p = WorkloadProfile::llama_like();
        let n = 1024;
        let tile = TileConfig::new(128, 128);
        let batch = gqa_batch(&p, n, 4, 2, 11);
        let m = Method::Anchor(AnchorConfig {
            tile,
            theta: 12.0,
            step: scaled_step(n, tile),
            init_blocks: 1,
            use_anchor: true,
        });
        let row = evaluate_batch(&m, &batch, 0, 2);
        assert_eq!(row.heads, 4);
        // 2 groups of 2 identical-Q/K heads ⇒ up to 50% hits (the benign
        // concurrent-miss race can lower it, never raise it).
        assert!(row.hit_rate <= 0.5 + 1e-9, "hit rate {}", row.hit_rate);
        assert!((0.0..=1.0).contains(&row.sparsity));
        assert!(row.latency_s > 0.0);
    }
}

//! Figure 2 — attention speedup vs FlashAttention across context lengths,
//! plus the A100 cost-model projection to the paper's 128k regime.
//!
//! Paper headline: ≈4.6× over Full-attn and ≈1.44× over FlexPrefill at
//! 128k. The engine measures relative wallclock at N ≤ 32k over a
//! **multi-head GQA batch** executed head-parallel through the plan
//! pipeline, reporting the plan-cache hit rate alongside latency (heads of
//! one group share Q/K, so identification work is reused — §3.2). The cost
//! model translates plan-coverage sparsity to A100-time at 64k/128k; no
//! attention is executed for the projection.

use super::common::{self, ExpScale};
use crate::attention::plan::PlanCache;
use crate::simulator::a100::A100Model;
use crate::util::{fmt_len, write_report};
use crate::workload::qkv::generate;

/// Heads per batch and heads per plan-sharing group for the measured path.
const BATCH_HEADS: usize = 4;
const GROUP_SIZE: usize = 2;

pub fn run(scale: ExpScale, seed: u64) -> Vec<Vec<String>> {
    let tile = scale.tile();
    let profile = common::default_profile();
    let a100 = A100Model::default();
    let iters = if scale == ExpScale::Quick { 1 } else { 2 };

    println!(
        "\n=== Fig. 2: speedup over FlashAttention \
         (batched [{BATCH_HEADS}, N, d] wallclock, head-parallel) ==="
    );
    let mut rows = Vec::new();
    for n in scale.lengths() {
        let batch = common::gqa_batch(&profile, n, BATCH_HEADS, GROUP_SIZE, seed);
        let keys = common::gqa_keys(0, BATCH_HEADS, GROUP_SIZE);
        let methods = common::paper_methods(n, tile, 12.0);
        let measure = |m: &crate::attention::Method| -> (f64, f64) {
            let mut best = f64::INFINITY;
            let mut hit_rate = 0.0;
            for _ in 0..iters.max(1) {
                let cache = PlanCache::new();
                let t0 = std::time::Instant::now();
                let out = m.run_batch_cached(&batch, &cache, &keys);
                let dt = t0.elapsed().as_secs_f64();
                crate::util::timer::black_box(out.outputs[0].out.data[0]);
                best = best.min(dt);
                hit_rate = out.hit_rate();
            }
            (best, hit_rate)
        };
        let (t_full, _) = measure(&methods[0]);
        for m in &methods[1..] {
            let (t, hit_rate) = measure(m);
            rows.push(vec![
                fmt_len(n),
                m.name().to_string(),
                format!("{:.2}", t * 1e3),
                format!("{:.2}x", t_full / t),
                crate::util::pct(hit_rate),
            ]);
        }
        rows.push(vec![
            fmt_len(n),
            "full-attn".into(),
            format!("{:.2}", t_full * 1e3),
            "1.00x".into(),
            crate::util::pct(0.0),
        ]);
    }
    common::print_table(
        &["length", "method", "latency_ms", "speedup", "plan_hits"],
        &rows,
    );

    // Cost-model projection at the paper's lengths. Raw sparsity does NOT
    // extrapolate (the always-computed anchor window is a large fraction
    // of short contexts and a vanishing one of 128k), so we measure the
    // *candidate-region keep rate* at the reference length and rebuild
    // coverage at the target length: covered(n) = anchor(n) + keep·rest(n).
    // Sparsity is read from each method's SparsePlan — identification only,
    // no attention executed.
    println!("\n--- A100 cost-model projection (paper regime) ---");
    let n_ref = *scale.lengths().last().unwrap();
    let wl = generate(&profile, n_ref, seed);
    let mut proj_rows = Vec::new();
    let methods = common::paper_methods(n_ref, tile, 12.0);
    // Anchor-region fraction at block granularity: init block + mean
    // window of (step/2 + 1) query blocks over an average causal span n/2.
    let anchor_frac = |n: usize| -> f64 {
        let step = common::scaled_step(n, tile) as f64;
        let anchor_tokens = (step / 2.0 + 1.0) * tile.b_q as f64 + tile.b_kv as f64;
        (anchor_tokens / (n as f64 / 2.0)).min(1.0)
    };
    for n in [65536usize, 131072] {
        let d = 128;
        let t_full = a100.full_attention_time(n, d);
        for m in &methods[1..] {
            let plan = m.plan(&wl.head);
            let measured_keep = 1.0 - plan.sparsity();
            // Separate the anchored share from the identified share at the
            // reference length, then recompose at the target length.
            let af_ref = anchor_frac(n_ref);
            let cand_keep = ((measured_keep - af_ref) / (1.0 - af_ref)).clamp(0.0, 1.0);
            let af = anchor_frac(n);
            let keep = match m {
                crate::attention::Method::Anchor(_) => af + cand_keep * (1.0 - af),
                // Fixed-budget baselines keep a length-scaled token budget,
                // i.e. a constant fraction: reuse measured keep directly.
                _ => measured_keep,
            };
            let sparsity = 1.0 - keep;
            let ident = crate::attention::CostTally {
                flops: 2 * ((n / tile.b_q) * n * d) as u64,
                kv_bytes: (n * d * 2) as u64,
                ident_scores: ((n / tile.b_q) * n) as u64,
            };
            let entries = ((n as f64) * (n as f64) / 2.0 * keep) as u64;
            let sparse = crate::attention::CostTally {
                flops: 4 * entries * d as u64,
                kv_bytes: (2.0 * keep * (n * d * 2) as f64) as u64,
                ident_scores: 0,
            };
            let t = match m {
                crate::attention::Method::Anchor(_) => {
                    a100.phase_time(&ident) + a100.gather_phase_time(&sparse)
                }
                crate::attention::Method::Streaming(_) => a100.phase_time(&sparse),
                _ => a100.phase_time(&ident) + a100.phase_time(&sparse),
            };
            proj_rows.push(vec![
                fmt_len(n),
                m.name().to_string(),
                format!("{:.2}", t * 1e3),
                format!("{:.2}x", t_full / t),
                crate::util::pct(sparsity),
            ]);
        }
        proj_rows.push(vec![fmt_len(n), "full-attn".into(), format!("{:.2}", t_full * 1e3), "1.00x".into(), "0.0%".into()]);
    }
    common::print_table(
        &["length", "method", "a100_ms", "speedup", "proj_sparsity"],
        &proj_rows,
    );

    let mut all = rows.clone();
    all.extend(proj_rows);
    let csv = common::to_csv(
        &["length", "method", "latency_ms", "speedup", "plan_hits"],
        &rows,
    );
    let _ = write_report("fig2_speedup.csv", &csv);
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_produces_all_methods() {
        let rows = run(ExpScale::Quick, 7);
        // 3 lengths × 5 methods + 2 projection lengths × 5 methods.
        assert!(rows.len() >= 3 * 5);
        assert!(rows.iter().any(|r| r[1] == "anchor"));
        assert!(rows.iter().any(|r| r[1] == "full-attn"));
        // The measured rows carry a plan-cache hit-rate column; with
        // GROUP_SIZE = 2 the sparse methods replan once per group, so some
        // row must report a nonzero hit rate.
        assert!(
            rows.iter().any(|r| r.len() == 5 && r[4] != "0.0%" && r[4].ends_with('%')),
            "no plan-cache hits reported"
        );
    }
}

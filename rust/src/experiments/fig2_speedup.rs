//! Figure 2 — attention speedup vs FlashAttention across context lengths,
//! plus the A100 cost-model projection to the paper's 128k regime.
//!
//! Paper headline: ≈4.6× over Full-attn and ≈1.44× over FlexPrefill at
//! 128k. The engine measures relative wallclock at N ≤ 32k over a
//! **multi-head GQA batch** executed head-parallel through the plan
//! pipeline, reporting the plan-cache hit rate alongside latency (heads of
//! one group share Q/K, so identification work is reused — §3.2). With
//! [`Fig2Options::pipeline`] the batch runs through the async plan
//! pipeline instead — identification of head *i+1* overlaps execution of
//! head *i* — and each row additionally reports **overlap efficiency**
//! (identification wall time hidden behind execution / total). Both modes
//! emit `reports/fig2_speedup_<mode>.json`, which the CI bench gate diffs
//! (pipelined latency must not regress vs sequential, overlap must be
//! nonzero). [`Fig2Options::shards`] measures the same grid through
//! head-group shard workers (`ShardedSession`, DESIGN.md §12) — every row
//! names its shard count and CI gates the 2-shard vs 1-shard total under
//! `shard_grid`. The cost model translates plan-coverage sparsity to
//! A100-time at 64k/128k; no attention is executed for the projection.

use super::common::{self, ExpScale};
use crate::attention::exec::ExecutorKind;
use crate::attention::pipeline::PipelineStats;
use crate::attention::shard::ShardedSession;
use crate::attention::Method;
use crate::simulator::a100::A100Model;
use crate::util::json::Json;
use crate::util::{fmt_len, write_report};
use crate::workload::qkv::generate;

/// Heads per batch and heads per plan-sharing group for the measured path.
const BATCH_HEADS: usize = 4;
const GROUP_SIZE: usize = 2;

/// Measurement-mode knobs (CLI: `--pipeline`, `--iters`, `--lengths`,
/// `--executor`, `--plan-store`, `--step`).
#[derive(Clone, Debug)]
pub struct Fig2Options {
    /// Run the batch through the async plan pipeline instead of the
    /// sequential plan-then-execute path.
    pub pipeline: bool,
    /// Override the per-point repeat count (best-of-N; default 1 quick /
    /// 2 full). CI uses 3 to stabilize the regression gate.
    pub iters: Option<usize>,
    /// Override the length grid (default [`ExpScale::lengths`]).
    pub lengths: Option<Vec<usize>>,
    /// Executor backends to measure; every row names its backend so
    /// backend regressions are attributable (CI runs `--executor both`).
    pub executors: Vec<ExecutorKind>,
    /// Runtime-manifest path for plan persistence: sessions warm their
    /// plan cache from it and flush fresh plans back, so a re-run reports
    /// warm-start identification cost (the CI cold/warm ratio).
    pub plan_store: Option<String>,
    /// Pin the anchor identification step (re-measure grid: 8, 16);
    /// `None` keeps the length-scaled default.
    pub step: Option<usize>,
    /// Head-group shard-worker counts to measure (`--shards 1,2,4`,
    /// DESIGN.md §12). Every row names its shard count; `[1]` is the
    /// unsharded session (bitwise-identical output). CI records the grid
    /// under `shard_grid` in `BENCH_fig2.json` and gates the 2-shard vs
    /// 1-shard total latency.
    pub shards: Vec<usize>,
    /// Process shard-worker counts to measure over the coordinate-only
    /// wire (`--wire-shards 1,2`, DESIGN.md §14): each point runs the
    /// batch through spawned `anchor-attn worker` processes AND an
    /// in-thread session with the same shard count, gates the two bitwise
    /// (outputs, plans, cache accounting), and reports both latencies.
    /// Empty = skip the wire grid. Only meaningful when invoked from the
    /// `anchor-attn` binary (spawn mode re-executes the current
    /// executable as a worker).
    pub wire_shards: Vec<usize>,
}

impl Default for Fig2Options {
    fn default() -> Self {
        Self {
            pipeline: false,
            iters: None,
            lengths: None,
            executors: vec![ExecutorKind::Cpu],
            plan_store: None,
            step: None,
            shards: vec![1],
            wire_shards: vec![],
        }
    }
}

pub fn run(scale: ExpScale, seed: u64) -> Vec<Vec<String>> {
    run_with(scale, seed, &Fig2Options::default())
}

pub fn run_with(scale: ExpScale, seed: u64, opts: &Fig2Options) -> Vec<Vec<String>> {
    let tile = scale.tile();
    let profile = common::default_profile();
    let a100 = A100Model::default();
    let iters = opts.iters.unwrap_or(if scale == ExpScale::Quick { 1 } else { 2 });
    let lengths = opts.lengths.clone().unwrap_or_else(|| scale.lengths());
    let executors = if opts.executors.is_empty() {
        vec![ExecutorKind::Cpu]
    } else {
        opts.executors.clone()
    };
    // Shard grid, zeros clamped (the CLI rejects them up front).
    let shard_counts: Vec<usize> = if opts.shards.is_empty() {
        vec![1]
    } else {
        opts.shards.iter().map(|&s| s.max(1)).collect()
    };
    let mode = if opts.pipeline { "pipelined" } else { "sequential" };
    // Step 0 cannot be measured; normalize once so the report's
    // `step_override` and the file tag name the step actually run (the
    // CLI rejects 0 up front).
    let step = opts.step.map(|s| s.max(1));
    // Report filenames carry every grid-changing knob so the CI bench can
    // run the base grid, the warm-start pair, the step grid and the shard
    // grid in one checkout without clobbering
    // (`fig2_speedup_sequential_step8.json`,
    // `fig2_speedup_sequential_store.json`,
    // `fig2_speedup_sequential_shards.json`, ...).
    let file_tag = {
        let mut t = mode.to_string();
        if let Some(s) = step {
            t.push_str(&format!("_step{s}"));
        }
        if opts.plan_store.is_some() {
            t.push_str("_store");
        }
        if shard_counts != [1] {
            t.push_str("_shards");
        }
        if !opts.wire_shards.is_empty() {
            t.push_str("_wire");
        }
        t
    };

    println!(
        "\n=== Fig. 2: speedup over FlashAttention \
         (batched [{BATCH_HEADS}, N, d] wallclock, head-parallel, {mode}) ==="
    );
    struct Measured {
        t: f64,
        hit_rate: f64,
        stats: PipelineStats,
        ident_scores: u64,
        seeded: u64,
    }
    let mut rows = Vec::new();
    let mut json_rows: Vec<Json> = Vec::new();
    let mut total_latency_ms = 0.0f64;
    let mut max_overlap = 0.0f64;
    let mut total_ident_paid = 0u64;
    let mut total_seeded = 0u64;
    for &n in &lengths {
        let batch = common::gqa_batch(&profile, n, BATCH_HEADS, GROUP_SIZE, seed);
        let keys = common::gqa_keys(0, BATCH_HEADS, GROUP_SIZE);
        let methods = common::paper_methods_with_step(n, tile, 12.0, step);
        for &kind in &executors {
            for &shards in &shard_counts {
                // One sharded session per repeat (shards = 1 is the
                // unsharded session, bitwise-identical), configured once
                // through the builder; with a plan store every session
                // warms from disk, so a cold process pays identification
                // exactly once per (method, n) and a warmed process pays
                // none (the CI cold/warm column).
                let mk_session = |m: &Method| -> ShardedSession {
                    let mut b = m.sharded_session(shards).executor(kind).keys(keys.clone());
                    if opts.pipeline {
                        b = b.pipelined(true);
                    }
                    if let Some(p) = &opts.plan_store {
                        b = b.persist(p).model(&format!("llama-like/{}", m.name()));
                    }
                    b.build().expect("fig2 session configuration rejected")
                };
                // Best-of-`iters` wallclock for one method over the whole
                // batch on this backend; hit rate / overlap / ident
                // accounting come from the fastest repeat.
                let measure = |m: &Method| -> Measured {
                    let mut best = Measured {
                        t: f64::INFINITY,
                        hit_rate: 0.0,
                        stats: PipelineStats::default(),
                        ident_scores: 0,
                        seeded: 0,
                    };
                    // Sessions stay alive until all repeats finish:
                    // dropping one mid-loop would flush its plans to the
                    // store file and self-warm the later "cold" repeats.
                    let mut sessions: Vec<ShardedSession> = Vec::new();
                    for _ in 0..iters.max(1) {
                        let mut session = mk_session(m);
                        let t0 = std::time::Instant::now();
                        let out = session.run_batch(&batch).expect("fig2 batch failed");
                        let dt = t0.elapsed().as_secs_f64();
                        crate::util::timer::black_box(out.outputs[0].out.data[0]);
                        if dt < best.t {
                            best = Measured {
                                t: dt,
                                hit_rate: out.hit_rate(),
                                stats: out.pipeline.unwrap_or_default(),
                                ident_scores: out.ident_cost_paid.ident_scores,
                                seeded: session.store_seeded(),
                            };
                        }
                        sessions.push(session);
                    }
                    // Populate the store for the next process only after
                    // every repeat measured (drop would flush too;
                    // explicit so flush errors surface here).
                    if opts.plan_store.is_some() {
                        if let Some(s) = sessions.last_mut() {
                            s.flush().expect("plan store flush failed");
                        }
                    }
                    best
                };
                let full_m = measure(&methods[0]);
                let mut record = |name: &str, m: &Measured, speedup: f64| {
                    let overlap = m.stats.overlap_efficiency();
                    total_latency_ms += m.t * 1e3;
                    max_overlap = max_overlap.max(overlap);
                    total_ident_paid += m.ident_scores;
                    total_seeded += m.seeded;
                    rows.push(vec![
                        fmt_len(n),
                        name.to_string(),
                        kind.name().to_string(),
                        shards.to_string(),
                        format!("{:.2}", m.t * 1e3),
                        format!("{speedup:.2}x"),
                        crate::util::pct(m.hit_rate),
                        crate::util::pct(overlap),
                        m.ident_scores.to_string(),
                    ]);
                    json_rows.push(Json::obj(vec![
                        ("length", Json::num(n as f64)),
                        ("method", Json::str(name)),
                        ("executor", Json::str(kind.name())),
                        ("shards", Json::num(shards as f64)),
                        ("latency_ms", Json::num(m.t * 1e3)),
                        ("speedup", Json::num(speedup)),
                        ("plan_hit_rate", Json::num(m.hit_rate)),
                        ("overlap_efficiency", Json::num(overlap)),
                        ("ident_total_ms", Json::num(m.stats.ident_total_s * 1e3)),
                        ("ident_hidden_ms", Json::num(m.stats.ident_hidden_s * 1e3)),
                        ("stall_ms", Json::num(m.stats.stall_s * 1e3)),
                        ("ident_paid_scores", Json::num(m.ident_scores as f64)),
                    ]));
                };
                for m in &methods[1..] {
                    let measured = measure(m);
                    let speedup = full_m.t / measured.t;
                    record(m.name(), &measured, speedup);
                }
                record("full-attn", &full_m, 1.0);
            }
        }
    }
    common::print_table(
        &[
            "length", "method", "executor", "shards", "latency_ms", "speedup", "plan_hits",
            "overlap", "ident",
        ],
        &rows,
    );

    // Wire grid: the same measurement through spawned process workers
    // speaking the coordinate-only wire (DESIGN.md §14), each point gated
    // bitwise against an in-thread session with the same shard count —
    // transport must never change results, costs, plans, or cache
    // accounting.
    let mut wire_json: Vec<Json> = Vec::new();
    if !opts.wire_shards.is_empty() {
        println!(
            "\n--- wire grid: process shard workers vs threads \
             (coordinate-only wire, bitwise-gated) ---"
        );
        let kind = executors[0];
        let mut wrows = Vec::new();
        for &n in &lengths {
            let batch = common::gqa_batch(&profile, n, BATCH_HEADS, GROUP_SIZE, seed);
            let keys = common::gqa_keys(0, BATCH_HEADS, GROUP_SIZE);
            let methods = common::paper_methods_with_step(n, tile, 12.0, step);
            for &ws in &opts.wire_shards {
                for m in &methods {
                    let mk = |remote: bool| -> ShardedSession {
                        let mut b = m.sharded_session(ws).executor(kind).keys(keys.clone());
                        if remote {
                            b = b.remote(crate::wire::RemoteSpec::Spawn { program: None });
                        }
                        b.build().expect("fig2 wire session rejected")
                    };
                    let mut threads = mk(false);
                    let t0 = std::time::Instant::now();
                    let base = threads.run_batch(&batch).expect("fig2 thread batch failed");
                    let t_threads = t0.elapsed().as_secs_f64();
                    let mut remote = mk(true);
                    let t0 = std::time::Instant::now();
                    let wired = remote.run_batch(&batch).expect("fig2 wire batch failed");
                    let t_wire = t0.elapsed().as_secs_f64();
                    let ctx = format!("{} n={n} wire_shards={ws}", m.name());
                    assert_eq!(
                        base.outputs.len(),
                        wired.outputs.len(),
                        "wire head count diverged ({ctx})"
                    );
                    for (a, b) in base.outputs.iter().zip(&wired.outputs) {
                        assert_eq!(a.out.data, b.out.data, "wire output diverged ({ctx})");
                        assert_eq!(a.cost, b.cost, "wire cost diverged ({ctx})");
                    }
                    assert_eq!(base.plans.len(), wired.plans.len(), "plan count ({ctx})");
                    for (a, b) in base.plans.iter().zip(&wired.plans) {
                        assert_eq!(**a, **b, "wire plan coordinates diverged ({ctx})");
                    }
                    assert_eq!(
                        (base.cache_hits, base.cache_misses),
                        (wired.cache_hits, wired.cache_misses),
                        "wire cache accounting diverged ({ctx})"
                    );
                    wrows.push(vec![
                        fmt_len(n),
                        m.name().to_string(),
                        ws.to_string(),
                        format!("{:.2}", t_threads * 1e3),
                        format!("{:.2}", t_wire * 1e3),
                        "bitwise".to_string(),
                    ]);
                    wire_json.push(Json::obj(vec![
                        ("length", Json::num(n as f64)),
                        ("method", Json::str(m.name())),
                        ("wire_shards", Json::num(ws as f64)),
                        ("threads_ms", Json::num(t_threads * 1e3)),
                        ("wire_ms", Json::num(t_wire * 1e3)),
                        ("parity", Json::Bool(true)),
                    ]));
                }
            }
        }
        common::print_table(
            &["length", "method", "wire_shards", "threads_ms", "wire_ms", "parity"],
            &wrows,
        );
    }

    // Cost-model projection at the paper's lengths. Raw sparsity does NOT
    // extrapolate (the always-computed anchor window is a large fraction
    // of short contexts and a vanishing one of 128k), so we measure the
    // *candidate-region keep rate* at the reference length and rebuild
    // coverage at the target length: covered(n) = anchor(n) + keep·rest(n).
    // Sparsity is read from each method's SparsePlan — identification only,
    // no attention executed.
    println!("\n--- A100 cost-model projection (paper regime) ---");
    let n_ref = *lengths.last().unwrap();
    let wl = generate(&profile, n_ref, seed);
    let mut proj_rows = Vec::new();
    let methods = common::paper_methods_with_step(n_ref, tile, 12.0, step);
    // Anchor-region fraction at block granularity: init block + mean
    // window of (step/2 + 1) query blocks over an average causal span n/2.
    let anchor_frac = |n: usize| -> f64 {
        let step = common::scaled_step(n, tile) as f64;
        let anchor_tokens = (step / 2.0 + 1.0) * tile.b_q as f64 + tile.b_kv as f64;
        (anchor_tokens / (n as f64 / 2.0)).min(1.0)
    };
    for n in [65536usize, 131072] {
        let d = 128;
        let t_full = a100.full_attention_time(n, d);
        for m in &methods[1..] {
            let plan = m.plan(&wl.head);
            let measured_keep = 1.0 - plan.sparsity();
            // Separate the anchored share from the identified share at the
            // reference length, then recompose at the target length.
            let af_ref = anchor_frac(n_ref);
            let cand_keep = ((measured_keep - af_ref) / (1.0 - af_ref)).clamp(0.0, 1.0);
            let af = anchor_frac(n);
            let keep = match m {
                crate::attention::Method::Anchor(_) => af + cand_keep * (1.0 - af),
                // Fixed-budget baselines keep a length-scaled token budget,
                // i.e. a constant fraction: reuse measured keep directly.
                _ => measured_keep,
            };
            let sparsity = 1.0 - keep;
            let ident = crate::attention::CostTally {
                flops: 2 * ((n / tile.b_q) * n * d) as u64,
                kv_bytes: (n * d * 2) as u64,
                ident_scores: ((n / tile.b_q) * n) as u64,
            };
            let entries = ((n as f64) * (n as f64) / 2.0 * keep) as u64;
            let sparse = crate::attention::CostTally {
                flops: 4 * entries * d as u64,
                kv_bytes: (2.0 * keep * (n * d * 2) as f64) as u64,
                ident_scores: 0,
            };
            let t = match m {
                crate::attention::Method::Anchor(_) => {
                    a100.phase_time(&ident) + a100.gather_phase_time(&sparse)
                }
                crate::attention::Method::Streaming(_) => a100.phase_time(&sparse),
                _ => a100.phase_time(&ident) + a100.phase_time(&sparse),
            };
            proj_rows.push(vec![
                fmt_len(n),
                m.name().to_string(),
                format!("{:.2}", t * 1e3),
                format!("{:.2}x", t_full / t),
                crate::util::pct(sparsity),
            ]);
        }
        proj_rows.push(vec![fmt_len(n), "full-attn".into(), format!("{:.2}", t_full * 1e3), "1.00x".into(), "0.0%".into()]);
    }
    common::print_table(
        &["length", "method", "a100_ms", "speedup", "proj_sparsity"],
        &proj_rows,
    );

    let report = common::bench_report_json(
        "fig2_speedup",
        mode,
        seed,
        json_rows,
        vec![
            ("heads", Json::num(BATCH_HEADS as f64)),
            ("group_size", Json::num(GROUP_SIZE as f64)),
            ("lengths", Json::arr(lengths.iter().map(|&n| Json::num(n as f64)))),
            ("iters", Json::num(iters as f64)),
            ("executors", Json::arr(executors.iter().map(|k| Json::str(k.name())))),
            ("shard_counts", Json::arr(shard_counts.iter().map(|&s| Json::num(s as f64)))),
            ("total_latency_ms", Json::num(total_latency_ms)),
            ("max_overlap_efficiency", Json::num(max_overlap)),
            (
                "plan_store",
                match &opts.plan_store {
                    Some(p) => Json::str(p),
                    None => Json::Null,
                },
            ),
            (
                "step_override",
                match step {
                    Some(s) => Json::num(s as f64),
                    None => Json::Null,
                },
            ),
            // Identification actually paid (fresh keys only): the CI
            // warm-start gate divides a cold run's total by a warm one's.
            ("ident_paid_scores_total", Json::num(total_ident_paid as f64)),
            ("store_seeded_plans", Json::num(total_seeded as f64)),
            // Process-worker grid (DESIGN.md §14): every row already
            // passed the bitwise gate against the in-thread session.
            (
                "wire_shard_counts",
                Json::arr(opts.wire_shards.iter().map(|&s| Json::num(s as f64))),
            ),
            ("wire_grid", Json::arr(wire_json)),
        ],
    );
    // Tag-specific filename: the CI bench job runs both modes plus the
    // warm-start and step grids in one checkout and diffs the files.
    let _ = common::write_json_report(&format!("fig2_speedup_{file_tag}.json"), &report);

    let mut all = rows.clone();
    all.extend(proj_rows);
    let csv = common::to_csv(
        &[
            "length", "method", "executor", "shards", "latency_ms", "speedup", "plan_hits",
            "overlap", "ident",
        ],
        &rows,
    );
    // Tag-suffixed like the JSON so successive grid runs in one checkout
    // keep every measurement set.
    let _ = write_report(&format!("fig2_speedup_{file_tag}.csv"), &csv);
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// The tests below write (and some read back) the shared
    /// `reports/fig2_speedup_<mode>.json` files; serialize them so a
    /// concurrent run never reads another test's report.
    static REPORT_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn quick_run_produces_all_methods() {
        let _g = REPORT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let rows = run(ExpScale::Quick, 7);
        // 3 lengths × 5 methods + 2 projection lengths × 5 methods.
        assert!(rows.len() >= 3 * 5);
        assert!(rows.iter().any(|r| r[1] == "anchor"));
        assert!(rows.iter().any(|r| r[1] == "full-attn"));
        // Measured rows name their executor backend (default grid: cpu)
        // and shard count (default grid: unsharded).
        assert!(rows.iter().any(|r| r.len() == 9 && r[2] == "cpu" && r[3] == "1"));
        // The measured rows carry a plan-cache hit-rate column; with
        // GROUP_SIZE = 2 the sparse methods replan once per group, so some
        // row must report a nonzero hit rate.
        assert!(
            rows.iter().any(|r| r.len() == 9 && r[6] != "0.0%" && r[6].ends_with('%')),
            "no plan-cache hits reported"
        );
        // Without a plan store every anchor row pays identification.
        assert!(
            rows.iter().any(|r| r.len() == 9 && r[1] == "anchor" && r[8] != "0"),
            "anchor rows must pay identification when no store warms them"
        );
    }

    /// Pipelined mode produces the full method set, reports an overlap
    /// column, and emits the JSON keys the CI gate reads.
    #[test]
    fn pipelined_mode_reports_overlap() {
        let _g = REPORT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let opts = Fig2Options {
            pipeline: true,
            iters: Some(1),
            lengths: Some(vec![1024, 2048]),
            ..Fig2Options::default()
        };
        let rows = run_with(ExpScale::Quick, 7, &opts);
        assert!(rows.iter().any(|r| r[1] == "anchor"));
        // Measured rows have an overlap column formatted as a percentage.
        assert!(rows.iter().any(|r| r.len() == 9 && r[7].ends_with('%')));
        let report = std::fs::read_to_string("reports/fig2_speedup_pipelined.json").unwrap();
        let j = Json::parse(&report).unwrap();
        assert_eq!(j.get("mode").as_str(), Some("pipelined"));
        assert!(j.get("total_latency_ms").as_f64().unwrap() > 0.0);
        let oe = j.get("max_overlap_efficiency").as_f64().unwrap();
        assert!((0.0..=1.0).contains(&oe), "overlap efficiency {oe}");
        assert!(j.get("rows").idx(0).get("latency_ms").as_f64().is_some());
        assert!(j.get("rows").idx(0).get("overlap_efficiency").as_f64().is_some());
        assert!(j.get("rows").idx(0).get("executor").as_str().is_some());
    }

    /// `--executor both` measures every method on both backends and the
    /// JSON report names each row's backend plus the run's backend grid.
    #[test]
    fn executor_grid_reports_both_backends() {
        let _g = REPORT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let opts = Fig2Options {
            pipeline: false,
            iters: Some(1),
            lengths: Some(vec![1024]),
            executors: vec![ExecutorKind::Cpu, ExecutorKind::Pjrt],
            ..Fig2Options::default()
        };
        let rows = run_with(ExpScale::Quick, 11, &opts);
        let cpu_rows = rows.iter().filter(|r| r.len() == 9 && r[2] == "cpu").count();
        let pjrt_rows = rows.iter().filter(|r| r.len() == 9 && r[2] == "pjrt").count();
        assert_eq!(cpu_rows, 5, "one cpu row per method");
        assert_eq!(pjrt_rows, 5, "one pjrt row per method");
        let report = std::fs::read_to_string("reports/fig2_speedup_sequential.json").unwrap();
        let j = Json::parse(&report).unwrap();
        let execs: Vec<&str> = j
            .get("executors")
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|e| e.as_str())
            .collect();
        assert_eq!(execs, vec!["cpu", "pjrt"]);
        let row_execs: Vec<&str> = j
            .get("rows")
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|r| r.get("executor").as_str())
            .collect();
        assert!(row_execs.contains(&"cpu") && row_execs.contains(&"pjrt"));
    }

    /// With `--plan-store`, a second run warms every plan from the
    /// manifest and pays zero identification — the CI cold/warm gate.
    #[test]
    fn plan_store_warm_start_pays_no_identification() {
        let _g = REPORT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let store = std::env::temp_dir()
            .join(format!("anchor_fig2_store_{}.json", std::process::id()));
        std::fs::write(&store, "{}\n").unwrap();
        let opts = Fig2Options {
            pipeline: false,
            iters: Some(1),
            lengths: Some(vec![1024]),
            executors: vec![ExecutorKind::Cpu],
            plan_store: Some(store.to_string_lossy().into_owned()),
            step: None,
            shards: vec![1],
            wire_shards: vec![],
        };
        run_with(ExpScale::Quick, 7, &opts);
        let cold = std::fs::read_to_string("reports/fig2_speedup_sequential_store.json").unwrap();
        let cold_j = Json::parse(&cold).unwrap();
        let cold_ident = cold_j.get("ident_paid_scores_total").as_f64().unwrap();
        assert!(cold_ident > 0.0, "cold run paid no identification");
        assert_eq!(cold_j.get("store_seeded_plans").as_f64(), Some(0.0));

        run_with(ExpScale::Quick, 7, &opts);
        let warm = std::fs::read_to_string("reports/fig2_speedup_sequential_store.json").unwrap();
        let warm_j = Json::parse(&warm).unwrap();
        assert_eq!(
            warm_j.get("ident_paid_scores_total").as_f64(),
            Some(0.0),
            "warm run must hit the plan store for every key"
        );
        assert!(warm_j.get("store_seeded_plans").as_f64().unwrap() > 0.0);
        assert_eq!(warm_j.get("plan_store").as_str(), Some(opts.plan_store.as_deref().unwrap()));
        let _ = std::fs::remove_file(&store);
    }

    /// `--step` pins the anchor identification step and tags the report
    /// filename (the step-8/16 re-measure grid).
    #[test]
    fn step_override_tags_the_report() {
        let _g = REPORT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let opts = Fig2Options {
            pipeline: false,
            iters: Some(1),
            lengths: Some(vec![1024]),
            executors: vec![ExecutorKind::Cpu],
            plan_store: None,
            step: Some(8),
            shards: vec![1],
            wire_shards: vec![],
        };
        let rows = run_with(ExpScale::Quick, 7, &opts);
        assert!(rows.iter().any(|r| r[1] == "anchor"));
        let path = "reports/fig2_speedup_sequential_step8.json";
        let report = std::fs::read_to_string(path).unwrap();
        let j = Json::parse(&report).unwrap();
        assert_eq!(j.get("step_override").as_usize(), Some(8));
        assert_eq!(j.get("mode").as_str(), Some("sequential"));
    }

    /// `--shards 1,2` measures every method per shard count, rows name
    /// their shard count, and the `_shards`-tagged report carries the
    /// per-row `shards` key plus the run's `shard_counts` grid — the
    /// schema the CI `shard_grid` gate aggregates
    /// (reports/fig2_shard_grid.md).
    #[test]
    fn shard_grid_reports_per_shard_count_rows() {
        let _g = REPORT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let opts = Fig2Options {
            iters: Some(1),
            lengths: Some(vec![1024]),
            shards: vec![1, 2],
            ..Fig2Options::default()
        };
        let rows = run_with(ExpScale::Quick, 7, &opts);
        let one = rows.iter().filter(|r| r.len() == 9 && r[3] == "1").count();
        let two = rows.iter().filter(|r| r.len() == 9 && r[3] == "2").count();
        assert_eq!(one, 5, "one unsharded row per method");
        assert_eq!(two, 5, "one 2-shard row per method");
        let report =
            std::fs::read_to_string("reports/fig2_speedup_sequential_shards.json").unwrap();
        let j = Json::parse(&report).unwrap();
        let counts: Vec<usize> = j
            .get("shard_counts")
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|s| s.as_usize())
            .collect();
        assert_eq!(counts, vec![1, 2]);
        let row_shards: Vec<usize> = j
            .get("rows")
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|r| r.get("shards").as_usize())
            .collect();
        assert!(row_shards.contains(&1) && row_shards.contains(&2));
        // Latency stays a number per shard count (the CI gate sums them).
        assert!(j.get("rows").idx(0).get("latency_ms").as_f64().is_some());
    }
}

//! Figure 4 (+ Appendix Figs. 8-10) — per-(layer, head) recall and
//! sparsity heatmaps for the three identification strategies: top-k,
//! top-cdf, difference-aware. Appendix B's point (input dynamism) is
//! covered by running a second, distinct input and reporting the per-head
//! recall shift.

use super::common::{self, ExpScale};
use crate::attention::metrics;
use crate::attention::strategy::{pooled_scores, select, Granularity, Strategy};
use crate::util::write_report;
use crate::workload::qkv::{generate, HeadKind};
use crate::workload::WorkloadProfile;

pub struct GridSpec {
    pub layers: usize,
    pub heads: usize,
    pub n: usize,
}

impl GridSpec {
    fn for_scale(scale: ExpScale) -> Self {
        match scale {
            ExpScale::Quick => Self { layers: 2, heads: 4, n: 2048 },
            ExpScale::Full => Self { layers: 4, heads: 8, n: 8192 },
        }
    }
}

/// Per-strategy grid outcome.
pub struct GridResult {
    pub strategy: String,
    /// (layer, head) -> (recall, sparsity)
    pub cells: Vec<(usize, usize, f64, f64)>,
}

impl GridResult {
    pub fn mean_recall(&self) -> f64 {
        crate::util::stats::mean(&self.cells.iter().map(|c| c.2).collect::<Vec<_>>())
    }

    pub fn mean_sparsity(&self) -> f64 {
        crate::util::stats::mean(&self.cells.iter().map(|c| c.3).collect::<Vec<_>>())
    }

    pub fn min_recall(&self) -> f64 {
        self.cells.iter().map(|c| c.2).fold(f64::INFINITY, f64::min)
    }
}

fn strategies(n: usize, theta: f32) -> Vec<Strategy> {
    vec![
        Strategy::TopK { k: (n / 8).max(8) },
        Strategy::TopCdf { gamma: 0.95 },
        Strategy::DiffAware { theta },
    ]
}

/// Calibrate a single global θ so difference-aware matches top-cdf's mean
/// sparsity across the grid (the paper's Fig. 4 compares strategies at
/// matched sparsity levels: 93.7 / 96.4 / 94.1 %). Sparsity-only
/// evaluation, so the search is cheap.
fn calibrate_theta(
    heads: &[crate::attention::strategy::PooledScores],
    target_sparsity: f64,
) -> f32 {
    let mean_sparsity = |theta: f32| -> f64 {
        let xs: Vec<f64> = heads
            .iter()
            .map(|ps| select(ps, Strategy::DiffAware { theta }, Granularity::Stripe).sparsity())
            .collect();
        crate::util::stats::mean(&xs)
    };
    let (mut lo, mut hi) = (-10.0f32, 40.0f32); // sparsity falls as θ rises
    for _ in 0..24 {
        let mid = 0.5 * (lo + hi);
        if mean_sparsity(mid) > target_sparsity {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

pub fn run_grid(spec: &GridSpec, profile: &WorkloadProfile, seed: u64) -> Vec<GridResult> {
    let tile = crate::attention::TileConfig::new(128, 128);

    // Generate all heads + pooled scores once.
    let mut cells = Vec::new();
    for layer in 0..spec.layers {
        for head in 0..spec.heads {
            let kind = HeadKind::for_cell(layer, head);
            let p = profile.clone().with_kind(kind);
            let wl = generate(&p, spec.n, seed ^ ((layer * 131 + head) as u64) << 8);
            let ps = pooled_scores(&wl.head, tile);
            cells.push((layer, head, wl, ps));
        }
    }

    // θ calibrated to top-cdf's sparsity level (matched-sparsity compare).
    let pooled: Vec<_> = cells.iter().map(|c| c.3.clone()).collect();
    let cdf_sparsity = crate::util::stats::mean(
        &pooled
            .iter()
            .map(|ps| select(ps, Strategy::TopCdf { gamma: 0.95 }, Granularity::Stripe).sparsity())
            .collect::<Vec<_>>(),
    );
    let theta = calibrate_theta(&pooled, cdf_sparsity);

    let strats = strategies(spec.n, theta);
    let mut results: Vec<GridResult> = strats
        .iter()
        .map(|s| GridResult { strategy: s.name().to_string(), cells: Vec::new() })
        .collect();
    for (layer, head, wl, ps) in &cells {
        for (si, strat) in strats.iter().enumerate() {
            let cov = select(ps, *strat, Granularity::Stripe);
            let rec = metrics::recall(&wl.head, &cov, tile);
            results[si].cells.push((*layer, *head, rec.mean_recall, cov.sparsity()));
        }
    }
    results
}

pub fn run(scale: ExpScale, seed: u64) -> Vec<GridResult> {
    let spec = GridSpec::for_scale(scale);
    let profile = common::default_profile();

    println!(
        "\n=== Fig. 4/8: per-head recall & sparsity heatmaps ({}×{} heads, n={}) ===",
        spec.layers,
        spec.heads,
        crate::util::fmt_len(spec.n)
    );
    let results = run_grid(&spec, &profile, seed);
    let mut rows = Vec::new();
    for r in &results {
        rows.push(vec![
            r.strategy.clone(),
            crate::util::pct(r.mean_recall()),
            crate::util::pct(r.min_recall()),
            crate::util::pct(r.mean_sparsity()),
        ]);
    }
    common::print_table(&["strategy", "mean recall", "min head recall", "mean sparsity"], &rows);
    println!("paper Fig.4 avg sparsity: top-k 93.7%  top-cdf 96.4%  diff-aware 94.1%");
    println!("(shape target: diff-aware ≈ top-cdf recall, both > static top-k worst-head)");

    // Appendix B: second distinct input — per-head recall shift.
    println!("\n--- Fig. 9/10 (App. B): input dynamism, second input ---");
    let results2 = run_grid(&spec, &profile, seed.wrapping_add(0x5eed));
    let mut dyn_rows = Vec::new();
    for (a, b) in results.iter().zip(&results2) {
        let shift: f64 = a
            .cells
            .iter()
            .zip(&b.cells)
            .map(|(x, y)| (x.3 - y.3).abs())
            .sum::<f64>()
            / a.cells.len() as f64;
        dyn_rows.push(vec![
            a.strategy.clone(),
            crate::util::pct(b.mean_recall()),
            crate::util::pct(shift),
        ]);
    }
    common::print_table(&["strategy", "recall (input B)", "mean |sparsity shift|"], &dyn_rows);
    println!("(dynamic strategies — top-cdf, diff-aware — adapt sparsity across inputs)");

    // CSV heatmaps.
    let mut csv = String::from("strategy,layer,head,recall,sparsity\n");
    for r in &results {
        for &(l, h, rec, sp) in &r.cells {
            csv.push_str(&format!("{},{},{},{:.4},{:.4}\n", r.strategy, l, h, rec, sp));
        }
    }
    let _ = write_report("fig4_heatmap.csv", &csv);
    results
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_all_cells_and_strategies() {
        let spec = GridSpec { layers: 2, heads: 2, n: 1024 };
        let res = run_grid(&spec, &common::default_profile(), 3);
        assert_eq!(res.len(), 3);
        for r in &res {
            assert_eq!(r.cells.len(), 4);
            for &(_, _, rec, sp) in &r.cells {
                assert!((0.0..=1.0 + 1e-9).contains(&rec));
                assert!((0.0..=1.0).contains(&sp));
            }
        }
    }

    #[test]
    fn diff_aware_tracks_topcdf_recall() {
        // §2.1.1's claim: difference-aware ≈ top-cdf recall without sorting.
        let spec = GridSpec { layers: 2, heads: 4, n: 2048 };
        let res = run_grid(&spec, &common::default_profile(), 9);
        let topcdf = res.iter().find(|r| r.strategy == "top-cdf").unwrap();
        let diff = res.iter().find(|r| r.strategy == "difference-aware").unwrap();
        assert!(
            (diff.mean_recall() - topcdf.mean_recall()).abs() < 0.15,
            "diff {} vs cdf {}",
            diff.mean_recall(),
            topcdf.mean_recall()
        );
    }
}

//! Figure 5 — distribution of row-maximum attention scores: the fraction
//! landing in the anchor regions (first token ∪ trailing 128-token local
//! window). Paper: ≈99 % (LLaMA), ≈90 % (Qwen) — the observation that
//! justifies computing anchors from those regions only.

use super::common::{self, ExpScale};
use crate::util::write_report;
use crate::workload::qkv::{anchor_dominance_init, generate};
use crate::workload::WorkloadProfile;

pub fn run(scale: ExpScale, seed: u64) -> Vec<Vec<String>> {
    let n = match scale {
        ExpScale::Quick => 2048,
        ExpScale::Full => 8192,
    };
    println!("\n=== Fig. 5: anchor-region max-score dominance (n = {}) ===", crate::util::fmt_len(n));

    let mut rows = Vec::new();
    for (name, profile, paper) in [
        ("llama-like", WorkloadProfile::llama_like(), 0.99),
        ("qwen-like", WorkloadProfile::qwen_like(), 0.90),
    ] {
        let wl = generate(&profile, n, seed);
        let dom = anchor_dominance_init(&wl.head, profile.sink_tokens, 128);
        rows.push(vec![
            name.to_string(),
            crate::util::pct(dom),
            crate::util::pct(paper),
        ]);
    }
    common::print_table(&["profile", "measured dominance", "paper"], &rows);

    let csv = common::to_csv(&["profile", "dominance", "paper"], &rows);
    let _ = write_report("fig5_dominance.csv", &csv);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_matches_paper_ordering() {
        let rows = run(ExpScale::Quick, 21);
        let parse = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
        let llama = parse(&rows[0][1]);
        let qwen = parse(&rows[1][1]);
        assert!(llama > 93.0, "llama-like dominance {llama}");
        assert!(qwen < llama, "qwen {qwen} must trail llama {llama}");
        assert!(qwen > 75.0, "qwen-like dominance {qwen} too low");
    }
}

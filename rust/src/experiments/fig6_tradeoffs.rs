//! Figure 6 — the three trade-off curves:
//!   (a) recall vs sparsity   (hyperparameter sweeps per method)
//!   (b) latency vs recall    (same sweeps, measured per-head latency)
//!   (c) latency vs length    (fixed paper hyperparameters)
//!
//! Shape targets (paper): anchor attains the highest sparsity at matched
//! recall (a), the lowest latency at matched recall (b), and scales best
//! with length despite its higher identification overhead (c).

use super::common::{self, ExpScale};
use crate::attention::anchor::AnchorConfig;
use crate::attention::baselines::block_topk::BlockTopKConfig;
use crate::attention::baselines::flexprefill::FlexPrefillConfig;
use crate::attention::baselines::streaming::StreamingConfig;
use crate::attention::baselines::vertical_slash::VerticalSlashConfig;
use crate::attention::Method;
use crate::util::{fmt_len, write_report};
use crate::workload::qkv::generate;

/// The per-method hyperparameter sweeps of Fig. 6a/6b.
pub fn sweep_methods(n: usize, tile: crate::attention::TileConfig, quick: bool) -> Vec<Method> {
    let thetas: &[f32] = if quick { &[8.0, 11.0, 14.0] } else { &[8.0, 9.0, 10.0, 11.0, 12.0, 13.0, 14.0, 15.0] };
    let gammas: &[f64] = if quick { &[0.7, 0.95] } else { &[0.5, 0.7, 0.8, 0.9, 0.95, 0.99] };
    let fracs: &[f64] = if quick { &[0.05, 0.2] } else { &[0.02, 0.05, 0.1, 0.2, 0.4] };

    let mut methods = Vec::new();
    for &theta in thetas {
        methods.push(Method::Anchor(AnchorConfig {
            tile,
            theta,
            step: super::common::scaled_step(n, tile),
            init_blocks: 1,
            use_anchor: true,
        }));
    }
    for &gamma in gammas {
        methods.push(Method::FlexPrefill(FlexPrefillConfig {
            tile,
            gamma,
            min_budget_tokens: (n / 64).max(tile.b_kv),
        }));
    }
    for &f in fracs {
        let tokens = ((n as f64 * f) as usize).max(tile.b_kv);
        methods.push(Method::VerticalSlash(VerticalSlashConfig {
            tile,
            vertical_tokens: tokens / 4,
            slash_tokens: tokens,
            last_q: 64.min(n),
        }));
        methods.push(Method::Streaming(StreamingConfig {
            tile,
            global_tokens: (tokens / 8).max(tile.b_kv),
            local_tokens: tokens,
        }));
        methods.push(Method::BlockTopK(BlockTopKConfig {
            tile,
            k: (tokens / tile.b_kv).max(1),
            force_sink_local: true,
        }));
    }
    methods
}

pub fn run(scale: ExpScale, seed: u64) -> Vec<common::EvalRow> {
    let tile = scale.tile();
    let profile = common::default_profile();
    let quick = scale == ExpScale::Quick;

    // ---- (a)+(b): sweeps at the main length -----------------------------
    let n = scale.main_n();
    let wl = generate(&profile, n, seed);
    println!("\n=== Fig. 6a/6b: recall-sparsity-latency sweeps (n = {}) ===", fmt_len(n));
    let mut evals = Vec::new();
    let mut rows = Vec::new();
    for m in sweep_methods(n, tile, quick) {
        let e = common::evaluate(&wl.head, &m, tile);
        rows.push(vec![
            e.method.clone(),
            crate::util::pct(e.sparsity),
            crate::util::pct(e.recall),
            format!("{:.2}", e.latency_s * 1e3),
        ]);
        evals.push(e);
    }
    common::print_table(&["method", "sparsity", "recall", "latency_ms"], &rows);

    // Paper-shape summary: best sparsity at recall >= 0.90 per method.
    println!("\n--- best sparsity at recall ≥ 90% (Fig. 6a readout) ---");
    let mut summary = Vec::new();
    for name in ["anchor", "flexprefill", "vertical-slash", "streaming-llm", "block-topk"] {
        let best = evals
            .iter()
            .filter(|e| e.method == name && e.recall >= 0.90)
            .map(|e| e.sparsity)
            .fold(f64::NEG_INFINITY, f64::max);
        summary.push(vec![
            name.to_string(),
            if best.is_finite() { crate::util::pct(best) } else { "n/a (recall<90%)".into() },
        ]);
    }
    common::print_table(&["method", "max sparsity @ recall≥90%"], &summary);

    // ---- (c): latency vs length at fixed params --------------------------
    println!("\n--- Fig. 6c: latency vs length (fixed paper params) ---");
    let mut len_rows = Vec::new();
    for n in scale.lengths() {
        let wl = generate(&profile, n, seed);
        for m in common::paper_methods(n, tile, 12.0) {
            let t = common::measure_latency(&wl.head, &m, 1);
            len_rows.push(vec![fmt_len(n), m.name().to_string(), format!("{:.2}", t * 1e3)]);
        }
    }
    common::print_table(&["length", "method", "latency_ms"], &len_rows);

    let csv = common::to_csv(
        &["method", "sparsity", "recall", "latency_ms"],
        &evals
            .iter()
            .map(|e| {
                vec![
                    e.method.clone(),
                    format!("{:.4}", e.sparsity),
                    format!("{:.4}", e.recall),
                    format!("{:.4}", e.latency_s * 1e3),
                ]
            })
            .collect::<Vec<_>>(),
    );
    let _ = write_report("fig6_tradeoffs.csv", &csv);
    evals
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchor_dominates_static_methods_at_matched_recall() {
        // Fig. 6a at quick scale: anchor must dominate the *static* and
        // block-top-k baselines at matched recall. (The flexprefill
        // comparison is meaningful only at long contexts where the anchor
        // window is a small fraction of causal span — asserted at full
        // scale by the bench + EXPERIMENTS.md, not at n=4k.)
        let evals = run(ExpScale::Quick, 33);
        // Recall at matched sparsity (>= 0.75) — the scale-robust axis:
        // at short contexts every method can buy recall with density, but
        // at matched high sparsity anchor's global identification must
        // recover more mass than the static pattern.
        let best_recall = |name: &str| {
            evals
                .iter()
                .filter(|e| e.method == name && e.sparsity >= 0.75)
                .map(|e| e.recall)
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let anchor = best_recall("anchor");
        assert!(anchor.is_finite(), "anchor has no point at sparsity >= 0.75");
        assert!(anchor > 0.9, "anchor recall at high sparsity: {anchor}");
        let streaming = best_recall("streaming-llm");
        if streaming.is_finite() {
            assert!(anchor >= streaming - 0.01, "anchor {anchor} vs streaming {streaming}");
        }
    }
}

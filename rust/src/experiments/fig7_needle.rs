//! Figure 7 — Needle-in-a-Haystack heatmap: retrieval accuracy over a
//! (context length × needle depth) grid for Vertical_Slash, FlexPrefill,
//! AnchorAttention (and Full as a reference row). Shape to reproduce:
//! dynamic methods (ours, FlexPrefill) stay uniformly high; static
//! Vertical_Slash degrades as length grows.

use super::common::{self, ExpScale};
use super::tab3_ruler::niah_accuracy;
use crate::util::{fmt_len, write_report};
use crate::workload::qkv::generate_with_needle;

pub fn run(scale: ExpScale, seed: u64) -> Vec<Vec<String>> {
    let tile = scale.tile();
    let profile = common::default_profile();
    let depths = [0.1, 0.3, 0.5, 0.7, 0.9];
    let lengths = scale.lengths();

    println!("\n=== Fig. 7: needle-in-a-haystack (length × depth) ===");
    let mut rows = Vec::new();
    let mut csv = String::from("method,length,depth,accuracy\n");

    for n in &lengths {
        let n = *n;
        let methods = common::paper_methods(n, tile, 12.0);
        for m in &methods {
            // Skip full (always 100) except as reference at the first length.
            if m.name() == "full-attn" && n != lengths[0] {
                continue;
            }
            // Uncached session: each depth is an unrelated input, so plan
            // reuse across the loop would be incorrect.
            let mut session = m.session().no_cache().build().expect("session");
            let mut row = vec![m.name().to_string(), fmt_len(n)];
            for (di, &depth) in depths.iter().enumerate() {
                let wl =
                    generate_with_needle(&profile, n, seed ^ ((di as u64) << 24), Some(depth));
                let pos = wl.meta.needle.as_ref().unwrap().position;
                let full = crate::attention::full::full_attention(&wl.head, tile);
                let out = session.run(&wl.head).expect("run").into_single();
                let acc = niah_accuracy(&wl.head, &out.coverage, &out.out, &full.out, pos, tile);
                row.push(format!("{acc:.0}"));
                csv.push_str(&format!("{},{},{},{:.1}\n", m.name(), n, depth, acc));
            }
            rows.push(row);
        }
    }

    let mut headers: Vec<String> = vec!["method".into(), "length".into()];
    headers.extend(depths.iter().map(|d| format!("depth {:.0}%", d * 100.0)));
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    common::print_table(&header_refs, &rows);

    let _ = write_report("fig7_needle.csv", &csv);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dynamic_methods_retrieve_across_depths() {
        let rows = run(ExpScale::Quick, 55);
        // Anchor rows must stay high at all depths for the longest length.
        let anchor_rows: Vec<_> = rows.iter().filter(|r| r[0] == "anchor").collect();
        assert!(!anchor_rows.is_empty());
        let last = anchor_rows.last().unwrap();
        for cell in &last[2..] {
            let acc: f64 = cell.parse().unwrap();
            assert!(acc > 70.0, "anchor accuracy {acc} at some depth");
        }
        // Streaming must fail at shallow depths (needle outside window) for
        // the longest length.
        let streaming_last = rows.iter().filter(|r| r[0] == "streaming-llm").last().unwrap();
        let shallow: f64 = streaming_last[2].parse().unwrap();
        assert!(shallow < 50.0, "streaming should miss a 10%-depth needle, got {shallow}");
    }
}

//! `bench micro` — the gated micro-benchmark suite for the raw-speed
//! executor pass (DESIGN.md §13). Not a paper figure: it times the
//! executor's primitives so the optimizations that do not change any
//! output bit (run-length span serving, d-specialized fold kernels,
//! thread-local scratch) stay measurably faster than the paths they
//! replaced.
//!
//! Five groups:
//!
//! * **gather-vs-span crossover** — the same row multiset served through
//!   [`KvSource::span_into`] (one read per run) vs [`KvSource::gather_into`]
//!   (one read per coordinate), over a grid of run lengths, on both the
//!   flat and the paged KV source. Quantifies the span win and the run
//!   length where it starts.
//! * **specialized-vs-generic folds** — the `d ∈ {64, 128}` const-generic
//!   matmul kernels against the runtime-`k` generic loops they shadow.
//! * **cold-vs-scratch allocation** — one tile step (span read, Q·Kᵀ,
//!   online-softmax fold) with per-iteration buffer allocation vs the
//!   executor's reuse discipline.
//! * **runs-vs-discrete end-to-end** — [`CpuTileExecutor`] in
//!   [`LoweringMode::Runs`] vs [`LoweringMode::Discrete`] on a structured
//!   anchor plan (identical bits out, different read schedule).
//! * **plan-store seeding** — warming from a legacy JSON plan store
//!   (parse the whole blob, decode every plan, then filter) vs the
//!   segmented store (index filter, then byte-range reads of only the
//!   ~1% of entries that match), at 100 / 1k / 10k stored keys
//!   (DESIGN.md §15).
//!
//! Every group reduces to dimensionless ratios (higher = the optimization
//! is winning) written under `ratios` in `reports/bench_micro.json`; CI
//! republishes that file as the `BENCH_micro.json` artifact. With
//! `--baseline F`, each ratio named in the committed baseline must stay
//! within [`GATE_TOLERANCE`] of its floor or the run exits nonzero.

use std::sync::Arc;

use anyhow::Context;

use crate::attention::anchor::AnchorConfig;
use crate::attention::exec::{CpuTileExecutor, Executor, FlatKv, KvSource, LoweringMode};
use crate::attention::full::BlockState;
use crate::attention::plan::{GroupPlan, SparsePlan};
use crate::attention::{CostTally, Method, TileConfig};
use crate::coordinator::kv_cache::{PagedKv, PagedKvStore};
use crate::runtime::manifest::{entry_from_json, write_legacy_json_store, PlanStore, PlanStoreKey};
use crate::tensor::{self, Mat};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::timer::{BenchResult, BenchRunner};
use crate::workload::{qkv, WorkloadProfile};

use super::common::{bench_report_json, print_table, scaled_step, write_json_report, ExpScale};

/// Allowed fractional slack below a baseline ratio before the gate fails
/// (>15% regression on any gated ratio is an error).
pub const GATE_TOLERANCE: f64 = 0.15;

/// CLI-surface options for the suite.
#[derive(Debug, Default, Clone)]
pub struct MicroOptions {
    /// Path to a committed baseline JSON (`{"ratios": {...}}`); when set,
    /// every ratio it names is gated against its floor.
    pub baseline: Option<String>,
}

/// Run the suite, print the table + ratios, write
/// `reports/bench_micro.json`, and apply the baseline gate if configured.
pub fn run_with(scale: ExpScale, seed: u64, opts: &MicroOptions) -> anyhow::Result<Json> {
    let quick = matches!(scale, ExpScale::Quick);
    let mode = if quick { "quick" } else { "full" };
    let runner = if quick { BenchRunner::quick() } else { BenchRunner::default() };
    let mut results: Vec<BenchResult> = Vec::new();
    let mut ratios: Vec<(String, f64)> = Vec::new();

    // ---- group 1: gather-vs-span crossover ------------------------------
    let d = 64;
    let n = 4096;
    let mut rng = Pcg64::seeded(seed ^ 0x0515C0);
    let k = Mat::from_fn(n, d, |_, _| rng.normal());
    let v = Mat::from_fn(n, d, |_, _| rng.normal());
    let flat = FlatKv::new(&k, &v);
    // Mirror the rows into a paged store so both read paths see identical
    // bytes; an identity page table keeps translation in the picture
    // without a pool in the loop.
    let page_tokens = 16;
    let mut store = PagedKvStore::new(n / page_tokens, page_tokens, d);
    let pages: Vec<u32> = (0..(n / page_tokens) as u32).collect();
    for pos in 0..n {
        store.write(&pages, pos, k.row(pos), v.row(pos))?;
    }
    let paged = PagedKv::new(&store, &pages);

    let run_lens: &[usize] = if quick { &[1, 4, 16, 64] } else { &[1, 2, 4, 8, 16, 32, 64] };
    let read_rows = 512;
    let mut k_dst = Mat::zeros(read_rows, d);
    let mut v_dst = Mat::zeros(read_rows, d);
    let mut crossover: Vec<(String, Json)> = Vec::new();
    for (src_name, src) in [("flat", &flat as &dyn KvSource), ("paged", &paged as &dyn KvSource)] {
        let mut span_wins_at: Option<usize> = None;
        for &len in run_lens {
            // `read_rows` rows arranged as runs of `len` with a one-row
            // gap, served as spans vs per-coordinate gathers of the same
            // multiset (exactly what the Runs lowering changes).
            let n_runs = read_rows / len;
            let starts: Vec<usize> = (0..n_runs).map(|r| r * (len + 1)).collect();
            assert!(starts.last().unwrap() + len <= n, "workload exceeds KV length");
            let coords: Vec<u32> =
                starts.iter().flat_map(|&s| (s..s + len).map(|x| x as u32)).collect();
            let span = runner.run(&format!("read/{src_name}/span/run{len}"), || {
                let mut row0 = 0;
                for &s in &starts {
                    src.span_into(s, s + len, row0, &mut k_dst, &mut v_dst);
                    row0 += len;
                }
                k_dst.data[0]
            });
            let gather = runner.run(&format!("read/{src_name}/gather/run{len}"), || {
                src.gather_into(&coords, 0, &mut k_dst, &mut v_dst);
                k_dst.data[0]
            });
            let ratio = gather.mean_s / span.mean_s;
            if ratio > 1.0 && span_wins_at.is_none() {
                span_wins_at = Some(len);
            }
            ratios.push((format!("read_{src_name}_gather_over_span_run{len}"), ratio));
            results.push(span);
            results.push(gather);
        }
        crossover.push((
            format!("{src_name}_span_wins_at_run_len"),
            span_wins_at.map(|l| Json::num(l as f64)).unwrap_or(Json::Null),
        ));
    }

    // ---- group 2: d-specialized vs generic fold kernels ------------------
    let (b_q, b_kv) = (128, 128);
    for dk in [64usize, 128] {
        let q_t = Mat::from_fn(b_q, dk, |_, _| rng.normal());
        let k_t = Mat::from_fn(b_kv, dk, |_, _| rng.normal());
        let p = Mat::from_fn(b_q, b_kv, |_, _| rng.normal().abs());
        let v_t = Mat::from_fn(b_kv, dk, |_, _| rng.normal());
        let mut s = Mat::zeros(b_q, b_kv);
        let mut acc = Mat::zeros(b_q, dk);
        let inv = 1.0 / (dk as f32).sqrt();
        let spec_qk = runner.run(&format!("fold/qk-spec/d{dk}"), || {
            tensor::matmul_nt_scaled(&q_t, &k_t, inv, &mut s);
            s.data[0]
        });
        let gen_qk = runner.run(&format!("fold/qk-generic/d{dk}"), || {
            tensor::matmul_nt_scaled_generic(&q_t, &k_t, inv, &mut s);
            s.data[0]
        });
        // The accumulate form grows unboundedly across iterations; zero it
        // each pass (same memset on both sides) to keep values finite.
        let spec_av = runner.run(&format!("fold/av-spec/d{dk}"), || {
            acc.data.fill(0.0);
            tensor::matmul_nn_acc(&p, &v_t, &mut acc);
            acc.data[0]
        });
        let gen_av = runner.run(&format!("fold/av-generic/d{dk}"), || {
            acc.data.fill(0.0);
            tensor::matmul_nn_acc_generic(&p, &v_t, &mut acc);
            acc.data[0]
        });
        ratios.push((
            format!("spec_fold_speedup_d{dk}"),
            (gen_qk.mean_s + gen_av.mean_s) / (spec_qk.mean_s + spec_av.mean_s),
        ));
        results.extend([spec_qk, gen_qk, spec_av, gen_av]);
    }

    // ---- group 3: cold allocation vs executor scratch --------------------
    // One tile step — span read, Q·Kᵀ, online-softmax fold — with buffers
    // allocated per iteration (the pre-scratch walk) vs reused the way
    // `fold_group_scratch`'s thread-local scratch does.
    let q_tile = Mat::from_fn(b_q, d, |_, _| rng.normal());
    let inv = 1.0 / (d as f32).sqrt();
    let cold = runner.run("alloc/cold", || {
        let mut k_t = Mat::zeros(b_kv, d);
        let mut v_t = Mat::zeros(b_kv, d);
        let mut s = Mat::zeros(b_q, b_kv);
        let mut state = BlockState::new(b_q, d);
        flat.span_into(0, b_kv, 0, &mut k_t, &mut v_t);
        tensor::matmul_nt_scaled(&q_tile, &k_t, inv, &mut s);
        state.fold_tile(&mut s, &v_t);
        state.l[0]
    });
    let mut k_t = Mat::zeros(b_kv, d);
    let mut v_t = Mat::zeros(b_kv, d);
    let mut s = Mat::zeros(b_q, b_kv);
    let mut state = BlockState::new(b_q, d);
    let scratch = runner.run("alloc/scratch", || {
        state.reset(b_q, d);
        flat.span_into(0, b_kv, 0, &mut k_t, &mut v_t);
        tensor::matmul_nt_scaled(&q_tile, &k_t, inv, &mut s);
        state.fold_tile(&mut s, &v_t);
        state.l[0]
    });
    ratios.push(("cold_over_scratch".to_string(), cold.mean_s / scratch.mean_s));
    results.extend([cold, scratch]);

    // ---- group 4: runs-vs-discrete end-to-end ----------------------------
    let n2 = if quick { 2048 } else { 4096 };
    let tile = TileConfig::new(128, 128);
    let wl = qkv::generate(&WorkloadProfile::llama_like(), n2, seed);
    let plan = Method::Anchor(AnchorConfig {
        tile,
        theta: 12.0,
        step: scaled_step(n2, tile),
        init_blocks: 1,
        use_anchor: true,
    })
    .plan(&wl.head);
    let runs_exec = CpuTileExecutor { serial: true, lowering: LoweringMode::Runs };
    let disc_exec = CpuTileExecutor { serial: true, lowering: LoweringMode::Discrete };
    let runs = runner.run(&format!("exec/anchor-runs/n{n2}"), || {
        runs_exec.execute(&wl.head, &plan).out.data[0]
    });
    let disc = runner.run(&format!("exec/anchor-discrete/n{n2}"), || {
        disc_exec.execute(&wl.head, &plan).out.data[0]
    });
    ratios.push(("discrete_over_runs".to_string(), disc.mean_s / runs.mean_s));
    results.extend([runs, disc]);

    // ---- group 5: plan-store seeding — legacy JSON vs segments -----------
    // Warm-start cost at fleet scale: a store holding `size` plans of
    // which 1% belong to this session's model. The JSON leg replays the
    // pre-segment behavior (parse the whole blob, decode every plan,
    // filter after the fact); the segment leg is `PlanStore::open` +
    // `plans_for_compatible`, which filters on the index and decodes
    // only the matching byte ranges (DESIGN.md §15).
    let store_tile = TileConfig::new(16, 16);
    let (store_n, store_d, store_step) = (128usize, 8usize, 2usize);
    let store_groups: Vec<GroupPlan> = (0..store_tile.q_blocks(store_n).div_ceil(store_step))
        .map(|g| {
            let win = (g * 32) as u32;
            let end = ((g + 1) * 32).min(store_n) as u32;
            if win == 0 {
                GroupPlan { spans: vec![(0, end)], stripes: vec![] }
            } else {
                GroupPlan {
                    spans: vec![(0, 16), (win, end)],
                    stripes: (16..win).step_by(5).collect(),
                }
            }
        })
        .collect();
    let store_plan = Arc::new(SparsePlan::new(
        "anchor",
        store_n,
        store_d,
        store_tile,
        store_step,
        store_groups,
        CostTally { flops: 640, kv_bytes: 128, ident_scores: 32 },
    ));
    for (size, label) in [(100usize, "100"), (1_000, "1k"), (10_000, "10k")] {
        let entries: Vec<(PlanStoreKey, usize, Arc<SparsePlan>)> = (0..size)
            .map(|i| {
                let model = if i % 100 == 0 { "hot" } else { "cold" };
                (
                    PlanStoreKey {
                        model: model.to_string(),
                        layer: i as u32,
                        head_group: 0,
                        n: store_n,
                    },
                    store_d,
                    Arc::clone(&store_plan),
                )
            })
            .collect();
        let dir = std::env::temp_dir()
            .join(format!("anchor_micro_store_{}_{label}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).context("creating plan-store bench dir")?;
        let legacy = dir.join("legacy.json");
        let segmented = dir.join("segmented.json");
        write_legacy_json_store(&legacy, &entries)?;
        write_legacy_json_store(&segmented, &entries)?;
        // One untimed open migrates the segment-side fixture into the
        // segmented layout; the timed leg then measures steady state.
        drop(PlanStore::open(&segmented)?);
        let json_leg = runner.run(&format!("store/seed-json/{label}"), || {
            let text = std::fs::read_to_string(&legacy).unwrap();
            let doc = Json::parse(&text).unwrap();
            let mut hits = 0usize;
            for e in doc.get("plan_store").get("entries").as_arr().unwrap_or(&[]) {
                let (key, d_e, plan) = entry_from_json(e).unwrap();
                if key.model == "hot"
                    && key.n == store_n
                    && d_e == store_d
                    && plan.method == "anchor"
                    && plan.tile == store_tile
                    && plan.step == store_step
                {
                    hits += 1;
                }
            }
            hits
        });
        let seg_leg = runner.run(&format!("store/seed-segment/{label}"), || {
            let mut store = PlanStore::open(&segmented).unwrap();
            store
                .plans_for_compatible("hot", store_n, "anchor", store_tile, store_step, store_d)
                .len()
        });
        ratios.push((
            format!("store_seed_json_over_segment_{label}"),
            json_leg.mean_s / seg_leg.mean_s,
        ));
        results.push(json_leg);
        results.push(seg_leg);
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- report ----------------------------------------------------------
    print_table(
        &["bench", "iters", "mean ms", "p50 ms", "min ms"],
        &results
            .iter()
            .map(|r| {
                vec![
                    r.name.clone(),
                    r.iters.to_string(),
                    format!("{:.4}", r.mean_s * 1e3),
                    format!("{:.4}", r.p50_s * 1e3),
                    format!("{:.4}", r.min_s * 1e3),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!("ratios (higher = optimization winning):");
    for (name, val) in &ratios {
        println!("  {name:<44} {val:.3}");
    }

    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::str(&r.name)),
                ("iters", Json::num(r.iters as f64)),
                ("mean_ms", Json::num(r.mean_s * 1e3)),
                ("p50_ms", Json::num(r.p50_s * 1e3)),
                ("p95_ms", Json::num(r.p95_s * 1e3)),
                ("min_ms", Json::num(r.min_s * 1e3)),
            ])
        })
        .collect();
    let ratios_json =
        Json::Obj(ratios.iter().map(|(k2, v2)| (k2.clone(), Json::num(*v2))).collect());
    let crossover_json = Json::Obj(crossover.into_iter().collect());
    let report = bench_report_json(
        "bench_micro",
        mode,
        seed,
        rows,
        vec![
            ("ratios", ratios_json),
            ("crossover", crossover_json),
            ("gate_tolerance", Json::num(GATE_TOLERANCE)),
            ("baseline", opts.baseline.as_deref().map(Json::str).unwrap_or(Json::Null)),
        ],
    );
    let path = write_json_report("bench_micro.json", &report)?;
    println!("wrote {}", path.display());

    // ---- gate ------------------------------------------------------------
    if let Some(baseline_path) = &opts.baseline {
        let text = std::fs::read_to_string(baseline_path)
            .with_context(|| format!("reading baseline '{baseline_path}'"))?;
        let baseline = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("baseline '{baseline_path}': {e}"))?;
        let lines = check_ratios(&baseline, &ratios, GATE_TOLERANCE)
            .with_context(|| format!("micro-bench gate vs '{baseline_path}'"))?;
        println!("gate vs {baseline_path} (tolerance {:.0}%):", GATE_TOLERANCE * 100.0);
        for line in lines {
            println!("  {line}");
        }
    }
    Ok(report)
}

/// Compare this run's ratios against the floors a baseline names. Every
/// baseline key must exist in `current` and stay ≥ `floor * (1 - tol)`;
/// returns per-key report lines, or an error listing every regression.
pub fn check_ratios(
    baseline: &Json,
    current: &[(String, f64)],
    tol: f64,
) -> anyhow::Result<Vec<String>> {
    let floors = baseline
        .get("ratios")
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("baseline has no 'ratios' object"))?;
    let now: std::collections::BTreeMap<&str, f64> =
        current.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let mut lines = Vec::new();
    let mut failures = Vec::new();
    for (key, floor) in floors {
        let floor = floor
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("baseline ratio '{key}' is not a number"))?;
        let cur = *now
            .get(key.as_str())
            .ok_or_else(|| anyhow::anyhow!("baseline ratio '{key}' missing from this run"))?;
        let ok = cur >= floor * (1.0 - tol);
        lines.push(format!(
            "{key:<44} {cur:.3} vs floor {floor:.3} [{}]",
            if ok { "ok" } else { "REGRESSED" }
        ));
        if !ok {
            failures.push(format!("{key}: {cur:.3} < {floor:.3} * (1 - {tol})"));
        }
    }
    anyhow::ensure!(
        failures.is_empty(),
        "micro-bench ratios regressed >{:.0}%:\n  {}",
        tol * 100.0,
        failures.join("\n  ")
    );
    Ok(lines)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn baseline(pairs: &[(&str, f64)]) -> Json {
        Json::obj(vec![(
            "ratios",
            Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), Json::num(*v))).collect()),
        )])
    }

    /// The gate passes ratios at or slightly below their floors (within
    /// tolerance), fails a real regression naming the key, and rejects
    /// baselines referencing ratios this run never produced.
    #[test]
    fn gate_applies_tolerance_and_names_regressions() {
        let current = vec![
            ("discrete_over_runs".to_string(), 1.4),
            ("cold_over_scratch".to_string(), 0.9),
            ("spec_fold_speedup_d64".to_string(), 1.02),
        ];
        // 0.9 >= 1.0 * 0.85: within the 15% band.
        let ok = check_ratios(
            &baseline(&[
                ("discrete_over_runs", 1.0),
                ("cold_over_scratch", 1.0),
                ("spec_fold_speedup_d64", 1.0),
            ]),
            &current,
            GATE_TOLERANCE,
        )
        .unwrap();
        assert_eq!(ok.len(), 3);
        assert!(ok.iter().all(|l| l.contains("[ok]")), "{ok:?}");
        // A floor the run undercuts by >15% fails and names the key.
        let err = check_ratios(&baseline(&[("cold_over_scratch", 1.2)]), &current, GATE_TOLERANCE)
            .unwrap_err();
        assert!(err.to_string().contains("cold_over_scratch"), "{err}");
        // Unknown baseline keys are an error, not silently skipped — a
        // renamed ratio must force a baseline update.
        let err = check_ratios(&baseline(&[("no_such_ratio", 1.0)]), &current, GATE_TOLERANCE)
            .unwrap_err();
        assert!(err.to_string().contains("no_such_ratio"), "{err}");
        // Malformed baselines fail loudly.
        assert!(check_ratios(&Json::obj(vec![]), &current, GATE_TOLERANCE).is_err());
    }
}

//! Experiment drivers — one per table/figure of the paper's evaluation
//! (DESIGN.md §3 maps each to its bench target). Every driver prints the
//! paper's rows/series and writes a CSV under `reports/`.
//!
//! All drivers accept quick/full scale (CPU testbed; DESIGN.md §6): quick
//! keeps CI fast, full is what `cargo bench` runs.

pub mod common;
pub mod fig2_speedup;
pub mod fig4_strategies;
pub mod fig5_dominance;
pub mod fig6_tradeoffs;
pub mod fig7_needle;
pub mod micro;
pub mod reuse;
pub mod serve_bench;
pub mod tab1_granularity;
pub mod tab2_longbench;
pub mod tab3_ruler;
pub mod tab4_ablation;

pub use common::ExpScale;

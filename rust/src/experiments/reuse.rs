//! `bench reuse` — the cross-layer commonality sweep (DESIGN.md §17).
//!
//! The speculative reuse layer bets that a neighboring layer's plan is a
//! good predictor of this layer's stripe set (§3.2's cross-input
//! commonality, read across depth). This driver *measures* that bet
//! instead of assuming it: it builds an AR(1)-correlated stack of layer
//! inputs (`Q/K[l] = ρ·Q/K[l-1] + √(1-ρ²)·noise`, mimicking how residual
//! streams drift slowly with depth) and, for every layer distance `k`,
//! recall-checks the distance-`k` donor through the *real*
//! [`Speculator`] machinery — same sampling rule, same floor, same
//! fallback — recording the recall it scores, the accept rate at the
//! default floor, and the identification cost actually paid relative to
//! fresh identification.
//!
//! Output: `reports/bench_reuse.json` — one row per distance (distance 0
//! is the identical-input sanity anchor and must score recall 1.0). CI's
//! bench job merges the rows into `BENCH_fig2.json` under `reuse_grid`
//! and gates the curve's shape: recall must not *increase* with
//! distance, and an accepted check must stay far cheaper than fresh
//! identification.

use std::sync::Arc;

use anyhow::{ensure, Result};

use super::common::{bench_report_json, print_table, write_json_report, ExpScale};
use crate::attention::anchor::AnchorConfig;
use crate::attention::plan::{PlanCache, PlanKey, Planner};
use crate::attention::reuse::{
    ReusePolicy, Speculator, DEFAULT_RECALL_FLOOR, RECALL_SAMPLE_STRIDE,
};
use crate::attention::{HeadInput, TileConfig};
use crate::tensor::Mat;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::workload::qkv::generate;
use crate::workload::WorkloadProfile;

/// Depth-drift correlation of the synthetic layer stack. High on
/// purpose: adjacent transformer layers see near-identical residual
/// streams; the sweep shows how fast the reuse bet decays as the
/// correlation compounds (`ρ^k` at distance `k`).
const LAYER_RHO: f32 = 0.92;

/// One aggregated measurement at a fixed layer distance.
#[derive(Clone, Debug)]
pub struct DistanceRow {
    pub distance: usize,
    pub pairs: usize,
    pub recall_mean: f64,
    pub recall_min: f64,
    /// Fraction of checks clearing [`DEFAULT_RECALL_FLOOR`].
    pub accept_rate: f64,
    /// Mean identification cost actually paid (check, plus full
    /// identification on fallback) over fresh identification's cost.
    pub ident_paid_frac: f64,
}

/// `stack[l]` drifts from `stack[l-1]` by an AR(1) step on Q and K (V is
/// irrelevant to identification and stays at the base workload's).
fn layer_stack(profile: &WorkloadProfile, n: usize, layers: usize, seed: u64) -> Vec<HeadInput> {
    let base = generate(profile, n, seed).head;
    let mut rng = Pcg64::seeded(seed.wrapping_mul(0x9e37_79b9).wrapping_add(7));
    let mut drift = |prev: &Mat| -> Mat {
        let scale = (1.0 - LAYER_RHO * LAYER_RHO).sqrt();
        let mut next = prev.clone();
        for x in next.data.iter_mut() {
            *x = LAYER_RHO * *x + scale * rng.normal();
        }
        next
    };
    let mut stack = vec![base];
    for _ in 1..layers {
        let prev = stack.last().unwrap();
        let next = HeadInput::new(drift(&prev.q), drift(&prev.k), prev.v.clone());
        stack.push(next);
    }
    stack
}

/// Recall-check the plan of `stack[l]` as a donor for `stack[l + dist]`
/// through the real [`Speculator`] (donor seeded one layer below the
/// target so the distance-1 probe finds it regardless of `dist` — the
/// sweep varies *input* distance, not probe plumbing).
fn measure_pair(
    cfg: AnchorConfig,
    donor: &HeadInput,
    target: &HeadInput,
) -> (u64, u64, f64, f64) {
    let donor_plan = Planner::plan(&cfg, donor);
    let fresh = Planner::plan(&cfg, target);
    let spec = Speculator::new(ReusePolicy::cross_layer(), cfg);
    let cache = PlanCache::new();
    cache.seed(PlanKey::new(0, 0), Arc::new(donor_plan));
    let plan = spec.resolve(&cache, PlanKey::new(1, 0), target);
    let (hits, fallbacks, recall) = spec.take_run_stats();
    let paid_frac = if fresh.ident_cost.ident_scores > 0 {
        plan.ident_cost.ident_scores as f64 / fresh.ident_cost.ident_scores as f64
    } else {
        1.0
    };
    (hits, fallbacks, recall.unwrap_or(1.0), paid_frac)
}

/// Run the sweep and return the per-distance rows.
pub fn sweep(scale: ExpScale, seed: u64) -> Vec<DistanceRow> {
    let (n, layers, seeds, max_dist) = match scale {
        ExpScale::Quick => (512, 6, 2u64, 3),
        ExpScale::Full => (1024, 8, 3u64, 4),
    };
    let cfg = AnchorConfig {
        tile: TileConfig::new(16, 16),
        theta: 6.0,
        step: 2,
        init_blocks: 1,
        use_anchor: true,
    };
    let profile = WorkloadProfile::llama_like();
    let stacks: Vec<Vec<HeadInput>> = (0..seeds)
        .map(|s| layer_stack(&profile, n, layers, seed.wrapping_add(s)))
        .collect();

    let mut rows = Vec::new();
    for dist in 0..=max_dist {
        let (mut hits, mut checks) = (0u64, 0u64);
        let mut recall_sum = 0.0;
        let mut recall_min = f64::INFINITY;
        let mut paid_sum = 0.0;
        let mut pairs = 0usize;
        for stack in &stacks {
            for l in 0..layers.saturating_sub(dist) {
                let (h, f, recall, paid) = measure_pair(cfg, &stack[l], &stack[l + dist]);
                hits += h;
                checks += h + f;
                recall_sum += recall;
                recall_min = recall_min.min(recall);
                paid_sum += paid;
                pairs += 1;
            }
        }
        rows.push(DistanceRow {
            distance: dist,
            pairs,
            recall_mean: recall_sum / pairs.max(1) as f64,
            recall_min: if pairs == 0 { 0.0 } else { recall_min },
            accept_rate: if checks == 0 { 0.0 } else { hits as f64 / checks as f64 },
            ident_paid_frac: paid_sum / pairs.max(1) as f64,
        });
    }
    rows
}

/// Drive the sweep, print the curve and write `reports/bench_reuse.json`.
pub fn run_with(scale: ExpScale, seed: u64) -> Result<Json> {
    let rows = sweep(scale, seed);
    println!(
        "bench reuse: cross-layer commonality, ρ={LAYER_RHO}, floor {DEFAULT_RECALL_FLOOR}, \
         sample stride {RECALL_SAMPLE_STRIDE}"
    );
    print_table(
        &["distance", "pairs", "recall_mean", "recall_min", "accept_rate", "ident_paid_frac"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.distance.to_string(),
                    r.pairs.to_string(),
                    format!("{:.4}", r.recall_mean),
                    format!("{:.4}", r.recall_min),
                    format!("{:.3}", r.accept_rate),
                    format!("{:.3}", r.ident_paid_frac),
                ]
            })
            .collect::<Vec<_>>(),
    );
    // The sweep is only evidence if its sanity anchor holds: an
    // identical-input donor must check out perfectly and cheaply.
    let d0 = &rows[0];
    ensure!(
        d0.recall_mean > 1.0 - 1e-9 && d0.accept_rate > 1.0 - 1e-9,
        "distance-0 sanity anchor failed: recall {} accept {}",
        d0.recall_mean,
        d0.accept_rate
    );
    ensure!(
        d0.ident_paid_frac < 1.0,
        "an accepted identical donor must be cheaper than fresh identification \
         (paid fraction {})",
        d0.ident_paid_frac
    );
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("distance", Json::num(r.distance as f64)),
                ("pairs", Json::num(r.pairs as f64)),
                ("recall_mean", Json::num(r.recall_mean)),
                ("recall_min", Json::num(r.recall_min)),
                ("accept_rate", Json::num(r.accept_rate)),
                ("ident_paid_frac", Json::num(r.ident_paid_frac)),
            ])
        })
        .collect();
    let rep = bench_report_json(
        "reuse_bench",
        "cross-layer",
        seed,
        json_rows,
        vec![
            ("rho", Json::num(LAYER_RHO as f64)),
            ("recall_floor", Json::num(DEFAULT_RECALL_FLOOR)),
            ("sample_stride", Json::num(RECALL_SAMPLE_STRIDE as f64)),
        ],
    );
    let path = write_json_report("bench_reuse.json", &rep)?;
    println!("wrote {}", path.display());
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The commonality curve behaves: perfect at distance 0, and the
    /// mean recall never *rises* as the input correlation decays (ties
    /// allowed — a strongly structured head can stay reusable for a few
    /// layers, which is the effect the policy banks on).
    #[test]
    fn recall_decays_with_layer_distance() {
        let rows = sweep(ExpScale::Quick, 7);
        assert_eq!(rows[0].distance, 0);
        assert!(rows[0].recall_mean > 1.0 - 1e-9, "d0 recall {}", rows[0].recall_mean);
        assert!(rows[0].accept_rate > 1.0 - 1e-9);
        assert!(rows[0].ident_paid_frac < 1.0, "check must undercut fresh ident");
        for w in rows.windows(2) {
            assert!(
                w[1].recall_mean <= w[0].recall_mean + 0.05,
                "recall rose with distance: {} -> {}",
                w[0].recall_mean,
                w[1].recall_mean
            );
        }
        // Every pair ran a check (a donor always exists in the sweep).
        assert!(rows.iter().all(|r| r.pairs > 0));
    }
}

//! `bench serve` — the SLO-gated serving harness (DESIGN.md §16).
//!
//! Replays a scenario-library trace ([`crate::workload::scenario`])
//! through the *real* serving path — admission, paged KV pool under
//! eviction pressure (prefill preemption on), chunked-prefill scheduler
//! with the live plan-hit EWMA, dynamic batcher — against a mock engine
//! that additionally drives a genuine [`AttentionSession`] per completed
//! prefill, all sessions sharing one [`PlanCache`] keyed by the trace's
//! reuse keys. Plan-cache hits therefore come from the cache itself, not
//! a model: a shared-prefix tenant whose requests collide on
//! `(tenant, group)` reuse keys hits warm plans, a needle tenant whose
//! keys are unique never does, and the per-scenario hit rates in the
//! report are the measured difference.
//!
//! Output: `reports/bench_serve.json` — TTFT/e2e percentiles,
//! goodput-per-core, per-scenario plan hit rates, KV eviction counts and
//! the trace's stream digest (the CI determinism check re-runs the
//! binary and compares digests). `--baseline F` gates the run: latency
//! ceilings within [`GATE_TOLERANCE`], throughput/hit-rate floors, and
//! the paper-flavored ordering check that shared-prefix reuse must beat
//! needle (§3.2's cross-input commonality, observed end-to-end).
//!
//! [`AttentionSession`]: crate::attention::session::AttentionSession

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::common::{bench_report_json, write_json_report, ExpScale};
use crate::attention::anchor::AnchorConfig;
use crate::attention::exec::ExecutorKind;
use crate::attention::plan::{BatchInput, PlanCache, PlanKey};
use crate::attention::reuse::ReusePolicy;
use crate::attention::{Method, TileConfig};
use crate::coordinator::batcher::EngineBatch;
use crate::coordinator::engine::{MockEngine, StepExecutor, StepOutcome};
use crate::coordinator::metrics::RequestOutcome;
use crate::coordinator::request::Request;
use crate::coordinator::scheduler::{CostConstants, SparsityModel};
use crate::coordinator::server::{serve, ServerConfig};
use crate::util::json::Json;
use crate::workload::scenario::{named_scenario, stream_digest, ScenarioRequest};
use crate::workload::WorkloadProfile;

/// Allowed fractional slack on a gated ceiling/floor before the gate
/// fails the run (latencies vary with machine; orderings do not).
pub const GATE_TOLERANCE: f64 = 0.15;

/// Context length of the per-request attention session. Small on purpose:
/// the harness measures *cache interaction* per request, not kernel
/// speed — the micro/fig2 suites own that.
const SESSION_N: usize = 256;

/// CLI-facing knobs for `bench serve`.
pub struct ServeBenchOptions {
    /// Scenario name: long-doc | rag | shared-prefix | needle | mixed.
    pub scenario: String,
    /// Trace size override (default scales with quick/full).
    pub requests: Option<usize>,
    /// Committed baseline JSON with `ceilings` / `floors` /
    /// `shared_prefix_beats_needle`; when set, violations exit nonzero.
    pub baseline: Option<String>,
    /// Speculative plan-reuse policy for the per-request sessions
    /// (DESIGN.md §17): exact | cross-layer | prefix. With `prefix` on a
    /// shared-prefix scenario, group-first misses resolve from sibling
    /// groups' plans at recall 1.0 and pay only the sampled check.
    pub reuse: ReusePolicy,
}

/// Fold a 64-bit scenario reuse key into the 32-bit plan-cache head
/// group, preserving distinctness of the needle tenant's unique keys.
fn fold_key(key: u64) -> u32 {
    (key ^ (key >> 32)) as u32
}

/// Mock engine wrapper that runs one real attention session per request
/// at prompt completion, sharing a single plan cache across the run.
struct ScenarioEngine {
    inner: MockEngine,
    method: Method,
    cache: Arc<PlanCache>,
    batch: BatchInput,
    /// Request id → plan-cache key derived from the scenario reuse key.
    plan_keys: HashMap<u64, PlanKey>,
    prompt_len: HashMap<u64, usize>,
    /// Prefill progress tracked independently of the mock (reset on
    /// preemption via `finish_request`, like the mock's own counter).
    prefilled: HashMap<u64, usize>,
    /// Requests whose session already ran — a preempted-and-replayed
    /// prefill must not double-count its cache interaction.
    ran: HashSet<u64>,
    pending_attrib: Vec<(u64, u64, u64)>,
    window_hits: u64,
    window_misses: u64,
    /// Speculative reuse policy applied to every per-request session.
    reuse: ReusePolicy,
    pending_spec: Vec<(u64, u64, u64)>,
    window_spec_hits: u64,
    window_spec_fallbacks: u64,
    /// Identification scores actually paid across the whole run — the
    /// quantity speculative reuse exists to shrink.
    ident_scores_paid: f64,
}

impl ScenarioEngine {
    fn new(seed: u64, trace: &[ScenarioRequest], model: SparsityModel, reuse: ReusePolicy) -> Self {
        let wl = crate::workload::qkv::generate(
            &WorkloadProfile::llama_like(),
            SESSION_N,
            seed,
        );
        // Tiny tile so SESSION_N yields enough blocks for anchor
        // identification to do real work per session.
        let method = Method::Anchor(AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta: 4.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        });
        let mut plan_keys = HashMap::new();
        let mut prompt_len = HashMap::new();
        for r in trace {
            plan_keys.insert(r.id, PlanKey::new(r.kind.index(), fold_key(r.reuse_key)));
            prompt_len.insert(r.id, r.prompt_tokens);
        }
        Self {
            inner: MockEngine::with_cost_model(512, model),
            method,
            cache: Arc::new(PlanCache::new()),
            batch: BatchInput::new(vec![wl.head]),
            plan_keys,
            prompt_len,
            prefilled: HashMap::new(),
            ran: HashSet::new(),
            pending_attrib: Vec::new(),
            window_hits: 0,
            window_misses: 0,
            reuse,
            pending_spec: Vec::new(),
            window_spec_hits: 0,
            window_spec_fallbacks: 0,
            ident_scores_paid: 0.0,
        }
    }

    fn run_session(&mut self, req: u64) {
        let Some(&key) = self.plan_keys.get(&req) else { return };
        let mut session = self
            .method
            .session()
            .shared_cache(self.cache.clone())
            .keys(vec![key])
            .reuse(self.reuse)
            .build()
            .expect("anchor session config is infallible");
        let out = session.run_batch(&self.batch).expect("in-memory batch cannot fail");
        self.window_hits += out.cache_hits;
        self.window_misses += out.cache_misses;
        self.pending_attrib.push((req, out.cache_hits, out.cache_misses));
        self.window_spec_hits += out.speculative_hits;
        self.window_spec_fallbacks += out.speculative_fallbacks;
        if out.speculative_hits + out.speculative_fallbacks > 0 {
            self.pending_spec.push((req, out.speculative_hits, out.speculative_fallbacks));
        }
        self.ident_scores_paid += out.ident_cost_paid.ident_scores as f64;
    }
}

impl StepExecutor for ScenarioEngine {
    fn execute(&mut self, batch: &EngineBatch) -> Vec<StepOutcome> {
        let outcomes = self.inner.execute(batch);
        for o in &outcomes {
            if let StepOutcome::PrefillChunk { req, took, .. } = *o {
                let done = {
                    let p = self.prefilled.entry(req).or_insert(0);
                    *p += took;
                    *p >= self.prompt_len.get(&req).copied().unwrap_or(usize::MAX)
                };
                if done && self.ran.insert(req) {
                    self.run_session(req);
                }
            }
        }
        outcomes
    }

    fn finish_request(&mut self, req: u64) {
        self.inner.finish_request(req);
        self.prefilled.remove(&req);
    }

    fn observed_plan_hit_rate(&mut self) -> Option<f64> {
        let total = self.window_hits + self.window_misses;
        if total == 0 {
            return None;
        }
        let rate = self.window_hits as f64 / total as f64;
        self.window_hits = 0;
        self.window_misses = 0;
        Some(rate)
    }

    fn take_plan_attribution(&mut self) -> Vec<(u64, u64, u64)> {
        std::mem::take(&mut self.pending_attrib)
    }

    fn observed_speculative_hit_rate(&mut self) -> Option<f64> {
        let total = self.window_spec_hits + self.window_spec_fallbacks;
        if total == 0 {
            return None;
        }
        let rate = self.window_spec_hits as f64 / total as f64;
        self.window_spec_hits = 0;
        self.window_spec_fallbacks = 0;
        Some(rate)
    }

    fn take_speculative_attribution(&mut self) -> Vec<(u64, u64, u64)> {
        std::mem::take(&mut self.pending_spec)
    }
}

/// Run the harness, print the serving summary, write
/// `reports/bench_serve.json`, and apply the SLO gate if configured.
pub fn run_with(scale: ExpScale, seed: u64, opts: &ServeBenchOptions) -> Result<Json> {
    let requests = opts.requests.unwrap_or(match scale {
        ExpScale::Quick => 32,
        ExpScale::Full => 96,
    });
    let cfg = named_scenario(&opts.scenario, requests, seed)?;
    let trace = cfg.generate()?;
    let digest = stream_digest(&trace);
    // Determinism is part of the contract: same seed, same stream —
    // byte-for-byte (CI re-runs the binary and compares digests too).
    ensure!(
        stream_digest(&cfg.generate()?) == digest,
        "scenario '{}' is not deterministic at seed {seed}",
        opts.scenario
    );
    println!(
        "bench serve: scenario '{}', {} requests, seed {seed}, reuse '{}', \
         stream digest {digest:016x}",
        opts.scenario,
        trace.len(),
        opts.reuse.name()
    );

    // Arrival times collapse to zero (stable sort keeps scenario arrival
    // order): with `realtime: false` the wall clock starts at serve
    // entry, so TTFT measures time-in-system, never a negative offset
    // against a synthetic arrival stamp.
    let submissions: Vec<Request> = trace
        .iter()
        .map(|t| {
            let mut r = Request::new(t.id, vec![1; t.prompt_tokens], t.decode_tokens, 0.0);
            r.scenario = Some(t.kind.tag().to_string());
            r
        })
        .collect();

    let model = SparsityModel::Anchor {
        stripe_keep: 0.1,
        anchor_tokens: 256,
        plan_hit_rate: 0.0,
        speculative_hit_rate: 0.0,
        pipelined: false,
        executor: ExecutorKind::Cpu,
        shards: 1,
        constants: CostConstants::modeled(),
    };
    let mut server = ServerConfig::default();
    server.scheduler.sparsity = model;
    // Eviction pressure is the point: a pool sized well below the
    // trace's aggregate footprint with prefill preemption enabled, so
    // the report's eviction counts exercise the §16 policy.
    server.scheduler.preempt_prefill = true;
    server.pool_pages = 96;

    let mut engine = ScenarioEngine::new(seed, &trace, model, opts.reuse);
    let report = serve(&server, submissions, &mut engine, |_, _| {})?;
    report.print_summary();
    let ident_scores_paid = engine.ident_scores_paid;

    let threads = crate::util::threadpool::num_threads().max(1);
    let completed = report.outcome_count(RequestOutcome::Completed);
    let goodput_per_core = completed as f64 / (report.wall_s.max(1e-9) * threads as f64);
    let breakdown = report.scenario_breakdown();
    let rows: Vec<Json> = breakdown
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("scenario", Json::str(&s.scenario)),
                ("requests", Json::num(s.requests as f64)),
                ("completed", Json::num(s.completed as f64)),
                ("p50_ttft_s", Json::num(s.p50_ttft_s)),
                ("p99_ttft_s", Json::num(s.p99_ttft_s)),
                ("plan_hits", Json::num(s.plan_hits as f64)),
                ("plan_misses", Json::num(s.plan_misses as f64)),
                ("plan_hit_rate", Json::num(s.plan_hit_rate())),
                ("speculative_hits", Json::num(s.speculative_hits as f64)),
                ("speculative_fallbacks", Json::num(s.speculative_fallbacks as f64)),
                ("speculative_hit_rate", Json::num(s.speculative_hit_rate())),
                ("evictions", Json::num(s.evictions as f64)),
            ])
        })
        .collect();
    let digest_hex = format!("{digest:016x}");
    let rep = bench_report_json(
        "serve_bench",
        &opts.scenario,
        seed,
        rows,
        vec![
            ("requests", Json::num(trace.len() as f64)),
            ("completed", Json::num(completed as f64)),
            ("wall_s", Json::num(report.wall_s)),
            ("p50_ttft_s", Json::num(report.ttft_percentile(0.50))),
            ("p95_ttft_s", Json::num(report.ttft_percentile(0.95))),
            ("p99_ttft_s", Json::num(report.ttft_percentile(0.99))),
            ("p99_e2e_s", Json::num(report.e2e_percentile(0.99))),
            ("goodput_per_core", Json::num(goodput_per_core)),
            ("kv_evictions", Json::num(report.kv_evictions as f64)),
            ("peak_queue_depth", Json::num(report.peak_queue_depth as f64)),
            ("reuse", Json::str(opts.reuse.name())),
            ("ident_cost_paid", Json::num(ident_scores_paid)),
            (
                "speculative_hits",
                Json::num(report.records.iter().map(|r| r.speculative_hits).sum::<u64>() as f64),
            ),
            (
                "speculative_fallbacks",
                Json::num(
                    report.records.iter().map(|r| r.speculative_fallbacks).sum::<u64>() as f64,
                ),
            ),
            ("stream_digest", Json::str(&digest_hex)),
            ("gate_tolerance", Json::num(GATE_TOLERANCE)),
            ("baseline", opts.baseline.as_deref().map(Json::str).unwrap_or(Json::Null)),
        ],
    );
    let path = write_json_report("bench_serve.json", &rep)?;
    println!("wrote {}", path.display());

    if let Some(bp) = &opts.baseline {
        // A gate over zero completed requests would compare empty-slice
        // percentile zeros against real ceilings and pass every one.
        ensure!(
            completed > 0,
            "serve SLO gate vs '{bp}': zero completed requests — nothing \
             to gate, refusing to pass vacuously"
        );
        let text = std::fs::read_to_string(bp)
            .with_context(|| format!("reading baseline '{bp}'"))?;
        let baseline =
            Json::parse(&text).map_err(|e| anyhow::anyhow!("baseline '{bp}': {e}"))?;
        let lines = check_slo(&baseline, &rep, GATE_TOLERANCE)
            .with_context(|| format!("serve SLO gate vs '{bp}'"))?;
        println!("gate vs {bp} (tolerance {:.0}%):", GATE_TOLERANCE * 100.0);
        for l in lines {
            println!("  {l}");
        }
    }
    Ok(rep)
}

/// Resolve a gate key against the report: summary fields by name
/// (`p99_ttft_s`), per-scenario row fields as `<scenario>:<field>`
/// (`shared-prefix:plan_hit_rate`).
fn metric(rep: &Json, key: &str) -> Option<f64> {
    if let Some((tag, field)) = key.split_once(':') {
        return rep
            .get("rows")
            .as_arr()?
            .iter()
            .find(|row| row.get("scenario").as_str() == Some(tag))
            .and_then(|row| row.get(field).as_f64());
    }
    rep.get(key).as_f64()
}

/// Percentile metrics (`p50_…`, `p99_…`) come from slices that return
/// 0.0 when empty: a zero there is the empty-slice sentinel, not a
/// measurement, and must never pass a ceiling vacuously.
fn is_percentile_key(key: &str) -> bool {
    let field = key.rsplit(':').next().unwrap_or(key);
    let mut chars = field.chars();
    chars.next() == Some('p') && chars.next().is_some_and(|c| c.is_ascii_digit())
}

/// Apply a baseline's SLO gate to a run report. `ceilings` are maxima
/// (latency-like, slack `1 + tol`), `floors` are minima (rate-like,
/// slack `1 - tol`), and `shared_prefix_beats_needle: true` demands the
/// deterministic reuse ordering with no slack at all. Every gated key
/// must resolve in the report — a renamed metric fails loudly, and so do
/// the vacuous-pass shapes: a run that completed zero requests, a
/// non-finite gated value, or a ceiling-gated percentile sitting at the
/// empty-slice 0.0.
pub fn check_slo(baseline: &Json, rep: &Json, tol: f64) -> Result<Vec<String>> {
    if let Some(completed) = rep.get("completed").as_f64() {
        ensure!(
            completed > 0.0,
            "SLO gate refused: the run completed zero requests, so every \
             latency percentile is the empty-slice 0.0 and any ceiling \
             would pass vacuously"
        );
    }
    let mut lines = Vec::new();
    let mut failures = Vec::new();
    let mut bound = |keys: &Json, ceiling: bool| -> Result<()> {
        let Json::Obj(map) = keys else {
            return Ok(()); // absent section: nothing gated
        };
        for (key, bound_v) in map {
            let bound_v = bound_v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("baseline bound '{key}' is not a number"))?;
            ensure!(
                bound_v.is_finite(),
                "baseline bound '{key}' is non-finite ({bound_v})"
            );
            let cur = metric(rep, key)
                .ok_or_else(|| anyhow::anyhow!("gated metric '{key}' missing from this run"))?;
            ensure!(
                cur.is_finite(),
                "gated metric '{key}' is non-finite ({cur}) — refusing a \
                 vacuous comparison"
            );
            ensure!(
                !(ceiling && cur == 0.0 && is_percentile_key(key)),
                "ceiling-gated percentile '{key}' is exactly 0.0 — the \
                 empty-slice sentinel, not a measurement; the run recorded \
                 nothing to gate"
            );
            let (ok, rel) = if ceiling {
                (cur <= bound_v * (1.0 + tol), cur / bound_v.max(1e-12))
            } else {
                (cur >= bound_v * (1.0 - tol), cur / bound_v.max(1e-12))
            };
            let line = format!(
                "{key}: {cur:.4} vs {} {bound_v:.4} ({rel:.2}x)",
                if ceiling { "ceiling" } else { "floor" }
            );
            if ok {
                lines.push(format!("OK   {line}"));
            } else {
                failures.push(format!("FAIL {line}"));
            }
        }
        Ok(())
    };
    bound(baseline.get("ceilings"), true)?;
    bound(baseline.get("floors"), false)?;
    if baseline.get("shared_prefix_beats_needle").as_bool() == Some(true) {
        let sp = metric(rep, "shared-prefix:plan_hit_rate")
            .ok_or_else(|| anyhow::anyhow!("no shared-prefix scenario in this run"))?;
        let needle = metric(rep, "needle:plan_hit_rate")
            .ok_or_else(|| anyhow::anyhow!("no needle scenario in this run"))?;
        let line = format!("shared-prefix hit rate {sp:.4} vs needle {needle:.4}");
        if sp > needle {
            lines.push(format!("OK   {line}"));
        } else {
            failures.push(format!("FAIL {line}"));
        }
    }
    ensure!(
        failures.is_empty(),
        "SLO gate failed:\n{}",
        failures.join("\n")
    );
    lines.extend(failures);
    Ok(lines)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rep() -> Json {
        Json::obj(vec![
            ("p99_ttft_s", Json::num(0.5)),
            ("goodput_per_core", Json::num(4.0)),
            (
                "rows",
                Json::arr(
                    [("shared-prefix", 0.8), ("needle", 0.0)].iter().map(|(tag, hr)| {
                        Json::obj(vec![
                            ("scenario", Json::str(tag)),
                            ("plan_hit_rate", Json::num(*hr)),
                        ])
                    }),
                ),
            ),
        ])
    }

    #[test]
    fn fold_key_separates_needle_keys() {
        // Needle keys count down from u64::MAX; folding must keep them
        // distinct (they'd otherwise fake cache hits between needles).
        let keys: std::collections::HashSet<u32> =
            (0..1000u64).map(|i| fold_key(u64::MAX - i)).collect();
        assert_eq!(keys.len(), 1000);
        // Tenant-scoped keys with distinct low halves stay distinct too.
        assert_ne!(fold_key(1 << 32), fold_key(2 << 32));
        assert_ne!(fold_key((1 << 32) | 3), fold_key((1 << 32) | 4));
    }

    #[test]
    fn slo_gate_passes_within_tolerance_and_orders_scenarios() {
        let baseline = Json::parse(
            r#"{"ceilings": {"p99_ttft_s": 0.45},
                "floors": {"goodput_per_core": 4.5,
                           "shared-prefix:plan_hit_rate": 0.75},
                "shared_prefix_beats_needle": true}"#,
        )
        .unwrap();
        // 0.5 <= 0.45*1.15, 4.0 >= 4.5*0.85, 0.8 >= 0.75*0.85, 0.8 > 0.0.
        let lines = check_slo(&baseline, &rep(), GATE_TOLERANCE).unwrap();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.starts_with("OK")));
    }

    #[test]
    fn slo_gate_fails_on_regression_and_on_missing_metrics() {
        let tight = Json::parse(r#"{"ceilings": {"p99_ttft_s": 0.2}}"#).unwrap();
        let err = check_slo(&tight, &rep(), GATE_TOLERANCE).unwrap_err();
        assert!(err.to_string().contains("p99_ttft_s"), "{err}");
        // A floor violation fails too.
        let floor = Json::parse(r#"{"floors": {"goodput_per_core": 9.0}}"#).unwrap();
        assert!(check_slo(&floor, &rep(), GATE_TOLERANCE).is_err());
        // Gating a metric the run never produced is an error, not a skip.
        let missing = Json::parse(r#"{"floors": {"no_such_metric": 1.0}}"#).unwrap();
        let err = check_slo(&missing, &rep(), GATE_TOLERANCE).unwrap_err();
        assert!(err.to_string().contains("no_such_metric"), "{err}");
        // An absent scenario row fails the ordering check loudly.
        let order = Json::parse(r#"{"shared_prefix_beats_needle": true}"#).unwrap();
        let mut no_rows = rep();
        if let Json::Obj(m) = &mut no_rows {
            m.insert("rows".into(), Json::Arr(vec![]));
        }
        assert!(check_slo(&order, &no_rows, GATE_TOLERANCE).is_err());
    }

    #[test]
    fn gate_fails_loudly_on_vacuous_runs() {
        let baseline = Json::parse(r#"{"ceilings": {"p99_ttft_s": 0.45}}"#).unwrap();
        // Zero completed requests: the gate refuses before comparing.
        let mut vacuous = rep();
        if let Json::Obj(m) = &mut vacuous {
            m.insert("completed".into(), Json::num(0.0));
        }
        let err = check_slo(&baseline, &vacuous, GATE_TOLERANCE).unwrap_err();
        assert!(err.to_string().contains("zero requests"), "{err}");
        // A ceiling-gated percentile at the empty-slice 0.0 is an error,
        // never an OK line (0.0 <= any positive ceiling would pass).
        let mut empty_pct = rep();
        if let Json::Obj(m) = &mut empty_pct {
            m.insert("completed".into(), Json::num(3.0));
            m.insert("p99_ttft_s".into(), Json::num(0.0));
        }
        let err = check_slo(&baseline, &empty_pct, GATE_TOLERANCE).unwrap_err();
        assert!(err.to_string().contains("empty-slice"), "{err}");
        // A non-finite gated value is an error for floors too (NaN/inf
        // comparisons would otherwise fail confusingly or pass silently).
        let floors = Json::parse(r#"{"floors": {"goodput_per_core": 1.0}}"#).unwrap();
        let mut nan = rep();
        if let Json::Obj(m) = &mut nan {
            m.insert("goodput_per_core".into(), Json::num(f64::NAN));
        }
        let err = check_slo(&floors, &nan, GATE_TOLERANCE).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        // A genuinely-zero non-percentile ceiling (e.g. eviction counts)
        // still gates normally — the sentinel check is percentile-only.
        let evict = Json::parse(r#"{"ceilings": {"kv_evictions": 5.0}}"#).unwrap();
        let mut quiet = rep();
        if let Json::Obj(m) = &mut quiet {
            m.insert("kv_evictions".into(), Json::num(0.0));
        }
        let lines = check_slo(&evict, &quiet, GATE_TOLERANCE).unwrap();
        assert!(lines.iter().any(|l| l.starts_with("OK") && l.contains("kv_evictions")));
    }

    #[test]
    fn reversed_ordering_fails_the_gate() {
        let order = Json::parse(r#"{"shared_prefix_beats_needle": true}"#).unwrap();
        let flipped = Json::obj(vec![(
            "rows",
            Json::arr([("shared-prefix", 0.1), ("needle", 0.6)].iter().map(|(tag, hr)| {
                Json::obj(vec![
                    ("scenario", Json::str(tag)),
                    ("plan_hit_rate", Json::num(*hr)),
                ])
            })),
        )]);
        assert!(check_slo(&order, &flipped, GATE_TOLERANCE).is_err());
    }
}

//! Table 1 — block vs stripe granularity at matched top-k budgets.
//!
//! Paper (128k RULER, LLaMA): block (128,128) top-k=256 → recall 88.5 %,
//! sparsity 56.3 %; stripe (128,1) top-k=16384 → recall 91.2 %, sparsity
//! 76.6 %. The claim to reproduce: **stripe achieves higher sparsity at
//! equal-or-higher recall** for the same selection budget class.

use super::common::{self, ExpScale};
use crate::attention::strategy::{pooled_scores, select, Granularity, Strategy};
use crate::attention::metrics;
use crate::util::write_report;
use crate::workload::qkv::generate;

pub fn run(scale: ExpScale, seed: u64) -> Vec<Vec<String>> {
    let n = scale.main_n();
    let tile = scale.tile();
    // Budgets scaled from the paper's 128k numbers.
    let k_block = ((256.0 * n as f64 / 131072.0).round() as usize).max(2);
    let k_stripe = ((16384.0 * n as f64 / 131072.0).round() as usize).max(16);

    println!("\n=== Table 1: identification granularity (n = {}) ===", crate::util::fmt_len(n));
    let profile = common::default_profile();
    let wl = generate(&profile, n, seed);
    let ps = pooled_scores(&wl.head, tile);

    let block_cov = select(&ps, Strategy::TopK { k: k_block }, Granularity::Block);
    let stripe_cov = select(&ps, Strategy::TopK { k: k_stripe }, Granularity::Stripe);
    let r_block = metrics::recall(&wl.head, &block_cov, tile);
    let r_stripe = metrics::recall(&wl.head, &stripe_cov, tile);

    let rows = vec![
        vec![
            format!("Block (Top-K={k_block})"),
            crate::util::pct(r_block.mean_recall),
            crate::util::pct(block_cov.sparsity()),
        ],
        vec![
            format!("Stripe (Top-K={k_stripe})"),
            crate::util::pct(r_stripe.mean_recall),
            crate::util::pct(stripe_cov.sparsity()),
        ],
    ];
    common::print_table(&["Method", "Recall Rate", "Sparsity Rate"], &rows);
    println!(
        "paper @128k: Block 88.5% / 56.3%   Stripe 91.2% / 76.6%  (shape target: stripe wins both)"
    );

    let csv = common::to_csv(&["method", "recall", "sparsity"], &rows);
    let _ = write_report("tab1_granularity.csv", &csv);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripe_dominates_block_on_structured_workload() {
        let rows = run(ExpScale::Quick, 11);
        let parse = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
        let block_recall = parse(&rows[0][1]);
        let stripe_recall = parse(&rows[1][1]);
        let block_sparsity = parse(&rows[0][2]);
        let stripe_sparsity = parse(&rows[1][2]);
        // The paper's Table 1 shape: stripe >= block on both axes.
        assert!(stripe_recall >= block_recall - 2.0, "{stripe_recall} vs {block_recall}");
        assert!(stripe_sparsity > block_sparsity, "{stripe_sparsity} vs {block_sparsity}");
    }
}

//! Table 2 — LongBench-style accuracy across 16 task families.
//!
//! Proxy (DESIGN.md §1): each task family is a distinct workload shape
//! (profile × head kind × length × needle presence), and accuracy is the
//! output-fidelity score of the sparse method against dense attention —
//! the mechanism behind downstream-task accuracy differences. Shape to
//! reproduce: Ours ≈ Full-attn > FlexPrefill / Vertical_Slash >
//! StreamingLLM on retrieval-flavored tasks; all methods close on
//! summarization-flavored (local) tasks.

use super::common::{self, ExpScale};
use super::tab3_ruler::niah_accuracy;
use crate::attention::metrics;
use crate::util::write_report;
use crate::workload::qkv::{generate, generate_with_needle, HeadKind};
use crate::workload::WorkloadProfile;

/// One LongBench-style task family.
pub struct Task {
    pub name: &'static str,
    pub kind: HeadKind,
    pub len_frac: f64,
    pub retrieval: bool,
}

/// The 16 LongBench tasks, mapped to workload shapes: QA and synthetic
/// retrieval tasks are needle-bearing; summarization/few-shot/code lean on
/// local+diffuse structure.
pub fn tasks() -> Vec<Task> {
    use HeadKind::*;
    vec![
        Task { name: "NarrQA", kind: Retrieval, len_frac: 1.0, retrieval: true },
        Task { name: "Qasper", kind: Retrieval, len_frac: 0.5, retrieval: true },
        Task { name: "MF-en", kind: Retrieval, len_frac: 0.75, retrieval: true },
        Task { name: "HotpotQA", kind: Retrieval, len_frac: 1.0, retrieval: true },
        Task { name: "2Wiki", kind: Retrieval, len_frac: 0.5, retrieval: true },
        Task { name: "Musique", kind: Retrieval, len_frac: 1.0, retrieval: true },
        Task { name: "GovRep", kind: LocalHeavy, len_frac: 1.0, retrieval: false },
        Task { name: "QMSum", kind: LocalHeavy, len_frac: 0.75, retrieval: false },
        Task { name: "MNews", kind: LocalHeavy, len_frac: 0.25, retrieval: false },
        Task { name: "TREC", kind: Diffuse, len_frac: 0.25, retrieval: false },
        Task { name: "Trivia", kind: Diffuse, len_frac: 0.5, retrieval: false },
        Task { name: "SAMSum", kind: LocalHeavy, len_frac: 0.25, retrieval: false },
        Task { name: "PCount", kind: SinkHeavy, len_frac: 0.5, retrieval: false },
        Task { name: "PR-en", kind: Retrieval, len_frac: 1.0, retrieval: true },
        Task { name: "Lcc", kind: LocalHeavy, len_frac: 0.25, retrieval: false },
        Task { name: "RP-P", kind: LocalHeavy, len_frac: 0.5, retrieval: false },
    ]
}

pub fn run_for_profile(
    scale: ExpScale,
    profile: &WorkloadProfile,
    label: &str,
    seed: u64,
) -> Vec<Vec<String>> {
    let tile = scale.tile();
    let base_n = scale.main_n() / 2; // LongBench inputs are shorter

    println!("\n=== Table 2 (LongBench proxy, {label}) ===");
    let mut rows = Vec::new();
    let mut method_scores: std::collections::BTreeMap<String, Vec<f64>> = Default::default();

    for (ti, task) in tasks().iter().enumerate() {
        let n = (((base_n as f64 * task.len_frac) as usize) / (tile.b_q * 2) * (tile.b_q * 2))
            .max(tile.b_q * 4);
        let p = profile.clone().with_kind(task.kind);
        let tseed = seed ^ ((ti as u64) << 16);
        let (wl, needle) = if task.retrieval {
            let wl = generate_with_needle(&p, n, tseed, Some(0.3 + 0.05 * ti as f64 % 0.6));
            let pos = wl.meta.needle.as_ref().unwrap().position;
            (wl, Some(pos))
        } else {
            (generate(&p, n, tseed), None)
        };
        let full = crate::attention::full::full_attention(&wl.head, tile);

        let mut row = vec![task.name.to_string()];
        for m in common::paper_methods(n, tile, 12.0) {
            let mut session = m.session().no_cache().build().expect("session");
            let out = session.run(&wl.head).expect("run").into_single();
            let score = match needle {
                Some(pos) => niah_accuracy(&wl.head, &out.coverage, &out.out, &full.out, pos, tile),
                None => metrics::fidelity_score(&out.out, &full.out, 0.25),
            };
            row.push(format!("{score:.1}"));
            method_scores.entry(m.name().to_string()).or_default().push(score);
        }
        rows.push(row);
    }

    common::print_table(
        &["task", "full-attn", "streaming", "v-slash", "flexprefill", "anchor(ours)"],
        &rows,
    );

    println!("\n--- averages ({label}) ---");
    let avg_rows: Vec<Vec<String>> = method_scores
        .iter()
        .map(|(m, xs)| vec![m.clone(), format!("{:.1}", crate::util::stats::mean(xs))])
        .collect();
    common::print_table(&["method", "avg"], &avg_rows);
    println!("paper avg (LLaMA): full 39.6 > ours 38.2 > flex 36.7 ≈ v-slash 36.5 > streaming 33.8");
    rows
}

pub fn run(scale: ExpScale, seed: u64) -> Vec<Vec<String>> {
    let mut all = run_for_profile(scale, &WorkloadProfile::llama_like(), "llama-like", seed);
    if scale == ExpScale::Full {
        all.extend(run_for_profile(scale, &WorkloadProfile::qwen_like(), "qwen-like", seed ^ 2));
    }
    let csv = common::to_csv(
        &["task", "full", "streaming", "vslash", "flexprefill", "anchor"],
        &all,
    );
    let _ = write_report("tab2_longbench.csv", &csv);
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sixteen_tasks_defined() {
        assert_eq!(tasks().len(), 16);
        assert!(tasks().iter().filter(|t| t.retrieval).count() >= 6);
    }

    #[test]
    fn anchor_beats_streaming_on_average() {
        let rows = run_for_profile(
            ExpScale::Quick,
            &WorkloadProfile::llama_like(),
            "test",
            99,
        );
        // Columns: task, full, streaming, vslash, flexprefill, anchor.
        let avg = |col: usize| -> f64 {
            let xs: Vec<f64> = rows.iter().map(|r| r[col].parse().unwrap()).collect();
            crate::util::stats::mean(&xs)
        };
        let full = avg(1);
        let streaming = avg(2);
        let anchor = avg(5);
        assert!(full >= anchor - 1.0, "full {full} vs anchor {anchor}");
        assert!(anchor > streaming, "anchor {anchor} vs streaming {streaming}");
        assert!(anchor > 80.0, "anchor absolute score {anchor}");
    }
}

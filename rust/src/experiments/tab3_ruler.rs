//! Table 3 — RULER-style accuracy across context lengths.
//!
//! Proxy (DESIGN.md §1): a needle key is planted at a random depth; a
//! method's accuracy combines (a) whether its coverage retains the needle
//! for the query blocks after it and (b) output fidelity of the final
//! block (where the "answer" is produced). Shape to reproduce: Full ≈
//! Anchor ≥ FlexPrefill ≈ Vertical_Slash ≫ StreamingLLM, with the gap
//! widening as context grows (paper Table 3).

use super::common::{self, ExpScale};
use crate::attention::{metrics, HeadInput, TileConfig};
use crate::attention::mask::Coverage;
use crate::util::{fmt_len, write_report};
use crate::workload::qkv::generate_with_needle;
use crate::workload::WorkloadProfile;

/// Needle-retrieval accuracy (0-100) of a coverage+output pair.
pub fn niah_accuracy(
    head: &HeadInput,
    cov: &Coverage,
    out: &crate::tensor::Mat,
    full_out: &crate::tensor::Mat,
    needle_pos: usize,
    tile: TileConfig,
) -> f64 {
    let n = head.n();
    let needle_block = needle_pos / tile.b_q;
    let q_blocks = cov.q_blocks();
    // Coverage component: fraction of post-needle query blocks seeing it.
    let post: Vec<usize> = (needle_block + 1..q_blocks).collect();
    let cov_frac = if post.is_empty() {
        1.0
    } else {
        post.iter().filter(|&&qb| cov.covered(qb, needle_pos)).count() as f64 / post.len() as f64
    };
    // Fidelity component: final block's output must match dense attention
    // (that is where the retrieval answer is read off).
    let last_rows = tile.b_q.min(n);
    let sparse_tail = out.rows_mat(n - last_rows, last_rows);
    let full_tail = full_out.rows_mat(n - last_rows, last_rows);
    let fid = metrics::fidelity_score(&sparse_tail, &full_tail, 0.25) / 100.0;
    100.0 * cov_frac * fid
}

pub fn run_for_profile(
    scale: ExpScale,
    profile: &WorkloadProfile,
    label: &str,
    seed: u64,
) -> Vec<Vec<String>> {
    let tile = scale.tile();
    let depths = [0.15, 0.5, 0.85];

    println!("\n=== Table 3 (RULER proxy, {label}) ===");
    let mut rows = Vec::new();
    let mut per_method: std::collections::BTreeMap<String, Vec<f64>> = Default::default();
    for n in scale.lengths() {
        let methods = common::paper_methods(n, tile, 12.0);
        for m in &methods {
            // Uncached: each depth is an unrelated input (no plan reuse).
            let mut session = m.session().no_cache().build().expect("session");
            let mut scores = Vec::new();
            for (di, &depth) in depths.iter().enumerate() {
                let wl = generate_with_needle(profile, n, seed ^ ((di as u64) << 20), Some(depth));
                let needle = wl.meta.needle.as_ref().unwrap().position;
                let full = crate::attention::full::full_attention(&wl.head, tile);
                let out = session.run(&wl.head).expect("run").into_single();
                scores.push(niah_accuracy(&wl.head, &out.coverage, &out.out, &full.out, needle, tile));
            }
            let avg = crate::util::stats::mean(&scores);
            rows.push(vec![fmt_len(n), m.name().to_string(), format!("{avg:.1}")]);
            per_method.entry(m.name().to_string()).or_default().push(avg);
        }
    }
    common::print_table(&["length", "method", "accuracy"], &rows);

    println!("\n--- per-method average across lengths ---");
    let avg_rows: Vec<Vec<String>> = per_method
        .iter()
        .map(|(m, xs)| vec![m.clone(), format!("{:.1}", crate::util::stats::mean(xs))])
        .collect();
    common::print_table(&["method", "avg accuracy"], &avg_rows);
    rows
}

pub fn run(scale: ExpScale, seed: u64) -> Vec<Vec<String>> {
    let mut all = run_for_profile(scale, &WorkloadProfile::llama_like(), "llama-like", seed);
    if scale == ExpScale::Full {
        all.extend(run_for_profile(scale, &WorkloadProfile::qwen_like(), "qwen-like", seed ^ 1));
    }
    let csv = common::to_csv(&["length", "method", "accuracy"], &all);
    let _ = write_report("tab3_ruler.csv", &csv);
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_attention_scores_perfect() {
        let scale = ExpScale::Quick;
        let profile = WorkloadProfile::llama_like();
        let tile = scale.tile();
        let wl = generate_with_needle(&profile, 2048, 5, Some(0.5));
        let needle = wl.meta.needle.as_ref().unwrap().position;
        let full = crate::attention::full::full_attention(&wl.head, tile);
        let acc = niah_accuracy(&wl.head, &full.coverage, &full.out, &full.out, needle, tile);
        assert!((acc - 100.0).abs() < 1e-6);
    }

    #[test]
    fn streaming_misses_mid_needle() {
        // The paper's core Table 3 finding: StreamingLLM cannot retrieve
        // mid-context needles; anchor can.
        let profile = WorkloadProfile::llama_like();
        let tile = TileConfig::new(128, 128);
        let n = 4096;
        let wl = generate_with_needle(&profile, n, 17, Some(0.5));
        let needle = wl.meta.needle.as_ref().unwrap().position;
        let full = crate::attention::full::full_attention(&wl.head, tile);

        let methods = common::paper_methods(n, tile, 12.0);
        let streaming = &methods[1];
        let anchor = &methods[4];
        let s_out =
            streaming.session().no_cache().build().unwrap().run(&wl.head).unwrap().into_single();
        let a_out =
            anchor.session().no_cache().build().unwrap().run(&wl.head).unwrap().into_single();
        let s_acc = niah_accuracy(&wl.head, &s_out.coverage, &s_out.out, &full.out, needle, tile);
        let a_acc = niah_accuracy(&wl.head, &a_out.coverage, &a_out.out, &full.out, needle, tile);
        assert!(a_acc > 90.0, "anchor accuracy {a_acc}");
        assert!(s_acc < a_acc - 20.0, "streaming {s_acc} vs anchor {a_acc}");
    }
}

//! Table 4 — anchor ablation: θ sweep with and without the anchor
//! (the "without" arm zeroes the anchor tensor, exactly as the paper
//! implements it). Shape to reproduce: with the anchor, the θ sweep walks
//! a much better sparsity-recall frontier (high sparsity at high recall);
//! without it, matching recall requires collapsing sparsity.

use super::common::{self, ExpScale};
use crate::attention::anchor::{anchor_attention_timed, AnchorConfig};
use crate::attention::metrics;
use crate::util::write_report;
use crate::workload::qkv::generate;

pub fn run(scale: ExpScale, seed: u64) -> Vec<Vec<String>> {
    let tile = scale.tile();
    let n = scale.main_n();
    let profile = common::default_profile();
    let wl = generate(&profile, n, seed);
    let thetas: Vec<f32> = match scale {
        ExpScale::Quick => vec![10.0, 12.0, 14.0],
        ExpScale::Full => vec![10.0, 11.0, 12.0, 13.0, 14.0, 15.0],
    };

    println!("\n=== Table 4: anchor ablation (n = {}) ===", crate::util::fmt_len(n));
    let mut rows = Vec::new();
    // Arms: (label, use_anchor, θ offset). At the paper's θ values the
    // zero-anchor rule `−qk ≤ θ` selects everything on this workload
    // (background logits sit near 0, not at the strongly negative levels
    // of the authors' models), so a θ−14 supplementary sweep exposes the
    // without-anchor frontier for the dominance comparison.
    let arms: [(&str, bool, f32); 3] =
        [("With Anchor", true, 0.0), ("Without Anchor", false, 0.0), ("Without Anchor*", false, -14.0)];
    for (label, use_anchor, offset) in arms {
        for &theta in &thetas {
            let step = common::scaled_step(n, tile);
            let cfg =
                AnchorConfig { tile, theta: theta + offset, step, init_blocks: 1, use_anchor };
            let (out, timings) = anchor_attention_timed(&wl.head, &cfg);
            let rec = metrics::recall(&wl.head, &out.coverage, tile);
            rows.push(vec![
                label.to_string(),
                format!("{:.1}", theta + offset),
                crate::util::pct(out.coverage.sparsity()),
                crate::util::pct(rec.mean_recall),
                format!("{:.1}", timings.total_s() * 1e3),
            ]);
        }
    }
    common::print_table(
        &["Anchor Attention", "θ", "Sparsity", "Recall", "Time (ms)"],
        &rows,
    );
    println!("paper @128k, θ=12: With 89%/82.8%/8.2ms — Without 52%/90.2%/29.5ms");
    println!("(shape target: at matched recall, With-Anchor keeps far higher sparsity & lower time)");

    let csv = common::to_csv(&["arm", "theta", "sparsity", "recall", "time_ms"], &rows);
    let _ = write_report("tab4_ablation.csv", &csv);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchor_frontier_dominates() {
        let rows = run(ExpScale::Quick, 77);
        let parse = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
        // For each recall the Without arm achieves, the With arm must offer
        // at least one point with >= that recall and >= that sparsity - eps.
        let with: Vec<(f64, f64)> =
            rows.iter().filter(|r| r[0] == "With Anchor").map(|r| (parse(&r[3]), parse(&r[2]))).collect();
        let without: Vec<(f64, f64)> = rows
            .iter()
            .filter(|r| r[0] == "Without Anchor")
            .map(|r| (parse(&r[3]), parse(&r[2])))
            .collect();
        for &(wr, ws) in &without {
            let dominated = with.iter().any(|&(r, s)| r >= wr - 1.0 && s >= ws - 1.0);
            assert!(dominated, "without-anchor point (recall {wr}, sparsity {ws}) not dominated");
        }
    }

    #[test]
    fn sparsity_decreases_with_theta() {
        let rows = run(ExpScale::Quick, 78);
        let parse = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
        let with: Vec<f64> =
            rows.iter().filter(|r| r[0] == "With Anchor").map(|r| parse(&r[2])).collect();
        for w in with.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "sparsity must fall as θ rises: {w:?}");
        }
    }
}

//! # AnchorAttention
//!
//! Reproduction of *“Anchor Attention: Difference-Aware Sparse Attention
//! with Stripe Granularity”* (EMNLP 2025) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L3 (this crate)** — serving coordinator (router, dynamic batcher,
//!   paged KV cache, chunked-prefill scheduler) plus the full experiment
//!   substrate: a multithreaded blocked attention engine implementing the
//!   paper's three algorithms and all evaluated baselines.
//! * **L2/L1 (`python/compile/`)** — JAX model and Pallas kernels, AOT
//!   lowered to HLO text and executed from Rust via the PJRT C API
//!   ([`runtime`]).
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod attention;
pub mod config;
pub mod experiments;
pub mod coordinator;
pub mod model;
pub mod plan_codec;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod util;
pub mod wire;
pub mod workload;

//! `anchor-attn` — launcher CLI for the AnchorAttention reproduction.
//!
//! Subcommands:
//!   selftest                      PJRT + artifact sanity checks
//!   serve       [--config F]      serve a synthetic trace over PJRT; all
//!                                 flags funnel through one typed
//!                                 ServeOverrides path (--executor
//!                                 cpu|pjrt names the plan executor in
//!                                 the scheduler's cost attribution;
//!                                 --plan-store F warms the plan-hit
//!                                 prior from a populated manifest plan
//!                                 store; --shards N prices head-group
//!                                 sharding, DESIGN.md §12; --transport
//!                                 threads|process picks the shard-worker
//!                                 transport, DESIGN.md §14;
//!                                 --max-pending N caps admission;
//!                                 --reuse exact|cross-layer|prefix picks
//!                                 the speculative plan-reuse policy
//!                                 (--recall-floor F tightens its recall
//!                                 gate), DESIGN.md §17;
//!                                 --calibration F loads machine-measured
//!                                 cost constants persisted by `calibrate`,
//!                                 DESIGN.md §13)
//!   worker      --uds P | --tcp A serve the coordinate-only wire protocol
//!                                 as a shard worker process (spawned by
//!                                 process-transport sessions, or started
//!                                 manually and addressed via endpoints;
//!                                 DESIGN.md §14)
//!   calibrate   [--manifest F]    measure the scheduler's cost constants
//!                                 (span read, discrete gather, tile fold,
//!                                 ident-vs-dense) on this machine and
//!                                 persist them into the runtime manifest
//!                                 (--executor cpu|pjrt|both, --quick,
//!                                 --wire measures the broadcast constant
//!                                 over a real framed socket round-trip,
//!                                 --show reloads + prices a 64k context)
//!   bench <exp> [--quick]         run one experiment driver
//!                                 (fig2|tab1|fig4|fig5|fig6|fig7|tab2|tab3|tab4|all,
//!                                 plus micro — the gated micro-bench suite,
//!                                 standalone, not part of `all`;
//!                                 micro extras: --baseline F gates ratios
//!                                 against a committed baseline, >15% fails;
//!                                 plus serve — the SLO serving harness,
//!                                 standalone, not part of `all`: replays a
//!                                 scenario-library trace (--scenario
//!                                 long-doc|rag|shared-prefix|needle|mixed)
//!                                 through the real serve path and reports
//!                                 TTFT percentiles, goodput-per-core and
//!                                 per-scenario plan hit rates into
//!                                 reports/bench_serve.json; --requests N
//!                                 sizes the trace, --baseline F gates p99
//!                                 TTFT and plan-hit-rate floors, --reuse
//!                                 exact|cross-layer|prefix turns on
//!                                 speculative plan reuse in the per-request
//!                                 sessions, DESIGN.md §16/§17;
//!                                 plus reuse — the cross-layer commonality
//!                                 sweep, standalone: layer distance vs
//!                                 recall-check verdicts into
//!                                 reports/bench_reuse.json, DESIGN.md §17)
//!                                 fig2 extras: --pipeline (overlap ident with
//!                                 execution), --iters N, --lengths a,b,c,
//!                                 --executor cpu|pjrt|both (backend grid),
//!                                 --plan-store F (manifest-backed plan
//!                                 persistence: cold vs warm identification),
//!                                 --step S (anchor identification step),
//!                                 --shards 1,2,4 (head-group shard grid),
//!                                 --wire-shards 1,2 (process-worker grid:
//!                                 same measurement through spawned wire
//!                                 workers, parity-gated against threads)
//!   dominance   [--n N]           Fig. 5 measurement at arbitrary length
//!   store <op>  --manifest F      segmented plan-store maintenance
//!                                 (DESIGN.md §15): `inspect` reports the
//!                                 index — format, entry/segment counts,
//!                                 models, bytes — without decoding
//!                                 payloads (--json for machine-readable
//!                                 output); `compact` merges segments and
//!                                 deletes superseded files; `migrate`
//!                                 imports a legacy JSON-blob store into
//!                                 segments (a no-op once migrated —
//!                                 opening does it transparently too)
//!   tpu-estimate                  L1 VMEM/MXU block-shape table
//!   gen-trace   [--rate R]        print a synthetic serving trace

use anchor_attention::attention::exec::ExecutorKind;
use anchor_attention::attention::reuse::ReusePolicy;
use anchor_attention::attention::session::SessionTransport;
use anchor_attention::attention::Method;
use anchor_attention::config::AppConfig;
use anchor_attention::coordinator::engine::PjrtEngine;
use anchor_attention::coordinator::scheduler::{CostConstants, SparsityModel};
use anchor_attention::coordinator::server::{serve_requests, ServeOverrides, ServeRequest};
use anchor_attention::experiments::{self, ExpScale};
use anchor_attention::util::cli::Args;
use anchor_attention::workload::trace::generate_trace;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("selftest") => selftest(&args),
        Some("serve") => cmd_serve(&args),
        Some("worker") => cmd_worker(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("bench") => cmd_bench(&args),
        Some("dominance") => cmd_dominance(&args),
        Some("store") => cmd_store(&args),
        Some("tpu-estimate") => cmd_tpu(),
        Some("gen-trace") => cmd_gen_trace(&args),
        _ => {
            eprintln!(
                "usage: anchor-attn <selftest|serve|worker|calibrate|bench|dominance|store|tpu-estimate|gen-trace> [flags]"
            );
            eprintln!(
                "  bench experiments: fig2 tab1 fig4 fig5 fig6 fig7 tab2 tab3 tab4 all micro \
                 serve reuse"
            );
            eprintln!("  store ops: inspect compact migrate (--manifest F [--json])");
            Ok(())
        }
    }
}

fn load_config(args: &Args) -> anyhow::Result<AppConfig> {
    match args.get("config") {
        Some(path) => AppConfig::load(path),
        None => Ok(AppConfig::default()),
    }
}

fn selftest(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    println!("[1/3] PJRT CPU client…");
    let rt = anchor_attention::runtime::Runtime::open(&cfg.artifact_dir)?;
    println!("      platform = {}", rt.platform());
    println!("[2/3] manifest…");
    rt.manifest().validate()?;
    println!(
        "      {} artifacts, {} params",
        rt.manifest().artifacts.len(),
        rt.manifest().weights.params.len()
    );
    println!("[3/3] compile + run attn_full_256…");
    let q = vec![0.1f32; 256 * 64];
    let out = rt.execute(
        "attn_full_256",
        &[
            anchor_attention::runtime::literal_f32(&[256, 64], &q)?,
            anchor_attention::runtime::literal_f32(&[256, 64], &q)?,
            anchor_attention::runtime::literal_f32(&[256, 64], &q)?,
        ],
    )?;
    anyhow::ensure!(out.len() == 1);
    println!("selftest OK");
    Ok(())
}

/// Parse `--reuse` (plus the optional `--recall-floor` tightener) into a
/// speculative plan-reuse policy; `None` when the flag is absent.
fn reuse_flag(args: &Args) -> anyhow::Result<Option<ReusePolicy>> {
    let Some(s) = args.get("reuse") else {
        anyhow::ensure!(
            args.get("recall-floor").is_none(),
            "--recall-floor requires --reuse cross-layer|prefix"
        );
        return Ok(None);
    };
    let mut policy = ReusePolicy::parse(s)?;
    if args.get("recall-floor").is_some() {
        anyhow::ensure!(!policy.is_exact(), "--recall-floor has no effect with --reuse exact");
        let floor = args.f64_or("recall-floor", 0.0)?;
        anyhow::ensure!(
            (0.0..=1.0).contains(&floor),
            "--recall-floor must be in [0, 1] (got {floor})"
        );
        policy = policy.with_recall_floor(floor);
    }
    Ok(Some(policy))
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let mut cfg = load_config(args)?;
    // Every serve-time flag funnels through one typed override struct —
    // the same validated path the config file and the wire front-end
    // share (`ServerConfig::apply_overrides`): no per-flag surgery on the
    // scheduler here, and every bad value is a descriptive error.
    let overrides = ServeOverrides {
        rate: match args.get("rate") {
            Some(_) => Some(args.f64_or("rate", 0.0)?),
            None => None,
        },
        num_requests: match args.get("requests") {
            Some(_) => Some(args.usize_or("requests", 0)?),
            None => None,
        },
        anchor_sched: args.has("anchor-sched"),
        pipeline: args.bool_or("pipeline", false)?,
        executor: match args.get("executor") {
            Some(s) => Some(ExecutorKind::parse(s)?),
            None => None,
        },
        shards: match args.get("shards") {
            Some(_) => Some(args.usize_or("shards", 1)?),
            None => None,
        },
        transport: match args.get("transport") {
            Some(s) => Some(SessionTransport::parse(s)?),
            None => None,
        },
        calibration: args.get("calibration").map(|s| s.to_string()),
        plan_store: args.get("plan-store").map(|s| s.to_string()),
        max_pending: match args.get("max-pending") {
            Some(_) => Some(args.usize_or("max-pending", 0)?),
            None => None,
        },
        reuse: reuse_flag(args)?,
    };
    overrides.apply_trace(&mut cfg.trace);
    cfg.server.apply_overrides(&overrides)?;
    overrides.apply_session(&mut cfg.session)?;
    if let Some(path) = &overrides.calibration {
        if let SparsityModel::Anchor { executor, constants: c, .. } = cfg.server.scheduler.sparsity
        {
            println!(
                "calibration: '{}' constants from {path} (ident {:.4}, broadcast {:.6}, \
                 span {:.2} ns/row, gather {:.2} ns/row, fold {:.3} ns/score)",
                executor.name(),
                c.ident_cost_frac,
                c.plan_broadcast_frac,
                c.span_ns_per_row,
                c.gather_ns_per_row,
                c.fold_ns_per_score
            );
        }
    }
    // Report the shard pricing actually in effect: the dense model never
    // prices shards, and a config file may set scheduler.shards
    // independently of session.shards — print the scheduler's own value.
    if let SparsityModel::Anchor { shards, .. } = cfg.server.scheduler.sparsity {
        if shards > 1 {
            println!(
                "sharding: scheduler cost model priced for {shards} head-group shard \
                 workers (near-linear exec scaling + plan-broadcast term, DESIGN.md §12)"
            );
        }
    }
    // The probe validates the whole session block — shard count, plan
    // store path, transport included — at startup: a bad path, a disabled
    // cache, or an unreachable worker endpoint fails fast with the
    // builder's error; a populated store guarantees first-touch
    // plan-cache hits for previously seen keys, so it warms the
    // scheduler's amortization prior (DESIGN.md §11/§12).
    if cfg.session.transport == SessionTransport::Process {
        println!("transport: process shard workers over the coordinate-only wire (DESIGN.md §14)");
    }
    let probe = cfg.session.sharded_builder(Method::Anchor(cfg.anchor)).build()?;
    if let (Some(total), Some(compatible)) = (probe.store_len(), probe.store_len_compatible()) {
        println!(
            "plan store: {total} persisted plan(s), {compatible} seedable by model '{}'",
            cfg.session.model
        );
        // Only plans this session could actually seed from (model tag +
        // method + geometry) justify the amortization prior — a store
        // populated by some other cell, or by a differently-configured
        // anchor, must not fake hits.
        if compatible > 0 {
            cfg.server.scheduler.sparsity.observe_plan_hit_rate(1.0);
        }
    }
    drop(probe);

    println!("loading engine from {} …", cfg.artifact_dir);
    let mut engine = PjrtEngine::new(&cfg.artifact_dir)?;
    let vocab = engine.vocab() as i32;

    // Submissions go through the typed front door: a prompt that cannot
    // fit `max_seq` is rejected with an explicit Oversized status (and
    // shows up in the report's outcome counts) instead of being silently
    // clamped into shape.
    let trace = generate_trace(&cfg.trace)?;
    let submissions: Vec<ServeRequest> = trace
        .iter()
        .map(|t| {
            let prompt: Vec<i32> = (0..t.prompt_tokens)
                .map(|i| ((t.id as usize * 131 + i * 7) % vocab as usize) as i32)
                .collect();
            ServeRequest {
                id: t.id,
                prompt,
                max_new_tokens: t.decode_tokens,
                arrival_s: t.arrival_s,
            }
        })
        .collect();
    println!("serving {} requests (rate {}/s)…", submissions.len(), cfg.trace.rate);

    let (report, responses) = serve_requests(&cfg.server, submissions, &mut engine, |e, r| {
        e.register(r.id, r.prompt.clone());
    })?;
    for r in responses.iter().filter(|r| !r.is_accepted()) {
        println!("rejected request {}: {} — {}", r.id, r.status.name(), r.detail);
    }
    report.print_summary();
    Ok(())
}

/// `worker` — serve the coordinate-only wire protocol (DESIGN.md §14) as a
/// shard worker process. Process-transport sessions spawn these
/// themselves over private UDS sockets; started manually (`--tcp` or
/// `--uds`) the endpoint can be handed to a session via
/// `RemoteSpec::Endpoints`. Blocks until a coordinator sends Shutdown
/// (UDS) or forever accepting connections (TCP).
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    match (args.get("uds"), args.get("tcp")) {
        (Some(path), None) => {
            anchor_attention::wire::worker::serve_uds(std::path::Path::new(path))
        }
        (None, Some(addr)) => anchor_attention::wire::worker::serve_tcp(addr),
        _ => anyhow::bail!("worker requires exactly one of --uds PATH or --tcp ADDR"),
    }
}

/// `calibrate` — measure the scheduler's cost constants on this machine
/// (DESIGN.md §13) and persist them under the runtime manifest's
/// `calibration` key; `serve --calibration F` loads them back. `--show`
/// skips measurement and reloads the stored set through the exact loader
/// serve uses, pricing a 64k context to prove the scheduler consumes it.
fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    use anchor_attention::coordinator::calibrate::calibrate_with;
    use anchor_attention::runtime::manifest::{load_calibration, save_calibration};
    let manifest = args.get("manifest");
    let quick = args.bool_or("quick", false)?;
    // `--wire` measures the plan-broadcast constant over a real framed
    // socket round-trip (delta-encoded coordinates through the wire
    // codec) instead of the in-memory clone proxy — the measured number
    // `serve --transport process` should be priced with (DESIGN.md §14).
    let wire = args.bool_or("wire", false)?;
    let kinds = match args.get("executor") {
        None => vec![ExecutorKind::default()],
        Some("both") => vec![ExecutorKind::Cpu, ExecutorKind::Pjrt],
        Some(s) => vec![ExecutorKind::parse(s)
            .map_err(|_| anyhow::anyhow!("--executor expects cpu|pjrt|both, got '{s}'"))?],
    };
    // One anchor model per report line: what the constants do to pricing.
    let price_64k = |constants: CostConstants| {
        let model = SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 256,
            plan_hit_rate: 0.5,
            speculative_hit_rate: 0.0,
            pipelined: false,
            executor: ExecutorKind::default(),
            shards: 1,
            constants,
        };
        model.effective_context(65536)
    };
    if args.bool_or("show", false)? {
        let path = manifest
            .ok_or_else(|| anyhow::anyhow!("calibrate --show requires --manifest F"))?;
        for kind in kinds {
            match load_calibration(path, kind)? {
                Some(c) => {
                    println!(
                        "{}: ident_cost_frac {:.4}  plan_broadcast_frac {:.6}  \
                         span {:.2} ns/row  gather {:.2} ns/row  fold {:.3} ns/score",
                        kind.name(),
                        c.ident_cost_frac,
                        c.plan_broadcast_frac,
                        c.span_ns_per_row,
                        c.gather_ns_per_row,
                        c.fold_ns_per_score
                    );
                    println!(
                        "    effective_context(65536): modeled {:.0} -> calibrated {:.0}",
                        price_64k(CostConstants::modeled()),
                        price_64k(c)
                    );
                }
                None => println!("{}: no calibration stored in {path}", kind.name()),
            }
        }
        return Ok(());
    }
    for kind in kinds {
        println!(
            "calibrating executor '{}' ({} mode{})…",
            kind.name(),
            if quick { "quick" } else { "full" },
            if wire { ", wire broadcast" } else { "" }
        );
        let cal = calibrate_with(kind, quick, wire);
        for r in &cal.rows {
            println!("  {}", r.report_line());
        }
        let c = cal.constants;
        println!(
            "  derived: ident_cost_frac {:.4} (ident {:.3} ms / dense {:.3} ms)",
            c.ident_cost_frac,
            cal.ident_s * 1e3,
            cal.dense_exec_s * 1e3
        );
        println!(
            "           plan_broadcast_frac {:.6} (broadcast {:.4} ms)",
            c.plan_broadcast_frac,
            cal.broadcast_s * 1e3
        );
        println!(
            "           span {:.2} ns/row  gather {:.2} ns/row  fold {:.3} ns/score",
            c.span_ns_per_row, c.gather_ns_per_row, c.fold_ns_per_score
        );
        println!(
            "  effective_context(65536): modeled {:.0} -> calibrated {:.0}",
            price_64k(CostConstants::modeled()),
            price_64k(c)
        );
        match manifest {
            Some(path) => {
                save_calibration(path, kind, &c)?;
                let back = load_calibration(path, kind)?;
                anyhow::ensure!(
                    back == Some(c),
                    "calibration did not round-trip through '{path}'"
                );
                println!("  persisted to {path} (calibration.executors.{})", kind.name());
            }
            None => println!("  (dry run — pass --manifest F to persist)"),
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let scale = ExpScale::from_quick_flag(args.bool_or("quick", false)?);
    let seed = args.u64_or("seed", 42)?;
    let which = args.positional().get(1).map(|s| s.as_str()).unwrap_or("all");
    // fig2-only knobs: `--pipeline` overlaps identification with execution,
    // `--iters N` / `--lengths a,b,c` pin the measurement grid (CI bench),
    // `--executor cpu|pjrt|both` picks the backend grid, `--plan-store F`
    // persists plans through the manifest (cold vs warm identification),
    // `--step S` overrides the anchor identification step (re-measure
    // grid), `--shards 1,2,4` measures the head-group shard grid
    // (DESIGN.md §12; rows land under `shard_grid` in `BENCH_fig2.json`).
    let lengths = args.usize_list_or("lengths", &[])?;
    let shard_counts = args.usize_list_or("shards", &[])?;
    anyhow::ensure!(
        shard_counts.iter().all(|&s| s >= 1),
        "--shards entries must be >= 1 (got {shard_counts:?})"
    );
    // `--wire-shards 1,2` re-runs the anchor measurement through spawned
    // process workers (coordinate-only wire, DESIGN.md §14), gating each
    // row bitwise against the in-thread shard path; rows land under
    // `wire_grid` in `BENCH_fig2.json`.
    let wire_shards = args.usize_list_or("wire-shards", &[])?;
    anyhow::ensure!(
        wire_shards.iter().all(|&s| s >= 1),
        "--wire-shards entries must be >= 1 (got {wire_shards:?})"
    );
    let executors = match args.get("executor") {
        None => vec![ExecutorKind::default()],
        Some("both") => vec![ExecutorKind::Cpu, ExecutorKind::Pjrt],
        Some(s) => vec![ExecutorKind::parse(s)
            .map_err(|_| anyhow::anyhow!("--executor expects cpu|pjrt|both, got '{s}'"))?],
    };
    let plan_store = args.get("plan-store").map(|s| s.to_string());
    if let Some(p) = &plan_store {
        // Fail fast with the store's descriptive error instead of
        // panicking mid-measurement; fig2's sessions re-open it per run.
        anchor_attention::runtime::manifest::PlanStore::open(p)?;
    }
    let fig2_opts = experiments::fig2_speedup::Fig2Options {
        pipeline: args.bool_or("pipeline", false)?,
        iters: match args.get("iters") {
            Some(_) => Some(args.usize_or("iters", 1)?),
            None => None,
        },
        lengths: if lengths.is_empty() { None } else { Some(lengths) },
        executors,
        plan_store,
        step: match args.get("step") {
            Some(_) => {
                let s = args.usize_or("step", 16)?;
                anyhow::ensure!(s >= 1, "--step must be >= 1 (got {s})");
                Some(s)
            }
            None => None,
        },
        shards: if shard_counts.is_empty() { vec![1] } else { shard_counts },
        wire_shards,
    };
    // micro-only knob: `--baseline F` gates the suite's dimensionless
    // ratios against a committed baseline — a >15% regression on any
    // gated ratio is an error (nonzero exit; the CI raw-speed gate).
    let micro_opts = experiments::micro::MicroOptions {
        baseline: args.get("baseline").map(|s| s.to_string()),
    };
    // serve-only knobs: `--scenario NAME` picks the workload scenario
    // (long-doc|rag|shared-prefix|needle|mixed), `--requests N` sizes the
    // trace, `--baseline F` gates p99 TTFT / plan-hit-rate floors,
    // `--reuse exact|cross-layer|prefix` turns on speculative plan reuse
    // in the per-request sessions (DESIGN.md §17).
    let serve_opts = experiments::serve_bench::ServeBenchOptions {
        scenario: args.get("scenario").unwrap_or("mixed").to_string(),
        requests: match args.get("requests") {
            Some(_) => Some(args.usize_or("requests", 0)?),
            None => None,
        },
        baseline: args.get("baseline").map(|s| s.to_string()),
        reuse: reuse_flag(args)?.unwrap_or(ReusePolicy::Exact),
    };
    let run_one = |name: &str| -> anyhow::Result<()> {
        match name {
            "fig2" => drop(experiments::fig2_speedup::run_with(scale, seed, &fig2_opts)),
            "tab1" => drop(experiments::tab1_granularity::run(scale, seed)),
            "fig4" => drop(experiments::fig4_strategies::run(scale, seed)),
            "fig5" => drop(experiments::fig5_dominance::run(scale, seed)),
            "fig6" => drop(experiments::fig6_tradeoffs::run(scale, seed)),
            "fig7" => drop(experiments::fig7_needle::run(scale, seed)),
            "tab2" => drop(experiments::tab2_longbench::run(scale, seed)),
            "tab3" => drop(experiments::tab3_ruler::run(scale, seed)),
            "tab4" => drop(experiments::tab4_ablation::run(scale, seed)),
            // Standalone: the micro suite times executor primitives, not a
            // paper figure, so `all` (the paper sweep) does not include it.
            "micro" => drop(experiments::micro::run_with(scale, seed, &micro_opts)?),
            // Standalone: the serving harness measures SLO metrics over
            // the coordinator, not a paper figure, so `all` skips it too.
            "serve" => drop(experiments::serve_bench::run_with(scale, seed, &serve_opts)?),
            // Standalone: the cross-layer commonality sweep (layer
            // distance vs speculative-recall verdicts, DESIGN.md §17).
            "reuse" => drop(experiments::reuse::run_with(scale, seed)?),
            other => eprintln!("unknown experiment '{other}'"),
        }
        Ok(())
    };
    if which == "all" {
        for name in ["fig2", "tab1", "fig4", "fig5", "fig6", "fig7", "tab2", "tab3", "tab4"] {
            run_one(name)?;
        }
    } else {
        run_one(which)?;
    }
    Ok(())
}

fn cmd_dominance(args: &Args) -> anyhow::Result<()> {
    let n = args.usize_or("n", 8192)?;
    let seed = args.u64_or("seed", 42)?;
    for (name, p) in [
        ("llama-like", anchor_attention::workload::WorkloadProfile::llama_like()),
        ("qwen-like", anchor_attention::workload::WorkloadProfile::qwen_like()),
    ] {
        let wl = anchor_attention::workload::qkv::generate(&p, n, seed);
        let (init, win, stripe, other) =
            anchor_attention::workload::qkv::dominance_breakdown(&wl, p.sink_tokens, 128);
        println!(
            "{name:>12}: {:.2}% anchor (init {:.1}%, window {:.1}%) | stripes {:.1}% | other {:.1}%",
            (init + win) * 100.0, init * 100.0, win * 100.0, stripe * 100.0, other * 100.0
        );
    }
    Ok(())
}

/// `store <inspect|compact|migrate> --manifest F [--json]` — maintenance
/// front-end for the segmented plan store (DESIGN.md §15). `inspect` is
/// strictly read-only: it reports from the index and the segment files'
/// metadata without decoding a single payload, so it is safe against a
/// store another process is actively writing.
fn cmd_store(args: &Args) -> anyhow::Result<()> {
    use anchor_attention::runtime::manifest::{PlanStore, PLAN_STORE_FORMAT};
    use anchor_attention::runtime::segment;
    use anchor_attention::util::json::Json;
    let op = args.positional().get(1).map(|s| s.as_str());
    let usage = "usage: anchor-attn store <inspect|compact|migrate> --manifest F [--json]";
    let Some(op) = op else {
        eprintln!("{usage}");
        return Ok(());
    };
    let manifest = args
        .get("manifest")
        .ok_or_else(|| anyhow::anyhow!("store {op}: --manifest F is required\n{usage}"))?
        .to_string();
    match op {
        "inspect" => {
            let text = std::fs::read_to_string(&manifest)
                .map_err(|e| anyhow::anyhow!("store inspect {manifest}: {e}"))?;
            let doc = Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("store inspect {manifest}: not valid JSON: {e}"))?;
            let ps = doc.get("plan_store");
            let format = if ps.is_null() {
                "none"
            } else if ps.get("format").as_str() == Some(PLAN_STORE_FORMAT) {
                PLAN_STORE_FORMAT
            } else if ps.get("format").is_null() {
                "legacy-json"
            } else {
                "unknown"
            };
            let mut models: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
            let mut total_entries = 0usize;
            let mut payload_bytes = 0u64;
            // (file, entries, payload bytes, on-disk bytes or null)
            let mut segments: Vec<(String, usize, u64, Option<u64>)> = Vec::new();
            let dir = segment::segments_dir(std::path::Path::new(&manifest));
            if format == PLAN_STORE_FORMAT {
                for seg in ps.get("entries").as_arr().unwrap_or(&[]) {
                    let file = seg.get("segment").as_str().unwrap_or("<malformed>").to_string();
                    let mut seg_entries = 0usize;
                    let mut seg_payload = 0u64;
                    for g in seg.get("groups").as_arr().unwrap_or(&[]) {
                        if let Some(m) = g.get("model").as_str() {
                            models.insert(m.to_string());
                        }
                        for rec in g.get("keys").as_arr().unwrap_or(&[]) {
                            seg_entries += 1;
                            seg_payload += rec.idx(3).as_f64().unwrap_or(0.0) as u64;
                        }
                    }
                    total_entries += seg_entries;
                    payload_bytes += seg_payload;
                    let file_bytes = std::fs::metadata(dir.join(&file)).ok().map(|m| m.len());
                    segments.push((file, seg_entries, seg_payload, file_bytes));
                }
            } else if format == "legacy-json" {
                for e in ps.get("entries").as_arr().unwrap_or(&[]) {
                    total_entries += 1;
                    if let Some(m) = e.get("model").as_str() {
                        models.insert(m.to_string());
                    }
                }
            }
            if args.has("json") {
                let report = Json::obj(vec![
                    ("manifest", Json::str(&manifest)),
                    ("format", Json::str(format)),
                    (
                        "version",
                        ps.get("version").as_usize().map_or(Json::Null, |v| Json::num(v as f64)),
                    ),
                    (
                        "migrated_from",
                        ps.get("migrated_from").as_str().map_or(Json::Null, Json::str),
                    ),
                    ("entries", Json::num(total_entries as f64)),
                    ("payload_bytes", Json::num(payload_bytes as f64)),
                    ("models", Json::arr(models.iter().map(|m| Json::str(m)))),
                    (
                        "segments",
                        Json::arr(segments.iter().map(|(file, entries, payload, disk)| {
                            Json::obj(vec![
                                ("file", Json::str(file)),
                                ("entries", Json::num(*entries as f64)),
                                ("payload_bytes", Json::num(*payload as f64)),
                                (
                                    "file_bytes",
                                    disk.map_or(Json::Null, |b| Json::num(b as f64)),
                                ),
                            ])
                        })),
                    ),
                ]);
                println!("{}", report.to_string_pretty());
            } else {
                println!("{manifest}: plan store format={format}, {total_entries} entries");
                if let Some(m) = ps.get("migrated_from").as_str() {
                    println!("  migrated from: {m}");
                }
                if !models.is_empty() {
                    println!(
                        "  models: {}",
                        models.iter().cloned().collect::<Vec<_>>().join(", ")
                    );
                }
                for (file, entries, payload, disk) in &segments {
                    println!(
                        "  {file}: {entries} entries, {payload} payload bytes{}",
                        match disk {
                            Some(b) => format!(", {b} bytes on disk"),
                            None => ", MISSING on disk".to_string(),
                        }
                    );
                }
            }
            Ok(())
        }
        "compact" => {
            let mut store = PlanStore::open(&manifest)?;
            let stats = store.compact()?;
            println!(
                "{manifest}: compacted {} segment(s) into {} ({} entries, {} file(s) removed)",
                stats.segments_before, stats.segments_after, stats.entries, stats.files_removed
            );
            Ok(())
        }
        "migrate" => {
            // Opening migrates a legacy store transparently (and is a
            // no-op on an already-segmented one); this just makes the
            // one-time import an explicit, observable step.
            let store = PlanStore::open(&manifest)?;
            println!("{manifest}: {} entr(ies) ready in the segmented store", store.len());
            Ok(())
        }
        other => Err(anyhow::anyhow!("store: unknown op '{other}'\n{usage}")),
    }
}

fn cmd_tpu() -> anyhow::Result<()> {
    use anchor_attention::simulator::tpu::{estimate, KernelTiles, TpuCore};
    let core = TpuCore::default();
    println!("{:<22} {:>12} {:>10} {:>8}", "tile (b_q,b_kv,d)", "VMEM bytes", "VMEM %", "MXU %");
    for (bq, bkv, d) in [
        (128, 128, 128),
        (128, 128, 64),
        (256, 128, 128),
        (128, 256, 128),
        (256, 256, 128),
        (512, 128, 128),
    ] {
        let e = estimate(
            &core,
            &KernelTiles { b_q: bq, b_kv: bkv, d, elem_bytes: 2, double_buffered: true },
        );
        println!(
            "{:<22} {:>12} {:>9.1}% {:>7.1}%{}",
            format!("({bq},{bkv},{d})"),
            e.vmem_bytes,
            e.vmem_frac * 100.0,
            e.mxu_utilization * 100.0,
            if e.fits { "" } else { "  OVERFLOW" }
        );
    }
    Ok(())
}

fn cmd_gen_trace(args: &Args) -> anyhow::Result<()> {
    let mut cfg = load_config(args)?.trace;
    cfg.rate = args.f64_or("rate", cfg.rate)?;
    cfg.num_requests = args.usize_or("requests", cfg.num_requests)?;
    for r in generate_trace(&cfg)? {
        println!(
            "{{\"id\": {}, \"arrival_s\": {:.3}, \"prompt_tokens\": {}, \"decode_tokens\": {}}}",
            r.id, r.arrival_s, r.prompt_tokens, r.decode_tokens
        );
    }
    Ok(())
}

//! `anchor-attn` — launcher CLI for the AnchorAttention reproduction.
//!
//! Subcommands:
//!   selftest                      PJRT + artifact sanity checks
//!   serve       [--config F]      serve a synthetic trace over PJRT
//!                                 (--executor cpu|pjrt names the plan
//!                                 executor backend in the scheduler's
//!                                 cost attribution; --plan-store F warms
//!                                 the plan-hit prior from a populated
//!                                 manifest plan store; --shards N prices
//!                                 head-group sharding, DESIGN.md §12;
//!                                 --calibration F loads machine-measured
//!                                 cost constants persisted by `calibrate`,
//!                                 DESIGN.md §13)
//!   calibrate   [--manifest F]    measure the scheduler's cost constants
//!                                 (span read, discrete gather, tile fold,
//!                                 ident-vs-dense) on this machine and
//!                                 persist them into the runtime manifest
//!                                 (--executor cpu|pjrt|both, --quick,
//!                                 --show reloads + prices a 64k context)
//!   bench <exp> [--quick]         run one experiment driver
//!                                 (fig2|tab1|fig4|fig5|fig6|fig7|tab2|tab3|tab4|all,
//!                                 plus micro — the gated micro-bench suite,
//!                                 standalone, not part of `all`;
//!                                 micro extras: --baseline F gates ratios
//!                                 against a committed baseline, >15% fails)
//!                                 fig2 extras: --pipeline (overlap ident with
//!                                 execution), --iters N, --lengths a,b,c,
//!                                 --executor cpu|pjrt|both (backend grid),
//!                                 --plan-store F (manifest-backed plan
//!                                 persistence: cold vs warm identification),
//!                                 --step S (anchor identification step),
//!                                 --shards 1,2,4 (head-group shard grid)
//!   dominance   [--n N]           Fig. 5 measurement at arbitrary length
//!   tpu-estimate                  L1 VMEM/MXU block-shape table
//!   gen-trace   [--rate R]        print a synthetic serving trace

use anchor_attention::attention::exec::ExecutorKind;
use anchor_attention::attention::Method;
use anchor_attention::config::AppConfig;
use anchor_attention::coordinator::engine::PjrtEngine;
use anchor_attention::coordinator::request::Request;
use anchor_attention::coordinator::scheduler::{CostConstants, SparsityModel};
use anchor_attention::coordinator::server::serve;
use anchor_attention::experiments::{self, ExpScale};
use anchor_attention::util::cli::Args;
use anchor_attention::workload::trace::generate_trace;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("selftest") => selftest(&args),
        Some("serve") => cmd_serve(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("bench") => cmd_bench(&args),
        Some("dominance") => cmd_dominance(&args),
        Some("tpu-estimate") => cmd_tpu(),
        Some("gen-trace") => cmd_gen_trace(&args),
        _ => {
            eprintln!(
                "usage: anchor-attn <selftest|serve|calibrate|bench|dominance|tpu-estimate|gen-trace> [flags]"
            );
            eprintln!("  bench experiments: fig2 tab1 fig4 fig5 fig6 fig7 tab2 tab3 tab4 all micro");
            Ok(())
        }
    }
}

fn load_config(args: &Args) -> anyhow::Result<AppConfig> {
    match args.get("config") {
        Some(path) => AppConfig::load(path),
        None => Ok(AppConfig::default()),
    }
}

fn selftest(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    println!("[1/3] PJRT CPU client…");
    let rt = anchor_attention::runtime::Runtime::open(&cfg.artifact_dir)?;
    println!("      platform = {}", rt.platform());
    println!("[2/3] manifest…");
    rt.manifest().validate()?;
    println!(
        "      {} artifacts, {} params",
        rt.manifest().artifacts.len(),
        rt.manifest().weights.params.len()
    );
    println!("[3/3] compile + run attn_full_256…");
    let q = vec![0.1f32; 256 * 64];
    let out = rt.execute(
        "attn_full_256",
        &[
            anchor_attention::runtime::literal_f32(&[256, 64], &q)?,
            anchor_attention::runtime::literal_f32(&[256, 64], &q)?,
            anchor_attention::runtime::literal_f32(&[256, 64], &q)?,
        ],
    )?;
    anyhow::ensure!(out.len() == 1);
    println!("selftest OK");
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let mut cfg = load_config(args)?;
    cfg.trace.rate = args.f64_or("rate", cfg.trace.rate)?;
    cfg.trace.num_requests = args.usize_or("requests", cfg.trace.num_requests)?;
    if args.has("anchor-sched") {
        cfg.server.scheduler.sparsity = SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 256,
            plan_hit_rate: 0.0,
            // `--pipeline` prices identification as overlapped with
            // execution (the async plan pipeline, DESIGN.md §9).
            pipelined: args.bool_or("pipeline", false)?,
            executor: ExecutorKind::default(),
            shards: 1,
            constants: CostConstants::modeled(),
        };
    }
    // `--executor cpu|pjrt` names the plan executor backend in the
    // scheduler's cost attribution (config: scheduler.executor).
    if let Some(s) = args.get("executor") {
        let kind = ExecutorKind::parse(s)?;
        if let SparsityModel::Anchor { ref mut executor, .. } = cfg.server.scheduler.sparsity {
            *executor = kind;
        }
    }
    // `--shards N` (config: scheduler.shards / session.shards): head-group
    // shard workers — the cost model prices near-linear exec scaling with
    // a plan-broadcast term (DESIGN.md §12).
    if args.has("shards") {
        let n = args.usize_or("shards", 1)?;
        anyhow::ensure!(n >= 1, "--shards must be >= 1 (got {n})");
        cfg.session.shards = n;
        if let SparsityModel::Anchor { ref mut shards, .. } = cfg.server.scheduler.sparsity {
            *shards = n;
        }
    }
    // `--calibration F` swaps the scheduler's modeled cost constants for
    // the machine-measured set `anchor-attn calibrate` persisted into the
    // runtime manifest (DESIGN.md §13). The lookup keys on the executor
    // backend actually priced, so it runs after --executor is applied.
    if let Some(path) = args.get("calibration") {
        let kind = match cfg.server.scheduler.sparsity {
            SparsityModel::Anchor { executor, .. } => executor,
            _ => anyhow::bail!(
                "--calibration needs the anchor scheduler (pass --anchor-sched \
                 or set scheduler.sparsity in the config)"
            ),
        };
        let c = anchor_attention::runtime::manifest::load_calibration(path, kind)?
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "manifest '{path}' holds no calibration for executor '{}' — run \
                     `anchor-attn calibrate --manifest {path} --executor {}` first",
                    kind.name(),
                    kind.name()
                )
            })?;
        cfg.server.scheduler.sparsity.set_constants(c);
        println!(
            "calibration: '{}' constants from {path} (ident {:.4}, broadcast {:.6}, \
             span {:.2} ns/row, gather {:.2} ns/row, fold {:.3} ns/score)",
            kind.name(),
            c.ident_cost_frac,
            c.plan_broadcast_frac,
            c.span_ns_per_row,
            c.gather_ns_per_row,
            c.fold_ns_per_score
        );
    }
    // Report the shard pricing actually in effect: the dense model never
    // prices shards, and a config file may set scheduler.shards
    // independently of session.shards — print the scheduler's own value.
    if let SparsityModel::Anchor { shards, .. } = cfg.server.scheduler.sparsity {
        if shards > 1 {
            println!(
                "sharding: scheduler cost model priced for {shards} head-group shard \
                 workers (near-linear exec scaling + plan-broadcast term, DESIGN.md §12)"
            );
        }
    }
    // `--plan-store F` (config: session.plan_store) points the session
    // block at a manifest-backed plan store. The probe below validates
    // the whole session block — shard count included — at startup: a bad
    // path, a disabled cache, or a zero shard count fails fast with the
    // builder's error; a populated store guarantees first-touch
    // plan-cache hits for previously seen keys, so it warms the
    // scheduler's amortization prior (DESIGN.md §11/§12).
    if let Some(p) = args.get("plan-store") {
        cfg.session.plan_store = Some(p.to_string());
    }
    let probe = cfg.session.sharded_builder(Method::Anchor(cfg.anchor)).build()?;
    if let (Some(total), Some(compatible)) = (probe.store_len(), probe.store_len_compatible()) {
        println!(
            "plan store: {total} persisted plan(s), {compatible} seedable by model '{}'",
            cfg.session.model
        );
        // Only plans this session could actually seed from (model tag +
        // method + geometry) justify the amortization prior — a store
        // populated by some other cell, or by a differently-configured
        // anchor, must not fake hits.
        if compatible > 0 {
            cfg.server.scheduler.sparsity.observe_plan_hit_rate(1.0);
        }
    }
    drop(probe);

    println!("loading engine from {} …", cfg.artifact_dir);
    let mut engine = PjrtEngine::new(&cfg.artifact_dir)?;
    let vocab = engine.vocab() as i32;

    let trace = generate_trace(&cfg.trace);
    let max_prompt = cfg.server.max_seq.saturating_sub(cfg.trace.decode_max);
    let requests: Vec<Request> = trace
        .iter()
        .map(|t| {
            let len = t.prompt_tokens.min(max_prompt);
            let prompt: Vec<i32> = (0..len)
                .map(|i| ((t.id as usize * 131 + i * 7) % vocab as usize) as i32)
                .collect();
            Request::new(t.id, prompt, t.decode_tokens, t.arrival_s)
        })
        .collect();
    println!("serving {} requests (rate {}/s)…", requests.len(), cfg.trace.rate);

    let report = serve(&cfg.server, requests, &mut engine, |e, r| {
        e.register(r.id, r.prompt.clone());
    })?;
    report.print_summary();
    Ok(())
}

/// `calibrate` — measure the scheduler's cost constants on this machine
/// (DESIGN.md §13) and persist them under the runtime manifest's
/// `calibration` key; `serve --calibration F` loads them back. `--show`
/// skips measurement and reloads the stored set through the exact loader
/// serve uses, pricing a 64k context to prove the scheduler consumes it.
fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    use anchor_attention::coordinator::calibrate::calibrate;
    use anchor_attention::runtime::manifest::{load_calibration, save_calibration};
    let manifest = args.get("manifest");
    let quick = args.bool_or("quick", false)?;
    let kinds = match args.get("executor") {
        None => vec![ExecutorKind::default()],
        Some("both") => vec![ExecutorKind::Cpu, ExecutorKind::Pjrt],
        Some(s) => vec![ExecutorKind::parse(s)
            .map_err(|_| anyhow::anyhow!("--executor expects cpu|pjrt|both, got '{s}'"))?],
    };
    // One anchor model per report line: what the constants do to pricing.
    let price_64k = |constants: CostConstants| {
        let model = SparsityModel::Anchor {
            stripe_keep: 0.1,
            anchor_tokens: 256,
            plan_hit_rate: 0.5,
            pipelined: false,
            executor: ExecutorKind::default(),
            shards: 1,
            constants,
        };
        model.effective_context(65536)
    };
    if args.bool_or("show", false)? {
        let path = manifest
            .ok_or_else(|| anyhow::anyhow!("calibrate --show requires --manifest F"))?;
        for kind in kinds {
            match load_calibration(path, kind)? {
                Some(c) => {
                    println!(
                        "{}: ident_cost_frac {:.4}  plan_broadcast_frac {:.6}  \
                         span {:.2} ns/row  gather {:.2} ns/row  fold {:.3} ns/score",
                        kind.name(),
                        c.ident_cost_frac,
                        c.plan_broadcast_frac,
                        c.span_ns_per_row,
                        c.gather_ns_per_row,
                        c.fold_ns_per_score
                    );
                    println!(
                        "    effective_context(65536): modeled {:.0} -> calibrated {:.0}",
                        price_64k(CostConstants::modeled()),
                        price_64k(c)
                    );
                }
                None => println!("{}: no calibration stored in {path}", kind.name()),
            }
        }
        return Ok(());
    }
    for kind in kinds {
        println!(
            "calibrating executor '{}' ({} mode)…",
            kind.name(),
            if quick { "quick" } else { "full" }
        );
        let cal = calibrate(kind, quick);
        for r in &cal.rows {
            println!("  {}", r.report_line());
        }
        let c = cal.constants;
        println!(
            "  derived: ident_cost_frac {:.4} (ident {:.3} ms / dense {:.3} ms)",
            c.ident_cost_frac,
            cal.ident_s * 1e3,
            cal.dense_exec_s * 1e3
        );
        println!(
            "           plan_broadcast_frac {:.6} (broadcast {:.4} ms)",
            c.plan_broadcast_frac,
            cal.broadcast_s * 1e3
        );
        println!(
            "           span {:.2} ns/row  gather {:.2} ns/row  fold {:.3} ns/score",
            c.span_ns_per_row, c.gather_ns_per_row, c.fold_ns_per_score
        );
        println!(
            "  effective_context(65536): modeled {:.0} -> calibrated {:.0}",
            price_64k(CostConstants::modeled()),
            price_64k(c)
        );
        match manifest {
            Some(path) => {
                save_calibration(path, kind, &c)?;
                let back = load_calibration(path, kind)?;
                anyhow::ensure!(
                    back == Some(c),
                    "calibration did not round-trip through '{path}'"
                );
                println!("  persisted to {path} (calibration.executors.{})", kind.name());
            }
            None => println!("  (dry run — pass --manifest F to persist)"),
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let scale = ExpScale::from_quick_flag(args.bool_or("quick", false)?);
    let seed = args.u64_or("seed", 42)?;
    let which = args.positional().get(1).map(|s| s.as_str()).unwrap_or("all");
    // fig2-only knobs: `--pipeline` overlaps identification with execution,
    // `--iters N` / `--lengths a,b,c` pin the measurement grid (CI bench),
    // `--executor cpu|pjrt|both` picks the backend grid, `--plan-store F`
    // persists plans through the manifest (cold vs warm identification),
    // `--step S` overrides the anchor identification step (re-measure
    // grid), `--shards 1,2,4` measures the head-group shard grid
    // (DESIGN.md §12; rows land under `shard_grid` in `BENCH_fig2.json`).
    let lengths = args.usize_list_or("lengths", &[])?;
    let shard_counts = args.usize_list_or("shards", &[])?;
    anyhow::ensure!(
        shard_counts.iter().all(|&s| s >= 1),
        "--shards entries must be >= 1 (got {shard_counts:?})"
    );
    let executors = match args.get("executor") {
        None => vec![ExecutorKind::default()],
        Some("both") => vec![ExecutorKind::Cpu, ExecutorKind::Pjrt],
        Some(s) => vec![ExecutorKind::parse(s)
            .map_err(|_| anyhow::anyhow!("--executor expects cpu|pjrt|both, got '{s}'"))?],
    };
    let plan_store = args.get("plan-store").map(|s| s.to_string());
    if let Some(p) = &plan_store {
        // Fail fast with the store's descriptive error instead of
        // panicking mid-measurement; fig2's sessions re-open it per run.
        anchor_attention::runtime::manifest::PlanStore::open(p)?;
    }
    let fig2_opts = experiments::fig2_speedup::Fig2Options {
        pipeline: args.bool_or("pipeline", false)?,
        iters: match args.get("iters") {
            Some(_) => Some(args.usize_or("iters", 1)?),
            None => None,
        },
        lengths: if lengths.is_empty() { None } else { Some(lengths) },
        executors,
        plan_store,
        step: match args.get("step") {
            Some(_) => {
                let s = args.usize_or("step", 16)?;
                anyhow::ensure!(s >= 1, "--step must be >= 1 (got {s})");
                Some(s)
            }
            None => None,
        },
        shards: if shard_counts.is_empty() { vec![1] } else { shard_counts },
    };
    // micro-only knob: `--baseline F` gates the suite's dimensionless
    // ratios against a committed baseline — a >15% regression on any
    // gated ratio is an error (nonzero exit; the CI raw-speed gate).
    let micro_opts = experiments::micro::MicroOptions {
        baseline: args.get("baseline").map(|s| s.to_string()),
    };
    let run_one = |name: &str| -> anyhow::Result<()> {
        match name {
            "fig2" => drop(experiments::fig2_speedup::run_with(scale, seed, &fig2_opts)),
            "tab1" => drop(experiments::tab1_granularity::run(scale, seed)),
            "fig4" => drop(experiments::fig4_strategies::run(scale, seed)),
            "fig5" => drop(experiments::fig5_dominance::run(scale, seed)),
            "fig6" => drop(experiments::fig6_tradeoffs::run(scale, seed)),
            "fig7" => drop(experiments::fig7_needle::run(scale, seed)),
            "tab2" => drop(experiments::tab2_longbench::run(scale, seed)),
            "tab3" => drop(experiments::tab3_ruler::run(scale, seed)),
            "tab4" => drop(experiments::tab4_ablation::run(scale, seed)),
            // Standalone: the micro suite times executor primitives, not a
            // paper figure, so `all` (the paper sweep) does not include it.
            "micro" => drop(experiments::micro::run_with(scale, seed, &micro_opts)?),
            other => eprintln!("unknown experiment '{other}'"),
        }
        Ok(())
    };
    if which == "all" {
        for name in ["fig2", "tab1", "fig4", "fig5", "fig6", "fig7", "tab2", "tab3", "tab4"] {
            run_one(name)?;
        }
    } else {
        run_one(which)?;
    }
    Ok(())
}

fn cmd_dominance(args: &Args) -> anyhow::Result<()> {
    let n = args.usize_or("n", 8192)?;
    let seed = args.u64_or("seed", 42)?;
    for (name, p) in [
        ("llama-like", anchor_attention::workload::WorkloadProfile::llama_like()),
        ("qwen-like", anchor_attention::workload::WorkloadProfile::qwen_like()),
    ] {
        let wl = anchor_attention::workload::qkv::generate(&p, n, seed);
        let (init, win, stripe, other) =
            anchor_attention::workload::qkv::dominance_breakdown(&wl, p.sink_tokens, 128);
        println!(
            "{name:>12}: {:.2}% anchor (init {:.1}%, window {:.1}%) | stripes {:.1}% | other {:.1}%",
            (init + win) * 100.0, init * 100.0, win * 100.0, stripe * 100.0, other * 100.0
        );
    }
    Ok(())
}

fn cmd_tpu() -> anyhow::Result<()> {
    use anchor_attention::simulator::tpu::{estimate, KernelTiles, TpuCore};
    let core = TpuCore::default();
    println!("{:<22} {:>12} {:>10} {:>8}", "tile (b_q,b_kv,d)", "VMEM bytes", "VMEM %", "MXU %");
    for (bq, bkv, d) in [
        (128, 128, 128),
        (128, 128, 64),
        (256, 128, 128),
        (128, 256, 128),
        (256, 256, 128),
        (512, 128, 128),
    ] {
        let e = estimate(
            &core,
            &KernelTiles { b_q: bq, b_kv: bkv, d, elem_bytes: 2, double_buffered: true },
        );
        println!(
            "{:<22} {:>12} {:>9.1}% {:>7.1}%{}",
            format!("({bq},{bkv},{d})"),
            e.vmem_bytes,
            e.vmem_frac * 100.0,
            e.mxu_utilization * 100.0,
            if e.fits { "" } else { "  OVERFLOW" }
        );
    }
    Ok(())
}

fn cmd_gen_trace(args: &Args) -> anyhow::Result<()> {
    let mut cfg = load_config(args)?.trace;
    cfg.rate = args.f64_or("rate", cfg.rate)?;
    cfg.num_requests = args.usize_or("requests", cfg.num_requests)?;
    for r in generate_trace(&cfg) {
        println!(
            "{{\"id\": {}, \"arrival_s\": {:.3}, \"prompt_tokens\": {}, \"decode_tokens\": {}}}",
            r.id, r.arrival_s, r.prompt_tokens, r.decode_tokens
        );
    }
    Ok(())
}

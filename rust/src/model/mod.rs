//! Rust-side LM glue: sessions over the AOT serving artifacts
//! (`lm_prefill256`, `lm_decode`) with functional KV caches.
//!
//! A [`LmModel`] owns the compiled executables + weight literals; a
//! [`LmSession`] owns one sequence's KV cache state. Prompts are processed
//! in fixed 256-token chunks (the artifact shape): partial tail chunks are
//! zero-padded, which is exact because within-chunk causality means valid
//! queries never attend padded keys, and the session position only
//! advances by the true token count so later chunks overwrite the padding.

use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::runtime::{literal_i32, literal_i32_scalar, Runtime};

pub struct LmModel {
    runtime: Rc<Runtime>,
    params: Vec<xla::Literal>,
    pub vocab: usize,
    pub max_seq: usize,
    pub prefill_chunk: usize,
    cache_shape: Vec<usize>,
}

impl LmModel {
    pub fn load(runtime: Rc<Runtime>) -> Result<Self> {
        let m = runtime.manifest().model;
        runtime.manifest().validate()?;
        let params = runtime.load_weights()?;
        let cache_shape = vec![m.n_layers, m.n_kv_heads, m.max_seq, m.d_head];
        Ok(Self {
            runtime,
            params,
            vocab: m.vocab,
            max_seq: m.max_seq,
            prefill_chunk: m.prefill_chunk,
            cache_shape,
        })
    }

    /// Eagerly compile both serving executables (avoids first-request
    /// latency spikes; used by the engine at startup).
    pub fn warmup(&self) -> Result<()> {
        self.runtime.executable("lm_prefill256")?;
        self.runtime.executable("lm_decode")?;
        Ok(())
    }

    pub fn new_session(&self) -> Result<LmSession> {
        let zeros = vec![0.0f32; self.cache_shape.iter().product()];
        Ok(LmSession {
            kcache: crate::runtime::literal_f32(&self.cache_shape, &zeros)?,
            vcache: crate::runtime::literal_f32(&self.cache_shape, &zeros)?,
            pos: 0,
        })
    }

    fn run_step(
        &self,
        artifact: &str,
        ids: &[i32],
        session: &mut LmSession,
        true_count: usize,
    ) -> Result<Vec<f32>> {
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(self.params.len() + 4);
        for p in &self.params {
            // Literal clone is a host-side copy; acceptable at this model
            // size (perf pass note: buffer donation would avoid it).
            inputs.push(clone_literal(p)?);
        }
        inputs.push(literal_i32(ids));
        inputs.push(std::mem::replace(&mut session.kcache, xla::Literal::scalar(0f32)));
        inputs.push(std::mem::replace(&mut session.vcache, xla::Literal::scalar(0f32)));
        inputs.push(literal_i32_scalar(session.pos as i32));

        let mut out = self.runtime.execute(artifact, &inputs)?;
        if out.len() != 3 {
            return Err(anyhow!("{artifact}: expected 3 outputs, got {}", out.len()));
        }
        session.vcache = out.pop().unwrap();
        session.kcache = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>()?;
        session.pos += true_count;
        Ok(logits)
    }

    /// Prefill the whole prompt; returns the logits row of the last
    /// *valid* token (`[vocab]`).
    pub fn prefill(&self, session: &mut LmSession, prompt: &[i32]) -> Result<Vec<f32>> {
        if prompt.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        if session.pos + prompt.len() > self.max_seq {
            return Err(anyhow!(
                "prompt of {} tokens exceeds max_seq {} (pos {})",
                prompt.len(),
                self.max_seq,
                session.pos
            ));
        }
        let chunk = self.prefill_chunk;
        let mut last = Vec::new();
        let mut off = 0;
        while off < prompt.len() {
            let take = (prompt.len() - off).min(chunk);
            let mut ids = vec![0i32; chunk];
            ids[..take].copy_from_slice(&prompt[off..off + take]);
            let logits = self.run_step("lm_prefill256", &ids, session, take)?;
            // Last valid row of this chunk.
            let row = take - 1;
            last = logits[row * self.vocab..(row + 1) * self.vocab].to_vec();
            off += take;
        }
        Ok(last)
    }

    /// One decode step; returns next-token logits (`[vocab]`).
    pub fn decode(&self, session: &mut LmSession, token: i32) -> Result<Vec<f32>> {
        if session.pos + 1 > self.max_seq {
            return Err(anyhow!("sequence exceeds max_seq {}", self.max_seq));
        }
        self.run_step("lm_decode", &[token], session, 1)
    }
}

/// One sequence's functional KV-cache state.
pub struct LmSession {
    kcache: xla::Literal,
    vcache: xla::Literal,
    pub pos: usize,
}

/// Greedy sampling.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0;
    let mut bestv = f32::NEG_INFINITY;
    for (i, &x) in logits.iter().enumerate() {
        if x > bestv {
            bestv = x;
            best = i;
        }
    }
    best as i32
}

fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    // The xla crate has no Clone for Literal; round-trip through raw data.
    let shape = l.array_shape()?;
    let dims: Vec<i64> = shape.dims().to_vec();
    let v = l.to_vec::<f32>()?;
    Ok(xla::Literal::vec1(&v).reshape(&dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[-2.0, -1.0, -3.0]), 1);
    }
}

//! Shared delta codec for plan coordinates — one implementation for the
//! wire protocol (DESIGN.md §14) and the segmented plan store (§15).
//!
//! The paper's premise (§3.2–3.4) is that stripe coordinates are sorted
//! and near-arithmetic, so deltas are small and varints shrink them:
//! * stripes: varint count, varint first value, then varint deltas that
//!   must be ≥ 1 — strict ascent is unrepresentable to violate;
//! * spans: varint count, then per span a varint gap from the previous
//!   span's end and a varint length ≥ 1 — overlap is unrepresentable.
//!
//! **Decode validates before it constructs.** `SparsePlan::new` `assert!`s
//! its invariants — a panic is the correct response to a caller bug but
//! the wrong response to a corrupted frame or a bit-flipped segment file.
//! Every decoder here therefore checks the full invariant set (lengths
//! against remaining bytes, group counts against plan geometry,
//! span/stripe ordering) and returns a descriptive `Err` first; the
//! constructor's asserts then re-verify what was already proven.
//!
//! This module was lifted out of `wire/codec.rs` so that storage and wire
//! cannot drift: a plan persisted by the store decodes bitwise-identically
//! to one received off the wire, and the corruption-rejection walls in
//! both test suites exercise the same code. The byte layout is unchanged
//! from the wire original — `put_plan` output is wire-stable.

use anyhow::{anyhow, Result};

use crate::attention::plan::{GroupPlan, SparsePlan};
use crate::attention::{CostTally, TileConfig};
use crate::runtime::manifest::method_static;
use crate::wire::frame::{Dec, Enc};

/// Sanity cap on tile edges, steps, and head dims decoded off the wire or
/// out of a segment — far above anything the grids run, small enough that
/// a corrupted field cannot drive pathological allocation downstream.
pub const MAX_GEOMETRY: u64 = 1 << 20;

pub fn put_tile(e: &mut Enc, t: TileConfig) {
    e.varint(t.b_q as u64);
    e.varint(t.b_kv as u64);
}

pub fn get_tile(d: &mut Dec) -> Result<TileConfig> {
    let b_q = get_geometry(d, "tile b_q")?;
    let b_kv = get_geometry(d, "tile b_kv")?;
    Ok(TileConfig { b_q, b_kv })
}

/// A geometry-sized field: ≥ 1 and ≤ [`MAX_GEOMETRY`].
pub fn get_geometry(d: &mut Dec, what: &str) -> Result<usize> {
    let v = d.varint()?;
    if v == 0 || v > MAX_GEOMETRY {
        return Err(anyhow!("wire: {what} = {v} out of range 1..={MAX_GEOMETRY}"));
    }
    Ok(v as usize)
}

pub fn put_cost(e: &mut Enc, c: CostTally) {
    e.u64(c.flops);
    e.u64(c.kv_bytes);
    e.u64(c.ident_scores);
}

pub fn get_cost(d: &mut Dec) -> Result<CostTally> {
    Ok(CostTally { flops: d.u64()?, kv_bytes: d.u64()?, ident_scores: d.u64()? })
}

pub fn put_group(e: &mut Enc, g: &GroupPlan) {
    e.varint(g.spans.len() as u64);
    let mut prev_end = 0u64;
    for &(s, e_) in &g.spans {
        e.varint(u64::from(s) - prev_end);
        e.varint(u64::from(e_) - u64::from(s));
        prev_end = u64::from(e_);
    }
    e.varint(g.stripes.len() as u64);
    let mut prev = 0u64;
    for (i, &c) in g.stripes.iter().enumerate() {
        if i == 0 {
            e.varint(u64::from(c));
        } else {
            e.varint(u64::from(c) - prev);
        }
        prev = u64::from(c);
    }
}

pub fn get_group(d: &mut Dec, n: u64) -> Result<GroupPlan> {
    let span_count = d.varint()? as usize;
    // Every span costs ≥ 2 payload bytes; bound the allocation by what can
    // actually be present.
    if span_count > d.remaining() {
        return Err(anyhow!(
            "wire: group declares {span_count} spans but only {} bytes remain",
            d.remaining()
        ));
    }
    let mut spans = Vec::with_capacity(span_count.min(1024));
    let mut prev_end = 0u64;
    for _ in 0..span_count {
        let start = prev_end
            .checked_add(d.varint()?)
            .ok_or_else(|| anyhow!("wire: span start overflows"))?;
        let len = d.varint()?;
        if len == 0 {
            return Err(anyhow!("wire: empty span in plan group"));
        }
        let end = start.checked_add(len).ok_or_else(|| anyhow!("wire: span end overflows"))?;
        if end > n {
            return Err(anyhow!("wire: span [{start}, {end}) exceeds plan length {n}"));
        }
        spans.push((start as u32, end as u32));
        prev_end = end;
    }
    let stripe_count = d.varint()? as usize;
    if stripe_count > d.remaining() {
        return Err(anyhow!(
            "wire: group declares {stripe_count} stripes but only {} bytes remain",
            d.remaining()
        ));
    }
    let mut stripes = Vec::with_capacity(stripe_count.min(1024));
    let mut prev = 0u64;
    for i in 0..stripe_count {
        let delta = d.varint()?;
        let col = if i == 0 {
            delta
        } else {
            if delta == 0 {
                return Err(anyhow!("wire: stripe delta of 0 breaks strict ascent"));
            }
            prev.checked_add(delta).ok_or_else(|| anyhow!("wire: stripe overflows"))?
        };
        if col >= n {
            return Err(anyhow!("wire: stripe {col} ≥ plan length {n}"));
        }
        stripes.push(col as u32);
        prev = col;
    }
    Ok(GroupPlan { spans, stripes })
}

/// Encode one plan. The head dim `d_head` rides along because
/// `predicted_cost` is *not* transmitted — the receiver re-prices the
/// decoded coordinates against `d_head`, which is bitwise-identical to the
/// sender's pricing (pure integer walk).
pub fn put_plan(e: &mut Enc, plan: &SparsePlan, d_head: usize) {
    e.str(plan.method);
    e.varint(plan.n as u64);
    e.varint(d_head as u64);
    put_tile(e, plan.tile);
    e.varint(plan.step as u64);
    put_cost(e, plan.ident_cost);
    for g in &plan.groups {
        put_group(e, g);
    }
}

/// Decode and fully validate one plan, then (and only then) hand the
/// coordinates to `SparsePlan::new`, which re-derives `predicted_cost`.
pub fn get_plan(d: &mut Dec) -> Result<SparsePlan> {
    get_plan_with_dim(d).map(|(plan, _)| plan)
}

/// Like [`get_plan`], but also return the head dim the plan was priced
/// against. `SparsePlan` does not store `d`, yet the plan store keys
/// entries by it — storage decode cross-checks this value against the
/// segment index.
pub fn get_plan_with_dim(d: &mut Dec) -> Result<(SparsePlan, usize)> {
    let method = method_static(&d.str()?)?;
    let n = d.varint()?;
    if n == 0 || n > u64::from(u32::MAX) {
        return Err(anyhow!("wire: plan length {n} out of range 1..=u32::MAX"));
    }
    let d_head = get_geometry(d, "plan head dim")?;
    let tile = get_tile(d)?;
    let step = get_geometry(d, "plan step")?;
    let ident_cost = get_cost(d)?;
    let expected = tile.q_blocks(n as usize).div_ceil(step);
    // Each group is ≥ 2 payload bytes; a corrupted n cannot force a giant
    // allocation past what the frame could hold.
    if expected > d.remaining() {
        return Err(anyhow!(
            "wire: plan geometry implies {expected} groups but only {} bytes remain",
            d.remaining()
        ));
    }
    let mut groups = Vec::with_capacity(expected.min(1024));
    for _ in 0..expected {
        groups.push(get_group(d, n)?);
    }
    Ok((SparsePlan::new(method, n as usize, d_head, tile, step, groups, ident_cost), d_head))
}

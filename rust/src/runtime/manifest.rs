//! `artifacts/manifest.json` schema — the contract between `aot.py` and
//! the Rust runtime/model layers.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<Self> {
        let dtype = j.get("dtype").as_str().ok_or_else(|| anyhow!("tensor missing dtype"))?;
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("tensor missing shape"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { dtype: dtype.to_string(), shape })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub count: usize,
}

#[derive(Clone, Debug)]
pub struct WeightsSpec {
    pub file: String,
    pub total_f32: usize,
    pub params: Vec<ParamSpec>,
}

/// Mirror of `python/compile/model.py::ModelCfg`.
#[derive(Clone, Copy, Debug)]
pub struct ModelSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub max_seq: usize,
    pub prefill_chunk: usize,
}

/// Anchor hyperparameters baked into the `attn_anchor_*` artifacts.
#[derive(Clone, Copy, Debug)]
pub struct AnchorSpec {
    pub block: usize,
    pub theta: f64,
    pub step: usize,
    pub init_blocks: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: ModelSpec,
    pub anchor: AnchorSpec,
    pub weights: WeightsSpec,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;

        let m = j.get("model");
        let req = |node: &Json, key: &str| -> Result<usize> {
            node.get(key).as_usize().ok_or_else(|| anyhow!("model.{key} missing"))
        };
        let model = ModelSpec {
            vocab: req(m, "vocab")?,
            d_model: req(m, "d_model")?,
            n_layers: req(m, "n_layers")?,
            n_heads: req(m, "n_heads")?,
            n_kv_heads: req(m, "n_kv_heads")?,
            d_head: req(m, "d_head")?,
            d_ffn: req(m, "d_ffn")?,
            max_seq: req(m, "max_seq")?,
            prefill_chunk: req(m, "prefill_chunk")?,
        };

        let a = j.get("anchor");
        let anchor = AnchorSpec {
            block: req(a, "block")?,
            theta: a.get("theta").as_f64().ok_or_else(|| anyhow!("anchor.theta"))?,
            step: req(a, "step")?,
            init_blocks: req(a, "init_blocks")?,
        };

        let w = j.get("weights");
        let params = w
            .get("params")
            .as_arr()
            .ok_or_else(|| anyhow!("weights.params missing"))?
            .iter()
            .map(|p| -> Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p.get("name").as_str().ok_or_else(|| anyhow!("param name"))?.into(),
                    shape: p
                        .get("shape")
                        .as_arr()
                        .ok_or_else(|| anyhow!("param shape"))?
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p.get("offset").as_usize().ok_or_else(|| anyhow!("param offset"))?,
                    count: p.get("count").as_usize().ok_or_else(|| anyhow!("param count"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let weights = WeightsSpec {
            file: w.get("file").as_str().unwrap_or("weights.bin").to_string(),
            total_f32: w.get("total_f32").as_usize().ok_or_else(|| anyhow!("total_f32"))?,
            params,
        };

        let artifacts = j
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts missing"))?
            .iter()
            .map(|a| -> Result<ArtifactSpec> {
                Ok(ArtifactSpec {
                    name: a.get("name").as_str().ok_or_else(|| anyhow!("artifact name"))?.into(),
                    file: a.get("file").as_str().ok_or_else(|| anyhow!("artifact file"))?.into(),
                    inputs: a
                        .get("inputs")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::parse)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: a
                        .get("outputs")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::parse)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Self { model, anchor, weights, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Sanity checks used by integration tests and `selftest`.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0;
        for p in &self.weights.params {
            if p.offset != off {
                return Err(anyhow!("param {} offset {} != expected {off}", p.name, p.offset));
            }
            let count: usize = p.shape.iter().product();
            if count != p.count {
                return Err(anyhow!("param {} count mismatch", p.name));
            }
            off += p.count;
        }
        if off != self.weights.total_f32 {
            return Err(anyhow!("weights total {} != sum of params {off}", self.weights.total_f32));
        }
        if self.model.n_heads % self.model.n_kv_heads != 0 {
            return Err(anyhow!("GQA head counts inconsistent"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
        "model": {"vocab": 512, "d_model": 256, "n_layers": 4, "n_heads": 8,
                  "n_kv_heads": 4, "d_head": 32, "d_ffn": 512, "max_seq": 2048,
                  "prefill_chunk": 256},
        "anchor": {"block": 32, "theta": 12.0, "step": 4, "init_blocks": 1},
        "weights": {"file": "weights.bin", "total_f32": 12,
                    "params": [{"name": "a", "shape": [3, 2], "offset": 0, "count": 6},
                               {"name": "b", "shape": [6], "offset": 6, "count": 6}]},
        "artifacts": [{"name": "x", "file": "x.hlo.txt",
                       "inputs": [{"dtype": "f32", "shape": [4, 4]}],
                       "outputs": [{"dtype": "f32", "shape": [4]}]}]
    }"#;

    #[test]
    fn parse_and_validate_mini() {
        let m = Manifest::parse(MINI).unwrap();
        m.validate().unwrap();
        assert_eq!(m.model.vocab, 512);
        assert_eq!(m.anchor.step, 4);
        assert_eq!(m.weights.params.len(), 2);
        let a = m.artifact("x").unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 4]);
        assert_eq!(a.inputs[0].elements(), 16);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn validate_rejects_bad_offsets() {
        let bad = MINI.replace("\"offset\": 6", "\"offset\": 7");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_total() {
        let bad = MINI.replace("\"total_f32\": 12", "\"total_f32\": 13");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn parse_rejects_missing_model_field() {
        let bad = MINI.replace("\"vocab\": 512, ", "");
        assert!(Manifest::parse(&bad).is_err());
    }
}

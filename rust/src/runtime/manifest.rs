//! `artifacts/manifest.json` schema — the contract between `aot.py` and
//! the Rust runtime/model layers — plus [`PlanStore`], the manifest-backed
//! persistence layer for [`SparsePlan`] coordinates (DESIGN.md §11):
//! sessions warm their plan cache from the manifest's `plan_store` key and
//! flush fresh plans back, so identification amortizes across process
//! restarts, not just within one.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::attention::exec::ExecutorKind;
use crate::attention::plan::{GroupPlan, PlanKey, SparsePlan};
use crate::attention::{CostTally, TileConfig};
use crate::coordinator::scheduler::CostConstants;
use crate::plan_codec;
use crate::runtime::segment::{self, SegmentLoc};
use crate::util::json::Json;
use crate::wire::frame::{Dec, Enc};

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<Self> {
        let dtype = j.get("dtype").as_str().ok_or_else(|| anyhow!("tensor missing dtype"))?;
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("tensor missing shape"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { dtype: dtype.to_string(), shape })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub count: usize,
}

#[derive(Clone, Debug)]
pub struct WeightsSpec {
    pub file: String,
    pub total_f32: usize,
    pub params: Vec<ParamSpec>,
}

/// Mirror of `python/compile/model.py::ModelCfg`.
#[derive(Clone, Copy, Debug)]
pub struct ModelSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub max_seq: usize,
    pub prefill_chunk: usize,
}

/// Anchor hyperparameters baked into the `attn_anchor_*` artifacts.
#[derive(Clone, Copy, Debug)]
pub struct AnchorSpec {
    pub block: usize,
    pub theta: f64,
    pub step: usize,
    pub init_blocks: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: ModelSpec,
    pub anchor: AnchorSpec,
    pub weights: WeightsSpec,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;

        let m = j.get("model");
        let req = |node: &Json, key: &str| -> Result<usize> {
            node.get(key).as_usize().ok_or_else(|| anyhow!("model.{key} missing"))
        };
        let model = ModelSpec {
            vocab: req(m, "vocab")?,
            d_model: req(m, "d_model")?,
            n_layers: req(m, "n_layers")?,
            n_heads: req(m, "n_heads")?,
            n_kv_heads: req(m, "n_kv_heads")?,
            d_head: req(m, "d_head")?,
            d_ffn: req(m, "d_ffn")?,
            max_seq: req(m, "max_seq")?,
            prefill_chunk: req(m, "prefill_chunk")?,
        };

        let a = j.get("anchor");
        let anchor = AnchorSpec {
            block: req(a, "block")?,
            theta: a.get("theta").as_f64().ok_or_else(|| anyhow!("anchor.theta"))?,
            step: req(a, "step")?,
            init_blocks: req(a, "init_blocks")?,
        };

        let w = j.get("weights");
        let params = w
            .get("params")
            .as_arr()
            .ok_or_else(|| anyhow!("weights.params missing"))?
            .iter()
            .map(|p| -> Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p.get("name").as_str().ok_or_else(|| anyhow!("param name"))?.into(),
                    shape: p
                        .get("shape")
                        .as_arr()
                        .ok_or_else(|| anyhow!("param shape"))?
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p.get("offset").as_usize().ok_or_else(|| anyhow!("param offset"))?,
                    count: p.get("count").as_usize().ok_or_else(|| anyhow!("param count"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let weights = WeightsSpec {
            file: w.get("file").as_str().unwrap_or("weights.bin").to_string(),
            total_f32: w.get("total_f32").as_usize().ok_or_else(|| anyhow!("total_f32"))?,
            params,
        };

        let artifacts = j
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts missing"))?
            .iter()
            .map(|a| -> Result<ArtifactSpec> {
                Ok(ArtifactSpec {
                    name: a.get("name").as_str().ok_or_else(|| anyhow!("artifact name"))?.into(),
                    file: a.get("file").as_str().ok_or_else(|| anyhow!("artifact file"))?.into(),
                    inputs: a
                        .get("inputs")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::parse)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: a
                        .get("outputs")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::parse)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Self { model, anchor, weights, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Sanity checks used by integration tests and `selftest`.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0;
        for p in &self.weights.params {
            if p.offset != off {
                return Err(anyhow!("param {} offset {} != expected {off}", p.name, p.offset));
            }
            let count: usize = p.shape.iter().product();
            if count != p.count {
                return Err(anyhow!("param {} count mismatch", p.name));
            }
            off += p.count;
        }
        if off != self.weights.total_f32 {
            return Err(anyhow!("weights total {} != sum of params {off}", self.weights.total_f32));
        }
        if self.model.n_heads % self.model.n_kv_heads != 0 {
            return Err(anyhow!("GQA head counts inconsistent"));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Plan persistence: SparsePlan coordinates in the runtime manifest
// ---------------------------------------------------------------------------

/// `plan_store` schema version; bump on incompatible layout changes.
/// Stores written by a different version are rejected, never reinterpreted.
pub const PLAN_STORE_VERSION: usize = 1;

/// Key a persisted plan is filed under — ROADMAP's `(model, layer,
/// head_group, n)`: the session's in-memory `PlanCache` key widened by a
/// caller-chosen model identifier and the sequence length the coordinates
/// were built for.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanStoreKey {
    pub model: String,
    pub layer: u32,
    pub head_group: u32,
    pub n: usize,
}

/// Discriminator for the segmented layout inside the `plan_store` key; a
/// legacy (pre-segment) store has no `format` field at all.
pub const PLAN_STORE_FORMAT: &str = "segments";

/// Segment count past which a flush schedules background compaction.
const COMPACT_SEGMENT_THRESHOLD: usize = 8;

/// What an entry is without decoding it — the index's per-group summary
/// (`d` lives on [`StoreEntry`], `n` on the key). Filters (`len_compatible`,
/// `plans_for_compatible`) run on this, so non-matching entries are never
/// read off disk, let alone decoded.
#[derive(Clone, Copy, Debug, PartialEq)]
struct PlanSummary {
    method: &'static str,
    tile: TileConfig,
    step: usize,
}

fn summary_of(plan: &SparsePlan) -> PlanSummary {
    PlanSummary { method: plan.method, tile: plan.tile, step: plan.step }
}

/// One known plan plus its LRU bookkeeping.
struct StoreEntry {
    /// Head dim the plan's `predicted_cost` was priced for.
    d: usize,
    /// Logical timestamp of the last warm (`plans_for`) or `insert` touch;
    /// the eviction cap removes the lowest-stamped entry first.
    touched: u64,
    summary: PlanSummary,
    state: EntryState,
}

enum EntryState {
    /// Decoded plan in memory. `loc` is its committed segment location —
    /// `None` while the payload has not been appended to a segment yet.
    Resident { plan: Arc<SparsePlan>, loc: Option<SegmentLoc> },
    /// Indexed but never decoded; the payload is read lazily on demand.
    OnDisk { loc: SegmentLoc },
}

impl StoreEntry {
    fn resident_plan(&self) -> Option<&Arc<SparsePlan>> {
        match &self.state {
            EntryState::Resident { plan, .. } => Some(plan),
            EntryState::OnDisk { .. } => None,
        }
    }

    /// The committed segment location, if any.
    fn loc(&self) -> Option<&SegmentLoc> {
        match &self.state {
            EntryState::Resident { loc, .. } => loc.as_ref(),
            EntryState::OnDisk { loc } => Some(loc),
        }
    }
}

/// Process-wide flush serialization, one lock per store path: concurrent
/// `PlanStore` instances on one manifest (shard coordinators, parallel
/// test sessions) must not interleave the read-merge-write in `flush`, or
/// the last writer would erase the others' entries. The key is the
/// canonicalized path, so `reports/m.json`, `./reports/m.json` and a
/// symlink to either all share one lock (the file exists — `open`
/// required it — so canonicalization only fails on races, where the raw
/// path is the best remaining key).
fn flush_lock(path: &Path) -> Arc<Mutex<()>> {
    static LOCKS: OnceLock<Mutex<HashMap<PathBuf, Arc<Mutex<()>>>>> = OnceLock::new();
    let key = std::fs::canonicalize(path).unwrap_or_else(|_| path.to_path_buf());
    let registry = LOCKS.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = registry.lock().unwrap_or_else(|e| e.into_inner());
    map.entry(key).or_default().clone()
}

/// Manifest-backed persistence for [`SparsePlan`] coordinates, segmented
/// (DESIGN.md §15).
///
/// The manifest's `plan_store` key holds a compact JSON **index** — per
/// segment file, per `(model, n, d, method, geometry)` group, a list of
/// `[layer, head_group, offset, len, crc]` records — while the payloads
/// themselves live delta-encoded ([`crate::plan_codec`], the wire codec)
/// in immutable binary segment files under a sidecar directory
/// (`<manifest>.segments/`). `open` parses only the index and verifies
/// each referenced segment's magic/version/length; payloads are read and
/// decoded lazily, so seeding cost scales with the session's filter, not
/// the fleet's key count. Any corrupted or truncated index fails `open`
/// with a descriptive error, and a payload whose CRC or cross-checked
/// identity (n/d/method/geometry vs the index) disagrees fails its read
/// loudly — never a silent empty or wrong plan.
///
/// The index always rides *inside* the existing runtime manifest JSON
/// (the store never creates the manifest — a persistence path without one
/// is a configuration error surfaced at session build). A manifest still
/// carrying the legacy JSON-blob `plan_store` (no `format` field) is
/// migrated into segments once at `open`, round-trip asserted, and marked
/// `migrated_from: "json-v1"`; the legacy layout stays readable but is
/// never written again.
///
/// `flush` appends dirty payloads to one *new* segment (write-then-rename,
/// like every mutation here) and rewrites the document captured at `open`
/// with the `plan_store` index replaced, preserving every other manifest
/// key. The index write is a *union*, built under a process-wide per-path
/// lock: this store's entries win per key, and on-disk entries another
/// store instance flushed since `open` are referenced untouched — so
/// concurrent sessions persisting to one manifest never erase each
/// other's plans (DESIGN.md §12). Keys this instance *evicted* are
/// tombstoned out of the union (an eviction is a real deletion, not a
/// suggestion the next flush resurrects). When the live segment count
/// passes a threshold, a background compaction merges them, drops
/// unreferenced payloads, and deletes the superseded files.
///
/// An optional `max_entries` cap bounds the entry set LRU-ish: every
/// eviction is logged loudly, `plans_for` (the warm path) refreshes the
/// entries it serves, and `insert` never evicts the entry it just wrote.
pub struct PlanStore {
    path: PathBuf,
    /// Sidecar segment directory (`<path>.segments/`).
    dir: PathBuf,
    doc: Json,
    entries: HashMap<PlanStoreKey, StoreEntry>,
    dirty: bool,
    /// LRU clock; bumped by `insert` and per `plans_for` warm pass.
    clock: u64,
    max_entries: Option<usize>,
    evictions: u64,
    /// Keys the cap evicted; excluded from the flush union so they stay
    /// deleted on disk (a later `insert` of the key clears the tombstone).
    evicted: HashSet<PlanStoreKey>,
    /// Marker preserved across rewrites once a legacy JSON store was
    /// imported (satellite of the §15 migration contract).
    migrated_from: Option<String>,
    /// At most one in-flight background compaction; joined before a new
    /// spawn and on drop, so no segment file mutates after the store dies.
    compactor: Option<JoinHandle<()>>,
}

impl Drop for PlanStore {
    fn drop(&mut self) {
        if let Some(h) = self.compactor.take() {
            let _ = h.join();
        }
    }
}

impl PlanStore {
    /// Open the store inside the runtime manifest at `path`. The file must
    /// exist and hold a JSON object; a `plan_store` key, when present, is
    /// parsed strictly. A segmented index additionally has every
    /// referenced segment's header and length verified before `open`
    /// returns, so truncation is caught here, not at first read. A legacy
    /// JSON-blob store is imported into segments once (see [`PlanStore`]).
    pub fn open(path: impl Into<PathBuf>) -> Result<Self> {
        let path = path.into();
        let dir = segment::segments_dir(&path);
        // Segment verification can race a concurrent instance's
        // compaction: the manifest we read may reference segments deleted
        // just after. Compaction always commits the new index (rename)
        // *before* deleting files, so re-reading converges; a check
        // failure against an *unchanged* manifest is genuine corruption.
        let mut prev_text: Option<String> = None;
        let (doc, entries, migrated_from, legacy) = loop {
            let text = std::fs::read_to_string(&path).map_err(|e| {
                anyhow!(
                    "plan store {}: persistence path has no runtime manifest ({e}); \
                     plans persist into an existing manifest JSON, e.g. artifacts/manifest.json",
                    path.display()
                )
            })?;
            let doc = Json::parse(&text).map_err(|e| {
                anyhow!("plan store {}: manifest is not valid JSON: {e}", path.display())
            })?;
            if doc.as_obj().is_none() {
                return Err(anyhow!(
                    "plan store {}: manifest must be a JSON object",
                    path.display()
                ));
            }
            let mut entries = HashMap::new();
            let mut migrated_from = None;
            let mut legacy: Option<Vec<(PlanStoreKey, usize, SparsePlan)>> = None;
            let mut seg_err = None;
            let ps = doc.get("plan_store");
            if !ps.is_null() {
                let version = ps
                    .get("version")
                    .as_usize()
                    .ok_or_else(|| anyhow!("plan store {}: missing version", path.display()))?;
                if version != PLAN_STORE_VERSION {
                    return Err(anyhow!(
                        "plan store {}: unsupported version {version} \
                         (expected {PLAN_STORE_VERSION})",
                        path.display()
                    ));
                }
                let format = ps.get("format");
                if format.is_null() {
                    // Legacy JSON blob: parse strictly, import into segments
                    // below (after `self` exists, so the import is one flush).
                    let arr = ps.get("entries").as_arr().ok_or_else(|| {
                        anyhow!("plan store {}: entries must be an array", path.display())
                    })?;
                    let mut parsed = Vec::with_capacity(arr.len());
                    let mut seen: HashSet<PlanStoreKey> = HashSet::new();
                    for (i, e) in arr.iter().enumerate() {
                        let (key, d, plan) = entry_from_json(e)
                            .with_context(|| format!("plan store {} entry {i}", path.display()))?;
                        if !seen.insert(key.clone()) {
                            return Err(anyhow!(
                                "plan store {} entry {i}: duplicate key",
                                path.display()
                            ));
                        }
                        parsed.push((key, d, plan));
                    }
                    legacy = Some(parsed);
                } else if format.as_str() == Some(PLAN_STORE_FORMAT) {
                    migrated_from = ps.get("migrated_from").as_str().map(str::to_string);
                    let (parsed, seg_min_len) = index_from_json(ps)
                        .with_context(|| format!("plan store {}", path.display()))?;
                    for (name, min_len) in &seg_min_len {
                        if let Err(e) = segment::check_segment(&dir, name, *min_len) {
                            seg_err = Some(
                                e.context(format!("plan store {}", path.display())),
                            );
                            break;
                        }
                    }
                    entries = parsed;
                } else {
                    return Err(anyhow!(
                        "plan store {}: unknown format '{}' (expected \"{PLAN_STORE_FORMAT}\" \
                         or a legacy store without the field)",
                        path.display(),
                        format.as_str().unwrap_or("<non-string>")
                    ));
                }
            }
            match seg_err {
                None => break (doc, entries, migrated_from, legacy),
                Some(err) => {
                    if prev_text.as_deref() == Some(text.as_str()) {
                        return Err(err);
                    }
                    prev_text = Some(text);
                }
            }
        };
        let mut store = Self {
            path,
            dir,
            doc,
            entries,
            dirty: false,
            clock: 0,
            max_entries: None,
            evictions: 0,
            evicted: HashSet::new(),
            migrated_from,
            compactor: None,
        };
        if let Some(legacy) = legacy {
            store.migrate_legacy(legacy)?;
        }
        Ok(store)
    }

    /// Import strictly-parsed legacy JSON entries into segments: one
    /// flush writes the payloads and the segmented index, then every
    /// entry is read back off disk and compared bitwise (coordinates,
    /// ident provenance, and the re-derived `predicted_cost`) before the
    /// migration is declared done. The `migrated_from` marker persists in
    /// the index; the legacy layout is never written again.
    fn migrate_legacy(&mut self, legacy: Vec<(PlanStoreKey, usize, SparsePlan)>) -> Result<()> {
        let count = legacy.len();
        for (key, d, plan) in legacy {
            let plan = Arc::new(plan);
            let summary = summary_of(&plan);
            self.entries.insert(
                key,
                StoreEntry {
                    d,
                    touched: 0,
                    summary,
                    state: EntryState::Resident { plan, loc: None },
                },
            );
        }
        self.migrated_from = Some("json-v1".to_string());
        self.dirty = true;
        self.flush().with_context(|| {
            format!("plan store {}: migrating legacy JSON entries", self.path.display())
        })?;
        for (k, e) in &self.entries {
            let (Some(plan), Some(loc)) = (e.resident_plan(), e.loc()) else {
                return Err(anyhow!(
                    "plan store {}: migration left (model={}, layer={}, head_group={}, n={}) \
                     without a committed segment location",
                    self.path.display(),
                    k.model,
                    k.layer,
                    k.head_group,
                    k.n
                ));
            };
            let bytes = segment::read_payload(&self.dir, loc)
                .with_context(|| format!("plan store {}: migration read-back", self.path.display()))?;
            let back = decode_payload(&bytes, k, e.d, &e.summary)
                .with_context(|| format!("plan store {}: migration read-back", self.path.display()))?;
            if back != **plan {
                return Err(anyhow!(
                    "plan store {}: migrated entry (model={}, layer={}, head_group={}, n={}) \
                     did not round-trip bitwise",
                    self.path.display(),
                    k.model,
                    k.layer,
                    k.head_group,
                    k.n
                ));
            }
        }
        eprintln!(
            "plan store {}: migrated {count} legacy JSON entr{} into segments",
            self.path.display(),
            if count == 1 { "y" } else { "ies" }
        );
        Ok(())
    }

    /// Cap the resident entry set (LRU-ish eviction, logged loudly).
    /// `None` removes the cap. A cap below the current size evicts
    /// immediately.
    pub fn set_max_entries(&mut self, cap: Option<usize>) {
        self.max_entries = cap;
        self.enforce_cap(None);
    }

    pub fn max_entries(&self) -> Option<usize> {
        self.max_entries
    }

    /// Entries evicted by the `max_entries` cap over this store's lifetime.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Evict lowest-touch entries until the cap holds, never removing
    /// `protect` (the entry an `insert` just wrote). Every eviction is
    /// loud: a silently shrinking store would masquerade as a cold cache.
    fn enforce_cap(&mut self, protect: Option<&PlanStoreKey>) {
        let Some(cap) = self.max_entries else { return };
        let cap = cap.max(1);
        while self.entries.len() > cap {
            let victim: Option<PlanStoreKey> = self
                .entries
                .iter()
                .filter(|&(k, _)| match protect {
                    Some(p) => p != k,
                    None => true,
                })
                .min_by(|a, b| {
                    (a.1.touched, &a.0.model, a.0.layer, a.0.head_group, a.0.n)
                        .cmp(&(b.1.touched, &b.0.model, b.0.layer, b.0.head_group, b.0.n))
                })
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else { break };
            eprintln!(
                "plan store {}: max_entries={cap} exceeded, evicting \
                 (model={}, layer={}, head_group={}, n={})",
                self.path.display(),
                victim.model,
                victim.layer,
                victim.head_group,
                victim.n
            );
            self.entries.remove(&victim);
            self.evicted.insert(victim);
            self.evictions += 1;
            self.dirty = true;
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Read and fully verify one entry's payload at `loc`, healing a
    /// stale location first if the disk index has moved the key (a
    /// concurrent instance's compaction): returns the decoded plan plus
    /// the location it was actually read from.
    fn read_entry(
        &self,
        key: &PlanStoreKey,
        d: usize,
        summary: &PlanSummary,
        loc: &SegmentLoc,
    ) -> Result<(SparsePlan, SegmentLoc)> {
        let first = segment::read_payload(&self.dir, loc)
            .and_then(|bytes| decode_payload(&bytes, key, d, summary));
        match first {
            Ok(plan) => Ok((plan, loc.clone())),
            Err(e) => {
                // Self-heal: re-resolve against the current disk index.
                if let Some(DiskEntry::Seg { d: dd, summary: ds, loc: dl }) =
                    self.disk_state().remove(key)
                {
                    if dl != *loc {
                        let bytes = segment::read_payload(&self.dir, &dl)?;
                        let plan = decode_payload(&bytes, key, dd, &ds)?;
                        if dd != d || ds != *summary {
                            return Err(anyhow!(
                                "entry moved on disk with a different identity (d {d} -> {dd})"
                            ));
                        }
                        return Ok((plan, dl));
                    }
                }
                Err(e)
            }
        }
    }

    /// Look up one persisted plan (read-only peek; does not refresh the
    /// entry's eviction stamp — warming goes through [`PlanStore::plans_for`]).
    /// An on-disk entry is decoded on the fly; a payload that fails its
    /// CRC or identity cross-check is reported loudly and served as
    /// `None`, never as a wrong plan.
    pub fn get(&self, key: &PlanStoreKey) -> Option<Arc<SparsePlan>> {
        let e = self.entries.get(key)?;
        if let Some(plan) = e.resident_plan() {
            return Some(plan.clone());
        }
        let loc = e.loc()?;
        match self.read_entry(key, e.d, &e.summary, loc) {
            Ok((plan, _)) => Some(Arc::new(plan)),
            Err(err) => {
                eprintln!(
                    "plan store {}: unreadable entry (model={}, layer={}, head_group={}, n={}): {err}",
                    self.path.display(),
                    key.model,
                    key.layer,
                    key.head_group,
                    key.n
                );
                None
            }
        }
    }

    /// Decode `key`'s entry into residency (if it is not already) and
    /// return its plan. A failed read is loud and yields `None`; the
    /// entry stays on disk so a later pass can retry after a heal.
    fn materialize(&mut self, key: &PlanStoreKey) -> Option<(usize, Arc<SparsePlan>)> {
        let e = self.entries.get(key)?;
        if let Some(plan) = e.resident_plan() {
            return Some((e.d, plan.clone()));
        }
        let (d, summary, loc) = (e.d, e.summary, e.loc()?.clone());
        match self.read_entry(key, d, &summary, &loc) {
            Ok((plan, used)) => {
                let plan = Arc::new(plan);
                let e = self.entries.get_mut(key)?;
                e.state = EntryState::Resident { plan: plan.clone(), loc: Some(used) };
                Some((d, plan))
            }
            Err(err) => {
                eprintln!(
                    "plan store {}: unreadable entry (model={}, layer={}, head_group={}, n={}): {err}",
                    self.path.display(),
                    key.model,
                    key.layer,
                    key.head_group,
                    key.n
                );
                None
            }
        }
    }

    /// All plans stored for `(model, n)` as `(PlanKey, priced head dim,
    /// plan)` triples — the shape a session seeds its `PlanCache` from,
    /// in deterministic `(layer, head_group)` order. The head dim rides
    /// along because `predicted_cost` was derived with it; a session must
    /// reject entries priced for a different `d`. Served entries are
    /// touched (one shared stamp per warm pass), so the eviction cap
    /// removes cold entries before the ones a session just warmed from.
    pub fn plans_for(&mut self, model: &str, n: usize) -> Vec<(PlanKey, usize, Arc<SparsePlan>)> {
        self.clock += 1;
        let stamp = self.clock;
        let keys: Vec<PlanStoreKey> = self
            .entries
            .keys()
            .filter(|k| k.model == model && k.n == n)
            .cloned()
            .collect();
        let mut out: Vec<(PlanKey, usize, Arc<SparsePlan>)> = Vec::new();
        for k in keys {
            if let Some((d, plan)) = self.materialize(&k) {
                if let Some(e) = self.entries.get_mut(&k) {
                    e.touched = stamp;
                }
                out.push((PlanKey::new(k.layer, k.head_group), d, plan));
            }
        }
        out.sort_by_key(|(k, _, _)| (k.layer, k.head_group));
        out
    }

    /// The seeding fast path (DESIGN.md §15): plans for `(model, n)` whose
    /// index summary also matches the session's `(method, tile, step, d)`
    /// configuration, in deterministic `(layer, head_group)` order. The
    /// filter runs entirely on the index, so non-matching entries are
    /// never read off disk, let alone decoded — seeding cost scales with
    /// the session's slice of the store, not the fleet's key count.
    pub fn plans_for_compatible(
        &mut self,
        model: &str,
        n: usize,
        method: &str,
        tile: TileConfig,
        step: usize,
        d: usize,
    ) -> Vec<(PlanKey, Arc<SparsePlan>)> {
        self.clock += 1;
        let stamp = self.clock;
        let keys: Vec<PlanStoreKey> = self
            .entries
            .iter()
            .filter(|(k, e)| {
                k.model == model
                    && k.n == n
                    && e.d == d
                    && e.summary.method == method
                    && e.summary.tile == tile
                    && e.summary.step == step
            })
            .map(|(k, _)| k.clone())
            .collect();
        let mut out: Vec<(PlanKey, Arc<SparsePlan>)> = Vec::new();
        for k in keys {
            if let Some((_, plan)) = self.materialize(&k) {
                if let Some(e) = self.entries.get_mut(&k) {
                    e.touched = stamp;
                }
                out.push((PlanKey::new(k.layer, k.head_group), plan));
            }
        }
        out.sort_by_key(|(k, _)| (k.layer, k.head_group));
        out
    }

    /// Donor plans for speculative prefix reuse (DESIGN.md §17): plans
    /// filed under `model` at a *shorter* length than `n` whose index
    /// summary matches the session's `(method, tile, step, d)`. Same
    /// index-only filter as [`Self::plans_for_compatible`], but widened
    /// from `k.n == n` to `k.n < n` — the speculator's recall check, not
    /// this lookup, decides whether a shorter plan's stripes still hold.
    /// Deterministic order: `(layer, head_group, n)`, so for each key the
    /// longest (closest) prefix comes last and wins a last-write table.
    pub fn plans_for_prefix(
        &mut self,
        model: &str,
        n: usize,
        method: &str,
        tile: TileConfig,
        step: usize,
        d: usize,
    ) -> Vec<(PlanKey, Arc<SparsePlan>)> {
        self.clock += 1;
        let stamp = self.clock;
        let mut keys: Vec<PlanStoreKey> = self
            .entries
            .iter()
            .filter(|(k, e)| {
                k.model == model
                    && k.n < n
                    && e.d == d
                    && e.summary.method == method
                    && e.summary.tile == tile
                    && e.summary.step == step
            })
            .map(|(k, _)| k.clone())
            .collect();
        keys.sort_by_key(|k| (k.layer, k.head_group, k.n));
        let mut out: Vec<(PlanKey, Arc<SparsePlan>)> = Vec::new();
        for k in keys {
            if let Some((_, plan)) = self.materialize(&k) {
                if let Some(e) = self.entries.get_mut(&k) {
                    e.touched = stamp;
                }
                out.push((PlanKey::new(k.layer, k.head_group), plan));
            }
        }
        out
    }

    /// Entries filed under `model` (any layer/head_group/length).
    pub fn len_for_model(&self, model: &str) -> usize {
        self.entries.keys().filter(|k| k.model == model).count()
    }

    /// Entries under `model` whose plan a `(method, tile, step)` session
    /// configuration could actually seed from (any length) — the same
    /// compatibility filter sessions apply when warming, so warm-start
    /// expectations (e.g. the serve plan-hit prior) read this, not a raw
    /// count. Answered from the index summary alone; nothing is decoded.
    pub fn len_compatible(
        &self,
        model: &str,
        method: &str,
        tile: TileConfig,
        step: usize,
    ) -> usize {
        self.entries
            .iter()
            .filter(|(k, e)| {
                k.model == model
                    && e.summary.method == method
                    && e.summary.tile == tile
                    && e.summary.step == step
            })
            .count()
    }

    /// Insert or overwrite a plan (priced at head dim `d`); returns whether
    /// the store changed. Re-inserting the same plan is a no-op, detected
    /// by `Arc` identity first (the steady-state path: a session syncs the
    /// same cached `Arc`s every run) and deep equality otherwise, so
    /// steady-state serving never dirties the store. Against an on-disk
    /// entry the summary is compared first and the payload decoded only
    /// when it matches — an identical plan is adopted into residency
    /// without dirtying anything.
    pub fn insert(&mut self, key: PlanStoreKey, d: usize, plan: Arc<SparsePlan>) -> bool {
        enum Probe {
            NoOp,
            AdoptClean(SegmentLoc),
            Write,
        }
        let probe = match self.entries.get(&key) {
            Some(e) if e.d == d => match &e.state {
                EntryState::Resident { plan: p, .. }
                    if Arc::ptr_eq(p, &plan) || **p == *plan =>
                {
                    Probe::NoOp
                }
                EntryState::OnDisk { loc } if e.summary == summary_of(&plan) => {
                    match self.read_entry(&key, e.d, &e.summary, loc) {
                        Ok((existing, used)) if existing == *plan => Probe::AdoptClean(used),
                        _ => Probe::Write,
                    }
                }
                _ => Probe::Write,
            },
            _ => Probe::Write,
        };
        match probe {
            Probe::NoOp => false,
            Probe::AdoptClean(loc) => {
                if let Some(e) = self.entries.get_mut(&key) {
                    e.state = EntryState::Resident { plan, loc: Some(loc) };
                }
                false
            }
            Probe::Write => {
                self.clock += 1;
                let touched = self.clock;
                self.evicted.remove(&key);
                let summary = summary_of(&plan);
                self.entries.insert(
                    key.clone(),
                    StoreEntry {
                        d,
                        touched,
                        summary,
                        state: EntryState::Resident { plan, loc: None },
                    },
                );
                self.dirty = true;
                self.enforce_cap(Some(&key));
                true
            }
        }
    }

    /// Everything the manifest on disk currently knows, keyed — lenient:
    /// unparseable disk state yields nothing (the rewrite about to happen
    /// restores a valid store either way). Both layouts are understood;
    /// legacy entries surface decoded so the union re-encodes them into
    /// segments.
    fn disk_state(&self) -> HashMap<PlanStoreKey, DiskEntry> {
        let mut out = HashMap::new();
        let Ok(text) = std::fs::read_to_string(&self.path) else { return out };
        let Ok(doc) = Json::parse(&text) else { return out };
        let ps = doc.get("plan_store");
        if ps.is_null() || ps.get("version").as_usize() != Some(PLAN_STORE_VERSION) {
            return out;
        }
        let format = ps.get("format");
        if format.is_null() {
            if let Some(arr) = ps.get("entries").as_arr() {
                for e in arr {
                    if let Ok((key, d, plan)) = entry_from_json(e) {
                        out.insert(key, DiskEntry::Legacy { d, plan: Arc::new(plan) });
                    }
                }
            }
        } else if format.as_str() == Some(PLAN_STORE_FORMAT) {
            if let Ok((entries, _)) = index_from_json(ps) {
                for (k, e) in entries {
                    if let EntryState::OnDisk { loc } = e.state {
                        out.insert(k, DiskEntry::Seg { d: e.d, summary: e.summary, loc });
                    }
                }
            }
        }
        out
    }

    /// Append dirty payloads to one new segment and rewrite the manifest
    /// index. A clean store is a no-op. Concurrent flushes to one path
    /// are serialized process-wide and the written index is the union of
    /// this store's entries with the disk-only entries of other instances
    /// (see the type docs), so a flush never erases entries another store
    /// instance committed first — and the cap never evicts them either
    /// (it bounds only this instance's entry set). Payloads already
    /// committed to a segment are *referenced*, not rewritten; only new
    /// or moved entries cost bytes.
    pub fn flush(&mut self) -> Result<()> {
        if !self.dirty {
            return Ok(());
        }
        enum Src {
            Loc(SegmentLoc),
            Append(Vec<u8>),
        }
        let referenced_segments;
        {
            let lock = flush_lock(&self.path);
            let _guard = lock.lock().unwrap_or_else(|e| e.into_inner());
            let disk = self.disk_state();
            let mut outs: Vec<(PlanStoreKey, usize, PlanSummary, Src)> = Vec::new();
            for (k, e) in &self.entries {
                match &e.state {
                    EntryState::Resident { plan, loc } => {
                        // Keep a committed location only while the disk
                        // index still agrees — a concurrent compaction may
                        // have moved or dropped the payload under us.
                        let keep = loc.as_ref().filter(|l| {
                            matches!(disk.get(k),
                                Some(DiskEntry::Seg { loc: dl, .. }) if dl == *l)
                        });
                        match keep {
                            Some(l) => outs.push((k.clone(), e.d, e.summary, Src::Loc(l.clone()))),
                            None => outs.push((
                                k.clone(),
                                e.d,
                                e.summary,
                                Src::Append(encode_payload(plan, e.d)),
                            )),
                        }
                    }
                    EntryState::OnDisk { loc } => match disk.get(k) {
                        Some(DiskEntry::Seg { d, summary, loc: dl }) => {
                            outs.push((k.clone(), *d, *summary, Src::Loc(dl.clone())));
                        }
                        Some(DiskEntry::Legacy { d, plan }) => {
                            outs.push((k.clone(), *d, summary_of(plan), Src::Append(encode_payload(plan, *d))));
                        }
                        None => match segment::read_payload(&self.dir, loc) {
                            // The key vanished from the disk index but its
                            // bytes are intact: ours win, re-append them.
                            Ok(bytes) => outs.push((k.clone(), e.d, e.summary, Src::Append(bytes))),
                            Err(err) => eprintln!(
                                "plan store {}: dropping unreadable entry \
                                 (model={}, layer={}, head_group={}, n={}) at flush: {err}",
                                self.path.display(),
                                k.model,
                                k.layer,
                                k.head_group,
                                k.n
                            ),
                        },
                    },
                }
            }
            for (k, de) in &disk {
                if self.entries.contains_key(k) || self.evicted.contains(k) {
                    continue;
                }
                match de {
                    DiskEntry::Seg { d, summary, loc } => {
                        outs.push((k.clone(), *d, *summary, Src::Loc(loc.clone())));
                    }
                    DiskEntry::Legacy { d, plan } => {
                        outs.push((k.clone(), *d, summary_of(plan), Src::Append(encode_payload(plan, *d))));
                    }
                }
            }
            outs.sort_by(|a, b| {
                (&a.0.model, a.0.layer, a.0.head_group, a.0.n)
                    .cmp(&(&b.0.model, b.0.layer, b.0.head_group, b.0.n))
            });
            // One new segment for everything that needs bytes on disk.
            let appends: Vec<&[u8]> = outs
                .iter()
                .filter_map(|(_, _, _, src)| match src {
                    Src::Append(bytes) => Some(bytes.as_slice()),
                    Src::Loc(_) => None,
                })
                .collect();
            let mut new_locs = if appends.is_empty() {
                Vec::new()
            } else {
                let name = segment::next_segment_name(&self.dir)?;
                segment::write_segment(&self.dir, &name, &appends)
                    .with_context(|| format!("plan store {}", self.path.display()))?
            }
            .into_iter();
            let finals: Vec<(PlanStoreKey, usize, PlanSummary, SegmentLoc)> = outs
                .into_iter()
                .map(|(k, d, s, src)| {
                    let loc = match src {
                        Src::Loc(l) => l,
                        Src::Append(_) => {
                            new_locs.next().expect("one loc per appended payload")
                        }
                    };
                    (k, d, s, loc)
                })
                .collect();
            let ps = index_to_json(&finals, self.migrated_from.as_deref());
            if let Json::Obj(m) = &mut self.doc {
                m.insert("plan_store".to_string(), ps);
            }
            let mut text = self.doc.to_string_pretty();
            text.push('\n');
            // Write-then-rename: flush also runs best-effort from session
            // drop, and a crash mid-write must never destroy the manifest
            // (it holds the aot.py artifact contract, not just plans). The
            // temp name is unique per flush so two stores flushing one path
            // never clobber each other's in-flight write.
            static FLUSH_SEQ: AtomicU64 = AtomicU64::new(0);
            let seq = FLUSH_SEQ.fetch_add(1, Ordering::Relaxed);
            let mut tmp_name = self.path.as_os_str().to_os_string();
            tmp_name.push(format!(".tmp.{}.{seq}", std::process::id()));
            let tmp = PathBuf::from(tmp_name);
            std::fs::write(&tmp, &text)
                .with_context(|| format!("writing plan store {}", tmp.display()))?;
            std::fs::rename(&tmp, &self.path)
                .with_context(|| format!("committing plan store {}", self.path.display()))?;
            // Adopt the committed locations so the next flush references
            // instead of re-appending.
            let mut seg_names: HashSet<String> = HashSet::new();
            for (k, d, s, loc) in finals {
                seg_names.insert(loc.segment.clone());
                if let Some(e) = self.entries.get_mut(&k) {
                    e.d = d;
                    e.summary = s;
                    e.state = match std::mem::replace(
                        &mut e.state,
                        EntryState::OnDisk { loc: loc.clone() },
                    ) {
                        EntryState::Resident { plan, .. } => {
                            EntryState::Resident { plan, loc: Some(loc) }
                        }
                        EntryState::OnDisk { .. } => EntryState::OnDisk { loc },
                    };
                }
            }
            referenced_segments = seg_names.len();
            self.dirty = false;
            // The committed file now reflects the deletions, so the
            // tombstones have done their one job. Keeping them would turn an
            // eviction into a permanent ban: another instance legitimately
            // re-writing the key later would be silently erased by this
            // instance's next flush.
            self.evicted.clear();
        }
        // Outside the lock: compaction takes it itself.
        if referenced_segments > COMPACT_SEGMENT_THRESHOLD {
            self.spawn_compaction();
        }
        Ok(())
    }

    /// Schedule a background compaction unless one is already running.
    fn spawn_compaction(&mut self) {
        if let Some(h) = &self.compactor {
            if !h.is_finished() {
                return;
            }
        }
        if let Some(h) = self.compactor.take() {
            let _ = h.join();
        }
        let path = self.path.clone();
        self.compactor = Some(std::thread::spawn(move || {
            match compact_plan_store(&path) {
                Ok(stats) => eprintln!(
                    "plan store {}: background compaction merged {} segments into {} \
                     ({} entries, {} files removed)",
                    path.display(),
                    stats.segments_before,
                    stats.segments_after,
                    stats.entries,
                    stats.files_removed
                ),
                Err(e) => eprintln!(
                    "plan store {}: background compaction failed (store left intact): {e}",
                    path.display()
                ),
            }
        }));
    }

    /// Synchronous compaction (the `store compact` CLI and tests): flush
    /// anything dirty, merge every live payload into one fresh segment,
    /// rewrite the index, and delete superseded files. Aborts with the
    /// store intact if any payload fails verification.
    pub fn compact(&mut self) -> Result<CompactionStats> {
        if let Some(h) = self.compactor.take() {
            let _ = h.join();
        }
        self.flush()?;
        let stats = compact_plan_store(&self.path)?;
        // Our in-memory locations now point at deleted segments; adopt
        // the rewritten index (reads would self-heal, but eagerly
        // re-resolving keeps every later flush reference-only).
        let disk = self.disk_state();
        for (k, e) in self.entries.iter_mut() {
            if let Some(DiskEntry::Seg { loc, .. }) = disk.get(k) {
                e.state = match std::mem::replace(
                    &mut e.state,
                    EntryState::OnDisk { loc: loc.clone() },
                ) {
                    EntryState::Resident { plan, .. } => {
                        EntryState::Resident { plan, loc: Some(loc.clone()) }
                    }
                    EntryState::OnDisk { .. } => EntryState::OnDisk { loc: loc.clone() },
                };
            }
        }
        Ok(stats)
    }
}

/// What one key maps to on disk right now (see [`PlanStore::disk_state`]).
enum DiskEntry {
    Seg { d: usize, summary: PlanSummary, loc: SegmentLoc },
    Legacy { d: usize, plan: Arc<SparsePlan> },
}

/// Result summary of one compaction pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct CompactionStats {
    /// Segment files present before (referenced or orphaned).
    pub segments_before: usize,
    /// Segment files referenced after (0 for an empty store, else 1).
    pub segments_after: usize,
    /// Live entries carried across.
    pub entries: usize,
    /// Files deleted (superseded segments, crashed writers' temps).
    pub files_removed: usize,
}

/// Merge every live payload into one fresh segment and delete the rest.
///
/// Runs under the per-path flush lock. Every payload is read and
/// CRC-verified *before* anything is written; any failure aborts with the
/// store intact. The new segment and the rewritten manifest both commit
/// via write-then-rename, so a kill at any point leaves either the old
/// index (referencing the old, still-present segments) or the new one —
/// half-written files are temps a later compaction sweeps up. Eviction
/// tombstones need no special handling here: compaction rewrites from the
/// committed index, which tombstoned keys never reach.
fn compact_plan_store(path: &Path) -> Result<CompactionStats> {
    let lock = flush_lock(path);
    let _guard = lock.lock().unwrap_or_else(|e| e.into_inner());
    let dir = segment::segments_dir(path);
    let files = segment::list_files(&dir)?;
    let segments_before = files.iter().filter(|f| segment::segment_seq(f).is_some()).count();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("plan store {}: compaction read", path.display()))?;
    let doc = Json::parse(&text)
        .map_err(|e| anyhow!("plan store {}: manifest is not valid JSON: {e}", path.display()))?;
    let ps = doc.get("plan_store");
    if ps.is_null() {
        // No store at all: the sidecar dir should hold nothing live.
        let files_removed = segment::remove_unreferenced(&dir, &HashSet::new());
        return Ok(CompactionStats { segments_before, files_removed, ..Default::default() });
    }
    if ps.get("version").as_usize() != Some(PLAN_STORE_VERSION) {
        return Err(anyhow!("plan store {}: unsupported version", path.display()));
    }
    if ps.get("format").as_str() != Some(PLAN_STORE_FORMAT) {
        return Err(anyhow!(
            "plan store {}: not a segmented store — open it once to migrate, then compact",
            path.display()
        ));
    }
    let (entries, _) = index_from_json(ps).with_context(|| {
        format!("plan store {}: compaction index parse", path.display())
    })?;
    let migrated_from = ps.get("migrated_from").as_str().map(str::to_string);
    // Fast path: already compact (a single segment, no strays).
    let mut referenced: HashSet<String> = HashSet::new();
    for e in entries.values() {
        if let Some(loc) = e.loc() {
            referenced.insert(loc.segment.clone());
        }
    }
    if referenced.len() <= 1 && files.len() == referenced.len() {
        return Ok(CompactionStats {
            segments_before,
            segments_after: referenced.len(),
            entries: entries.len(),
            ..Default::default()
        });
    }
    // Verify-read every live payload before touching anything.
    let mut live: Vec<(PlanStoreKey, usize, PlanSummary, Vec<u8>)> = Vec::new();
    for (k, e) in &entries {
        let loc = e.loc().ok_or_else(|| anyhow!("index entry without a location"))?;
        let bytes = segment::read_payload(&dir, loc).with_context(|| {
            format!(
                "plan store {}: compaction aborted, entry (model={}, layer={}, \
                 head_group={}, n={}) unreadable",
                path.display(),
                k.model,
                k.layer,
                k.head_group,
                k.n
            )
        })?;
        live.push((k.clone(), e.d, e.summary, bytes));
    }
    live.sort_by(|a, b| {
        (&a.0.model, a.0.layer, a.0.head_group, a.0.n)
            .cmp(&(&b.0.model, b.0.layer, b.0.head_group, b.0.n))
    });
    let mut finals: Vec<(PlanStoreKey, usize, PlanSummary, SegmentLoc)> = Vec::new();
    let mut keep: HashSet<String> = HashSet::new();
    if !live.is_empty() {
        let name = segment::next_segment_name(&dir)?;
        let payloads: Vec<&[u8]> = live.iter().map(|(_, _, _, b)| b.as_slice()).collect();
        let locs = segment::write_segment(&dir, &name, &payloads)
            .with_context(|| format!("plan store {}: compaction write", path.display()))?;
        keep.insert(name);
        for ((k, d, s, _), loc) in live.into_iter().zip(locs) {
            finals.push((k, d, s, loc));
        }
    }
    let entries_count = finals.len();
    let mut doc = doc;
    let ps = index_to_json(&finals, migrated_from.as_deref());
    if let Json::Obj(m) = &mut doc {
        m.insert("plan_store".to_string(), ps);
    }
    let mut out = doc.to_string_pretty();
    out.push('\n');
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(format!(".compact.tmp.{}", std::process::id()));
    let tmp = PathBuf::from(tmp_name);
    std::fs::write(&tmp, &out)
        .with_context(|| format!("writing compacted plan store {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("committing compacted plan store {}", path.display()))?;
    // Only after the new index is committed do the old files go.
    let files_removed = segment::remove_unreferenced(&dir, &keep);
    Ok(CompactionStats {
        segments_before,
        segments_after: keep.len(),
        entries: entries_count,
        files_removed,
    })
}

/// Method-name interning: `SparsePlan::method` is a `&'static str`, so a
/// deserialized plan (from the plan store or off the wire) must map onto a
/// known method identifier — an unknown name is a corruption signal, never
/// silently accepted.
pub(crate) fn method_static(name: &str) -> Result<&'static str> {
    const KNOWN: [&str; 7] = [
        "full-attn",
        "anchor",
        "streaming-llm",
        "vertical-slash",
        "flexprefill",
        "block-topk",
        "test",
    ];
    KNOWN
        .iter()
        .find(|&&k| k == name)
        .copied()
        .ok_or_else(|| anyhow!("unknown method '{name}' in plan store"))
}

fn cost_to_json(c: &CostTally) -> Json {
    Json::obj(vec![
        ("flops", Json::num(c.flops as f64)),
        ("kv_bytes", Json::num(c.kv_bytes as f64)),
        ("ident_scores", Json::num(c.ident_scores as f64)),
    ])
}

fn cost_from_json(j: &Json) -> Result<CostTally> {
    let field = |k: &str| -> Result<u64> {
        let x = j.get(k).as_f64().ok_or_else(|| anyhow!("cost missing {k}"))?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(anyhow!("cost {k} is not a non-negative integer"));
        }
        Ok(x as u64)
    };
    Ok(CostTally {
        flops: field("flops")?,
        kv_bytes: field("kv_bytes")?,
        ident_scores: field("ident_scores")?,
    })
}

/// Serialize a plan's coordinates plus its identification provenance.
/// `d` is the head dim the plan was priced for; `predicted_cost` is *not*
/// persisted — it is re-derived from the coordinates on load, so the
/// stored unit stays pure coordinates (DESIGN.md §11).
pub fn plan_to_json(plan: &SparsePlan, d: usize) -> Json {
    Json::obj(vec![
        ("method", Json::str(plan.method)),
        ("n", Json::num(plan.n as f64)),
        ("d", Json::num(d as f64)),
        ("b_q", Json::num(plan.tile.b_q as f64)),
        ("b_kv", Json::num(plan.tile.b_kv as f64)),
        ("step", Json::num(plan.step as f64)),
        ("ident_cost", cost_to_json(&plan.ident_cost)),
        (
            "groups",
            Json::arr(plan.groups.iter().map(|g| {
                Json::obj(vec![
                    (
                        "spans",
                        Json::arr(g.spans.iter().map(|&(s, e)| {
                            Json::arr([Json::num(s as f64), Json::num(e as f64)])
                        })),
                    ),
                    ("stripes", Json::arr(g.stripes.iter().map(|&c| Json::num(c as f64)))),
                ])
            })),
        ),
    ])
}

/// Deserialize a plan, validating every coordinate: sizes nonzero, group
/// count matching `(n, b_q, step)`, spans sorted/in-range/non-overlapping,
/// stripes strictly ascending and `< n`. Returns the plan and the head dim
/// it was priced for; `predicted_cost` is recomputed, not trusted.
pub fn plan_from_json(j: &Json) -> Result<(SparsePlan, usize)> {
    let method = method_static(
        j.get("method").as_str().ok_or_else(|| anyhow!("plan missing method"))?,
    )?;
    let req = |k: &str| -> Result<usize> {
        j.get(k).as_usize().ok_or_else(|| anyhow!("plan missing {k}"))
    };
    let n = req("n")?;
    let d = req("d")?;
    let b_q = req("b_q")?;
    let b_kv = req("b_kv")?;
    let step = req("step")?;
    if n == 0 || d == 0 || b_q == 0 || b_kv == 0 || step == 0 {
        return Err(anyhow!("plan has a zero-sized dimension"));
    }
    if n > u32::MAX as usize {
        return Err(anyhow!("plan n={n} exceeds the u32 coordinate range"));
    }
    let tile = TileConfig::new(b_q, b_kv);
    let ident_cost = cost_from_json(j.get("ident_cost"))?;
    let garr = j.get("groups").as_arr().ok_or_else(|| anyhow!("plan missing groups"))?;
    let expect_groups = tile.q_blocks(n).div_ceil(step);
    if garr.len() != expect_groups {
        return Err(anyhow!(
            "plan has {} groups, expected {expect_groups} for n={n}, b_q={b_q}, step={step}",
            garr.len()
        ));
    }
    let mut groups = Vec::with_capacity(garr.len());
    for (gi, g) in garr.iter().enumerate() {
        let spans_arr =
            g.get("spans").as_arr().ok_or_else(|| anyhow!("group {gi}: missing spans"))?;
        let mut spans = Vec::with_capacity(spans_arr.len());
        let mut prev_end = 0usize;
        for (si, pair) in spans_arr.iter().enumerate() {
            let s =
                pair.idx(0).as_usize().ok_or_else(|| anyhow!("group {gi} span {si}: bad start"))?;
            let e =
                pair.idx(1).as_usize().ok_or_else(|| anyhow!("group {gi} span {si}: bad end"))?;
            if s >= e || e > n {
                return Err(anyhow!("group {gi} span {si}: [{s}, {e}) out of range for n={n}"));
            }
            if si > 0 && s < prev_end {
                return Err(anyhow!("group {gi} span {si}: overlapping or unsorted spans"));
            }
            prev_end = e;
            spans.push((s as u32, e as u32));
        }
        let stripes_arr =
            g.get("stripes").as_arr().ok_or_else(|| anyhow!("group {gi}: missing stripes"))?;
        let mut stripes: Vec<u32> = Vec::with_capacity(stripes_arr.len());
        for (ci, c) in stripes_arr.iter().enumerate() {
            let col = c.as_usize().ok_or_else(|| anyhow!("group {gi} stripe {ci}: bad column"))?;
            if col >= n {
                return Err(anyhow!("group {gi} stripe {ci}: column {col} >= n={n}"));
            }
            if let Some(&last) = stripes.last() {
                if col as u32 <= last {
                    return Err(anyhow!(
                        "group {gi} stripe {ci}: unsorted or duplicate column {col}"
                    ));
                }
            }
            stripes.push(col as u32);
        }
        groups.push(GroupPlan { spans, stripes });
    }
    Ok((SparsePlan::new(method, n, d, tile, step, groups, ident_cost), d))
}

// ---------------------------------------------------------------------------
// Calibration: measured cost constants in the runtime manifest
// ---------------------------------------------------------------------------

/// `calibration` schema version; bump on incompatible layout changes.
/// Entries written by a different version are rejected, never
/// reinterpreted.
pub const CALIBRATION_VERSION: usize = 1;

fn constants_to_json(c: &CostConstants) -> Json {
    Json::obj(vec![
        ("ident_cost_frac", Json::num(c.ident_cost_frac)),
        ("plan_broadcast_frac", Json::num(c.plan_broadcast_frac)),
        ("span_ns_per_row", Json::num(c.span_ns_per_row)),
        ("gather_ns_per_row", Json::num(c.gather_ns_per_row)),
        ("fold_ns_per_score", Json::num(c.fold_ns_per_score)),
    ])
}

fn constants_from_json(j: &Json) -> Result<CostConstants> {
    let field = |k: &str| -> Result<f64> {
        let x = j.get(k).as_f64().ok_or_else(|| anyhow!("calibration missing {k}"))?;
        if !x.is_finite() || x < 0.0 {
            return Err(anyhow!("calibration {k} must be a finite non-negative number"));
        }
        Ok(x)
    };
    Ok(CostConstants {
        ident_cost_frac: field("ident_cost_frac")?,
        plan_broadcast_frac: field("plan_broadcast_frac")?,
        span_ns_per_row: field("span_ns_per_row")?,
        gather_ns_per_row: field("gather_ns_per_row")?,
        fold_ns_per_score: field("fold_ns_per_score")?,
    })
}

/// Persist one executor's measured [`CostConstants`] under the manifest's
/// `calibration` key, preserving every other key — including other
/// executors' entries — with the plan store's write-then-rename
/// discipline. The file must already exist and hold a JSON object:
/// calibration rides in a runtime manifest, it never creates one.
pub fn save_calibration(
    path: impl AsRef<Path>,
    kind: ExecutorKind,
    c: &CostConstants,
) -> Result<()> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path).map_err(|e| {
        anyhow!(
            "calibration {}: persistence path has no runtime manifest ({e}); \
             constants persist into an existing manifest JSON, e.g. artifacts/manifest.json",
            path.display()
        )
    })?;
    let mut doc = Json::parse(&text)
        .map_err(|e| anyhow!("calibration {}: manifest is not valid JSON: {e}", path.display()))?;
    if doc.as_obj().is_none() {
        return Err(anyhow!("calibration {}: manifest must be a JSON object", path.display()));
    }
    // Merge into the existing executors map so calibrating one backend
    // never drops the other's constants.
    let mut executors: Vec<(String, Json)> = Vec::new();
    let existing = doc.get("calibration");
    if !existing.is_null() && existing.get("version").as_usize() == Some(CALIBRATION_VERSION) {
        if let Some(map) = existing.get("executors").as_obj() {
            for (k, v) in map {
                if k != kind.name() {
                    executors.push((k.clone(), v.clone()));
                }
            }
        }
    }
    executors.push((kind.name().to_string(), constants_to_json(c)));
    let cal = Json::obj(vec![
        ("version", Json::num(CALIBRATION_VERSION as f64)),
        ("executors", Json::Obj(executors.into_iter().collect())),
    ]);
    if let Json::Obj(m) = &mut doc {
        m.insert("calibration".to_string(), cal);
    }
    let mut out = doc.to_string_pretty();
    out.push('\n');
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(format!(".cal.tmp.{}", std::process::id()));
    let tmp = PathBuf::from(tmp_name);
    std::fs::write(&tmp, &out)
        .with_context(|| format!("writing calibration {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("committing calibration {}", path.display()))?;
    Ok(())
}

/// Load the constants calibrated for `kind`, if the manifest carries any.
/// `Ok(None)` means "never calibrated" (no `calibration` key, or no entry
/// for this executor); a malformed or version-mismatched key is an `Err`,
/// never silently the modeled defaults.
pub fn load_calibration(
    path: impl AsRef<Path>,
    kind: ExecutorKind,
) -> Result<Option<CostConstants>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("calibration {}: {e}", path.display()))?;
    let doc = Json::parse(&text)
        .map_err(|e| anyhow!("calibration {}: manifest is not valid JSON: {e}", path.display()))?;
    let cal = doc.get("calibration");
    if cal.is_null() {
        return Ok(None);
    }
    let version = cal
        .get("version")
        .as_usize()
        .ok_or_else(|| anyhow!("calibration {}: missing version", path.display()))?;
    if version != CALIBRATION_VERSION {
        return Err(anyhow!(
            "calibration {}: unsupported version {version} (expected {CALIBRATION_VERSION})",
            path.display()
        ));
    }
    let entry = cal.get("executors").get(kind.name());
    if entry.is_null() {
        return Ok(None);
    }
    constants_from_json(entry)
        .with_context(|| format!("calibration {} executor {}", path.display(), kind.name()))
        .map(Some)
}

pub(crate) fn entry_to_json(key: &PlanStoreKey, d: usize, plan: &SparsePlan) -> Json {
    Json::obj(vec![
        ("model", Json::str(&key.model)),
        ("layer", Json::num(key.layer as f64)),
        ("head_group", Json::num(key.head_group as f64)),
        ("n", Json::num(key.n as f64)),
        ("plan", plan_to_json(plan, d)),
    ])
}

pub(crate) fn entry_from_json(j: &Json) -> Result<(PlanStoreKey, usize, SparsePlan)> {
    let model = j.get("model").as_str().ok_or_else(|| anyhow!("entry missing model"))?.to_string();
    let layer = j.get("layer").as_usize().ok_or_else(|| anyhow!("entry missing layer"))? as u32;
    let head_group =
        j.get("head_group").as_usize().ok_or_else(|| anyhow!("entry missing head_group"))? as u32;
    let n = j.get("n").as_usize().ok_or_else(|| anyhow!("entry missing n"))?;
    let (plan, d) = plan_from_json(j.get("plan"))?;
    if plan.n != n {
        return Err(anyhow!("entry n={n} disagrees with plan n={}", plan.n));
    }
    Ok((PlanStoreKey { model, layer, head_group, n }, d, plan))
}

/// Segment payload = exactly the wire encoding of one plan
/// ([`plan_codec::put_plan`]): one codec for the network and the disk, so
/// a payload is byte-identical to the frame a shard worker would receive.
fn encode_payload(plan: &SparsePlan, d: usize) -> Vec<u8> {
    let mut e = Enc::new();
    plan_codec::put_plan(&mut e, plan, d);
    e.buf
}

/// Decode one payload and cross-check it against the index identity it
/// was filed under — `n` from the key, `d` and `(method, tile, step)`
/// from the index group. A disagreement means index/segment skew (a
/// corrupted index pointing at someone else's bytes) and is rejected; the
/// store never serves a plan under the wrong key.
fn decode_payload(
    bytes: &[u8],
    key: &PlanStoreKey,
    d: usize,
    summary: &PlanSummary,
) -> Result<SparsePlan> {
    let mut dec = Dec::new(bytes);
    let (plan, d_head) = plan_codec::get_plan_with_dim(&mut dec)?;
    dec.finish()?;
    if plan.n != key.n {
        return Err(anyhow!("payload n={} disagrees with indexed n={}", plan.n, key.n));
    }
    if d_head != d {
        return Err(anyhow!("payload head dim {d_head} disagrees with indexed d={d}"));
    }
    if plan.method != summary.method || plan.tile != summary.tile || plan.step != summary.step {
        return Err(anyhow!(
            "payload identity ({}, b_q={}, b_kv={}, step={}) disagrees with the index \
             ({}, b_q={}, b_kv={}, step={})",
            plan.method,
            plan.tile.b_q,
            plan.tile.b_kv,
            plan.step,
            summary.method,
            summary.tile.b_q,
            summary.tile.b_kv,
            summary.step
        ));
    }
    Ok(plan)
}

/// Parse the segmented `plan_store` index strictly. Returns the entry map
/// (every entry `OnDisk`) plus, per referenced segment, the minimum file
/// length implied by its farthest entry — `open` verifies each segment
/// against it so truncation fails there, not at first read.
fn index_from_json(
    ps: &Json,
) -> Result<(HashMap<PlanStoreKey, StoreEntry>, HashMap<String, u64>)> {
    let mut entries: HashMap<PlanStoreKey, StoreEntry> = HashMap::new();
    let mut seg_min_len: HashMap<String, u64> = HashMap::new();
    let arr = ps
        .get("entries")
        .as_arr()
        .ok_or_else(|| anyhow!("index entries must be an array"))?;
    for (si, seg) in arr.iter().enumerate() {
        let name = seg
            .get("segment")
            .as_str()
            .ok_or_else(|| anyhow!("index entry {si}: missing segment name"))?;
        if segment::segment_seq(name).is_none() {
            return Err(anyhow!("index entry {si}: malformed segment name '{name}'"));
        }
        let groups = seg
            .get("groups")
            .as_arr()
            .ok_or_else(|| anyhow!("index entry {si} ({name}): groups must be an array"))?;
        for (gi, g) in groups.iter().enumerate() {
            let at = format!("index entry {si} ({name}) group {gi}");
            let model =
                g.get("model").as_str().ok_or_else(|| anyhow!("{at}: missing model"))?.to_string();
            let req = |k: &str| -> Result<usize> {
                g.get(k).as_usize().ok_or_else(|| anyhow!("{at}: missing {k}"))
            };
            let n = req("n")?;
            let d = req("d")?;
            let b_q = req("b_q")?;
            let b_kv = req("b_kv")?;
            let step = req("step")?;
            let method = method_static(
                g.get("method").as_str().ok_or_else(|| anyhow!("{at}: missing method"))?,
            )
            .with_context(|| at.clone())?;
            if n == 0 || d == 0 || b_q == 0 || b_kv == 0 || step == 0 {
                return Err(anyhow!("{at}: zero-sized dimension"));
            }
            if n > u32::MAX as usize {
                return Err(anyhow!("{at}: n={n} exceeds the u32 coordinate range"));
            }
            let summary = PlanSummary { method, tile: TileConfig::new(b_q, b_kv), step };
            let keys =
                g.get("keys").as_arr().ok_or_else(|| anyhow!("{at}: missing keys"))?;
            for (ki, rec) in keys.iter().enumerate() {
                let field = |i: usize, what: &str| -> Result<u64> {
                    let x = rec
                        .idx(i)
                        .as_f64()
                        .ok_or_else(|| anyhow!("{at} key {ki}: bad {what}"))?;
                    if x < 0.0 || x.fract() != 0.0 {
                        return Err(anyhow!(
                            "{at} key {ki}: {what} is not a non-negative integer"
                        ));
                    }
                    Ok(x as u64)
                };
                let layer = field(0, "layer")?;
                let head_group = field(1, "head_group")?;
                let offset = field(2, "offset")?;
                let len = field(3, "len")?;
                let crc = field(4, "crc")?;
                if layer > u32::MAX as u64 || head_group > u32::MAX as u64 {
                    return Err(anyhow!("{at} key {ki}: coordinate exceeds u32"));
                }
                if crc > u32::MAX as u64 {
                    return Err(anyhow!("{at} key {ki}: crc exceeds u32"));
                }
                if len == 0 || len > segment::MAX_ENTRY_BYTES as u64 {
                    return Err(anyhow!("{at} key {ki}: implausible payload length {len}"));
                }
                if offset < segment::SEGMENT_HEADER_BYTES {
                    return Err(anyhow!(
                        "{at} key {ki}: offset {offset} inside the segment header"
                    ));
                }
                let key = PlanStoreKey {
                    model: model.clone(),
                    layer: layer as u32,
                    head_group: head_group as u32,
                    n,
                };
                let loc = SegmentLoc {
                    segment: name.to_string(),
                    offset,
                    len: len as u32,
                    crc: crc as u32,
                };
                let end = loc.end();
                let prior = entries.insert(
                    key,
                    StoreEntry { d, touched: 0, summary, state: EntryState::OnDisk { loc } },
                );
                if prior.is_some() {
                    return Err(anyhow!("{at} key {ki}: duplicate store key"));
                }
                let min = seg_min_len.entry(name.to_string()).or_insert(0);
                *min = (*min).max(end);
            }
        }
    }
    Ok((entries, seg_min_len))
}

/// Serialize the committed entry set into the segmented index layout:
/// per segment, per `(model, n, d, method, b_q, b_kv, step)` group, the
/// sorted `[layer, head_group, offset, len, crc]` records. Grouping pulls
/// the filterable identity out of the per-key records, so a session's
/// compatibility filter skips whole groups without touching their keys.
fn index_to_json(
    all: &[(PlanStoreKey, usize, PlanSummary, SegmentLoc)],
    migrated_from: Option<&str>,
) -> Json {
    type GroupId = (String, usize, usize, &'static str, usize, usize, usize);
    type KeyRec = (u32, u32, u64, u32, u32);
    let mut segs: BTreeMap<String, BTreeMap<GroupId, Vec<KeyRec>>> = BTreeMap::new();
    for (k, d, s, loc) in all {
        segs.entry(loc.segment.clone())
            .or_default()
            .entry((k.model.clone(), k.n, *d, s.method, s.tile.b_q, s.tile.b_kv, s.step))
            .or_default()
            .push((k.layer, k.head_group, loc.offset, loc.len, loc.crc));
    }
    let entries = Json::arr(segs.into_iter().map(|(name, groups)| {
        Json::obj(vec![
            ("segment", Json::str(&name)),
            (
                "groups",
                Json::arr(groups.into_iter().map(
                    |((model, n, d, method, b_q, b_kv, step), mut keys)| {
                        keys.sort_unstable();
                        Json::obj(vec![
                            ("model", Json::str(&model)),
                            ("n", Json::num(n as f64)),
                            ("d", Json::num(d as f64)),
                            ("method", Json::str(method)),
                            ("b_q", Json::num(b_q as f64)),
                            ("b_kv", Json::num(b_kv as f64)),
                            ("step", Json::num(step as f64)),
                            (
                                "keys",
                                Json::arr(keys.into_iter().map(
                                    |(layer, head_group, offset, len, crc)| {
                                        Json::arr([
                                            Json::num(layer as f64),
                                            Json::num(head_group as f64),
                                            Json::num(offset as f64),
                                            Json::num(len as f64),
                                            Json::num(crc as f64),
                                        ])
                                    },
                                )),
                            ),
                        ])
                    },
                )),
            ),
        ])
    }));
    let mut fields = vec![
        ("version", Json::num(PLAN_STORE_VERSION as f64)),
        ("format", Json::str(PLAN_STORE_FORMAT)),
        ("entries", entries),
    ];
    if let Some(m) = migrated_from {
        fields.push(("migrated_from", Json::str(m)));
    }
    Json::obj(fields)
}

/// Fixture helper (tests, benches, the CI migration smoke): write
/// `entries` to `path` in the **legacy** pre-segment JSON-blob layout —
/// the shape old deployments left behind, which `PlanStore::open`
/// migrates on first contact. The store itself never writes this layout
/// anymore. Creates the manifest as `{}` if `path` does not exist.
pub fn write_legacy_json_store(
    path: impl AsRef<Path>,
    entries: &[(PlanStoreKey, usize, Arc<SparsePlan>)],
) -> Result<()> {
    let path = path.as_ref();
    let mut doc = match std::fs::read_to_string(path) {
        Ok(text) => Json::parse(&text)
            .map_err(|e| anyhow!("legacy store {}: not valid JSON: {e}", path.display()))?,
        Err(_) => Json::obj(vec![]),
    };
    if doc.as_obj().is_none() {
        return Err(anyhow!("legacy store {}: manifest must be a JSON object", path.display()));
    }
    let mut sorted: Vec<&(PlanStoreKey, usize, Arc<SparsePlan>)> = entries.iter().collect();
    sorted.sort_by(|a, b| {
        (&a.0.model, a.0.layer, a.0.head_group, a.0.n)
            .cmp(&(&b.0.model, b.0.layer, b.0.head_group, b.0.n))
    });
    let arr: Vec<Json> =
        sorted.iter().map(|(k, d, plan)| entry_to_json(k, *d, plan)).collect();
    let ps = Json::obj(vec![
        ("version", Json::num(PLAN_STORE_VERSION as f64)),
        ("entries", Json::Arr(arr)),
    ]);
    if let Json::Obj(m) = &mut doc {
        m.insert("plan_store".to_string(), ps);
    }
    let mut text = doc.to_string_pretty();
    text.push('\n');
    std::fs::write(path, &text)
        .with_context(|| format!("writing legacy store {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
        "model": {"vocab": 512, "d_model": 256, "n_layers": 4, "n_heads": 8,
                  "n_kv_heads": 4, "d_head": 32, "d_ffn": 512, "max_seq": 2048,
                  "prefill_chunk": 256},
        "anchor": {"block": 32, "theta": 12.0, "step": 4, "init_blocks": 1},
        "weights": {"file": "weights.bin", "total_f32": 12,
                    "params": [{"name": "a", "shape": [3, 2], "offset": 0, "count": 6},
                               {"name": "b", "shape": [6], "offset": 6, "count": 6}]},
        "artifacts": [{"name": "x", "file": "x.hlo.txt",
                       "inputs": [{"dtype": "f32", "shape": [4, 4]}],
                       "outputs": [{"dtype": "f32", "shape": [4]}]}]
    }"#;

    #[test]
    fn parse_and_validate_mini() {
        let m = Manifest::parse(MINI).unwrap();
        m.validate().unwrap();
        assert_eq!(m.model.vocab, 512);
        assert_eq!(m.anchor.step, 4);
        assert_eq!(m.weights.params.len(), 2);
        let a = m.artifact("x").unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 4]);
        assert_eq!(a.inputs[0].elements(), 16);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn validate_rejects_bad_offsets() {
        let bad = MINI.replace("\"offset\": 6", "\"offset\": 7");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_total() {
        let bad = MINI.replace("\"total_f32\": 12", "\"total_f32\": 13");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn parse_rejects_missing_model_field() {
        let bad = MINI.replace("\"vocab\": 512, ", "");
        assert!(Manifest::parse(&bad).is_err());
    }

    // ---- plan store -------------------------------------------------------

    fn tmp_manifest(tag: &str, contents: &str) -> PathBuf {
        let path = std::env::temp_dir()
            .join(format!("anchor_manifest_{}_{tag}.json", std::process::id()));
        std::fs::write(&path, contents).unwrap();
        path
    }

    fn sample_plan(n: usize, d: usize) -> SparsePlan {
        let tile = TileConfig::new(16, 16);
        let groups: Vec<GroupPlan> = (0..tile.q_blocks(n).div_ceil(2))
            .map(|g| {
                let win = (g * 2 * 16) as u32;
                let end = ((g + 1) * 2 * 16).min(n) as u32;
                if win == 0 {
                    GroupPlan { spans: vec![(0, end)], stripes: vec![] }
                } else {
                    GroupPlan {
                        spans: vec![(0, 16), (win, end)],
                        stripes: (16..win).step_by(5).collect(),
                    }
                }
            })
            .collect();
        let ident = CostTally { flops: 640, kv_bytes: 128, ident_scores: 32 };
        SparsePlan::new("anchor", n, d, tile, 2, groups, ident)
    }

    #[test]
    fn plan_json_round_trips_identically() {
        let plan = sample_plan(96, 8);
        let j = plan_to_json(&plan, 8);
        let reparsed = Json::parse(&j.to_string()).unwrap();
        let (back, d) = plan_from_json(&reparsed).unwrap();
        assert_eq!(d, 8);
        assert_eq!(back, plan, "round trip must be identity, predicted cost included");
    }

    #[test]
    fn plan_store_round_trips_through_the_manifest_file() {
        let path = tmp_manifest("roundtrip", "{\"other_key\": 7}\n");
        let plan = Arc::new(sample_plan(96, 8));
        let key = PlanStoreKey { model: "m".into(), layer: 0, head_group: 1, n: 96 };
        let mut store = PlanStore::open(&path).unwrap();
        assert!(store.is_empty());
        assert!(store.insert(key.clone(), 8, plan.clone()));
        // Re-inserting the identical plan does not dirty the store.
        assert!(!store.insert(key.clone(), 8, plan.clone()));
        store.flush().unwrap();

        let mut reopened = PlanStore::open(&path).unwrap();
        assert_eq!(reopened.len(), 1);
        assert_eq!(*reopened.get(&key).unwrap(), *plan);
        let seeds = reopened.plans_for("m", 96);
        assert_eq!(seeds.len(), 1);
        assert_eq!(seeds[0].0, PlanKey::new(0, 1));
        assert_eq!(seeds[0].1, 8, "priced head dim rides along");
        assert!(reopened.plans_for("m", 128).is_empty());
        assert!(reopened.plans_for("other", 96).is_empty());
        assert_eq!(reopened.len_for_model("m"), 1);
        assert_eq!(reopened.len_compatible("m", "anchor", TileConfig::new(16, 16), 2), 1);
        assert_eq!(reopened.len_compatible("m", "anchor", TileConfig::new(16, 16), 4), 0);
        assert_eq!(reopened.len_compatible("m", "full-attn", TileConfig::new(16, 16), 2), 0);
        // Other manifest keys survive the rewrite.
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("other_key").as_usize(), Some(7));
        assert_eq!(doc.get("plan_store").get("version").as_usize(), Some(1));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn plan_store_requires_an_existing_manifest() {
        let missing = std::env::temp_dir().join("anchor_manifest_does_not_exist.json");
        let err = PlanStore::open(&missing).unwrap_err().to_string();
        assert!(err.contains("no runtime manifest"), "{err}");
        let not_obj = tmp_manifest("not_obj", "[1, 2]\n");
        assert!(PlanStore::open(&not_obj).is_err());
        let _ = std::fs::remove_file(&not_obj);
    }

    #[test]
    fn corrupted_store_entries_are_rejected_not_emptied() {
        let path = tmp_manifest("corrupt", "{}\n");
        let mut store = PlanStore::open(&path).unwrap();
        store.insert(
            PlanStoreKey { model: "m".into(), layer: 0, head_group: 0, n: 96 },
            8,
            Arc::new(sample_plan(96, 8)),
        );
        store.flush().unwrap();
        let good = std::fs::read_to_string(&path).unwrap();

        // Truncated file: not JSON at all.
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(PlanStore::open(&path).is_err());

        // Structurally valid JSON, corrupted index fields: open must error
        // — or, where the edit leaves the index self-consistent (a
        // coordinate rewrite open-time checks cannot see), every read of
        // the affected key must fail loudly instead of serving the
        // payload under a wrong identity.
        for (from, to) in [
            ("\"step\": 2", "\"step\": 0"),
            ("\"method\": \"anchor\"", "\"method\": \"mystery\""),
            ("\"n\": 96", "\"n\": 95"),
            ("\"version\": 1", "\"version\": 99"),
        ] {
            assert!(good.contains(from), "fixture drifted: {from}");
            std::fs::write(&path, good.replace(from, to)).unwrap();
            match PlanStore::open(&path) {
                Err(e) => assert!(!e.to_string().is_empty(), "{from} -> {to} must error"),
                Ok(opened) => {
                    for n in [96usize, 95] {
                        let k = PlanStoreKey { model: "m".into(), layer: 0, head_group: 0, n };
                        assert!(
                            opened.get(&k).is_none(),
                            "{from} -> {to}: corrupted entry must fail its read"
                        );
                    }
                }
            }
        }

        // The pristine store still reopens after the corruption sweep.
        std::fs::write(&path, &good).unwrap();
        assert!(PlanStore::open(&path).is_ok(), "pristine store must reopen");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir_all(segment::segments_dir(&path));
    }

    fn key(model: &str, group: u32, n: usize) -> PlanStoreKey {
        PlanStoreKey { model: model.into(), layer: 0, head_group: group, n }
    }

    /// Calibration constants round-trip per executor through the manifest:
    /// saving one backend preserves the other's entry and every unrelated
    /// manifest key, and corruption is an error, never silent defaults.
    #[test]
    fn calibration_round_trips_per_executor_and_preserves_keys() {
        let path = tmp_manifest("calibration", "{\"other_key\": 7}\n");
        assert_eq!(load_calibration(&path, ExecutorKind::Cpu).unwrap(), None);

        let cpu = CostConstants {
            ident_cost_frac: 0.2,
            plan_broadcast_frac: 0.003,
            span_ns_per_row: 1.5,
            gather_ns_per_row: 6.25,
            fold_ns_per_score: 0.75,
        };
        let pjrt = CostConstants { ident_cost_frac: 0.3, ..cpu };
        save_calibration(&path, ExecutorKind::Cpu, &cpu).unwrap();
        save_calibration(&path, ExecutorKind::Pjrt, &pjrt).unwrap();
        assert_eq!(load_calibration(&path, ExecutorKind::Cpu).unwrap(), Some(cpu));
        assert_eq!(load_calibration(&path, ExecutorKind::Pjrt).unwrap(), Some(pjrt));

        // Re-saving one backend keeps the other and the unrelated keys.
        let cpu2 = CostConstants { fold_ns_per_score: 0.5, ..cpu };
        save_calibration(&path, ExecutorKind::Cpu, &cpu2).unwrap();
        assert_eq!(load_calibration(&path, ExecutorKind::Cpu).unwrap(), Some(cpu2));
        assert_eq!(load_calibration(&path, ExecutorKind::Pjrt).unwrap(), Some(pjrt));
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("other_key").as_usize(), Some(7));
        assert_eq!(doc.get("calibration").get("version").as_usize(), Some(1));

        // Corrupted entries and version drift are rejected loudly.
        let good = std::fs::read_to_string(&path).unwrap();
        for (from, to) in [
            ("\"version\": 1", "\"version\": 99"),
            ("\"ident_cost_frac\": 0.2", "\"ident_cost_frac\": \"fast\""),
        ] {
            assert!(good.contains(from), "fixture drifted: {from}");
            std::fs::write(&path, good.replace(from, to)).unwrap();
            assert!(load_calibration(&path, ExecutorKind::Cpu).is_err(), "{from} -> {to}");
        }
        // Saving never creates a manifest from nothing.
        let missing = std::env::temp_dir().join("anchor_manifest_cal_missing.json");
        let _ = std::fs::remove_file(&missing);
        assert!(save_calibration(&missing, ExecutorKind::Cpu, &cpu).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compatible_filter_seeds_only_the_matching_slice() {
        let path = tmp_manifest("compat", "{}\n");
        let p8 = Arc::new(sample_plan(96, 8));
        let mut store = PlanStore::open(&path).unwrap();
        store.insert(key("m", 0, 96), 8, p8.clone());
        // Same geometry, different priced head dim: must not seed.
        store.insert(key("m", 1, 96), 4, Arc::new(sample_plan(96, 4)));
        // Different model: must not seed.
        store.insert(key("other", 2, 96), 8, p8.clone());
        store.flush().unwrap();
        drop(store);

        let tile = TileConfig::new(16, 16);
        let mut re = PlanStore::open(&path).unwrap();
        let seeds = re.plans_for_compatible("m", 96, "anchor", tile, 2, 8);
        assert_eq!(seeds.len(), 1);
        assert_eq!(seeds[0].0, PlanKey::new(0, 0));
        assert_eq!(*seeds[0].1, *p8);
        assert!(re.plans_for_compatible("m", 96, "anchor", tile, 4, 8).is_empty());
        assert!(re.plans_for_compatible("m", 96, "full-attn", tile, 2, 8).is_empty());
        assert!(re.plans_for_compatible("m", 96, "anchor", TileConfig::new(8, 8), 2, 8).is_empty());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir_all(segment::segments_dir(&path));
    }

    #[test]
    fn migration_imports_legacy_json_bitwise_once() {
        let path = tmp_manifest("migrate", "{\"other_key\": 7}\n");
        let plan = Arc::new(sample_plan(96, 8));
        write_legacy_json_store(
            &path,
            &[(key("m", 0, 96), 8, plan.clone()), (key("m", 1, 96), 8, plan.clone())],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"plan\""), "fixture must be the legacy inline-plan layout");

        // First open migrates; entries must survive bitwise.
        let store = PlanStore::open(&path).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(*store.get(&key("m", 0, 96)).unwrap(), *plan);
        drop(store);

        // The legacy blob is gone, replaced by the marked segmented index;
        // unrelated manifest keys survive.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.contains("\"plan\""), "legacy inline plans must not be rewritten");
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("plan_store").get("format").as_str(), Some(PLAN_STORE_FORMAT));
        assert_eq!(doc.get("plan_store").get("migrated_from").as_str(), Some("json-v1"));
        assert_eq!(doc.get("other_key").as_usize(), Some(7));

        let re = PlanStore::open(&path).unwrap();
        assert_eq!(re.len(), 2);
        assert_eq!(*re.get(&key("m", 1, 96)).unwrap(), *plan);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir_all(segment::segments_dir(&path));
    }

    #[test]
    fn compaction_merges_segments_and_removes_files() {
        let path = tmp_manifest("compact_merge", "{}\n");
        let plan = Arc::new(sample_plan(96, 8));
        let mut store = PlanStore::open(&path).unwrap();
        for g in 0..3 {
            store.insert(key("m", g, 96), 8, plan.clone());
            store.flush().unwrap(); // one new segment per flush
        }
        let dir = segment::segments_dir(&path);
        assert!(segment::list_files(&dir).unwrap().len() >= 3, "flushes must append segments");
        let stats = store.compact().unwrap();
        assert_eq!(stats.segments_after, 1);
        assert_eq!(stats.entries, 3);
        assert_eq!(segment::list_files(&dir).unwrap(), {
            let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
            let seg = doc.get("plan_store").get("entries").idx(0).get("segment");
            vec![seg.as_str().unwrap().to_string()]
        });
        // Everything still reads: through the live store and a fresh open.
        assert_eq!(*store.get(&key("m", 2, 96)).unwrap(), *plan);
        drop(store);
        let re = PlanStore::open(&path).unwrap();
        assert_eq!(re.len(), 3);
        for g in 0..3 {
            assert_eq!(*re.get(&key("m", g, 96)).unwrap(), *plan);
        }
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn max_entries_cap_evicts_lru_and_counts() {
        let path = tmp_manifest("cap_lru", "{}\n");
        let mut store = PlanStore::open(&path).unwrap();
        store.set_max_entries(Some(2));
        assert_eq!(store.max_entries(), Some(2));
        let plan = Arc::new(sample_plan(96, 8));
        store.insert(key("m", 0, 96), 8, plan.clone());
        store.insert(key("m", 1, 96), 8, plan.clone());
        assert_eq!((store.len(), store.evictions()), (2, 0));
        // Third insert overflows: the oldest-touched entry (group 0) goes,
        // never the entry just written.
        store.insert(key("m", 2, 96), 8, plan.clone());
        assert_eq!((store.len(), store.evictions()), (2, 1));
        assert!(store.get(&key("m", 0, 96)).is_none(), "LRU entry must evict");
        assert!(store.get(&key("m", 2, 96)).is_some(), "just-inserted entry survives");
        // Re-inserting an identical resident plan is a no-op, no eviction.
        assert!(!store.insert(key("m", 2, 96), 8, plan.clone()));
        assert_eq!(store.evictions(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn warm_pass_protects_seeded_entries_from_eviction() {
        let path = tmp_manifest("cap_warm", "{}\n");
        let plan96 = Arc::new(sample_plan(96, 8));
        let plan128 = Arc::new(sample_plan(128, 8));
        let mut store = PlanStore::open(&path).unwrap();
        // Cold entry at n=128, then the n=96 entry a session will warm from.
        store.insert(key("m", 0, 128), 8, plan128);
        store.insert(key("m", 0, 96), 8, plan96.clone());
        store.set_max_entries(Some(2));
        // Warm pass: seeding touches the n=96 entry...
        let seeds = store.plans_for("m", 96);
        assert_eq!(seeds.len(), 1);
        // ...so the next insert evicts the cold n=128 entry, never the one
        // the session just warmed from.
        store.insert(key("m", 1, 96), 8, plan96);
        assert_eq!(store.len(), 2);
        assert!(store.get(&key("m", 0, 96)).is_some(), "warmed entry must survive");
        assert!(store.get(&key("m", 0, 128)).is_none(), "cold entry evicts instead");
        assert_eq!(store.evictions(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cap_below_current_size_evicts_immediately_and_flushes() {
        let path = tmp_manifest("cap_shrink", "{}\n");
        let plan = Arc::new(sample_plan(96, 8));
        let mut store = PlanStore::open(&path).unwrap();
        for g in 0..4 {
            store.insert(key("m", g, 96), 8, plan.clone());
        }
        store.flush().unwrap();
        store.set_max_entries(Some(2));
        assert_eq!(store.len(), 2);
        assert_eq!(store.evictions(), 2);
        store.flush().unwrap();
        // The capped set persists: evicted keys are tombstoned out of the
        // flush union, so the stale on-disk copies are really deleted —
        // never resurrected past the bound — and evictions() stays 2.
        assert_eq!(store.evictions(), 2, "flush must not re-evict");
        let reopened = PlanStore::open(&path).unwrap();
        assert_eq!(reopened.len(), 2, "flush after eviction persists the capped set");
        let _ = std::fs::remove_file(&path);
    }
}
